//! Streaming layer: single-pass incremental TSQR over unbounded row
//! streams.
//!
//! The paper's Direct TSQR makes "slightly more than 2 passes" over a
//! materialized matrix. For R-only / Σ-only workloads the sequential
//! communication-optimal TSQR of Demmel et al. (arXiv:0809.2407)
//! collapses that to **one pass over rows that never exist in full**:
//! each arriving chunk folds into a running `R` via repeated
//! `[R; chunk] → qr` reduction ([`fold::RFold`]), with the binary
//! fold tree bounded at `O(log m)` depth so resident state stays
//! `O(n²)` for any stream length.
//!
//! Two front-ends drive the core:
//!
//! * [`crate::session::StreamingWriter`]
//!   ([`crate::session::TsqrSession::stream`]) — in-process streaming
//!   with optional Q retention: leaf `Q`s spill to the DFS as chunk
//!   recipes, and `finalize_qr()` replays the Direct-TSQR Q-formation
//!   over the fold tree.
//! * The wire protocol's `StreamFold` opcode (v4) — a remote peer
//!   opens a fold on the serving side, pushes chunks, and gets the
//!   final `R` back; `mrtsqr stream` drives the same core from the
//!   CLI over chunked stdin or a seeded generator.
//!
//! The determinism contract extends to streams: **`R`/Σ bits are
//! invariant to chunk size and arrival interleaving** at every
//! `(host_threads, shards, procs, hosts)` setting, because the fold
//! tree is shaped by row count alone — never by timing. See
//! [`fold`] for the mechanics and `rust/tests/stream.rs` for the
//! enforcement.

pub mod fold;

pub use fold::{FoldStats, FoldTree, LeafTransform, RFold};

use crate::linalg::{jacobi_svd, Matrix};

/// Digest of a streamed result, bit-compatible with
/// [`crate::session::Factorization::result_digest`] (same FNV-1a over
/// `R` shape/bits + Σ), so streamed and batch reports diff with one
/// `grep result_digest` recipe.
pub fn result_digest(r: &Matrix, sigma: Option<&[f64]>) -> String {
    crate::util::digest::r_sigma_digest(r, sigma)
}

/// Singular values of a streamed (square) `R`, descending — the Σ of
/// the stream, since `A` and `R` share singular values.
pub fn sigma_from_r(r: &Matrix) -> Vec<f64> {
    jacobi_svd(r).sigma
}
