//! Incremental single-pass R-fold: sequential communication-optimal
//! TSQR (Demmel, Grigori, Hoemmen & Langou, arXiv:0809.2407) over an
//! unbounded row stream.
//!
//! Rows arrive in whatever chunking the producer likes; [`RFold`]
//! re-buffers them into *canonical leaf blocks* of exactly
//! `chunk_rows` rows (the last block may be ragged). Each full leaf is
//! reduced to its triangular factor with the blocked compact-WY kernel
//! ([`crate::linalg::householder_qr`]), and leaf `R`s fold pairwise
//! through a binary-counter stack: two same-level `R`s combine via
//! `qr([R_older; R_newer])` and carry one level up. After `m` rows the
//! stack holds at most `⌈log₂(m/chunk_rows)⌉ ≤ 64` small factors, so
//! the resident state is `O(n²)` (with a log-bounded constant) no
//! matter how long the stream runs, and the final `R` is available
//! immediately after the last row lands — **one pass, and the raw
//! input never exists in full anywhere**.
//!
//! # Determinism
//!
//! The fold tree's shape is a pure function of `(total rows,
//! chunk_rows, cols)`: leaves are cut at exact multiples of
//! `chunk_rows` regardless of push granularity, and the binary counter
//! folds in arrival order. Pushing one row at a time, a thousand at a
//! time, or the whole matrix in one call therefore produces
//! bit-identical `R`/Σ — the streaming extension of the repo-wide
//! determinism contract (`rust/tests/stream.rs` enforces it).
//!
//! # Q formation
//!
//! In [`RFold::record_q`] mode each factored leaf's thin `Q` is handed
//! to the caller (a [`crate::session::StreamingWriter`] spills it to
//! the DFS as a *chunk recipe*) and every join keeps its small
//! `(≤2n)×n` factor in an arena. [`FoldTree::leaf_transforms`] then
//! replays the Direct-TSQR step-3 recursion top-down — `S_root = I`,
//! `[S_left; S_right] = Q_join · S_join` — giving the `n×n` transform
//! each spilled leaf `Q` must be multiplied by to yield its slice of
//! the full `Q`.

use anyhow::{ensure, Result};

use crate::linalg::{householder_qr, Matrix};

/// Sentinel node id used when Q recording is off.
const NO_NODE: usize = usize::MAX;

/// One node of the fold tree (only materialized in `record_q` mode).
#[derive(Clone, Debug)]
pub enum FoldNode {
    /// A canonical input block of `rows` raw rows. `factored` is false
    /// only for blocks shorter than `cols` (ragged tail or tiny
    /// streams), whose rows are kept verbatim instead of being QR'd.
    Leaf { index: usize, rows: usize, factored: bool },
    /// `[R_left; R_right] = q · R_this`. `q` is `None` when the stack
    /// was still shorter than `cols` rows (no reduction happened);
    /// the children's factors were just concatenated.
    Join { left: usize, right: usize, rows_left: usize, rows_right: usize, q: Option<Matrix> },
}

/// Pass/size accounting for a completed fold.
#[derive(Clone, Debug, Default)]
pub struct FoldStats {
    /// Raw rows pushed into the fold.
    pub rows: u64,
    /// Stream width.
    pub cols: usize,
    /// Canonical leaf block height.
    pub chunk_rows: usize,
    /// Leaf blocks cut (⌈rows / chunk_rows⌉).
    pub leaves: usize,
    /// Pairwise `[R;R] → qr` reductions performed.
    pub folds: usize,
    /// Raw input rows consumed out of the arrival buffer. Every row
    /// leaves the buffer exactly once, so `rows_consumed == rows` is
    /// the single-pass invariant ([`FoldStats::input_passes`]).
    pub rows_consumed: u64,
    /// High-water mark of resident rows: arrival buffer + every stack
    /// `R` + undrained leaf-Q spill. Compare against `rows` to see the
    /// streaming win.
    pub peak_resident_rows: usize,
    /// Deepest binary-counter level reached (≤ 64 for any physical
    /// stream).
    pub max_depth: usize,
}

impl FoldStats {
    /// Passes over the raw input: exactly 1 for any stream that folded
    /// each row once (0 for an empty stream).
    pub fn input_passes(&self) -> u64 {
        if self.rows == 0 {
            0
        } else {
            self.rows_consumed.div_ceil(self.rows)
        }
    }
}

/// The completed fold tree, for Q replay. See the module docs.
#[derive(Clone, Debug)]
pub struct FoldTree {
    nodes: Vec<FoldNode>,
    root: usize,
    /// Height of the root factor (== `cols` once rows ≥ cols).
    root_rows: usize,
}

/// One leaf's share of the Q-formation replay.
#[derive(Clone, Debug)]
pub struct LeafTransform {
    /// Canonical leaf index (row range `[index·chunk_rows, …)`).
    pub index: usize,
    /// Raw rows in this leaf.
    pub rows: usize,
    /// Whether the leaf was QR'd (its thin `Q` was emitted) or kept
    /// verbatim (its `Q` is implicitly the identity).
    pub factored: bool,
    /// The transform: final `Q` rows of this leaf are
    /// `Q_leaf · transform` when factored, `transform` itself when not.
    pub transform: Matrix,
}

impl FoldTree {
    /// Replay Direct-TSQR step 3 top-down, returning one transform per
    /// leaf in ascending leaf order.
    pub fn leaf_transforms(&self) -> Vec<LeafTransform> {
        let mut out = Vec::new();
        let mut stack = vec![(self.root, Matrix::identity(self.root_rows))];
        while let Some((id, s)) = stack.pop() {
            match &self.nodes[id] {
                FoldNode::Leaf { index, rows, factored } => {
                    out.push(LeafTransform {
                        index: *index,
                        rows: *rows,
                        factored: *factored,
                        transform: s,
                    });
                }
                FoldNode::Join { left, right, rows_left, rows_right, q } => {
                    let prod = match q {
                        Some(q) => q.matmul(&s),
                        None => s,
                    };
                    debug_assert_eq!(prod.rows, rows_left + rows_right);
                    stack.push((*left, prod.slice_rows(0, *rows_left)));
                    stack.push((*right, prod.slice_rows(*rows_left, prod.rows)));
                }
            }
        }
        out.sort_by_key(|t| t.index);
        out
    }
}

/// A pending factor on the binary-counter stack.
struct Slot {
    r: Matrix,
    node: usize,
}

/// The incremental fold. See the module docs for the contract.
pub struct RFold {
    cols: usize,
    chunk_rows: usize,
    record_q: bool,
    /// Arrival buffer: `buf_rows` rows of the next (partial) leaf.
    buf: Vec<f64>,
    buf_rows: usize,
    next_leaf: usize,
    /// Binary counter: `levels[k]` holds the fold of a run of `2^k`
    /// leaves, higher levels older.
    levels: Vec<Option<Slot>>,
    /// Node arena (`record_q` only).
    nodes: Vec<FoldNode>,
    /// Factored leaf `Q`s awaiting [`RFold::drain_leaf_q`]
    /// (`record_q` only).
    pending_q: Vec<(usize, Matrix)>,
    pending_q_rows: usize,
    stats: FoldStats,
}

impl RFold {
    /// A fold over `cols`-wide rows with canonical leaf blocks of
    /// `chunk_rows` (clamped to ≥ 1).
    pub fn new(cols: usize, chunk_rows: usize) -> Self {
        let chunk_rows = chunk_rows.max(1);
        RFold {
            cols,
            chunk_rows,
            record_q: false,
            buf: Vec::new(),
            buf_rows: 0,
            next_leaf: 0,
            levels: Vec::new(),
            nodes: Vec::new(),
            pending_q: Vec::new(),
            pending_q_rows: 0,
            stats: FoldStats { cols, chunk_rows, ..FoldStats::default() },
        }
    }

    /// Turn on Q recording. Must be called before any rows arrive; the
    /// caller is responsible for draining [`RFold::drain_leaf_q`] after
    /// every push (the fold counts undrained spill as resident).
    pub fn record_q(&mut self) -> Result<()> {
        ensure!(self.stats.rows == 0, "record_q must be enabled before the first row");
        self.record_q = true;
        Ok(())
    }

    /// Rows pushed so far.
    pub fn rows(&self) -> u64 {
        self.stats.rows
    }

    /// Whether Q recording is on.
    pub fn records_q(&self) -> bool {
        self.record_q
    }

    /// Stream width.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Running accounting (final numbers come from `finish_*`).
    pub fn stats(&self) -> &FoldStats {
        &self.stats
    }

    /// Push one row.
    pub fn push_row(&mut self, row: &[f64]) -> Result<()> {
        ensure!(row.len() == self.cols, "row has {} values, stream is {} wide", row.len(), self.cols);
        self.buf.extend_from_slice(row);
        self.buf_rows += 1;
        self.stats.rows += 1;
        self.note_resident();
        if self.buf_rows == self.chunk_rows {
            self.close_leaf();
        }
        Ok(())
    }

    /// Push a chunk of rows (any height — the fold re-buffers into
    /// canonical leaves, so chunking never changes bits).
    pub fn push_chunk(&mut self, a: &Matrix) -> Result<()> {
        ensure!(a.cols == self.cols, "chunk is {} wide, stream is {} wide", a.cols, self.cols);
        let mut next = 0;
        while next < a.rows {
            let take = (self.chunk_rows - self.buf_rows).min(a.rows - next);
            for i in next..next + take {
                self.buf.extend_from_slice(a.row(i));
            }
            self.buf_rows += take;
            self.stats.rows += take as u64;
            next += take;
            self.note_resident();
            if self.buf_rows == self.chunk_rows {
                self.close_leaf();
            }
        }
        Ok(())
    }

    /// Take the factored leaf `Q`s produced since the last drain
    /// (ascending leaf index). Empty unless [`RFold::record_q`] is on.
    pub fn drain_leaf_q(&mut self) -> Vec<(usize, Matrix)> {
        self.pending_q_rows = 0;
        std::mem::take(&mut self.pending_q)
    }

    /// Finish the stream: fold the remaining stack into the final `R`.
    pub fn finish_r(self) -> Result<(Matrix, FoldStats)> {
        let (r, _, stats) = self.finish_tree()?;
        Ok((r, stats))
    }

    /// Finish the stream, keeping the fold tree for Q replay.
    pub fn finish_tree(mut self) -> Result<(Matrix, FoldTree, FoldStats)> {
        ensure!(self.stats.rows > 0, "cannot finalize an empty stream");
        if self.buf_rows > 0 {
            self.close_leaf();
        }
        // Merge survivors newest → oldest: level k joins *above* the
        // accumulated newer rows, mirroring input order.
        let mut acc: Option<Slot> = None;
        let levels = std::mem::take(&mut self.levels);
        for slot in levels.into_iter().flatten() {
            acc = Some(match acc {
                None => slot,
                Some(newer) => self.join(slot, newer),
            });
        }
        let root = acc.expect("non-empty stream folds to a root");
        let tree = FoldTree {
            nodes: std::mem::take(&mut self.nodes),
            root: root.node,
            root_rows: root.r.rows,
        };
        self.stats.peak_resident_rows = self.stats.peak_resident_rows.max(self.resident_rows());
        Ok((root.r, tree, self.stats))
    }

    fn resident_rows(&self) -> usize {
        self.buf_rows
            + self.pending_q_rows
            + self.levels.iter().flatten().map(|s| s.r.rows).sum::<usize>()
    }

    fn note_resident(&mut self) {
        let now = self.resident_rows();
        if now > self.stats.peak_resident_rows {
            self.stats.peak_resident_rows = now;
        }
    }

    /// Reduce the arrival buffer to a leaf factor and carry it into
    /// the binary counter.
    fn close_leaf(&mut self) {
        let rows = self.buf_rows;
        let index = self.next_leaf;
        self.next_leaf += 1;
        self.stats.leaves += 1;
        self.stats.rows_consumed += rows as u64;
        let block = Matrix::from_rows(rows, self.cols, std::mem::take(&mut self.buf));
        self.buf_rows = 0;
        let factored = rows >= self.cols;
        let r = if factored {
            let (q, r) = householder_qr(&block);
            if self.record_q {
                self.pending_q.push((index, q));
                self.pending_q_rows += rows;
            }
            r
        } else {
            block
        };
        let node = if self.record_q {
            self.nodes.push(FoldNode::Leaf { index, rows, factored });
            self.nodes.len() - 1
        } else {
            NO_NODE
        };
        self.insert(Slot { r, node }, 0);
        self.note_resident();
    }

    /// Carry a factor into the binary counter at `level`, folding on
    /// collision.
    fn insert(&mut self, mut slot: Slot, mut level: usize) {
        loop {
            if self.levels.len() <= level {
                self.levels.push(None);
            }
            if level + 1 > self.stats.max_depth {
                self.stats.max_depth = level + 1;
            }
            match self.levels[level].take() {
                None => {
                    self.levels[level] = Some(slot);
                    return;
                }
                Some(older) => {
                    slot = self.join(older, slot);
                    level += 1;
                }
            }
        }
    }

    /// `qr([R_older; R_newer])` (or plain concatenation while the stack
    /// is still shorter than `cols` rows).
    fn join(&mut self, older: Slot, newer: Slot) -> Slot {
        let rows_left = older.r.rows;
        let rows_right = newer.r.rows;
        let stacked = Matrix::vstack(&[&older.r, &newer.r]);
        let (r, q) = if stacked.rows >= self.cols {
            self.stats.folds += 1;
            let (q, r) = householder_qr(&stacked);
            (r, Some(q))
        } else {
            (stacked, None)
        };
        let node = if self.record_q {
            self.nodes.push(FoldNode::Join {
                left: older.node,
                right: newer.node,
                rows_left,
                rows_right,
                q,
            });
            self.nodes.len() - 1
        } else {
            NO_NODE
        };
        Slot { r, node }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::gaussian(rows, cols, &mut rng)
    }

    #[test]
    fn push_granularity_does_not_change_bits() {
        let a = gaussian(257, 6, 7);
        let mut one_shot = RFold::new(6, 32);
        one_shot.push_chunk(&a).unwrap();
        let (r_one, _) = one_shot.finish_r().unwrap();

        let mut by_row = RFold::new(6, 32);
        for i in 0..a.rows {
            by_row.push_row(a.row(i)).unwrap();
        }
        let (r_row, _) = by_row.finish_r().unwrap();
        assert_eq!(r_one.data, r_row.data);

        let mut ragged = RFold::new(6, 32);
        let mut next = 0;
        for (k, step) in [1usize, 7, 50, 3, 100, 96].iter().enumerate() {
            let end = (next + step).min(a.rows);
            ragged.push_chunk(&a.slice_rows(next, end)).unwrap();
            next = end;
            assert!(k < 6);
        }
        assert_eq!(next, a.rows);
        let (r_ragged, _) = ragged.finish_r().unwrap();
        assert_eq!(r_one.data, r_ragged.data);
    }

    #[test]
    fn fold_r_matches_direct_qr_factor() {
        let a = gaussian(300, 5, 11);
        let mut fold = RFold::new(5, 64);
        fold.push_chunk(&a).unwrap();
        let (r, stats) = fold.finish_r().unwrap();
        let (_, r_direct) = householder_qr(&a);
        // Same factor up to column signs; compare |R| and the Gram
        // identity RᵀR = AᵀA.
        assert_eq!(r.rows, 5);
        for i in 0..5 {
            for j in 0..5 {
                assert!((r[(i, j)].abs() - r_direct[(i, j)].abs()).abs() < 1e-9);
            }
        }
        assert_eq!(stats.input_passes(), 1);
        assert_eq!(stats.rows, 300);
        assert_eq!(stats.leaves, 5);
        assert!(stats.peak_resident_rows < 300);
    }

    #[test]
    fn q_replay_reconstructs_the_input() {
        let a = gaussian(190, 4, 3);
        let mut fold = RFold::new(4, 48);
        fold.record_q().unwrap();
        let mut leaf_q = Vec::new();
        fold.push_chunk(&a).unwrap();
        leaf_q.extend(fold.drain_leaf_q());
        let (r, tree, _) = fold.finish_tree().unwrap();
        let mut q_parts: Vec<Matrix> = Vec::new();
        for t in tree.leaf_transforms() {
            let part = if t.factored {
                let (idx, q) = leaf_q.remove(0);
                assert_eq!(idx, t.index);
                q.matmul(&t.transform)
            } else {
                t.transform.clone()
            };
            assert_eq!(part.rows, t.rows);
            q_parts.push(part);
        }
        let refs: Vec<&Matrix> = q_parts.iter().collect();
        let q = Matrix::vstack(&refs);
        let back = q.matmul(&r);
        assert_eq!(back.rows, a.rows);
        let diff = back.sub(&a).max_abs();
        assert!(diff < 1e-9, "QR replay drifted: {diff}");
        assert!(q.orthogonality_error() < 1e-9);
    }

    #[test]
    fn tiny_streams_shorter_than_cols_still_fold() {
        let a = gaussian(3, 8, 5);
        let mut fold = RFold::new(8, 1);
        fold.push_chunk(&a).unwrap();
        let (r, stats) = fold.finish_r().unwrap();
        // Fewer rows than columns: the "R" is the raw stack.
        assert_eq!((r.rows, r.cols), (3, 8));
        assert_eq!(stats.folds, 0);
        assert_eq!(r.data, a.data);
    }

    #[test]
    fn empty_stream_refuses_to_finalize() {
        let fold = RFold::new(4, 16);
        assert!(fold.finish_r().is_err());
    }
}
