//! Fault injection + Hadoop-style retry semantics.
//!
//! The paper's §V-C experiment crashes tasks with probability `p` and
//! measures the job-time penalty (23.2% at p = 1/8 for Direct TSQR).
//! We reproduce the semantics: each task *attempt* fails independently
//! with probability `p`; a failed attempt wastes a fraction of the
//! task's duration (the crash happens mid-task) and the scheduler
//! re-executes until success or `max_attempts`.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct FaultPolicy {
    /// Per-attempt crash probability.
    pub probability: f64,
    /// Attempts before the job is declared failed (Hadoop default: 4).
    pub max_attempts: usize,
    /// Fraction of the task duration wasted by a failed attempt.
    pub waste_fraction: f64,
}

impl FaultPolicy {
    pub fn new(probability: f64) -> Self {
        FaultPolicy { probability, max_attempts: 4, waste_fraction: 0.5 }
    }

    pub fn none() -> Self {
        FaultPolicy { probability: 0.0, max_attempts: 1, waste_fraction: 0.0 }
    }
}

impl Default for FaultPolicy {
    fn default() -> Self {
        Self::none()
    }
}

/// Outcome of running one task under the fault policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttemptOutcome {
    /// Total attempts (1 = no faults).
    pub attempts: usize,
    /// Virtual-time multiplier ≥ 1 for the task's duration:
    /// `(attempts-1) * waste_fraction + 1`.
    pub duration_factor: f64,
    /// Whether the task ultimately succeeded.
    pub succeeded: bool,
}

/// Draw the attempt sequence for one task.
pub fn draw_attempts(policy: &FaultPolicy, rng: &mut Rng) -> AttemptOutcome {
    let mut attempts = 1;
    while rng.chance(policy.probability) {
        if attempts >= policy.max_attempts {
            return AttemptOutcome {
                attempts,
                duration_factor: 1.0 + (attempts as f64) * policy.waste_fraction,
                succeeded: false,
            };
        }
        attempts += 1;
    }
    AttemptOutcome {
        attempts,
        duration_factor: 1.0 + (attempts as f64 - 1.0) * policy.waste_fraction,
        succeeded: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_faults_single_attempt() {
        let mut rng = Rng::new(1);
        let o = draw_attempts(&FaultPolicy::none(), &mut rng);
        assert_eq!(o, AttemptOutcome { attempts: 1, duration_factor: 1.0, succeeded: true });
    }

    #[test]
    fn always_fails_hits_max_attempts() {
        let mut rng = Rng::new(2);
        let policy = FaultPolicy { probability: 1.0, max_attempts: 3, waste_fraction: 0.5 };
        let o = draw_attempts(&policy, &mut rng);
        assert_eq!(o.attempts, 3);
        assert!(!o.succeeded);
    }

    #[test]
    fn retry_frequency_matches_probability() {
        let mut rng = Rng::new(3);
        let policy = FaultPolicy::new(0.125);
        let n = 100_000;
        let total_attempts: usize =
            (0..n).map(|_| draw_attempts(&policy, &mut rng).attempts).sum();
        // E[attempts] = 1/(1-p) = 1.1428…
        let mean = total_attempts as f64 / n as f64;
        assert!((mean - 1.0 / (1.0 - 0.125)).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn duration_factor_grows_with_retries() {
        let policy = FaultPolicy { probability: 1.0, max_attempts: 2, waste_fraction: 0.5 };
        let mut rng = Rng::new(4);
        let o = draw_attempts(&policy, &mut rng);
        assert_eq!(o.attempts, 2);
        assert!(o.duration_factor > 1.0);
    }
}
