//! Slot-limited virtual-time scheduling.
//!
//! The cluster has `m_max` map slots and `r_max` reduce slots (paper:
//! 40 + 40 on the 10-node ICME cluster). Tasks are placed greedily onto
//! the least-loaded slot in longest-processing-time order — the classic
//! LPT list schedule, a ≤4/3 approximation of optimal makespan, which is
//! more than enough fidelity for reproducing wave effects (1200 tasks on
//! 40 slots = 30 waves).

/// LPT makespan of `durations` over `slots` identical slots.
pub fn makespan(durations: &[f64], slots: usize) -> f64 {
    if durations.is_empty() {
        return 0.0;
    }
    let slots = slots.max(1).min(durations.len());
    let mut order: Vec<usize> = (0..durations.len()).collect();
    order.sort_by(|&a, &b| durations[b].partial_cmp(&durations[a]).unwrap());
    // binary-heap-free least-loaded selection: slots is small (≤ ~64)
    let mut load = vec![0.0f64; slots];
    for &i in &order {
        let (argmin, _) = load
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        load[argmin] += durations[i];
    }
    load.into_iter().fold(0.0, f64::max)
}

/// Effective parallelism of a stage: `min(slots, tasks)` — and for
/// reduce stages additionally the number of distinct keys (a reducer
/// with no keys does nothing; paper §II-A's `p_j^r = min{r_max, r_j, k_j}`).
pub fn effective_parallelism(slots: usize, tasks: usize, distinct_keys: Option<usize>) -> usize {
    let p = slots.min(tasks);
    match distinct_keys {
        Some(k) => p.min(k.max(1)),
        None => p,
    }
    .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        assert_eq!(makespan(&[], 4), 0.0);
    }

    #[test]
    fn single_slot_sums() {
        let d = [1.0, 2.0, 3.0];
        assert!((makespan(&d, 1) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn enough_slots_takes_max() {
        let d = [1.0, 2.0, 3.0];
        assert!((makespan(&d, 10) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn waves_of_equal_tasks() {
        // 8 tasks of 1s on 4 slots = 2 waves = 2s
        let d = [1.0f64; 8];
        assert!((makespan(&d, 4) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lpt_balances() {
        // LPT on {4, 3, 3, 2, 2, 2} over 2 slots -> 8 (optimal)
        let d = [4.0, 3.0, 3.0, 2.0, 2.0, 2.0];
        assert!((makespan(&d, 2) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn parallelism_caps() {
        assert_eq!(effective_parallelism(40, 1200, None), 40);
        assert_eq!(effective_parallelism(40, 4, None), 4);
        // Cholesky QR reduce: n distinct keys cap the reducers (paper)
        assert_eq!(effective_parallelism(40, 40, Some(4)), 4);
        assert_eq!(effective_parallelism(40, 40, Some(1000)), 40);
        assert_eq!(effective_parallelism(40, 0, None), 1);
    }
}
