//! The MapReduce job runner.
//!
//! Executes a [`JobSpec`] over the simulated DFS: split → map (with
//! fault-injected attempts) → shuffle (group + partition) → reduce →
//! write outputs. Every byte is metered and charged to the virtual disk
//! clock; the step's virtual duration is the slot-scheduled makespan of
//! its task durations plus the per-iteration startup, mirroring the
//! paper's model `T = Σ_j (R_j β_r + W_j β_w)/p_j` with wave effects.
//!
//! # Virtual vs host parallelism
//!
//! Two independent notions of parallelism coexist:
//!
//! * **virtual** — the paper's `m_max`/`r_max` slot schedule, which
//!   drives `virtual_secs` and is what the evaluation tables measure;
//! * **host** — the real OS threads that execute task bodies. Map and
//!   reduce waves fan out over a [`ClusterConfig::host_threads`]-sized
//!   `std::thread::scope` worker pool (task bodies are `Send + Sync`,
//!   see [`super::job`]).
//!
//! The two never interact: fault draws are forked from the engine RNG in
//! task-id order *before* the wave is dispatched, and task emissions are
//! merged back in task-id order afterwards, so DFS contents, shuffle
//! grouping, fault draws, and every [`StepStats`] field except the
//! wall-clock measurements (`wall_secs`, `map_compute_secs`,
//! `reduce_compute_secs`, and the recorded `host_threads`) are
//! byte-identical whatever the pool size. The virtual clock charges only
//! the deterministic model quantities (metered bytes, startup costs,
//! fault duration factors) — measured host compute time is reported in
//! the wall-clock fields but never folded into `virtual_secs`, which is
//! what makes the guarantee hold (`rust/tests/parallel.rs` enforces it).
//!
//! The same determinism carries one level up: the serving layer runs a
//! *pool* of identically-configured engines
//! ([`crate::session::SessionBuilder::engine_shards`]) and, because an
//! engine's outputs depend only on its inputs and the job-scoped fault
//! RNG — never on engine identity — which engine of the pool serves a
//! job is invisible in everything but wall clock
//! ([`crate::mapreduce::JobStats::shard`] records the placement;
//! `rust/tests/shards.rs` enforces the invariant).

use super::fault::{draw_attempts, AttemptOutcome, FaultPolicy};
use super::job::{Emitter, JobSpec, KeyGroup};
use super::metrics::StepStats;
use super::scheduler::{effective_parallelism, makespan};
use super::shuffle::{group_by_key, partition};
use crate::dfs::{Dfs, DiskModel, Record};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Default host worker-thread count: everything the machine offers.
pub fn default_host_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Cluster slot configuration (paper: m_max = r_max = 40) plus the host
/// execution pool size.
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    pub map_slots: usize,
    pub reduce_slots: usize,
    /// OS threads executing task bodies (host parallelism — orthogonal
    /// to the virtual slot schedule). `1` runs tasks inline on the
    /// calling thread; the default is the machine's available
    /// parallelism. Results are bit-identical for every value.
    pub host_threads: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { map_slots: 40, reduce_slots: 40, host_threads: default_host_threads() }
    }
}

impl ClusterConfig {
    /// This configuration with a different host pool size.
    pub fn with_host_threads(mut self, n: usize) -> Self {
        self.host_threads = n.max(1);
        self
    }
}

/// What one task execution hands back to the merge phase.
struct TaskOutput {
    em: Emitter,
    /// Measured wall-clock seconds inside the task body (diagnostic
    /// only — never charged to the virtual clock).
    compute_secs: f64,
    /// Bytes read from the task's input split (map waves; reduce waves
    /// account their input bytes in the pre-draw pass and leave this 0).
    in_bytes: u64,
}

/// Run `n` task bodies over a `workers`-thread scoped pool, returning
/// the outputs in task order. With one worker the tasks run inline on
/// the calling thread. On failure the pool stops claiming new tasks
/// (fast-fail, like the serial loop) and the lowest-task-id error among
/// the tasks that ran is returned.
fn run_tasks<T, F>(workers: usize, n: usize, task: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if n == 0 {
        return Ok(Vec::new());
    }
    if workers <= 1 || n == 1 {
        return (0..n).map(task).collect();
    }
    let slots: Vec<Mutex<Option<Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = task(i);
                if out.is_err() {
                    failed.store(true, Ordering::Relaxed);
                }
                *slots[i].lock().expect("task slot") = Some(out);
            });
        }
    });
    // merge in task-id order; a slot left `None` was skipped after some
    // other task failed, and that failure is present in another slot
    let mut results: Vec<Option<Result<T>>> = slots
        .into_iter()
        .map(|s| s.into_inner().expect("task slot poisoned"))
        .collect();
    if let Some(err_slot) = results.iter_mut().find(|r| matches!(r, Some(Err(_)))) {
        match err_slot.take() {
            Some(Err(e)) => return Err(e),
            _ => unreachable!("just matched Some(Err(_))"),
        }
    }
    results
        .into_iter()
        .map(|r| match r {
            Some(Ok(t)) => Ok(t),
            _ => unreachable!("no failure recorded, so every task index ran"),
        })
        .collect()
}

/// Default seed of the engine's fault RNG when no fault policy is set.
const DEFAULT_FAULT_SEED: u64 = 0x7153_71A5_u64;

/// The engine: DFS + disk model + cluster + fault policy.
pub struct Engine {
    pub dfs: Dfs,
    pub model: DiskModel,
    pub cluster: ClusterConfig,
    pub faults: FaultPolicy,
    rng: Rng,
    fault_seed: u64,
}

impl Engine {
    pub fn new(model: DiskModel, cluster: ClusterConfig) -> Self {
        Engine {
            dfs: Dfs::new(),
            model,
            cluster,
            faults: FaultPolicy::none(),
            rng: Rng::new(DEFAULT_FAULT_SEED),
            fault_seed: DEFAULT_FAULT_SEED,
        }
    }

    pub fn with_faults(mut self, faults: FaultPolicy, seed: u64) -> Self {
        self.faults = faults;
        self.rng = Rng::new(seed);
        self.fault_seed = seed;
        self
    }

    /// The seed the engine's internal fault RNG was built from. The job
    /// service derives an *independent* per-job fault stream from this
    /// (`Rng` handed to [`Engine::run_with_rng`]), so concurrent jobs
    /// sharing one engine draw faults deterministically regardless of
    /// how their steps interleave.
    pub fn fault_seed(&self) -> u64 {
        self.fault_seed
    }

    /// Fault outcome for one task, forked from `rng`. Always called in
    /// task-id order (before any wave is dispatched) so the draw
    /// sequence is independent of the host pool size.
    fn draw_task_outcome(faults: &FaultPolicy, rng: &mut Rng, stream: u64) -> AttemptOutcome {
        let mut task_rng = rng.fork(stream);
        draw_attempts(faults, &mut task_rng)
    }

    /// Virtual write cost of one task's emissions under the job's
    /// per-channel byte scales.
    fn write_virtual(spec: &JobSpec, em: &Emitter) -> f64 {
        let main_bytes: u64 = em.main.iter().map(|r| r.size_bytes()).sum();
        let mut virt = main_bytes as f64 * spec.output_scale;
        for (chan, rec) in &em.side {
            let scale = spec
                .side_outputs
                .iter()
                .find(|(c, _, _)| c == chan)
                .map(|(_, _, s)| *s)
                .unwrap_or(1.0);
            virt += rec.size_bytes() as f64 * scale;
        }
        virt
    }

    /// Run one MapReduce job; outputs land in the DFS, metrics returned.
    /// Fault outcomes draw from the engine's own RNG, whose state
    /// threads across successive `run` calls (the single-session
    /// behavior).
    pub fn run(&mut self, spec: &JobSpec) -> Result<StepStats> {
        let mut rng = self.rng.clone();
        let out = self.run_with_rng(spec, &mut rng);
        self.rng = rng;
        out
    }

    /// Like [`Engine::run`], but drawing fault outcomes from an
    /// explicit RNG. The concurrent job service gives every job its own
    /// stream (derived from [`Engine::fault_seed`] and the job id), so
    /// fault draws stay deterministic however concurrent jobs interleave
    /// their steps on the shared engine.
    pub fn run_with_rng(&mut self, spec: &JobSpec, fault_rng: &mut Rng) -> Result<StepStats> {
        let wall_start = Instant::now();
        let mut stats = StepStats { name: spec.name.clone(), ..Default::default() };

        // ---- split input ----
        let splits = self
            .dfs
            .splits(&spec.input, spec.map_tasks)
            .with_context(|| format!("job {:?}: splitting input", spec.name))?;
        stats.map_tasks = splits.len();

        // side-input (distributed cache) bytes are read by *every* task
        let mut side_bytes = 0u64;
        let mut side_virtual = 0.0f64;
        let mut side_recs: u64 = 0;
        for f in &spec.side_inputs {
            side_bytes += self.dfs.file_bytes(f)?;
            side_virtual += self.dfs.virtual_bytes(f)?;
            side_recs += self.dfs.file_records(f)? as u64;
        }
        let input_scale = self.dfs.scale(&spec.input);

        // ---- map stage ----
        // fault draws first, in task-id order (see draw_task_outcome)
        let mut map_outcomes = Vec::with_capacity(splits.len());
        for task_id in 0..splits.len() {
            let outcome = Self::draw_task_outcome(&self.faults, fault_rng, task_id as u64);
            if !outcome.succeeded {
                bail!("job {:?}: map task {task_id} exceeded max attempts", spec.name);
            }
            stats.map_attempts += outcome.attempts;
            stats.faults += outcome.attempts - 1;
            map_outcomes.push(outcome);
        }

        let workers = self.cluster.host_threads.max(1);
        stats.host_threads = workers.min(splits.len().max(1));
        let dfs = &self.dfs;
        // Batched dispatch: the mapper's hint partitions the wave's task
        // ids into fixed contiguous chunks *before* scheduling, so the
        // chunking — like fault draws — is independent of host_threads
        // and emissions merge in the same task-id order either way.
        let hint = spec.mapper.batch_hint().max(1);
        let map_results: Vec<TaskOutput> = if hint <= 1 {
            run_tasks(workers, splits.len(), |task_id| {
                let input = dfs.read_split(&spec.input, splits[task_id])?;
                let in_bytes: u64 = input.iter().map(|r| r.size_bytes()).sum();
                let side_refs: Vec<&[Record]> = spec
                    .side_inputs
                    .iter()
                    .map(|f| dfs.get(f))
                    .collect::<Result<_>>()?;
                let mut em = Emitter::new();
                let t0 = Instant::now();
                spec.mapper
                    .run(task_id, input, &side_refs, &mut em)
                    .with_context(|| format!("job {:?}: map task {task_id}", spec.name))?;
                Ok(TaskOutput { em, compute_secs: t0.elapsed().as_secs_f64(), in_bytes })
            })?
        } else {
            let chunks = splits.len().div_ceil(hint);
            let nested: Vec<Vec<TaskOutput>> = run_tasks(workers, chunks, |chunk| {
                let lo = chunk * hint;
                let hi = (lo + hint).min(splits.len());
                let inputs: Vec<&[Record]> = (lo..hi)
                    .map(|t| dfs.read_split(&spec.input, splits[t]))
                    .collect::<Result<_>>()?;
                let side_refs: Vec<&[Record]> = spec
                    .side_inputs
                    .iter()
                    .map(|f| dfs.get(f))
                    .collect::<Result<_>>()?;
                let mut ems: Vec<Emitter> = (lo..hi).map(|_| Emitter::new()).collect();
                let t0 = Instant::now();
                spec.mapper
                    .run_batch(lo, &inputs, &side_refs, &mut ems)
                    .with_context(|| format!("job {:?}: map tasks {lo}..{hi}", spec.name))?;
                let batch_secs = t0.elapsed().as_secs_f64();
                Ok(ems
                    .into_iter()
                    .enumerate()
                    .map(|(k, em)| TaskOutput {
                        em,
                        // one fused kernel call per chunk: attribute its
                        // wall time to the chunk's first task (the field
                        // is only ever summed into map_compute_secs)
                        compute_secs: if k == 0 { batch_secs } else { 0.0 },
                        in_bytes: inputs[k].iter().map(|r| r.size_bytes()).sum(),
                    })
                    .collect())
            })?;
            nested.into_iter().flatten().collect()
        };

        // merge in task-id order: byte accounting, durations, emissions
        let mut map_durations = Vec::with_capacity(splits.len());
        let mut shuffle_input: Vec<Record> = Vec::new();
        let mut side_out: Vec<(String, Record)> = Vec::new();
        for ((task, &split), outcome) in map_results.into_iter().zip(&splits).zip(&map_outcomes) {
            let in_bytes = task.in_bytes;
            let mut em = task.em;
            let out_bytes = em.bytes_emitted();
            stats.map_io.add_read(in_bytes + side_bytes, (split.1 - split.0) as u64 + side_recs);
            stats.map_io.add_write(out_bytes, em.records_emitted());
            stats.map_compute_secs += task.compute_secs;

            // per-file virtual scaling: input/side at their registered
            // scales; main emissions at output_scale; side emissions at
            // their channel's scale
            let disk = self.model.read_secs_f(in_bytes as f64 * input_scale + side_virtual)
                + self.model.write_secs_f(Self::write_virtual(spec, &em));
            map_durations.push((disk + self.model.task_startup_secs) * outcome.duration_factor);

            shuffle_input.append(&mut em.main);
            side_out.append(&mut em.side);
        }
        let p_m = effective_parallelism(self.cluster.map_slots, stats.map_tasks, None);
        let mut virtual_secs =
            self.model.iteration_startup_secs + makespan(&map_durations, p_m);

        // ---- reduce stage (if any) ----
        let mut final_output: Vec<Record> = Vec::new();
        if let Some(reducer) = spec.reducer {
            let groups = group_by_key(shuffle_input);
            stats.distinct_keys = groups.len();
            let parts = partition(groups, spec.reduce_tasks.max(1));
            stats.reduce_tasks = parts.iter().filter(|p| !p.is_empty()).count();

            // fault draws in rid order, skipping empty partitions (the
            // serial engine never forked for those)
            struct ReduceWork {
                rid: usize,
                groups: Vec<KeyGroup>,
                outcome: AttemptOutcome,
                in_bytes: u64,
                in_records: u64,
            }
            let mut work: Vec<ReduceWork> = Vec::new();
            for (rid, part) in parts.into_iter().enumerate() {
                if part.is_empty() {
                    continue;
                }
                let outcome =
                    Self::draw_task_outcome(&self.faults, fault_rng, 0x8000_0000 + rid as u64);
                if !outcome.succeeded {
                    bail!("job {:?}: reduce task {rid} exceeded max attempts", spec.name);
                }
                stats.reduce_attempts += outcome.attempts;
                stats.faults += outcome.attempts - 1;

                let in_bytes: u64 = part
                    .iter()
                    .map(|(k, vs)| {
                        (k.len() * vs.len()) as u64
                            + vs.iter().map(|v| v.len() as u64).sum::<u64>()
                    })
                    .sum();
                let in_records: u64 = part.values().map(|v| v.len() as u64).sum();
                work.push(ReduceWork {
                    rid,
                    groups: part.into_iter().collect(),
                    outcome,
                    in_bytes,
                    in_records,
                });
            }

            stats.host_threads = stats.host_threads.max(workers.min(work.len().max(1)));
            let reduce_results = run_tasks(workers, work.len(), |i| {
                let item = &work[i];
                let mut em = Emitter::new();
                let t0 = Instant::now();
                reducer
                    .run(&item.groups, &mut em)
                    .with_context(|| format!("job {:?}: reduce task {}", spec.name, item.rid))?;
                Ok(TaskOutput { em, compute_secs: t0.elapsed().as_secs_f64(), in_bytes: 0 })
            })?;

            let mut reduce_durations = Vec::with_capacity(work.len());
            for (task, item) in reduce_results.into_iter().zip(&work) {
                let mut em = task.em;
                let out_bytes = em.bytes_emitted();
                stats.reduce_io.add_read(item.in_bytes, item.in_records);
                stats.reduce_io.add_write(out_bytes, em.records_emitted());
                stats.reduce_compute_secs += task.compute_secs;

                // shuffle traffic carries the main channel's scale
                let disk = self.model.read_secs_f(item.in_bytes as f64 * spec.output_scale)
                    + self.model.write_secs_f(Self::write_virtual(spec, &em));
                reduce_durations
                    .push((disk + self.model.task_startup_secs) * item.outcome.duration_factor);

                final_output.append(&mut em.main);
                side_out.append(&mut em.side);
            }
            let p_r = effective_parallelism(
                self.cluster.reduce_slots,
                spec.reduce_tasks.max(1),
                Some(stats.distinct_keys),
            );
            virtual_secs += makespan(&reduce_durations, p_r);
        } else {
            // map-only job: default channel goes straight to the output
            final_output = shuffle_input;
        }

        // ---- write outputs to DFS (registering their virtual scales) ----
        self.dfs.put(&spec.output, final_output);
        self.dfs.set_scale(&spec.output, spec.output_scale);
        // route side-channel records to their configured files
        for (channel, file, scale) in &spec.side_outputs {
            let recs: Vec<Record> = side_out
                .iter()
                .filter(|(c, _)| c == channel)
                .map(|(_, r)| r.clone())
                .collect();
            self.dfs.put(file, recs);
            self.dfs.set_scale(file, *scale);
        }
        // any side emissions without a configured channel are an error
        for (c, _) in &side_out {
            if !spec.side_outputs.iter().any(|(ch, _, _)| ch == c) {
                bail!("job {:?}: emission to unconfigured side channel {c:?}", spec.name);
            }
        }

        stats.virtual_secs = virtual_secs;
        stats.wall_secs = wall_start.elapsed().as_secs_f64();
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::records::{decode_row, encode_row, row_key};
    use crate::mapreduce::job::{MapTask, ReduceTask};

    /// Mapper: emits (col_index, value) per element — a toy column sum.
    struct ColMap;
    impl MapTask for ColMap {
        fn run(&self, _: usize, input: &[Record], _: &[&[Record]], out: &mut Emitter) -> Result<()> {
            for rec in input {
                for (j, v) in decode_row(&rec.value).into_iter().enumerate() {
                    out.emit(vec![j as u8], encode_row(&[v]));
                }
            }
            Ok(())
        }
    }

    struct SumReduce;
    impl ReduceTask for SumReduce {
        fn run(&self, partition: &[(Vec<u8>, Vec<Vec<u8>>)], out: &mut Emitter) -> Result<()> {
            for (key, values) in partition {
                let s: f64 = values.iter().map(|v| decode_row(v)[0]).sum();
                out.emit(key.clone(), encode_row(&[s]));
            }
            Ok(())
        }
    }

    fn engine_with_input(rows: usize, cols: usize) -> Engine {
        let mut e = Engine::new(DiskModel::pure_bandwidth(1e-9, 2e-9), ClusterConfig::default());
        let recs: Vec<Record> = (0..rows)
            .map(|i| {
                Record::new(
                    row_key(i as u64),
                    encode_row(&(0..cols).map(|j| (i * cols + j) as f64).collect::<Vec<_>>()),
                )
            })
            .collect();
        e.dfs.put("input", recs);
        e
    }

    #[test]
    fn map_reduce_column_sums() {
        let mut e = engine_with_input(10, 3);
        let m = ColMap;
        let r = SumReduce;
        let spec = JobSpec::map_reduce("colsum", "input", 4, &m, &r, 2, "out");
        let stats = e.run(&spec).unwrap();
        assert_eq!(stats.map_tasks, 4);
        assert_eq!(stats.distinct_keys, 3);
        let out = e.dfs.get("out").unwrap();
        assert_eq!(out.len(), 3);
        // column j sum over i of (3i + j): 3*45 + 10j
        for rec in out {
            let j = rec.key[0] as f64;
            let got = decode_row(&rec.value)[0];
            assert!((got - (135.0 + 10.0 * j)).abs() < 1e-9, "col {j} got {got}");
        }
    }

    #[test]
    fn map_only_passes_through() {
        let mut e = engine_with_input(5, 2);
        let m = ColMap;
        let spec = JobSpec::map_only("ids", "input", 2, &m, "out");
        let stats = e.run(&spec).unwrap();
        assert_eq!(stats.reduce_tasks, 0);
        assert_eq!(e.dfs.file_records("out").unwrap(), 10);
        assert!(stats.virtual_secs > 0.0);
    }

    /// `ColMap` semantics plus a batch hint, exercising the chunked
    /// dispatch path through the default `run_batch`.
    struct BatchedColMap(usize);
    impl MapTask for BatchedColMap {
        fn run(&self, id: usize, input: &[Record], side: &[&[Record]], out: &mut Emitter) -> Result<()> {
            ColMap.run(id, input, side, out)
        }
        fn batch_hint(&self) -> usize {
            self.0
        }
    }

    #[test]
    fn batched_dispatch_is_invisible_to_results_and_accounting() {
        // same job with hint 1 vs 3 (13 tasks => a ragged final chunk),
        // at 1 and 8 host threads: outputs and every non-wall-clock
        // stat must be identical
        let run = |hint: usize, threads: usize| {
            let mut e = engine_with_input(26, 2);
            e.cluster.host_threads = threads;
            let m = BatchedColMap(hint);
            let spec = JobSpec::map_reduce("batched", "input", 13, &m, &SumReduce, 2, "out");
            let stats = e.run(&spec).unwrap();
            (e.dfs.get("out").unwrap().to_vec(), stats)
        };
        let (base_out, base) = run(1, 1);
        for (hint, threads) in [(3usize, 1usize), (3, 8), (5, 2), (100, 4)] {
            let (out, stats) = run(hint, threads);
            assert_eq!(out, base_out, "hint={hint} threads={threads}");
            assert_eq!(stats.map_tasks, base.map_tasks);
            assert_eq!(stats.map_io, base.map_io);
            assert_eq!(stats.reduce_io, base.reduce_io);
            assert_eq!(stats.map_attempts, base.map_attempts);
            assert_eq!(stats.distinct_keys, base.distinct_keys);
        }
    }

    #[test]
    fn batched_mapper_error_carries_chunk_context() {
        struct FailBatch;
        impl MapTask for FailBatch {
            fn run(&self, id: usize, _: &[Record], _: &[&[Record]], _: &mut Emitter) -> Result<()> {
                if id == 3 {
                    anyhow::bail!("task {id} failed")
                }
                Ok(())
            }
            fn batch_hint(&self) -> usize {
                4
            }
        }
        let mut e = engine_with_input(8, 1);
        let m = FailBatch;
        let spec = JobSpec::map_only("batch-fail", "input", 8, &m, "out");
        let err = format!("{:#}", e.run(&spec).unwrap_err());
        assert!(err.contains("map tasks 0..4"), "{err}");
        assert!(err.contains("task 3 failed"), "{err}");
    }

    #[test]
    fn io_accounting_matches_file_sizes() {
        let mut e = engine_with_input(8, 4);
        let m = ColMap;
        let r = SumReduce;
        let spec = JobSpec::map_reduce("acct", "input", 3, &m, &r, 2, "out");
        let stats = e.run(&spec).unwrap();
        let input_bytes = e.dfs.file_bytes("input").unwrap();
        assert_eq!(stats.map_io.bytes_read, input_bytes);
        // every map emission is later read by some reducer
        assert_eq!(stats.map_io.bytes_written, stats.reduce_io.bytes_read);
        assert_eq!(
            stats.reduce_io.bytes_written,
            e.dfs.file_bytes("out").unwrap()
        );
    }

    #[test]
    fn faults_increase_attempts_and_time() {
        let mk = |p: f64, seed: u64| {
            let mut e = engine_with_input(64, 2);
            e = Engine {
                dfs: std::mem::take(&mut e.dfs),
                ..Engine::new(DiskModel::icme_like(), ClusterConfig::default())
            }
            .with_faults(
                FaultPolicy { probability: p, max_attempts: 16, waste_fraction: 0.5 },
                seed,
            );
            let m = ColMap;
            let spec = JobSpec::map_only("f", "input", 32, &m, "out");
            e.run(&spec).unwrap()
        };
        let clean = mk(0.0, 1);
        let faulty = mk(0.3, 1);
        assert_eq!(clean.faults, 0);
        assert!(faulty.faults > 0);
        assert!(faulty.map_attempts > clean.map_attempts);
        assert!(faulty.virtual_secs > clean.virtual_secs);
    }

    #[test]
    fn unconfigured_side_channel_errors() {
        struct BadMap;
        impl MapTask for BadMap {
            fn run(&self, _: usize, _: &[Record], _: &[&[Record]], out: &mut Emitter) -> Result<()> {
                out.emit_to("mystery", vec![1], vec![2]);
                Ok(())
            }
        }
        let mut e = engine_with_input(4, 1);
        let m = BadMap;
        let spec = JobSpec::map_only("bad", "input", 1, &m, "out");
        assert!(e.run(&spec).is_err());
    }

    #[test]
    fn more_tasks_than_records_collapses() {
        let mut e = engine_with_input(3, 1);
        let m = ColMap;
        let spec = JobSpec::map_only("tiny", "input", 100, &m, "out");
        let stats = e.run(&spec).unwrap();
        assert_eq!(stats.map_tasks, 3); // capped at record count
    }

    #[test]
    fn mapper_error_carries_job_context() {
        struct FailMap;
        impl MapTask for FailMap {
            fn run(&self, _: usize, _: &[Record], _: &[&[Record]], _: &mut Emitter) -> Result<()> {
                anyhow::bail!("boom")
            }
        }
        let mut e = engine_with_input(4, 1);
        let m = FailMap;
        let spec = JobSpec::map_only("exploding-job", "input", 2, &m, "out");
        let err = format!("{:#}", e.run(&spec).unwrap_err());
        assert!(err.contains("exploding-job"), "{err}");
        assert!(err.contains("boom"), "{err}");
    }

    #[test]
    fn lowest_task_id_error_wins_under_parallel_execution() {
        // a serial loop reports the first failing task; the pooled
        // engine must report the same one however the wave is scheduled
        struct FailPastZero;
        impl MapTask for FailPastZero {
            fn run(&self, id: usize, _: &[Record], _: &[&[Record]], _: &mut Emitter) -> Result<()> {
                if id >= 1 {
                    anyhow::bail!("task {id} failed")
                }
                Ok(())
            }
        }
        let mut e = engine_with_input(16, 1);
        e.cluster.host_threads = 8;
        let m = FailPastZero;
        let spec = JobSpec::map_only("first-error", "input", 8, &m, "out");
        let err = format!("{:#}", e.run(&spec).unwrap_err());
        assert!(err.contains("map task 1"), "{err}");
        assert!(err.contains("task 1 failed"), "{err}");
    }

    #[test]
    fn missing_input_fails_cleanly() {
        let mut e = Engine::new(DiskModel::icme_like(), ClusterConfig::default());
        let m = ColMap;
        let spec = JobSpec::map_only("nofile", "does-not-exist", 2, &m, "out");
        assert!(e.run(&spec).is_err());
    }

    #[test]
    fn more_reducers_than_keys_counts_nonempty_only() {
        let mut e = engine_with_input(10, 2); // 2 distinct keys
        let m = ColMap;
        let r = SumReduce;
        let spec = JobSpec::map_reduce("wide", "input", 4, &m, &r, 40, "out");
        let stats = e.run(&spec).unwrap();
        assert_eq!(stats.distinct_keys, 2);
        assert!(stats.reduce_tasks <= 2, "empty partitions must not count");
    }

    #[test]
    fn output_scale_registered_on_dfs() {
        let mut e = engine_with_input(6, 2);
        let m = ColMap;
        let spec = JobSpec::map_only("scaled", "input", 2, &m, "out").with_output_scale(250.0);
        e.run(&spec).unwrap();
        assert_eq!(e.dfs.scale("out"), 250.0);
        let vb = e.dfs.virtual_bytes("out").unwrap();
        assert!((vb - e.dfs.file_bytes("out").unwrap() as f64 * 250.0).abs() < 1e-9);
    }

    #[test]
    fn scaled_reads_increase_virtual_time_only() {
        let run = |scale: f64| {
            let mut e = engine_with_input(64, 4);
            e.dfs.set_scale("input", scale);
            let m = ColMap;
            let spec = JobSpec::map_only("s", "input", 8, &m, "out");
            e.run(&spec).unwrap()
        };
        let s1 = run(1.0);
        let s1000 = run(1000.0);
        // accounting of actual bytes is identical…
        assert_eq!(s1.map_io.bytes_read, s1000.map_io.bytes_read);
        // …but the virtual clock charges the scale
        assert!(s1000.virtual_secs > s1.virtual_secs);
    }

    #[test]
    fn side_inputs_are_readable_and_charged() {
        struct CacheMap;
        impl MapTask for CacheMap {
            fn run(&self, _: usize, input: &[Record], side: &[&[Record]], out: &mut Emitter) -> Result<()> {
                assert_eq!(side.len(), 1);
                let bias = decode_row(&side[0][0].value)[0];
                for rec in input {
                    let v: f64 = decode_row(&rec.value).iter().sum();
                    out.emit(rec.key.clone(), encode_row(&[v + bias]));
                }
                Ok(())
            }
        }
        let mut e = engine_with_input(6, 2);
        e.dfs.put("cache", vec![Record::new(row_key(0), encode_row(&[100.0]))]);
        let m = CacheMap;
        let spec = JobSpec::map_only("c", "input", 3, &m, "out").with_side_input("cache");
        let stats = e.run(&spec).unwrap();
        let cache_bytes = e.dfs.file_bytes("cache").unwrap();
        let input_bytes = e.dfs.file_bytes("input").unwrap();
        // each of the 3 tasks reads the cache once
        assert_eq!(stats.map_io.bytes_read, input_bytes + 3 * cache_bytes);
        let out = e.dfs.get("out").unwrap();
        assert!(decode_row(&out[0].value)[0] >= 100.0);
    }

    /// Full-field step comparison minus the wall-clock measurements
    /// (the determinism contract; the cross-algorithm version lives in
    /// `rust/tests/parallel.rs`).
    fn assert_steps_deterministic(a: &StepStats, b: &StepStats) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.map_tasks, b.map_tasks);
        assert_eq!(a.reduce_tasks, b.reduce_tasks);
        assert_eq!(a.distinct_keys, b.distinct_keys);
        assert_eq!(a.map_io, b.map_io);
        assert_eq!(a.reduce_io, b.reduce_io);
        assert_eq!(a.map_attempts, b.map_attempts);
        assert_eq!(a.reduce_attempts, b.reduce_attempts);
        assert_eq!(a.faults, b.faults);
        assert_eq!(a.virtual_secs.to_bits(), b.virtual_secs.to_bits(), "virtual clock drifted");
    }

    #[test]
    fn host_threads_do_not_change_outputs_or_stats() {
        let run = |host_threads: usize| {
            let mut e = engine_with_input(64, 3);
            e = Engine {
                dfs: std::mem::take(&mut e.dfs),
                ..Engine::new(
                    DiskModel::icme_like(),
                    ClusterConfig::default().with_host_threads(host_threads),
                )
            }
            .with_faults(
                FaultPolicy { probability: 0.2, max_attempts: 16, waste_fraction: 0.5 },
                7,
            );
            let m = ColMap;
            let r = SumReduce;
            let spec = JobSpec::map_reduce("det", "input", 16, &m, &r, 3, "out");
            let stats = e.run(&spec).unwrap();
            let out: Vec<Record> = e.dfs.get("out").unwrap().to_vec();
            (stats, out)
        };
        let (s1, out1) = run(1);
        let (s8, out8) = run(8);
        assert_eq!(out1, out8, "DFS output must not depend on the pool size");
        assert_steps_deterministic(&s1, &s8);
        assert_eq!(s1.host_threads, 1);
        assert!(s8.host_threads > 1);
    }

    #[test]
    fn explicit_fault_rng_is_independent_of_engine_state() {
        // the job service hands each job its own RNG: the draws must
        // depend only on that RNG, not on how many runs the engine's
        // internal RNG has served in between
        let policy = FaultPolicy { probability: 0.3, max_attempts: 16, waste_fraction: 0.5 };
        let run_with = |warmup_runs: usize| {
            let mut e = engine_with_input(64, 2);
            e = Engine {
                dfs: std::mem::take(&mut e.dfs),
                ..Engine::new(DiskModel::icme_like(), ClusterConfig::default())
            }
            .with_faults(policy, 11);
            let m = ColMap;
            let spec = JobSpec::map_only("warm", "input", 16, &m, "out");
            for _ in 0..warmup_runs {
                e.run(&spec).unwrap(); // advances the *internal* rng
            }
            let spec = JobSpec::map_only("probe", "input", 16, &m, "out2");
            let mut job_rng = Rng::new(0xDEAD_BEEF);
            e.run_with_rng(&spec, &mut job_rng).unwrap()
        };
        let a = run_with(0);
        let b = run_with(3);
        assert_eq!(a.map_attempts, b.map_attempts, "explicit stream drifted");
        assert_eq!(a.faults, b.faults);
        assert_eq!(a.virtual_secs.to_bits(), b.virtual_secs.to_bits());
    }

    #[test]
    fn fault_seed_is_recorded() {
        let e = Engine::new(DiskModel::icme_like(), ClusterConfig::default());
        assert_eq!(e.fault_seed(), super::DEFAULT_FAULT_SEED);
        let e = e.with_faults(FaultPolicy::none(), 42);
        assert_eq!(e.fault_seed(), 42);
    }

    #[test]
    fn virtual_clock_is_deterministic_across_runs() {
        // the virtual clock charges only modelled quantities, so two
        // identical runs agree to the bit (measured compute lives in
        // the wall-clock fields only)
        let run = || {
            let mut e = engine_with_input(40, 2);
            let m = ColMap;
            let r = SumReduce;
            let spec = JobSpec::map_reduce("bits", "input", 8, &m, &r, 2, "out");
            e.run(&spec).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.virtual_secs.to_bits(), b.virtual_secs.to_bits());
        assert_steps_deterministic(&a, &b);
    }
}
