//! Hadoop-like MapReduce engine substrate.
//!
//! Implements the computational engine the paper targets: **map** applies
//! a task function to each input split, **shuffle** groups emitted
//! key-value pairs by key (sorted, like Hadoop), **reduce** applies a
//! task function per key group. On top of the paper's semantics the
//! engine provides:
//!
//! * slot-limited scheduling ([`scheduler`]) with a *virtual disk clock*
//!   derived from the [`crate::dfs::DiskModel`] — this is what makes the
//!   simulated job times reproduce the paper's performance tables;
//! * Hadoop-style transparent fault tolerance ([`fault`]): task attempts
//!   crash with configurable probability and are re-executed (Fig. 7);
//! * per-step I/O and timing metrics ([`metrics`]) that line up with the
//!   byte-count formulas of the paper's Table III.
//!
//! Side outputs ("feathers" in the paper's Dumbo implementation — Q and
//! R written to *separate files* from one task) and side inputs (the
//! step-3 distributed cache file of second-stage Q factors) are
//! first-class, since Direct TSQR needs both.
//!
//! Task bodies are `Send + Sync` and each map/reduce wave executes on a
//! real host thread pool ([`ClusterConfig::host_threads`]) while
//! remaining bit-for-bit deterministic — see [`engine`] for the
//! virtual-vs-host parallelism contract.

pub mod engine;
pub mod fault;
pub mod job;
pub mod metrics;
pub mod scheduler;
pub mod shuffle;

pub use engine::{default_host_threads, ClusterConfig, Engine};
pub use fault::FaultPolicy;
pub use job::{Emitter, JobSpec, KeyGroup, MapTask, ReduceTask};
pub use metrics::{JobStats, StepStats};
