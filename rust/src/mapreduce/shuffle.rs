//! The shuffle: group map emissions by key, sorted, and partition the
//! key groups across reduce tasks (hash partitioner, like Hadoop's
//! default).

use crate::dfs::Record;
use std::collections::BTreeMap;

/// Key-grouped, key-sorted map output.
pub type Groups = BTreeMap<Vec<u8>, Vec<Vec<u8>>>;

/// Group records by key (sorted by key — Hadoop's sort phase).
pub fn group_by_key(records: Vec<Record>) -> Groups {
    let mut groups: Groups = BTreeMap::new();
    for rec in records {
        groups.entry(rec.key).or_default().push(rec.value);
    }
    groups
}

/// FNV-1a — a stable stand-in for Hadoop's `key.hashCode() % R`.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assign each key group to one of `parts` partitions. Returns a vec of
/// `parts` maps (some possibly empty). Keys within a partition stay
/// sorted.
pub fn partition(groups: Groups, parts: usize) -> Vec<Groups> {
    let parts = parts.max(1);
    let mut out: Vec<Groups> = (0..parts).map(|_| Groups::new()).collect();
    for (key, values) in groups {
        let p = (fnv1a(&key) % parts as u64) as usize;
        out[p].insert(key, values);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(k: &[u8], v: &[u8]) -> Record {
        Record::new(k.to_vec(), v.to_vec())
    }

    #[test]
    fn groups_and_sorts() {
        let groups = group_by_key(vec![
            rec(b"b", b"1"),
            rec(b"a", b"2"),
            rec(b"b", b"3"),
        ]);
        let keys: Vec<&[u8]> = groups.keys().map(|k| k.as_slice()).collect();
        assert_eq!(keys, vec![b"a".as_slice(), b"b".as_slice()]);
        assert_eq!(groups[b"b".as_slice()], vec![b"1".to_vec(), b"3".to_vec()]);
    }

    #[test]
    fn grouping_preserves_emission_order_within_key() {
        let groups = group_by_key(vec![rec(b"k", b"1"), rec(b"k", b"2")]);
        assert_eq!(groups[b"k".as_slice()], vec![b"1".to_vec(), b"2".to_vec()]);
    }

    #[test]
    fn partition_covers_all_keys() {
        let groups = group_by_key(
            (0..100u8).map(|i| rec(&[i], &[i])).collect(),
        );
        let parts = partition(groups.clone(), 7);
        assert_eq!(parts.len(), 7);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, groups.len());
    }

    #[test]
    fn partition_deterministic() {
        let mk = || group_by_key((0..50u8).map(|i| rec(&[i], &[i])).collect());
        let a = partition(mk(), 4);
        let b = partition(mk(), 4);
        for (pa, pb) in a.iter().zip(&b) {
            assert_eq!(pa.len(), pb.len());
        }
    }

    #[test]
    fn single_partition_keeps_everything() {
        let groups = group_by_key(vec![rec(b"x", b"1"), rec(b"y", b"2")]);
        let parts = partition(groups, 1);
        assert_eq!(parts[0].len(), 2);
    }
}
