//! Per-step and per-job metrics.
//!
//! Everything the paper's evaluation reports is derived from these:
//! byte counts per stage (Table III cross-check), task/parallelism
//! numbers (Table IV), virtual job time (Tables V, VI, IX), per-step
//! fractions (Table VIII), attempts/faults (Fig. 7).

use crate::dfs::{DiskModel, IoMeter};

/// Metrics for one MapReduce iteration (one map[+reduce] stage pair).
///
/// Every field is deterministic — byte-identical for a given job
/// whatever the host thread-pool size — except the wall-clock
/// measurements: `wall_secs`, `map_compute_secs`, `reduce_compute_secs`
/// (real measured time) and `host_threads` (configuration, not
/// outcome). `rust/tests/parallel.rs` enforces the split.
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    pub name: String,
    pub map_tasks: usize,
    pub reduce_tasks: usize,
    /// Distinct keys shuffled into the reduce stage (`k_j` in the paper).
    pub distinct_keys: usize,
    pub map_io: IoMeter,
    pub reduce_io: IoMeter,
    /// Measured wall-clock compute inside map / reduce task bodies
    /// (diagnostic; never charged to the virtual clock).
    pub map_compute_secs: f64,
    pub reduce_compute_secs: f64,
    /// Virtual time of this step (slot-scheduled disk + startup under
    /// the paper's model — fully deterministic).
    pub virtual_secs: f64,
    /// Real wall time spent executing this step in the simulator.
    pub wall_secs: f64,
    /// Total task attempts (== tasks when no faults injected).
    pub map_attempts: usize,
    pub reduce_attempts: usize,
    /// Injected faults observed.
    pub faults: usize,
    /// Realized host worker-thread pool size for this step's widest
    /// wave (`min(ClusterConfig::host_threads, tasks)`); 0 for leader
    /// and marker steps that never enter the engine.
    pub host_threads: usize,
}

impl StepStats {
    pub fn total_io(&self) -> IoMeter {
        let mut io = self.map_io;
        io.merge(&self.reduce_io);
        io
    }
}

/// Aggregated metrics for a whole algorithm run (several steps).
#[derive(Debug, Clone, Default)]
pub struct JobStats {
    pub steps: Vec<StepStats>,
    /// Index of the engine shard the job ran on (0 for sessions and
    /// single-shard services). Stamped by the service router; like
    /// `host_threads`, it is a *placement* record — every modelled
    /// metric in `steps` is bit-identical whatever shard served the
    /// job (`rust/tests/shards.rs` enforces this).
    pub shard: usize,
    /// Whether an idle shard stole this job off its routed queue
    /// before running it. Like `shard`, a pure *placement* record —
    /// stealing never changes a modelled metric
    /// (`rust/tests/steal.rs` enforces this).
    pub stolen: bool,
}

impl JobStats {
    pub fn push(&mut self, s: StepStats) {
        self.steps.push(s);
    }

    pub fn extend(&mut self, other: JobStats) {
        self.steps.extend(other.steps);
    }

    /// Total virtual job time (the paper's "job time (secs.)").
    pub fn virtual_secs(&self) -> f64 {
        self.steps.iter().map(|s| s.virtual_secs).sum()
    }

    pub fn wall_secs(&self) -> f64 {
        self.steps.iter().map(|s| s.wall_secs).sum()
    }

    pub fn total_io(&self) -> IoMeter {
        let mut io = IoMeter::default();
        for s in &self.steps {
            io.merge(&s.total_io());
        }
        io
    }

    pub fn total_faults(&self) -> usize {
        self.steps.iter().map(|s| s.faults).sum()
    }

    /// Realized host parallelism across the run: the widest worker pool
    /// any engine step actually used (0 if no engine step ran).
    pub fn host_threads(&self) -> usize {
        self.steps.iter().map(|s| s.host_threads).max().unwrap_or(0)
    }

    pub fn compute_secs(&self) -> f64 {
        self.steps
            .iter()
            .map(|s| s.map_compute_secs + s.reduce_compute_secs)
            .sum()
    }

    /// Disk-only virtual time under a (possibly different) model —
    /// lets tests check model-vs-accounting consistency.
    pub fn disk_secs(&self, model: &DiskModel) -> f64 {
        self.steps.iter().map(|s| s.total_io().disk_secs(model)).sum()
    }

    /// Fraction of virtual time per step (paper Table VIII).
    pub fn step_fractions(&self) -> Vec<(String, f64)> {
        let total = self.virtual_secs().max(f64::MIN_POSITIVE);
        self.steps
            .iter()
            .map(|s| (s.name.clone(), s.virtual_secs / total))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(name: &str, vsecs: f64, read: u64, written: u64) -> StepStats {
        let mut s = StepStats { name: name.into(), virtual_secs: vsecs, ..Default::default() };
        s.map_io.add_read(read, 1);
        s.map_io.add_write(written, 1);
        s
    }

    #[test]
    fn aggregates() {
        let mut j = JobStats::default();
        j.push(step("s1", 2.0, 100, 50));
        j.push(step("s2", 3.0, 10, 5));
        assert!((j.virtual_secs() - 5.0).abs() < 1e-12);
        assert_eq!(j.total_io().bytes_read, 110);
        assert_eq!(j.total_io().bytes_written, 55);
    }

    #[test]
    fn fractions_sum_to_one() {
        let mut j = JobStats::default();
        j.push(step("a", 1.0, 0, 0));
        j.push(step("b", 3.0, 0, 0));
        let fr = j.step_fractions();
        let total: f64 = fr.iter().map(|(_, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!((fr[1].1 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn disk_secs_uses_model() {
        let mut j = JobStats::default();
        j.push(step("a", 0.0, 1000, 500));
        let m = DiskModel::pure_bandwidth(1e-3, 2e-3);
        assert!((j.disk_secs(&m) - 2.0).abs() < 1e-12);
    }
}
