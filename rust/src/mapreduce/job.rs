//! Job specification: mapper/reducer traits and the emitter.

use crate::dfs::Record;
use anyhow::Result;

/// Where an emitted record goes.
pub const DEFAULT_CHANNEL: &str = "";

/// Collects task emissions, separated into the default channel (which
/// feeds the shuffle / job output) and named side channels (the paper's
/// "feathers" extension: Q and R factors written to separate files).
#[derive(Debug, Default)]
pub struct Emitter {
    pub main: Vec<Record>,
    pub side: Vec<(String, Record)>,
}

impl Emitter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Emit to the default channel (shuffled if the job has a reducer,
    /// otherwise written to the job's output file).
    pub fn emit(&mut self, key: Vec<u8>, value: Vec<u8>) {
        self.main.push(Record::new(key, value));
    }

    /// Emit to a named side-output channel.
    pub fn emit_to(&mut self, channel: &str, key: Vec<u8>, value: Vec<u8>) {
        self.side.push((channel.to_string(), Record::new(key, value)));
    }

    pub fn bytes_emitted(&self) -> u64 {
        self.main.iter().map(|r| r.size_bytes()).sum::<u64>()
            + self.side.iter().map(|(_, r)| r.size_bytes()).sum::<u64>()
    }

    pub fn records_emitted(&self) -> u64 {
        (self.main.len() + self.side.len()) as u64
    }
}

/// A map task: processes one whole input split (Hadoop-streaming style —
/// the paper's mappers gather their split into a local matrix before
/// computing, so the per-record callback shape would be wrong here).
///
/// `Send + Sync`: one task value is shared by every map task of a wave,
/// and waves fan out over the engine's host thread pool
/// ([`super::engine::ClusterConfig::host_threads`]). Task bodies
/// holding `&dyn BlockCompute` satisfy the bound because
/// [`crate::runtime::BlockCompute`] itself requires `Send + Sync`.
pub trait MapTask: Send + Sync {
    /// `task_id` is the index of this map task within the job; `side`
    /// holds the records of each side-input file (distributed cache),
    /// in the order listed in [`JobSpec::side_inputs`].
    fn run(
        &self,
        task_id: usize,
        input: &[Record],
        side: &[&[Record]],
        out: &mut Emitter,
    ) -> Result<()>;

    /// How many consecutive map tasks this mapper wants delivered to a
    /// single [`MapTask::run_batch`] call. The engine chunks a wave's
    /// task ids `[0, hint)`, `[hint, 2·hint)`, … — a fixed partition
    /// independent of `host_threads`, so batching never perturbs which
    /// task sees which split. `1` (the default) keeps the plain
    /// per-task dispatch path.
    fn batch_hint(&self) -> usize {
        1
    }

    /// Process `inputs.len()` consecutive tasks in one call: task ids
    /// `first_id..first_id + inputs.len()`, with `outs[k]` receiving
    /// exactly what task `first_id + k` would have emitted through
    /// [`MapTask::run`]. Implementations must keep the per-task
    /// emission contract bit-identical — batching may only amortize
    /// dispatch (see [`crate::runtime::BlockCompute::factor_blocks`]).
    /// The default loops `run`.
    fn run_batch(
        &self,
        first_id: usize,
        inputs: &[&[Record]],
        side: &[&[Record]],
        outs: &mut [Emitter],
    ) -> Result<()> {
        debug_assert_eq!(inputs.len(), outs.len());
        for (k, (input, out)) in inputs.iter().zip(outs.iter_mut()).enumerate() {
            self.run(first_id + k, input, side, out)?;
        }
        Ok(())
    }
}

/// One key group delivered to a reducer: `(key, values)` with values in
/// emission order.
pub type KeyGroup = (Vec<u8>, Vec<Vec<u8>>);

/// A reduce task body: receives its *whole partition* (key groups in
/// sorted key order). Per-key reducers simply loop; partition-scoped
/// reducers (Direct TSQR step 2 stacks the R factors of *all* keys)
/// need the full view — the paper's reduce task "maintains an ordered
/// list of the keys read".
///
/// `Send + Sync` for the same reason as [`MapTask`]: reduce waves run
/// on the host thread pool.
pub trait ReduceTask: Send + Sync {
    fn run(&self, partition: &[KeyGroup], out: &mut Emitter) -> Result<()>;
}

/// Declarative job description consumed by [`super::Engine::run`].
pub struct JobSpec<'a> {
    /// For logs/metrics.
    pub name: String,
    /// DFS input file.
    pub input: String,
    /// Number of map tasks (input splits). The engine caps it at the
    /// record count.
    pub map_tasks: usize,
    pub mapper: &'a dyn MapTask,
    /// `None` makes this a map-only job (Direct TSQR steps 1 and 3).
    pub reducer: Option<&'a dyn ReduceTask>,
    /// Requested reduce tasks; effective parallelism is additionally
    /// capped by the number of distinct keys (paper §II-A discussion).
    pub reduce_tasks: usize,
    /// DFS file receiving default-channel output.
    pub output: String,
    /// Virtual-byte scale of the default channel: applied to main-channel
    /// emissions, shuffle traffic and the output file (see
    /// [`crate::dfs::Dfs::set_scale`]).
    pub output_scale: f64,
    /// (channel name, DFS file, virtual-byte scale) for side outputs.
    pub side_outputs: Vec<(String, String, f64)>,
    /// DFS files broadcast to every map task (distributed cache).
    pub side_inputs: Vec<String>,
}

impl<'a> JobSpec<'a> {
    /// Minimal map-only job.
    pub fn map_only(
        name: &str,
        input: &str,
        map_tasks: usize,
        mapper: &'a dyn MapTask,
        output: &str,
    ) -> Self {
        JobSpec {
            name: name.to_string(),
            input: input.to_string(),
            map_tasks,
            mapper,
            reducer: None,
            reduce_tasks: 0,
            output: output.to_string(),
            output_scale: 1.0,
            side_outputs: Vec::new(),
            side_inputs: Vec::new(),
        }
    }

    /// Full map+shuffle+reduce job.
    pub fn map_reduce(
        name: &str,
        input: &str,
        map_tasks: usize,
        mapper: &'a dyn MapTask,
        reducer: &'a dyn ReduceTask,
        reduce_tasks: usize,
        output: &str,
    ) -> Self {
        JobSpec {
            name: name.to_string(),
            input: input.to_string(),
            map_tasks,
            mapper,
            reducer: Some(reducer),
            reduce_tasks,
            output: output.to_string(),
            output_scale: 1.0,
            side_outputs: Vec::new(),
            side_inputs: Vec::new(),
        }
    }

    pub fn with_side_output(mut self, channel: &str, file: &str) -> Self {
        self.side_outputs.push((channel.to_string(), file.to_string(), 1.0));
        self
    }

    pub fn with_scaled_side_output(mut self, channel: &str, file: &str, scale: f64) -> Self {
        self.side_outputs.push((channel.to_string(), file.to_string(), scale));
        self
    }

    pub fn with_side_input(mut self, file: &str) -> Self {
        self.side_inputs.push(file.to_string());
        self
    }

    pub fn with_output_scale(mut self, scale: f64) -> Self {
        self.output_scale = scale;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emitter_accounts_bytes() {
        let mut e = Emitter::new();
        e.emit(vec![1, 2], vec![3, 4, 5]);
        e.emit_to("q", vec![9], vec![8, 7]);
        assert_eq!(e.bytes_emitted(), 5 + 3);
        assert_eq!(e.records_emitted(), 2);
        assert_eq!(e.main.len(), 1);
        assert_eq!(e.side.len(), 1);
        assert_eq!(e.side[0].0, "q");
    }

    struct NopMap;
    impl MapTask for NopMap {
        fn run(&self, _: usize, _: &[Record], _: &[&[Record]], _: &mut Emitter) -> Result<()> {
            Ok(())
        }
    }

    struct EchoIdMap;
    impl MapTask for EchoIdMap {
        fn run(&self, id: usize, input: &[Record], _: &[&[Record]], out: &mut Emitter) -> Result<()> {
            out.emit(vec![id as u8], vec![input.len() as u8]);
            Ok(())
        }
    }

    #[test]
    fn default_run_batch_loops_run() {
        let m = EchoIdMap;
        let a = [Record::new(vec![0], vec![0])];
        let b = [Record::new(vec![1], vec![1]), Record::new(vec![2], vec![2])];
        let inputs: Vec<&[Record]> = vec![&a, &b];
        let mut outs = vec![Emitter::new(), Emitter::new()];
        m.run_batch(5, &inputs, &[], &mut outs).unwrap();
        assert_eq!(outs[0].main[0].key, vec![5]);
        assert_eq!(outs[0].main[0].value, vec![1]);
        assert_eq!(outs[1].main[0].key, vec![6]);
        assert_eq!(outs[1].main[0].value, vec![2]);
        assert_eq!(m.batch_hint(), 1);
    }

    #[test]
    fn spec_builders() {
        let m = NopMap;
        let spec = JobSpec::map_only("j", "in", 4, &m, "out")
            .with_side_output("q", "qfile")
            .with_side_input("cache");
        assert_eq!(spec.map_tasks, 4);
        assert!(spec.reducer.is_none());
        assert_eq!(spec.side_outputs, vec![("q".into(), "qfile".into(), 1.0)]);
        assert_eq!(spec.side_inputs, vec!["cache".to_string()]);
    }
}
