//! Batch-job manifests for `mrtsqr batch`.
//!
//! One job per line, whitespace-separated, `#` comments:
//!
//! ```text
//! # name  rows   cols  seed  want   algo     [priority] [@shard]
//! A1      40000  10    1     qr     auto
//! A2      80000  25    2     svd    direct   high
//! A3      40000  10    3     r      auto     low        @1
//! A4      20000  8     4     sigma  indirect @0
//! ```
//!
//! `want`: `qr` | `r` | `svd` | `sigma`; `algo`: `auto` or any fixed
//! CLI algorithm name ([`Algorithm::parse`]); `priority` defaults to
//! `normal`. A trailing `@<k>` pins the job to engine shard `k`
//! ([`crate::session::Placement::Pinned`]) instead of letting the
//! service's least-loaded router place it; it errors at submission
//! when the service has fewer than `k+1` shards (`mrtsqr batch
//! --shards N`).

use crate::coordinator::Algorithm;
use crate::session::{AlgoChoice, FactorizationRequest, Placement, Priority, Want};
use anyhow::{bail, Context, Result};

/// One parsed manifest line: the input to generate and the request to
/// run on it.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchEntry {
    /// Input name (also the job's report label).
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Gaussian-ingestion seed.
    pub seed: u64,
    pub want: Want,
    pub algo: AlgoChoice,
    pub priority: Priority,
    /// Engine-shard placement (`@<k>` in the manifest; `Auto` = routed).
    pub placement: Placement,
}

impl BatchEntry {
    /// The service request this entry describes.
    pub fn request(&self) -> FactorizationRequest {
        let base = match self.want {
            Want::Qr => FactorizationRequest::qr(),
            Want::ROnly => FactorizationRequest::r_only(),
            Want::Svd => FactorizationRequest::svd(),
            Want::SingularValues => FactorizationRequest::singular_values(),
        };
        let base = match self.algo {
            AlgoChoice::Auto => base.auto(),
            AlgoChoice::Fixed(algo) => base.with_algorithm(algo),
        };
        let base = match self.placement {
            Placement::Auto => base,
            Placement::Pinned(k) => base.pinned(k),
        };
        base.with_priority(self.priority).labeled(self.name.clone())
    }

    /// Short human-readable request description for report tables.
    pub fn describe(&self) -> String {
        let want = match self.want {
            Want::Qr => "qr",
            Want::ROnly => "r",
            Want::Svd => "svd",
            Want::SingularValues => "sigma",
        };
        let algo = match self.algo {
            AlgoChoice::Auto => "auto".to_string(),
            AlgoChoice::Fixed(a) => a.cli_name().to_string(),
        };
        format!("{want}/{algo}")
    }
}

fn parse_want(s: &str) -> Result<Want> {
    Ok(match s {
        "qr" => Want::Qr,
        "r" | "r-only" => Want::ROnly,
        "svd" => Want::Svd,
        "sigma" | "singular-values" => Want::SingularValues,
        other => bail!("unknown want {other:?} (qr|r|svd|sigma)"),
    })
}

fn parse_algo(s: &str) -> Result<AlgoChoice> {
    if s == "auto" {
        return Ok(AlgoChoice::Auto);
    }
    Ok(AlgoChoice::Fixed(Algorithm::parse(s)?))
}

fn parse_line(fields: &[&str]) -> Result<BatchEntry> {
    if !(6..=8).contains(&fields.len()) {
        bail!(
            "expected `name rows cols seed want algo [priority] [@shard]`, got {} fields",
            fields.len()
        );
    }
    // the optional trailing fields: a priority name and/or an `@<k>`
    // shard pin, in either order
    let mut priority = Priority::Normal;
    let mut placement = Placement::Auto;
    let mut seen_priority = false;
    let mut seen_placement = false;
    for field in &fields[6..] {
        if let Some(shard) = field.strip_prefix('@') {
            if seen_placement {
                bail!("duplicate @shard field {field:?}");
            }
            placement = Placement::Pinned(shard.parse().context("@shard")?);
            seen_placement = true;
        } else {
            if seen_priority {
                bail!("duplicate priority field {field:?}");
            }
            priority = Priority::parse(field)?;
            seen_priority = true;
        }
    }
    Ok(BatchEntry {
        name: fields[0].to_string(),
        rows: fields[1].parse().context("rows")?,
        cols: fields[2].parse().context("cols")?,
        seed: fields[3].parse().context("seed")?,
        want: parse_want(fields[4])?,
        algo: parse_algo(fields[5])?,
        priority,
        placement,
    })
}

/// Parse a whole manifest. Blank lines and `#` comments are skipped;
/// errors name the offending line.
pub fn parse_manifest(text: &str) -> Result<Vec<BatchEntry>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        let entry = parse_line(&fields)
            .with_context(|| format!("manifest line {}: {line:?}", lineno + 1))?;
        out.push(entry);
    }
    if out.is_empty() {
        bail!("manifest has no jobs");
    }
    Ok(out)
}

/// Generate a synthetic batch of `jobs` entries for load generation
/// (`mrtsqr loadgen`): the 8-way mixed request cycle the determinism
/// suites exercise (every `want`, fixed and auto algorithms, all three
/// priorities), over `inputs` distinct gaussian matrices reused
/// round-robin. Entries sharing an input name share its
/// rows/cols/seed, so each input is ingested once however many jobs
/// read it. Deterministic: the same arguments always produce the same
/// manifest.
pub fn synthetic_manifest(
    jobs: usize,
    inputs: usize,
    base_rows: usize,
    cols: usize,
    seed: u64,
) -> Vec<BatchEntry> {
    let inputs = inputs.max(1);
    let cols = cols.max(2);
    (0..jobs)
        .map(|i| {
            let k = i % inputs;
            // the same 8-request mix as rust/tests/client.rs, cycled
            let (want, algo, priority) = match i % 8 {
                0 => (Want::Qr, AlgoChoice::Auto, Priority::Normal),
                1 => (Want::Qr, AlgoChoice::Fixed(Algorithm::DirectTsqr), Priority::Normal),
                2 => (Want::Qr, AlgoChoice::Fixed(Algorithm::DirectTsqrFused), Priority::High),
                3 => (Want::ROnly, AlgoChoice::Auto, Priority::Normal),
                4 => (
                    Want::ROnly,
                    AlgoChoice::Fixed(Algorithm::Cholesky { refine: false }),
                    Priority::Normal,
                ),
                5 => (Want::Svd, AlgoChoice::Auto, Priority::Normal),
                6 => (Want::SingularValues, AlgoChoice::Auto, Priority::Low),
                _ => (
                    Want::Qr,
                    AlgoChoice::Fixed(Algorithm::IndirectTsqr { refine: true }),
                    Priority::Normal,
                ),
            };
            BatchEntry {
                name: format!("gen-{k}"),
                rows: base_rows + 40 * k,
                cols: cols + k % 3,
                seed: seed.wrapping_add(k as u64),
                want,
                algo,
                priority,
                placement: Placement::Auto,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_doc_example() {
        let text = "\
# name  rows   cols  seed  want   algo     [priority] [@shard]
A1      40000  10    1     qr     auto
A2      80000  25    2     svd    direct   high

A3      40000  10    3     r      auto     low   @1   # trailing comment
A4      20000  8     4     sigma  indirect @0
";
        let jobs = parse_manifest(text).unwrap();
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].name, "A1");
        assert_eq!(jobs[0].want, Want::Qr);
        assert_eq!(jobs[0].algo, AlgoChoice::Auto);
        assert_eq!(jobs[0].priority, Priority::Normal);
        assert_eq!(jobs[0].placement, Placement::Auto);
        assert_eq!(jobs[1].algo, AlgoChoice::Fixed(Algorithm::DirectTsqr));
        assert_eq!(jobs[1].priority, Priority::High);
        assert_eq!(jobs[2].want, Want::ROnly);
        assert_eq!(jobs[2].priority, Priority::Low);
        assert_eq!(jobs[2].placement, Placement::Pinned(1));
        assert_eq!(jobs[3].want, Want::SingularValues);
        assert_eq!(jobs[3].placement, Placement::Pinned(0));
        assert_eq!(jobs[3].priority, Priority::Normal);
        assert_eq!(jobs[3].describe(), "sigma/indirect");
    }

    #[test]
    fn shard_pin_and_priority_compose_in_either_order() {
        let e = parse_manifest("A 100 4 7 qr direct @2 high").unwrap().remove(0);
        assert_eq!(e.priority, Priority::High);
        assert_eq!(e.placement, Placement::Pinned(2));
        let req = e.request();
        assert_eq!(req.placement, Placement::Pinned(2));
        assert!(parse_manifest("A 100 4 7 qr direct @1 @2").is_err(), "duplicate pin");
        assert!(parse_manifest("A 100 4 7 qr direct low high").is_err(), "duplicate priority");
        assert!(parse_manifest("A 100 4 7 qr direct @x").is_err(), "non-numeric shard");
    }

    #[test]
    fn entry_builds_a_labeled_prioritized_request() {
        let e = parse_manifest("hot 100 4 7 qr direct high").unwrap().remove(0);
        let req = e.request();
        assert_eq!(req.want, Want::Qr);
        assert_eq!(req.algo, AlgoChoice::Fixed(Algorithm::DirectTsqr));
        assert_eq!(req.priority, Priority::High);
        assert_eq!(req.label.as_deref(), Some("hot"));
    }

    #[test]
    fn synthetic_manifest_is_deterministic_and_input_consistent() {
        let a = synthetic_manifest(20, 3, 1000, 6, 42);
        let b = synthetic_manifest(20, 3, 1000, 6, 42);
        assert_eq!(a, b, "same arguments, same manifest");
        assert_eq!(a.len(), 20);
        // entries sharing a name must agree on rows/cols/seed — one
        // ingestion serves them all
        let mut shapes: std::collections::HashMap<&str, (usize, usize, u64)> =
            std::collections::HashMap::new();
        for e in &a {
            let shape = (e.rows, e.cols, e.seed);
            assert_eq!(*shapes.entry(&e.name).or_insert(shape), shape, "{}", e.name);
        }
        assert_eq!(shapes.len(), 3, "three distinct inputs");
        // the mix covers every want and all three priorities
        for want in [Want::Qr, Want::ROnly, Want::Svd, Want::SingularValues] {
            assert!(a.iter().any(|e| e.want == want), "{want:?} missing");
        }
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert!(a.iter().any(|e| e.priority == p), "{p:?} missing");
        }
    }

    #[test]
    fn bad_lines_are_reported_with_position() {
        let err = parse_manifest("A 100 4 7 qr").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"), "{err:#}");
        let err = parse_manifest("A 100 4 7 qr direct urgent").unwrap_err();
        assert!(format!("{err:#}").contains("urgent"), "{err:#}");
        let err = parse_manifest("A ten 4 7 qr direct").unwrap_err();
        assert!(format!("{err:#}").contains("rows"), "{err:#}");
        assert!(parse_manifest("# only comments\n").is_err());
    }
}
