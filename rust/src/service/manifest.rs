//! Batch-job manifests for `mrtsqr batch`.
//!
//! One job per line, whitespace-separated, `#` comments:
//!
//! ```text
//! # name  rows   cols  seed  want   algo     [priority] [@shard]
//! A1      40000  10    1     qr     auto
//! A2      80000  25    2     svd    direct   high
//! A3      40000  10    3     r      auto     low        @1
//! A4      20000  8     4     sigma  indirect @0
//! ```
//!
//! `want`: `qr` | `r` | `svd` | `sigma` | `lowrank:<rank>` |
//! `solve[:<rhs>]`; `algo`: `auto` or any fixed CLI algorithm name
//! ([`Algorithm::parse`]); `priority` defaults to `normal`. The
//! sketching wants take extra colon-separated knobs in any order after
//! the leading number — `p<oversample>`, `q<power_iters>`,
//! `s<seed>`, and a sketch-kind name (`gauss`/`countsketch`):
//!
//! ```text
//! L1  60000  48  5  lowrank:4:p8:q1:s42:countsketch  randomized
//! S1  60000  9   6  solve:1:s7                       auto
//! ```
//!
//! A trailing `@<k>` pins the job to engine shard `k`
//! ([`crate::session::Placement::Pinned`]) instead of letting the
//! service's least-loaded router place it; it errors at submission
//! when the service has fewer than `k+1` shards (`mrtsqr batch
//! --shards N`). Two more trailing flags opt a job out of elastic
//! scheduling: `+nosteal` (never stolen by an idle shard) and
//! `+exempt` (ignores per-label admission quotas).
//!
//! A manifest may also carry `%scheduler` directive lines configuring
//! the pool the batch runs on ([`parse_manifest_full`]); CLI flags
//! override them key by key:
//!
//! ```text
//! %scheduler steal=on locality=on quota=2 autoscale=1:4 interval_ms=100
//! ```

use crate::coordinator::Algorithm;
use crate::service::SchedulerConfig;
use crate::session::{
    AlgoChoice, FactorizationRequest, Placement, Priority, SubmitOptions, Want,
};
use crate::sketch::{SketchKind, SketchOptions, DEFAULT_OVERSAMPLE};
use anyhow::{bail, Context, Result};
use std::time::Duration;

/// One parsed manifest line: the input to generate and the request to
/// run on it.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchEntry {
    /// Input name (also the job's report label).
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Gaussian-ingestion seed.
    pub seed: u64,
    pub want: Want,
    pub algo: AlgoChoice,
    pub priority: Priority,
    /// Engine-shard placement (`@<k>` in the manifest; `Auto` = routed).
    pub placement: Placement,
    /// `+nosteal`: never let an idle shard steal this job.
    pub no_steal: bool,
    /// `+exempt`: admit this job past per-label quotas.
    pub quota_exempt: bool,
    /// Sketch operator + seed (`lowrank`/`solve` wants; the default
    /// everywhere else).
    pub sketch: SketchOptions,
}

impl BatchEntry {
    /// The service request this entry describes.
    pub fn request(&self) -> FactorizationRequest {
        let base = match self.want {
            Want::Qr => FactorizationRequest::qr(),
            Want::ROnly => FactorizationRequest::r_only(),
            Want::Svd => FactorizationRequest::svd(),
            Want::SingularValues => FactorizationRequest::singular_values(),
            Want::LowRank { rank, oversample, power_iters } => {
                FactorizationRequest::low_rank(rank).oversample(oversample).power_iters(power_iters)
            }
            Want::Solve { rhs } => FactorizationRequest::solve().rhs_cols(rhs),
        }
        .with_sketch(self.sketch);
        let base = match self.algo {
            AlgoChoice::Auto => base.auto(),
            AlgoChoice::Fixed(algo) => base.with_algorithm(algo),
        };
        let mut opts = SubmitOptions::new()
            .priority(self.priority)
            .label(self.name.clone())
            .placement(self.placement);
        if self.no_steal {
            opts = opts.no_steal();
        }
        if self.quota_exempt {
            opts = opts.quota_exempt();
        }
        base.options(opts)
    }

    /// Short human-readable request description for report tables.
    pub fn describe(&self) -> String {
        let want = match self.want {
            Want::Qr => "qr".to_string(),
            Want::ROnly => "r".to_string(),
            Want::Svd => "svd".to_string(),
            Want::SingularValues => "sigma".to_string(),
            Want::LowRank { rank, .. } => format!("lowrank:{rank}"),
            Want::Solve { rhs } => format!("solve:{rhs}"),
        };
        let algo = match self.algo {
            AlgoChoice::Auto => "auto".to_string(),
            AlgoChoice::Fixed(a) => a.cli_name().to_string(),
        };
        format!("{want}/{algo}")
    }
}

fn parse_want(s: &str) -> Result<(Want, SketchOptions)> {
    let mut parts = s.split(':');
    let head = parts.next().unwrap_or("");
    let want = match head {
        "qr" => Want::Qr,
        "r" | "r-only" => Want::ROnly,
        "svd" => Want::Svd,
        "sigma" | "singular-values" => Want::SingularValues,
        "lowrank" => {
            let rank: usize = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("lowrank wants a rank: lowrank:<rank>[:...]"))?
                .parse()
                .context("lowrank rank")?;
            if rank == 0 {
                bail!("lowrank rank must be >= 1");
            }
            Want::LowRank { rank, oversample: DEFAULT_OVERSAMPLE, power_iters: 0 }
        }
        "solve" => {
            // the rhs count is optional (defaults to 1) but must come
            // right after the head when given, like lowrank's rank
            Want::Solve { rhs: 1 }
        }
        other => bail!("unknown want {other:?} (qr|r|svd|sigma|lowrank:<rank>|solve[:<rhs>])"),
    };
    let mut want = want;
    let mut sketch = SketchOptions::default();
    let mut first = matches!(want, Want::Solve { .. });
    for knob in parts {
        // solve's optional leading rhs number
        if first {
            first = false;
            if let (Want::Solve { rhs }, Ok(n)) = (&mut want, knob.parse::<usize>()) {
                *rhs = n;
                continue;
            }
        }
        if let Some(v) = knob.strip_prefix('p') {
            if let (Want::LowRank { oversample, .. }, Ok(n)) = (&mut want, v.parse()) {
                *oversample = n;
                continue;
            }
        }
        if let Some(v) = knob.strip_prefix('q') {
            if let (Want::LowRank { power_iters, .. }, Ok(n)) = (&mut want, v.parse()) {
                *power_iters = n;
                continue;
            }
        }
        if let Some(v) = knob.strip_prefix('s') {
            if let Ok(n) = v.parse() {
                sketch.seed = n;
                continue;
            }
        }
        sketch.kind = SketchKind::parse(knob)
            .with_context(|| format!("want knob {knob:?} (p<n>|q<n>|s<seed>|gauss|countsketch)"))?;
    }
    Ok((want, sketch))
}

fn parse_algo(s: &str) -> Result<AlgoChoice> {
    if s == "auto" {
        return Ok(AlgoChoice::Auto);
    }
    Ok(AlgoChoice::Fixed(Algorithm::parse(s)?))
}

fn parse_line(fields: &[&str]) -> Result<BatchEntry> {
    if !(6..=10).contains(&fields.len()) {
        bail!(
            "expected `name rows cols seed want algo [priority] [@shard] \
             [+nosteal] [+exempt]`, got {} fields",
            fields.len()
        );
    }
    // the optional trailing fields: a priority name, an `@<k>` shard
    // pin, and `+` opt-out flags, in any order
    let mut priority = Priority::Normal;
    let mut placement = Placement::Auto;
    let mut no_steal = false;
    let mut quota_exempt = false;
    let mut seen_priority = false;
    let mut seen_placement = false;
    for field in &fields[6..] {
        if let Some(shard) = field.strip_prefix('@') {
            if seen_placement {
                bail!("duplicate @shard field {field:?}");
            }
            placement = Placement::Pinned(shard.parse().context("@shard")?);
            seen_placement = true;
        } else if let Some(flag) = field.strip_prefix('+') {
            match flag {
                "nosteal" if !no_steal => no_steal = true,
                "exempt" if !quota_exempt => quota_exempt = true,
                "nosteal" | "exempt" => bail!("duplicate flag {field:?}"),
                _ => bail!("unknown flag {field:?} (+nosteal|+exempt)"),
            }
        } else {
            if seen_priority {
                bail!("duplicate priority field {field:?}");
            }
            priority = Priority::parse(field)?;
            seen_priority = true;
        }
    }
    let (want, sketch) = parse_want(fields[4])?;
    Ok(BatchEntry {
        name: fields[0].to_string(),
        rows: fields[1].parse().context("rows")?,
        cols: fields[2].parse().context("cols")?,
        seed: fields[3].parse().context("seed")?,
        want,
        algo: parse_algo(fields[5])?,
        priority,
        placement,
        no_steal,
        quota_exempt,
        sketch,
    })
}

/// Fold one `%scheduler` directive's `key=value` fields into `cfg`.
/// Later directives (and later keys on one line) win key by key.
fn parse_scheduler_directive(fields: &[&str], mut cfg: SchedulerConfig) -> Result<SchedulerConfig> {
    if fields.is_empty() {
        bail!("%scheduler wants `key=value` fields (steal|locality|quota|autoscale|interval_ms)");
    }
    for field in fields {
        let (key, value) = field
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("expected key=value, got {field:?}"))?;
        match key {
            "steal" => cfg.steal = parse_on_off(value)?,
            "locality" => cfg.locality = parse_on_off(value)?,
            "quota" => {
                let n: u64 = value.parse().context("quota")?;
                cfg.quota_per_label = if n == 0 { None } else { Some(n as usize) };
            }
            "autoscale" => {
                let (min, max) = value
                    .split_once(':')
                    .ok_or_else(|| anyhow::anyhow!("autoscale wants MIN:MAX, got {value:?}"))?;
                cfg.autoscale_min = min.parse().context("autoscale min")?;
                cfg.autoscale_max = max.parse().context("autoscale max")?;
                if cfg.autoscale_max > 0 && cfg.autoscale_min > cfg.autoscale_max {
                    bail!("autoscale min {} exceeds max {}", cfg.autoscale_min, cfg.autoscale_max);
                }
            }
            "interval_ms" => {
                cfg.autoscale_interval =
                    Duration::from_millis(value.parse().context("interval_ms")?);
            }
            other => bail!("unknown %scheduler key {other:?}"),
        }
    }
    Ok(cfg)
}

fn parse_on_off(s: &str) -> Result<bool> {
    match s {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => bail!("expected on|off, got {other:?}"),
    }
}

/// A fully parsed manifest: the job entries plus any pool-level
/// `%scheduler` directive ([`SchedulerConfig`]). `scheduler` is `None`
/// when the manifest has no directive; CLI flags override it key by
/// key in `mrtsqr batch`.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub entries: Vec<BatchEntry>,
    pub scheduler: Option<SchedulerConfig>,
}

/// Parse a whole manifest, jobs and `%scheduler` directives alike.
/// Blank lines and `#` comments are skipped; errors name the offending
/// line.
pub fn parse_manifest_full(text: &str) -> Result<Manifest> {
    let mut entries = Vec::new();
    let mut scheduler = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields[0] == "%scheduler" {
            let cfg = parse_scheduler_directive(&fields[1..], scheduler.unwrap_or_default())
                .with_context(|| format!("manifest line {}: {line:?}", lineno + 1))?;
            scheduler = Some(cfg);
            continue;
        }
        let entry = parse_line(&fields)
            .with_context(|| format!("manifest line {}: {line:?}", lineno + 1))?;
        entries.push(entry);
    }
    if entries.is_empty() {
        bail!("manifest has no jobs");
    }
    Ok(Manifest { entries, scheduler })
}

/// Parse a manifest's job entries, ignoring any `%scheduler` directive
/// (the pre-elastic surface; `mrtsqr batch` uses
/// [`parse_manifest_full`]).
pub fn parse_manifest(text: &str) -> Result<Vec<BatchEntry>> {
    Ok(parse_manifest_full(text)?.entries)
}

/// Generate a synthetic batch of `jobs` entries for load generation
/// (`mrtsqr loadgen`): the 10-way mixed request cycle the determinism
/// suites exercise (every `want` including the PR 10 sketching family,
/// fixed and auto algorithms, all three priorities), over `inputs`
/// distinct gaussian matrices reused round-robin. Entries sharing an
/// input name share its rows/cols/seed, so each input is ingested once
/// however many jobs read it. Deterministic: the same arguments always
/// produce the same manifest.
pub fn synthetic_manifest(
    jobs: usize,
    inputs: usize,
    base_rows: usize,
    cols: usize,
    seed: u64,
) -> Vec<BatchEntry> {
    let inputs = inputs.max(1);
    let cols = cols.max(2);
    (0..jobs)
        .map(|i| {
            let k = i % inputs;
            let entry_cols = cols + k % 3;
            // the mixed-request cycle, extended with a LowRank and a
            // Solve leg in PR 10 (rank/oversample clamped so any cols
            // stays valid; the seeded sketch keeps the legs digestable)
            let (want, algo, priority) = match i % 10 {
                0 => (Want::Qr, AlgoChoice::Auto, Priority::Normal),
                1 => (Want::Qr, AlgoChoice::Fixed(Algorithm::DirectTsqr), Priority::Normal),
                2 => (Want::Qr, AlgoChoice::Fixed(Algorithm::DirectTsqrFused), Priority::High),
                3 => (Want::ROnly, AlgoChoice::Auto, Priority::Normal),
                4 => (
                    Want::ROnly,
                    AlgoChoice::Fixed(Algorithm::Cholesky { refine: false }),
                    Priority::Normal,
                ),
                5 => (Want::Svd, AlgoChoice::Auto, Priority::Normal),
                6 => (Want::SingularValues, AlgoChoice::Auto, Priority::Low),
                7 => (
                    Want::LowRank {
                        rank: (entry_cols / 4).max(1),
                        oversample: (entry_cols / 4).max(1),
                        power_iters: 0,
                    },
                    AlgoChoice::Fixed(Algorithm::Randomized),
                    Priority::Normal,
                ),
                8 => (Want::Solve { rhs: 1 }, AlgoChoice::Auto, Priority::Low),
                _ => (
                    Want::Qr,
                    AlgoChoice::Fixed(Algorithm::IndirectTsqr { refine: true }),
                    Priority::Normal,
                ),
            };
            BatchEntry {
                name: format!("gen-{k}"),
                rows: base_rows + 40 * k,
                cols: entry_cols,
                seed: seed.wrapping_add(k as u64),
                want,
                algo,
                priority,
                placement: Placement::Auto,
                no_steal: false,
                quota_exempt: false,
                sketch: SketchOptions { seed: seed ^ 0x5EED, ..SketchOptions::default() },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_doc_example() {
        let text = "\
# name  rows   cols  seed  want   algo     [priority] [@shard]
A1      40000  10    1     qr     auto
A2      80000  25    2     svd    direct   high

A3      40000  10    3     r      auto     low   @1   # trailing comment
A4      20000  8     4     sigma  indirect @0
";
        let jobs = parse_manifest(text).unwrap();
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].name, "A1");
        assert_eq!(jobs[0].want, Want::Qr);
        assert_eq!(jobs[0].algo, AlgoChoice::Auto);
        assert_eq!(jobs[0].priority, Priority::Normal);
        assert_eq!(jobs[0].placement, Placement::Auto);
        assert_eq!(jobs[1].algo, AlgoChoice::Fixed(Algorithm::DirectTsqr));
        assert_eq!(jobs[1].priority, Priority::High);
        assert_eq!(jobs[2].want, Want::ROnly);
        assert_eq!(jobs[2].priority, Priority::Low);
        assert_eq!(jobs[2].placement, Placement::Pinned(1));
        assert_eq!(jobs[3].want, Want::SingularValues);
        assert_eq!(jobs[3].placement, Placement::Pinned(0));
        assert_eq!(jobs[3].priority, Priority::Normal);
        assert_eq!(jobs[3].describe(), "sigma/indirect");
    }

    #[test]
    fn shard_pin_and_priority_compose_in_either_order() {
        let e = parse_manifest("A 100 4 7 qr direct @2 high").unwrap().remove(0);
        assert_eq!(e.priority, Priority::High);
        assert_eq!(e.placement, Placement::Pinned(2));
        let req = e.request();
        assert_eq!(req.options.placement, Placement::Pinned(2));
        assert!(parse_manifest("A 100 4 7 qr direct @1 @2").is_err(), "duplicate pin");
        assert!(parse_manifest("A 100 4 7 qr direct low high").is_err(), "duplicate priority");
        assert!(parse_manifest("A 100 4 7 qr direct @x").is_err(), "non-numeric shard");
    }

    #[test]
    fn entry_builds_a_labeled_prioritized_request() {
        let e = parse_manifest("hot 100 4 7 qr direct high").unwrap().remove(0);
        let req = e.request();
        assert_eq!(req.want, Want::Qr);
        assert_eq!(req.algo, AlgoChoice::Fixed(Algorithm::DirectTsqr));
        assert_eq!(req.options.priority, Priority::High);
        assert_eq!(req.options.label.as_deref(), Some("hot"));
        assert!(!req.options.no_steal);
        assert!(!req.options.quota_exempt);
    }

    #[test]
    fn elastic_flags_parse_and_reach_the_request() {
        let e = parse_manifest("A 100 4 7 qr direct +nosteal high +exempt @1")
            .unwrap()
            .remove(0);
        assert!(e.no_steal);
        assert!(e.quota_exempt);
        assert_eq!(e.priority, Priority::High);
        assert_eq!(e.placement, Placement::Pinned(1));
        let req = e.request();
        assert!(req.options.no_steal);
        assert!(req.options.quota_exempt);
        assert!(parse_manifest("A 100 4 7 qr direct +nosteal +nosteal").is_err());
        assert!(parse_manifest("A 100 4 7 qr direct +turbo").is_err());
    }

    #[test]
    fn scheduler_directives_merge_and_cli_keeps_entries() {
        let text = "\
%scheduler steal=on quota=2
A 100 4 7 qr direct
%scheduler locality=on autoscale=1:4 interval_ms=50   # later line merges
";
        let m = parse_manifest_full(text).unwrap();
        assert_eq!(m.entries.len(), 1);
        let cfg = m.scheduler.expect("directive present");
        assert!(cfg.steal);
        assert!(cfg.locality);
        assert_eq!(cfg.quota_per_label, Some(2));
        assert_eq!((cfg.autoscale_min, cfg.autoscale_max), (1, 4));
        assert_eq!(cfg.autoscale_interval, Duration::from_millis(50));
        // quota=0 switches the quota off; bad keys and shapes error
        let off = parse_manifest_full("%scheduler quota=0\nA 100 4 7 qr direct").unwrap();
        assert_eq!(off.scheduler.expect("directive").quota_per_label, None);
        assert!(parse_manifest_full("%scheduler steal=sometimes\nA 100 4 7 qr auto").is_err());
        assert!(parse_manifest_full("%scheduler autoscale=4:1\nA 100 4 7 qr auto").is_err());
        assert!(parse_manifest_full("%scheduler turbo=on\nA 100 4 7 qr auto").is_err());
        // the directive-ignoring surface still sees the jobs
        assert_eq!(parse_manifest(text).unwrap().len(), 1);
    }

    #[test]
    fn synthetic_manifest_is_deterministic_and_input_consistent() {
        let a = synthetic_manifest(20, 3, 1000, 6, 42);
        let b = synthetic_manifest(20, 3, 1000, 6, 42);
        assert_eq!(a, b, "same arguments, same manifest");
        assert_eq!(a.len(), 20);
        // entries sharing a name must agree on rows/cols/seed — one
        // ingestion serves them all
        let mut shapes: std::collections::HashMap<&str, (usize, usize, u64)> =
            std::collections::HashMap::new();
        for e in &a {
            let shape = (e.rows, e.cols, e.seed);
            assert_eq!(*shapes.entry(&e.name).or_insert(shape), shape, "{}", e.name);
        }
        assert_eq!(shapes.len(), 3, "three distinct inputs");
        // the mix covers every want and all three priorities
        for want in [Want::Qr, Want::ROnly, Want::Svd, Want::SingularValues] {
            assert!(a.iter().any(|e| e.want == want), "{want:?} missing");
        }
        assert!(
            a.iter().any(|e| matches!(e.want, Want::LowRank { .. })),
            "LowRank leg missing"
        );
        assert!(
            a.iter().any(|e| matches!(e.want, Want::Solve { .. })),
            "Solve leg missing"
        );
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert!(a.iter().any(|e| e.priority == p), "{p:?} missing");
        }
        // every LowRank leg must be feasible at its own cols: the
        // randomized family needs rank + oversample <= cols
        for e in &a {
            if let Want::LowRank { rank, oversample, .. } = e.want {
                assert!(rank >= 1 && rank + oversample <= e.cols, "{}: infeasible", e.name);
            }
        }
        // the sketch seed is pinned so the legs stay digest-comparable
        assert!(a.iter().all(|e| e.sketch == a[0].sketch));
    }

    #[test]
    fn sketch_wants_parse_with_colon_knobs() {
        let e = parse_manifest("L 4000 64 7 lowrank:4:p8:q1:s42:countsketch randomized")
            .unwrap()
            .remove(0);
        assert_eq!(e.want, Want::LowRank { rank: 4, oversample: 8, power_iters: 1 });
        assert_eq!(e.sketch, SketchOptions { kind: SketchKind::CountSketch, seed: 42 });
        assert_eq!(e.algo, AlgoChoice::Fixed(Algorithm::Randomized));
        assert_eq!(e.describe(), "lowrank:4/randomized");
        let req = e.request();
        assert_eq!(req.want, e.want);
        assert_eq!(req.sketch, e.sketch);

        // defaults: oversample falls back, solve's rhs defaults to 1
        let e = parse_manifest("L 4000 64 7 lowrank:6 auto").unwrap().remove(0);
        assert_eq!(
            e.want,
            Want::LowRank { rank: 6, oversample: DEFAULT_OVERSAMPLE, power_iters: 0 }
        );
        assert_eq!(e.sketch, SketchOptions::default());
        let e = parse_manifest("S 4000 8 7 solve auto").unwrap().remove(0);
        assert_eq!(e.want, Want::Solve { rhs: 1 });
        let e = parse_manifest("S 4000 8 7 solve:2:s7 auto").unwrap().remove(0);
        assert_eq!(e.want, Want::Solve { rhs: 2 });
        assert_eq!(e.sketch.seed, 7);
        assert_eq!(e.describe(), "solve:2/auto");

        // error shapes: missing rank, zero rank, unknown knob, and
        // lowrank-only knobs rejected on solve
        assert!(parse_manifest("L 4000 64 7 lowrank auto").is_err(), "rank required");
        assert!(parse_manifest("L 4000 64 7 lowrank:0 auto").is_err(), "rank >= 1");
        assert!(parse_manifest("L 4000 64 7 lowrank:4:turbo auto").is_err(), "unknown knob");
        assert!(parse_manifest("S 4000 8 7 solve:1:p8 auto").is_err(), "p is lowrank-only");
        assert!(parse_manifest("S 4000 8 7 solve:1:q2 auto").is_err(), "q is lowrank-only");
    }

    #[test]
    fn scheduler_unknown_keys_are_rejected_with_line_numbers() {
        // regression (PR 10 satellite): the rejection must carry the
        // 1-based line number so a typo in a long manifest is findable
        let text = "\
A 100 4 7 qr direct
# comment
%scheduler turbo=on
";
        let err = parse_manifest_full(text).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 3"), "{msg}");
        assert!(msg.contains("turbo"), "{msg}");
    }

    #[test]
    fn bad_lines_are_reported_with_position() {
        let err = parse_manifest("A 100 4 7 qr").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"), "{err:#}");
        let err = parse_manifest("A 100 4 7 qr direct urgent").unwrap_err();
        assert!(format!("{err:#}").contains("urgent"), "{err:#}");
        let err = parse_manifest("A ten 4 7 qr direct").unwrap_err();
        assert!(format!("{err:#}").contains("rows"), "{err:#}");
        assert!(parse_manifest("# only comments\n").is_err());
    }
}
