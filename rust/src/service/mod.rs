//! L5 — the concurrent job service: submit/await factorization jobs
//! over one shared cluster.
//!
//! The paper's pitch is *throughput on a shared platform*: Direct TSQR
//! wins because many independent map/reduce tasks keep the machine
//! busy, and both Demmel et al.'s communication-optimal TSQR
//! (arXiv:0809.2407) and the grid TSQR of Agullo et al.
//! (arXiv:0912.2572) treat the factorization as a *service*, not a
//! one-shot program. [`TsqrService`] makes the public API say the same
//! thing:
//!
//! ```no_run
//! use mrtsqr::session::{FactorizationRequest, Priority, TsqrSession};
//!
//! # fn main() -> anyhow::Result<()> {
//! let svc = TsqrSession::builder().service_workers(4).build_service()?;
//! let a = svc.ingest_gaussian("A", 100_000, 25, 42)?;
//! let b = svc.ingest_gaussian("B", 50_000, 10, 43)?;
//! let j1 = svc.submit(&a, FactorizationRequest::qr())?;               // returns immediately
//! let j2 = svc.submit(&b, FactorizationRequest::svd().with_priority(Priority::High))?;
//! let (f1, f2) = (j1.wait()?, j2.wait()?);                            // Arc<Factorization>
//! println!("{} + {} done", f1.algorithm.name(), f2.algorithm.name());
//! # Ok(())
//! # }
//! ```
//!
//! # Architecture
//!
//! * **Shared cluster.** One `Mutex<Engine>` (DFS + disk model + slot
//!   config + host pool size) and one [`SharedCompute`] backend serve
//!   every job. Workers lock the engine per *step* (one MapReduce
//!   iteration or one leader DFS access), never across a whole job, so
//!   in-flight jobs interleave their iterations — job A's serial
//!   leader work (R⁻¹, Jacobi SVD, κ probes) overlaps job B's engine
//!   waves, and each wave still fans out on the engine's
//!   `host_threads` pool.
//! * **Bounded priority-FIFO queue.** [`TsqrService::submit`] enqueues
//!   and returns a [`JobHandle`]; at capacity it blocks
//!   (back-pressure) while [`TsqrService::try_submit`] errors. Workers
//!   dequeue the highest [`Priority`] first, FIFO within a priority.
//! * **Per-job namespaces.** Every job's intermediates live under
//!   `job-<id>/tmp/…`, fixing the latent collision of `seq`-derived
//!   temp names on a shared DFS; [`TsqrService::evict_job`] sweeps a
//!   namespace when its factors are no longer needed.
//! * **Per-job fault streams.** Fault draws come from an RNG derived
//!   from the cluster's fault seed and the job id
//!   ([`Engine::run_with_rng`]), so injected faults are deterministic
//!   however concurrently jobs interleave.
//! * **One execution path.** Workers run
//!   [`crate::session::TsqrSession::factorize`]'s own engine room
//!   (`session::exec`) — a session *is* this service degenerated to
//!   inline execution, and `rust/tests/service.rs` asserts
//!   concurrent-vs-serial bit-identity of `R`, `Q`, Σ and
//!   `virtual_secs`.
//!
//! `service_workers(0)` gives manual-drain mode: nothing runs in the
//! background and [`TsqrService::drain_now`] /
//! [`TsqrService::drain_one`] execute queued jobs on the calling
//! thread in deterministic (priority, FIFO) order — the serial
//! baseline the determinism tests compare against.

pub mod manifest;

pub use manifest::{parse_manifest, BatchEntry};

use crate::coordinator::{CoordOpts, Coordinator, MatrixHandle};
use crate::dfs::Dfs;
use crate::linalg::Matrix;
use crate::mapreduce::Engine;
use crate::runtime::SharedCompute;
use crate::session::{exec, Factorization, FactorizationRequest, MatrixWriter, Priority};
use crate::util::rng::Rng;
use crate::workload;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

/// Service-only knobs carried by the [`crate::session::SessionBuilder`].
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Background worker threads (`0` = manual drain).
    pub workers: usize,
    /// Bounded queue capacity (≥ 1).
    pub queue_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: 2, queue_capacity: 64 }
    }
}

/// Identifier of one submitted job; also names its DFS namespace
/// (`job-<id>/`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

impl JobId {
    /// The job's DFS namespace prefix.
    pub fn namespace(&self) -> String {
        format!("job-{}/", self.0)
    }
}

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// Observable lifecycle of a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

/// Terminal state + result storage for one job.
enum JobSlot {
    Queued,
    Running,
    Done { fact: Arc<Factorization>, wall_secs: f64 },
    Failed { msg: String, wall_secs: f64 },
    Cancelled,
}

struct JobShared {
    slot: Mutex<JobSlot>,
    done: Condvar,
}

/// Handle returned by [`TsqrService::submit`]: poll or block for the
/// job's [`Factorization`]. All methods take `&self`; the result is an
/// `Arc`, so `wait`/`try_result` can be called repeatedly and from
/// multiple threads.
pub struct JobHandle {
    id: JobId,
    label: Option<String>,
    shared: Arc<JobShared>,
}

impl JobHandle {
    pub fn id(&self) -> JobId {
        self.id
    }

    /// The request's label, if it carried one.
    pub fn label(&self) -> Option<&str> {
        self.label.as_deref()
    }

    pub fn status(&self) -> JobStatus {
        match *self.shared.slot.lock().expect("job slot") {
            JobSlot::Queued => JobStatus::Queued,
            JobSlot::Running => JobStatus::Running,
            JobSlot::Done { .. } => JobStatus::Done,
            JobSlot::Failed { .. } => JobStatus::Failed,
            JobSlot::Cancelled => JobStatus::Cancelled,
        }
    }

    /// Block until the job reaches a terminal state; `Ok` carries the
    /// shared factorization, `Err` a failure/cancellation report.
    pub fn wait(&self) -> Result<Arc<Factorization>> {
        let mut slot = self.shared.slot.lock().expect("job slot");
        loop {
            match &*slot {
                JobSlot::Queued | JobSlot::Running => {
                    slot = self.shared.done.wait(slot).expect("job slot");
                }
                JobSlot::Done { fact, .. } => return Ok(fact.clone()),
                JobSlot::Failed { msg, .. } => bail!("{} failed: {msg}", self.id),
                JobSlot::Cancelled => bail!("{} was cancelled before it ran", self.id),
            }
        }
    }

    /// Non-blocking probe: `None` while the job is queued or running,
    /// `Some(result)` once terminal.
    pub fn try_result(&self) -> Option<Result<Arc<Factorization>>> {
        match &*self.shared.slot.lock().expect("job slot") {
            JobSlot::Queued | JobSlot::Running => None,
            JobSlot::Done { fact, .. } => Some(Ok(fact.clone())),
            JobSlot::Failed { msg, .. } => Some(Err(anyhow!("{} failed: {msg}", self.id))),
            JobSlot::Cancelled => Some(Err(anyhow!("{} was cancelled before it ran", self.id))),
        }
    }

    /// Measured wall-clock seconds of the job's execution (`None`
    /// until it completed or failed while running). Queue wait time is
    /// *excluded*: this is running-to-terminal, the per-job number
    /// `mrtsqr batch` sums to show submit/await overlap.
    pub fn wall_secs(&self) -> Option<f64> {
        match &*self.shared.slot.lock().expect("job slot") {
            JobSlot::Done { wall_secs, .. } | JobSlot::Failed { wall_secs, .. } => {
                Some(*wall_secs)
            }
            _ => None,
        }
    }

    /// Cancel the job if it has not started running. Returns `true` on
    /// success; a job already running (or finished) is unaffected and
    /// `false` comes back.
    pub fn cancel(&self) -> bool {
        let mut slot = self.shared.slot.lock().expect("job slot");
        if matches!(*slot, JobSlot::Queued) {
            *slot = JobSlot::Cancelled;
            self.shared.done.notify_all();
            true
        } else {
            false
        }
    }
}

/// One queue entry (the handle keeps the shared slot alive on the
/// caller's side).
struct QueuedJob {
    id: JobId,
    priority: Priority,
    input: MatrixHandle,
    req: FactorizationRequest,
    shared: Arc<JobShared>,
}

struct QueueState {
    jobs: VecDeque<QueuedJob>,
    /// `false` once shutdown begins: submissions are rejected, workers
    /// drain what is left and exit.
    open: bool,
}

struct ServiceInner {
    engine: Mutex<Engine>,
    compute: SharedCompute,
    opts: CoordOpts,
    /// Base seed for per-job fault streams (see [`Engine::fault_seed`]).
    fault_seed: u64,
    queue: Mutex<QueueState>,
    /// Signalled when a job is enqueued (workers wait here).
    ready: Condvar,
    /// Signalled when a job is dequeued (blocked `submit`s wait here).
    space: Condvar,
    capacity: usize,
}

impl ServiceInner {
    fn lock_queue(&self) -> MutexGuard<'_, QueueState> {
        self.queue.lock().expect("service queue")
    }

    /// Highest priority first, FIFO (smallest id) within a priority.
    fn pop_best(jobs: &mut VecDeque<QueuedJob>) -> Option<QueuedJob> {
        let mut best: Option<usize> = None;
        for (i, job) in jobs.iter().enumerate() {
            match best {
                None => best = Some(i),
                // strictly-greater keeps the earliest (lowest id) of a
                // priority class, because the deque is in id order
                Some(b) if job.priority > jobs[b].priority => best = Some(i),
                Some(_) => {}
            }
        }
        best.and_then(|i| jobs.remove(i))
    }

    /// Run one dequeued job to a terminal state. Skips (and reports
    /// `false` for) jobs cancelled while queued.
    fn execute_job(&self, job: QueuedJob) -> bool {
        {
            let mut slot = job.shared.slot.lock().expect("job slot");
            if matches!(*slot, JobSlot::Cancelled) {
                return false;
            }
            *slot = JobSlot::Running;
        }
        let t0 = Instant::now();
        // catch_unwind so one panicking job reports Failed instead of
        // killing its worker thread and wedging every waiter
        let outcome = catch_unwind(AssertUnwindSafe(|| self.run_request(&job)));
        let wall_secs = t0.elapsed().as_secs_f64();
        let slot_value = match outcome {
            Ok(Ok(fact)) => JobSlot::Done { fact: Arc::new(fact), wall_secs },
            Ok(Err(err)) => JobSlot::Failed { msg: format!("{err:#}"), wall_secs },
            Err(_) => JobSlot::Failed { msg: "job panicked".into(), wall_secs },
        };
        *job.shared.slot.lock().expect("job slot") = slot_value;
        job.shared.done.notify_all();
        true
    }

    fn run_request(&self, job: &QueuedJob) -> Result<Factorization> {
        // per-job fault stream: depends only on (cluster seed, job id),
        // never on how concurrent jobs interleave their steps
        let fault_rng =
            Rng::new(self.fault_seed ^ (job.id.0 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut coord = Coordinator::shared(&self.engine, &*self.compute)
            .with_opts(self.opts)
            .with_namespace(job.id.namespace())
            .with_fault_rng(fault_rng);
        exec::execute(&mut coord, &job.input, &job.req)
    }
}

fn worker_loop(inner: Arc<ServiceInner>) {
    loop {
        let job = {
            let mut q = inner.lock_queue();
            loop {
                if let Some(job) = ServiceInner::pop_best(&mut q.jobs) {
                    break Some(job);
                }
                if !q.open {
                    break None;
                }
                q = inner.ready.wait(q).expect("service queue");
            }
        };
        let Some(job) = job else { return };
        inner.space.notify_one();
        inner.execute_job(job);
    }
}

/// A concurrent factorization service over one shared simulated
/// cluster. Build with
/// [`crate::session::SessionBuilder::build_service`]; see the
/// [module docs](self) for the architecture.
pub struct TsqrService {
    inner: Arc<ServiceInner>,
    workers: Vec<JoinHandle<()>>,
    backend_desc: &'static str,
    next_id: AtomicU64,
}

impl TsqrService {
    pub(crate) fn start(
        engine: Engine,
        compute: SharedCompute,
        backend_desc: &'static str,
        opts: CoordOpts,
        cfg: ServiceConfig,
    ) -> TsqrService {
        let inner = Arc::new(ServiceInner {
            fault_seed: engine.fault_seed(),
            engine: Mutex::new(engine),
            compute,
            opts,
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), open: true }),
            ready: Condvar::new(),
            space: Condvar::new(),
            capacity: cfg.queue_capacity.max(1),
        });
        let workers = (0..cfg.workers)
            .map(|i| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("tsqr-worker-{i}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn service worker")
            })
            .collect();
        TsqrService { inner, workers, backend_desc, next_id: AtomicU64::new(0) }
    }

    /// Short name of the resolved compute backend.
    pub fn backend_desc(&self) -> &'static str {
        self.backend_desc
    }

    /// Background worker threads serving the queue.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Bounded queue capacity (submissions beyond it block).
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Host worker threads each job's map/reduce waves fan out on (the
    /// cluster's realized `ClusterConfig::host_threads`).
    pub fn host_threads(&self) -> usize {
        lock_engine(&self.inner.engine).cluster.host_threads
    }

    /// Jobs currently queued (not yet picked up by a worker).
    pub fn pending(&self) -> usize {
        self.inner.lock_queue().jobs.len()
    }

    // ----------------------------------------------------- submission

    fn enqueue(&self, q: &mut QueueState, input: &MatrixHandle, req: FactorizationRequest) -> JobHandle {
        let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
        let shared = Arc::new(JobShared { slot: Mutex::new(JobSlot::Queued), done: Condvar::new() });
        let handle = JobHandle { id, label: req.label.clone(), shared: shared.clone() };
        q.jobs.push_back(QueuedJob {
            id,
            priority: req.priority,
            input: input.clone(),
            req,
            shared,
        });
        self.inner.ready.notify_one();
        handle
    }

    /// Submit a job and return immediately with its [`JobHandle`]. At
    /// queue capacity this *blocks* until a worker (or drain) frees a
    /// slot — back-pressure, not unbounded buffering.
    pub fn submit(&self, input: &MatrixHandle, req: FactorizationRequest) -> Result<JobHandle> {
        let mut q = self.inner.lock_queue();
        while q.open && q.jobs.len() >= self.inner.capacity {
            q = self.inner.space.wait(q).expect("service queue");
        }
        if !q.open {
            bail!("job service is shut down");
        }
        Ok(self.enqueue(&mut q, input, req))
    }

    /// Non-blocking [`TsqrService::submit`]: errors instead of waiting
    /// when the queue is at capacity.
    pub fn try_submit(&self, input: &MatrixHandle, req: FactorizationRequest) -> Result<JobHandle> {
        let mut q = self.inner.lock_queue();
        if !q.open {
            bail!("job service is shut down");
        }
        if q.jobs.len() >= self.inner.capacity {
            bail!(
                "job queue at capacity ({} queued) — wait for a worker or use submit()",
                self.inner.capacity
            );
        }
        Ok(self.enqueue(&mut q, input, req))
    }

    // ---------------------------------------------------- manual drain

    /// Pop and run the next queued job (highest priority, FIFO within)
    /// on the *calling* thread; `None` when nothing is queued. Jobs
    /// cancelled while queued are discarded, not counted. With
    /// `service_workers(0)` this is the deterministic serial engine the
    /// determinism tests baseline against.
    pub fn drain_one(&self) -> Option<JobId> {
        loop {
            let job = ServiceInner::pop_best(&mut self.inner.lock_queue().jobs)?;
            self.inner.space.notify_one();
            let id = job.id;
            if self.inner.execute_job(job) {
                return Some(id);
            }
        }
    }

    /// Run queued jobs on the calling thread until the queue is empty;
    /// returns how many executed.
    pub fn drain_now(&self) -> usize {
        let mut ran = 0;
        while self.drain_one().is_some() {
            ran += 1;
        }
        ran
    }

    // ------------------------------------------------------- ingestion

    /// Ingest an in-memory matrix into the shared DFS.
    pub fn ingest_matrix(&self, name: &str, a: &Matrix) -> Result<MatrixHandle> {
        self.ingest_with(name, a.cols, |w| w.push_chunk(a))
    }

    /// Ingest a seeded gaussian matrix (same records as
    /// [`crate::session::TsqrSession::ingest_gaussian`]).
    pub fn ingest_gaussian(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
    ) -> Result<MatrixHandle> {
        let mut rng = Rng::new(seed);
        let mut row = vec![0.0f64; cols];
        self.ingest_with(name, cols, |w| {
            for _ in 0..rows {
                for v in row.iter_mut() {
                    *v = rng.gaussian();
                }
                w.push_row(&row)?;
            }
            Ok(())
        })
    }

    /// Stream rows into the shared DFS through a [`MatrixWriter`]
    /// (the engine lock is held for the closure's duration — ingest
    /// before submitting jobs that read the file).
    pub fn ingest_with(
        &self,
        name: &str,
        cols: usize,
        f: impl FnOnce(&mut MatrixWriter) -> Result<()>,
    ) -> Result<MatrixHandle> {
        let mut engine = lock_engine(&self.inner.engine);
        let mut w = MatrixWriter::new(&mut engine.dfs, name, cols);
        f(&mut w)?;
        Ok(w.finish())
    }

    /// Read a handle's rows back from the shared DFS.
    pub fn get_matrix(&self, handle: &MatrixHandle) -> Result<Matrix> {
        let engine = lock_engine(&self.inner.engine);
        workload::get_matrix(&engine.dfs, &handle.file, handle.cols)
    }

    /// Run a closure against the shared DFS (byte totals, listings).
    pub fn with_dfs<T>(&self, f: impl FnOnce(&Dfs) -> T) -> T {
        f(&lock_engine(&self.inner.engine).dfs)
    }

    /// Mark a DFS file's virtual byte scale (see
    /// [`crate::session::TsqrSession::set_scale`]).
    pub fn set_scale(&self, name: &str, scale: f64) {
        lock_engine(&self.inner.engine).dfs.set_scale(name, scale);
    }

    // ------------------------------------------------------- lifecycle

    /// Delete one finished job's DFS namespace (`job-<id>/…` — its Q
    /// factor and intermediates). Returns how many files were swept.
    /// Handles into that namespace become dangling, which is the
    /// caller's contract to uphold.
    pub fn evict_job(&self, id: JobId) -> usize {
        let mut engine = lock_engine(&self.inner.engine);
        engine.dfs.delete_prefix(&id.namespace())
    }

    /// Graceful shutdown: reject new submissions, let the workers
    /// drain everything already queued, join them, and cancel whatever
    /// remains (only possible in manual-drain mode). Called on drop.
    pub fn shutdown(&mut self) {
        {
            let mut q = self.inner.lock_queue();
            if !q.open {
                return;
            }
            q.open = false;
        }
        self.inner.ready.notify_all();
        self.inner.space.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
        // manual-drain mode can leave queued jobs behind: resolve their
        // handles so no waiter hangs forever
        let mut q = self.inner.lock_queue();
        while let Some(job) = q.jobs.pop_front() {
            let mut slot = job.shared.slot.lock().expect("job slot");
            if matches!(*slot, JobSlot::Queued) {
                *slot = JobSlot::Cancelled;
            }
            job.shared.done.notify_all();
        }
    }
}

impl Drop for TsqrService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Backend, TsqrSession};

    fn manual_service() -> TsqrService {
        TsqrSession::builder()
            .backend(Backend::Native)
            .rows_per_task(50)
            .service_workers(0)
            .queue_capacity(8)
            .build_service()
            .unwrap()
    }

    #[test]
    fn submit_drain_wait_round_trip() {
        let svc = manual_service();
        let h = svc.ingest_gaussian("A", 300, 5, 1).unwrap();
        let job = svc.submit(&h, FactorizationRequest::qr().labeled("smoke")).unwrap();
        assert_eq!(job.status(), JobStatus::Queued);
        assert_eq!(job.label(), Some("smoke"));
        assert!(job.try_result().is_none());
        assert_eq!(svc.pending(), 1);
        assert_eq!(svc.drain_now(), 1);
        let fact = job.wait().unwrap();
        assert_eq!(job.status(), JobStatus::Done);
        assert!(job.wall_secs().unwrap() >= 0.0);
        assert_eq!(fact.r.rows, 5);
        // the Q handle lives in the job's namespace
        let qf = &fact.q.as_ref().unwrap().file;
        assert!(qf.starts_with(&job.id().namespace()), "{qf}");
        let q = svc.get_matrix(fact.q.as_ref().unwrap()).unwrap();
        assert!(q.orthogonality_error() < 1e-10);
    }

    #[test]
    fn priorities_jump_the_fifo_queue() {
        let svc = manual_service();
        let h = svc.ingest_gaussian("A", 60, 3, 2).unwrap();
        let lo = svc
            .submit(&h, FactorizationRequest::r_only().with_priority(Priority::Low))
            .unwrap();
        let n1 = svc.submit(&h, FactorizationRequest::r_only()).unwrap();
        let n2 = svc.submit(&h, FactorizationRequest::r_only()).unwrap();
        let hi = svc
            .submit(&h, FactorizationRequest::r_only().with_priority(Priority::High))
            .unwrap();
        let order: Vec<JobId> = std::iter::from_fn(|| svc.drain_one()).collect();
        assert_eq!(order, vec![hi.id(), n1.id(), n2.id(), lo.id()]);
    }

    #[test]
    fn evict_job_sweeps_only_that_namespace() {
        let svc = manual_service();
        let h = svc.ingest_gaussian("A", 200, 4, 3).unwrap();
        let j0 = svc.submit(&h, FactorizationRequest::qr()).unwrap();
        let j1 = svc.submit(&h, FactorizationRequest::qr()).unwrap();
        svc.drain_now();
        let f0 = j0.wait().unwrap();
        let f1 = j1.wait().unwrap();
        assert!(svc.evict_job(j0.id()) > 0);
        assert!(svc.get_matrix(f0.q.as_ref().unwrap()).is_err(), "evicted Q gone");
        let q1 = svc.get_matrix(f1.q.as_ref().unwrap()).unwrap();
        assert_eq!(q1.rows, 200, "other job's namespace untouched");
        // input matrix is outside every job namespace
        assert!(svc.get_matrix(&h).is_ok());
    }

    #[test]
    fn shutdown_rejects_new_submissions_and_resolves_queued_handles() {
        let mut svc = manual_service();
        let h = svc.ingest_gaussian("A", 60, 3, 4).unwrap();
        let stranded = svc.submit(&h, FactorizationRequest::r_only()).unwrap();
        svc.shutdown();
        assert_eq!(stranded.status(), JobStatus::Cancelled);
        assert!(stranded.wait().is_err());
        assert!(svc.submit(&h, FactorizationRequest::r_only()).is_err());
        assert!(svc.try_submit(&h, FactorizationRequest::r_only()).is_err());
    }
}
