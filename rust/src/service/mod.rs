//! L5 — the concurrent job service: submit/await factorization jobs
//! over a pool of engine shards.
//!
//! The paper's pitch is *throughput on a shared platform*: Direct TSQR
//! wins because many independent map/reduce tasks keep the machine
//! busy, and both Demmel et al.'s communication-optimal TSQR
//! (arXiv:0809.2407) and the grid TSQR of Agullo et al.
//! (arXiv:0912.2572) treat the factorization as a *service*, not a
//! one-shot program. [`TsqrService`] makes the public API say the same
//! thing:
//!
//! ```no_run
//! use mrtsqr::session::{FactorizationRequest, Priority, SubmitOptions, TsqrSession};
//!
//! # fn main() -> anyhow::Result<()> {
//! let svc = TsqrSession::builder().service_workers(4).build_service()?;
//! let a = svc.ingest_gaussian("A", 100_000, 25, 42)?;
//! let b = svc.ingest_gaussian("B", 50_000, 10, 43)?;
//! let j1 = svc.submit(&a, FactorizationRequest::qr())?;               // returns immediately
//! let j2 = svc.submit(
//!     &b,
//!     FactorizationRequest::svd().options(SubmitOptions::new().priority(Priority::High)),
//! )?;
//! let (f1, f2) = (j1.wait()?, j2.wait()?);                            // Arc<Factorization>
//! println!("{} + {} done", f1.algorithm.name(), f2.algorithm.name());
//! # Ok(())
//! # }
//! ```
//!
//! # Architecture
//!
//! * **Engine shard pool.** The cluster is
//!   [`crate::session::SessionBuilder::engine_shards`] independent
//!   `Mutex<Engine>` shards — each with its own DFS subtree
//!   (`shard-<k>/` prefix under which every job's `job-<id>/`
//!   namespace nests) and its own virtual clock — all sharing one
//!   pooled [`SharedCompute`] backend. Jobs on different shards run
//!   with **zero cross-job locking**; only jobs placed on the *same*
//!   shard contend for its engine, and even then only per *step* (one
//!   MapReduce iteration or one leader DFS access), so same-shard jobs
//!   still interleave their iterations while each wave fans out on the
//!   engine's `host_threads` pool. The default of one shard is exactly
//!   the historical single-engine service.
//! * **Router.** [`TsqrService::submit`] assigns each job to the
//!   least-loaded shard (queued + running jobs; ties broken
//!   deterministically on the job id), or honors an explicit
//!   [`Placement::Pinned`] on the request. Ingested matrices are
//!   pinned to shard 0 (their *home* shard); routing a job elsewhere
//!   O(1)-copies the input's reference-counted records onto the target
//!   shard at submission ([`crate::dfs::Dfs::export_file`]). Placement
//!   is pure scheduling: `shards=1` and `shards=N` produce
//!   bit-identical `R`/`Q`/Σ/`virtual_secs`/fault draws per job
//!   (`rust/tests/shards.rs`).
//! * **Bounded priority-FIFO queues.** Each shard owns one;
//!   [`TsqrService::submit`] enqueues on the routed shard and returns a
//!   [`JobHandle`]; at that shard's capacity it blocks (back-pressure)
//!   while [`TsqrService::try_submit`] errors. Each shard's
//!   [`crate::session::SessionBuilder::service_workers`] worker
//!   threads dequeue the highest [`Priority`] first, FIFO within a
//!   priority.
//! * **Per-job namespaces.** Every job's intermediates live under
//!   `<shard-ns>job-<id>/tmp/…` on its shard, fixing the latent
//!   collision of `seq`-derived temp names on a shared DFS;
//!   [`TsqrService::evict_job`] sweeps exactly that one namespace on
//!   that one shard.
//! * **Per-job fault streams.** Fault draws come from an RNG derived
//!   from the cluster's fault seed and the job id
//!   ([`Engine::run_with_rng`]) — never from the shard — so injected
//!   faults are deterministic however jobs interleave *and* wherever
//!   the router places them.
//! * **One execution path.** Workers run
//!   [`crate::session::TsqrSession::factorize`]'s own engine room
//!   (`session::exec`) — a session *is* this service degenerated to
//!   one shard and inline execution, and `rust/tests/service.rs` +
//!   `rust/tests/shards.rs` assert concurrent-vs-serial and
//!   sharded-vs-unsharded bit-identity of `R`, `Q`, Σ and
//!   `virtual_secs`.
//!
//! `service_workers(0)` gives manual-drain mode: nothing runs in the
//! background and [`TsqrService::drain_now`] /
//! [`TsqrService::drain_one`] execute queued jobs on the calling
//! thread in deterministic (priority, job-id) order across all shards
//! — the serial baseline the determinism tests compare against.
//!
//! # Elastic scheduling
//!
//! One [`SchedulerConfig`] knob group
//! ([`crate::session::SessionBuilder::scheduler`]) turns on the
//! elastic policies — all of them pure scheduling, so every modelled
//! bit (R/Q/Σ, `virtual_secs`, fault draws, `result_digest`) is
//! identical at any setting:
//!
//! * **Work stealing** (`steal`): an idle shard's worker threads steal
//!   the globally best *queued* job — same [`ServiceInner::sched_key`]
//!   order as the worker pop and the manual drain — from another
//!   shard's queue, re-staging its input by the O(1)
//!   `export_file`/`import_file` path. Running jobs are never
//!   migrated, the serial `service_workers(0)` drain never steals,
//!   and [`SubmitOptions::no_steal`] pins a job to its routed queue.
//! * **Chained-job locality** (`locality`): `Placement::Auto` prefers
//!   the least-loaded shard *already holding* the job's input over a
//!   globally least-loaded shard that would need a staging copy.
//! * **Admission control** (`quota_per_label`): at most that many
//!   in-flight jobs per [`SubmitOptions::label`]; excess submissions
//!   park at an admission gate (still cancellable, status `Queued`)
//!   and enter their routed queue in `sched_key` order as the label's
//!   jobs retire. One greedy tenant can no longer starve the pool.
//! * **Worker autoscaling** (`autoscale_min`/`autoscale_max`): a
//!   process-pool concern — see
//!   [`crate::session::SessionBuilder::worker_processes`]; the
//!   in-process service ignores the bounds.
//!
//! [`TsqrService::sched_tally`] reports per-shard steal counts and
//! per-label admission holds; `mrtsqr batch --json` and `mrtsqr
//! loadgen` surface the same tallies end-to-end.

pub mod manifest;

pub use manifest::{parse_manifest, parse_manifest_full, synthetic_manifest, BatchEntry};

use crate::coordinator::{lock_engine, CoordOpts, Coordinator, MatrixHandle};
use crate::dfs::records::{encode_row, row_key, Record};
use crate::dfs::Dfs;
use crate::linalg::Matrix;
use crate::mapreduce::Engine;
use crate::runtime::SharedCompute;
use crate::session::{
    exec, Factorization, FactorizationRequest, MatrixWriter, Placement, Priority, SubmitOptions,
};
use crate::util::rng::Rng;
use crate::workload;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service-only knobs carried by the [`crate::session::SessionBuilder`].
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Background worker threads *per engine shard* (`0` = manual
    /// drain).
    pub workers: usize,
    /// Bounded queue capacity per shard (≥ 1).
    pub queue_capacity: usize,
    /// Independent engine shards (≥ 1; 1 = the historical
    /// single-engine service).
    pub engine_shards: usize,
    /// Elastic-scheduling policies (stealing, locality, quotas,
    /// autoscaling bounds).
    pub scheduler: SchedulerConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            queue_capacity: 64,
            engine_shards: 1,
            scheduler: SchedulerConfig::default(),
        }
    }
}

/// The elastic-scheduling knob group (see the
/// [module docs](self#elastic-scheduling)): work stealing, chained-job
/// locality, per-label admission quotas, and worker-process
/// autoscaling bounds, configured in one place on
/// [`crate::session::SessionBuilder::scheduler`] and shipped verbatim
/// in the wire-v5 config handshake. Every policy defaults *off*, which
/// is bit-for-bit the pre-elastic scheduler; none of them ever changes
/// numerical results — stealing, locality, quotas and scaling are pure
/// scheduling.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchedulerConfig {
    /// Let idle shard workers steal queued jobs from other shards'
    /// queues (never running jobs; `service_workers(0)` manual drain
    /// never steals).
    pub steal: bool,
    /// Make `Placement::Auto` prefer a shard already holding the job's
    /// input matrix over a least-loaded shard that would need a
    /// staging copy.
    pub locality: bool,
    /// Per-[`SubmitOptions::label`] cap on in-flight jobs; excess
    /// submissions park at the admission gate in `sched_key` order.
    /// `None` = no admission control.
    pub quota_per_label: Option<usize>,
    /// Lower bound of live worker processes under autoscaling (clamped
    /// to ≥ 1; meaningful only with `worker_processes`).
    pub autoscale_min: usize,
    /// Upper bound of live worker processes; `0` disables autoscaling
    /// (the default).
    pub autoscale_max: usize,
    /// How often the autoscaler samples queue depth.
    pub autoscale_interval: Duration,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            steal: false,
            locality: false,
            quota_per_label: None,
            autoscale_min: 1,
            autoscale_max: 0,
            autoscale_interval: Duration::from_millis(250),
        }
    }
}

impl SchedulerConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enable/disable queue-level work stealing.
    pub fn steal(mut self, on: bool) -> Self {
        self.steal = on;
        self
    }

    /// Enable/disable input-locality preference for `Placement::Auto`.
    pub fn locality(mut self, on: bool) -> Self {
        self.locality = on;
        self
    }

    /// Cap in-flight jobs per label (admission control).
    pub fn quota_per_label(mut self, quota: usize) -> Self {
        self.quota_per_label = Some(quota);
        self
    }

    /// Autoscale worker processes between `min` and `max` live procs.
    pub fn autoscale(mut self, min: usize, max: usize) -> Self {
        self.autoscale_min = min;
        self.autoscale_max = max;
        self
    }

    /// Override the autoscaler's sampling interval.
    pub fn autoscale_interval(mut self, interval: Duration) -> Self {
        self.autoscale_interval = interval;
        self
    }
}

/// Cumulative elastic-scheduling counters reported by
/// [`TsqrService::sched_tally`] (and aggregated across worker
/// processes/hosts by the L6/L7 transports).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedTally {
    /// Jobs each shard executed after stealing them from another
    /// shard's queue (indexed by the *executing* shard).
    pub per_shard_steals: Vec<u64>,
    /// `(label, count)` of submissions that parked at the admission
    /// gate, sorted by label.
    pub admission_held: Vec<(String, u64)>,
}

/// Identifier of one submitted job; also names its DFS namespace
/// (`job-<id>/`, nested under its shard's `shard-<k>/` prefix on a
/// sharded service).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

impl JobId {
    /// The job's DFS namespace prefix (relative to its shard's).
    pub fn namespace(&self) -> String {
        format!("job-{}/", self.0)
    }
}

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// Observable lifecycle of a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

/// Terminal state + result storage for one job.
enum JobSlot {
    Queued,
    Running,
    Done { fact: Arc<Factorization>, wall_secs: f64 },
    /// Terminal state of an ingestion job ([`JobKind::Ingest`]): the
    /// rows are durably on the home shard.
    Ingested { handle: MatrixHandle, wall_secs: f64 },
    Failed { msg: String, wall_secs: f64 },
    Cancelled,
}

/// What kind of work a queued job carries — factorizations and
/// ingestions share one scheduler, one id space, and one lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    Factorize,
    Ingest,
}

struct JobShared {
    slot: Mutex<JobSlot>,
    done: Condvar,
}

/// Handle returned by [`TsqrService::submit`]: poll or block for the
/// job's [`Factorization`]. All methods take `&self`; the result is an
/// `Arc`, so `wait`/`try_result` can be called repeatedly and from
/// multiple threads.
pub struct JobHandle {
    id: JobId,
    kind: JobKind,
    label: Option<String>,
    shared: Arc<JobShared>,
}

impl JobHandle {
    pub fn id(&self) -> JobId {
        self.id
    }

    /// Whether this job factorizes or ingests.
    pub fn kind(&self) -> JobKind {
        self.kind
    }

    /// The request's label, if it carried one.
    pub fn label(&self) -> Option<&str> {
        self.label.as_deref()
    }

    pub fn status(&self) -> JobStatus {
        match *self.shared.slot.lock().expect("job slot") {
            JobSlot::Queued => JobStatus::Queued,
            JobSlot::Running => JobStatus::Running,
            JobSlot::Done { .. } | JobSlot::Ingested { .. } => JobStatus::Done,
            JobSlot::Failed { .. } => JobStatus::Failed,
            JobSlot::Cancelled => JobStatus::Cancelled,
        }
    }

    /// Block until the job reaches a terminal state; `Ok` carries the
    /// shared factorization, `Err` a failure/cancellation report.
    pub fn wait(&self) -> Result<Arc<Factorization>> {
        let mut slot = self.shared.slot.lock().expect("job slot");
        loop {
            match &*slot {
                JobSlot::Queued | JobSlot::Running => {
                    slot = self.shared.done.wait(slot).expect("job slot");
                }
                JobSlot::Done { fact, .. } => return Ok(fact.clone()),
                JobSlot::Ingested { .. } => {
                    bail!("{} is an ingestion job — wait on its IngestHandle", self.id)
                }
                JobSlot::Failed { msg, .. } => bail!("{} failed: {msg}", self.id),
                JobSlot::Cancelled => bail!("{} was cancelled before it ran", self.id),
            }
        }
    }

    /// Non-blocking probe: `None` while the job is queued or running,
    /// `Some(result)` once terminal.
    pub fn try_result(&self) -> Option<Result<Arc<Factorization>>> {
        match &*self.shared.slot.lock().expect("job slot") {
            JobSlot::Queued | JobSlot::Running => None,
            JobSlot::Done { fact, .. } => Some(Ok(fact.clone())),
            JobSlot::Ingested { .. } => {
                Some(Err(anyhow!("{} is an ingestion job — wait on its IngestHandle", self.id)))
            }
            JobSlot::Failed { msg, .. } => Some(Err(anyhow!("{} failed: {msg}", self.id))),
            JobSlot::Cancelled => Some(Err(anyhow!("{} was cancelled before it ran", self.id))),
        }
    }

    /// Measured wall-clock seconds of the job's execution (`None`
    /// until it completed or failed while running). Queue wait time is
    /// *excluded*: this is running-to-terminal, the per-job number
    /// `mrtsqr batch` sums to show submit/await overlap.
    pub fn wall_secs(&self) -> Option<f64> {
        match &*self.shared.slot.lock().expect("job slot") {
            JobSlot::Done { wall_secs, .. }
            | JobSlot::Ingested { wall_secs, .. }
            | JobSlot::Failed { wall_secs, .. } => Some(*wall_secs),
            _ => None,
        }
    }

    /// Cancel the job if it has not started running. Returns `true` on
    /// success; a job already running (or finished) is unaffected and
    /// `false` comes back.
    pub fn cancel(&self) -> bool {
        let mut slot = self.shared.slot.lock().expect("job slot");
        if matches!(*slot, JobSlot::Queued) {
            *slot = JobSlot::Cancelled;
            self.shared.done.notify_all();
            true
        } else {
            false
        }
    }
}

/// A deterministic description of the rows an asynchronous ingestion
/// job writes. Recipes (not row bytes) travel through the queue, so an
/// ingestion is replayable and cheap to enqueue.
#[derive(Clone)]
pub enum IngestRecipe {
    /// Seeded gaussian rows — byte-identical to
    /// [`TsqrService::ingest_gaussian`] with the same seed.
    Gaussian { rows: usize, seed: u64 },
    /// Explicit rows, shared behind an `Arc` (cloned once at
    /// submission).
    Rows(Arc<Matrix>),
}

impl IngestRecipe {
    fn rows(&self) -> usize {
        match self {
            IngestRecipe::Gaussian { rows, .. } => *rows,
            IngestRecipe::Rows(a) => a.rows,
        }
    }
}

/// Handle returned by [`TsqrService::ingest_async`]: the matrix's
/// shape is known up front from the recipe, so
/// [`IngestHandle::handle`] can feed a dependent
/// [`TsqrService::submit`] immediately — the scheduler queues that job
/// behind the ingestion via a dependency edge.
pub struct IngestHandle {
    job: JobHandle,
    handle: MatrixHandle,
}

impl IngestHandle {
    pub fn id(&self) -> JobId {
        self.job.id()
    }

    /// The matrix handle (valid for dependent submissions right away;
    /// the rows themselves land when the ingestion job runs).
    pub fn handle(&self) -> &MatrixHandle {
        &self.handle
    }

    pub fn status(&self) -> JobStatus {
        self.job.status()
    }

    /// Block until the rows are durably on the home shard.
    pub fn wait(&self) -> Result<MatrixHandle> {
        let mut slot = self.job.shared.slot.lock().expect("job slot");
        loop {
            match &*slot {
                JobSlot::Queued | JobSlot::Running => {
                    slot = self.job.shared.done.wait(slot).expect("job slot");
                }
                JobSlot::Ingested { handle, .. } => return Ok(handle.clone()),
                JobSlot::Done { .. } => bail!("{} is not an ingestion job", self.job.id),
                JobSlot::Failed { msg, .. } => bail!("{} failed: {msg}", self.job.id),
                JobSlot::Cancelled => bail!("{} was cancelled before it ran", self.job.id),
            }
        }
    }

    /// See [`JobHandle::wall_secs`].
    pub fn wall_secs(&self) -> Option<f64> {
        self.job.wall_secs()
    }

    /// Cancel the ingestion if it has not started (see
    /// [`JobHandle::cancel`]). Jobs already submitted against the
    /// handle then fail with a precise dependency error.
    pub fn cancel(&self) -> bool {
        self.job.cancel()
    }

    /// The underlying job handle (status polling, labels).
    pub fn job(&self) -> &JobHandle {
        &self.job
    }
}

/// What a queued job does when a worker (or drain) picks it up.
enum JobWork {
    /// Factorize `input` per `req` — the classic L5 job.
    Factorize { input: MatrixHandle, req: FactorizationRequest },
    /// Materialize `name` on the job's shard from a deterministic
    /// recipe, appending in bounded chunks so the engine lock is
    /// re-acquired per chunk, never held across the upload.
    Ingest { name: String, cols: usize, recipe: IngestRecipe },
}

impl JobWork {
    fn kind(&self) -> JobKind {
        match self {
            JobWork::Factorize { .. } => JobKind::Factorize,
            JobWork::Ingest { .. } => JobKind::Ingest,
        }
    }
}

/// One queue entry (the handle keeps the shared slot alive on the
/// caller's side).
struct QueuedJob {
    id: JobId,
    priority: Priority,
    work: JobWork,
    /// Jobs that must reach a terminal state before this one may run
    /// (today: the in-flight ingestion of this job's input). The drain
    /// stays deterministic: among *ready* jobs the ordinary
    /// [`ServiceInner::sched_key`] order decides, and dependency edges
    /// only ever delay a job behind work that was enqueued before it.
    deps: Vec<(JobId, Arc<JobShared>)>,
    shared: Arc<JobShared>,
    /// The request's tenant label (admission-quota key).
    label: Option<String>,
    /// [`SubmitOptions::no_steal`]: never migrate off the routed shard.
    no_steal: bool,
    /// Whether this job holds one unit of its label's admission quota
    /// ([`ServiceInner::settle_admission`] releases it exactly once at
    /// the terminal transition).
    quota_counted: bool,
    /// Set when a thief shard stole this job off its routed queue
    /// (stamped into [`crate::mapreduce::JobStats::stolen`]).
    stolen: bool,
}

/// Readiness of a queued job's dependency edges.
enum DepState {
    Ready,
    Waiting,
    Broken(String),
}

fn dep_state(deps: &[(JobId, Arc<JobShared>)]) -> DepState {
    for (id, shared) in deps {
        match &*shared.slot.lock().expect("job slot") {
            JobSlot::Queued | JobSlot::Running => return DepState::Waiting,
            JobSlot::Failed { msg, .. } => {
                return DepState::Broken(format!("dependency {id} failed: {msg}"))
            }
            JobSlot::Cancelled => return DepState::Broken(format!("dependency {id} was cancelled")),
            JobSlot::Done { .. } | JobSlot::Ingested { .. } => {}
        }
    }
    DepState::Ready
}

struct QueueState {
    jobs: VecDeque<QueuedJob>,
    /// `false` once shutdown begins: submissions are rejected, workers
    /// drain what is left and exit.
    open: bool,
}

/// One engine shard: an independent cluster (engine = DFS + disk model
/// + slot config + host pool) with its own bounded job queue and its
/// own DFS namespace prefix. Jobs on different shards never touch each
/// other's locks.
struct Shard {
    /// `""` on a single-shard service (the historical names),
    /// `shard-<k>/` otherwise.
    ns: String,
    engine: Mutex<Engine>,
    queue: Mutex<QueueState>,
    /// Signalled when a job is enqueued (this shard's workers wait
    /// here).
    ready: Condvar,
    /// Signalled when a job is dequeued (blocked `submit`s wait here).
    space: Condvar,
    /// Queued + running jobs — the router's load metric.
    load: AtomicUsize,
    /// Jobs this shard executed after stealing them from another
    /// shard's queue.
    steals: AtomicU64,
}

struct ServiceInner {
    shards: Vec<Shard>,
    compute: SharedCompute,
    opts: CoordOpts,
    /// Base seed for per-job fault streams (see [`Engine::fault_seed`]).
    /// One seed for the whole pool: a job's fault draws depend on its
    /// id only, never on its placement.
    fault_seed: u64,
    /// Per-shard queue capacity.
    capacity: usize,
    /// Router decisions: job id → shard index (read by
    /// [`TsqrService::shard_of`], freed by [`TsqrService::evict_job`]).
    /// One small entry per live job; eviction is the retirement step
    /// that reclaims it, so a service churning through unbounded jobs
    /// should evict them as it retires them.
    placements: Mutex<HashMap<u64, usize>>,
    /// Live asynchronous ingestions: matrix name → the job
    /// materializing it. A `submit` reading one of these names gets a
    /// dependency edge on the ingestion; entries retire when the
    /// ingestion reaches a terminal state (eagerly on completion,
    /// lazily at the next lookup).
    ingests: Mutex<HashMap<String, (JobId, Arc<JobShared>)>>,
    /// Elastic-scheduling policies (fixed at construction).
    scheduler: SchedulerConfig,
    /// Admission-control state (only consulted when
    /// `scheduler.quota_per_label` is set).
    admission: Mutex<Admission>,
}

/// Per-label fair-share admission state: in-flight counts and the gate
/// where over-quota submissions park.
#[derive(Default)]
struct Admission {
    /// label → jobs currently holding a quota unit (admitted, not yet
    /// terminal).
    inflight: HashMap<String, usize>,
    /// Parked submissions: `(routed shard, job)`. Admitted in
    /// [`ServiceInner::sched_key`] order as quota frees up.
    held: Vec<(usize, QueuedJob)>,
    /// Cumulative per-label count of submissions that parked here.
    held_total: HashMap<String, u64>,
}

impl ServiceInner {
    fn lock_queue(&self, shard: usize) -> MutexGuard<'_, QueueState> {
        self.shards[shard].queue.lock().expect("service queue")
    }

    /// The one scheduling order, shared by the per-shard workers
    /// ([`ServiceInner::pop_best`]) and the cross-shard manual drain
    /// ([`TsqrService::drain_one`]): smaller key runs earlier —
    /// highest priority first, FIFO (smallest job id) within a
    /// priority.
    fn sched_key(priority: Priority, id: JobId) -> (std::cmp::Reverse<Priority>, JobId) {
        (std::cmp::Reverse(priority), id)
    }

    /// Position + key of the job [`ServiceInner::sched_key`] orders
    /// first among those passing `eligible`. This is the **one** scan
    /// every consumer of the queue order uses — worker pop
    /// ([`ServiceInner::pop_best`]), the cross-shard manual drain
    /// ([`TsqrService::drain_one`]), steal victim selection
    /// ([`ServiceInner::steal_best`]), and admission
    /// ([`ServiceInner::settle_admission`]) — so the four orders can
    /// never desynchronize.
    fn best_pos(
        jobs: &VecDeque<QueuedJob>,
        eligible: impl Fn(&QueuedJob) -> bool,
    ) -> Option<(usize, (std::cmp::Reverse<Priority>, JobId))> {
        jobs.iter()
            .enumerate()
            .filter(|(_, job)| eligible(job))
            .map(|(i, job)| (i, Self::sched_key(job.priority, job.id)))
            .min_by_key(|&(_, key)| key)
    }

    /// Pop the job [`ServiceInner::sched_key`] orders first among the
    /// *runnable* ones — jobs whose dependencies are still queued or
    /// running stay put (dependency-aware drain; broken-dependency
    /// jobs are popped so [`ServiceInner::execute_job`] can fail them
    /// fast with a precise error).
    fn pop_best(jobs: &mut VecDeque<QueuedJob>) -> Option<QueuedJob> {
        Self::best_pos(jobs, |job| !matches!(dep_state(&job.deps), DepState::Waiting))
            .and_then(|(i, _)| jobs.remove(i))
    }

    /// Whether a queued job may migrate to another shard's worker:
    /// only factorizations (an ingestion writes its *home* shard), only
    /// jobs that did not opt out, and only dependency-ready ones (a
    /// broken-dep job stays for its own shard's fast-fail path).
    fn stealable(job: &QueuedJob) -> bool {
        matches!(job.work, JobWork::Factorize { .. })
            && !job.no_steal
            && matches!(dep_state(&job.deps), DepState::Ready)
    }

    /// Steal the globally best stealable queued job for idle shard
    /// `thief`: scan every other queue for the candidate
    /// [`ServiceInner::sched_key`] orders first (locks are taken one
    /// shard at a time), then re-lock the winner's queue and remove it
    /// — it may have been popped or drained meanwhile, in which case
    /// the steal simply fails and the caller rescans. The stolen job's
    /// input is re-staged onto the thief (O(1) reference-counted copy)
    /// and its placement record moves, so `shard_of`/`stats.shard`
    /// report where it actually ran.
    fn steal_best(&self, thief: usize) -> Option<QueuedJob> {
        let mut best: Option<(usize, JobId, (std::cmp::Reverse<Priority>, JobId))> = None;
        for k in 0..self.shards.len() {
            if k == thief {
                continue;
            }
            let q = self.lock_queue(k);
            if let Some((pos, key)) = Self::best_pos(&q.jobs, Self::stealable) {
                let id = q.jobs[pos].id;
                let better = match best {
                    None => true,
                    Some((_, _, best_key)) => key < best_key,
                };
                if better {
                    best = Some((k, id, key));
                }
            }
        }
        let (victim, id, _) = best?;
        let mut job = {
            let mut q = self.lock_queue(victim);
            let pos = q.jobs.iter().position(|j| j.id == id && Self::stealable(j))?;
            q.jobs.remove(pos)?
        };
        self.shards[victim].load.fetch_sub(1, Ordering::Relaxed);
        self.shards[victim].space.notify_one();
        self.shards[thief].load.fetch_add(1, Ordering::Relaxed);
        if let JobWork::Factorize { input, .. } = &job.work {
            self.stage_input(thief, &input.file);
        }
        self.placements.lock().expect("placements").insert(id.0, thief);
        self.shards[thief].steals.fetch_add(1, Ordering::Relaxed);
        job.stolen = true;
        Some(job)
    }

    /// Pick the shard for a job: an explicit pin (validated), or the
    /// least-loaded shard with a deterministic job-id tie-break. With
    /// [`SchedulerConfig::locality`] on, Auto placement first narrows
    /// to the shards already holding `input` (chained jobs land next
    /// to the Q they read, copy-free) and falls back to the full pool
    /// when none does.
    fn route(&self, id: JobId, placement: Placement, input: &str) -> Result<usize> {
        match placement {
            Placement::Pinned(k) => {
                if k >= self.shards.len() {
                    bail!(
                        "request pinned to shard {k}, but the service has {} shard(s)",
                        self.shards.len()
                    );
                }
                Ok(k)
            }
            Placement::Auto => {
                let candidates: Vec<usize> = if self.scheduler.locality {
                    let holders: Vec<usize> = (0..self.shards.len())
                        .filter(|&k| lock_engine(&self.shards[k].engine).dfs.exists(input))
                        .collect();
                    if holders.is_empty() {
                        (0..self.shards.len()).collect()
                    } else {
                        holders
                    }
                } else {
                    (0..self.shards.len()).collect()
                };
                let loads: Vec<usize> = candidates
                    .iter()
                    .map(|&k| self.shards[k].load.load(Ordering::Relaxed))
                    .collect();
                let min = *loads.iter().min().expect("at least one shard");
                let tied: Vec<usize> = candidates
                    .iter()
                    .zip(&loads)
                    .filter(|&(_, &l)| l == min)
                    .map(|(&k, _)| k)
                    .collect();
                Ok(tied[(id.0 as usize) % tied.len()])
            }
        }
    }

    /// Make `file` readable on `target`: a no-op when it is already
    /// there, an O(1) reference-counted copy from whichever shard holds
    /// it otherwise (source and target are locked one at a time, never
    /// together). A file found nowhere is left alone — the job will
    /// fail with the ordinary missing-input error when it runs.
    fn stage_input(&self, target: usize, file: &str) {
        if lock_engine(&self.shards[target].engine).dfs.exists(file) {
            return;
        }
        let mut found = None;
        for (i, shard) in self.shards.iter().enumerate() {
            if i == target {
                continue;
            }
            if let Ok(export) = lock_engine(&shard.engine).dfs.export_file(file) {
                found = Some(export);
                break;
            }
        }
        if let Some((records, scale)) = found {
            lock_engine(&self.shards[target].engine).dfs.import_file(file, records, scale);
        }
    }

    /// A terminal transition may unblock dependents queued on *any*
    /// shard: wake every shard's workers so a dependency-parked queue
    /// re-evaluates.
    fn wake_all_shards(&self) {
        for shard in &self.shards {
            shard.ready.notify_all();
        }
    }

    /// Retire a finished ingestion's name registration (only if it
    /// still points at this job — a re-ingest of the same name may
    /// have replaced it).
    fn retire_ingest_registration(&self, job: &QueuedJob) {
        if let JobWork::Ingest { name, .. } = &job.work {
            let mut ingests = self.ingests.lock().expect("ingests");
            if ingests.get(name).is_some_and(|(id, _)| *id == job.id) {
                ingests.remove(name);
            }
        }
    }

    /// Run one dequeued job to a terminal state on `shard_idx`. Skips
    /// (and reports `false` for) jobs cancelled while queued, and fails
    /// fast — without ever entering `Running` — on jobs whose
    /// dependency broke.
    fn execute_job(&self, shard_idx: usize, job: QueuedJob) -> bool {
        let shard = &self.shards[shard_idx];
        if let DepState::Broken(msg) = dep_state(&job.deps) {
            {
                let mut slot = job.shared.slot.lock().expect("job slot");
                if !matches!(*slot, JobSlot::Cancelled) {
                    *slot = JobSlot::Failed { msg, wall_secs: 0.0 };
                }
            }
            job.shared.done.notify_all();
            shard.load.fetch_sub(1, Ordering::Relaxed);
            self.retire_ingest_registration(&job);
            self.settle_admission(&job);
            self.wake_all_shards();
            return false;
        }
        {
            let mut slot = job.shared.slot.lock().expect("job slot");
            if matches!(*slot, JobSlot::Cancelled) {
                drop(slot);
                shard.load.fetch_sub(1, Ordering::Relaxed);
                self.retire_ingest_registration(&job);
                self.settle_admission(&job);
                self.wake_all_shards();
                return false;
            }
            *slot = JobSlot::Running;
        }
        let t0 = Instant::now();
        // catch_unwind so one panicking job reports Failed instead of
        // killing its worker thread and wedging every waiter
        let outcome = catch_unwind(AssertUnwindSafe(|| self.run_work(shard_idx, &job)));
        let wall_secs = t0.elapsed().as_secs_f64();
        let slot_value = match outcome {
            Ok(Ok(WorkOutput::Fact(mut fact))) => {
                fact.stats.shard = shard_idx;
                fact.stats.stolen = job.stolen;
                JobSlot::Done { fact: Arc::new(fact), wall_secs }
            }
            Ok(Ok(WorkOutput::Ingested(handle))) => JobSlot::Ingested { handle, wall_secs },
            Ok(Err(err)) => JobSlot::Failed { msg: format!("{err:#}"), wall_secs },
            Err(_) => JobSlot::Failed { msg: "job panicked".into(), wall_secs },
        };
        *job.shared.slot.lock().expect("job slot") = slot_value;
        job.shared.done.notify_all();
        shard.load.fetch_sub(1, Ordering::Relaxed);
        self.retire_ingest_registration(&job);
        self.settle_admission(&job);
        self.wake_all_shards();
        true
    }

    /// Give back one admission-quota unit taken by a submission that
    /// failed before enqueue (shutdown or capacity races).
    fn release_quota(&self, label: &str) {
        let mut adm = self.admission.lock().expect("admission");
        if let Some(n) = adm.inflight.get_mut(label) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                adm.inflight.remove(label);
            }
        }
    }

    /// Release the terminal job's admission-quota unit (if it held
    /// one) and admit the best held submission(s) whose label now has
    /// headroom, in [`ServiceInner::sched_key`] order. Cancelled holds
    /// are discarded. Admitted jobs enter their routed shard's queue
    /// past its capacity bound — the gate already delayed them once.
    fn settle_admission(&self, job: &QueuedJob) {
        if !job.quota_counted {
            return;
        }
        let quota = self.scheduler.quota_per_label.unwrap_or(usize::MAX);
        let mut adm = self.admission.lock().expect("admission");
        if let Some(label) = job.label.as_deref() {
            if let Some(n) = adm.inflight.get_mut(label) {
                *n = n.saturating_sub(1);
                if *n == 0 {
                    adm.inflight.remove(label);
                }
            }
        }
        loop {
            // the held list is not a VecDeque, so scan it directly with
            // the same sched_key order best_pos encodes
            let mut best: Option<(usize, (std::cmp::Reverse<Priority>, JobId))> = None;
            for (i, (_, held)) in adm.held.iter().enumerate() {
                let cancelled =
                    matches!(*held.shared.slot.lock().expect("job slot"), JobSlot::Cancelled);
                let label = held.label.as_deref().unwrap_or_default();
                let over = adm.inflight.get(label).copied().unwrap_or(0) >= quota;
                if over && !cancelled {
                    continue;
                }
                let key = Self::sched_key(held.priority, held.id);
                let better = match best {
                    None => true,
                    Some((_, best_key)) => key < best_key,
                };
                if better {
                    best = Some((i, key));
                }
            }
            let Some((i, _)) = best else { break };
            let (shard_idx, mut held) = adm.held.remove(i);
            if matches!(*held.shared.slot.lock().expect("job slot"), JobSlot::Cancelled) {
                // resolved while parked: nothing to run, nothing counted
                continue;
            }
            let label = held.label.clone().unwrap_or_default();
            *adm.inflight.entry(label).or_insert(0) += 1;
            held.quota_counted = true;
            let admitted = {
                let mut q = self.lock_queue(shard_idx);
                if q.open {
                    q.jobs.push_back(held);
                    true
                } else {
                    drop(q);
                    let mut slot = held.shared.slot.lock().expect("job slot");
                    *slot = JobSlot::Cancelled;
                    drop(slot);
                    held.shared.done.notify_all();
                    let label = held.label.as_deref().unwrap_or_default();
                    if let Some(n) = adm.inflight.get_mut(label) {
                        *n = n.saturating_sub(1);
                        if *n == 0 {
                            adm.inflight.remove(label);
                        }
                    }
                    false
                }
            };
            if admitted {
                self.shards[shard_idx].load.fetch_add(1, Ordering::Relaxed);
                self.shards[shard_idx].ready.notify_one();
                if self.scheduler.steal {
                    self.wake_all_shards();
                }
            }
        }
    }

    fn run_work(&self, shard_idx: usize, job: &QueuedJob) -> Result<WorkOutput> {
        match &job.work {
            JobWork::Factorize { input, req } => {
                let shard = &self.shards[shard_idx];
                // a job that queued behind an ingestion could not stage
                // its input at submission (the rows did not exist yet)
                // — staging is idempotent, so re-run it here
                self.stage_input(shard_idx, &input.file);
                // per-job fault stream: depends only on (cluster seed,
                // job id), never on how concurrent jobs interleave
                // their steps — or on which shard the router picked
                let fault_rng =
                    Rng::new(self.fault_seed ^ (job.id.0 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut coord = Coordinator::shared(&shard.engine, &*self.compute)
                    .with_opts(self.opts)
                    .with_namespace(format!("{}{}", shard.ns, job.id.namespace()))
                    .with_fault_rng(fault_rng);
                exec::execute(&mut coord, input, req).map(WorkOutput::Fact)
            }
            JobWork::Ingest { name, cols, recipe } => {
                self.run_ingest(shard_idx, name, *cols, recipe).map(WorkOutput::Ingested)
            }
        }
    }

    /// Body of an asynchronous ingestion job: generate rows from the
    /// recipe and append them in [`INGEST_CHUNK_ROWS`]-row chunks,
    /// taking the shard's engine lock per chunk — a long upload never
    /// starves same-shard factorizations. A failed ingestion deletes
    /// its partial file: no half-written matrix is ever visible.
    fn run_ingest(
        &self,
        shard_idx: usize,
        name: &str,
        cols: usize,
        recipe: &IngestRecipe,
    ) -> Result<MatrixHandle> {
        let shard = &self.shards[shard_idx];
        // scales registered ahead of ingestion live on shard 0; an
        // ingest homed elsewhere must honor them there (mirrors the
        // synchronous path)
        let pre_scale = if shard_idx != 0 {
            let engine = lock_engine(&self.shards[0].engine);
            Some(engine.dfs.scale(name)).filter(|s| *s != 1.0)
        } else {
            None
        };
        lock_engine(&shard.engine).dfs.put(name, Vec::new());
        let total_rows = recipe.rows();
        let write = (|| -> Result<()> {
            match recipe {
                IngestRecipe::Gaussian { rows, seed } => {
                    let mut rng = Rng::new(*seed);
                    let mut row = vec![0.0f64; cols];
                    let mut next = 0usize;
                    while next < *rows {
                        let take = INGEST_CHUNK_ROWS.min(rows - next);
                        let mut recs = Vec::with_capacity(take);
                        for _ in 0..take {
                            for v in row.iter_mut() {
                                *v = rng.gaussian();
                            }
                            recs.push(Record::new(row_key(next as u64), encode_row(&row)));
                            next += 1;
                        }
                        lock_engine(&shard.engine).dfs.append(name, recs);
                    }
                }
                IngestRecipe::Rows(a) => {
                    ensure!(
                        a.cols == cols,
                        "recipe rows are {} wide, ingestion declared {cols}",
                        a.cols
                    );
                    let mut next = 0usize;
                    while next < a.rows {
                        let take = INGEST_CHUNK_ROWS.min(a.rows - next);
                        let recs: Vec<Record> = (next..next + take)
                            .map(|i| Record::new(row_key(i as u64), encode_row(a.row(i))))
                            .collect();
                        next += take;
                        lock_engine(&shard.engine).dfs.append(name, recs);
                    }
                }
            }
            Ok(())
        })();
        if let Err(err) = write {
            lock_engine(&shard.engine).dfs.delete(name);
            return Err(err);
        }
        if let Some(scale) = pre_scale {
            lock_engine(&shard.engine).dfs.set_scale(name, scale);
        }
        // stale copies elsewhere are now invalid (mirrors the
        // synchronous ingest)
        for (k, other) in self.shards.iter().enumerate() {
            if k != shard_idx {
                lock_engine(&other.engine).dfs.delete(name);
            }
        }
        Ok(MatrixHandle::new(name, total_rows, cols))
    }
}

/// Result of one executed job, by kind.
enum WorkOutput {
    Fact(Factorization),
    Ingested(MatrixHandle),
}

/// Rows appended per engine-lock acquisition during a chunked
/// ingestion: the lock is released between chunks so same-shard jobs
/// interleave with a long upload.
const INGEST_CHUNK_ROWS: usize = 4096;

/// What one scheduling round decided for a worker thread.
enum WorkerStep {
    Run(QueuedJob),
    Idle,
    Exit,
}

fn worker_loop(inner: Arc<ServiceInner>, shard_idx: usize) {
    let steal = inner.scheduler.steal;
    loop {
        // fast path: pop the best runnable job from our own queue
        let step = {
            let shard = &inner.shards[shard_idx];
            let mut q = shard.queue.lock().expect("service queue");
            if let Some(job) = ServiceInner::pop_best(&mut q.jobs) {
                WorkerStep::Run(job)
            } else if !q.open && q.jobs.is_empty() {
                WorkerStep::Exit
            } else {
                WorkerStep::Idle
            }
        };
        match step {
            WorkerStep::Run(job) => {
                inner.shards[shard_idx].space.notify_one();
                inner.execute_job(shard_idx, job);
                continue;
            }
            WorkerStep::Exit => return,
            WorkerStep::Idle => {}
        }
        // idle: with stealing on, raid the globally best victim queue
        // before going to sleep
        if steal {
            if let Some(job) = inner.steal_best(shard_idx) {
                inner.execute_job(shard_idx, job);
                continue;
            }
        }
        // nothing runnable anywhere we may touch: sleep until an
        // enqueue (or terminal transition) rings this shard's bell
        let shard = &inner.shards[shard_idx];
        let q = shard.queue.lock().expect("service queue");
        let runnable =
            q.jobs.iter().any(|job| !matches!(dep_state(&job.deps), DepState::Waiting));
        if runnable || (!q.open && q.jobs.is_empty()) {
            continue; // re-enter the fast path (or exit) with fresh state
        }
        if steal || !q.jobs.is_empty() {
            // with stealing, a victim-shard enqueue can race our failed
            // steal scan; with dependency-parked jobs, a dependency
            // cancelled through its own handle rings no bell. Both
            // cases poll with a timeout rather than sleeping forever.
            let _ = shard
                .ready
                .wait_timeout(q, Duration::from_millis(50))
                .expect("service queue");
        } else {
            let _ = shard.ready.wait(q).expect("service queue");
        }
    }
}

/// A concurrent factorization service over a pool of simulated cluster
/// shards. Build with
/// [`crate::session::SessionBuilder::build_service`]; see the
/// [module docs](self) for the architecture.
pub struct TsqrService {
    inner: Arc<ServiceInner>,
    workers: Vec<JoinHandle<()>>,
    backend_desc: &'static str,
    next_id: AtomicU64,
}

impl TsqrService {
    pub(crate) fn start(
        engines: Vec<Engine>,
        compute: SharedCompute,
        backend_desc: &'static str,
        opts: CoordOpts,
        cfg: ServiceConfig,
    ) -> TsqrService {
        assert!(!engines.is_empty(), "a service needs at least one engine shard");
        let nshards = engines.len();
        let fault_seed = engines[0].fault_seed();
        let shards: Vec<Shard> = engines
            .into_iter()
            .enumerate()
            .map(|(k, engine)| Shard {
                // single-shard services keep the historical un-prefixed
                // names (bit-for-bit the pre-shard service)
                ns: if nshards == 1 { String::new() } else { format!("shard-{k}/") },
                engine: Mutex::new(engine),
                queue: Mutex::new(QueueState { jobs: VecDeque::new(), open: true }),
                ready: Condvar::new(),
                space: Condvar::new(),
                load: AtomicUsize::new(0),
                steals: AtomicU64::new(0),
            })
            .collect();
        let inner = Arc::new(ServiceInner {
            shards,
            compute,
            opts,
            fault_seed,
            capacity: cfg.queue_capacity.max(1),
            placements: Mutex::new(HashMap::new()),
            ingests: Mutex::new(HashMap::new()),
            scheduler: cfg.scheduler,
            admission: Mutex::new(Admission::default()),
        });
        let workers = (0..nshards)
            .flat_map(|k| (0..cfg.workers).map(move |i| (k, i)))
            .map(|(k, i)| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("tsqr-worker-{k}-{i}"))
                    .spawn(move || worker_loop(inner, k))
                    .expect("spawn service worker")
            })
            .collect();
        TsqrService { inner, workers, backend_desc, next_id: AtomicU64::new(0) }
    }

    /// Short name of the resolved compute backend.
    pub fn backend_desc(&self) -> &'static str {
        self.backend_desc
    }

    /// Total background worker threads serving the queues
    /// ([`crate::session::SessionBuilder::service_workers`] per shard).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Engine shards in the pool.
    pub fn shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// Bounded per-shard queue capacity (submissions beyond it block).
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Host worker threads each job's map/reduce waves fan out on (the
    /// cluster's realized `ClusterConfig::host_threads`; every shard
    /// shares the configuration).
    pub fn host_threads(&self) -> usize {
        lock_engine(&self.inner.shards[0].engine).cluster.host_threads
    }

    /// Jobs currently queued across all shards (not yet picked up by a
    /// worker), parked admission holds included.
    pub fn pending(&self) -> usize {
        (0..self.inner.shards.len())
            .map(|k| self.inner.lock_queue(k).jobs.len())
            .sum::<usize>()
            + self.inner.admission.lock().expect("admission").held.len()
    }

    /// Cumulative elastic-scheduling counters: per-shard steal counts
    /// and per-label admission holds (sorted by label). All zeros /
    /// empty with the default [`SchedulerConfig`].
    pub fn sched_tally(&self) -> SchedTally {
        let per_shard_steals = self
            .inner
            .shards
            .iter()
            .map(|s| s.steals.load(Ordering::Relaxed))
            .collect();
        let adm = self.inner.admission.lock().expect("admission");
        let mut admission_held: Vec<(String, u64)> =
            adm.held_total.iter().map(|(l, n)| (l.clone(), *n)).collect();
        admission_held.sort();
        SchedTally { per_shard_steals, admission_held }
    }

    /// The scheduler policies this service was built with.
    pub fn scheduler(&self) -> SchedulerConfig {
        self.inner.scheduler
    }

    /// The shard the router assigned to `id` (`None` for unknown or
    /// already-evicted jobs). For completed jobs the same index is
    /// recorded durably in the result's
    /// [`crate::mapreduce::JobStats::shard`].
    pub fn shard_of(&self, id: JobId) -> Option<usize> {
        self.inner
            .placements
            .lock()
            .expect("placements")
            .get(&id.0)
            .copied()
            .filter(|&shard| shard != Self::PENDING_SHARD)
    }

    // ----------------------------------------------------- submission

    /// Placeholder shard recorded while a submission is between id
    /// reservation and enqueue (never a valid shard index;
    /// [`TsqrService::shard_of`] filters it out).
    const PENDING_SHARD: usize = usize::MAX;

    /// Reserve the next auto-assigned id. The reservation lives in the
    /// placements map, which makes the duplicate check in
    /// [`TsqrService::submit_with_id`] atomic with it: an explicit id
    /// raced against an auto allocation can never end up shared by two
    /// live jobs (same `job-<id>/` namespace, same fault stream).
    fn reserve_auto_id(&self) -> JobId {
        let mut placements = self.inner.placements.lock().expect("placements");
        loop {
            let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
            if let std::collections::hash_map::Entry::Vacant(slot) = placements.entry(id.0) {
                slot.insert(Self::PENDING_SHARD);
                return id;
            }
            // the counter ran into an explicit id still live: skip it
        }
    }

    /// Reserve a caller-chosen id, atomically rejecting one already in
    /// use by a live (unevicted) job.
    fn reserve_explicit_id(&self, id: JobId) -> Result<()> {
        let mut placements = self.inner.placements.lock().expect("placements");
        if placements.contains_key(&id.0) {
            bail!("job id {id} is already in use by a live (unevicted) job");
        }
        placements.insert(id.0, Self::PENDING_SHARD);
        drop(placements);
        // keep auto-assigned ids ahead of every explicit one
        self.next_id.fetch_max(id.0 + 1, Ordering::Relaxed);
        Ok(())
    }

    /// Release a reservation whose submission failed before enqueue.
    fn unreserve(&self, id: JobId) {
        self.inner.placements.lock().expect("placements").remove(&id.0);
    }

    #[allow(clippy::too_many_arguments)]
    fn enqueue(
        &self,
        shard_idx: usize,
        q: &mut QueueState,
        id: JobId,
        priority: Priority,
        label: Option<String>,
        work: JobWork,
        deps: Vec<(JobId, Arc<JobShared>)>,
        no_steal: bool,
        quota_counted: bool,
    ) -> JobHandle {
        let shared = Arc::new(JobShared { slot: Mutex::new(JobSlot::Queued), done: Condvar::new() });
        let handle = JobHandle { id, kind: work.kind(), label: label.clone(), shared: shared.clone() };
        q.jobs.push_back(QueuedJob {
            id,
            priority,
            work,
            deps,
            shared,
            label,
            no_steal,
            quota_counted,
            stolen: false,
        });
        let shard = &self.inner.shards[shard_idx];
        shard.load.fetch_add(1, Ordering::Relaxed);
        self.inner.placements.lock().expect("placements").insert(id.0, shard_idx);
        shard.ready.notify_one();
        if self.inner.scheduler.steal {
            // idle thieves on other shards may want this job
            self.inner.wake_all_shards();
        }
        handle
    }

    /// The dependency edge a job reading `file` must carry: the live
    /// asynchronous ingestion materializing that name, if one exists.
    /// Terminal registry entries retire here (lazy half of the
    /// retirement contract); a dependency that already *failed* or was
    /// cancelled rejects the submission up front with the root cause.
    fn ingest_dep(&self, file: &str) -> Result<Vec<(JobId, Arc<JobShared>)>> {
        let mut ingests = self.inner.ingests.lock().expect("ingests");
        let Some((dep_id, shared)) = ingests.get(file).map(|(id, s)| (*id, s.clone())) else {
            return Ok(Vec::new());
        };
        let state = {
            let slot = shared.slot.lock().expect("job slot");
            match &*slot {
                JobSlot::Queued | JobSlot::Running => None,
                JobSlot::Ingested { .. } | JobSlot::Done { .. } => Some(Ok(())),
                JobSlot::Failed { msg, .. } => Some(Err(anyhow!(
                    "input {file:?}: its ingestion (job {dep_id}) failed: {msg}"
                ))),
                JobSlot::Cancelled => Some(Err(anyhow!(
                    "input {file:?}: its ingestion (job {dep_id}) was cancelled"
                ))),
            }
        };
        match state {
            None => Ok(vec![(dep_id, shared)]),
            Some(done) => {
                if ingests.get(file).is_some_and(|(id, _)| *id == dep_id) {
                    ingests.remove(file);
                }
                done.map(|()| Vec::new())
            }
        }
    }

    /// Route an already-identified job: pick its shard and stage its
    /// input there.
    fn place(&self, id: JobId, req: &FactorizationRequest, input: &MatrixHandle) -> Result<usize> {
        let shard_idx = self.inner.route(id, req.options.placement, &input.file)?;
        self.inner.stage_input(shard_idx, &input.file);
        Ok(shard_idx)
    }

    /// Submit a job and return immediately with its [`JobHandle`]. At
    /// the routed shard's queue capacity this *blocks* until a worker
    /// (or drain) frees a slot — back-pressure, not unbounded
    /// buffering.
    pub fn submit(&self, input: &MatrixHandle, req: FactorizationRequest) -> Result<JobHandle> {
        let id = self.reserve_auto_id();
        self.submit_gated(id, input, req, true)
    }

    /// The one submission path behind [`TsqrService::submit`] /
    /// [`TsqrService::submit_with_id`] / [`TsqrService::try_submit`]:
    /// route + stage, collect dependency edges, pass the admission
    /// gate, then enqueue — blocking at capacity (`block`) or erroring
    /// there. Releases the id reservation (and any admission-quota
    /// unit taken) on every failure path.
    fn submit_gated(
        &self,
        id: JobId,
        input: &MatrixHandle,
        req: FactorizationRequest,
        block: bool,
    ) -> Result<JobHandle> {
        let placed = self
            .place(id, &req, input)
            .and_then(|shard_idx| Ok((shard_idx, self.ingest_dep(&input.file)?)));
        let (shard_idx, deps) = match placed {
            Ok(placed) => placed,
            Err(err) => {
                self.unreserve(id);
                return Err(err);
            }
        };
        let (priority, label) = (req.options.priority, req.options.label.clone());
        let no_steal = req.options.no_steal;
        // admission gate: a labeled, non-exempt job over its label's
        // in-flight quota parks here instead of entering a shard queue
        // (the handle comes back immediately; the job stays `Queued`
        // and cancellable, and enters its routed queue in sched_key
        // order as the label's jobs retire)
        let mut quota_counted = false;
        if let (Some(quota), Some(lbl)) = (self.inner.scheduler.quota_per_label, label.clone()) {
            if !req.options.quota_exempt {
                let mut adm = self.inner.admission.lock().expect("admission");
                if adm.inflight.get(&lbl).copied().unwrap_or(0) >= quota {
                    let shared = Arc::new(JobShared {
                        slot: Mutex::new(JobSlot::Queued),
                        done: Condvar::new(),
                    });
                    let handle = JobHandle {
                        id,
                        kind: JobKind::Factorize,
                        label: label.clone(),
                        shared: shared.clone(),
                    };
                    let work = JobWork::Factorize { input: input.clone(), req };
                    adm.held.push((
                        shard_idx,
                        QueuedJob {
                            id,
                            priority,
                            work,
                            deps,
                            shared,
                            label,
                            no_steal,
                            quota_counted: false,
                            stolen: false,
                        },
                    ));
                    *adm.held_total.entry(lbl).or_insert(0) += 1;
                    self.inner.placements.lock().expect("placements").insert(id.0, shard_idx);
                    return Ok(handle);
                }
                *adm.inflight.entry(lbl).or_insert(0) += 1;
                quota_counted = true;
            }
        }
        let shard = &self.inner.shards[shard_idx];
        let mut q = self.inner.lock_queue(shard_idx);
        if block {
            while q.open && q.jobs.len() >= self.inner.capacity {
                q = shard.space.wait(q).expect("service queue");
            }
        }
        if !q.open {
            drop(q);
            self.unreserve(id);
            if quota_counted {
                self.inner.release_quota(label.as_deref().unwrap_or_default());
            }
            bail!("job service is shut down");
        }
        if q.jobs.len() >= self.inner.capacity {
            // only reachable in the non-blocking flavor
            drop(q);
            self.unreserve(id);
            if quota_counted {
                self.inner.release_quota(label.as_deref().unwrap_or_default());
            }
            bail!(
                "shard {shard_idx} job queue at capacity ({} queued) — wait for a worker or use submit()",
                self.inner.capacity
            );
        }
        let work = JobWork::Factorize { input: input.clone(), req };
        Ok(self.enqueue(shard_idx, &mut q, id, priority, label, work, deps, no_steal, quota_counted))
    }

    /// [`TsqrService::submit`] under a *caller-assigned* job id (it
    /// must not collide with a live job's). A job's DFS namespace and
    /// fault-RNG stream derive from its id alone, so a caller that
    /// controls ids controls determinism across services — this is how
    /// the cross-process [`crate::client::TsqrClient`] keeps worker
    /// processes bit-identical to an in-process pool. Auto-assigned
    /// ids ([`TsqrService::submit`]) always continue past the largest
    /// explicit one.
    pub fn submit_with_id(
        &self,
        id: JobId,
        input: &MatrixHandle,
        req: FactorizationRequest,
    ) -> Result<JobHandle> {
        self.reserve_explicit_id(id)?;
        self.submit_gated(id, input, req, true)
    }

    /// Non-blocking [`TsqrService::submit`]: errors instead of waiting
    /// when the routed shard's queue is at capacity.
    pub fn try_submit(&self, input: &MatrixHandle, req: FactorizationRequest) -> Result<JobHandle> {
        let id = self.reserve_auto_id();
        self.submit_gated(id, input, req, false)
    }

    // ---------------------------------------------------- manual drain

    /// Pop and run the globally next queued job (highest priority,
    /// lowest job id within a priority, across every shard) on the
    /// *calling* thread; `None` when nothing is queued. Jobs cancelled
    /// while queued are discarded, not counted. With
    /// `service_workers(0)` this is the deterministic serial engine the
    /// determinism tests baseline against.
    pub fn drain_one(&self) -> Option<JobId> {
        loop {
            // scan every shard queue for the *runnable* job sched_key
            // orders first; remember one still-pending dependency so a
            // fully-parked queue can block on it instead of returning
            // None while work remains
            let mut best: Option<(usize, (std::cmp::Reverse<Priority>, JobId))> = None;
            let mut saw_waiting = false;
            let mut waiting_dep: Option<Arc<JobShared>> = None;
            for k in 0..self.inner.shards.len() {
                let q = self.inner.lock_queue(k);
                for job in &q.jobs {
                    if matches!(dep_state(&job.deps), DepState::Waiting) {
                        saw_waiting = true;
                        if waiting_dep.is_none() {
                            waiting_dep = job
                                .deps
                                .iter()
                                .find(|(_, shared)| {
                                    matches!(
                                        *shared.slot.lock().expect("job slot"),
                                        JobSlot::Queued | JobSlot::Running
                                    )
                                })
                                .map(|(_, shared)| shared.clone());
                        }
                    }
                }
                // same scan the worker pop and steal victim selection
                // use — one comparator, three consumers
                if let Some((_pos, key)) = ServiceInner::best_pos(&q.jobs, |job| {
                    !matches!(dep_state(&job.deps), DepState::Waiting)
                }) {
                    let better = match best {
                        None => true,
                        Some((_, best_key)) => key < best_key,
                    };
                    if better {
                        best = Some((k, key));
                    }
                }
            }
            let Some((shard_idx, (_, id))) = best else {
                if !saw_waiting {
                    return None;
                }
                match waiting_dep {
                    // the dependency went terminal between the two
                    // checks — rescan, its dependent is runnable now
                    None => continue,
                    Some(shared) => {
                        // every queued job is parked behind a running
                        // dependency (executing on a background worker
                        // or another drainer): wait for it, then rescan
                        let mut slot = shared.slot.lock().expect("job slot");
                        while matches!(*slot, JobSlot::Queued | JobSlot::Running) {
                            slot = shared.done.wait(slot).expect("job slot");
                        }
                        continue;
                    }
                }
            };
            // re-lock and pop that specific job; a background worker
            // may have taken it meanwhile — rescan if so
            let job = {
                let mut q = self.inner.lock_queue(shard_idx);
                match q.jobs.iter().position(|j| j.id == id) {
                    Some(pos) => q.jobs.remove(pos),
                    None => continue,
                }
            };
            let Some(job) = job else { continue };
            self.inner.shards[shard_idx].space.notify_one();
            if self.inner.execute_job(shard_idx, job) {
                return Some(id);
            }
        }
    }

    /// Run queued jobs on the calling thread until every shard's queue
    /// is empty; returns how many executed.
    pub fn drain_now(&self) -> usize {
        let mut ran = 0;
        while self.drain_one().is_some() {
            ran += 1;
        }
        ran
    }

    // ------------------------------------------------------- ingestion

    /// Ingest an in-memory matrix into the pool (pinned to shard 0, the
    /// home shard; jobs routed elsewhere receive an O(1) copy).
    pub fn ingest_matrix(&self, name: &str, a: &Matrix) -> Result<MatrixHandle> {
        self.ingest_matrix_placed(name, a, Placement::Auto)
    }

    /// [`TsqrService::ingest_matrix`] with an explicit home-shard
    /// [`Placement`]: `Pinned(k)` lands the rows directly on shard `k`,
    /// so a job pinned there reads them with no cross-shard staging
    /// copy at submission. `Auto` keeps the historical home, shard 0.
    pub fn ingest_matrix_placed(
        &self,
        name: &str,
        a: &Matrix,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        self.ingest_with_placed(name, a.cols, placement, |w| w.push_chunk(a))
    }

    /// Ingest a seeded gaussian matrix (same records as
    /// [`crate::session::TsqrSession::ingest_gaussian`]).
    pub fn ingest_gaussian(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
    ) -> Result<MatrixHandle> {
        self.ingest_gaussian_placed(name, rows, cols, seed, Placement::Auto)
    }

    /// [`TsqrService::ingest_gaussian`] with an explicit home-shard
    /// [`Placement`] (see [`TsqrService::ingest_matrix_placed`]).
    pub fn ingest_gaussian_placed(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        let mut rng = Rng::new(seed);
        let mut row = vec![0.0f64; cols];
        self.ingest_with_placed(name, cols, placement, |w| {
            for _ in 0..rows {
                for v in row.iter_mut() {
                    *v = rng.gaussian();
                }
                w.push_row(&row)?;
            }
            Ok(())
        })
    }

    /// Stream rows into the pool through a [`MatrixWriter`]. The
    /// matrix lands on shard 0 — its *home* shard. Rows are generated
    /// into a detached scratch store and published with one short O(1)
    /// import, so the home shard's engine lock is **not** held while
    /// the closure runs: jobs already executing there keep making
    /// progress during a long upload. Other shards receive the file by
    /// O(1) reference-counted copy when the router places a reader
    /// there. For fully queued, overlap-friendly ingestion see
    /// [`TsqrService::ingest_gaussian_async`] /
    /// [`TsqrService::ingest_matrix_async`].
    pub fn ingest_with(
        &self,
        name: &str,
        cols: usize,
        f: impl FnOnce(&mut MatrixWriter) -> Result<()>,
    ) -> Result<MatrixHandle> {
        self.ingest_with_placed(name, cols, Placement::Auto, f)
    }

    /// [`TsqrService::ingest_with`] with an explicit home-shard
    /// [`Placement`]. With `Pinned(k)` the rows land on shard `k` up
    /// front — closing the gap where every large input first staged on
    /// shard 0 and was then copied to its real destination. A scale
    /// registered before ingestion ([`TsqrService::set_scale`] keeps
    /// its scale-before-ingest contract via shard 0) is carried onto
    /// the pinned home shard.
    pub fn ingest_with_placed(
        &self,
        name: &str,
        cols: usize,
        placement: Placement,
        f: impl FnOnce(&mut MatrixWriter) -> Result<()>,
    ) -> Result<MatrixHandle> {
        let home = match placement {
            Placement::Auto => 0,
            Placement::Pinned(k) => {
                if k >= self.inner.shards.len() {
                    bail!(
                        "ingest pinned to shard {k}, but the service has {} shard(s)",
                        self.inner.shards.len()
                    );
                }
                k
            }
        };
        // scales registered ahead of ingestion live on shard 0 (and,
        // for an unpinned ingest, that IS the home); a pinned ingest
        // must honor shard 0's registration on its actual home shard,
        // falling back to whatever the home itself has registered
        let final_scale = {
            let home_scale = lock_engine(&self.inner.shards[home].engine).dfs.scale(name);
            let zero_scale = if home != 0 {
                lock_engine(&self.inner.shards[0].engine).dfs.scale(name)
            } else {
                1.0
            };
            if zero_scale != 1.0 { zero_scale } else { home_scale }
        };
        // generate the rows into a detached scratch DFS — the home
        // shard's engine lock is NOT held while the closure runs, so
        // concurrent jobs on that shard keep making progress during a
        // long upload; publication is one short O(1) import at the end
        // (and a failing closure publishes nothing at all)
        let mut scratch = Dfs::new();
        let handle = {
            let mut w = MatrixWriter::new(&mut scratch, name, cols);
            f(&mut w)?;
            w.finish()
        };
        let (records, _) = scratch.export_file(name).expect("scratch ingest file");
        lock_engine(&self.inner.shards[home].engine).dfs.import_file(name, records, final_scale);
        // re-ingesting a name overwrites the home copy, so any copy an
        // earlier ingest or job staged onto another shard is now stale
        // — drop them all; the next job routed there re-stages fresh
        for (k, shard) in self.inner.shards.iter().enumerate() {
            if k != home {
                lock_engine(&shard.engine).dfs.delete(name);
            }
        }
        Ok(handle)
    }

    // ------------------------------------------ asynchronous ingestion

    /// Queue a seeded gaussian ingestion as a first-class job and
    /// return immediately (see [`TsqrService::ingest_async`]). Writes
    /// the same records, bit for bit, as
    /// [`TsqrService::ingest_gaussian`].
    pub fn ingest_gaussian_async(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
    ) -> Result<IngestHandle> {
        self.ingest_async(name, cols, IngestRecipe::Gaussian { rows, seed }, Placement::Auto)
    }

    /// Queue an in-memory matrix upload as a first-class job and
    /// return immediately (see [`TsqrService::ingest_async`]).
    pub fn ingest_matrix_async(&self, name: &str, a: &Matrix) -> Result<IngestHandle> {
        let cols = a.cols;
        self.ingest_async(name, cols, IngestRecipe::Rows(Arc::new(a.clone())), Placement::Auto)
    }

    /// Queue an ingestion as a first-class job and return an
    /// [`IngestHandle`] immediately. Unlike the synchronous ingest
    /// family, the upload runs on the shard's worker queue, appending
    /// in short chunked engine-lock acquisitions — it never holds an
    /// engine lock for its duration, so running factorizations on the
    /// same shard interleave with it. A [`TsqrService::submit`] naming
    /// the still-ingesting matrix queues *behind* it via a
    /// job-dependency edge and runs bit-identically to
    /// ingest-then-submit. `Placement::Auto` homes the matrix on shard
    /// 0, like the synchronous path.
    pub fn ingest_async(
        &self,
        name: &str,
        cols: usize,
        recipe: IngestRecipe,
        placement: Placement,
    ) -> Result<IngestHandle> {
        let id = self.reserve_auto_id();
        self.ingest_async_reserved(id, name, cols, recipe, placement)
    }

    /// [`TsqrService::ingest_async`] under a *caller-assigned* job id
    /// (same contract as [`TsqrService::submit_with_id`]: controlling
    /// ids controls determinism across services).
    pub fn ingest_async_with_id(
        &self,
        id: JobId,
        name: &str,
        cols: usize,
        recipe: IngestRecipe,
        placement: Placement,
    ) -> Result<IngestHandle> {
        self.reserve_explicit_id(id)?;
        self.ingest_async_reserved(id, name, cols, recipe, placement)
    }

    fn ingest_async_reserved(
        &self,
        id: JobId,
        name: &str,
        cols: usize,
        recipe: IngestRecipe,
        placement: Placement,
    ) -> Result<IngestHandle> {
        let rows = recipe.rows();
        let home = match placement {
            Placement::Auto => 0,
            Placement::Pinned(k) if k < self.inner.shards.len() => k,
            Placement::Pinned(k) => {
                self.unreserve(id);
                bail!(
                    "ingest pinned to shard {k}, but the service has {} shard(s)",
                    self.inner.shards.len()
                );
            }
        };
        // a still-live writer of the same name must finish before this
        // one starts (two interleaved writers would corrupt the file);
        // a previous ingestion that failed or was cancelled is simply
        // superseded, not an error for the re-ingest
        let deps = self.ingest_dep(name).unwrap_or_default();
        let shard = &self.inner.shards[home];
        let mut q = self.inner.lock_queue(home);
        while q.open && q.jobs.len() >= self.inner.capacity {
            q = shard.space.wait(q).expect("service queue");
        }
        if !q.open {
            drop(q);
            self.unreserve(id);
            bail!("job service is shut down");
        }
        let work = JobWork::Ingest { name: name.to_string(), cols, recipe };
        let label = Some(format!("ingest:{name}"));
        // ingestions write their home shard: never stolen (enforced by
        // kind in `stealable` too) and never quota-gated
        let job = self.enqueue(home, &mut q, id, Priority::Normal, label, work, deps, true, false);
        // register while still holding the queue lock: popping the job
        // needs this lock, so no submit() can observe the queued
        // ingestion without also seeing its registry entry
        self.inner
            .ingests
            .lock()
            .expect("ingests")
            .insert(name.to_string(), (id, job.shared.clone()));
        drop(q);
        Ok(IngestHandle { job, handle: MatrixHandle::new(name, rows, cols) })
    }

    /// Read a handle's rows back from the pool: shards are scanned in
    /// index order and the first copy wins (every copy of a file is
    /// byte-identical — files are immutable once ingested or written by
    /// their job).
    pub fn get_matrix(&self, handle: &MatrixHandle) -> Result<Matrix> {
        for shard in &self.inner.shards {
            let engine = lock_engine(&shard.engine);
            if engine.dfs.exists(&handle.file) {
                return workload::get_matrix(&engine.dfs, &handle.file, handle.cols);
            }
        }
        bail!("dfs: no such file {:?} on any shard", handle.file)
    }

    /// Run a closure against shard 0's DFS (byte totals, listings) —
    /// the home shard every ingestion lands on. Use
    /// [`TsqrService::with_dfs_on`] to inspect another shard.
    pub fn with_dfs<T>(&self, f: impl FnOnce(&Dfs) -> T) -> T {
        f(&lock_engine(&self.inner.shards[0].engine).dfs)
    }

    /// Run a closure against one shard's DFS; errors on an
    /// out-of-range shard index.
    pub fn with_dfs_on<T>(&self, shard: usize, f: impl FnOnce(&Dfs) -> T) -> Result<T> {
        match self.inner.shards.get(shard) {
            Some(s) => Ok(f(&lock_engine(&s.engine).dfs)),
            None => bail!("no such shard {shard} (service has {})", self.inner.shards.len()),
        }
    }

    /// Mark a DFS file's virtual byte scale (see
    /// [`crate::session::TsqrSession::set_scale`]). Registered
    /// unconditionally on the home shard — like a session, the scale
    /// may be set before the file is ingested — and on every other
    /// shard already holding a staged copy; copies staged later carry
    /// the home scale along ([`crate::dfs::Dfs::export_file`]).
    pub fn set_scale(&self, name: &str, scale: f64) {
        lock_engine(&self.inner.shards[0].engine).dfs.set_scale(name, scale);
        for shard in &self.inner.shards[1..] {
            let mut engine = lock_engine(&shard.engine);
            if engine.dfs.exists(name) {
                engine.dfs.set_scale(name, scale);
            }
        }
    }

    // ------------------------------------------------------- lifecycle

    /// Delete one finished job's DFS namespace
    /// (`<shard-ns>job-<id>/…` — its Q factor and intermediates):
    /// swept on the shard that ran the job *and*, should a chained job
    /// have staged one of its files elsewhere, on every shard holding
    /// such a copy. No other job's namespace and no ingested matrix is
    /// touched. Returns how many files were swept (copies included).
    /// Handles into that namespace become dangling, which is the
    /// caller's contract to uphold. Eviction also frees the job's
    /// placement record — it is the retirement step of the job
    /// lifecycle, and a service churning through very many jobs should
    /// evict them as it retires them.
    pub fn evict_job(&self, id: JobId) -> usize {
        self.inner.placements.lock().expect("placements").remove(&id.0);
        let job_ns = id.namespace();
        let mut swept = 0;
        for shard in &self.inner.shards {
            let mut engine = lock_engine(&shard.engine);
            // a staged copy keeps its original (owner-prefixed) name,
            // so sweep every possible owner prefix on every shard
            for owner in &self.inner.shards {
                swept += engine.dfs.delete_prefix(&format!("{}{}", owner.ns, job_ns));
            }
        }
        swept
    }

    /// Graceful shutdown: reject new submissions, let the workers
    /// drain everything already queued, join them, and cancel whatever
    /// remains (only possible in manual-drain mode). Called on drop.
    pub fn shutdown(&mut self) {
        let mut was_open = false;
        for k in 0..self.inner.shards.len() {
            let mut q = self.inner.lock_queue(k);
            was_open |= q.open;
            q.open = false;
        }
        if !was_open {
            return;
        }
        for shard in &self.inner.shards {
            shard.ready.notify_all();
            shard.space.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
        // manual-drain mode can leave queued jobs behind: resolve their
        // handles so no waiter hangs forever
        for (k, shard) in self.inner.shards.iter().enumerate() {
            let mut q = self.inner.lock_queue(k);
            while let Some(job) = q.jobs.pop_front() {
                shard.load.fetch_sub(1, Ordering::Relaxed);
                let mut slot = job.shared.slot.lock().expect("job slot");
                if matches!(*slot, JobSlot::Queued) {
                    *slot = JobSlot::Cancelled;
                }
                job.shared.done.notify_all();
            }
        }
        // submissions parked at the admission gate never reached a
        // shard queue — resolve them the same way
        let held: Vec<(usize, QueuedJob)> = {
            let mut adm = self.inner.admission.lock().expect("admission");
            adm.held.drain(..).collect()
        };
        for (_, job) in held {
            let mut slot = job.shared.slot.lock().expect("job slot");
            if matches!(*slot, JobSlot::Queued) {
                *slot = JobSlot::Cancelled;
            }
            drop(slot);
            job.shared.done.notify_all();
        }
    }
}

impl Drop for TsqrService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Backend, TsqrSession};

    fn manual_service() -> TsqrService {
        TsqrSession::builder()
            .backend(Backend::Native)
            .rows_per_task(50)
            .service_workers(0)
            .queue_capacity(8)
            .build_service()
            .unwrap()
    }

    fn manual_sharded(shards: usize) -> TsqrService {
        TsqrSession::builder()
            .backend(Backend::Native)
            .rows_per_task(50)
            .engine_shards(shards)
            .service_workers(0)
            .queue_capacity(8)
            .build_service()
            .unwrap()
    }

    #[test]
    fn submit_drain_wait_round_trip() {
        let svc = manual_service();
        let h = svc.ingest_gaussian("A", 300, 5, 1).unwrap();
        let job = svc
            .submit(&h, FactorizationRequest::qr().options(SubmitOptions::new().label("smoke")))
            .unwrap();
        assert_eq!(job.status(), JobStatus::Queued);
        assert_eq!(job.label(), Some("smoke"));
        assert!(job.try_result().is_none());
        assert_eq!(svc.pending(), 1);
        assert_eq!(svc.drain_now(), 1);
        let fact = job.wait().unwrap();
        assert_eq!(job.status(), JobStatus::Done);
        assert!(job.wall_secs().unwrap() >= 0.0);
        assert_eq!(fact.r.rows, 5);
        assert_eq!(fact.stats.shard, 0, "single-shard service runs everything on shard 0");
        // the Q handle lives in the job's namespace — un-prefixed on a
        // single-shard service, exactly the historical names
        let qf = &fact.q.as_ref().unwrap().file;
        assert!(qf.starts_with(&job.id().namespace()), "{qf}");
        let q = svc.get_matrix(fact.q.as_ref().unwrap()).unwrap();
        assert!(q.orthogonality_error() < 1e-10);
    }

    #[test]
    fn priorities_jump_the_fifo_queue() {
        let svc = manual_service();
        let h = svc.ingest_gaussian("A", 60, 3, 2).unwrap();
        let lo = svc
            .submit(
                &h,
                FactorizationRequest::r_only().options(SubmitOptions::new().priority(Priority::Low)),
            )
            .unwrap();
        let n1 = svc.submit(&h, FactorizationRequest::r_only()).unwrap();
        let n2 = svc.submit(&h, FactorizationRequest::r_only()).unwrap();
        let hi = svc
            .submit(
                &h,
                FactorizationRequest::r_only()
                    .options(SubmitOptions::new().priority(Priority::High)),
            )
            .unwrap();
        let order: Vec<JobId> = std::iter::from_fn(|| svc.drain_one()).collect();
        assert_eq!(order, vec![hi.id(), n1.id(), n2.id(), lo.id()]);
    }

    #[test]
    fn priorities_order_across_shards_in_manual_drain() {
        // drain_one's (priority, job-id) order spans the whole pool:
        // pin jobs to different shards and the High one still runs
        // first wherever it sits
        let svc = manual_sharded(2);
        let h = svc.ingest_gaussian("A", 60, 3, 2).unwrap();
        let lo = svc
            .submit(
                &h,
                FactorizationRequest::r_only()
                    .options(SubmitOptions::new().pinned(0).priority(Priority::Low)),
            )
            .unwrap();
        let n = svc
            .submit(&h, FactorizationRequest::r_only().options(SubmitOptions::new().pinned(0)))
            .unwrap();
        let hi = svc
            .submit(
                &h,
                FactorizationRequest::r_only()
                    .options(SubmitOptions::new().pinned(1).priority(Priority::High)),
            )
            .unwrap();
        let order: Vec<JobId> = std::iter::from_fn(|| svc.drain_one()).collect();
        assert_eq!(order, vec![hi.id(), n.id(), lo.id()]);
    }

    #[test]
    fn evict_job_sweeps_only_that_namespace() {
        let svc = manual_service();
        let h = svc.ingest_gaussian("A", 200, 4, 3).unwrap();
        let j0 = svc.submit(&h, FactorizationRequest::qr()).unwrap();
        let j1 = svc.submit(&h, FactorizationRequest::qr()).unwrap();
        svc.drain_now();
        let f0 = j0.wait().unwrap();
        let f1 = j1.wait().unwrap();
        assert!(svc.evict_job(j0.id()) > 0);
        assert!(svc.get_matrix(f0.q.as_ref().unwrap()).is_err(), "evicted Q gone");
        let q1 = svc.get_matrix(f1.q.as_ref().unwrap()).unwrap();
        assert_eq!(q1.rows, 200, "other job's namespace untouched");
        // input matrix is outside every job namespace
        assert!(svc.get_matrix(&h).is_ok());
        // unknown / already-evicted ids sweep nothing
        assert_eq!(svc.evict_job(j0.id()), 0);
        assert_eq!(svc.evict_job(JobId(999)), 0);
    }

    #[test]
    fn shutdown_rejects_new_submissions_and_resolves_queued_handles() {
        let mut svc = manual_service();
        let h = svc.ingest_gaussian("A", 60, 3, 4).unwrap();
        let stranded = svc.submit(&h, FactorizationRequest::r_only()).unwrap();
        svc.shutdown();
        assert_eq!(stranded.status(), JobStatus::Cancelled);
        assert!(stranded.wait().is_err());
        assert!(svc.submit(&h, FactorizationRequest::r_only()).is_err());
        assert!(svc.try_submit(&h, FactorizationRequest::r_only()).is_err());
    }

    #[test]
    fn pinned_placement_is_validated_at_submission() {
        let svc = manual_service();
        let h = svc.ingest_gaussian("A", 60, 3, 5).unwrap();
        let err = svc
            .submit(&h, FactorizationRequest::r_only().options(SubmitOptions::new().pinned(1)))
            .unwrap_err();
        assert!(err.to_string().contains("shard"), "{err}");
        // in-range pin on the only shard is fine
        let job = svc
            .submit(&h, FactorizationRequest::r_only().options(SubmitOptions::new().pinned(0)))
            .unwrap();
        svc.drain_now();
        assert!(job.wait().is_ok());
    }

    #[test]
    fn router_balances_and_records_placements() {
        let svc = manual_sharded(3);
        let h = svc.ingest_gaussian("A", 120, 4, 6).unwrap();
        let jobs: Vec<_> = (0..6)
            .map(|_| svc.submit(&h, FactorizationRequest::r_only()).unwrap())
            .collect();
        // 6 auto-routed jobs over 3 idle shards: least-loaded routing
        // must spread them 2/2/2
        let mut per_shard = [0usize; 3];
        for j in &jobs {
            per_shard[svc.shard_of(j.id()).unwrap()] += 1;
        }
        assert_eq!(per_shard, [2, 2, 2], "least-loaded router must balance");
        svc.drain_now();
        for j in &jobs {
            let fact = j.wait().unwrap();
            assert_eq!(fact.stats.shard, svc.shard_of(j.id()).unwrap());
        }
    }

    #[test]
    fn sharded_namespaces_nest_under_the_shard_prefix() {
        let svc = manual_sharded(2);
        let h = svc.ingest_gaussian("A", 200, 4, 7).unwrap();
        let job = svc
            .submit(&h, FactorizationRequest::qr().options(SubmitOptions::new().pinned(1)))
            .unwrap();
        svc.drain_now();
        let fact = job.wait().unwrap();
        let qf = &fact.q.as_ref().unwrap().file;
        assert!(
            qf.starts_with(&format!("shard-1/{}", job.id().namespace())),
            "Q must live under the shard's namespace: {qf}"
        );
        // the input was staged onto shard 1 by reference, not copied
        let on_home = svc.with_dfs(|d| d.exists("A"));
        let on_one = svc.with_dfs_on(1, |d| d.exists("A")).unwrap();
        assert!(on_home && on_one, "input present on both home and target shard");
        assert!(svc.with_dfs_on(2, |_| ()).is_err(), "out-of-range shard errors");
    }

    #[test]
    fn set_scale_before_ingest_still_registers() {
        // scales live independently of file contents, so (as with a
        // session) a scale set before the matrix arrives must stick
        let svc = manual_service();
        svc.set_scale("A", 1e6);
        svc.ingest_gaussian("A", 60, 3, 9).unwrap();
        assert_eq!(svc.with_dfs(|d| d.scale("A")), 1e6);
        // and a staged copy carries the scale to the other shard
        let sharded = manual_sharded(2);
        let h = sharded.ingest_gaussian("B", 60, 3, 9).unwrap();
        sharded.set_scale("B", 250.0);
        let job = sharded
            .submit(&h, FactorizationRequest::r_only().options(SubmitOptions::new().pinned(1)))
            .unwrap();
        sharded.drain_now();
        job.wait().unwrap();
        assert_eq!(sharded.with_dfs_on(1, |d| d.scale("B")).unwrap(), 250.0);
    }

    #[test]
    fn pinned_ingest_plus_pinned_job_never_copies_across_shards() {
        // the ingestion shard-pinning satellite: a large input pinned
        // to its consumer's shard must land there up front — no copy on
        // shard 0, and no staging copy at submission
        let svc = manual_sharded(3);
        let h = svc
            .ingest_gaussian_placed("A", 300, 4, 11, Placement::Pinned(1))
            .unwrap();
        assert!(!svc.with_dfs(|d| d.exists("A")), "pinned ingest must skip shard 0");
        assert!(svc.with_dfs_on(1, |d| d.exists("A")).unwrap());
        let job = svc
            .submit(&h, FactorizationRequest::qr().options(SubmitOptions::new().pinned(1)))
            .unwrap();
        svc.drain_now();
        let fact = job.wait().unwrap();
        assert_eq!(fact.stats.shard, 1);
        // after the whole lifecycle, "A" still lives on exactly one shard
        for k in [0usize, 2] {
            assert!(
                !svc.with_dfs_on(k, |d| d.exists("A")).unwrap(),
                "shard {k} must never receive a copy of the pinned input"
            );
        }
        // the result is readable (get_matrix scans all shards)
        assert!(svc.get_matrix(fact.q.as_ref().unwrap()).is_ok());
        // a job routed *elsewhere* still works — staged from shard 1
        let j2 = svc
            .submit(&h, FactorizationRequest::r_only().options(SubmitOptions::new().pinned(2)))
            .unwrap();
        svc.drain_now();
        j2.wait().unwrap();
        assert!(svc.with_dfs_on(2, |d| d.exists("A")).unwrap(), "cross-shard staging still works");
        // out-of-range pins error
        assert!(svc
            .ingest_gaussian_placed("B", 10, 2, 1, Placement::Pinned(9))
            .is_err());
    }

    #[test]
    fn pinned_ingest_honors_scale_set_before_ingestion() {
        let svc = manual_sharded(2);
        svc.set_scale("A", 2000.0);
        svc.ingest_gaussian_placed("A", 60, 3, 4, Placement::Pinned(1)).unwrap();
        assert_eq!(svc.with_dfs_on(1, |d| d.scale("A")).unwrap(), 2000.0);
    }

    #[test]
    fn submit_with_id_controls_namespace_and_rejects_live_duplicates() {
        let svc = manual_service();
        let h = svc.ingest_gaussian("A", 200, 4, 6).unwrap();
        let job = svc.submit_with_id(JobId(7), &h, FactorizationRequest::qr()).unwrap();
        assert_eq!(job.id(), JobId(7));
        // a live id cannot be reused…
        let err = svc.submit_with_id(JobId(7), &h, FactorizationRequest::qr()).unwrap_err();
        assert!(err.to_string().contains("already in use"), "{err}");
        // …and auto ids continue past the explicit one
        let auto = svc.submit(&h, FactorizationRequest::r_only()).unwrap();
        assert_eq!(auto.id(), JobId(8));
        svc.drain_now();
        let fact = job.wait().unwrap();
        assert!(
            fact.q.as_ref().unwrap().file.starts_with("job-7/"),
            "the namespace must follow the explicit id: {}",
            fact.q.as_ref().unwrap().file
        );
        auto.wait().unwrap();
        // eviction retires the id; reuse becomes legal again
        svc.evict_job(JobId(7));
        let again = svc.submit_with_id(JobId(7), &h, FactorizationRequest::r_only()).unwrap();
        svc.drain_now();
        again.wait().unwrap();
    }

    #[test]
    fn async_ingest_then_dependent_submit_drains_in_order() {
        let svc = manual_service();
        let ing = svc.ingest_gaussian_async("A", 300, 5, 1).unwrap();
        assert_eq!(ing.status(), JobStatus::Queued);
        assert_eq!(ing.job().kind(), JobKind::Ingest);
        // the handle is usable for a dependent submission immediately
        let job = svc.submit(ing.handle(), FactorizationRequest::qr()).unwrap();
        assert_eq!(job.kind(), JobKind::Factorize);
        // manual drain runs the ingestion first (the dependent job is
        // parked on its edge), then the factorization
        assert_eq!(svc.drain_now(), 2);
        let m = ing.wait().unwrap();
        assert_eq!((m.rows, m.cols), (300, 5));
        let fact = job.wait().unwrap();
        assert_eq!(fact.r.rows, 5);
    }

    #[test]
    fn async_ingest_matches_synchronous_ingest_bits() {
        let sync_svc = manual_service();
        let async_svc = manual_service();
        let hs = sync_svc.ingest_gaussian("A", 500, 6, 42).unwrap();
        let ing = async_svc.ingest_gaussian_async("A", 500, 6, 42).unwrap();
        async_svc.drain_now();
        let ha = ing.wait().unwrap();
        let (ms, ma) =
            (sync_svc.get_matrix(&hs).unwrap(), async_svc.get_matrix(&ha).unwrap());
        assert_eq!(ms.data, ma.data, "queued ingestion must write the same bits");
    }

    #[test]
    fn cancelled_ingest_fails_dependents_with_the_root_cause() {
        let svc = manual_service();
        let ing = svc.ingest_gaussian_async("A", 100, 4, 2).unwrap();
        let job = svc.submit(ing.handle(), FactorizationRequest::qr()).unwrap();
        assert!(ing.cancel());
        // a submission against the dead (not yet drained) name reports
        // the cause up front — the lazy half of registry retirement …
        let err = svc.submit(ing.handle(), FactorizationRequest::qr()).unwrap_err();
        assert!(err.to_string().contains("cancelled"), "{err}");
        assert_eq!(svc.drain_now(), 0, "both queued jobs resolve without executing");
        let err = job.wait().unwrap_err();
        assert!(err.to_string().contains("cancelled"), "{err}");
        // … and a re-ingest of the name supersedes the dead writer
        let again = svc.ingest_gaussian_async("A", 100, 4, 2).unwrap();
        svc.drain_now();
        assert_eq!(again.wait().unwrap().rows, 100);
    }

    #[test]
    fn poisoned_shard_engine_does_not_stop_the_pool() {
        // extends PR 3's lock_engine poison-recovery test to the pool:
        // poison shard 1's engine mutex the way a panicking job would
        // (panic while holding the lock), then both shards must still
        // serve — lock_engine strips the poison
        let svc = manual_sharded(2);
        let h = svc.ingest_gaussian("A", 200, 4, 8).unwrap();
        let poisoned = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _guard = svc.inner.shards[1].engine.lock().unwrap();
            panic!("job dies while holding shard 1's engine");
        }));
        assert!(poisoned.is_err());
        assert!(svc.inner.shards[1].engine.lock().is_err(), "shard 1 should be poisoned");
        for k in 0..2 {
            let job = svc
                .submit(&h, FactorizationRequest::qr().options(SubmitOptions::new().pinned(k)))
                .unwrap();
            svc.drain_now();
            let fact = job.wait().unwrap_or_else(|e| panic!("shard {k} wedged: {e:#}"));
            assert_eq!(fact.stats.shard, k);
        }
    }

    /// A synthetic queued factorization for order-property tests (the
    /// work is never executed).
    fn synthetic_job(id: u64, priority: Priority) -> QueuedJob {
        QueuedJob {
            id: JobId(id),
            priority,
            work: JobWork::Factorize {
                input: MatrixHandle::new("A", 10, 2),
                req: FactorizationRequest::r_only(),
            },
            deps: Vec::new(),
            shared: Arc::new(JobShared { slot: Mutex::new(JobSlot::Queued), done: Condvar::new() }),
            label: None,
            no_steal: false,
            quota_counted: false,
            stolen: false,
        }
    }

    /// The skew-hazard audit (PR 9 satellite): over random queues, the
    /// shared `best_pos` scan — the one order behind the worker pop,
    /// the manual drain, *and* steal victim selection — must replay a
    /// full sort by `sched_key` exactly, and the steal-eligibility
    /// filter must agree with the runnable filter on dep-free queues.
    #[test]
    fn sched_key_scan_matches_full_sort_on_random_queues() {
        let mut rng = Rng::new(0xE1A5);
        for round in 0..100 {
            let n = 1 + rng.below(12) as usize;
            let mut jobs: VecDeque<QueuedJob> = VecDeque::new();
            let mut used = std::collections::HashSet::new();
            for _ in 0..n {
                let mut id = rng.below(64);
                while !used.insert(id) {
                    id = rng.below(64);
                }
                let priority = match rng.below(3) {
                    0 => Priority::Low,
                    1 => Priority::Normal,
                    _ => Priority::High,
                };
                jobs.push_back(synthetic_job(id, priority));
            }
            let mut expect: Vec<(std::cmp::Reverse<Priority>, JobId)> =
                jobs.iter().map(|j| ServiceInner::sched_key(j.priority, j.id)).collect();
            expect.sort();
            // steal victim selection and runnable pop agree on the head
            let steal_head = ServiceInner::best_pos(&jobs, ServiceInner::stealable);
            let pop_head = ServiceInner::best_pos(&jobs, |job| {
                !matches!(dep_state(&job.deps), DepState::Waiting)
            });
            assert_eq!(steal_head, pop_head, "round {round}");
            // repeated pops replay the sorted order exactly
            let mut popped = Vec::new();
            while let Some(job) = ServiceInner::pop_best(&mut jobs) {
                popped.push(ServiceInner::sched_key(job.priority, job.id));
            }
            assert_eq!(popped, expect, "round {round}");
        }
    }

    /// A `no_steal` job is invisible to victim selection while an
    /// ordinary one right behind it is taken.
    #[test]
    fn no_steal_jobs_are_not_victim_candidates() {
        let mut jobs: VecDeque<QueuedJob> = VecDeque::new();
        let mut first = synthetic_job(0, Priority::High);
        first.no_steal = true;
        jobs.push_back(first);
        jobs.push_back(synthetic_job(1, Priority::Normal));
        let (pos, _) = ServiceInner::best_pos(&jobs, ServiceInner::stealable).unwrap();
        assert_eq!(jobs[pos].id, JobId(1), "the opted-out High job must be skipped");
        jobs[1].no_steal = true;
        assert!(ServiceInner::best_pos(&jobs, ServiceInner::stealable).is_none());
    }
}
