//! Zero-padding of blocks to static artifact shapes, and the inverse
//! extraction.
//!
//! PJRT executables are compiled for fixed shapes; a `(rows × cols)`
//! block is embedded into the top-left corner of a `(b × n)` zero
//! buffer. Why this is exact:
//!
//! * **rows**: Householder reflectors built from columns with trailing
//!   zeros have zeros there, and every update preserves them → the thin
//!   Q's padded rows are exactly 0 and the top block/R agree with the
//!   unpadded factorization to roundoff. `gram`/`matmul` padding is an
//!   identity (adds zero terms).
//! * **cols**: zero columns produce identity reflectors (guarded in the
//!   kernel), zero rows/columns of R, and the leading `cols` columns of
//!   Q together with the principal `cols×cols` block of R form a valid
//!   thin QR of the original block.
//!
//! Pinned down by `python/tests/test_padding.py` (kernel side) and the
//! tests here (extraction side).

use crate::linalg::Matrix;

/// Embed `a` in the top-left of a `(b × n)` zero matrix (row-major).
pub fn pad_to(a: &Matrix, b: usize, n: usize) -> Vec<f64> {
    assert!(a.rows <= b && a.cols <= n, "pad_to smaller than input");
    let mut out = vec![0.0f64; b * n];
    for i in 0..a.rows {
        out[i * n..i * n + a.cols].copy_from_slice(a.row(i));
    }
    out
}

/// Extract the top-left `(rows × cols)` block from a row-major `(b × n)`
/// buffer.
pub fn extract(buf: &[f64], b: usize, n: usize, rows: usize, cols: usize) -> Matrix {
    assert_eq!(buf.len(), b * n, "buffer shape mismatch");
    assert!(rows <= b && cols <= n);
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        m.row_mut(i).copy_from_slice(&buf[i * n..i * n + cols]);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pad_extract_roundtrip() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(5, 3, &mut rng);
        let buf = pad_to(&a, 8, 4);
        assert_eq!(buf.len(), 32);
        let back = extract(&buf, 8, 4, 5, 3);
        assert_eq!(back.data, a.data);
    }

    #[test]
    fn padding_is_zero() {
        let a = Matrix::from_rows(1, 1, vec![7.0]);
        let buf = pad_to(&a, 2, 2);
        assert_eq!(buf, vec![7.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn exact_fit_is_identity() {
        let mut rng = Rng::new(2);
        let a = Matrix::gaussian(4, 4, &mut rng);
        let buf = pad_to(&a, 4, 4);
        assert_eq!(buf, a.data);
    }

    #[test]
    #[should_panic]
    fn rejects_shrinking() {
        let a = Matrix::zeros(4, 4);
        pad_to(&a, 2, 4);
    }

    #[test]
    fn padded_qr_extraction_is_valid_qr() {
        // End-to-end property the runtime relies on, via the native QR:
        // factor the padded block, extract, check factorization.
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(37, 5, &mut rng);
        let padded = Matrix::from_rows(64, 8, pad_to(&a, 64, 8));
        let (qp, rp) = crate::linalg::householder_qr(&padded);
        let q = extract(&qp.data, 64, 8, 37, 5);
        let r = extract(&rp.data, 8, 8, 5, 5);
        assert!(a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm() < 1e-13);
        assert!(q.orthogonality_error() < 1e-13);
        assert!(r.is_upper_triangular(0.0));
    }
}
