//! Execution of the AOT-compiled L1/L2 artifacts from the rust hot path.
//!
//! `make artifacts` lowers the JAX/Pallas per-task computations to HLO
//! text (one module per `(op, block_rows, cols)` in the manifest). This
//! module loads them with the `xla` crate's PJRT CPU client
//! (`HloModuleProto::from_text_file` → `client.compile` → `execute`),
//! caches compiled executables per shape, and pads partial blocks up to
//! the nearest manifest shape (zero-padding is mathematically exact for
//! all our ops — see DESIGN.md and `pad.rs`).
//!
//! [`BlockCompute`] is the interface the coordinator's algorithms use;
//! [`NativeRuntime`] is a pure-rust implementation of the same interface
//! (the oracle for differential tests, and the "Python-vs-C++" baseline
//! of the paper's Table I reproduction).

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod pad;

pub use artifacts::{Manifest, ManifestEntry, Op};
#[cfg(feature = "pjrt")]
pub use client::{PjrtRuntime, RuntimeStats};

use crate::linalg::{householder_qr, Matrix};
use anyhow::Result;
use std::sync::Arc;

/// A shareable, thread-safe handle to a resolved compute backend —
/// clone it into as many sessions (or engine task bodies on the host
/// thread pool) as needed; PJRT backends then share one compiled
/// executable cache process-wide.
pub type SharedCompute = Arc<dyn BlockCompute + Send + Sync>;

/// Block-level compute interface used by every MapReduce task body.
///
/// `Send + Sync` is part of the contract: the MapReduce engine executes
/// map/reduce waves on a host thread pool and every task of a wave
/// shares one backend reference, so implementations must guard any
/// interior mutability (see the `Mutex`-protected executable cache in
/// the PJRT client).
pub trait BlockCompute: Send + Sync {
    /// Thin QR of a tall block: `(rows×n) -> (Q rows×n, R n×n)`.
    fn qr(&self, a: &Matrix) -> Result<(Matrix, Matrix)>;
    /// Gram matrix `AᵀA` of a block.
    fn gram(&self, a: &Matrix) -> Result<Matrix>;
    /// Tall×small product `(rows×n)·(n×k)`.
    fn matmul(&self, a: &Matrix, s: &Matrix) -> Result<Matrix>;
    /// Fused QR + right-multiply: returns `(Q·s, R)`.
    fn qr_apply(&self, a: &Matrix, s: &Matrix) -> Result<(Matrix, Matrix)> {
        let (q, r) = self.qr(a)?;
        Ok((self.matmul(&q, s)?, r))
    }
    /// Largest block (rows) a single `qr` call can handle.
    fn max_qr_rows(&self, cols: usize) -> usize;
}

/// Pure-rust implementation of [`BlockCompute`] (no PJRT).
#[derive(Debug, Default)]
pub struct NativeRuntime;

impl BlockCompute for NativeRuntime {
    fn qr(&self, a: &Matrix) -> Result<(Matrix, Matrix)> {
        Ok(householder_qr(a))
    }

    fn gram(&self, a: &Matrix) -> Result<Matrix> {
        Ok(a.gram())
    }

    fn matmul(&self, a: &Matrix, s: &Matrix) -> Result<Matrix> {
        Ok(a.matmul(s))
    }

    fn max_qr_rows(&self, _cols: usize) -> usize {
        usize::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn native_qr_contract() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(40, 6, &mut rng);
        let rt = NativeRuntime;
        let (q, r) = rt.qr(&a).unwrap();
        assert!(a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm() < 1e-13);
        assert!(q.orthogonality_error() < 1e-13);
    }

    #[test]
    fn native_qr_apply_default_impl() {
        let mut rng = Rng::new(2);
        let a = Matrix::gaussian(30, 4, &mut rng);
        let s = Matrix::identity(4);
        let rt = NativeRuntime;
        let (qs, r) = rt.qr_apply(&a, &s).unwrap();
        assert!(a.sub(&qs.matmul(&r)).frob_norm() / a.frob_norm() < 1e-13);
    }
}
