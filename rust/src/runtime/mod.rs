//! Execution of the AOT-compiled L1/L2 artifacts from the rust hot path.
//!
//! `make artifacts` lowers the JAX/Pallas per-task computations to HLO
//! text (one module per `(op, block_rows, cols)` in the manifest). This
//! module loads them with the `xla` crate's PJRT CPU client
//! (`HloModuleProto::from_text_file` → `client.compile` → `execute`),
//! caches compiled executables per shape, and pads partial blocks up to
//! the nearest manifest shape (zero-padding is mathematically exact for
//! all our ops — see DESIGN.md and `pad.rs`).
//!
//! [`BlockCompute`] is the interface the coordinator's algorithms use;
//! [`NativeRuntime`] is a pure-rust implementation of the same interface
//! (the oracle for differential tests, and the "Python-vs-C++" baseline
//! of the paper's Table I reproduction).

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod pad;

pub use artifacts::{Manifest, ManifestEntry, Op};
#[cfg(feature = "pjrt")]
pub use client::{PjrtRuntime, RuntimeStats};

use crate::linalg::Matrix;
use anyhow::Result;
use std::sync::Arc;

/// A shareable, thread-safe handle to a resolved compute backend —
/// clone it into as many sessions (or engine task bodies on the host
/// thread pool) as needed; PJRT backends then share one compiled
/// executable cache process-wide.
pub type SharedCompute = Arc<dyn BlockCompute + Send + Sync>;

/// Block-level compute interface used by every MapReduce task body.
///
/// `Send + Sync` is part of the contract: the MapReduce engine executes
/// map/reduce waves on a host thread pool and every task of a wave
/// shares one backend reference, so implementations must guard any
/// interior mutability (see the `Mutex`-protected executable cache in
/// the PJRT client).
pub trait BlockCompute: Send + Sync {
    /// Thin QR of a tall block: `(rows×n) -> (Q rows×n, R n×n)`.
    fn qr(&self, a: &Matrix) -> Result<(Matrix, Matrix)>;
    /// Batched thin QR: factor `blocks` in one dispatch. Each `(Q, R)`
    /// must be bit-identical to a standalone [`BlockCompute::qr`] call
    /// on that block — batching may only amortize dispatch and scratch
    /// allocation. The default loops `qr`; backends with a cheaper
    /// batched path (the native workspace reuse, a future PJRT batch
    /// executable) override it.
    fn factor_blocks(&self, blocks: &[Matrix]) -> Result<Vec<(Matrix, Matrix)>> {
        blocks.iter().map(|a| self.qr(a)).collect()
    }
    /// Mixed-precision thin QR (f32 storage, f64 accumulate, one
    /// refinement step) for κ-gated opt-in callers. Backends without a
    /// reduced-precision path serve the full-precision factorization —
    /// callers may not assume which one ran. The native override also
    /// falls back to full precision when the fast path declines (input
    /// outside f32 range, refinement breakdown).
    fn qr_mixed(&self, a: &Matrix) -> Result<(Matrix, Matrix)> {
        self.qr(a)
    }
    /// Gram matrix `AᵀA` of a block.
    fn gram(&self, a: &Matrix) -> Result<Matrix>;
    /// Tall×small product `(rows×n)·(n×k)`.
    fn matmul(&self, a: &Matrix, s: &Matrix) -> Result<Matrix>;
    /// Fused QR + right-multiply: returns `(Q·s, R)`.
    fn qr_apply(&self, a: &Matrix, s: &Matrix) -> Result<(Matrix, Matrix)> {
        let (q, r) = self.qr(a)?;
        Ok((self.matmul(&q, s)?, r))
    }
    /// Largest block (rows) a single `qr` call can handle.
    fn max_qr_rows(&self, cols: usize) -> usize;
}

/// Pure-rust implementation of [`BlockCompute`] (no PJRT), built on the
/// blocked panel kernels in [`crate::linalg::block`].
///
/// `panel` is the Householder panel width — a pure speed knob: results
/// are bit-identical at any setting (see the `block` module docs), so
/// it is safe to tune per deployment via
/// `SessionBuilder::panel_block(b)` without invalidating digests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NativeRuntime {
    panel: usize,
}

impl NativeRuntime {
    /// Default-width runtime ([`linalg::DEFAULT_PANEL`]).
    ///
    /// [`linalg::DEFAULT_PANEL`]: crate::linalg::DEFAULT_PANEL
    pub fn new() -> Self {
        NativeRuntime { panel: crate::linalg::DEFAULT_PANEL }
    }

    /// Runtime with an explicit panel width (clamped to ≥ 1).
    pub fn with_panel(panel: usize) -> Self {
        NativeRuntime { panel: panel.max(1) }
    }

    /// The configured panel width.
    pub fn panel(&self) -> usize {
        self.panel
    }

    /// A `&'static` default-width instance — handy for tests and
    /// benches that need a `'static` oracle reference.
    pub fn oracle() -> &'static NativeRuntime {
        static ORACLE: NativeRuntime = NativeRuntime { panel: crate::linalg::DEFAULT_PANEL };
        &ORACLE
    }
}

impl Default for NativeRuntime {
    fn default() -> Self {
        NativeRuntime::new()
    }
}

impl BlockCompute for NativeRuntime {
    fn qr(&self, a: &Matrix) -> Result<(Matrix, Matrix)> {
        Ok(crate::linalg::blocked_qr(a, self.panel))
    }

    fn factor_blocks(&self, blocks: &[Matrix]) -> Result<Vec<(Matrix, Matrix)>> {
        Ok(crate::linalg::factor_blocks(blocks, self.panel))
    }

    fn qr_mixed(&self, a: &Matrix) -> Result<(Matrix, Matrix)> {
        match crate::linalg::mixed_qr(a) {
            Some(qr) => Ok(qr),
            None => self.qr(a),
        }
    }

    fn gram(&self, a: &Matrix) -> Result<Matrix> {
        Ok(a.gram())
    }

    fn matmul(&self, a: &Matrix, s: &Matrix) -> Result<Matrix> {
        Ok(a.matmul(s))
    }

    fn max_qr_rows(&self, _cols: usize) -> usize {
        usize::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn native_qr_contract() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(40, 6, &mut rng);
        let rt = NativeRuntime::new();
        let (q, r) = rt.qr(&a).unwrap();
        assert!(a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm() < 1e-13);
        assert!(q.orthogonality_error() < 1e-13);
    }

    #[test]
    fn native_qr_apply_default_impl() {
        let mut rng = Rng::new(2);
        let a = Matrix::gaussian(30, 4, &mut rng);
        let s = Matrix::identity(4);
        let rt = NativeRuntime::new();
        let (qs, r) = rt.qr_apply(&a, &s).unwrap();
        assert!(a.sub(&qs.matmul(&r)).frob_norm() / a.frob_norm() < 1e-13);
    }

    #[test]
    fn panel_width_is_a_pure_speed_knob() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(64, 12, &mut rng);
        let (q1, r1) = NativeRuntime::with_panel(1).qr(&a).unwrap();
        let (q2, r2) = NativeRuntime::with_panel(64).qr(&a).unwrap();
        assert_eq!(q1.data, q2.data);
        assert_eq!(r1.data, r2.data);
    }

    #[test]
    fn factor_blocks_matches_per_block_qr() {
        let mut rng = Rng::new(4);
        let blocks: Vec<Matrix> =
            (0..5).map(|i| Matrix::gaussian(20 + 7 * i, 4, &mut rng)).collect();
        let rt = NativeRuntime::new();
        let batched = rt.factor_blocks(&blocks).unwrap();
        for (a, (qb, rb)) in blocks.iter().zip(&batched) {
            let (q, r) = rt.qr(a).unwrap();
            assert_eq!(q.data, qb.data);
            assert_eq!(r.data, rb.data);
        }
    }

    #[test]
    fn qr_mixed_falls_back_outside_f32_range() {
        let mut rng = Rng::new(5);
        let mut a = Matrix::gaussian(30, 4, &mut rng);
        a[(2, 2)] = 1e300;
        let rt = NativeRuntime::new();
        let (q, r) = rt.qr_mixed(&a).unwrap();
        // fallback must serve the full-precision factorization
        let (qf, rf) = rt.qr(&a).unwrap();
        assert_eq!(q.data, qf.data);
        assert_eq!(r.data, rf.data);
    }
}
