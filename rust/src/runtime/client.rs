//! PJRT client wrapper: load HLO text → compile (cached) → execute.
//!
//! One [`PjrtRuntime`] owns the CPU client and an executable cache keyed
//! by `(op, b, n)`. Each artifact is compiled once per process (a cold
//! race may rarely compile a shape twice; the first insert wins) and
//! compilation never blocks concurrent hits on cached shapes; the hot
//! path is literal creation + `execute` + literal readback. Compile
//! counts and timings are tracked in [`RuntimeStats`] for the perf pass
//! (EXPERIMENTS.md §Perf).
//!
//! The runtime is shared across the engine's host worker threads
//! ([`crate::mapreduce::ClusterConfig::host_threads`]), so all interior
//! mutability is `Mutex`-guarded and executables are handed out as
//! `Arc`s.

use super::artifacts::{Manifest, ManifestEntry, Op};
use super::pad::{extract, pad_to};
use super::BlockCompute;
use crate::linalg::Matrix;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Execution counters for the perf pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
    /// f64 elements shipped host->device and back.
    pub elements_in: u64,
    pub elements_out: u64,
}

/// PJRT-backed implementation of [`BlockCompute`].
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<(Op, usize, usize), Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<RuntimeStats>,
}

// SAFETY: the `xla` crate's client/executable wrappers are `!Send`/
// `!Sync` only because they hold raw pointers to C++ objects; the
// underlying PJRT CPU client and loaded executables are documented
// thread-safe (compilation and execution take internal locks in the
// PJRT runtime). All rust-side shared state (`cache`, `stats`) is
// `Mutex`-guarded, and `Manifest` is read-only after construction.
unsafe impl Send for PjrtRuntime {}
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Create from the default artifacts directory (env
    /// `MRTSQR_ARTIFACTS` or `artifacts/`).
    pub fn from_default_artifacts() -> Result<Self> {
        Self::new(Manifest::load(&Manifest::default_dir())?)
    }

    pub fn new(manifest: Manifest) -> Result<Self> {
        // quiet the TF/XLA C++ banner unless the user overrides
        if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Snapshot of the execution counters.
    pub fn stats(&self) -> RuntimeStats {
        *self.stats.lock().expect("runtime stats")
    }

    /// Compile (or fetch from cache) the executable for an entry. The
    /// cache lock is *not* held across compilation, so concurrent hits
    /// on already-compiled shapes never stall behind a cold compile; a
    /// race on the same cold shape may compile it twice, in which case
    /// the first insert wins and the duplicate is dropped.
    fn executable(&self, entry: &ManifestEntry) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let key = (entry.op, entry.b, entry.n);
        if let Some(exe) = self.cache.lock().expect("executable cache").get(&key) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path_of(entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow!("loading HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", entry.file))?;
        let exe = Arc::new(exe);
        {
            let mut st = self.stats.lock().expect("runtime stats");
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        let mut cache = self.cache.lock().expect("executable cache");
        Ok(cache.entry(key).or_insert(exe).clone())
    }

    /// Execute an entry on padded row-major buffers, returning the raw
    /// output buffers (tuple elements, row-major).
    fn execute_raw(&self, entry: &ManifestEntry, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        if inputs.len() != entry.num_inputs {
            bail!("{}: expected {} inputs, got {}", entry.file, entry.num_inputs, inputs.len());
        }
        let exe = self.executable(entry)?;
        let t0 = Instant::now();
        let mut literals = Vec::with_capacity(inputs.len());
        for (idx, buf) in inputs.iter().enumerate() {
            let (rows, cols) = if idx == 0 {
                (entry.b as i64, entry.n as i64)
            } else {
                (entry.n as i64, entry.n as i64)
            };
            debug_assert_eq!(buf.len() as i64, rows * cols);
            let lit = xla::Literal::vec1(buf)
                .reshape(&[rows, cols])
                .map_err(|e| anyhow!("reshape input {idx}: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", entry.file))?;
        let mut root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback {}: {e:?}", entry.file))?;
        // aot.py lowers with return_tuple=True: always a tuple
        let parts = root
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose tuple {}: {e:?}", entry.file))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        {
            let mut st = self.stats.lock().expect("runtime stats");
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
            st.elements_in += inputs.iter().map(|b| b.len() as u64).sum::<u64>();
            st.elements_out += out.iter().map(|b| b.len() as u64).sum::<u64>();
        }
        Ok(out)
    }

    fn select(&self, op: Op, rows: usize, cols: usize) -> Result<&ManifestEntry> {
        self.manifest.select(op, rows, cols).ok_or_else(|| {
            anyhow!(
                "no artifact for op={} rows={rows} cols={cols} (max rows for this op/cols: {}) — \
                 regenerate artifacts or split the block",
                op.name(),
                self.manifest.max_rows(op, cols)
            )
        })
    }
}

impl BlockCompute for PjrtRuntime {
    fn qr(&self, a: &Matrix) -> Result<(Matrix, Matrix)> {
        if a.rows < a.cols {
            bail!("qr requires rows >= cols, got {}x{}", a.rows, a.cols);
        }
        let entry = self.select(Op::Qr, a.rows, a.cols)?.clone();
        let out = self.execute_raw(&entry, &[pad_to(a, entry.b, entry.n)])?;
        let q = extract(&out[0], entry.b, entry.n, a.rows, a.cols);
        let r = extract(&out[1], entry.n, entry.n, a.cols, a.cols);
        Ok((q, r))
    }

    fn gram(&self, a: &Matrix) -> Result<Matrix> {
        // Gram decomposes over row chunks: AᵀA = Σ chunkᵀchunk, so any
        // block size is served by chunking through the largest artifact.
        let max_b = self.manifest.max_rows(Op::Gram, a.cols);
        if max_b == 0 {
            bail!("no gram artifact for cols={}", a.cols);
        }
        if a.rows <= max_b {
            let entry = self.select(Op::Gram, a.rows, a.cols)?.clone();
            let out = self.execute_raw(&entry, &[pad_to(a, entry.b, entry.n)])?;
            return Ok(extract(&out[0], entry.n, entry.n, a.cols, a.cols));
        }
        let mut acc = Matrix::zeros(a.cols, a.cols);
        let mut start = 0;
        while start < a.rows {
            let end = (start + max_b).min(a.rows);
            let part = self.gram(&a.slice_rows(start, end))?;
            acc = acc.add(&part);
            start = end;
        }
        Ok(acc)
    }

    fn matmul(&self, a: &Matrix, s: &Matrix) -> Result<Matrix> {
        if a.cols != s.rows {
            bail!("matmul shape mismatch: {}x{} @ {}x{}", a.rows, a.cols, s.rows, s.cols);
        }
        if s.cols > s.rows {
            bail!("matmul artifact requires k <= n, got {}x{}", s.rows, s.cols);
        }
        // Row-wise independent: chunk tall inputs through the largest artifact.
        let max_b = self.manifest.max_rows(Op::Matmul, a.cols);
        if max_b == 0 {
            bail!("no matmul artifact for cols={}", a.cols);
        }
        if a.rows > max_b {
            let mut parts = Vec::new();
            let mut start = 0;
            while start < a.rows {
                let end = (start + max_b).min(a.rows);
                parts.push(self.matmul(&a.slice_rows(start, end), s)?);
                start = end;
            }
            let refs: Vec<&Matrix> = parts.iter().collect();
            return Ok(Matrix::vstack(&refs));
        }
        let entry = self.select(Op::Matmul, a.rows, a.cols)?.clone();
        let out = self.execute_raw(
            &entry,
            &[pad_to(a, entry.b, entry.n), pad_to(s, entry.n, entry.n)],
        )?;
        Ok(extract(&out[0], entry.b, entry.n, a.rows, s.cols))
    }

    fn qr_apply(&self, a: &Matrix, s: &Matrix) -> Result<(Matrix, Matrix)> {
        if a.rows < a.cols || s.rows != a.cols || s.cols != a.cols {
            bail!(
                "qr_apply shapes: a {}x{}, s {}x{}",
                a.rows, a.cols, s.rows, s.cols
            );
        }
        match self.manifest.select(Op::QrApply, a.rows, a.cols) {
            Some(entry) => {
                let entry = entry.clone();
                let out = self.execute_raw(
                    &entry,
                    &[pad_to(a, entry.b, entry.n), pad_to(s, entry.n, entry.n)],
                )?;
                let qs = extract(&out[0], entry.b, entry.n, a.rows, a.cols);
                let r = extract(&out[1], entry.n, entry.n, a.cols, a.cols);
                Ok((qs, r))
            }
            // fall back to the two-artifact composition
            None => {
                let (q, r) = self.qr(a)?;
                Ok((self.matmul(&q, s)?, r))
            }
        }
    }

    fn max_qr_rows(&self, cols: usize) -> usize {
        self.manifest.max_rows(Op::Qr, cols)
    }
}
