//! The artifact manifest: which `(op, b, n)` modules exist on disk.
//!
//! `aot.py` writes `manifest.tsv` (serde is unavailable offline, so the
//! interchange is one tab-separated line per module):
//!
//! ```text
//! op \t block_rows \t cols \t dtype \t file \t num_inputs
//! ```

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// The exported ops (mirror of `model.EXPORTS` on the python side).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Op {
    Qr,
    Gram,
    Matmul,
    QrApply,
}

impl Op {
    pub fn parse(s: &str) -> Result<Op> {
        Ok(match s {
            "qr" => Op::Qr,
            "gram" => Op::Gram,
            "matmul" => Op::Matmul,
            "qr_apply" => Op::QrApply,
            other => bail!("unknown op in manifest: {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Op::Qr => "qr",
            Op::Gram => "gram",
            Op::Matmul => "matmul",
            Op::QrApply => "qr_apply",
        }
    }
}

/// One AOT-compiled module.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub op: Op,
    pub b: usize,
    pub n: usize,
    pub file: String,
    pub num_inputs: usize,
}

/// Parsed manifest with shape-selection logic.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 6 {
                bail!("manifest line {}: expected 6 fields, got {}", lineno + 1, cols.len());
            }
            if cols[3] != "f64" {
                bail!("manifest line {}: only f64 artifacts supported, got {}", lineno + 1, cols[3]);
            }
            entries.push(ManifestEntry {
                op: Op::parse(cols[0])?,
                b: cols[1].parse().context("block rows")?,
                n: cols[2].parse().context("cols")?,
                file: cols[4].to_string(),
                num_inputs: cols[5].parse().context("num_inputs")?,
            });
        }
        if entries.is_empty() {
            bail!("manifest at {dir:?} has no entries");
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Default artifacts directory: `$MRTSQR_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root (also probing the parent, so tests
    /// running under `target/` still find it).
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("MRTSQR_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.tsv").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    /// Smallest artifact that fits a `(rows × cols)` input for `op`:
    /// minimal padded column count first (padding columns inflates every
    /// later byte), then minimal block rows ≥ `rows`.
    pub fn select(&self, op: Op, rows: usize, cols: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.n >= cols && e.b >= rows)
            .min_by_key(|e| (e.n, e.b))
    }

    /// Largest block-rows available for `op` at column count `cols`
    /// (0 if no artifact can serve this op/cols).
    pub fn max_rows(&self, op: Op, cols: usize) -> usize {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.n >= cols)
            .map(|e| e.b)
            .max()
            .unwrap_or(0)
    }

    pub fn path_of(&self, entry: &ManifestEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "qr\t256\t4\tf64\tqr_256x4.hlo.txt\t1\n\
                          qr\t1024\t4\tf64\tqr_1024x4.hlo.txt\t1\n\
                          qr\t1024\t10\tf64\tqr_1024x10.hlo.txt\t1\n\
                          matmul\t1024\t10\tf64\tmm.hlo.txt\t2\n";

    fn sample() -> Manifest {
        Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap()
    }

    #[test]
    fn parses_entries() {
        let m = sample();
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.entries[0].op, Op::Qr);
        assert_eq!(m.entries[0].b, 256);
    }

    #[test]
    fn select_prefers_tight_fit() {
        let m = sample();
        let e = m.select(Op::Qr, 100, 4).unwrap();
        assert_eq!((e.b, e.n), (256, 4));
        let e = m.select(Op::Qr, 300, 4).unwrap();
        assert_eq!((e.b, e.n), (1024, 4));
        // col padding: 5 cols -> n=10 artifact
        let e = m.select(Op::Qr, 100, 5).unwrap();
        assert_eq!((e.b, e.n), (1024, 10));
    }

    #[test]
    fn select_none_when_too_big() {
        let m = sample();
        assert!(m.select(Op::Qr, 5000, 4).is_none());
        assert!(m.select(Op::Qr, 10, 64).is_none());
        assert!(m.select(Op::Gram, 10, 4).is_none());
    }

    #[test]
    fn max_rows_per_op() {
        let m = sample();
        assert_eq!(m.max_rows(Op::Qr, 4), 1024);
        assert_eq!(m.max_rows(Op::Qr, 10), 1024);
        assert_eq!(m.max_rows(Op::Qr, 100), 0);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("/"), "qr\t1\t2\n").is_err());
        assert!(Manifest::parse(Path::new("/"), "").is_err());
        assert!(Manifest::parse(Path::new("/"), "qr\t1\t2\tf32\tx\t1\n").is_err());
        assert!(Manifest::parse(Path::new("/"), "wat\t1\t2\tf64\tx\t1\n").is_err());
    }

    #[test]
    fn real_manifest_loads() {
        // integration-ish: if the artifacts have been built, load them
        let dir = Manifest::default_dir();
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.select(Op::Qr, 1000, 50).is_some());
            assert!(m.select(Op::Gram, 4096, 100).is_some());
        }
    }
}
