//! MapReduce Householder QR (paper §III-A, Fig. 4) — the classic stable
//! algorithm as a baseline, and the reason Direct TSQR exists.
//!
//! Iterative by nature: column `j` needs (a) the norm of the trailing
//! column to build the reflector `v_j`, (b) `w = Aᵀv_j` (map+reduce),
//! (c) the rank-1 rewrite `A ← A − β v_j wᵀ` of the *entire matrix on
//! disk*. As in the paper, the first and third passes are merged (the
//! update pass also emits the next column's partial norms), so the
//! algorithm costs **2 passes per column = 2n passes**, every other one
//! rewriting the matrix. BLAS-2, row-layout bound — hopeless in
//! MapReduce, which is precisely Table VI's point.
//!
//! Only `R` is produced (as in the paper's implementation); `limit`
//! allows benchmarks to run the first few columns and extrapolate,
//! exactly like the paper's Table VI footnote.

use super::io::rows_to_block;
use super::{Coordinator, MatrixHandle};
use crate::dfs::records::{decode_row, encode_row, row_key, Record};
use crate::linalg::Matrix;
use crate::mapreduce::{Emitter, JobSpec, JobStats, KeyGroup, MapTask, ReduceTask};
use anyhow::{ensure, Result};

/// Broadcast parameters for one column step.
#[derive(Debug, Clone, Copy)]
struct ColParams {
    j: usize,
    alpha: f64,
}

fn encode_params(p: &ColParams) -> Vec<u8> {
    encode_row(&[p.j as f64, p.alpha])
}

fn decode_params(bytes: &[u8]) -> ColParams {
    let v = decode_row(bytes);
    ColParams { j: v[0] as usize, alpha: v[1] }
}

/// The reflector portion owned by one block: column `j` of `A_p` for
/// global rows ≥ j, with the pivot entry shifted by −alpha.
fn local_reflector(a: &Matrix, first_row: u64, p: &ColParams) -> Vec<f64> {
    let mut v = vec![0.0f64; a.rows];
    for i in 0..a.rows {
        let g = first_row as usize + i;
        if g >= p.j {
            v[i] = a[(i, p.j)];
        }
        if g == p.j {
            v[i] -= p.alpha;
        }
    }
    v
}

/// Pass A ("w-pass"): partial `vᵀv` and `A_pᵀ v_p`.
struct WPassMap;

impl MapTask for WPassMap {
    fn run(&self, task_id: usize, input: &[Record], side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        let p = decode_params(&side[0][0].value);
        let (a, first_row) = rows_to_block(input)?;
        let v = local_reflector(&a, first_row, &p);
        let vv: f64 = v.iter().map(|x| x * x).sum();
        let mut w = vec![0.0f64; a.cols + 1];
        w[0] = vv;
        for i in 0..a.rows {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            for (wk, &aik) in w[1..].iter_mut().zip(a.row(i)) {
                *wk += vi * aik;
            }
        }
        out.emit(row_key(task_id as u64), encode_row(&w));
        Ok(())
    }
}

/// Sum the per-task `[vᵀv, w…]` vectors into one record.
struct VecSumReduce;

impl ReduceTask for VecSumReduce {
    fn run(&self, partition: &[KeyGroup], out: &mut Emitter) -> Result<()> {
        let mut acc: Option<Vec<f64>> = None;
        for (_k, values) in partition {
            for v in values {
                let row = decode_row(v);
                match &mut acc {
                    None => acc = Some(row),
                    Some(a) => {
                        ensure!(a.len() == row.len(), "ragged partials");
                        for (x, y) in a.iter_mut().zip(row) {
                            *x += y;
                        }
                    }
                }
            }
        }
        if let Some(a) = acc {
            out.emit(row_key(0), encode_row(&a));
        }
        Ok(())
    }
}

/// Pass B ("update pass"): `A_p ← A_p − β v_p wᵀ`, rewrite the block,
/// and emit the next column's partial `[norm², diag]` statistics.
struct UpdatePassMap;

impl MapTask for UpdatePassMap {
    fn run(&self, task_id: usize, input: &[Record], side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        let p = decode_params(&side[0][0].value);
        let wrec = decode_row(&side[1][0].value);
        let (vv, w) = (wrec[0], &wrec[1..]);
        let (mut a, first_row) = rows_to_block(input)?;
        let beta = if vv > 0.0 { 2.0 / vv } else { 0.0 };
        let v = local_reflector(&a, first_row, &p);
        for i in 0..a.rows {
            let s = beta * v[i];
            if s != 0.0 {
                for (aik, wk) in a.row_mut(i).iter_mut().zip(w) {
                    *aik -= s * wk;
                }
            }
        }
        // rewrite rows with their original keys
        super::io::emit_rows(out, first_row, &a);
        // next column statistics: Σ x² over global rows ≥ j+1, plus the
        // diagonal entry A[j+1, j+1] if this block owns it
        let jn = p.j + 1;
        if jn < a.cols {
            let mut norm2 = 0.0f64;
            let mut diag = 0.0f64;
            for i in 0..a.rows {
                let g = first_row as usize + i;
                if g >= jn {
                    norm2 += a[(i, jn)] * a[(i, jn)];
                }
                if g == jn {
                    diag = a[(i, jn)];
                }
            }
            out.emit_to("stat", row_key(task_id as u64), encode_row(&[norm2, diag]));
        }
        Ok(())
    }
}

/// Initial pass: `[norm²(col 0), A[0,0]]` partials.
struct NormPassMap;

impl MapTask for NormPassMap {
    fn run(&self, task_id: usize, input: &[Record], _side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        let (a, first_row) = rows_to_block(input)?;
        let mut norm2 = 0.0f64;
        let mut diag = 0.0f64;
        for i in 0..a.rows {
            norm2 += a[(i, 0)] * a[(i, 0)];
            if first_row as usize + i == 0 {
                diag = a[(i, 0)];
            }
        }
        out.emit(row_key(task_id as u64), encode_row(&[norm2, diag]));
        Ok(())
    }
}

fn alpha_from(norm2: f64, diag: f64) -> f64 {
    let norm = norm2.sqrt();
    if diag >= 0.0 {
        -norm
    } else {
        norm
    }
}

fn sum_stats(records: &[Record]) -> (f64, f64) {
    let mut norm2 = 0.0;
    let mut diag = 0.0;
    for rec in records {
        let v = decode_row(&rec.value);
        norm2 += v[0];
        diag += v[1]; // only one block owns the diagonal; others emit 0
    }
    (norm2, diag)
}

/// Compute `R` by `2n` MapReduce passes. `limit` runs only the first
/// `limit` columns (benchmark extrapolation — paper Table VI's `*`);
/// `R` is only returned for full runs.
pub fn householder_r(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    limit: Option<usize>,
) -> Result<(Matrix, JobStats)> {
    let n = input.cols;
    let cols_to_run = limit.unwrap_or(n).min(n);
    let mut stats = JobStats::default();
    let map_tasks = coord.map_tasks_for(input.rows);

    // initial norm pass
    let stat_file = coord.tmp("house-stat");
    {
        let mapper = NormPassMap;
        let reducer = VecSumReduce;
        let spec = JobSpec::map_reduce(
            "house-norm0", &input.file, map_tasks, &mapper, &reducer, 1, &stat_file,
        );
        stats.push(coord.run_step(&spec)?);
    }
    let (mut norm2, mut diag) = coord.dfs(|dfs| -> Result<(f64, f64)> {
        let recs = dfs.get(&stat_file)?;
        let v = decode_row(&recs[0].value);
        Ok((v[0], v[1]))
    })?;

    let mut current = input.file.clone();
    for j in 0..cols_to_run {
        let params = ColParams { j, alpha: alpha_from(norm2, diag) };
        let params_file = coord.tmp("house-params");
        coord.dfs_mut(|dfs| {
            dfs.put(&params_file, vec![Record::new(row_key(0), encode_params(&params))])
        });

        // pass A: w = Aᵀ v (+ vᵀv)
        let w_file = coord.tmp("house-w");
        {
            let mapper = WPassMap;
            let reducer = VecSumReduce;
            let spec = JobSpec::map_reduce(
                &format!("house-w{j}"), &current, map_tasks, &mapper, &reducer, 1, &w_file,
            )
            .with_side_input(&params_file);
            stats.push(coord.run_step(&spec)?);
        }

        // pass B: update + rewrite + next-column stats
        let next = coord.tmp("house-a");
        let stat = coord.tmp("house-stat");
        {
            let mapper = UpdatePassMap;
            let data_scale = coord.dfs(|d| d.scale(&current));
            let spec = JobSpec::map_only(
                &format!("house-update{j}"), &current, map_tasks, &mapper, &next,
            )
            .with_side_input(&params_file)
            .with_side_input(&w_file)
            .with_side_output("stat", &stat)
            .with_output_scale(data_scale);
            stats.push(coord.run_step(&spec)?);
        }
        if j + 1 < n {
            let (n2, d) = coord.dfs(|dfs| dfs.get(&stat).map(sum_stats))?;
            norm2 = n2;
            diag = d;
        }
        if current != input.file {
            coord.dfs_mut(|dfs| dfs.delete(&current));
        }
        current = next;
    }

    // collect R from the leading n rows of the final matrix (only
    // meaningful for full runs)
    let mut r = Matrix::zeros(n, n);
    if cols_to_run == n {
        coord.dfs(|dfs| -> Result<()> {
            let recs = dfs.get(&current)?;
            for rec in recs.iter().take(n) {
                let i = super::io::parse_row_key(&rec.key)? as usize;
                if i < n {
                    let row = decode_row(&rec.value);
                    for j in i..n {
                        r[(i, j)] = row[j]; // below-diagonal residue is ~0
                    }
                }
            }
            Ok(())
        })?;
        super::indirect_tsqr::normalize_r_signs(&mut Matrix::zeros(0, 0), &mut r);
    }
    Ok((r, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{householder_qr, qr::sign_normalize};
    use crate::mapreduce::{ClusterConfig, Engine};
    use crate::runtime::NativeRuntime;
    use crate::util::rng::Rng;
    use crate::workload::put_matrix;

    fn coord_with(a: &Matrix) -> (Coordinator<'static>, MatrixHandle) {
        let mut engine = Engine::new(crate::dfs::DiskModel::icme_like(), ClusterConfig::default());
        put_matrix(&mut engine.dfs, "A", a);
        (Coordinator::new(engine, NativeRuntime::oracle()), MatrixHandle::new("A", a.rows, a.cols))
    }

    #[test]
    fn r_matches_serial_householder() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(120, 5, &mut rng);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 30;
        let (r, stats) = householder_r(&mut coord, &h, None).unwrap();
        let (mut qo, mut ro) = householder_qr(&a);
        sign_normalize(&mut qo, &mut ro);
        assert!(r.sub(&ro).max_abs() < 1e-10 * ro.max_abs(), "diff {}", r.sub(&ro).max_abs());
        // 1 norm pass + 2 jobs per column
        assert_eq!(stats.steps.len(), 1 + 2 * 5);
    }

    #[test]
    fn pass_count_is_two_per_column() {
        let mut rng = Rng::new(2);
        let a = Matrix::gaussian(60, 4, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let (_, stats) = householder_r(&mut coord, &h, Some(2)).unwrap();
        assert_eq!(stats.steps.len(), 1 + 2 * 2);
        // each update pass rewrites the matrix
        let update_steps: Vec<_> =
            stats.steps.iter().filter(|s| s.name.starts_with("house-update")).collect();
        let a_bytes = 60 * (32 + 4 * 8) as u64;
        for s in update_steps {
            assert!(s.map_io.bytes_written >= a_bytes, "rewrites full matrix");
        }
    }

    #[test]
    fn single_column_matrix() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(40, 1, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let (r, _) = householder_r(&mut coord, &h, None).unwrap();
        let norm: f64 = a.data.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((r[(0, 0)] - norm).abs() < 1e-10);
    }
}
