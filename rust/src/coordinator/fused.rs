//! Fused Direct TSQR — the paper's §VI "future work", implemented.
//!
//! > "once all the local mappers have run in the first step […] if we
//! > run a standard, in-memory MPI implementation to compute the QR
//! > factorization of this smaller matrix, then we could remove two
//! > iterations from the direct TSQR method. Also, we would remove much
//! > of the disk IO associated with saving the Q_i matrices."
//!
//! Concretely:
//!
//! 1. *map-only*: local QR, emit **only** `R_i` (no Q₁ spill — this is
//!    the big write the paper wants gone);
//! 2. *leader, in-memory*: gather the stacked `R` (it is tiny,
//!    `m₁·n × n`) and factor it serially — the "in-memory MPI" stand-in,
//!    charged as a leader step;
//! 3. *map-only over A again*: each task **recomputes** its local QR and
//!    multiplies by its `Q²_i` in one fused artifact call
//!    (`qr_apply`: `(A_i, Q²_i) → (Q_i·Q²_i, R_i)`). Determinism of the
//!    kernel makes the recomputed `Q_i` identical to step 1's.
//!
//! I/O compared with plain Direct TSQR: the `8mn + Km` Q₁ *write* and
//! *read* disappear in exchange for re-reading `A` (already required).
//! Since `β_w ≈ 2β_r`, the model predicts a ~25–35% job-time win — the
//! `ablation_fused` bench measures it.

use super::io::{decode_block, encode_block, rows_to_block};
use super::{Coordinator, MatrixHandle};
use crate::dfs::records::{row_key, Record};
use crate::linalg::Matrix;
use crate::mapreduce::{Emitter, JobSpec, JobStats, MapTask, StepStats};
use crate::runtime::BlockCompute;
use anyhow::{anyhow, ensure, Result};

/// Step 1: local QR, R only.
struct ROnlyMap<'a> {
    compute: &'a dyn BlockCompute,
}

impl MapTask for ROnlyMap<'_> {
    fn run(&self, task_id: usize, input: &[Record], _side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        let (a, _) = rows_to_block(input)?;
        let r = super::indirect_tsqr::r_of(self.compute, &a)?;
        out.emit(row_key(task_id as u64), encode_block(0, &r));
        Ok(())
    }
}

/// Step 3: recompute the local QR and fuse the right-multiply.
struct QrApplyMap<'a> {
    compute: &'a dyn BlockCompute,
    cols: usize,
    /// Shared parsed-side-file cache (see `Step3Map` in
    /// [`super::direct_tsqr`] for why `Mutex` + `Arc`).
    q2_cache: std::sync::Mutex<
        Option<std::sync::Arc<std::collections::HashMap<Vec<u8>, Matrix>>>,
    >,
}

impl QrApplyMap<'_> {
    fn q2(
        &self,
        side: &[Record],
    ) -> Result<std::sync::Arc<std::collections::HashMap<Vec<u8>, Matrix>>> {
        let mut cache = self.q2_cache.lock().expect("q2 cache");
        if let Some(map) = cache.as_ref() {
            return Ok(map.clone());
        }
        let map = std::sync::Arc::new(super::io::parse_q2_side(side, self.cols)?);
        *cache = Some(map.clone());
        Ok(map)
    }
}

impl MapTask for QrApplyMap<'_> {
    fn run(&self, task_id: usize, input: &[Record], side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        ensure!(side.len() == 1, "fused step 3 wants the Q² side file");
        let q2map = self.q2(side[0])?;
        let q2 = q2map
            .get(&row_key(task_id as u64))
            .ok_or_else(|| anyhow!("no Q² block for task {task_id}"))?;
        let (a, first_row) = rows_to_block(input)?;
        let qs = if a.rows >= a.cols {
            self.compute.qr_apply(&a, q2)?.0
        } else {
            let pad = Matrix::zeros(a.cols - a.rows, a.cols);
            let stacked = Matrix::vstack(&[&a, &pad]);
            self.compute.qr_apply(&stacked, q2)?.0.slice_rows(0, a.rows)
        };
        super::io::emit_rows(out, first_row, &qs);
        Ok(())
    }
}

/// Leader-side in-memory factorization of the stacked R (charged as a
/// leader step reading/writing the factor bytes).
fn leader_step2(
    coord: &mut Coordinator,
    r1_file: &str,
    q2_file: &str,
    n: usize,
) -> Result<(Matrix, StepStats)> {
    let (blocks, read_bytes) = coord.dfs(|dfs| -> Result<(Vec<(Vec<u8>, Matrix)>, u64)> {
        let recs = dfs.get(r1_file)?;
        let mut blocks = Vec::with_capacity(recs.len());
        let mut bytes = 0u64;
        for rec in recs {
            bytes += rec.size_bytes();
            let (_, r_i) = decode_block(&rec.value)?;
            ensure!(r_i.cols == n, "R block width");
            blocks.push((rec.key.clone(), r_i));
        }
        Ok((blocks, bytes))
    })?;
    let refs: Vec<&Matrix> = blocks.iter().map(|(_, m)| m).collect();
    let stacked = Matrix::vstack(&refs);
    // in-memory factorization (serial Householder — the "MPI" stand-in)
    let (q2, r) = crate::linalg::householder_qr(&stacked);

    let mut out_records = Vec::with_capacity(blocks.len());
    let mut offset = 0usize;
    let mut write_bytes = 0u64;
    for (key, r_i) in &blocks {
        let q2_i = q2.slice_rows(offset, offset + r_i.rows);
        let rec = Record::new(key.clone(), encode_block(offset as u64, &q2_i));
        write_bytes += rec.size_bytes();
        out_records.push(rec);
        offset += r_i.rows;
    }
    coord.dfs_mut(|dfs| dfs.put(q2_file, out_records));

    let model = coord.model();
    let mut s = StepStats { name: "fused-step2(leader)".into(), map_tasks: 1, ..Default::default() };
    s.map_io.add_read(read_bytes, blocks.len() as u64);
    s.map_io.add_write(write_bytes, blocks.len() as u64);
    s.virtual_secs = model.read_secs(read_bytes)
        + model.write_secs(write_bytes)
        + model.task_startup_secs;
    Ok((r, s))
}

/// Run the fused Direct TSQR (paper §VI). Requires the stacked R to fit
/// in leader memory — callers with huge `m₁·n` should use the recursive
/// [`super::direct_tsqr`] instead.
pub fn direct_tsqr_fused(
    coord: &mut Coordinator,
    input: &MatrixHandle,
) -> Result<super::QrResult> {
    let n = input.cols;
    let mut stats = JobStats::default();
    let data_scale = coord.dfs(|d| d.scale(&input.file));

    // step 1: R factors only
    let r1_file = coord.tmp("fused-r1");
    {
        let mapper = ROnlyMap { compute: coord.compute };
        let spec = JobSpec::map_only(
            "fused-step1",
            &input.file,
            coord.map_tasks_for(input.rows),
            &mapper,
            &r1_file,
        );
        stats.push(coord.run_step(&spec)?);
    }

    // step 2: in-memory on the leader
    let q2_file = coord.tmp("fused-q2");
    let (r, step2) = leader_step2(coord, &r1_file, &q2_file, n)?;
    stats.push(step2);

    // step 3: re-read A, fused qr·Q² per block
    let q_file = coord.tmp("fused-q");
    {
        let mapper = QrApplyMap {
            compute: coord.compute,
            cols: n,
            q2_cache: std::sync::Mutex::new(None),
        };
        let spec = JobSpec::map_only(
            "fused-step3",
            &input.file,
            coord.map_tasks_for(input.rows),
            &mapper,
            &q_file,
        )
        .with_side_input(&q2_file)
        .with_output_scale(data_scale);
        stats.push(coord.run_step(&spec)?);
    }

    Ok(super::QrResult {
        q: Some(MatrixHandle::new(&q_file, input.rows, n)),
        r,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Algorithm;
    use crate::linalg::matrix_with_condition;
    use crate::mapreduce::{ClusterConfig, Engine};
    use crate::runtime::NativeRuntime;
    use crate::util::rng::Rng;
    use crate::workload::{get_matrix, put_matrix};

    fn coord_with(a: &Matrix) -> (Coordinator<'static>, MatrixHandle) {
        let mut engine = Engine::new(crate::dfs::DiskModel::icme_like(), ClusterConfig::default());
        put_matrix(&mut engine.dfs, "A", a);
        (Coordinator::new(engine, NativeRuntime::oracle()), MatrixHandle::new("A", a.rows, a.cols))
    }

    #[test]
    fn fused_is_a_valid_stable_factorization() {
        let mut rng = Rng::new(1);
        let a = matrix_with_condition(600, 8, 1e12, &mut rng);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 64;
        let res = direct_tsqr_fused(&mut coord, &h).unwrap();
        let q = coord.dfs(|d| get_matrix(d, &res.q.unwrap().file, 8)).unwrap();
        assert!(q.orthogonality_error() < 1e-12, "orth {}", q.orthogonality_error());
        assert!(a.sub(&q.matmul(&res.r)).frob_norm() / a.frob_norm() < 1e-12);
    }

    #[test]
    fn fused_writes_less_than_plain_direct() {
        // the whole point: no Q1 spill
        let mut rng = Rng::new(2);
        let a = Matrix::gaussian(800, 6, &mut rng);
        let (mut c1, h1) = coord_with(&a);
        c1.opts.rows_per_task = 50;
        let plain = c1.qr(&h1, Algorithm::DirectTsqr).unwrap();
        let (mut c2, h2) = coord_with(&a);
        c2.opts.rows_per_task = 50;
        let fused = direct_tsqr_fused(&mut c2, &h2).unwrap();
        let wb_plain = plain.stats.total_io().bytes_written;
        let wb_fused = fused.stats.total_io().bytes_written;
        assert!(
            (wb_fused as f64) < 0.7 * wb_plain as f64,
            "fused writes {wb_fused} vs plain {wb_plain}"
        );
        // and it is faster on the virtual clock
        assert!(fused.stats.virtual_secs() < plain.stats.virtual_secs());
    }

    #[test]
    fn fused_matches_plain_direct_r() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(300, 5, &mut rng);
        let (mut c1, h1) = coord_with(&a);
        let plain = c1.qr(&h1, Algorithm::DirectTsqr).unwrap();
        let (mut c2, h2) = coord_with(&a);
        let fused = direct_tsqr_fused(&mut c2, &h2).unwrap();
        let mut r1 = plain.r.clone();
        let mut r2 = fused.r.clone();
        super::super::indirect_tsqr::normalize_r_signs(&mut Matrix::zeros(0, 0), &mut r1);
        super::super::indirect_tsqr::normalize_r_signs(&mut Matrix::zeros(0, 0), &mut r2);
        assert!(r1.sub(&r2).max_abs() < 1e-10 * r1.max_abs());
    }
}
