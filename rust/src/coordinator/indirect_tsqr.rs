//! Indirect TSQR (paper §II-B; Constantine & Gleich).
//!
//! Stable computation of `R` only: each map task factors its block and
//! ships the `n×n` `R_i` rows into a reduction *tree* — first a
//! `r_max`-way level (keys spread across reducers, each reducer QRs its
//! stack), then a final single-reducer level. `Q` is later recovered
//! indirectly as `A·R⁻¹` ([`super::ar_inv`]), which is the numerically
//! unstable step the Direct TSQR avoids.

use super::io::{read_small_matrix, rows_to_block};
use super::{Coordinator, MatrixHandle};
use crate::dfs::records::{encode_row, row_key, Record};
use crate::linalg::Matrix;
use crate::mapreduce::{Emitter, JobSpec, JobStats, KeyGroup, MapTask, ReduceTask};
use crate::runtime::BlockCompute;
use anyhow::{ensure, Result};

/// QR-and-keep-R of a (possibly short) stack: pads with zero rows when
/// the stack has fewer rows than columns (R of the padded stack equals
/// R of the stack), and folds sequentially (`R ← R of [R; next chunk]`)
/// when the stack exceeds the backend's largest block.
pub(crate) fn r_of(compute: &dyn BlockCompute, m: &Matrix) -> Result<Matrix> {
    let max = compute.max_qr_rows(m.cols).max(2 * m.cols);
    if m.rows > max {
        let mut r: Option<Matrix> = None;
        let chunk_rows = max - m.cols;
        let mut start = 0;
        while start < m.rows {
            let end = (start + chunk_rows).min(m.rows);
            let chunk = m.slice_rows(start, end);
            let stacked = match &r {
                Some(prev) => Matrix::vstack(&[prev, &chunk]),
                None => chunk,
            };
            r = Some(r_of(compute, &stacked)?);
            start = end;
        }
        return Ok(r.expect("non-empty stack"));
    }
    if m.rows >= m.cols {
        Ok(compute.qr(m)?.1)
    } else {
        let pad = Matrix::zeros(m.cols - m.rows, m.cols);
        let stacked = Matrix::vstack(&[m, &pad]);
        Ok(compute.qr(&stacked)?.1)
    }
}

/// Map: local QR, emit the rows of `R_i` keyed by global R-row id
/// (`task·n + j` → `m1·n` distinct keys, paper Table IV).
struct LocalQrMap<'a> {
    compute: &'a dyn BlockCompute,
}

impl MapTask for LocalQrMap<'_> {
    fn run(&self, task_id: usize, input: &[Record], _side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        let (a, _) = rows_to_block(input)?;
        let r = r_of(self.compute, &a)?;
        for j in 0..r.rows {
            out.emit(row_key((task_id * r.rows + j) as u64), encode_row(r.row(j)));
        }
        Ok(())
    }
}

/// Reduce: stack this partition's R rows (key-sorted), QR them, emit the
/// new R rows re-keyed by partition-local ids.
struct StackQrReduce<'a> {
    compute: &'a dyn BlockCompute,
    cols: usize,
}

impl ReduceTask for StackQrReduce<'_> {
    fn run(&self, partition: &[KeyGroup], out: &mut Emitter) -> Result<()> {
        let mut data = Vec::new();
        let mut rows = 0usize;
        for (_key, values) in partition {
            for v in values {
                let row = crate::dfs::records::decode_row(v);
                ensure!(row.len() == self.cols, "ragged R rows");
                data.extend_from_slice(&row);
                rows += 1;
            }
        }
        let stacked = Matrix::from_rows(rows, self.cols, data);
        let r = r_of(self.compute, &stacked)?;
        // re-key rows by the partition's smallest input key so the next
        // level gets distinct, ordered keys
        let base = partition[0].0.clone();
        let base_id = super::io::parse_row_key(&base)?;
        for j in 0..r.rows {
            out.emit(row_key(base_id + j as u64), encode_row(r.row(j)));
        }
        Ok(())
    }
}

/// Identity map (the second tree level reads the first level's R file).
struct IdentityMap;

impl MapTask for IdentityMap {
    fn run(&self, _id: usize, input: &[Record], _side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        for rec in input {
            out.emit(rec.key.clone(), rec.value.clone());
        }
        Ok(())
    }
}

/// Compute `R` via the two-level TSQR reduction tree.
pub fn indirect_r(coord: &mut Coordinator, input: &MatrixHandle) -> Result<(Matrix, JobStats)> {
    let mut stats = JobStats::default();
    let n = input.cols;

    // level 1: map QR + r_max-way reduce QR
    let level1 = coord.tmp("indirect-r1");
    let mapper = LocalQrMap { compute: coord.compute };
    let reducer = StackQrReduce { compute: coord.compute, cols: n };
    let spec = JobSpec::map_reduce(
        "indirect-level1",
        &input.file,
        coord.map_tasks_for(input.rows),
        &mapper,
        &reducer,
        coord.opts.reduce_tasks,
        &level1,
    );
    stats.push(coord.run_step(&spec)?);

    // level 2: identity map + single reduce QR -> final R
    let level2 = coord.tmp("indirect-r2");
    let id = IdentityMap;
    let reducer2 = StackQrReduce { compute: coord.compute, cols: n };
    let records = coord.dfs(|d| d.file_records(&level1))?;
    let spec2 = JobSpec::map_reduce(
        "indirect-level2",
        &level1,
        records.min(coord.opts.reduce_tasks).max(1),
        &id,
        &reducer2,
        1,
        &level2,
    );
    stats.push(coord.run_step(&spec2)?);

    let mut r = coord.dfs(|d| d.get(&level2).and_then(read_small_matrix))?;
    ensure!(r.rows == n && r.cols == n, "final R is {}x{}", r.rows, r.cols);
    // normalize diag(R) >= 0 so results are comparable across trees
    let mut dummy_q = Matrix::zeros(0, 0);
    normalize_r_signs(&mut dummy_q, &mut r);
    Ok((r, stats))
}

/// Single-level-tree ablation: map QR straight into one reducer, no
/// intermediate `r_max`-way level. Constantine & Gleich "found that
/// using an additional MapReduce iteration to form a more parallel
/// reduction tree could greatly accelerate the method" (paper §II-B) —
/// the `ablation_tree` bench measures that trade-off (one fewer
/// iteration-startup vs a serial single-reducer gather of all `m₁·n`
/// rows).
pub fn indirect_r_single_level(
    coord: &mut Coordinator,
    input: &MatrixHandle,
) -> Result<(Matrix, JobStats)> {
    let mut stats = JobStats::default();
    let n = input.cols;
    let out = coord.tmp("indirect-1lvl");
    let mapper = LocalQrMap { compute: coord.compute };
    let reducer = StackQrReduce { compute: coord.compute, cols: n };
    let spec = JobSpec::map_reduce(
        "indirect-single-level",
        &input.file,
        coord.map_tasks_for(input.rows),
        &mapper,
        &reducer,
        1,
        &out,
    );
    stats.push(coord.run_step(&spec)?);
    let mut r = coord.dfs(|d| d.get(&out).and_then(read_small_matrix))?;
    ensure!(r.rows == n && r.cols == n, "final R is {}x{}", r.rows, r.cols);
    normalize_r_signs(&mut Matrix::zeros(0, 0), &mut r);
    Ok((r, stats))
}

/// Flip R row signs so the diagonal is non-negative (QR sign freedom).
/// When `q` is non-empty its columns are flipped consistently.
pub fn normalize_r_signs(q: &mut Matrix, r: &mut Matrix) {
    for j in 0..r.rows {
        if r[(j, j)] < 0.0 {
            for k in j..r.cols {
                r[(j, k)] = -r[(j, k)];
            }
            for i in 0..q.rows {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{householder_qr, matrix_with_condition, qr::sign_normalize};
    use crate::mapreduce::{ClusterConfig, Engine};
    use crate::runtime::NativeRuntime;
    use crate::util::rng::Rng;
    use crate::workload::put_matrix;

    fn coord_with(a: &Matrix) -> (Coordinator<'static>, MatrixHandle) {
        let mut engine = Engine::new(crate::dfs::DiskModel::icme_like(), ClusterConfig::default());
        put_matrix(&mut engine.dfs, "A", a);
        (Coordinator::new(engine, NativeRuntime::oracle()), MatrixHandle::new("A", a.rows, a.cols))
    }

    #[test]
    fn r_matches_oracle() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(600, 8, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let (r, stats) = indirect_r(&mut coord, &h).unwrap();
        let (mut qo, mut ro) = householder_qr(&a);
        sign_normalize(&mut qo, &mut ro);
        assert!(r.sub(&ro).max_abs() < 1e-10 * ro.max_abs(), "{:?}", r.sub(&ro).max_abs());
        assert_eq!(stats.steps.len(), 2);
    }

    #[test]
    fn r_stable_on_ill_conditioned() {
        // unlike Cholesky QR, TSQR's R survives kappa = 1e12
        let mut rng = Rng::new(2);
        let a = matrix_with_condition(300, 6, 1e12, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let (r, _) = indirect_r(&mut coord, &h).unwrap();
        let (mut qo, mut ro) = householder_qr(&a);
        sign_normalize(&mut qo, &mut ro);
        // compare on the dominant scale
        assert!(r.sub(&ro).max_abs() < 1e-12 * ro.max_abs());
    }

    #[test]
    fn partition_count_invariance() {
        // R must not depend on the number of map tasks or reducers
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(240, 5, &mut rng);
        let (mut c1, h1) = coord_with(&a);
        c1.opts.rows_per_task = 40;
        let (r1, _) = indirect_r(&mut c1, &h1).unwrap();
        let (mut c2, h2) = coord_with(&a);
        c2.opts.rows_per_task = 17;
        c2.opts.reduce_tasks = 7;
        let (r2, _) = indirect_r(&mut c2, &h2).unwrap();
        assert!(r1.sub(&r2).max_abs() < 1e-10 * r1.max_abs());
    }

    #[test]
    fn tiny_blocks_shorter_than_n() {
        let mut rng = Rng::new(4);
        let a = Matrix::gaussian(50, 8, &mut rng);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 3; // blocks with fewer rows than cols
        let (r, _) = indirect_r(&mut coord, &h).unwrap();
        let g = r.transpose().matmul(&r);
        assert!(g.sub(&a.gram()).max_abs() < 1e-10 * a.gram().max_abs());
    }
}
