//! Cholesky QR (paper §II-A, Alg. 1).
//!
//! Map stage: each task gathers its split into `A_p` and computes the
//! Gram matrix `A_pᵀA_p` (the `gram` artifact on the PJRT path), emitting
//! one record per Gram row keyed by row index — so the reduce stage has
//! exactly `n` distinct keys (the architecture limitation the paper
//! points out). Reduce: sum the per-task rows. A serial Cholesky on the
//! gathered `n×n` matrix gives `R = Lᵀ`.
//!
//! Breakdown semantics: `cond(AᵀA) = cond(A)²`, so for `cond(A) ≳ 1e8`
//! the factorization fails — surfaced as an error carrying
//! [`crate::linalg::CholeskyError`], which the stability bench (Fig. 6)
//! reports as "breakdown".

use super::io::rows_to_block;
use super::{Coordinator, MatrixHandle};
use crate::dfs::records::{decode_row, encode_row, row_key, Record};
use crate::linalg::{cholesky, Matrix};
use crate::mapreduce::{Emitter, JobSpec, JobStats, KeyGroup, MapTask, ReduceTask, StepStats};
use crate::runtime::BlockCompute;
use anyhow::{ensure, Result};

struct GramMap<'a> {
    compute: &'a dyn BlockCompute,
}

impl MapTask for GramMap<'_> {
    fn run(&self, _id: usize, input: &[Record], _side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        let (a, _) = rows_to_block(input)?;
        let g = self.compute.gram(&a)?;
        for i in 0..g.rows {
            out.emit(row_key(i as u64), encode_row(g.row(i)));
        }
        Ok(())
    }
}

struct RowSumReduce;

impl ReduceTask for RowSumReduce {
    fn run(&self, partition: &[KeyGroup], out: &mut Emitter) -> Result<()> {
        for (key, values) in partition {
            ensure!(!values.is_empty(), "empty row-sum group");
            let mut acc = decode_row(&values[0]);
            for v in &values[1..] {
                let row = decode_row(v);
                ensure!(row.len() == acc.len(), "ragged gram rows");
                for (a, b) in acc.iter_mut().zip(row) {
                    *a += b;
                }
            }
            out.emit(key.clone(), encode_row(&acc));
        }
        Ok(())
    }
}

/// Charge the serial n×n gather+factor as a tiny leader step (the
/// paper's Table III models it as one iteration of `8n²+8n` traffic).
fn leader_step(coord: &Coordinator, name: &str, read: u64, write: u64) -> StepStats {
    let model = coord.model();
    let mut s = StepStats { name: name.into(), map_tasks: 1, ..Default::default() };
    s.map_io.add_read(read, 0);
    s.map_io.add_write(write, 0);
    s.virtual_secs = model.iteration_startup_secs
        + model.read_secs(read)
        + model.write_secs(write)
        + model.task_startup_secs;
    s
}

/// Compute `R` via Cholesky QR. Returns the breakdown error (with a
/// downcastable [`crate::linalg::CholeskyError`]) for ill-conditioned
/// inputs — the paper's Fig. 6 failure mode.
pub fn cholesky_r(coord: &mut Coordinator, input: &MatrixHandle) -> Result<(Matrix, JobStats)> {
    let mut stats = JobStats::default();
    let gram_file = coord.tmp("chol-gram");
    let mapper = GramMap { compute: coord.compute };
    let reducer = RowSumReduce;
    let spec = JobSpec::map_reduce(
        "cholesky-gram",
        &input.file,
        coord.map_tasks_for(input.rows),
        &mapper,
        &reducer,
        coord.opts.reduce_tasks,
        &gram_file,
    );
    stats.push(coord.run_step(&spec)?);

    // leader: gather AᵀA, serial Cholesky
    let g = coord.dfs(|dfs| -> Result<Matrix> {
        let recs = dfs.get(&gram_file)?;
        ensure!(recs.len() == input.cols, "gram has {} rows, want {}", recs.len(), input.cols);
        let mut g = Matrix::zeros(input.cols, input.cols);
        for rec in recs {
            // reduce output arrives in partition order, not key order —
            // place each row by its key
            let i = super::io::parse_row_key(&rec.key)? as usize;
            ensure!(i < input.cols, "gram row key {i} out of range");
            let row = decode_row(&rec.value);
            ensure!(row.len() == input.cols, "gram row width");
            g.row_mut(i).copy_from_slice(&row);
        }
        Ok(g)
    })?;
    let nn = (8 * input.cols * input.cols + 8 * input.cols) as u64;
    stats.push(leader_step(coord, "cholesky-factor", nn, nn));

    let l = cholesky(&g).map_err(anyhow::Error::new)?;
    Ok((l.transpose(), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{householder_qr, matrix_with_condition, qr::sign_normalize};
    use crate::mapreduce::{ClusterConfig, Engine};
    use crate::runtime::NativeRuntime;
    use crate::util::rng::Rng;
    use crate::workload::put_matrix;

    fn coord_with(a: &Matrix) -> (Coordinator<'static>, MatrixHandle) {
        let mut engine = Engine::new(crate::dfs::DiskModel::icme_like(), ClusterConfig::default());
        put_matrix(&mut engine.dfs, "A", a);
        let coord = Coordinator::new(engine, NativeRuntime::oracle());
        (coord, MatrixHandle::new("A", a.rows, a.cols))
    }

    #[test]
    fn r_matches_householder_oracle() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(500, 6, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let (r, stats) = cholesky_r(&mut coord, &h).unwrap();
        let (mut qo, mut ro) = householder_qr(&a);
        sign_normalize(&mut qo, &mut ro);
        // Cholesky R has positive diagonal by construction
        assert!(r.sub(&ro).max_abs() < 1e-9 * ro.max_abs());
        assert!(r.is_upper_triangular(0.0));
        assert_eq!(stats.steps.len(), 2);
        assert_eq!(stats.steps[0].distinct_keys, 6);
    }

    #[test]
    fn breaks_down_on_ill_conditioned() {
        let mut rng = Rng::new(2);
        let a = matrix_with_condition(400, 8, 1e10, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let err = cholesky_r(&mut coord, &h).unwrap_err();
        assert!(err.downcast_ref::<crate::linalg::CholeskyError>().is_some());
    }

    #[test]
    fn single_row_blocks_ok() {
        // map tasks smaller than n: gram still sums correctly
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(40, 5, &mut rng);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 1; // 40 tasks of 1 row each
        let (r, _) = cholesky_r(&mut coord, &h).unwrap();
        let g = r.transpose().matmul(&r);
        assert!(g.sub(&a.gram()).max_abs() < 1e-10 * a.gram().max_abs());
    }
}
