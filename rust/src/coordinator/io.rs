//! Record codecs shared by the pipelines.
//!
//! Two layouts live in the DFS:
//!
//! * **row records** — one matrix row per record, key = 32-byte global
//!   row id (the canonical tall-and-skinny layout, paper §I-A);
//! * **block records** — a whole factor (`Q_i`, `R_i`, `Q_i²`) per
//!   record, key = 32-byte task id, value = magic + first-row offset +
//!   dims + data. The paper's step 1 emits exactly these ("a unique map
//!   task identifier as the key and the Q or R factor as the value").

use crate::dfs::records::{decode_row, encode_row, row_key, Record};
use crate::linalg::Matrix;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;

/// Magic prefix distinguishing block records from row records.
const BLOCK_MAGIC: &[u8; 8] = b"MRBLOCK1";

/// Encode a factor block with its global first-row offset.
pub fn encode_block(first_row: u64, m: &Matrix) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + m.data.len() * 8);
    out.extend_from_slice(BLOCK_MAGIC);
    out.extend_from_slice(&first_row.to_le_bytes());
    out.extend_from_slice(&(m.rows as u64).to_le_bytes());
    out.extend_from_slice(&(m.cols as u64).to_le_bytes());
    for v in &m.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Encode a block plus `filler` trailing bytes. The paper's on-disk Q
/// files carry a 32-byte key per matrix row (`K·m` in Table III's byte
/// counts); [`encode_block`] stores one key per *block*, so the step-1
/// Q₁ emission appends `32·rows` filler to keep the byte accounting —
/// and therefore every performance table — aligned with the paper.
pub fn encode_block_with_filler(first_row: u64, m: &Matrix, filler: usize) -> Vec<u8> {
    let mut out = encode_block(first_row, m);
    out.resize(out.len() + filler, 0u8);
    out
}

/// Decode a block record value -> (first_row, matrix). Trailing filler
/// bytes (see [`encode_block_with_filler`]) are ignored.
pub fn decode_block(bytes: &[u8]) -> Result<(u64, Matrix)> {
    ensure!(bytes.len() >= 32 && &bytes[..8] == BLOCK_MAGIC, "not a block record");
    let first_row = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let rows = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(bytes[24..32].try_into().unwrap()) as usize;
    ensure!(bytes.len() >= 32 + rows * cols * 8, "block record too short");
    let data = decode_row(&bytes[32..32 + rows * cols * 8]);
    Ok((first_row, Matrix::from_rows(rows, cols, data)))
}

pub fn is_block_record(value: &[u8]) -> bool {
    value.len() >= 8 && &value[..8] == BLOCK_MAGIC
}

/// Parse the global row id out of a 32-byte row key.
pub fn parse_row_key(key: &[u8]) -> Result<u64> {
    let s = std::str::from_utf8(key).context("row key not utf8")?;
    s.trim_start_matches('0').parse::<u64>().or_else(|_| {
        if s.chars().all(|c| c == '0') {
            Ok(0)
        } else {
            bail!("bad row key {s:?}")
        }
    })
}

/// Assemble a map split of row records into a `Matrix`, returning the
/// global row id of the first record (splits are contiguous).
pub fn rows_to_block(input: &[Record]) -> Result<(Matrix, u64)> {
    ensure!(!input.is_empty(), "empty split");
    let first_row = parse_row_key(&input[0].key)?;
    let cols = input[0].value.len() / 8;
    let mut data = Vec::with_capacity(input.len() * cols);
    for rec in input {
        let row = decode_row(&rec.value);
        ensure!(row.len() == cols, "ragged rows in split");
        data.extend_from_slice(&row);
    }
    Ok((Matrix::from_rows(input.len(), cols, data), first_row))
}

/// Emit a matrix as row records with keys `first_row..first_row+rows`.
pub fn emit_rows(out: &mut crate::mapreduce::Emitter, first_row: u64, m: &Matrix) {
    for i in 0..m.rows {
        out.emit(row_key(first_row + i as u64), encode_row(m.row(i)));
    }
}

/// Parse a step-2 Q² side file into per-block factors. Accepts both
/// layouts (see module docs): block records map directly; row records
/// (a recursive Direct TSQR's Q output) are sliced into consecutive
/// `block_rows`-row chunks in key order, with ordinal-based task keys.
pub fn parse_q2_side(records: &[Record], block_rows: usize) -> Result<HashMap<Vec<u8>, Matrix>> {
    ensure!(!records.is_empty(), "empty Q2 side file");
    let mut out = HashMap::new();
    if is_block_record(&records[0].value) {
        for rec in records {
            let (_, m) = decode_block(&rec.value)?;
            out.insert(rec.key.clone(), m);
        }
        return Ok(out);
    }
    // row layout: records are already key-sorted (global row ids)
    let cols = records[0].value.len() / 8;
    ensure!(
        records.len() % block_rows == 0,
        "row-layout Q2 of {} rows is not a multiple of block_rows {}",
        records.len(),
        block_rows
    );
    for (ordinal, chunk) in records.chunks(block_rows).enumerate() {
        let mut data = Vec::with_capacity(block_rows * cols);
        for rec in chunk {
            data.extend_from_slice(&decode_row(&rec.value));
        }
        out.insert(row_key(ordinal as u64), Matrix::from_rows(block_rows, cols, data));
    }
    Ok(out)
}

/// Read an n×n factor written as row records (e.g. the final R̃).
pub fn read_small_matrix(records: &[Record]) -> Result<Matrix> {
    let (m, _) = rows_to_block(records)?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::records::row_key;
    use crate::util::rng::Rng;

    #[test]
    fn block_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Matrix::gaussian(5, 3, &mut rng);
        let enc = encode_block(42, &m);
        assert!(is_block_record(&enc));
        let (fr, back) = decode_block(&enc).unwrap();
        assert_eq!(fr, 42);
        assert_eq!(back.data, m.data);
    }

    #[test]
    fn row_key_parsing() {
        assert_eq!(parse_row_key(&row_key(0)).unwrap(), 0);
        assert_eq!(parse_row_key(&row_key(12345)).unwrap(), 12345);
        assert!(parse_row_key(b"not a key").is_err());
    }

    #[test]
    fn rows_to_block_contiguous() {
        let mut rng = Rng::new(2);
        let m = Matrix::gaussian(4, 2, &mut rng);
        let recs: Vec<Record> = (0..4)
            .map(|i| Record::new(row_key(10 + i as u64), encode_row(m.row(i))))
            .collect();
        let (back, first) = rows_to_block(&recs).unwrap();
        assert_eq!(first, 10);
        assert_eq!(back.data, m.data);
    }

    #[test]
    fn q2_side_block_layout() {
        let mut rng = Rng::new(3);
        let m0 = Matrix::gaussian(3, 3, &mut rng);
        let m1 = Matrix::gaussian(3, 3, &mut rng);
        let recs = vec![
            Record::new(row_key(0), encode_block(0, &m0)),
            Record::new(row_key(1), encode_block(3, &m1)),
        ];
        let map = parse_q2_side(&recs, 3).unwrap();
        assert_eq!(map[&row_key(0)].data, m0.data);
        assert_eq!(map[&row_key(1)].data, m1.data);
    }

    #[test]
    fn q2_side_row_layout() {
        let mut rng = Rng::new(4);
        let q = Matrix::gaussian(6, 2, &mut rng); // 3 blocks of 2 rows
        let recs: Vec<Record> = (0..6)
            .map(|i| Record::new(row_key(i as u64), encode_row(q.row(i))))
            .collect();
        let map = parse_q2_side(&recs, 2).unwrap();
        assert_eq!(map.len(), 3);
        assert_eq!(map[&row_key(1)].data, q.slice_rows(2, 4).data);
    }

    #[test]
    fn q2_side_row_layout_rejects_ragged() {
        let recs: Vec<Record> = (0..5)
            .map(|i| Record::new(row_key(i as u64), encode_row(&[0.0, 0.0])))
            .collect();
        assert!(parse_q2_side(&recs, 2).is_err());
    }

    #[test]
    fn emit_rows_keys() {
        let mut em = crate::mapreduce::Emitter::new();
        let m = Matrix::identity(2);
        emit_rows(&mut em, 7, &m);
        assert_eq!(em.main.len(), 2);
        assert_eq!(em.main[0].key, row_key(7));
        assert_eq!(em.main[1].key, row_key(8));
    }
}
