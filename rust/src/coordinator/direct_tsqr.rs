//! **Direct TSQR** — the paper's contribution (§III-B, Fig. 5), plus the
//! recursive extension (Alg. 2) and the SVD modification.
//!
//! Three steps, two map functions + one reduce function:
//!
//! 1. *map-only*: each task factors its block `A_i = Q_i R_i`, writing
//!    `Q_i` and `R_i` to **separate files** (the "feathers" pattern),
//!    keyed by task id.
//! 2. *single reduce*: gathers all `R_i` (ordered by key — "the kth key
//!    in the list corresponds to rows (k−1)n+1 to kn"), factors the
//!    stack `[R_1; …; R_m1] = [Q²_1; …; Q²_m1] R̃`, and emits each `Q²_i`
//!    keyed by its originating task plus the final `R̃`.
//! 3. *map-only*: reads the `Q_i` file with the step-2 output as a
//!    distributed-cache side file ("redundant parsing allows us to skip
//!    the shuffle"), emitting `Q` rows = `Q_i · Q²_i`.
//!
//! **Recursion** (Alg. 2): when `m1·n` exceeds the gather limit the
//! stacked-R matrix is re-exported as a row file and Direct TSQR is
//! invoked on it; its `Q` output (row layout) plugs straight into step 3
//! as the `Q²` side file.
//!
//! **SVD** (§III-B末): step 2 additionally factors `R̃ = U Σ Vᵀ`
//! (serial Jacobi on n×n) and — on the fast path — multiplies `Q²_i U`
//! before emitting, so step 3 directly produces `QU` with no extra pass:
//! `A = (QU) Σ Vᵀ`.

use super::io::{
    decode_block, encode_block, parse_q2_side, read_small_matrix, rows_to_block,
};
use super::{Coordinator, MatrixHandle};
use crate::dfs::records::{encode_row, row_key, Record};
use crate::linalg::{jacobi_svd, Matrix};
use crate::mapreduce::{Emitter, JobSpec, JobStats, KeyGroup, MapTask, ReduceTask};
use crate::runtime::BlockCompute;
use anyhow::{anyhow, bail, ensure, Result};

/// Options for a Direct TSQR run.
#[derive(Debug, Clone, Copy)]
pub struct DirectOpts {
    /// Also compute the SVD (`R̃ = UΣVᵀ`, step 3 emits `QU`).
    pub compute_svd: bool,
    /// Maximum recursion depth for Alg. 2 (safety bound).
    pub max_depth: usize,
}

impl Default for DirectOpts {
    fn default() -> Self {
        DirectOpts { compute_svd: false, max_depth: 8 }
    }
}

/// Σ and V from the TSVD extension.
#[derive(Debug, Clone)]
pub struct SvdParts {
    pub sigma: Vec<f64>,
    pub v: Matrix,
}

/// Output of a Direct TSQR run.
#[derive(Debug)]
pub struct DirectOutput {
    /// Q (or QU when `compute_svd`), row layout, aligned with the input.
    pub q: MatrixHandle,
    /// The final upper-triangular factor R̃.
    pub r: Matrix,
    pub svd: Option<SvdParts>,
    pub stats: JobStats,
}

// ---------------------------------------------------------------- step 1

struct Step1Map<'a> {
    compute: &'a dyn BlockCompute,
    /// Factor through the κ-gated mixed-precision path (Auto opt-in,
    /// depth 0 only — the recursive levels refactor tiny R stacks where
    /// full precision is essentially free).
    mixed: bool,
}

/// How many consecutive step-1 blocks one `factor_blocks` dispatch
/// amortizes. Any value gives bit-identical results (the batched entry
/// point's contract); 8 keeps a chunk's inputs + factors comfortably in
/// cache for paper-sized blocks.
const STEP1_BATCH: usize = 8;

impl Step1Map<'_> {
    /// Zero-pad a short block (rows < cols) up to square — exact, see
    /// `runtime::pad` — returning the padded block and the original
    /// row count.
    fn padded(a: Matrix) -> (Matrix, usize) {
        let rows = a.rows;
        if a.rows >= a.cols {
            (a, rows)
        } else {
            let pad = Matrix::zeros(a.cols - a.rows, a.cols);
            (Matrix::vstack(&[&a, &pad]), rows)
        }
    }

    /// Emit one factored block: R_i to the default channel (step-2
    /// input), Q_i to the side file. The Q record carries 32 bytes of
    /// row-key filler per row so the on-disk bytes match the paper's
    /// Table III (`8mn + Km` of Q data in step 1's writes and step 3's
    /// reads).
    fn emit_factors(
        task_id: usize,
        first_row: u64,
        orig_rows: usize,
        q: &Matrix,
        r: &Matrix,
        out: &mut Emitter,
    ) {
        let q_slice;
        let q = if q.rows > orig_rows {
            q_slice = q.slice_rows(0, orig_rows);
            &q_slice
        } else {
            q
        };
        out.emit(row_key(task_id as u64), encode_block(0, r));
        out.emit_to(
            "q1",
            row_key(task_id as u64),
            super::io::encode_block_with_filler(first_row, q, 32 * q.rows),
        );
    }
}

impl MapTask for Step1Map<'_> {
    fn run(&self, task_id: usize, input: &[Record], _side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        let (a, first_row) = rows_to_block(input)?;
        // blocks shorter than n: zero-pad rows (exact; see runtime::pad)
        let (a, orig_rows) = Self::padded(a);
        let (q, r) = if self.mixed { self.compute.qr_mixed(&a)? } else { self.compute.qr(&a)? };
        Self::emit_factors(task_id, first_row, orig_rows, &q, &r, out);
        Ok(())
    }

    fn batch_hint(&self) -> usize {
        // the mixed path is per-block anyway (see run_batch), so keep
        // its dispatch unbatched
        if self.mixed {
            1
        } else {
            STEP1_BATCH
        }
    }

    fn run_batch(
        &self,
        first_id: usize,
        inputs: &[&[Record]],
        _side: &[&[Record]],
        outs: &mut [Emitter],
    ) -> Result<()> {
        let mut blocks = Vec::with_capacity(inputs.len());
        let mut metas = Vec::with_capacity(inputs.len());
        for input in inputs {
            let (a, first_row) = rows_to_block(input)?;
            let (a, orig_rows) = Self::padded(a);
            blocks.push(a);
            metas.push((first_row, orig_rows));
        }
        let factors = self.compute.factor_blocks(&blocks)?;
        ensure!(factors.len() == blocks.len(), "factor_blocks returned a short batch");
        for (k, ((q, r), &(first_row, orig_rows))) in factors.iter().zip(&metas).enumerate() {
            Self::emit_factors(first_id + k, first_row, orig_rows, q, r, &mut outs[k]);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- step 2

struct IdentityMap;

impl MapTask for IdentityMap {
    fn run(&self, _id: usize, input: &[Record], _side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        for rec in input {
            out.emit(rec.key.clone(), rec.value.clone());
        }
        Ok(())
    }
}

struct Step2Reduce<'a> {
    compute: &'a dyn BlockCompute,
    cols: usize,
    compute_svd: bool,
}

impl ReduceTask for Step2Reduce<'_> {
    fn run(&self, partition: &[KeyGroup], out: &mut Emitter) -> Result<()> {
        // ordered list of keys = ordered list of R_i blocks
        let mut blocks = Vec::with_capacity(partition.len());
        for (key, values) in partition {
            ensure!(values.len() == 1, "duplicate R block for key {key:?}");
            let (_, r_i) = decode_block(&values[0])?;
            ensure!(r_i.cols == self.cols, "R block width mismatch");
            blocks.push((key.clone(), r_i));
        }
        let refs: Vec<&Matrix> = blocks.iter().map(|(_, m)| m).collect();
        let stacked = Matrix::vstack(&refs);
        let (q2, r) = if stacked.rows >= stacked.cols {
            self.compute.qr(&stacked)?
        } else {
            let pad = Matrix::zeros(stacked.cols - stacked.rows, stacked.cols);
            let (qp, r) = self.compute.qr(&Matrix::vstack(&[&stacked, &pad]))?;
            (qp.slice_rows(0, stacked.rows), r)
        };

        // SVD extension: R̃ = U Σ Vᵀ; fold U into the emitted Q² blocks
        let u = if self.compute_svd {
            let svd = jacobi_svd(&r);
            out.emit_to("svd", b"sigma".to_vec(), encode_row(&svd.sigma));
            out.emit_to("svd", b"v".to_vec(), encode_block(0, &svd.v));
            Some(svd.u)
        } else {
            None
        };

        // emit Q²_i per originating task (optionally ·U), and R̃ rows
        let mut offset = 0usize;
        for (key, r_i) in &blocks {
            let mut q2_i = q2.slice_rows(offset, offset + r_i.rows);
            if let Some(u) = &u {
                q2_i = self.compute.matmul(&q2_i, u)?;
            }
            out.emit_to("q2", key.clone(), encode_block(offset as u64, &q2_i));
            offset += r_i.rows;
        }
        for j in 0..r.rows {
            out.emit(row_key(j as u64), encode_row(r.row(j)));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- step 3

struct Step3Map<'a> {
    compute: &'a dyn BlockCompute,
    cols: usize,
    /// Parsed-side-file cache. In Hadoop every task re-parses the Q²
    /// distributed-cache file (the paper's "redundant parsing") — the
    /// engine *charges* that read per task, but since all tasks run in
    /// this process we parse once to keep wall time proportional.
    /// `Mutex<Option<Arc>>` rather than `OnceLock` because parsing can
    /// fail and `OnceLock::get_or_try_init` is unstable; holding the
    /// lock across the parse means concurrent tasks on the host pool
    /// wait for the one parse instead of duplicating it.
    q2_cache: std::sync::Mutex<Option<std::sync::Arc<std::collections::HashMap<Vec<u8>, Matrix>>>>,
}

impl Step3Map<'_> {
    fn q2(
        &self,
        side: &[Record],
    ) -> Result<std::sync::Arc<std::collections::HashMap<Vec<u8>, Matrix>>> {
        let mut cache = self.q2_cache.lock().expect("q2 cache");
        if let Some(map) = cache.as_ref() {
            return Ok(map.clone());
        }
        let map = std::sync::Arc::new(parse_q2_side(side, self.cols)?);
        *cache = Some(map.clone());
        Ok(map)
    }
}

impl MapTask for Step3Map<'_> {
    fn run(&self, _id: usize, input: &[Record], side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        ensure!(side.len() == 1, "step 3 wants the Q² side file");
        let q2map = self.q2(side[0])?;
        for rec in input {
            let (first_row, q1) = decode_block(&rec.value)?;
            let q2 = q2map
                .get(&rec.key)
                .ok_or_else(|| anyhow!("no Q² block for task key {:?}", rec.key))?;
            let q = self.compute.matmul(&q1, q2)?;
            super::io::emit_rows(out, first_row, &q);
        }
        Ok(())
    }
}

// ------------------------------------------------------------- pipeline

/// Run Direct TSQR on `input`, recursing per Alg. 2 when the stacked R
/// factors exceed the gather limit.
pub fn direct_tsqr(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    opts: &DirectOpts,
) -> Result<DirectOutput> {
    direct_tsqr_level(coord, input, opts, 0)
}

fn direct_tsqr_level(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    opts: &DirectOpts,
    depth: usize,
) -> Result<DirectOutput> {
    if depth >= opts.max_depth {
        bail!("direct TSQR recursion exceeded max depth {}", opts.max_depth);
    }
    let n = input.cols;
    let mut stats = JobStats::default();

    // ---- step 1: map-only local QR, Q and R to separate files ----
    let r1_file = coord.tmp("direct-r1");
    let q1_file = coord.tmp("direct-q1");
    let map_tasks = coord.map_tasks_for(input.rows);
    // Q data is O(m·n) and inherits the input's virtual byte scale; the
    // R factors are O(m1·n²) metadata and stay at scale 1 (DESIGN.md §2).
    let data_scale = coord.dfs(|d| d.scale(&input.file));
    {
        let mapper = Step1Map { compute: coord.compute, mixed: coord.mixed_step1 && depth == 0 };
        let spec = JobSpec::map_only(
            &format!("direct-step1(d{depth})"),
            &input.file,
            map_tasks,
            &mapper,
            &r1_file,
        )
        .with_scaled_side_output("q1", &q1_file, data_scale);
        stats.push(coord.run_step(&spec)?);
    }
    let m1 = coord.dfs(|d| d.file_records(&r1_file))?;
    let stacked_rows = m1 * n;
    let gather_limit = coord
        .opts
        .gather_limit
        .unwrap_or_else(|| coord.compute.max_qr_rows(n))
        .max(2 * n); // always allow at least a trivial gather

    let (q2_file, r, svd) = if stacked_rows > gather_limit && m1 > 1 {
        // ---- Alg. 2: recurse on the stacked R factors ----
        let spill = coord.tmp("direct-spill");
        let (spill_stats, spill_rows) = spill_r1_to_rows(coord, &r1_file, &spill, n)?;
        stats.push(spill_stats);
        let sub_input = MatrixHandle::new(&spill, spill_rows, n);
        // Re-block at the gather limit: each recursive task must compress
        // many R factors into one (a block of b rows emits an n-row R, so
        // b must exceed n for the stack to shrink — blocks of
        // gather_limit rows guarantee geometric reduction per level).
        let saved_rpt = coord.opts.rows_per_task;
        coord.opts.rows_per_task = gather_limit;
        let sub = direct_tsqr_level(coord, &sub_input, opts, depth + 1);
        coord.opts.rows_per_task = saved_rpt;
        let sub = sub?;
        stats.extend(sub.stats);
        (sub.q.file, sub.r, sub.svd)
    } else {
        // ---- step 2: identity map + single reduce over all R_i ----
        let r2_file = coord.tmp("direct-r2");
        let q2_file = coord.tmp("direct-q2");
        let svd_file = coord.tmp("direct-svd");
        {
            let id = IdentityMap;
            let reducer = Step2Reduce {
                compute: coord.compute,
                cols: n,
                compute_svd: opts.compute_svd,
            };
            let spec = JobSpec::map_reduce(
                &format!("direct-step2(d{depth})"),
                &r1_file,
                m1.min(coord.opts.reduce_tasks).max(1),
                &id,
                &reducer,
                1,
                &r2_file,
            )
            .with_side_output("q2", &q2_file)
            .with_side_output("svd", &svd_file);
            stats.push(coord.run_step(&spec)?);
        }
        let r = coord.dfs(|d| d.get(&r2_file).and_then(read_small_matrix))?;
        ensure!(r.rows == n && r.cols == n, "R̃ is {}x{}", r.rows, r.cols);
        let svd = if opts.compute_svd {
            Some(read_svd_parts(coord, &svd_file)?)
        } else {
            None
        };
        (q2_file, r, svd)
    };

    // ---- step 3: map-only Q_i · Q²_i with the side file ----
    let q_file = coord.tmp("direct-q");
    {
        let mapper = Step3Map {
            compute: coord.compute,
            cols: n,
            q2_cache: std::sync::Mutex::new(None),
        };
        let q1_records = coord.dfs(|d| d.file_records(&q1_file))?;
        let spec = JobSpec::map_only(
            &format!("direct-step3(d{depth})"),
            &q1_file,
            q1_records, // one map task per first-step block
            &mapper,
            &q_file,
        )
        .with_side_input(&q2_file)
        .with_output_scale(data_scale);
        stats.push(coord.run_step(&spec)?);
    }

    Ok(DirectOutput {
        q: MatrixHandle::new(&q_file, input.rows, n),
        r,
        svd,
        stats,
    })
}

/// Re-export the step-1 R blocks as a row file (input of the recursive
/// level). Charged as a leader pass over the R file.
fn spill_r1_to_rows(
    coord: &mut Coordinator,
    r1_file: &str,
    out_file: &str,
    n: usize,
) -> Result<(crate::mapreduce::StepStats, usize)> {
    let (rows, read_bytes) = coord.dfs(|dfs| -> Result<(Vec<Vec<u8>>, u64)> {
        let mut rows = Vec::new();
        let mut read_bytes = 0u64;
        for rec in dfs.get(r1_file)? {
            read_bytes += rec.size_bytes();
            let (_, r_i) = decode_block(&rec.value)?;
            ensure!(r_i.cols == n, "R block width");
            for j in 0..r_i.rows {
                rows.push(encode_row(r_i.row(j)));
            }
        }
        Ok((rows, read_bytes))
    })?;
    let records: Vec<Record> = rows
        .into_iter()
        .enumerate()
        .map(|(i, v)| Record::new(row_key(i as u64), v))
        .collect();
    let nrows = records.len();
    let write_bytes: u64 = records.iter().map(|r| r.size_bytes()).sum();
    coord.dfs_mut(|dfs| dfs.put(out_file, records));

    let model = coord.model();
    let mut s = crate::mapreduce::StepStats {
        name: "direct-spill".into(),
        map_tasks: 1,
        ..Default::default()
    };
    s.map_io.add_read(read_bytes, 0);
    s.map_io.add_write(write_bytes, nrows as u64);
    s.virtual_secs = model.read_secs(read_bytes)
        + model.write_secs(write_bytes)
        + model.task_startup_secs;
    Ok((s, nrows))
}

fn read_svd_parts(coord: &Coordinator, svd_file: &str) -> Result<SvdParts> {
    let (sigma, v) = coord.dfs(|dfs| -> Result<(Option<Vec<f64>>, Option<Matrix>)> {
        let mut sigma = None;
        let mut v = None;
        for rec in dfs.get(svd_file)? {
            match rec.key.as_slice() {
                b"sigma" => sigma = Some(crate::dfs::records::decode_row(&rec.value)),
                b"v" => v = Some(decode_block(&rec.value)?.1),
                other => bail!("unexpected svd record key {other:?}"),
            }
        }
        Ok((sigma, v))
    })?;
    Ok(SvdParts {
        sigma: sigma.ok_or_else(|| anyhow!("missing sigma record"))?,
        v: v.ok_or_else(|| anyhow!("missing V record"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix_with_condition;
    use crate::mapreduce::{ClusterConfig, Engine};
    use crate::runtime::NativeRuntime;
    use crate::util::rng::Rng;
    use crate::workload::{get_matrix, put_matrix};

    fn coord_with(a: &Matrix) -> (Coordinator<'static>, MatrixHandle) {
        let mut engine = Engine::new(crate::dfs::DiskModel::icme_like(), ClusterConfig::default());
        put_matrix(&mut engine.dfs, "A", a);
        (Coordinator::new(engine, NativeRuntime::oracle()), MatrixHandle::new("A", a.rows, a.cols))
    }

    fn check_qr(a: &Matrix, coord: &Coordinator, out: &DirectOutput, tol: f64) {
        let q = coord.dfs(|d| get_matrix(d, &out.q.file, a.cols)).unwrap();
        assert_eq!(q.rows, a.rows);
        assert!(q.orthogonality_error() < tol, "orth {}", q.orthogonality_error());
        let recon = a.sub(&q.matmul(&out.r)).frob_norm() / a.frob_norm();
        assert!(recon < tol, "recon {recon}");
        assert!(out.r.is_upper_triangular(1e-12 * out.r.max_abs()));
    }

    #[test]
    fn three_step_factorization() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(500, 6, &mut rng);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 64;
        let out = direct_tsqr(&mut coord, &h, &DirectOpts::default()).unwrap();
        check_qr(&a, &coord, &out, 1e-12);
        // 3 engine steps, no recursion
        assert_eq!(out.stats.steps.len(), 3);
    }

    #[test]
    fn stable_at_extreme_condition() {
        // the headline claim: orthogonal Q at kappa = 1e15
        let mut rng = Rng::new(2);
        let a = matrix_with_condition(600, 10, 1e15, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let out = direct_tsqr(&mut coord, &h, &DirectOpts::default()).unwrap();
        let q = coord.dfs(|d| get_matrix(d, &out.q.file, 10)).unwrap();
        assert!(q.orthogonality_error() < 1e-13, "orth {}", q.orthogonality_error());
    }

    #[test]
    fn recursive_path_matches() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(512, 4, &mut rng);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 16; // 32 tasks -> 128 stacked rows
        coord.opts.gather_limit = Some(32); // force recursion (>= 2n)
        let out = direct_tsqr(&mut coord, &h, &DirectOpts::default()).unwrap();
        check_qr(&a, &coord, &out, 1e-12);
        // recursion shows up as extra steps
        assert!(out.stats.steps.len() > 3, "steps: {}", out.stats.steps.len());
        assert!(out.stats.steps.iter().any(|s| s.name.contains("d1")));
    }

    #[test]
    fn svd_extension_reconstructs() {
        let mut rng = Rng::new(4);
        let sigma_true: Vec<f64> = (0..5).map(|i| 2.0f64.powi(-(i as i32))).collect();
        let (a, _, _) = crate::linalg::matgen::matrix_with_spectrum(200, 5, &sigma_true, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let opts = DirectOpts { compute_svd: true, ..Default::default() };
        let out = direct_tsqr(&mut coord, &h, &opts).unwrap();
        let svd = out.svd.as_ref().unwrap();
        for (got, want) in svd.sigma.iter().zip(&sigma_true) {
            assert!((got / want - 1.0).abs() < 1e-10, "sigma {got} vs {want}");
        }
        // A = (QU) Σ Vᵀ
        let qu = coord.dfs(|d| get_matrix(d, &out.q.file, 5)).unwrap();
        assert!(qu.orthogonality_error() < 1e-12);
        let mut qus = qu.clone();
        for j in 0..5 {
            for i in 0..qus.rows {
                qus[(i, j)] *= svd.sigma[j];
            }
        }
        let recon = a.sub(&qus.matmul(&svd.v.transpose())).frob_norm() / a.frob_norm();
        assert!(recon < 1e-11, "recon {recon}");
    }

    #[test]
    fn single_block_degenerate() {
        // whole matrix in one task: step 2 gets one R block
        let mut rng = Rng::new(5);
        let a = Matrix::gaussian(64, 4, &mut rng);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 1000;
        let out = direct_tsqr(&mut coord, &h, &DirectOpts::default()).unwrap();
        check_qr(&a, &coord, &out, 1e-12);
    }

    #[test]
    fn step_names_match_paper_structure() {
        let mut rng = Rng::new(6);
        let a = Matrix::gaussian(200, 4, &mut rng);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 50;
        let out = direct_tsqr(&mut coord, &h, &DirectOpts::default()).unwrap();
        let names: Vec<&str> = out.stats.steps.iter().map(|s| s.name.as_str()).collect();
        assert!(names[0].contains("step1"));
        assert!(names[1].contains("step2"));
        assert!(names[2].contains("step3"));
        // step 1 and step 3 are map-only
        assert_eq!(out.stats.steps[0].reduce_tasks, 0);
        assert_eq!(out.stats.steps[2].reduce_tasks, 0);
        assert_eq!(out.stats.steps[1].reduce_tasks, 1);
        assert_eq!(out.stats.steps[1].distinct_keys, 4); // m1 = 4 tasks
    }
}
