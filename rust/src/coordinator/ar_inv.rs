//! Indirect `Q` computation: `Q = A·R⁻¹`, optionally with one step of
//! iterative refinement (paper §II-C, Fig. 3).
//!
//! `R⁻¹` is inverted serially on the leader (n×n, cheap) and broadcast
//! to the map tasks as a distributed-cache side input; each task forms
//! `A_p·R⁻¹` through the `matmul` artifact. Not backward stable: the
//! error in `‖QᵀQ−I‖` scales with cond(A). One refinement sweep —
//! re-running the same R-factorization on the computed `Q` and
//! multiplying by the *new* inverse — pushes the error back to ~ε until
//! cond(A) ≈ 1e16 (Fig. 6).

use super::io::{decode_block, encode_block, rows_to_block};
use super::{cholesky_qr, indirect_tsqr, Coordinator, MatrixHandle, RFactorMethod};
use crate::dfs::records::{row_key, Record};
use crate::linalg::{tri_inverse_upper, Matrix};
use crate::mapreduce::{Emitter, JobSpec, JobStats, MapTask};
use crate::runtime::BlockCompute;
use anyhow::{anyhow, ensure, Result};

/// Map: `Q_p = A_p · R⁻¹` with `R⁻¹` from the side channel.
struct ApplyRinvMap<'a> {
    compute: &'a dyn BlockCompute,
}

impl MapTask for ApplyRinvMap<'_> {
    fn run(&self, _id: usize, input: &[Record], side: &[&[Record]], out: &mut Emitter) -> Result<()> {
        ensure!(side.len() == 1 && side[0].len() == 1, "expected one R⁻¹ side record");
        let (_, rinv) = decode_block(&side[0][0].value)?;
        let (a, first_row) = rows_to_block(input)?;
        let q = self.compute.matmul(&a, &rinv)?;
        super::io::emit_rows(out, first_row, &q);
        Ok(())
    }
}

/// One `A·R⁻¹` product pass: returns the Q handle.
pub fn apply_rinv(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    r: &Matrix,
    out_file: &str,
) -> Result<(MatrixHandle, JobStats)> {
    let mut stats = JobStats::default();
    let rinv = tri_inverse_upper(r)
        .ok_or_else(|| anyhow!("R is singular — A must be full-rank (paper assumption)"))?;
    let rinv_file = coord.tmp("rinv");
    let data_scale = coord.dfs_mut(|dfs| {
        dfs.put(&rinv_file, vec![Record::new(row_key(0), encode_block(0, &rinv))]);
        dfs.scale(&input.file)
    });

    let mapper = ApplyRinvMap { compute: coord.compute };
    let spec = JobSpec::map_only(
        "ar-inv",
        &input.file,
        coord.map_tasks_for(input.rows),
        &mapper,
        out_file,
    )
    .with_side_input(&rinv_file)
    .with_output_scale(data_scale);
    stats.push(coord.run_step(&spec)?);
    Ok((MatrixHandle::new(out_file, input.rows, input.cols), stats))
}

/// Full indirect-Q pipeline: `Q = A·R⁻¹`, plus an optional refinement
/// sweep that re-factors the computed `Q` with `method` and applies the
/// second inverse. Returns `(Q handle, updated R, stats)` — with
/// refinement the final factorization is `A = Q · (R₂·R₁)`.
pub fn q_via_rinv(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    r: &Matrix,
    refine: bool,
    method: RFactorMethod,
) -> Result<(MatrixHandle, Matrix, JobStats)> {
    let q_file = coord.tmp("q-indirect");
    let (q, mut stats) = apply_rinv(coord, input, r, &q_file)?;
    if !refine {
        return Ok((q, r.clone(), stats));
    }

    // refinement: factor the computed Q with the same method…
    let (r2, st) = match method {
        RFactorMethod::Cholesky => cholesky_qr::cholesky_r(coord, &q)?,
        RFactorMethod::IndirectTsqr => indirect_tsqr::indirect_r(coord, &q)?,
    };
    stats.extend(st);
    // …and multiply by the new inverse.
    let q2_file = coord.tmp("q-refined");
    let (q2, st2) = apply_rinv(coord, &q, &r2, &q2_file)?;
    stats.extend(st2);
    Ok((q2, r2.matmul(r), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix_with_condition;
    use crate::mapreduce::{ClusterConfig, Engine};
    use crate::runtime::NativeRuntime;
    use crate::util::rng::Rng;
    use crate::workload::{get_matrix, put_matrix};

    fn coord_with(a: &Matrix) -> (Coordinator<'static>, MatrixHandle) {
        let mut engine = Engine::new(crate::dfs::DiskModel::icme_like(), ClusterConfig::default());
        put_matrix(&mut engine.dfs, "A", a);
        (Coordinator::new(engine, NativeRuntime::oracle()), MatrixHandle::new("A", a.rows, a.cols))
    }

    fn recon_err(a: &Matrix, q: &Matrix, r: &Matrix) -> f64 {
        a.sub(&q.matmul(r)).frob_norm() / a.frob_norm()
    }

    #[test]
    fn well_conditioned_q_is_orthogonal() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(300, 6, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let (r, _) = indirect_tsqr::indirect_r(&mut coord, &h).unwrap();
        let (qh, r_out, _) = q_via_rinv(&mut coord, &h, &r, false, RFactorMethod::IndirectTsqr).unwrap();
        let q = coord.dfs(|d| get_matrix(d, &qh.file, 6)).unwrap();
        assert!(q.orthogonality_error() < 1e-10);
        assert!(recon_err(&a, &q, &r_out) < 1e-12);
    }

    #[test]
    fn ill_conditioned_q_loses_orthogonality_without_refinement() {
        let mut rng = Rng::new(2);
        let a = matrix_with_condition(400, 8, 1e10, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let (r, _) = indirect_tsqr::indirect_r(&mut coord, &h).unwrap();
        let (qh, _, _) = q_via_rinv(&mut coord, &h, &r, false, RFactorMethod::IndirectTsqr).unwrap();
        let q = coord.dfs(|d| get_matrix(d, &qh.file, 8)).unwrap();
        // error ~ kappa * eps >> 1e-10 (the paper's Fig. 6 phenomenon)
        assert!(q.orthogonality_error() > 1e-8, "err {}", q.orthogonality_error());
    }

    #[test]
    fn refinement_restores_orthogonality() {
        let mut rng = Rng::new(3);
        let a = matrix_with_condition(400, 8, 1e10, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let (r, _) = indirect_tsqr::indirect_r(&mut coord, &h).unwrap();
        let (qh, r_out, _) = q_via_rinv(&mut coord, &h, &r, true, RFactorMethod::IndirectTsqr).unwrap();
        let q = coord.dfs(|d| get_matrix(d, &qh.file, 8)).unwrap();
        assert!(q.orthogonality_error() < 1e-12, "err {}", q.orthogonality_error());
        assert!(recon_err(&a, &q, &r_out) < 1e-9);
    }

    #[test]
    fn singular_r_is_reported() {
        let mut rng = Rng::new(4);
        let a = Matrix::gaussian(50, 4, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let mut r = Matrix::identity(4);
        r[(2, 2)] = 0.0;
        assert!(apply_rinv(&mut coord, &h, &r, "qq").is_err());
    }
}
