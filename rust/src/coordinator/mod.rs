//! L3 coordinator — the paper's QR algorithms as MapReduce pipelines.
//!
//! Every algorithm consumes a [`MatrixHandle`] (a row-record file in the
//! simulated DFS) and drives one or more engine jobs whose task bodies
//! call [`crate::runtime::BlockCompute`] — i.e. the AOT-compiled
//! JAX/Pallas artifacts on the PJRT path, or the pure-rust oracle.
//!
//! | method | stability | passes |
//! |---|---|---|
//! | [`cholesky_qr`] (+`ar_inv`)  | `R` loses κ², breaks down κ≳1e8 | 1 (+2 for Q) |
//! | [`indirect_tsqr`] (+`ar_inv`)| stable `R`, `Q` loses κ        | 1 (+2 for Q) |
//! | either + iterative refinement| ~ε until κ≈1e16                | ×2 |
//! | [`direct_tsqr`] (this paper) | ε always                        | ~2+ε |
//! | [`householder`]              | ε, but 2n passes                | 2n |
//! | [`direct_tsqr`] with SVD     | ε                               | same as QR |

pub mod ar_inv;
pub mod cholesky_qr;
pub mod direct_tsqr;
pub mod fused;
pub mod householder;
pub mod indirect_tsqr;
pub mod io;

pub use direct_tsqr::{DirectOpts, DirectOutput, SvdParts};

use crate::dfs::{Dfs, DiskModel};
use crate::linalg::Matrix;
use crate::mapreduce::{Engine, JobSpec, JobStats, StepStats};
use crate::perfmodel::AlgoKind;
use crate::runtime::BlockCompute;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::sync::{Mutex, MutexGuard};

/// A tall-and-skinny matrix stored in the DFS (row records keyed by
/// 32-byte global row ids).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatrixHandle {
    pub file: String,
    pub rows: usize,
    pub cols: usize,
}

impl MatrixHandle {
    pub fn new(file: &str, rows: usize, cols: usize) -> Self {
        MatrixHandle { file: file.to_string(), rows, cols }
    }
}

/// Algorithm selector for [`Coordinator::qr`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Cholesky QR (Alg. 1) + `A·R⁻¹`; optionally one refinement sweep.
    Cholesky { refine: bool },
    /// Indirect TSQR (Constantine–Gleich) + `A·R⁻¹`; optional refinement.
    IndirectTsqr { refine: bool },
    /// The paper's 3-step Direct TSQR (recursive when the step-2 gather
    /// exceeds the runtime's block limit).
    DirectTsqr,
    /// The paper's §VI proposal: in-memory step 2 + fused recompute-Q
    /// step 3 (no Q₁ disk spill). See [`fused`].
    DirectTsqrFused,
    /// 2n-pass MapReduce Householder QR (R only — the paper's baseline).
    Householder,
    /// The randomized sketching family ([`crate::sketch`]): randomized
    /// range finder + truncated SVD for `Want::LowRank` requests,
    /// sketch-and-precondition least squares for `Want::Solve`. Not a
    /// QR pipeline — [`Coordinator::qr`] rejects it; dispatch happens
    /// in the session execution layer.
    Randomized,
}

impl Algorithm {
    pub fn kind(&self) -> AlgoKind {
        match self {
            Algorithm::Cholesky { refine: false } => AlgoKind::Cholesky,
            Algorithm::Cholesky { refine: true } => AlgoKind::CholeskyIr,
            Algorithm::IndirectTsqr { refine: false } => AlgoKind::IndirectTsqr,
            Algorithm::IndirectTsqr { refine: true } => AlgoKind::IndirectTsqrIr,
            Algorithm::DirectTsqr => AlgoKind::DirectTsqr,
            Algorithm::DirectTsqrFused => AlgoKind::DirectTsqrFused,
            Algorithm::Householder => AlgoKind::Householder,
            Algorithm::Randomized => AlgoKind::Randomized,
        }
    }

    pub fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// The canonical CLI spelling (inverse of [`Algorithm::parse`]).
    pub fn cli_name(&self) -> &'static str {
        match self {
            Algorithm::Cholesky { refine: false } => "cholesky",
            Algorithm::Cholesky { refine: true } => "cholesky-ir",
            Algorithm::IndirectTsqr { refine: false } => "indirect",
            Algorithm::IndirectTsqr { refine: true } => "indirect-ir",
            Algorithm::DirectTsqr => "direct",
            Algorithm::DirectTsqrFused => "direct-fused",
            Algorithm::Householder => "householder",
            Algorithm::Randomized => "randomized",
        }
    }

    /// Parse a CLI algorithm name (see [`Algorithm::cli_name`]).
    pub fn parse(s: &str) -> Result<Algorithm> {
        Ok(match s {
            "cholesky" => Algorithm::Cholesky { refine: false },
            "cholesky-ir" => Algorithm::Cholesky { refine: true },
            "indirect" => Algorithm::IndirectTsqr { refine: false },
            "indirect-ir" => Algorithm::IndirectTsqr { refine: true },
            "direct" => Algorithm::DirectTsqr,
            "direct-fused" => Algorithm::DirectTsqrFused,
            "householder" => Algorithm::Householder,
            "randomized" => Algorithm::Randomized,
            other => bail!(
                "unknown algorithm {other:?} (cholesky|cholesky-ir|indirect|indirect-ir|direct|direct-fused|householder|randomized)"
            ),
        })
    }

    pub const ALL: [Algorithm; 8] = [
        Algorithm::Cholesky { refine: false },
        Algorithm::IndirectTsqr { refine: false },
        Algorithm::Cholesky { refine: true },
        Algorithm::IndirectTsqr { refine: true },
        Algorithm::DirectTsqr,
        Algorithm::DirectTsqrFused,
        Algorithm::Householder,
        Algorithm::Randomized,
    ];
}

/// Result of a QR run: `R` always; `Q` unless the algorithm only
/// produces `R` (Householder baseline).
#[derive(Debug)]
pub struct QrResult {
    pub q: Option<MatrixHandle>,
    pub r: Matrix,
    pub stats: JobStats,
}

/// Tuning knobs shared by the pipelines.
#[derive(Debug, Clone, Copy)]
pub struct CoordOpts {
    /// Rows per step-1 map task (block size; padded to a manifest shape).
    pub rows_per_task: usize,
    /// Reduce tasks for shuffling stages (`r_max` by default).
    pub reduce_tasks: usize,
    /// Override for the step-2 gather limit (rows) — forces the
    /// recursive path when small. `None`: the runtime's `max_qr_rows`.
    pub gather_limit: Option<usize>,
    /// Panel width for the native backend's blocked Householder QR
    /// (`None`: [`crate::linalg::DEFAULT_PANEL`]). Pure speed knob —
    /// results are bit-identical at any width, so it rides outside the
    /// digest contract like `host_threads`.
    pub panel_block: Option<usize>,
    /// Allow the Auto policy to take the mixed-precision (f32-storage /
    /// f64-accumulate + one refinement step) step-1 path when the κ
    /// probe is within [`crate::linalg::MIXED_KAPPA_MAX`]. Off by
    /// default: enabling it changes result bits on the runs it fires
    /// for, and the decision is recorded in the step stats marker.
    pub mixed_precision: bool,
    /// Canonical leaf block height for streaming folds
    /// ([`crate::session::TsqrSession::stream`]). Part of the digest
    /// contract for *streamed* results (it shapes the fold tree, like
    /// `rows_per_task` shapes batch step 1) — but arrival chunking and
    /// every scheduling knob remain outside it.
    pub stream_chunk_rows: usize,
}

impl Default for CoordOpts {
    fn default() -> Self {
        CoordOpts {
            rows_per_task: 1000,
            reduce_tasks: 40,
            gather_limit: None,
            panel_block: None,
            mixed_precision: false,
            stream_chunk_rows: 1000,
        }
    }
}

/// How a [`Coordinator`] reaches its engine: exclusively owned (the
/// single-session path — identical semantics to the pre-service code),
/// or shared behind a `Mutex` with every other in-flight job of a
/// [`crate::service::TsqrService`] cluster. In the shared case the lock
/// is taken per *step* (one engine job, one DFS access), never across a
/// whole factorization, so concurrent jobs interleave their MapReduce
/// iterations on the common DFS.
enum EngineRef<'c> {
    /// Boxed to keep the variant pointer-sized next to `Shared`.
    Owned(Box<Engine>),
    Shared(&'c Mutex<Engine>),
}

/// Lock a shared engine, recovering from poison: the engine's state is
/// consistent between steps (a panicking job dies between two `run`
/// calls from the lock's perspective), and one job's panic must not
/// wedge every other job — or the owning service's accessors — on the
/// cluster.
pub(crate) fn lock_engine(m: &Mutex<Engine>) -> MutexGuard<'_, Engine> {
    m.lock().unwrap_or_else(|poison| poison.into_inner())
}

/// The coordinator: drives one factorization's pipelines against an
/// engine (owned, or shared with other in-flight jobs) and a borrowed
/// block-compute backend.
pub struct Coordinator<'c> {
    engine: EngineRef<'c>,
    pub compute: &'c dyn BlockCompute,
    pub opts: CoordOpts,
    /// Temp-file counter; [`crate::session`] threads it across requests
    /// so handles returned by earlier factorizations stay valid.
    pub(crate) seq: usize,
    /// DFS namespace prefix for every temp file this coordinator names
    /// (`job-<id>/` for service jobs, a session's configured namespace,
    /// or `""`). Keeps concurrent requests over one shared DFS from
    /// clobbering each other's intermediates.
    ns: String,
    /// Per-job fault stream. `None`: draws come from the engine's own
    /// RNG (single-session behavior, state threading across requests).
    /// `Some`: draws come from this job-private RNG, making them
    /// independent of how concurrent jobs interleave.
    fault_rng: Option<Rng>,
    /// Cached copy of the engine's disk model for leader-step cost
    /// formulas (avoids re-locking a shared engine for plain reads).
    model: DiskModel,
    /// Set by the Auto policy (never by fixed-algorithm requests) for
    /// the duration of one `run_fixed` call when `opts.mixed_precision`
    /// is on and the κ probe cleared the gate: depth-0 Direct TSQR
    /// step-1 maps then factor through
    /// [`crate::runtime::BlockCompute::qr_mixed`].
    pub(crate) mixed_step1: bool,
}

impl<'c> Coordinator<'c> {
    pub fn new(engine: Engine, compute: &'c dyn BlockCompute) -> Self {
        let model = engine.model;
        Coordinator {
            engine: EngineRef::Owned(Box::new(engine)),
            compute,
            opts: CoordOpts::default(),
            seq: 0,
            ns: String::new(),
            fault_rng: None,
            model,
            mixed_step1: false,
        }
    }

    /// A coordinator over a cluster-shared engine (see
    /// [`crate::service::TsqrService`]). The mutex is locked per step.
    pub fn shared(engine: &'c Mutex<Engine>, compute: &'c dyn BlockCompute) -> Self {
        let model = lock_engine(engine).model;
        Coordinator {
            engine: EngineRef::Shared(engine),
            compute,
            opts: CoordOpts::default(),
            seq: 0,
            ns: String::new(),
            fault_rng: None,
            model,
            mixed_step1: false,
        }
    }

    pub fn with_opts(mut self, opts: CoordOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Prefix every temp-file name with `ns` (the per-job / per-session
    /// DFS namespace).
    pub fn with_namespace(mut self, ns: impl Into<String>) -> Self {
        self.ns = ns.into();
        self
    }

    /// Draw fault outcomes from a job-private RNG instead of the
    /// engine's internal one (see [`Engine::run_with_rng`]).
    pub fn with_fault_rng(mut self, rng: Rng) -> Self {
        self.fault_rng = Some(rng);
        self
    }

    /// Run a closure with exclusive access to the engine (locks the
    /// cluster mutex on the shared path — keep the closure to one
    /// step's worth of work).
    pub fn with_engine<T>(&mut self, f: impl FnOnce(&mut Engine) -> T) -> T {
        match &mut self.engine {
            EngineRef::Owned(e) => f(e),
            EngineRef::Shared(m) => f(&mut lock_engine(m)),
        }
    }

    /// Read-only DFS access (locks the cluster mutex on the shared
    /// path for the closure's duration).
    pub fn dfs<T>(&self, f: impl FnOnce(&Dfs) -> T) -> T {
        match &self.engine {
            EngineRef::Owned(e) => f(&e.dfs),
            EngineRef::Shared(m) => f(&lock_engine(m).dfs),
        }
    }

    /// Mutable DFS access (same locking discipline as [`Self::dfs`]).
    pub fn dfs_mut<T>(&mut self, f: impl FnOnce(&mut Dfs) -> T) -> T {
        self.with_engine(|e| f(&mut e.dfs))
    }

    /// The engine's disk model (cached — no lock).
    pub fn model(&self) -> DiskModel {
        self.model
    }

    /// Run one MapReduce step on the engine, drawing faults from the
    /// job-private stream when one is set.
    pub fn run_step(&mut self, spec: &JobSpec) -> Result<StepStats> {
        let mut rng = self.fault_rng.take();
        let out = self.with_engine(|e| match rng.as_mut() {
            Some(r) => e.run_with_rng(spec, r),
            None => e.run(spec),
        });
        self.fault_rng = rng;
        out
    }

    /// Take the engine back out (single-session check-in; panics for
    /// cluster-shared coordinators, which never owned it).
    pub(crate) fn into_engine(self) -> Engine {
        match self.engine {
            EngineRef::Owned(e) => *e,
            EngineRef::Shared(_) => panic!("shared coordinators do not own their engine"),
        }
    }

    /// Fresh temp-file name inside this coordinator's namespace.
    pub(crate) fn tmp(&mut self, tag: &str) -> String {
        self.seq += 1;
        format!("{}tmp/{}-{:04}", self.ns, tag, self.seq)
    }

    pub(crate) fn map_tasks_for(&self, rows: usize) -> usize {
        rows.div_ceil(self.opts.rows_per_task).max(1)
    }

    /// Run `algo` on `input`, producing Q (where applicable) and R.
    pub fn qr(&mut self, input: &MatrixHandle, algo: Algorithm) -> Result<QrResult> {
        match algo {
            Algorithm::Cholesky { refine } => {
                let (r, mut stats) = cholesky_qr::cholesky_r(self, input)?;
                let (q, r, st) = ar_inv::q_via_rinv(self, input, &r, refine, RFactorMethod::Cholesky)?;
                stats.extend(st);
                Ok(QrResult { q: Some(q), r, stats })
            }
            Algorithm::IndirectTsqr { refine } => {
                let (r, mut stats) = indirect_tsqr::indirect_r(self, input)?;
                let (q, r, st) =
                    ar_inv::q_via_rinv(self, input, &r, refine, RFactorMethod::IndirectTsqr)?;
                stats.extend(st);
                Ok(QrResult { q: Some(q), r, stats })
            }
            Algorithm::DirectTsqr => {
                let out = direct_tsqr::direct_tsqr(self, input, &DirectOpts::default())?;
                Ok(QrResult { q: Some(out.q), r: out.r, stats: out.stats })
            }
            Algorithm::DirectTsqrFused => fused::direct_tsqr_fused(self, input),
            Algorithm::Householder => {
                let (r, stats) = householder::householder_r(self, input, None)?;
                Ok(QrResult { q: None, r, stats })
            }
            Algorithm::Randomized => bail!(
                "the randomized family serves LowRank/Solve requests, not QR (see crate::sketch)"
            ),
        }
    }

    /// Tall-and-skinny SVD via the Direct TSQR extension (paper §III-B):
    /// `A = (Q·U) Σ Vᵀ` with the `U` product fused into step 3.
    pub fn svd(&mut self, input: &MatrixHandle) -> Result<direct_tsqr::DirectOutput> {
        let opts = DirectOpts { compute_svd: true, ..Default::default() };
        direct_tsqr::direct_tsqr(self, input, &opts)
    }

    /// Singular values only (paper §III-B, last sentence): "it would be
    /// favorable to use the TSQR implementation from Sec. II-B to
    /// compute R" — one pass, then a serial n×n Jacobi SVD.
    pub fn singular_values(&mut self, input: &MatrixHandle) -> Result<(Vec<f64>, JobStats)> {
        let (r, stats) = indirect_tsqr::indirect_r(self, input)?;
        Ok((crate::linalg::jacobi_svd(&r).sigma, stats))
    }
}

/// Which R-factorization a refinement sweep re-uses (the paper refines
/// Cholesky QR with Cholesky QR, and Indirect TSQR with Indirect TSQR).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RFactorMethod {
    Cholesky,
    IndirectTsqr,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_cli_names_round_trip() {
        for algo in Algorithm::ALL {
            assert_eq!(Algorithm::parse(algo.cli_name()).unwrap(), algo, "{algo:?}");
        }
    }

    #[test]
    fn all_covers_every_variant() {
        // the CLI parses 8 names; ALL must expose the same 8 (the fused
        // §VI variant was historically missing)
        assert_eq!(Algorithm::ALL.len(), 8);
        assert!(Algorithm::ALL.contains(&Algorithm::DirectTsqrFused));
        assert!(Algorithm::ALL.contains(&Algorithm::Randomized));
        // no duplicates
        for (i, a) in Algorithm::ALL.iter().enumerate() {
            for b in &Algorithm::ALL[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn parse_rejects_unknown_names() {
        assert!(Algorithm::parse("qr").is_err());
        assert!(Algorithm::parse("").is_err());
        // `auto` is a session-layer concept, not a fixed algorithm
        assert!(Algorithm::parse("auto").is_err());
    }

    #[test]
    fn lock_engine_recovers_from_a_poisoned_cluster() {
        // a panicking job must not wedge other jobs or the service's
        // accessors: lock_engine strips the poison
        use crate::dfs::DiskModel;
        use crate::mapreduce::ClusterConfig;
        let m = Mutex::new(Engine::new(DiskModel::icme_like(), ClusterConfig::default()));
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = m.lock().unwrap();
            panic!("job dies while holding the engine");
        }));
        assert!(m.lock().is_err(), "mutex should be poisoned");
        let engine = lock_engine(&m);
        assert_eq!(engine.cluster.map_slots, 40, "engine reachable after poison");
    }

    #[test]
    fn tmp_names_carry_the_namespace() {
        use crate::dfs::DiskModel;
        use crate::mapreduce::ClusterConfig;
        use crate::runtime::NativeRuntime;
        let engine = Engine::new(DiskModel::icme_like(), ClusterConfig::default());
        let mut c = Coordinator::new(engine, NativeRuntime::oracle()).with_namespace("job-7/");
        assert_eq!(c.tmp("x"), "job-7/tmp/x-0001");
        assert_eq!(c.tmp("x"), "job-7/tmp/x-0002");
    }

    /// The latent collision the job service fixes: two request streams
    /// over ONE shared DFS both start their temp counters at zero, so
    /// without namespaces the second stream overwrites the first one's
    /// intermediates (and any Q handle pointing at them). Distinct
    /// namespaces keep every handle intact.
    #[test]
    fn namespaces_prevent_shared_dfs_temp_collisions() {
        use crate::dfs::DiskModel;
        use crate::mapreduce::ClusterConfig;
        use crate::runtime::NativeRuntime;
        use crate::util::rng::Rng;
        use crate::workload::{get_matrix, put_matrix};

        let mut engine = Engine::new(DiskModel::icme_like(), ClusterConfig::default());
        let mut rng = Rng::new(5);
        let a = Matrix::gaussian(200, 4, &mut rng);
        put_matrix(&mut engine.dfs, "A", &a);
        let h = MatrixHandle::new("A", a.rows, a.cols);
        let shared = Mutex::new(engine);

        // two independent "jobs", same request, same fresh seq counter
        let run = |ns: &str| {
            let mut c = Coordinator::shared(&shared, NativeRuntime::oracle()).with_namespace(ns);
            c.qr(&h, Algorithm::DirectTsqr).unwrap()
        };
        let res0 = run("job-0/");
        let q0_file = res0.q.as_ref().unwrap().file.clone();
        let q0 = {
            let e = shared.lock().unwrap();
            get_matrix(&e.dfs, &q0_file, a.cols).unwrap()
        };
        let res1 = run("job-1/");
        assert_ne!(q0_file, res1.q.as_ref().unwrap().file, "temp names must not collide");
        // job 0's Q is still byte-identical after job 1 ran: with a
        // shared namespace (the old `tmp/...` scheme) job 1's identical
        // seq-derived names would have overwritten it
        let e = shared.lock().unwrap();
        let q0_again = get_matrix(&e.dfs, &q0_file, a.cols).unwrap();
        assert_eq!(q0.data, q0_again.data);
        assert!(q0.orthogonality_error() < 1e-12);
    }
}
