//! L3 coordinator — the paper's QR algorithms as MapReduce pipelines.
//!
//! Every algorithm consumes a [`MatrixHandle`] (a row-record file in the
//! simulated DFS) and drives one or more engine jobs whose task bodies
//! call [`crate::runtime::BlockCompute`] — i.e. the AOT-compiled
//! JAX/Pallas artifacts on the PJRT path, or the pure-rust oracle.
//!
//! | method | stability | passes |
//! |---|---|---|
//! | [`cholesky_qr`] (+`ar_inv`)  | `R` loses κ², breaks down κ≳1e8 | 1 (+2 for Q) |
//! | [`indirect_tsqr`] (+`ar_inv`)| stable `R`, `Q` loses κ        | 1 (+2 for Q) |
//! | either + iterative refinement| ~ε until κ≈1e16                | ×2 |
//! | [`direct_tsqr`] (this paper) | ε always                        | ~2+ε |
//! | [`householder`]              | ε, but 2n passes                | 2n |
//! | [`direct_tsqr`] with SVD     | ε                               | same as QR |

pub mod ar_inv;
pub mod cholesky_qr;
pub mod direct_tsqr;
pub mod fused;
pub mod householder;
pub mod indirect_tsqr;
pub mod io;

pub use direct_tsqr::{DirectOpts, DirectOutput, SvdParts};

use crate::linalg::Matrix;
use crate::mapreduce::{Engine, JobStats};
use crate::perfmodel::AlgoKind;
use crate::runtime::BlockCompute;
use anyhow::{bail, Result};

/// A tall-and-skinny matrix stored in the DFS (row records keyed by
/// 32-byte global row ids).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MatrixHandle {
    pub file: String,
    pub rows: usize,
    pub cols: usize,
}

impl MatrixHandle {
    pub fn new(file: &str, rows: usize, cols: usize) -> Self {
        MatrixHandle { file: file.to_string(), rows, cols }
    }
}

/// Algorithm selector for [`Coordinator::qr`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Cholesky QR (Alg. 1) + `A·R⁻¹`; optionally one refinement sweep.
    Cholesky { refine: bool },
    /// Indirect TSQR (Constantine–Gleich) + `A·R⁻¹`; optional refinement.
    IndirectTsqr { refine: bool },
    /// The paper's 3-step Direct TSQR (recursive when the step-2 gather
    /// exceeds the runtime's block limit).
    DirectTsqr,
    /// The paper's §VI proposal: in-memory step 2 + fused recompute-Q
    /// step 3 (no Q₁ disk spill). See [`fused`].
    DirectTsqrFused,
    /// 2n-pass MapReduce Householder QR (R only — the paper's baseline).
    Householder,
}

impl Algorithm {
    pub fn kind(&self) -> AlgoKind {
        match self {
            Algorithm::Cholesky { refine: false } => AlgoKind::Cholesky,
            Algorithm::Cholesky { refine: true } => AlgoKind::CholeskyIr,
            Algorithm::IndirectTsqr { refine: false } => AlgoKind::IndirectTsqr,
            Algorithm::IndirectTsqr { refine: true } => AlgoKind::IndirectTsqrIr,
            Algorithm::DirectTsqr => AlgoKind::DirectTsqr,
            Algorithm::DirectTsqrFused => AlgoKind::DirectTsqrFused,
            Algorithm::Householder => AlgoKind::Householder,
        }
    }

    pub fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// The canonical CLI spelling (inverse of [`Algorithm::parse`]).
    pub fn cli_name(&self) -> &'static str {
        match self {
            Algorithm::Cholesky { refine: false } => "cholesky",
            Algorithm::Cholesky { refine: true } => "cholesky-ir",
            Algorithm::IndirectTsqr { refine: false } => "indirect",
            Algorithm::IndirectTsqr { refine: true } => "indirect-ir",
            Algorithm::DirectTsqr => "direct",
            Algorithm::DirectTsqrFused => "direct-fused",
            Algorithm::Householder => "householder",
        }
    }

    /// Parse a CLI algorithm name (see [`Algorithm::cli_name`]).
    pub fn parse(s: &str) -> Result<Algorithm> {
        Ok(match s {
            "cholesky" => Algorithm::Cholesky { refine: false },
            "cholesky-ir" => Algorithm::Cholesky { refine: true },
            "indirect" => Algorithm::IndirectTsqr { refine: false },
            "indirect-ir" => Algorithm::IndirectTsqr { refine: true },
            "direct" => Algorithm::DirectTsqr,
            "direct-fused" => Algorithm::DirectTsqrFused,
            "householder" => Algorithm::Householder,
            other => bail!(
                "unknown algorithm {other:?} (cholesky|cholesky-ir|indirect|indirect-ir|direct|direct-fused|householder)"
            ),
        })
    }

    pub const ALL: [Algorithm; 7] = [
        Algorithm::Cholesky { refine: false },
        Algorithm::IndirectTsqr { refine: false },
        Algorithm::Cholesky { refine: true },
        Algorithm::IndirectTsqr { refine: true },
        Algorithm::DirectTsqr,
        Algorithm::DirectTsqrFused,
        Algorithm::Householder,
    ];
}

/// Result of a QR run: `R` always; `Q` unless the algorithm only
/// produces `R` (Householder baseline).
#[derive(Debug)]
pub struct QrResult {
    pub q: Option<MatrixHandle>,
    pub r: Matrix,
    pub stats: JobStats,
}

/// Tuning knobs shared by the pipelines.
#[derive(Debug, Clone, Copy)]
pub struct CoordOpts {
    /// Rows per step-1 map task (block size; padded to a manifest shape).
    pub rows_per_task: usize,
    /// Reduce tasks for shuffling stages (`r_max` by default).
    pub reduce_tasks: usize,
    /// Override for the step-2 gather limit (rows) — forces the
    /// recursive path when small. `None`: the runtime's `max_qr_rows`.
    pub gather_limit: Option<usize>,
}

impl Default for CoordOpts {
    fn default() -> Self {
        CoordOpts { rows_per_task: 1000, reduce_tasks: 40, gather_limit: None }
    }
}

/// The coordinator: owns the engine, borrows the block-compute backend.
pub struct Coordinator<'c> {
    pub engine: Engine,
    pub compute: &'c dyn BlockCompute,
    pub opts: CoordOpts,
    /// Temp-file counter; [`crate::session`] threads it across requests
    /// so handles returned by earlier factorizations stay valid.
    pub(crate) seq: usize,
}

impl<'c> Coordinator<'c> {
    pub fn new(engine: Engine, compute: &'c dyn BlockCompute) -> Self {
        Coordinator { engine, compute, opts: CoordOpts::default(), seq: 0 }
    }

    pub fn with_opts(mut self, opts: CoordOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Fresh temp-file name.
    pub(crate) fn tmp(&mut self, tag: &str) -> String {
        self.seq += 1;
        format!("tmp/{}-{:04}", tag, self.seq)
    }

    pub(crate) fn map_tasks_for(&self, rows: usize) -> usize {
        rows.div_ceil(self.opts.rows_per_task).max(1)
    }

    /// Run `algo` on `input`, producing Q (where applicable) and R.
    pub fn qr(&mut self, input: &MatrixHandle, algo: Algorithm) -> Result<QrResult> {
        match algo {
            Algorithm::Cholesky { refine } => {
                let (r, mut stats) = cholesky_qr::cholesky_r(self, input)?;
                let (q, r, st) = ar_inv::q_via_rinv(self, input, &r, refine, RFactorMethod::Cholesky)?;
                stats.extend(st);
                Ok(QrResult { q: Some(q), r, stats })
            }
            Algorithm::IndirectTsqr { refine } => {
                let (r, mut stats) = indirect_tsqr::indirect_r(self, input)?;
                let (q, r, st) =
                    ar_inv::q_via_rinv(self, input, &r, refine, RFactorMethod::IndirectTsqr)?;
                stats.extend(st);
                Ok(QrResult { q: Some(q), r, stats })
            }
            Algorithm::DirectTsqr => {
                let out = direct_tsqr::direct_tsqr(self, input, &DirectOpts::default())?;
                Ok(QrResult { q: Some(out.q), r: out.r, stats: out.stats })
            }
            Algorithm::DirectTsqrFused => fused::direct_tsqr_fused(self, input),
            Algorithm::Householder => {
                let (r, stats) = householder::householder_r(self, input, None)?;
                Ok(QrResult { q: None, r, stats })
            }
        }
    }

    /// Tall-and-skinny SVD via the Direct TSQR extension (paper §III-B):
    /// `A = (Q·U) Σ Vᵀ` with the `U` product fused into step 3.
    pub fn svd(&mut self, input: &MatrixHandle) -> Result<direct_tsqr::DirectOutput> {
        let opts = DirectOpts { compute_svd: true, ..Default::default() };
        direct_tsqr::direct_tsqr(self, input, &opts)
    }

    /// Singular values only (paper §III-B, last sentence): "it would be
    /// favorable to use the TSQR implementation from Sec. II-B to
    /// compute R" — one pass, then a serial n×n Jacobi SVD.
    pub fn singular_values(&mut self, input: &MatrixHandle) -> Result<(Vec<f64>, JobStats)> {
        let (r, stats) = indirect_tsqr::indirect_r(self, input)?;
        Ok((crate::linalg::jacobi_svd(&r).sigma, stats))
    }
}

/// Which R-factorization a refinement sweep re-uses (the paper refines
/// Cholesky QR with Cholesky QR, and Indirect TSQR with Indirect TSQR).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RFactorMethod {
    Cholesky,
    IndirectTsqr,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_cli_names_round_trip() {
        for algo in Algorithm::ALL {
            assert_eq!(Algorithm::parse(algo.cli_name()).unwrap(), algo, "{algo:?}");
        }
    }

    #[test]
    fn all_covers_every_variant() {
        // the CLI parses 7 names; ALL must expose the same 7 (the fused
        // §VI variant was historically missing)
        assert_eq!(Algorithm::ALL.len(), 7);
        assert!(Algorithm::ALL.contains(&Algorithm::DirectTsqrFused));
        // no duplicates
        for (i, a) in Algorithm::ALL.iter().enumerate() {
            for b in &Algorithm::ALL[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn parse_rejects_unknown_names() {
        assert!(Algorithm::parse("qr").is_err());
        assert!(Algorithm::parse("").is_err());
        // `auto` is a session-layer concept, not a fixed algorithm
        assert!(Algorithm::parse("auto").is_err());
    }
}
