//! Table IV — stage parallelism: `p_j^m = min{m_max, m_j}`,
//! `p_j^r = min{r_max, r_j, k_j}`.

use super::counts::StepBytes;

/// Cluster slot limits (paper: m_max = r_max = 40).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageParallelism {
    pub m_max: u64,
    pub r_max: u64,
}

impl Default for StageParallelism {
    fn default() -> Self {
        StageParallelism { m_max: 40, r_max: 40 }
    }
}

impl StageParallelism {
    /// `p_j^m` for a step.
    pub fn map(&self, step: &StepBytes) -> u64 {
        self.m_max.min(step.m_tasks.max(1))
    }

    /// `p_j^r` for a step (1 when the step has no reduce traffic, so the
    /// zero-byte term is harmless).
    pub fn reduce(&self, step: &StepBytes) -> u64 {
        if step.r_tasks == 0 {
            return 1;
        }
        self.r_max.min(step.r_tasks).min(step.keys.max(1))
    }

    /// The paper's Table IV m_1 values (map tasks per workload): the
    /// direct method launches more tasks because it also writes Q.
    /// Returns (m1_indirect, m1_direct) for one of the five paper
    /// workloads, or None for other shapes.
    pub fn paper_m1(rows: u64, cols: u64) -> Option<(u64, u64)> {
        match (rows, cols) {
            (4_000_000_000, 4) => Some((1200, 2000)),
            (2_500_000_000, 10) => Some((1680, 2640)),
            (600_000_000, 25) => Some((1200, 1600)),
            (500_000_000, 50) => Some((1920, 2560)),
            (150_000_000, 100) => Some((1200, 1600)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(m_tasks: u64, r_tasks: u64, keys: u64) -> StepBytes {
        StepBytes { m_tasks, r_tasks, keys, ..Default::default() }
    }

    #[test]
    fn map_capped_by_slots() {
        let p = StageParallelism::default();
        assert_eq!(p.map(&step(1200, 0, 0)), 40);
        assert_eq!(p.map(&step(4, 0, 0)), 4);
    }

    #[test]
    fn reduce_capped_by_keys() {
        let p = StageParallelism::default();
        // Cholesky QR: n = 4 keys -> at most 4 reducers (paper §II-A)
        assert_eq!(p.reduce(&step(1200, 40, 4)), 4);
        assert_eq!(p.reduce(&step(1200, 40, 16800)), 40);
        assert_eq!(p.reduce(&step(1200, 1, 1680)), 1);
        assert_eq!(p.reduce(&step(1200, 0, 0)), 1);
    }

    #[test]
    fn paper_m1_table() {
        assert_eq!(StageParallelism::paper_m1(4_000_000_000, 4), Some((1200, 2000)));
        assert_eq!(StageParallelism::paper_m1(150_000_000, 100), Some((1200, 1600)));
        assert_eq!(StageParallelism::paper_m1(7, 7), None);
    }
}
