//! Table V — lower bounds `T_lb` from byte counts + parallelism + β.

use super::counts::{algorithm_steps, AlgoKind, WorkloadShape};
use super::parallelism::StageParallelism;

/// `T_lb` in seconds for one algorithm on one workload.
///
/// `beta_r`/`beta_w` are per-slot inverse bandwidths (seconds/byte) —
/// the same units as [`crate::dfs::DiskModel`]. Householder repeats its
/// column-step `n` times, as in the paper.
pub fn lower_bound_secs(
    algo: AlgoKind,
    shape: &WorkloadShape,
    par: &StageParallelism,
    beta_r: f64,
    beta_w: f64,
) -> f64 {
    let steps = algorithm_steps(algo, shape);
    let reps = if algo == AlgoKind::Householder { shape.n as f64 } else { 1.0 };
    let one_pass: f64 = steps
        .iter()
        .map(|s| {
            let map = (s.rm as f64 * beta_r + s.wm as f64 * beta_w) / par.map(s) as f64;
            let red = (s.rr as f64 * beta_r + s.wr as f64 * beta_w) / par.reduce(s) as f64;
            map + red
        })
        .sum();
    reps * one_pass
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper-fitted betas (Table II, ~overall): per-slot s/byte.
    const BETA_R: f64 = 1.6e-9 * 40.0;
    const BETA_W: f64 = 3.15e-9 * 40.0;

    fn bound(algo: AlgoKind, m: u64, n: u64, m1: u64) -> f64 {
        let s = WorkloadShape::new(m, n, m1);
        lower_bound_secs(algo, &s, &StageParallelism::default(), BETA_R, BETA_W)
    }

    #[test]
    fn table5_orderings_hold() {
        // For every paper workload: Chol == Indirect < Direct < IR < House.
        for &(m, n, m1, m1d) in &[
            (4_000_000_000u64, 4u64, 1200u64, 2000u64),
            (2_500_000_000, 10, 1680, 2640),
            (600_000_000, 25, 1200, 1600),
            (500_000_000, 50, 1920, 2560),
            (150_000_000, 100, 1200, 1600),
        ] {
            let chol = bound(AlgoKind::Cholesky, m, n, m1);
            let ind = bound(AlgoKind::IndirectTsqr, m, n, m1);
            let chol_ir = bound(AlgoKind::CholeskyIr, m, n, m1);
            let direct = bound(AlgoKind::DirectTsqr, m, n, m1d);
            let house = bound(AlgoKind::Householder, m, n, m1);
            assert!((chol / ind - 1.0).abs() < 0.05, "chol≈indirect at {m}x{n}");
            assert!(direct > chol, "direct > chol at {m}x{n}");
            assert!(direct < chol_ir * 1.05, "direct ≲ 2*chol at {m}x{n}");
            assert!(house > 2.0 * direct, "householder worst at {m}x{n}");
        }
    }

    #[test]
    fn table5_magnitudes_near_paper() {
        // Paper Table V: 2.5Bx10 -> Cholesky 1645s, Direct 2464s,
        // House 16448s. Our formulas + paper betas should land within
        // ~35% (the paper's own fits vary by workload).
        let chol = bound(AlgoKind::Cholesky, 2_500_000_000, 10, 1680);
        let direct = bound(AlgoKind::DirectTsqr, 2_500_000_000, 10, 2640);
        let house = bound(AlgoKind::Householder, 2_500_000_000, 10, 1680);
        assert!((chol / 1645.0 - 1.0).abs() < 0.35, "chol {chol}");
        assert!((direct / 2464.0 - 1.0).abs() < 0.35, "direct {direct}");
        assert!((house / 16448.0 - 1.0).abs() < 0.35, "house {house}");
    }

    #[test]
    fn householder_scales_with_n() {
        let h10 = bound(AlgoKind::Householder, 1_000_000_000, 10, 1200);
        let h100 = bound(AlgoKind::Householder, 100_000_000, 100, 1200);
        // same matrix volume, 10x the columns -> ~10x the bound
        assert!(h100 / h10 > 5.0);
    }

    #[test]
    fn zero_beta_zero_bound() {
        let s = WorkloadShape::new(1000, 4, 4);
        assert_eq!(
            lower_bound_secs(AlgoKind::Cholesky, &s, &StageParallelism::default(), 0.0, 0.0),
            0.0
        );
    }
}
