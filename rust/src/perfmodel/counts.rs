//! Table III — bytes read/written per step, per algorithm.
//!
//! Conventions from the paper: a double is 8 bytes, a row key is `K`
//! bytes (K = 32), `m` rows, `n` cols, `m_1`/`m_3` map-task counts for
//! steps 1/3, `r_1` reduce tasks for step 1. Householder is shown for
//! one column-step and repeated `n` times by the bound.

/// Workload + cluster-shape parameters entering the byte formulas.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadShape {
    /// rows
    pub m: u64,
    /// cols
    pub n: u64,
    /// key bytes (paper: 32)
    pub k: u64,
    /// map tasks in step 1 (Table IV)
    pub m1: u64,
    /// map tasks in step 3 (== m1 in the paper's configs)
    pub m3: u64,
    /// reduce tasks in step 1 (r_max for the TSQR tree)
    pub r1: u64,
}

impl WorkloadShape {
    pub fn new(m: u64, n: u64, m1: u64) -> Self {
        WorkloadShape { m, n, k: 32, m1, m3: m1, r1: 40 }
    }

    /// Matrix bytes on HDFS: `8mn + Km` (paper "HDFS Size").
    pub fn hdfs_bytes(&self) -> u64 {
        8 * self.m * self.n + self.k * self.m
    }

    /// Flop count the paper normalizes by: `2 m n²` (Table VII).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * (self.n as f64) * (self.n as f64)
    }
}

/// Bytes moved by one MapReduce iteration (`R/W` × `map/reduce`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepBytes {
    pub rm: u64,
    pub wm: u64,
    pub rr: u64,
    pub wr: u64,
    /// map tasks `m_j` of this step
    pub m_tasks: u64,
    /// reduce tasks `r_j` requested
    pub r_tasks: u64,
    /// distinct reduce keys `k_j`
    pub keys: u64,
}

/// Algorithm selector for the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    Cholesky,
    IndirectTsqr,
    CholeskyIr,
    IndirectTsqrIr,
    DirectTsqr,
    /// The paper's §VI proposal (in-memory step 2, no Q₁ spill).
    DirectTsqrFused,
    Householder,
    /// The PR 10 randomized family (modeled at `ℓ = max(n/4, 1)`):
    /// one fused sketch-project pass over `A`, a TSQR of the `m×ℓ`
    /// sketch, and an `m×ℓ` project-back pass.
    Randomized,
}

impl AlgoKind {
    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::Cholesky => "Cholesky",
            AlgoKind::IndirectTsqr => "Indirect TSQR",
            AlgoKind::CholeskyIr => "Cholesky+I.R.",
            AlgoKind::IndirectTsqrIr => "Indirect TSQR+I.R.",
            AlgoKind::DirectTsqr => "Direct TSQR",
            AlgoKind::DirectTsqrFused => "Direct TSQR (fused)",
            AlgoKind::Householder => "House.",
            AlgoKind::Randomized => "Randomized",
        }
    }

    /// The paper's six evaluated algorithms (the fused §VI variant is
    /// benchmarked separately as an ablation).
    pub const ALL: [AlgoKind; 6] = [
        AlgoKind::Cholesky,
        AlgoKind::IndirectTsqr,
        AlgoKind::CholeskyIr,
        AlgoKind::IndirectTsqrIr,
        AlgoKind::DirectTsqr,
        AlgoKind::Householder,
    ];
}

/// The `A·R⁻¹` product pass shared by the indirect methods (step 3 in
/// Table III): every map task reads the matrix split plus the broadcast
/// `R⁻¹` (`m_3(8n²+8n)` in aggregate) and rewrites the matrix.
fn ar_inv_step(s: &WorkloadShape) -> StepBytes {
    StepBytes {
        rm: 8 * s.m * s.n + s.k * s.m + s.m3 * (8 * s.n * s.n + 8 * s.n),
        wm: 8 * s.m * s.n + s.k * s.m,
        rr: 0,
        wr: 0,
        m_tasks: s.m3,
        r_tasks: 0,
        keys: 0,
    }
}

/// Steps 1–2 of Cholesky QR (Alg. 1 + the n×n gather/factor iteration).
fn cholesky_r_steps(s: &WorkloadShape) -> Vec<StepBytes> {
    let nn = 8 * s.n * s.n + 8 * s.n;
    vec![
        // step 1: gram per block, row-sum reduce (k_1 = n keys)
        StepBytes {
            rm: 8 * s.m * s.n + s.k * s.m,
            wm: s.m1 * nn,
            rr: s.m1 * nn,
            wr: nn,
            m_tasks: s.m1,
            r_tasks: 40,
            keys: s.n,
        },
        // step 2: gather AᵀA, serial Cholesky (tiny n×n traffic)
        StepBytes { rm: nn, wm: nn, rr: nn, wr: nn, m_tasks: 1, r_tasks: 1, keys: s.n },
    ]
}

/// Steps 1–2 of Indirect TSQR (R-only TSQR with an r_1-way tree).
fn indirect_r_steps(s: &WorkloadShape) -> Vec<StepBytes> {
    let nn = 8 * s.n * s.n + 8 * s.n;
    vec![
        StepBytes {
            rm: 8 * s.m * s.n + s.k * s.m,
            wm: s.m1 * nn,
            rr: s.m1 * nn,
            wr: s.r1 * nn,
            m_tasks: s.m1,
            r_tasks: s.r1,
            keys: s.m1 * s.n,
        },
        StepBytes {
            rm: s.r1 * nn,
            wm: s.r1 * nn,
            rr: s.r1 * nn,
            wr: nn,
            m_tasks: 40,
            r_tasks: 1,
            keys: s.m1 * s.n,
        },
    ]
}

/// Byte counts for every step of `algo` (Householder: one column-step;
/// multiply by `n` iterations for totals, as the paper does).
pub fn algorithm_steps(algo: AlgoKind, s: &WorkloadShape) -> Vec<StepBytes> {
    let nn = 8 * s.n * s.n + 8 * s.n;
    let a_bytes = 8 * s.m * s.n + s.k * s.m;
    match algo {
        AlgoKind::Cholesky => {
            let mut steps = cholesky_r_steps(s);
            steps.push(ar_inv_step(s));
            steps
        }
        AlgoKind::IndirectTsqr => {
            let mut steps = indirect_r_steps(s);
            steps.push(ar_inv_step(s));
            steps
        }
        // Iterative refinement re-runs the R computation on Q and a
        // second product pass — the paper's Table V doubles the bound.
        AlgoKind::CholeskyIr => {
            let mut steps = algorithm_steps(AlgoKind::Cholesky, s);
            steps.extend(algorithm_steps(AlgoKind::Cholesky, s));
            steps
        }
        AlgoKind::IndirectTsqrIr => {
            let mut steps = algorithm_steps(AlgoKind::IndirectTsqr, s);
            steps.extend(algorithm_steps(AlgoKind::IndirectTsqr, s));
            steps
        }
        AlgoKind::DirectTsqr => vec![
            // step 1 (map only): write Q_i (8mn + Km) + R_i (8m1n²) +
            // bookkeeping (64 per task)
            StepBytes {
                rm: a_bytes,
                wm: 8 * s.m * s.n + 8 * s.m1 * s.n * s.n + s.k * s.m + 64 * s.m1,
                rr: 0,
                wr: 0,
                m_tasks: s.m1,
                r_tasks: 0,
                keys: 0,
            },
            // step 2: identity map over the R_i file into 1 reducer
            StepBytes {
                rm: 8 * s.m1 * s.n * s.n + s.k * s.m1,
                wm: 8 * s.m1 * s.n * s.n + s.k * s.m1,
                rr: 8 * s.m1 * s.n * s.n + s.k * s.m1,
                wr: 8 * s.m1 * s.n * s.n + 32 * s.m1 + nn,
                m_tasks: 40,
                r_tasks: 1,
                keys: s.m1,
            },
            // step 3: map-only product; every task re-reads the Q² file
            StepBytes {
                rm: 8 * s.m * s.n + s.k * s.m + s.m3 * (8 * s.m1 * s.n * s.n + 64 * s.m1),
                wm: 8 * s.m * s.n + s.k * s.m,
                rr: 0,
                wr: 0,
                m_tasks: s.m3,
                r_tasks: 0,
                keys: 0,
            },
        ],
        // §VI fused variant: no Q₁ write in step 1, step 2 on the
        // leader, step 3 re-reads A and recomputes Q_i via the fused
        // qr·Q² artifact.
        AlgoKind::DirectTsqrFused => vec![
            StepBytes {
                rm: a_bytes,
                wm: 8 * s.m1 * s.n * s.n + s.k * s.m1,
                rr: 0,
                wr: 0,
                m_tasks: s.m1,
                r_tasks: 0,
                keys: 0,
            },
            // leader gather + in-memory factor + Q² write
            StepBytes {
                rm: 8 * s.m1 * s.n * s.n + s.k * s.m1,
                wm: 8 * s.m1 * s.n * s.n + s.k * s.m1 + nn,
                rr: 0,
                wr: 0,
                m_tasks: 1,
                r_tasks: 0,
                keys: 0,
            },
            StepBytes {
                rm: a_bytes + s.m3 * (8 * s.m1 * s.n * s.n + 64 * s.m1),
                wm: a_bytes,
                rr: 0,
                wr: 0,
                m_tasks: s.m3,
                r_tasks: 0,
                keys: 0,
            },
        ],
        AlgoKind::Householder => vec![
            // update pass: rewrite the matrix
            StepBytes {
                rm: a_bytes,
                wm: a_bytes,
                rr: 0,
                wr: 0,
                m_tasks: s.m1,
                r_tasks: 0,
                keys: 0,
            },
            // reduction pass: partial wᵀ sums (16 bytes per task)
            StepBytes {
                rm: a_bytes,
                wm: 16 * s.m1,
                rr: 0,
                wr: 0,
                m_tasks: s.m1,
                r_tasks: 0,
                keys: 0,
            },
        ],
        // Randomized SVD at the modeled sketch width ℓ = max(n/4, 1):
        // only the fused sketch-project pass touches A-sized bytes; the
        // TSQR of Y and the project-back both move m×ℓ < m×n.
        AlgoKind::Randomized => {
            let ell = (s.n / 4).max(1);
            let y_bytes = 8 * s.m * ell + s.k * s.m;
            let ln = 8 * ell * s.n + 8 * ell;
            vec![
                // fused sketch-project: read A (+ broadcast Ω per task),
                // spill Y, reduce the ℓ×n partial sums into C
                StepBytes {
                    rm: a_bytes + s.m1 * (8 * s.n * ell + 8 * ell),
                    wm: y_bytes + s.m1 * ln,
                    rr: s.m1 * ln,
                    wr: ln,
                    m_tasks: s.m1,
                    r_tasks: 1,
                    keys: ell,
                },
                // TSQR of Y (m×ℓ) — one read/write of the sketch file
                StepBytes {
                    rm: y_bytes,
                    wm: y_bytes + 8 * s.m1 * ell * ell,
                    rr: 0,
                    wr: 0,
                    m_tasks: s.m1,
                    r_tasks: 0,
                    keys: 0,
                },
                // project-back Û = Q_y·W (m×ℓ in, m×ℓ out)
                StepBytes {
                    rm: y_bytes + s.m3 * (8 * ell * ell + 8 * ell),
                    wm: y_bytes,
                    rr: 0,
                    wr: 0,
                    m_tasks: s.m3,
                    r_tasks: 0,
                    keys: 0,
                },
            ]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> WorkloadShape {
        // the paper's 2.5B x 10 workload
        WorkloadShape::new(2_500_000_000, 10, 1680)
    }

    #[test]
    fn hdfs_size_formula() {
        // 8mn + Km; (the paper's reported "HDFS Size (GB)" column uses
        // its on-disk text encoding and differs by a constant factor —
        // the model only needs the formula to be self-consistent)
        assert_eq!(shape().hdfs_bytes(), 8 * 2_500_000_000 * 10 + 32 * 2_500_000_000);
    }

    #[test]
    fn flops_match_table7() {
        // Table VII: 2*rows*cols² for 2.5B x 10 = 5.00e+11
        assert!((shape().flops() - 5.0e11).abs() / 5.0e11 < 1e-12);
    }

    #[test]
    fn direct_reads_matrix_twice_writes_twice() {
        let s = shape();
        let steps = algorithm_steps(AlgoKind::DirectTsqr, &s);
        assert_eq!(steps.len(), 3);
        let a = s.hdfs_bytes();
        // step 1 and step 3 each read the full matrix
        assert!(steps[0].rm >= a && steps[2].rm >= a);
        // Q is written in step 1 and rewritten in step 3
        assert!(steps[0].wm >= a && steps[2].wm >= a);
    }

    #[test]
    fn householder_is_two_passes_per_column() {
        let s = shape();
        let steps = algorithm_steps(AlgoKind::Householder, &s);
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].rm, s.hdfs_bytes());
        assert_eq!(steps[0].wm, s.hdfs_bytes());
        assert_eq!(steps[1].wm, 16 * s.m1);
    }

    #[test]
    fn ir_doubles_step_bytes() {
        let s = shape();
        let plain: u64 = algorithm_steps(AlgoKind::Cholesky, &s).iter().map(|x| x.rm).sum();
        let ir: u64 = algorithm_steps(AlgoKind::CholeskyIr, &s).iter().map(|x| x.rm).sum();
        assert_eq!(ir, 2 * plain);
    }

    #[test]
    fn cholesky_reduce_keys_is_n() {
        let s = shape();
        let steps = algorithm_steps(AlgoKind::Cholesky, &s);
        assert_eq!(steps[0].keys, s.n);
    }

    #[test]
    fn randomized_reads_a_once() {
        let s = shape();
        let steps = algorithm_steps(AlgoKind::Randomized, &s);
        assert_eq!(steps.len(), 3);
        let a = s.hdfs_bytes();
        // only the sketch-project pass is at A scale…
        assert!(steps[0].rm >= a);
        assert!(steps[1].rm < a && steps[2].rm < a);
        // …so the family moves strictly fewer map-read bytes than the
        // exact Direct TSQR pipeline
        let rand: u64 = steps.iter().map(|x| x.rm).sum();
        let direct: u64 =
            algorithm_steps(AlgoKind::DirectTsqr, &s).iter().map(|x| x.rm).sum();
        assert!(rand < direct);
    }

    #[test]
    fn indirect_keys_m1n() {
        let s = shape();
        let steps = algorithm_steps(AlgoKind::IndirectTsqr, &s);
        assert_eq!(steps[0].keys, s.m1 * s.n);
    }
}
