//! The paper's performance model (§V-A).
//!
//! Two fitted parameters — inverse read/write bandwidth `β_r`, `β_w` —
//! plus per-step byte counts (Table III) and stage parallelism
//! (Table IV) give a lower bound on job time (Table V):
//!
//! ```text
//! T_lb = Σ_j (R_j^m β_r + W_j^m β_w)/p_j^m + (R_j^r β_r + W_j^r β_w)/p_j^r
//! ```
//!
//! The engine's measured byte accounting is cross-checked against these
//! closed forms in `rust/tests/props.rs`, and Table IX reports the
//! measured/T_lb multiple.

pub mod bounds;
pub mod counts;
pub mod parallelism;

pub use bounds::lower_bound_secs;
pub use counts::{algorithm_steps, AlgoKind, StepBytes, WorkloadShape};
pub use parallelism::StageParallelism;
