//! Randomized sketching — the randomized algorithm family as MapReduce
//! pipelines (SVD survey, arXiv 2009.00761; Halko–Martinsson–Tropp).
//!
//! Where the paper's Direct TSQR computes *exact* factors in ~2 passes,
//! this subsystem trades a controlled amount of accuracy for strictly
//! fewer passes over `A`:
//!
//! * [`rand_svd::randomized_svd`] — a randomized range finder
//!   (`Y = A·Ω` for a seeded `n×ℓ` test matrix, `ℓ = rank +
//!   oversample`) feeding a truncated SVD. One fused *sketch-project*
//!   pass per power iteration computes both `Y` and `C = YᵀA` as
//!   partial sums, so the whole factorization reads `A` exactly
//!   `1 + power_iters` times — vs the exact path's two-pass Direct
//!   TSQR SVD plus a truncation pass.
//! * [`solve::sketched_solve`] — sketch-and-precondition least
//!   squares: one pass sketches the augmented `[A b]` down to `s`
//!   rows, the leader QRs the sketch, and a second pass solves the
//!   normal equations of the preconditioned basis `Q̃ = A·R_s⁻¹`
//!   (κ(Q̃) ≈ O(1), so the Gram solve is benign) through the same
//!   side-input broadcast machinery as [`crate::coordinator::ar_inv`].
//!
//! **Determinism contract.** Both sketches are *seeded*: the Gaussian
//! test matrix is generated from `SketchOptions::seed` (per-block
//! generators fork off the seed by task id on the row-sketch path),
//! CountSketch hashes global row ids under the seed, and every partial
//! sum is reduced in task-id order by a single reducer. Bits are
//! therefore invariant to `host_threads`, engine shards, worker
//! processes and network hosts — the same digest contract the exact
//! family enforces — and the seed ships in the wire payload so remote
//! runs reproduce local ones exactly.

pub mod operators;
pub mod rand_svd;
pub mod solve;

pub use operators::{countsketch_omega, countsketch_slot, gaussian_omega};
pub use rand_svd::{exact_low_rank, randomized_svd, LowRankOutput};
pub use solve::{sketched_solve, solve_from_augmented_r, SolveOutput};

use anyhow::{bail, Result};

/// Which sketching operator generates the test matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchKind {
    /// Dense i.i.d. N(0,1) test matrix from a seeded generator.
    Gaussian,
    /// CountSketch: one `±1` per input row, bucketed by a seeded hash.
    /// Cheaper to apply (no gemm against a dense Ω on the row-sketch
    /// path) at slightly worse distortion constants.
    CountSketch,
}

impl SketchKind {
    /// The canonical CLI spelling (inverse of [`SketchKind::parse`]).
    pub fn cli_name(&self) -> &'static str {
        match self {
            SketchKind::Gaussian => "gauss",
            SketchKind::CountSketch => "countsketch",
        }
    }

    /// Parse a CLI/manifest sketch-kind name.
    pub fn parse(s: &str) -> Result<SketchKind> {
        Ok(match s {
            "gauss" | "gaussian" => SketchKind::Gaussian,
            "countsketch" => SketchKind::CountSketch,
            other => bail!("unknown sketch kind {other:?} (gauss|countsketch)"),
        })
    }
}

/// Default oversampling parameter `p` (Halko et al. recommend 5–10; the
/// failure probability decays like `p^{-p}`).
pub const DEFAULT_OVERSAMPLE: usize = 8;

/// Default sketch seed. Like an ingestion seed, it is part of the
/// *request*, not the cluster: two runs with the same seed are
/// bit-identical whatever the scaling knobs say.
pub const DEFAULT_SKETCH_SEED: u64 = 0x5EED;

/// How a request's sketching operator is seeded and shaped. Rides on
/// every [`crate::session::FactorizationRequest`]; only `LowRank` /
/// `Solve` requests that actually take a randomized path consult it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SketchOptions {
    pub kind: SketchKind,
    /// Seed for the test matrix / hash functions. Part of the digest
    /// contract (like `rows_per_task`), unlike the scheduling knobs.
    pub seed: u64,
}

impl Default for SketchOptions {
    fn default() -> Self {
        SketchOptions { kind: SketchKind::Gaussian, seed: DEFAULT_SKETCH_SEED }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sketch_kind_names_round_trip() {
        for kind in [SketchKind::Gaussian, SketchKind::CountSketch] {
            assert_eq!(SketchKind::parse(kind.cli_name()).unwrap(), kind);
        }
        assert_eq!(SketchKind::parse("gaussian").unwrap(), SketchKind::Gaussian);
        assert!(SketchKind::parse("srht").is_err());
    }

    #[test]
    fn default_options_are_seeded_gaussian() {
        let o = SketchOptions::default();
        assert_eq!(o.kind, SketchKind::Gaussian);
        assert_eq!(o.seed, DEFAULT_SKETCH_SEED);
    }
}
