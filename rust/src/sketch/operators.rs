//! Seeded sketching operators as map/reduce passes.
//!
//! Three reusable building blocks, each a single engine step:
//!
//! * [`sketch_project_pass`] — the fused column-sketch pass: each map
//!   task forms `Y_i = A_i·Ω` against a broadcast `Ω` side file and
//!   emits the partial projection `C_i = Y_iᵀ A_i` under one key; a
//!   single reducer sums the partials *in task-id order* (the engine
//!   delivers values in emission order, and map emissions merge by
//!   task id), so the sum — and every downstream bit — is invariant
//!   to scheduling. Optionally spills `Y` rows to a side channel for
//!   the follow-up TSQR.
//! * [`row_sketch_pass`] — the row-sketch pass for least squares:
//!   each task compresses its block to `s` rows with a per-block
//!   Gaussian forked from the seed by task id (or CountSketch bucketing
//!   by *global* row id), emitting partials summed the same way.
//! * [`apply_side_matmul`] / [`col_slice_pass`] — broadcast-product
//!   and column-truncation passes over row files (the project-back and
//!   exact-truncation steps).

use super::SketchKind;
use crate::coordinator::io::{decode_block, encode_block, rows_to_block};
use crate::coordinator::{Coordinator, MatrixHandle};
use crate::dfs::records::{decode_row, encode_row, row_key, Record};
use crate::linalg::Matrix;
use crate::mapreduce::{Emitter, JobSpec, JobStats, KeyGroup, MapTask, ReduceTask};
use crate::runtime::BlockCompute;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

// ------------------------------------------------------- test matrices

/// Dense `n×ℓ` i.i.d. N(0,1) test matrix from `seed`. Generated once on
/// the leader and broadcast — the per-*block* forked generators are the
/// row-sketch path's job ([`row_sketch_pass`]), where the sketched
/// dimension is the row space and a global Ω would be `m`-sized.
pub fn gaussian_omega(n: usize, ell: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::gaussian(n, ell, &mut rng)
}

/// CountSketch slot for global row/column index `j` under `seed`:
/// `(bucket, ±1)`. A pure function of `(seed, j, ell)` — no generator
/// state — so collisions are deterministic wherever the hash is
/// evaluated (leader, any map task, any host).
pub fn countsketch_slot(seed: u64, j: u64, ell: usize) -> (usize, f64) {
    let mut rng = Rng::new(seed ^ j.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let bucket = (rng.next_u64() % ell as u64) as usize;
    let sign = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
    (bucket, sign)
}

/// CountSketch test matrix as a dense `n×ℓ`: one `±1` per row, column
/// drawn by the seeded hash. Dense so the column-sketch path can reuse
/// the same broadcast-gemm pass as Gaussian (at tall-and-skinny widths
/// `n×ℓ` is leader-trivial); the row-sketch path applies the hash
/// directly without materializing anything.
pub fn countsketch_omega(n: usize, ell: usize, seed: u64) -> Matrix {
    let mut omega = Matrix::zeros(n, ell);
    for i in 0..n {
        let (bucket, sign) = countsketch_slot(seed, i as u64, ell);
        omega[(i, bucket)] = sign;
    }
    omega
}

/// The single reduce key all partial-sum emissions share.
const PARTIAL_KEY: &[u8] = b"partial-sum";

// ---------------------------------------------------------- map tasks

/// Fused sketch-project map: `Y_i = A_i·Ω` (side file), emit
/// `C_i = Y_iᵀA_i`; optionally spill `Y_i` rows for the range TSQR.
struct SketchProjectMap<'a> {
    compute: &'a dyn BlockCompute,
    spill_y: bool,
}

impl MapTask for SketchProjectMap<'_> {
    fn run(
        &self,
        _id: usize,
        input: &[Record],
        side: &[&[Record]],
        out: &mut Emitter,
    ) -> Result<()> {
        ensure!(side.len() == 1, "sketch-project wants the Ω side file");
        ensure!(side[0].len() == 1, "Ω side file should hold one block record");
        let (_, omega) = decode_block(&side[0][0].value)?;
        let (a, first_row) = rows_to_block(input)?;
        let y = self.compute.matmul(&a, &omega)?;
        let c_i = self.compute.matmul(&y.transpose(), &a)?;
        out.emit(PARTIAL_KEY.to_vec(), encode_block(0, &c_i));
        if self.spill_y {
            for i in 0..y.rows {
                out.emit_to("y", row_key(first_row + i as u64), encode_row(y.row(i)));
            }
        }
        Ok(())
    }
}

/// Row-sketch map for least squares: compress the block to `srows`
/// rows. Gaussian blocks fork the request seed by task id (splits are
/// fixed by `rows_per_task` before scheduling, so the fork stream —
/// like the engine's fault forks — is scheduling-invariant);
/// CountSketch hashes the *global* row id so the partial is independent
/// of how rows landed in blocks at all.
struct RowSketchMap<'a> {
    compute: &'a dyn BlockCompute,
    kind: SketchKind,
    seed: u64,
    srows: usize,
}

impl MapTask for RowSketchMap<'_> {
    fn run(
        &self,
        task_id: usize,
        input: &[Record],
        _side: &[&[Record]],
        out: &mut Emitter,
    ) -> Result<()> {
        let (ab, first_row) = rows_to_block(input)?;
        let partial = match self.kind {
            SketchKind::Gaussian => {
                let mut base = Rng::new(self.seed);
                let mut rng = base.fork(task_id as u64);
                let s_i = Matrix::gaussian(self.srows, ab.rows, &mut rng);
                self.compute.matmul(&s_i, &ab)?
            }
            SketchKind::CountSketch => {
                let mut p = Matrix::zeros(self.srows, ab.cols);
                for i in 0..ab.rows {
                    let (bucket, sign) =
                        countsketch_slot(self.seed, first_row + i as u64, self.srows);
                    for j in 0..ab.cols {
                        p[(bucket, j)] += sign * ab[(i, j)];
                    }
                }
                p
            }
        };
        out.emit(PARTIAL_KEY.to_vec(), encode_block(0, &partial));
        Ok(())
    }
}

/// Preconditioned-Gram map for sketch-and-precondition least squares:
/// with the broadcast `R_s⁻¹`, form `Q̃_i = A_i·R_s⁻¹` and emit the
/// partial `[Q̃ᵀQ̃ | Q̃ᵀb]` block (`n×(n+rhs)`).
struct PrecondGramMap<'a> {
    compute: &'a dyn BlockCompute,
    /// Columns of `A` proper; the remaining `rhs` columns are `b`.
    n: usize,
}

impl MapTask for PrecondGramMap<'_> {
    fn run(
        &self,
        _id: usize,
        input: &[Record],
        side: &[&[Record]],
        out: &mut Emitter,
    ) -> Result<()> {
        ensure!(side.len() == 1, "precond-gram wants the R_s⁻¹ side file");
        let (_, rinv) = decode_block(&side[0][0].value)?;
        let (ab, _) = rows_to_block(input)?;
        let n = self.n;
        ensure!(ab.cols > n, "augmented block narrower than A");
        let a = Matrix::from_fn(ab.rows, n, |i, j| ab[(i, j)]);
        let qt = self.compute.matmul(&a, &rinv)?;
        // [Q̃_i | b_i] so one gemm yields both the Gram block and Q̃ᵀb
        let aug = Matrix::from_fn(ab.rows, ab.cols, |i, j| {
            if j < n {
                qt[(i, j)]
            } else {
                ab[(i, j)]
            }
        });
        let partial = self.compute.matmul(&qt.transpose(), &aug)?;
        out.emit(PARTIAL_KEY.to_vec(), encode_block(0, &partial));
        Ok(())
    }
}

/// Broadcast-product map: emit `A_i · W` rows (the project-back step).
struct MatMulSideMap<'a> {
    compute: &'a dyn BlockCompute,
}

impl MapTask for MatMulSideMap<'_> {
    fn run(
        &self,
        _id: usize,
        input: &[Record],
        side: &[&[Record]],
        out: &mut Emitter,
    ) -> Result<()> {
        ensure!(side.len() == 1, "matmul-side wants the W side file");
        let (_, w) = decode_block(&side[0][0].value)?;
        let (a, first_row) = rows_to_block(input)?;
        let prod = self.compute.matmul(&a, &w)?;
        crate::coordinator::io::emit_rows(out, first_row, &prod);
        Ok(())
    }
}

/// Keep the first `keep` columns of every row (exact truncation pass).
struct ColSliceMap {
    keep: usize,
}

impl MapTask for ColSliceMap {
    fn run(
        &self,
        _id: usize,
        input: &[Record],
        _side: &[&[Record]],
        out: &mut Emitter,
    ) -> Result<()> {
        for rec in input {
            let row = decode_row(&rec.value);
            ensure!(row.len() >= self.keep, "row narrower than the kept rank");
            out.emit(rec.key.clone(), encode_row(&row[..self.keep]));
        }
        Ok(())
    }
}

/// Sum block-record partials in arrival (= task-id) order, then emit
/// the total as row records. Sequential left-to-right summation over a
/// deterministic order is what makes the sketch bits
/// scheduling-invariant.
struct SumReduce;

impl ReduceTask for SumReduce {
    fn run(&self, partition: &[KeyGroup], out: &mut Emitter) -> Result<()> {
        ensure!(partition.len() == 1, "partial sums share one key");
        let (_, values) = &partition[0];
        let mut acc: Option<Matrix> = None;
        for v in values {
            let (_, p) = decode_block(v)?;
            acc = Some(match acc {
                None => p,
                Some(a) => a.add(&p),
            });
        }
        let total = acc.expect("at least one partial");
        for j in 0..total.rows {
            out.emit(row_key(j as u64), encode_row(total.row(j)));
        }
        Ok(())
    }
}

// -------------------------------------------------------- pass runners

/// Stage a small matrix as a one-record block side file (the same
/// broadcast pattern as `ar_inv`'s `R⁻¹` distribution).
pub(crate) fn put_block_side(coord: &mut Coordinator, tag: &str, m: &Matrix) -> String {
    let file = coord.tmp(tag);
    coord.dfs_mut(|d| d.put(&file, vec![Record::new(row_key(0), encode_block(0, m))]));
    file
}

/// Read a small leader-side matrix back out of a pass's row-record
/// output.
fn read_rows(coord: &Coordinator, file: &str, cols: usize) -> Result<Matrix> {
    coord.dfs(|d| crate::workload::get_matrix(d, file, cols))
}

/// One fused sketch-project pass: returns `C = (A·Ω)ᵀA` (`ℓ×n`) and,
/// when `spill_y` names a file, leaves `Y = A·Ω` there as row records.
/// `label` lands in the step stats (sketch kind/seed/ℓ are recorded
/// through it).
pub(crate) fn sketch_project_pass(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    omega: &Matrix,
    spill_y: Option<&str>,
    label: &str,
    stats: &mut JobStats,
) -> Result<Matrix> {
    let omega_file = put_block_side(coord, "sk-omega", omega);
    let c_file = coord.tmp("sk-c");
    let map_tasks = coord.map_tasks_for(input.rows);
    let data_scale = coord.dfs(|d| d.scale(&input.file));
    let mapper = SketchProjectMap { compute: coord.compute, spill_y: spill_y.is_some() };
    let mut spec = JobSpec::map_reduce(label, &input.file, map_tasks, &mapper, &SumReduce, 1, &c_file)
        .with_side_input(&omega_file);
    if let Some(y_file) = spill_y {
        spec = spec.with_scaled_side_output("y", y_file, data_scale);
    }
    stats.push(coord.run_step(&spec)?);
    read_rows(coord, &c_file, input.cols)
}

/// One row-sketch pass: returns `S·A` (`srows×cols`).
pub(crate) fn row_sketch_pass(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    kind: SketchKind,
    seed: u64,
    srows: usize,
    label: &str,
    stats: &mut JobStats,
) -> Result<Matrix> {
    let out_file = coord.tmp("sk-rowsketch");
    let map_tasks = coord.map_tasks_for(input.rows);
    let mapper = RowSketchMap { compute: coord.compute, kind, seed, srows };
    let spec =
        JobSpec::map_reduce(label, &input.file, map_tasks, &mapper, &SumReduce, 1, &out_file);
    stats.push(coord.run_step(&spec)?);
    read_rows(coord, &out_file, input.cols)
}

/// One preconditioned-Gram pass: returns `[Q̃ᵀQ̃ | Q̃ᵀb]`
/// (`n×(n+rhs)`) for `Q̃ = A·R_s⁻¹`.
pub(crate) fn precond_gram_pass(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    rinv: &Matrix,
    label: &str,
    stats: &mut JobStats,
) -> Result<Matrix> {
    let rinv_file = put_block_side(coord, "sk-rinv", rinv);
    let out_file = coord.tmp("sk-gram");
    let map_tasks = coord.map_tasks_for(input.rows);
    let n = rinv.cols;
    let mapper = PrecondGramMap { compute: coord.compute, n };
    let spec =
        JobSpec::map_reduce(label, &input.file, map_tasks, &mapper, &SumReduce, 1, &out_file)
            .with_side_input(&rinv_file);
    stats.push(coord.run_step(&spec)?);
    read_rows(coord, &out_file, input.cols)
}

/// Broadcast-product pass over a row file: `out = input · w`.
pub(crate) fn apply_side_matmul(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    w: &Matrix,
    label: &str,
    stats: &mut JobStats,
) -> Result<MatrixHandle> {
    let w_file = put_block_side(coord, "sk-w", w);
    let out_file = coord.tmp("sk-prod");
    let map_tasks = coord.map_tasks_for(input.rows);
    let data_scale = coord.dfs(|d| d.scale(&input.file));
    let mapper = MatMulSideMap { compute: coord.compute };
    let spec = JobSpec::map_only(label, &input.file, map_tasks, &mapper, &out_file)
        .with_side_input(&w_file)
        .with_output_scale(data_scale);
    stats.push(coord.run_step(&spec)?);
    Ok(MatrixHandle::new(&out_file, input.rows, w.cols))
}

/// Column-truncation pass over a row file: keep the first `keep` cols.
pub(crate) fn col_slice_pass(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    keep: usize,
    label: &str,
    stats: &mut JobStats,
) -> Result<MatrixHandle> {
    let out_file = coord.tmp("sk-slice");
    let map_tasks = coord.map_tasks_for(input.rows);
    let data_scale = coord.dfs(|d| d.scale(&input.file));
    let mapper = ColSliceMap { keep };
    let spec = JobSpec::map_only(label, &input.file, map_tasks, &mapper, &out_file)
        .with_output_scale(data_scale);
    stats.push(coord.run_step(&spec)?);
    Ok(MatrixHandle::new(&out_file, input.rows, keep))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::DiskModel;
    use crate::mapreduce::{ClusterConfig, Engine};
    use crate::runtime::NativeRuntime;
    use crate::workload::put_matrix;

    fn coord_with(a: &Matrix) -> (Coordinator<'static>, MatrixHandle) {
        let mut engine = Engine::new(DiskModel::icme_like(), ClusterConfig::default());
        put_matrix(&mut engine.dfs, "A", a);
        (
            Coordinator::new(engine, NativeRuntime::oracle()),
            MatrixHandle::new("A", a.rows, a.cols),
        )
    }

    #[test]
    fn gaussian_omega_is_seed_deterministic() {
        let a = gaussian_omega(20, 4, 7);
        let b = gaussian_omega(20, 4, 7);
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, gaussian_omega(20, 4, 8).data);
    }

    #[test]
    fn countsketch_slot_is_pure_and_covers_buckets() {
        let ell = 7;
        let mut seen = vec![false; ell];
        for j in 0..200u64 {
            let (b1, s1) = countsketch_slot(42, j, ell);
            let (b2, s2) = countsketch_slot(42, j, ell);
            assert_eq!((b1, s1.to_bits()), (b2, s2.to_bits()), "slot must be pure");
            assert!(b1 < ell && s1.abs() == 1.0);
            seen[b1] = true;
        }
        assert!(seen.iter().all(|&b| b), "200 draws should cover 7 buckets");
        // different seeds give different hash functions
        let same = (0..200u64)
            .filter(|&j| countsketch_slot(1, j, ell) == countsketch_slot(2, j, ell))
            .count();
        assert!(same < 120, "seeds 1 and 2 agree on {same}/200 slots");
    }

    #[test]
    fn countsketch_omega_has_one_entry_per_row() {
        let omega = countsketch_omega(30, 5, 9);
        for i in 0..30 {
            let nz: Vec<f64> =
                (0..5).map(|j| omega[(i, j)]).filter(|v| *v != 0.0).collect();
            assert_eq!(nz.len(), 1);
            assert_eq!(nz[0].abs(), 1.0);
        }
    }

    #[test]
    fn sketch_project_matches_serial_product() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(120, 6, &mut rng);
        let omega = gaussian_omega(6, 3, 11);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 32;
        let mut stats = JobStats::default();
        let y_file = coord.tmp("y");
        let c = sketch_project_pass(&mut coord, &h, &omega, Some(&y_file), "t", &mut stats)
            .unwrap();
        let y_want = a.matmul(&omega);
        let c_want = y_want.transpose().matmul(&a);
        assert_eq!((c.rows, c.cols), (3, 6));
        assert!(c.sub(&c_want).max_abs() < 1e-12 * c_want.max_abs().max(1.0));
        let y = coord.dfs(|d| crate::workload::get_matrix(d, &y_file, 3)).unwrap();
        assert_eq!(y.rows, 120);
        assert!(y.sub(&y_want).max_abs() < 1e-13 * y_want.max_abs());
    }

    #[test]
    fn row_sketch_partials_are_block_invariant_for_countsketch() {
        // CountSketch hashes global row ids, so the summed sketch is
        // identical whatever rows_per_task splits the blocks into
        let mut rng = Rng::new(4);
        let a = Matrix::gaussian(90, 4, &mut rng);
        let mut first: Option<Matrix> = None;
        for rpt in [16, 90] {
            let (mut coord, h) = coord_with(&a);
            coord.opts.rows_per_task = rpt;
            let mut stats = JobStats::default();
            let s = row_sketch_pass(
                &mut coord,
                &h,
                SketchKind::CountSketch,
                5,
                8,
                "t",
                &mut stats,
            )
            .unwrap();
            match &first {
                None => first = Some(s),
                Some(f) => assert_eq!(f.data, s.data, "rpt={rpt}"),
            }
        }
    }

    #[test]
    fn col_slice_keeps_leading_columns() {
        let mut rng = Rng::new(5);
        let a = Matrix::gaussian(40, 5, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let mut stats = JobStats::default();
        let out = col_slice_pass(&mut coord, &h, 2, "t", &mut stats).unwrap();
        let m = coord.dfs(|d| crate::workload::get_matrix(d, &out.file, 2)).unwrap();
        assert_eq!((m.rows, m.cols), (40, 2));
        for i in 0..40 {
            assert_eq!(m[(i, 0)].to_bits(), a[(i, 0)].to_bits());
            assert_eq!(m[(i, 1)].to_bits(), a[(i, 1)].to_bits());
        }
    }
}
