//! Least squares over the cluster: exact (via the triangular factor of
//! the augmented `[A b]`) and sketch-and-precondition
//! (Rokhlin–Tygert / Blendenpik style, arranged as two MapReduce
//! passes).
//!
//! The request shape: the ingested input is the *augmented* matrix
//! `[A b]` — the trailing `rhs` columns are right-hand sides — so the
//! least-squares family rides the existing one-matrix ingestion and
//! wire surface unchanged.
//!
//! **Exact.** Any R-producing pipeline applied to `[A b]` yields
//! `R_aug = [[R_A, R_ab], [0, R_res]]`; back-substitution gives
//! `x = R_A⁻¹ R_ab` with residual norm `‖R_res‖` — no extra pass.
//!
//! **Sketched.** Pass 1 ([`super::operators::row_sketch_pass`])
//! compresses `[A b]` to `s = min(4(n+rhs), m)` rows with a seeded
//! row sketch; the leader QRs the sketch for `R_s`. Pass 2
//! ([`super::operators::precond_gram_pass`]) broadcasts `R_s⁻¹` —
//! the same side-file pattern as `ar_inv` — and accumulates
//! `[Q̃ᵀQ̃ | Q̃ᵀb]` for `Q̃ = A·R_s⁻¹`. Because the sketch is a
//! subspace embedding, `κ(Q̃) = O(1)` whatever `κ(A)` is, so the
//! normal equations — fatal at `κ²` for raw `A` — are benign here:
//! Cholesky of `Q̃ᵀQ̃ ≈ I` then `x = R_s⁻¹ y`. Two passes total.

use super::operators::{precond_gram_pass, row_sketch_pass};
use super::SketchOptions;
use crate::coordinator::{Coordinator, MatrixHandle};
use crate::linalg::{back_substitute, cholesky, householder_qr, tri_inverse_upper, Matrix};
use crate::mapreduce::JobStats;
use anyhow::{anyhow, ensure, Result};

/// Output of a least-squares solve.
#[derive(Debug)]
pub struct SolveOutput {
    /// The `n×rhs` solution(s) to `min ‖A x − b‖₂`, one column per
    /// right-hand side.
    pub x: Matrix,
    /// The `n×n` triangle behind the solve: `R_A` (exact path) or the
    /// sketched preconditioner `R_s`. Enters the result digest.
    pub r: Matrix,
    pub stats: JobStats,
    /// Rows of the row sketch (0 on the exact path).
    pub sketch_rows: usize,
}

/// Split an augmented width into `(n, rhs)` with bounds checking.
pub(crate) fn split_cols(total_cols: usize, rhs: usize) -> Result<usize> {
    ensure!(rhs >= 1, "solve request needs rhs >= 1");
    ensure!(
        rhs < total_cols,
        "rhs {} leaves no system columns in a width-{} input",
        rhs,
        total_cols
    );
    Ok(total_cols - rhs)
}

/// Exact least squares from the triangular factor of the augmented
/// input: `x = R_A⁻¹ R_ab` by back-substitution. Returns `(x, R_A)`.
pub fn solve_from_augmented_r(r_aug: &Matrix, n: usize, rhs: usize) -> Result<(Matrix, Matrix)> {
    ensure!(
        r_aug.cols == n + rhs && r_aug.rows >= n,
        "augmented R is {}x{}, want >= {}x{}",
        r_aug.rows,
        r_aug.cols,
        n,
        n + rhs
    );
    let r_a = Matrix::from_fn(n, n, |i, j| r_aug[(i, j)]);
    for i in 0..n {
        ensure!(
            r_a[(i, i)] != 0.0,
            "A is numerically rank-deficient (R_A[{i},{i}] = 0); least squares needs full column rank"
        );
    }
    let mut x = Matrix::zeros(n, rhs);
    for k in 0..rhs {
        let b: Vec<f64> = (0..n).map(|i| r_aug[(i, n + k)]).collect();
        let col = back_substitute(&r_a, &b);
        for i in 0..n {
            x[(i, k)] = col[i];
        }
    }
    Ok((x, r_a))
}

/// Row count of the least-squares sketch: 4× the augmented width is the
/// usual subspace-embedding margin, clamped to the input height.
pub(crate) fn ls_sketch_rows(total_cols: usize, rows: usize) -> usize {
    (4 * total_cols).min(rows).max(total_cols)
}

/// Sketch-and-precondition least squares on the augmented `[A b]`
/// (see module docs). Two passes over the input; bits depend only on
/// the input, `rhs`, `rows_per_task` and the sketch seed.
pub fn sketched_solve(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    rhs: usize,
    sketch: SketchOptions,
) -> Result<SolveOutput> {
    let total = input.cols;
    let n = split_cols(total, rhs)?;
    ensure!(
        input.rows >= total,
        "sketched solve wants an overdetermined system ({}x{} augmented input)",
        input.rows,
        total
    );
    let srows = ls_sketch_rows(total, input.rows);
    let mut stats = JobStats::default();

    // ---- pass 1: seeded row sketch of [A b], leader QR → R_s ----
    let label = format!(
        "sketch-rows({} seed={} s={srows})",
        sketch.kind.cli_name(),
        sketch.seed
    );
    let sab = row_sketch_pass(coord, input, sketch.kind, sketch.seed, srows, &label, &mut stats)?;
    let (_, r_aug_s) = householder_qr(&sab);
    let r_s = Matrix::from_fn(n, n, |i, j| r_aug_s[(i, j)]);
    let rinv = tri_inverse_upper(&r_s).ok_or_else(|| {
        anyhow!("sketched R is singular: A is numerically rank-deficient under the sketch")
    })?;

    // ---- pass 2: preconditioned normal equations through R_s⁻¹ ----
    let gram = precond_gram_pass(coord, input, &rinv, "precond-gram", &mut stats)?;
    let g = gram.block(n, n);
    let c = Matrix::from_fn(n, rhs, |i, k| gram[(i, n + k)]);
    // G = Q̃ᵀQ̃ ≈ I: Cholesky is safe by construction
    let l = cholesky(&g)
        .map_err(|e| anyhow!("preconditioned Gram lost positive-definiteness: {e:?}"))?;
    let lt_inv = tri_inverse_upper(&l.transpose())
        .ok_or_else(|| anyhow!("preconditioned Gram factor is singular"))?;
    // y = (L Lᵀ)⁻¹ c = Lᵀ⁻¹ (L⁻¹ c), with L⁻¹ = (Lᵀ⁻¹)ᵀ
    let y = lt_inv.matmul(&lt_inv.transpose().matmul(&c));
    let x = rinv.matmul(&y);

    Ok(SolveOutput { x, r: r_s, stats, sketch_rows: srows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::DiskModel;
    use crate::mapreduce::{ClusterConfig, Engine};
    use crate::runtime::NativeRuntime;
    use crate::util::rng::Rng;
    use crate::workload::put_matrix;

    fn coord_with(a: &Matrix) -> (Coordinator<'static>, MatrixHandle) {
        let mut engine = Engine::new(DiskModel::icme_like(), ClusterConfig::default());
        put_matrix(&mut engine.dfs, "AB", a);
        (
            Coordinator::new(engine, NativeRuntime::oracle()),
            MatrixHandle::new("AB", a.rows, a.cols),
        )
    }

    /// Build [A b] with b = A·x_true + noise·z, z ⟂-ish random.
    fn augmented(
        m: usize,
        n: usize,
        noise: f64,
        rng: &mut Rng,
    ) -> (Matrix, Matrix, Matrix) {
        let a = Matrix::gaussian(m, n, rng);
        let x_true = Matrix::gaussian(n, 1, rng);
        let b0 = a.matmul(&x_true);
        let z = Matrix::gaussian(m, 1, rng);
        let ab = Matrix::from_fn(m, n + 1, |i, j| {
            if j < n {
                a[(i, j)]
            } else {
                b0[(i, 0)] + noise * z[(i, 0)]
            }
        });
        (ab, a, x_true)
    }

    #[test]
    fn exact_solve_from_augmented_r_recovers_x() {
        let mut rng = Rng::new(1);
        let (ab, _, x_true) = augmented(200, 5, 0.0, &mut rng);
        let (_, r_aug) = householder_qr(&ab);
        let (x, r_a) = solve_from_augmented_r(&r_aug, 5, 1).unwrap();
        assert_eq!((x.rows, x.cols), (5, 1));
        assert!(r_a.is_upper_triangular(1e-12 * r_a.max_abs()));
        for i in 0..5 {
            assert!((x[(i, 0)] - x_true[(i, 0)]).abs() < 1e-10);
        }
    }

    #[test]
    fn sketched_solve_matches_exact_residual() {
        let mut rng = Rng::new(2);
        let (ab, a, x_true) = augmented(300, 6, 1e-3, &mut rng);
        let b = Matrix::from_fn(300, 1, |i, _| ab[(i, 6)]);
        // exact LS residual via dense QR
        let (_, r_aug) = householder_qr(&ab);
        let (x_exact, _) = solve_from_augmented_r(&r_aug, 6, 1).unwrap();
        let exact_res = a.matmul(&x_exact).sub(&b).frob_norm();

        let (mut coord, h) = coord_with(&ab);
        coord.opts.rows_per_task = 64;
        let out = sketched_solve(&mut coord, &h, 1, SketchOptions::default()).unwrap();
        assert_eq!(out.sketch_rows, 28); // 4·(6+1)
        let sk_res = a.matmul(&out.x).sub(&b).frob_norm();
        // sketch-and-precondition solves the same normal equations to
        // working precision: residuals must agree tightly
        assert!(
            sk_res <= exact_res * (1.0 + 1e-6) + 1e-12,
            "sketched {sk_res} vs exact {exact_res}"
        );
        for i in 0..6 {
            assert!((out.x[(i, 0)] - x_true[(i, 0)]).abs() < 1e-2);
        }
    }

    #[test]
    fn countsketch_solve_also_works() {
        use super::super::SketchKind;
        let mut rng = Rng::new(3);
        let (ab, a, _) = augmented(240, 4, 0.0, &mut rng);
        let b = Matrix::from_fn(240, 1, |i, _| ab[(i, 4)]);
        let (mut coord, h) = coord_with(&ab);
        coord.opts.rows_per_task = 50;
        let out = sketched_solve(
            &mut coord,
            &h,
            1,
            SketchOptions { kind: SketchKind::CountSketch, seed: 7 },
        )
        .unwrap();
        // zero-noise system: the LS solution interpolates exactly
        assert!(a.matmul(&out.x).sub(&b).frob_norm() < 1e-8);
    }

    #[test]
    fn bounds_are_validated() {
        assert!(split_cols(5, 0).is_err());
        assert!(split_cols(5, 5).is_err());
        assert_eq!(split_cols(5, 2).unwrap(), 3);
        assert_eq!(ls_sketch_rows(7, 1000), 28);
        assert_eq!(ls_sketch_rows(7, 20), 20);
    }
}
