//! Randomized range finder → randomized truncated SVD
//! (Halko–Martinsson–Tropp Alg. 4.3/4.4 + 5.1, arranged for MapReduce).
//!
//! Pass structure (`q = power_iters`, `ℓ = rank + oversample`):
//!
//! 1. `1+q` fused *sketch-project* passes over `A`
//!    ([`super::operators::sketch_project_pass`]): each computes
//!    `C_j = (A·Ω_j)ᵀA`; between passes the leader orthonormalizes
//!    `Ω_{j+1} = orth(C_jᵀ)` (the power-iteration stabilization), and
//!    the final pass spills `Y = A·Ω_q` as a row file.
//! 2. Direct TSQR of `Y` (`m×ℓ`, *below* the `A`-bytes threshold at
//!    `ℓ < n`) via the existing step machinery → orthonormal `Q_y`
//!    and triangle `R_y`.
//! 3. Leader smalls: `B = R_y⁻ᵀ·C` (`= Q_yᵀA` without another pass,
//!    since `C = YᵀA = R_yᵀQ_yᵀA`), wide-`B` SVD via QR of `Bᵀ` +
//!    square Jacobi, truncation to `rank`.
//! 4. One project-back pass over `Q_y` (`m×ℓ` bytes) → `Û = Q_y·W`.
//!
//! Total reads at or above `A`'s size: exactly `1+q` — strictly fewer
//! than the exact path (Direct-TSQR SVD reads `A`-sized files twice,
//! plus a truncation pass), which is the whole point of the family.

use super::operators::{
    apply_side_matmul, col_slice_pass, countsketch_omega, gaussian_omega, sketch_project_pass,
};
use super::{SketchKind, SketchOptions};
use crate::coordinator::{direct_tsqr, Coordinator, DirectOpts, MatrixHandle};
use crate::linalg::{householder_qr, jacobi_svd, tri_inverse_upper, Matrix};
use crate::mapreduce::JobStats;
use anyhow::{anyhow, bail, ensure, Result};

/// Output of a (randomized or exact) truncated SVD: `A ≈ Û Σ_r V_rᵀ`.
#[derive(Debug)]
pub struct LowRankOutput {
    /// Approximate left singular vectors, `m×rank`, row layout.
    pub u: MatrixHandle,
    /// The triangular factor behind `u`: `R_y` of the range basis on
    /// the randomized path, the full `R̃` on the exact path. This is
    /// what enters the result digest.
    pub r: Matrix,
    /// Leading `rank` singular value estimates, descending.
    pub sigma: Vec<f64>,
    /// Approximate right singular vectors, `n×rank`.
    pub v: Matrix,
    pub stats: JobStats,
    /// Sketch width actually used (`min(rank+oversample, n, m)`;
    /// `n` on the exact path).
    pub ell: usize,
}

fn validate_rank(input: &MatrixHandle, rank: usize) -> Result<()> {
    ensure!(rank >= 1, "low-rank request needs rank >= 1");
    ensure!(
        rank <= input.cols && rank <= input.rows,
        "rank {} exceeds the {}x{} input",
        rank,
        input.rows,
        input.cols
    );
    Ok(())
}

/// Keep the first `k` columns.
fn take_cols(m: &Matrix, k: usize) -> Matrix {
    Matrix::from_fn(m.rows, k, |i, j| m[(i, j)])
}

/// Randomized truncated SVD of `input` (see module docs for the pass
/// structure). Bits depend only on the input, `rank`/`oversample`/
/// `power_iters`, `rows_per_task` and the sketch seed — never on any
/// scheduling knob.
pub fn randomized_svd(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    rank: usize,
    oversample: usize,
    power_iters: usize,
    sketch: SketchOptions,
) -> Result<LowRankOutput> {
    validate_rank(input, rank)?;
    let n = input.cols;
    let ell = (rank + oversample).min(n).min(input.rows);
    ensure!(ell >= rank, "oversampled width collapsed below rank");
    let mut stats = JobStats::default();

    // ---- 1+q fused sketch-project passes over A ----
    let mut omega = match sketch.kind {
        SketchKind::Gaussian => gaussian_omega(n, ell, sketch.seed),
        SketchKind::CountSketch => countsketch_omega(n, ell, sketch.seed),
    };
    let y_file = coord.tmp("sk-y");
    let mut c = Matrix::zeros(0, 0);
    for j in 0..=power_iters {
        let spill = j == power_iters;
        let label = format!(
            "sketch-project(q{j}, {} seed={} ell={ell})",
            sketch.kind.cli_name(),
            sketch.seed
        );
        c = sketch_project_pass(
            coord,
            input,
            &omega,
            spill.then_some(y_file.as_str()),
            &label,
            &mut stats,
        )?;
        if !spill {
            // power iteration: Ω ← orth(CᵀA-direction) = orth((YᵀA)ᵀ),
            // re-orthonormalized each round so repeated products don't
            // collapse onto the top singular direction numerically
            let (next, _) = householder_qr(&c.transpose());
            omega = next;
        }
    }

    // ---- Direct TSQR of the spilled Y (m×ℓ — below the A threshold) ----
    let y_handle = MatrixHandle::new(&y_file, input.rows, ell);
    let tsqr = direct_tsqr::direct_tsqr(coord, &y_handle, &DirectOpts::default())?;
    stats.extend(tsqr.stats);
    let r_y = tsqr.r;

    // ---- leader smalls: B = R_y⁻ᵀ C, then the wide-B SVD ----
    let rinv = tri_inverse_upper(&r_y).ok_or_else(|| {
        anyhow!(
            "sketched range basis is rank-deficient (numerical rank of A < {ell}); \
             lower rank/oversample"
        )
    })?;
    let b = rinv.transpose().matmul(&c); // ℓ×n, = Q_yᵀA up to roundoff
    let (qb, rb) = householder_qr(&b.transpose()); // B = R_bᵀ Q_bᵀ
    let small = jacobi_svd(&rb.transpose()); // ℓ×ℓ: R_bᵀ = U₁ Σ V₁ᵀ
    let sigma: Vec<f64> = small.sigma[..rank].to_vec();
    let v = take_cols(&qb.matmul(&small.v), rank); // n×rank
    let w = take_cols(&small.u, rank); // ℓ×rank

    // ---- project-back pass: Û = Q_y · W ----
    let u = apply_side_matmul(coord, &tsqr.q, &w, "sketch-project-back", &mut stats)?;

    Ok(LowRankOutput { u, r: r_y, sigma, v, stats, ell })
}

/// Exact truncated SVD: the two-pass Direct-TSQR SVD plus one
/// column-truncation pass over `QU`. The accuracy baseline — and the
/// algorithm the Auto policy picks when the requested rank is too close
/// to `n` for sketching to save anything.
pub fn exact_low_rank(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    rank: usize,
) -> Result<LowRankOutput> {
    validate_rank(input, rank)?;
    let out = coord.svd(input)?;
    let mut stats = out.stats;
    let svd = out.svd.ok_or_else(|| anyhow!("direct SVD returned no Σ/V"))?;
    ensure!(svd.sigma.len() >= rank, "SVD returned fewer than rank values");
    let u = col_slice_pass(coord, &out.q, rank, "lowrank-truncate", &mut stats)?;
    Ok(LowRankOutput {
        u,
        r: out.r,
        sigma: svd.sigma[..rank].to_vec(),
        v: take_cols(&svd.v, rank),
        stats,
        ell: input.cols,
    })
}

/// The Auto policy's sketch-vs-exact gate for `Want::LowRank`: sketch
/// when the oversampled width is at most half the column count —
/// below that the randomized path reads strictly fewer bytes; above
/// it, the exact two-pass SVD is both cheaper and exact.
pub fn sketch_pays_off(cols: usize, rank: usize, oversample: usize) -> bool {
    2 * (rank + oversample) <= cols
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::DiskModel;
    use crate::linalg::matgen::matrix_with_spectrum;
    use crate::mapreduce::{ClusterConfig, Engine};
    use crate::runtime::NativeRuntime;
    use crate::util::rng::Rng;
    use crate::workload::put_matrix;

    fn coord_with(a: &Matrix) -> (Coordinator<'static>, MatrixHandle) {
        let mut engine = Engine::new(DiskModel::icme_like(), ClusterConfig::default());
        put_matrix(&mut engine.dfs, "A", a);
        (
            Coordinator::new(engine, NativeRuntime::oracle()),
            MatrixHandle::new("A", a.rows, a.cols),
        )
    }

    fn logspace_sigma(n: usize, decades: f64) -> Vec<f64> {
        (0..n).map(|i| 10f64.powf(-decades * i as f64 / (n - 1) as f64)).collect()
    }

    #[test]
    fn randomized_svd_recovers_decaying_spectrum() {
        let mut rng = Rng::new(1);
        let sigma_true = logspace_sigma(24, 6.0);
        let (a, _, _) = matrix_with_spectrum(300, 24, &sigma_true, &mut rng);
        let (mut coord, h) = coord_with(&a);
        coord.opts.rows_per_task = 64;
        let out = randomized_svd(
            &mut coord,
            &h,
            4,
            4,
            1,
            SketchOptions::default(),
        )
        .unwrap();
        assert_eq!(out.sigma.len(), 4);
        for (got, want) in out.sigma.iter().zip(&sigma_true) {
            assert!((got / want - 1.0).abs() < 1e-2, "sigma {got} vs {want}");
        }
        // A ≈ Û Σ V̂ᵀ within a few σ_{rank+1}
        let u = coord.dfs(|d| crate::workload::get_matrix(d, &out.u.file, 4)).unwrap();
        assert!(u.orthogonality_error() < 1e-10, "orth {}", u.orthogonality_error());
        let mut us = u.clone();
        for j in 0..4 {
            for i in 0..us.rows {
                us[(i, j)] *= out.sigma[j];
            }
        }
        let err = a.sub(&us.matmul(&out.v.transpose())).frob_norm();
        let tail: f64 = sigma_true[4..].iter().map(|s| s * s).sum::<f64>().sqrt();
        assert!(err < 10.0 * tail.max(sigma_true[4]), "err {err} vs tail {tail}");
    }

    #[test]
    fn exact_low_rank_matches_truncated_direct_svd() {
        let mut rng = Rng::new(2);
        let sigma_true = vec![16.0, 4.0, 1.0, 0.25, 0.0625];
        let (a, _, _) = matrix_with_spectrum(150, 5, &sigma_true, &mut rng);
        let (mut coord, h) = coord_with(&a);
        let out = exact_low_rank(&mut coord, &h, 2).unwrap();
        assert_eq!(out.sigma.len(), 2);
        for (got, want) in out.sigma.iter().zip(&sigma_true) {
            assert!((got / want - 1.0).abs() < 1e-10);
        }
        assert_eq!(out.v.cols, 2);
        let u = coord.dfs(|d| crate::workload::get_matrix(d, &out.u.file, 2)).unwrap();
        assert_eq!((u.rows, u.cols), (150, 2));
        assert!(u.orthogonality_error() < 1e-12);
    }

    #[test]
    fn rank_validation_rejects_nonsense() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(30, 4, &mut rng);
        let (mut coord, h) = coord_with(&a);
        assert!(randomized_svd(&mut coord, &h, 0, 2, 0, SketchOptions::default()).is_err());
        assert!(randomized_svd(&mut coord, &h, 5, 2, 0, SketchOptions::default()).is_err());
        assert!(exact_low_rank(&mut coord, &h, 0).is_err());
    }

    #[test]
    fn gate_splits_on_half_the_columns() {
        assert!(sketch_pays_off(40, 4, 8));
        assert!(!sketch_pays_off(20, 4, 8));
        assert!(sketch_pays_off(24, 4, 8)); // boundary: 2·12 == 24
    }
}
