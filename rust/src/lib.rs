//! # mrtsqr — Direct QR factorizations for tall-and-skinny matrices
//!
//! A rust + JAX + Pallas reproduction of Benson, Gleich & Demmel,
//! *"Direct QR factorizations for tall-and-skinny matrices in MapReduce
//! architectures"* (IEEE BigData 2013).
//!
//! The system is a three-layer stack:
//!
//! * **L3 (this crate)** — the MapReduce coordinator: a Hadoop-like
//!   engine ([`mapreduce`]) over a simulated HDFS ([`dfs`]) with a
//!   disk-bandwidth virtual clock, plus the paper's algorithms
//!   ([`coordinator`]): Cholesky QR, Indirect TSQR, `A·R⁻¹` (+ iterative
//!   refinement), **Direct TSQR** (the paper's contribution), its
//!   recursive extension, Householder QR, and the TSVD extension.
//! * **L2/L1 (python, build-time only)** — per-task block computations
//!   (local Householder QR, Gram, tall×small matmul) authored as Pallas
//!   kernels inside JAX functions, AOT-lowered to HLO text once by
//!   `make artifacts`, and executed from rust via the PJRT CPU client
//!   ([`runtime`]). Python is never on the request path.
//!
//! Pure-rust dense linear algebra ([`linalg`]) provides the serial
//! `n×n` steps the paper runs on a single node (Cholesky, `R⁻¹`,
//! Jacobi SVD) and an independent correctness oracle.

pub mod coordinator;
pub mod dfs;
pub mod linalg;
pub mod mapreduce;
pub mod perfmodel;
pub mod runtime;
pub mod util;
pub mod workload;

pub use coordinator::{Algorithm, Coordinator};
pub use linalg::Matrix;
