//! # mrtsqr — Direct QR factorizations for tall-and-skinny matrices
//!
//! A rust + JAX + Pallas reproduction of Benson, Gleich & Demmel,
//! *"Direct QR factorizations for tall-and-skinny matrices in MapReduce
//! architectures"* (IEEE BigData 2013).
//!
//! The system is a seven-layer stack:
//!
//! * **L7 ([`client::tcp`] + [`client::net`]) — the network layer.** A
//!   [`client::TcpServer`] (`mrtsqr serve --listen <addr>`) serves the
//!   L6 wire protocol over TCP: one long-lived engine pool, one DFS
//!   and one retained job registry shared across every connection. A
//!   [`client::TcpTransport`]
//!   ([`session::SessionBuilder::connect`]) drives one or more such
//!   hosts through the same [`client::Transport`] seam — a `NetRouter`
//!   lifts placement across hosts, periodic health checks route `Auto`
//!   jobs around dead and lagging servers, per-request deadlines mark
//!   silent hosts suspect, and a dropped connection *parks* its
//!   in-flight jobs for reconnect-and-resubmit (the server's registry
//!   re-attaches resubmitted ids, so a mid-batch connection kill still
//!   yields bit-identical results — `rust/tests/tcp.rs`). Version
//!   mismatches are rejected at the handshake with a clean error
//!   frame. `mrtsqr batch --connect` and `mrtsqr loadgen` drive it
//!   from the CLI.
//! * **L6 ([`client`]) — the transport-agnostic serving facade.** A
//!   [`client::TsqrClient`] (built via
//!   [`session::SessionBuilder::build_client`]) hides *where* the
//!   engine pool lives behind one [`client::Transport`] seam: the
//!   `Local` transport wraps an in-process L5 service unchanged, while
//!   the `Process` transport
//!   ([`session::SessionBuilder::worker_processes`]) spawns
//!   `mrtsqr worker` child processes — one engine pool each — and
//!   speaks a versioned, length-prefixed binary wire format
//!   ([`client::wire`]) over their stdin/stdout pipes, with a
//!   reader-thread demux so any number of in-flight
//!   [`client::ClientJobHandle`]s multiplex one pipe. Jobs carry
//!   client-assigned global ids, f64s travel as exact bits, and a
//!   `ProcRouter` lifts the shard router across processes
//!   (`Placement::Pinned(k)` ≡ process `k / shards`, local shard
//!   `k % shards`) — so in-process vs cross-process is pure placement:
//!   bit-identical `R`/`Q`/Σ/`virtual_secs`/fault draws/digests
//!   (`rust/tests/client.rs`). `mrtsqr batch --worker-procs N` and the
//!   `mrtsqr serve`/`mrtsqr worker` subcommands drive it from the CLI.
//! * **L5 ([`service`]) — the serving layer.** A
//!   [`service::TsqrService`] (built from the same
//!   [`session::SessionBuilder`] via
//!   [`session::SessionBuilder::build_service`]) turns the one-caller
//!   session into a concurrent job service: `submit(&handle, request)`
//!   returns a [`service::JobHandle`] immediately, and a router places
//!   each job on one of [`session::SessionBuilder::engine_shards`]
//!   independent engine shards (least-loaded, or
//!   [`session::Placement::Pinned`]) — each shard its own lock-guarded
//!   cluster with its own DFS subtree and bounded priority-FIFO queue,
//!   all sharing one pooled backend — so jobs on different shards run
//!   with zero cross-job locking while per-job
//!   `shard-<k>/job-<id>/` DFS namespaces keep intermediates
//!   collision-free. Results are bit-identical to serial, unsharded
//!   execution. The `mrtsqr batch` subcommand drives it from a
//!   manifest (`--shards N`).
//! * **L4 ([`session`]) — the single-caller API.** A [`session::TsqrSession`]
//!   built fluently ([`session::TsqrSession::builder`]) bundles the
//!   cluster, disk model, fault policy, compute backend, and tuning
//!   knobs; matrices stream in through `ingest*` without materializing;
//!   and a single request/response pair
//!   ([`session::FactorizationRequest`] → [`session::Factorization`])
//!   serves QR, R-only, SVD, and singular values. The default `Auto`
//!   policy estimates κ₂(A) with a one-pass probe, reuses the probe's
//!   `R` for well-conditioned inputs (two passes over A total), and
//!   runs Direct TSQR otherwise — the paper's stability story turned
//!   into a scheduling decision.
//! * **L3 ([`coordinator`]) — the execution layer**: a Hadoop-like
//!   engine ([`mapreduce`]) over a simulated HDFS ([`dfs`]) with a
//!   disk-bandwidth virtual clock, running the paper's algorithms:
//!   Cholesky QR, Indirect TSQR, `A·R⁻¹` (+ iterative refinement),
//!   **Direct TSQR** (the paper's contribution), its recursive
//!   extension, Householder QR, and the TSVD extension.
//! * **L2/L1 (python, build-time only)** — per-task block computations
//!   (local Householder QR, Gram, tall×small matmul) authored as Pallas
//!   kernels inside JAX functions, AOT-lowered to HLO text once by
//!   `make artifacts`, and executed from rust via the PJRT CPU client
//!   ([`runtime`], behind the `pjrt` feature). Python is never on the
//!   request path.
//!
//! Since PR 9 the whole stack schedules **elastically** behind two
//! small knob surfaces: per-job [`session::SubmitOptions`]
//! (priority/label/placement plus `no_steal`/`quota_exempt` opt-outs,
//! set with [`session::FactorizationRequest::options`]) and pool-level
//! [`service::SchedulerConfig`]
//! ([`session::SessionBuilder::scheduler`]) — idle shards steal queued
//! jobs in `sched_key` order, `Auto` placement prefers the shard
//! already holding a chained job's input, per-label admission quotas
//! hold excess submissions fairly, and the Process transport autoscales
//! its worker-process population between configured bounds. Every knob
//! is pure scheduling: `result_digest`s are bit-identical at any
//! setting (`rust/tests/steal.rs`), and [`client::Transport::sched_tally`]
//! reports pool-wide steal/admission counters.
//!
//! Cutting across L4–L7 sits the **[`stream`] layer** (PR 8): a
//! single-pass incremental TSQR ([`stream::RFold`]) that folds each
//! arriving row-chunk into a running `R` via `[R; chunk] → qr`
//! reduction, so R/Σ of an unbounded stream is available `O(n²)` after
//! the last row lands — the paper's "slightly more than 2 passes"
//! collapses to 1 for R-only, and the raw input never exists in full.
//! [`session::TsqrSession::stream`] returns a
//! [`session::StreamingWriter`] (with `finalize_qr()` replaying
//! Direct-TSQR Q-formation from DFS-spilled chunk recipes), the
//! service makes ingestion itself a first-class async job
//! ([`service::TsqrService::ingest_async`] →
//! [`service::IngestHandle`], with dependency-aware scheduling so
//! `submit` on a still-ingesting matrix queues behind it), and the
//! wire protocol (v4) carries `IngestAsync`/`IngestStatus`/`StreamFold`
//! opcodes; `mrtsqr stream` drives it from the CLI. Streamed R/Σ bits
//! are invariant to chunk size and arrival interleaving
//! (`rust/tests/stream.rs`).
//!
//! Also cutting across the stack sits the **[`sketch`] layer**
//! (PR 10): the randomized algorithm family — a seeded Gaussian /
//! CountSketch range finder feeding a randomized truncated SVD
//! ([`sketch::randomized_svd`], `1 + power_iters` passes over `A`),
//! and sketch-and-precondition least squares
//! ([`sketch::sketched_solve`], two passes) — surfaced through the
//! same request pair as `Want::LowRank { .. }` / `Want::Solve { .. }`
//! with `algo: Randomized`. Sketch seeds ride the request (and the
//! wire payload, protocol v6) exactly like ingestion seeds, and every
//! partial sum reduces in task-id order, so the family inherits the
//! bit-identical-at-every-scaling-setting contract unchanged
//! (`rust/tests/sketch.rs`). The `Auto` policy gates sketch-vs-exact
//! on the requested rank vs. the column count (low-rank) or the
//! existing κ probe (solve).
//!
//! Pure-rust dense linear algebra ([`linalg`]) provides the serial
//! `n×n` steps the paper runs on a single node (Cholesky, `R⁻¹`,
//! Jacobi SVD) and an independent correctness oracle. Since PR 7 it is
//! also the native hot path: a register-tiled f64 gemm microkernel
//! ([`linalg::gemm`]) behind [`Matrix::matmul`]/`gram`, a blocked
//! compact-WY Householder panel QR ([`linalg::blocked_qr`]) behind
//! [`linalg::householder_qr`] whose `R` is *bitwise identical* to the
//! textbook reference at every panel width
//! ([`session::SessionBuilder::panel_block`] is therefore a pure speed
//! knob, outside the digest contract like `host_threads`), a batched
//! [`runtime::BlockCompute::factor_blocks`] entry the engine's map
//! waves dispatch through ([`mapreduce::MapTask::run_batch`]), and an
//! opt-in κ-gated mixed-precision step-1 path
//! ([`session::SessionBuilder::mixed_precision`], recorded in the
//! `auto-select` marker because it changes bits where it fires).
//! `rust/tests/kernels.rs` enforces all of these contracts end to end.
//!
//! # Execution model: virtual vs host parallelism
//!
//! The *virtual* schedule (the paper's `m_max`/`r_max` slots) is what
//! `virtual_secs` and every reproduced table measure; the *host* thread
//! pool ([`mapreduce::ClusterConfig::host_threads`], exposed as
//! [`session::SessionBuilder::host_threads`]) is what actually executes
//! task bodies, wall-clock-parallel on real cores. The whole stack is
//! `Send + Sync` — [`runtime::BlockCompute`] backends are shared as
//! [`runtime::SharedCompute`] (`Arc<dyn BlockCompute + Send + Sync>`)
//! across sessions and worker threads — and the engine guarantees
//! bit-identical outputs, fault draws, and metrics (wall-clock fields
//! aside) at every pool size; `rust/tests/parallel.rs` enforces it.
//!
//! ```no_run
//! use mrtsqr::session::{FactorizationRequest, TsqrSession};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = TsqrSession::builder().build()?;
//! let a = session.ingest_gaussian("A", 100_000, 25, 42)?;
//! let fact = session.factorize(&a, &FactorizationRequest::qr())?;
//! println!("{} ran in {:.1} virtual s", fact.algorithm.name(), fact.stats.virtual_secs());
//! # Ok(())
//! # }
//! ```

pub mod client;
pub mod coordinator;
pub mod dfs;
pub mod linalg;
pub mod mapreduce;
pub mod perfmodel;
pub mod runtime;
pub mod service;
pub mod session;
pub mod sketch;
pub mod stream;
pub mod util;
pub mod workload;

pub use client::{ClientJobHandle, Transport, TsqrClient};
pub use coordinator::{Algorithm, Coordinator, MatrixHandle};
pub use linalg::Matrix;
pub use service::{
    IngestHandle, IngestRecipe, JobHandle, JobId, JobKind, JobStatus, SchedTally,
    SchedulerConfig, TsqrService,
};
pub use session::{
    Backend, Factorization, FactorizationRequest, Placement, Priority, SubmitOptions,
    TsqrSession,
};
pub use sketch::{SketchKind, SketchOptions};
