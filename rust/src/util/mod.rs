//! Small self-contained utilities.
//!
//! The build environment is offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (`rand`,
//! `clap`, `criterion`, `proptest`, `serde`) are unavailable. This
//! module hand-rolls the minimal versions the project needs.

pub mod bench;
pub mod cli;
pub mod digest;
pub mod experiments;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;

pub use rng::Rng;
