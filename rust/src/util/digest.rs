//! FNV-1a digest of a factorization's numerical content.
//!
//! One digest definition is shared by the batch path
//! ([`crate::session::Factorization::result_digest`]) and the streaming
//! path ([`crate::stream::result_digest`]) so CI can diff the two
//! families of reports with the same `grep result_digest | diff`
//! recipe. The digest covers `R`'s shape and exact f64 bit patterns
//! plus Σ when present — wall-clock and scheduling metadata are
//! excluded on purpose.

use crate::linalg::Matrix;

/// FNV-1a over `R`'s shape + exact bits, then Σ (when present).
///
/// Two results agree on this hex string iff their factors are
/// bit-identical.
pub fn r_sigma_digest(r: &Matrix, sigma: Option<&[f64]>) -> String {
    full_digest(r, sigma, None)
}

/// [`r_sigma_digest`] extended with an optional least-squares solution
/// block (PR 10's `Want::Solve`). When `solution` is `None` the digested
/// byte stream is identical to the pre-extension definition, so every
/// existing digest — QR, SVD, streaming — is unchanged.
pub fn full_digest(r: &Matrix, sigma: Option<&[f64]>, solution: Option<&Matrix>) -> String {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= u64::from(b);
            h = h.wrapping_mul(FNV_PRIME);
        }
    };
    eat(&(r.rows as u64).to_le_bytes());
    eat(&(r.cols as u64).to_le_bytes());
    for v in &r.data {
        eat(&v.to_bits().to_le_bytes());
    }
    if let Some(sigma) = sigma {
        eat(&(sigma.len() as u64).to_le_bytes());
        for v in sigma {
            eat(&v.to_bits().to_le_bytes());
        }
    }
    if let Some(x) = solution {
        eat(&(x.rows as u64).to_le_bytes());
        eat(&(x.cols as u64).to_le_bytes());
        for v in &x.data {
            eat(&v.to_bits().to_le_bytes());
        }
    }
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_solution_preserves_legacy_digest() {
        let r = Matrix::from_fn(2, 2, |i, j| (i * 2 + j) as f64);
        let sigma = [3.0, 1.0];
        assert_eq!(
            r_sigma_digest(&r, Some(&sigma)),
            full_digest(&r, Some(&sigma), None)
        );
        let x = Matrix::from_fn(2, 1, |i, _| i as f64);
        assert_ne!(
            full_digest(&r, Some(&sigma), Some(&x)),
            full_digest(&r, Some(&sigma), None)
        );
    }
}
