//! Minimal JSON emission (serde is not vendored offline).
//!
//! Write-only: enough to serialize bench/batch reports
//! (`BENCH_*.json`, `mrtsqr batch --json`) with stable field order.

use std::fmt::Write as _;

/// A JSON value tree. Build with the constructors, render with
/// [`Json::render`].
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Field order is preserved as inserted.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn obj(fields: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Serialize compactly (no insignificant whitespace beyond `": "`
    /// and `", "`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // integers print without a trailing ".0"
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    // JSON has no NaN/Inf
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    Json::Str(k.clone()).write(out);
                    out.push_str(": ");
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_structures() {
        let j = Json::obj([
            ("name", Json::str("batch")),
            ("jobs", Json::num(4)),
            ("speedup", Json::num(1.75)),
            ("quick", Json::Bool(true)),
            ("none", Json::Null),
            ("ids", Json::arr([Json::num(0), Json::num(1)])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"name": "batch", "jobs": 4, "speedup": 1.75, "quick": true, "none": null, "ids": [0, 1]}"#
        );
    }

    #[test]
    fn escapes_strings_and_nonfinite_numbers() {
        assert_eq!(Json::str("a\"b\\c\nd").render(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::str("\u{1}").render(), "\"\\u0001\"");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(Json::num(3.0).render(), "3");
        assert_eq!(Json::num(1e18).render(), "1000000000000000000");
    }
}
