//! Tiny benchmarking harness (criterion is not vendored offline).
//!
//! Provides warmup + median-of-k wall timing with spread reporting.
//! Bench targets are `harness = false` binaries that print paper-shaped
//! tables via [`crate::util::table`].

use std::time::Instant;

/// Result of a timed measurement.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub median_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
    pub iters: usize,
}

impl Sample {
    pub fn throughput(&self, units: f64) -> f64 {
        units / self.median_secs
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Sample {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Sample {
        median_secs: times[times.len() / 2],
        min_secs: times[0],
        max_secs: *times.last().unwrap(),
        iters,
    }
}

/// One-shot wall timing of `f`, returning (result, seconds).
pub fn once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// `--quick` mode helper: benches honor MRTSQR_BENCH_QUICK=1 to shrink
/// workloads (used by CI / `cargo bench` smoke runs).
pub fn quick_mode() -> bool {
    std::env::var("MRTSQR_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_orders_samples() {
        let s = time(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_secs <= s.median_secs && s.median_secs <= s.max_secs);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn once_returns_value() {
        let (v, secs) = once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
