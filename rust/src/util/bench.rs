//! Tiny benchmarking harness (criterion is not vendored offline).
//!
//! Provides warmup + median-of-k wall timing with spread reporting.
//! Bench targets are `harness = false` binaries that print paper-shaped
//! tables via [`crate::util::table`].

use std::time::Instant;

/// Result of a timed measurement.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub median_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
    pub iters: usize,
}

impl Sample {
    pub fn throughput(&self, units: f64) -> f64 {
        units / self.median_secs
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Sample {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Sample {
        median_secs: times[times.len() / 2],
        min_secs: times[0],
        max_secs: *times.last().unwrap(),
        iters,
    }
}

/// One-shot wall timing of `f`, returning (result, seconds).
pub fn once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// `--quick` mode helper: benches honor MRTSQR_BENCH_QUICK=1 to shrink
/// workloads (used by CI / `cargo bench` smoke runs).
pub fn quick_mode() -> bool {
    std::env::var("MRTSQR_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick")
}

/// `--host-threads N` (or `MRTSQR_HOST_THREADS=N`) override for bench
/// harnesses: how many OS threads execute task bodies in the parallel
/// leg of the wall-clock comparison. `None` = the engine default
/// (available parallelism). Purely a wall-clock knob — virtual times
/// are bit-identical at any value.
pub fn host_threads_arg() -> Option<usize> {
    parse_host_threads(std::env::args())
        .or_else(|| std::env::var("MRTSQR_HOST_THREADS").ok().and_then(|v| v.parse().ok()))
}

/// `--<name> VALUE` / `--<name>=VALUE` lookup in this process's argv
/// (bench harnesses are plain binaries without a CLI parser; the
/// BENCH-trajectory `--bench-json PATH` flag uses this).
pub fn arg_value(name: &str) -> Option<String> {
    parse_arg_value(std::env::args(), name)
}

/// Argv-scanning cores of [`host_threads_arg`] / [`arg_value`], split
/// out so they can be tested on a synthetic token list (mutating the
/// real process env from a test races the multi-threaded test harness).
fn parse_host_threads<I: Iterator<Item = String>>(args: I) -> Option<usize> {
    parse_arg_value(args, "host-threads").and_then(|v| v.parse().ok())
}

fn parse_arg_value<I: Iterator<Item = String>>(mut args: I, name: &str) -> Option<String> {
    let flag = format!("--{name}");
    let prefix = format!("--{name}=");
    while let Some(a) = args.next() {
        if a == flag {
            return args.next();
        }
        if let Some(v) = a.strip_prefix(&prefix) {
            return Some(v.to_string());
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_orders_samples() {
        let s = time(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_secs <= s.median_secs && s.median_secs <= s.max_secs);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn once_returns_value() {
        let (v, secs) = once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn host_threads_flag_parsing() {
        let parse = |toks: &[&str]| {
            parse_host_threads(toks.iter().map(|s| s.to_string()))
        };
        assert_eq!(parse(&["bench", "--host-threads", "6"]), Some(6));
        assert_eq!(parse(&["bench", "--host-threads=12", "--quick"]), Some(12));
        assert_eq!(parse(&["bench", "--quick"]), None);
        assert_eq!(parse(&["--host-threads", "zero?"]), None);
        assert_eq!(parse(&["--host-threads"]), None);
    }

    #[test]
    fn generic_arg_value_parsing() {
        let parse = |toks: &[&str], name: &str| {
            parse_arg_value(toks.iter().map(|s| s.to_string()), name)
        };
        assert_eq!(
            parse(&["bench", "--bench-json", "out.json"], "bench-json").as_deref(),
            Some("out.json")
        );
        assert_eq!(
            parse(&["--bench-json=B.json", "--quick"], "bench-json").as_deref(),
            Some("B.json")
        );
        assert_eq!(parse(&["--quick"], "bench-json"), None);
    }
}
