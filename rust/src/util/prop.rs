//! Mini property-testing helper (proptest is not vendored offline).
//!
//! `check` runs a property over `cases` seeded random inputs produced by
//! a generator; on failure it reports the seed and the debug-printed
//! input so the case can be replayed deterministically (set
//! `MRTSQR_PROP_SEED` to pin the base seed).

use super::rng::Rng;
use std::fmt::Debug;

/// Number of cases per property (override with MRTSQR_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("MRTSQR_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32)
}

fn base_seed() -> u64 {
    std::env::var("MRTSQR_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `prop` over `cases` inputs drawn from `gen`. Panics with the
/// replay seed on the first failing case.
pub fn check<T: Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (replay: MRTSQR_PROP_SEED={base}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Convenience: assert two f64s are within `tol` (absolute + relative).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 16,
            |r| (r.uniform(), r.uniform()),
            |&(a, b)| close(a + b, b + a, 0.0),
        );
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        check("always-fails", 4, |r| r.next_u64(), |_| Err("no".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1.0, 2.0, 1e-9).is_err());
        assert!(close(1e9, 1e9 + 1.0, 1e-6).is_ok()); // relative
    }
}
