//! Deterministic PRNG (xoshiro256**) + gaussian sampling.
//!
//! `rand` is not vendored offline; this is the standard xoshiro256**
//! generator (Blackman & Vigna), plenty for workload generation, fault
//! injection and property tests — everything here is seeded and
//! reproducible by construction.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second gaussian from Box-Muller
    spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) (bound > 0), unbiased enough for
    /// simulation purposes.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fork an independent stream (for per-task fault decisions).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut lo = false;
        let mut hi = false;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            lo |= u < 0.1;
            hi |= u > 0.9;
        }
        assert!(lo && hi);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn chance_frequency() {
        let mut r = Rng::new(5);
        let hits = (0..100_000).filter(|_| r.chance(0.125)).count();
        let freq = hits as f64 / 100_000.0;
        assert!((freq - 0.125).abs() < 0.005, "freq {freq}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(9);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
