//! Shared experiment runners behind the paper-table benches.
//!
//! Tables VI/VII/VIII/IX all derive from one sweep (job times per
//! algorithm per workload); this module runs it once per bench binary
//! and lets each bench print its own view. Everything goes through the
//! [`crate::session`] layer — one resolved backend (see
//! [`crate::session::Backend::resolve`]) is shared across all the
//! per-measurement sessions so PJRT executables compile once.

use crate::coordinator::{householder, indirect_tsqr, Algorithm, MatrixHandle};
use crate::dfs::DiskModel;
use crate::linalg::Matrix;
use crate::mapreduce::JobStats;
use crate::perfmodel::{lower_bound_secs, AlgoKind, StageParallelism, WorkloadShape};
use crate::runtime::SharedCompute;
use crate::session::{FactorizationRequest, TsqrSession};
use crate::workload::{paper_workloads, ScaledWorkload};
use anyhow::Result;

/// One (workload, algorithm) measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub workload: ScaledWorkload,
    pub algo: Algorithm,
    pub virtual_secs: f64,
    pub wall_secs: f64,
    pub stats: JobStats,
    /// Model lower bound at paper scale with the engine's betas.
    pub t_lb: f64,
}

impl Measurement {
    /// Paper Table VII metric: `2·m·n²/t` at paper scale.
    pub fn flops_per_sec(&self) -> f64 {
        let shape = WorkloadShape::new(self.workload.paper_rows, self.workload.cols as u64, 1);
        shape.flops() / self.virtual_secs
    }

    pub fn multiple_of_lb(&self) -> f64 {
        self.virtual_secs / self.t_lb
    }
}

/// Default workload scale for benches (paper rows / this): QUICK mode
/// shrinks further.
pub fn bench_scale() -> u64 {
    if crate::util::bench::quick_mode() {
        40_000
    } else {
        4_000
    }
}

/// Map-task counts mirroring the paper's Table IV exactly. Running the
/// paper's *real* task counts (1200–2640) is what makes the per-file
/// virtual-byte scaling honest: every `O(m1·n²)` factor file (the step-1
/// R blocks, the step-2 Q² side file) then has paper-scale actual size
/// and is charged at scale 1, while only the `O(m·n)` matrix files carry
/// the workload scale.
fn map_tasks_for(w: &ScaledWorkload, direct: bool) -> usize {
    let paper = if direct { w.m1_direct } else { w.m1_indirect } as usize;
    paper.min(w.rows).max(1)
}

/// Run `limit` columns of MapReduce Householder and extrapolate the
/// virtual time to the input's full width — the paper's own method for
/// its Table VI `*` entries. Returns `(extrapolated secs, stats)`.
pub fn householder_extrapolated(
    session: &mut TsqrSession,
    input: &MatrixHandle,
    limit: usize,
) -> Result<(f64, JobStats)> {
    let cols_run = limit.min(input.cols).max(1);
    let (_, stats) =
        session.with_coordinator(|c| householder::householder_r(c, input, Some(cols_run)))?;
    // extrapolate: norm pass + per-column cost × n
    let norm_pass = stats.steps[0].virtual_secs;
    let per_col = (stats.virtual_secs() - norm_pass) / cols_run as f64;
    Ok((norm_pass + per_col * input.cols as f64, stats))
}

/// Indirect-TSQR `R` through the session with an explicit reduction-tree
/// depth (the `ablation_tree` bench's knob; paper §II-B).
pub fn indirect_r_with_tree(
    session: &mut TsqrSession,
    input: &MatrixHandle,
    two_level: bool,
) -> Result<(Matrix, JobStats)> {
    session.with_coordinator(|c| {
        if two_level {
            indirect_tsqr::indirect_r(c, input)
        } else {
            indirect_tsqr::indirect_r_single_level(c, input)
        }
    })
}

/// Run one algorithm on one scaled workload with paper-scale virtual
/// byte accounting. Householder runs 4 columns and extrapolates (the
/// paper's own method for Table VI).
pub fn run_one(
    compute: SharedCompute,
    w: &ScaledWorkload,
    algo: Algorithm,
    beta_r: f64,
    beta_w: f64,
) -> Result<Measurement> {
    let model = DiskModel {
        beta_r,
        beta_w,
        byte_scale: 1.0, // per-file scales below, not a global multiplier
        iteration_startup_secs: 15.0,
        task_startup_secs: 2.0,
    };
    let is_direct = matches!(algo, Algorithm::DirectTsqr);
    let tasks = map_tasks_for(w, is_direct);
    let mut session = TsqrSession::builder()
        .disk_model(model)
        .compute(compute)
        .rows_per_task((w.rows / tasks).max(1))
        .build()?;
    let input = session.ingest_gaussian("A", w.rows, w.cols, 0xBEEF ^ w.cols as u64)?;
    // the matrix (and the Q files derived from it) are O(m·n): charge at
    // the workload scale so virtual times land in paper units
    session.set_scale("A", w.byte_scale);

    let t0 = std::time::Instant::now();
    let (virtual_secs, stats) = if algo == Algorithm::Householder {
        householder_extrapolated(&mut session, &input, 4)?
    } else {
        let res =
            session.factorize(&input, &FactorizationRequest::qr().with_algorithm(algo))?;
        (res.stats.virtual_secs(), res.stats)
    };
    let wall_secs = t0.elapsed().as_secs_f64();

    // model bound at paper scale (paper Table IV m1 counts)
    let m1 = if is_direct { w.m1_direct } else { w.m1_indirect };
    let shape = WorkloadShape::new(w.paper_rows, w.cols as u64, m1);
    let t_lb = lower_bound_secs(algo.kind(), &shape, &StageParallelism::default(), beta_r, beta_w);

    Ok(Measurement { workload: *w, algo, virtual_secs, wall_secs, stats, t_lb })
}

/// The six algorithms of the paper's Table VI, in its column order. (The
/// fused §VI variant is in [`Algorithm::ALL`] but measured separately by
/// the `ablation_fused` bench — the paper never timed it.)
pub const TABLE6_ALGOS: [Algorithm; 6] = [
    Algorithm::Cholesky { refine: false },
    Algorithm::IndirectTsqr { refine: false },
    Algorithm::Cholesky { refine: true },
    Algorithm::IndirectTsqr { refine: true },
    Algorithm::DirectTsqr,
    Algorithm::Householder,
];

/// The full Table VI sweep: all six algorithms × the five workloads.
pub fn run_table6_sweep(
    compute: SharedCompute,
    beta_r: f64,
    beta_w: f64,
) -> Result<Vec<Measurement>> {
    let mut out = Vec::new();
    for w in paper_workloads(bench_scale()) {
        for algo in TABLE6_ALGOS {
            out.push(run_one(compute.clone(), &w, algo, beta_r, beta_w)?);
        }
    }
    Ok(out)
}

/// The paper's measured Table VI numbers, for side-by-side printing.
pub fn paper_table6(algo: AlgoKind, paper_rows: u64) -> Option<f64> {
    let idx = match paper_rows {
        4_000_000_000 => 0,
        2_500_000_000 => 1,
        600_000_000 => 2,
        500_000_000 => 3,
        150_000_000 => 4,
        _ => return None,
    };
    let col: [f64; 5] = match algo {
        AlgoKind::Cholesky => [2931.0, 2508.0, 1098.0, 1563.0, 921.0],
        AlgoKind::IndirectTsqr => [4076.0, 2509.0, 1104.0, 1618.0, 954.0],
        AlgoKind::CholeskyIr => [5832.0, 5011.0, 2221.0, 3204.0, 1878.0],
        AlgoKind::IndirectTsqrIr => [7431.0, 5052.0, 2235.0, 3298.0, 1960.0],
        AlgoKind::DirectTsqr => [6128.0, 4035.0, 1910.0, 3090.0, 2154.0],
        AlgoKind::Householder => [15021.0, 32950.0, 37388.0, 117775.0, 133025.0],
        // §VI variant was proposed, never measured by the paper
        AlgoKind::DirectTsqrFused => return None,
    };
    Some(col[idx])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeRuntime;

    fn native() -> SharedCompute {
        std::sync::Arc::new(NativeRuntime::new())
    }

    #[test]
    fn run_one_direct_smoke() {
        let w = ScaledWorkload {
            paper_rows: 4_000_000_000,
            cols: 4,
            rows: 4000,
            byte_scale: 1_000_000.0,
            m1_indirect: 1200,
            m1_direct: 2000,
        };
        let m = run_one(native(), &w, Algorithm::DirectTsqr, 64e-9, 126e-9).unwrap();
        assert!(m.virtual_secs > 0.0);
        assert!(m.t_lb > 0.0);
        assert!(m.flops_per_sec() > 0.0);
    }

    #[test]
    fn householder_extrapolates() {
        let w = ScaledWorkload {
            paper_rows: 600_000_000,
            cols: 25,
            rows: 2000,
            byte_scale: 300_000.0,
            m1_indirect: 1200,
            m1_direct: 1600,
        };
        let m = run_one(native(), &w, Algorithm::Householder, 64e-9, 126e-9).unwrap();
        // only 4 columns actually ran (1 + 2*4 = 9 steps), but the time
        // reflects all 25
        assert_eq!(m.stats.steps.len(), 9);
        assert!(m.virtual_secs > m.stats.virtual_secs());
    }

    #[test]
    fn table6_algos_match_the_paper_column_order() {
        assert_eq!(TABLE6_ALGOS.len(), 6);
        assert!(!TABLE6_ALGOS.contains(&Algorithm::DirectTsqrFused));
        for algo in TABLE6_ALGOS {
            assert!(paper_table6(algo.kind(), 4_000_000_000).is_some(), "{algo:?}");
        }
    }

    #[test]
    fn paper_table6_lookup() {
        assert_eq!(paper_table6(AlgoKind::DirectTsqr, 2_500_000_000), Some(4035.0));
        assert_eq!(paper_table6(AlgoKind::Householder, 7), None);
    }
}
