//! Plain-text table rendering for the bench harnesses.
//!
//! Every paper table/figure bench prints rows in the same layout as the
//! paper so the reproduction can be eyeballed side-by-side.

/// Column-aligned text table.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$}  ", c, w = width[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let total: usize = width.iter().sum::<usize>() + 2 * ncol;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Human-readable count like `4,000,000,000`.
pub fn commas(v: u64) -> String {
    let s = v.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Scientific notation like the paper's `2.09e+07`.
pub fn sci(v: f64) -> String {
    format!("{:.2e}", v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(&["1".into(), "22222".into()]);
        t.row(&["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    #[should_panic]
    fn ragged_row_panics() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn commas_format() {
        assert_eq!(commas(4_000_000_000), "4,000,000,000");
        assert_eq!(commas(150), "150");
        assert_eq!(commas(1_000), "1,000");
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(2.09e7), "2.09e7");
    }
}
