//! Minimal CLI argument parsing (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

/// Parsed command line: subcommand, options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand if it
    /// does not start with `-`).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_opts() {
        // note: a bare `--flag value` pair is ambiguous in this minimal
        // parser (the next token is consumed as the value), so flags go
        // last or use `--flag=1`
        let a = parse("run --rows 1000 --cols=8 input.mat --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_usize("rows", 0), 1000);
        assert_eq!(a.get_usize("cols", 0), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["input.mat"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --quick");
        assert!(a.flag("quick"));
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("algo", "direct"), "direct");
        assert_eq!(a.get_f64("beta", 1.5), 1.5);
        assert!(!a.flag("nope"));
    }

    #[test]
    fn negative_number_value() {
        let a = parse("run --shift -3");
        // "-3" does not start with "--" so it is consumed as a value
        assert_eq!(a.get("shift"), Some("-3"));
    }
}
