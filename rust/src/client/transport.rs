//! The transport seam: one trait, two ways to reach an engine pool.
//!
//! A [`crate::client::TsqrClient`] never talks to a
//! [`crate::service::TsqrService`] directly — it talks to a
//! [`Transport`], and the transport decides where the pool lives:
//!
//! * [`LocalTransport`] wraps an in-process sharded `TsqrService`.
//!   Every call is a direct delegation — no serialization, no copies,
//!   zero behavior change; a client over this transport is bit-identical
//!   to calling the service itself (`rust/tests/client.rs`).
//! * [`crate::client::ProcessTransport`] spawns `mrtsqr worker` child
//!   processes (one engine pool each) and speaks the
//!   [`crate::client::wire`] protocol over their stdin/stdout pipes.
//!
//! The trait's job ids are **caller-assigned**: the client allocates a
//! globally increasing [`JobId`] and every transport must run the job
//! under exactly that id (namespace `job-<id>/`, per-job fault stream).
//! That is the determinism hinge — a job's fault draws and DFS
//! namespace depend only on its id, so in-process and cross-process
//! placements of the same submission order produce bit-identical
//! results.

use crate::coordinator::MatrixHandle;
use crate::linalg::Matrix;
use crate::service::{
    IngestHandle, IngestRecipe, JobHandle, JobId, JobStatus, SchedTally, TsqrService,
};
use crate::session::{Factorization, FactorizationRequest, Placement};
use anyhow::{bail, Result};
use std::sync::Arc;

/// One submitted job as seen through a transport: poll or block for its
/// result. Implementations: a thin wrapper over
/// [`crate::service::JobHandle`] (local), or a slot filled by the pipe
/// reader thread (process).
pub trait TransportJob: Send + Sync {
    fn id(&self) -> JobId;
    fn label(&self) -> Option<&str>;
    fn status(&self) -> JobStatus;
    /// Block until terminal; `Ok` carries the shared factorization.
    fn wait(&self) -> Result<Arc<Factorization>>;
    /// `None` while queued/running, `Some(result)` once terminal.
    fn try_result(&self) -> Option<Result<Arc<Factorization>>>;
    /// Cancel if not yet running; `true` on success.
    fn cancel(&self) -> bool;
    /// Measured running→terminal wall seconds (`None` until then; on a
    /// process transport, measured worker-side).
    fn wall_secs(&self) -> Option<f64>;
}

/// One queued ingestion job as seen through a transport (PR 8's async
/// ingest). The [`MatrixHandle`] is valid for dependent submissions
/// immediately; the rows land when the job runs. Implementations: a
/// thin wrapper over [`crate::service::IngestHandle`] (local), or a
/// status-polling proxy over the wire (process/tcp).
pub trait TransportIngest: Send + Sync {
    fn id(&self) -> JobId;
    /// The matrix the ingestion will produce (usable right away).
    fn handle(&self) -> MatrixHandle;
    fn status(&self) -> JobStatus;
    /// Block until the rows are durably on their home shard.
    fn wait(&self) -> Result<MatrixHandle>;
    /// Cancel if not yet running; `true` on success. Dependent jobs
    /// then fail with a precise dependency error.
    fn cancel(&self) -> bool;
}

/// Where a client's engine pool lives and how to reach it. All methods
/// take `&self`: a transport is shared by every handle the client gives
/// out. See the [module docs](self) for the two implementations and the
/// caller-assigned-id contract.
pub trait Transport: Send + Sync {
    /// Worker processes behind this transport (1 means in-process).
    fn procs(&self) -> usize;
    /// Total engine shards across all processes.
    fn shards(&self) -> usize;
    /// Total service worker threads across all processes.
    fn workers(&self) -> usize;
    /// Bounded per-shard queue capacity.
    fn capacity(&self) -> usize;
    /// Resolved compute backend name ("native", "pjrt", "custom").
    fn backend_desc(&self) -> String;
    /// Host threads each job's waves fan out on (per process).
    fn host_threads(&self) -> usize;

    /// Ingest a seeded gaussian matrix. `placement` pins the *global*
    /// shard the rows land on ([`Placement::Auto`] = the home shard,
    /// process 0 / shard 0).
    fn ingest_gaussian(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<MatrixHandle>;

    /// Ingest an in-memory matrix (exact bits; chunked on the wire).
    fn ingest_matrix(&self, name: &str, a: &Matrix, placement: Placement)
        -> Result<MatrixHandle>;

    /// Queue a seeded gaussian ingestion as a first-class async job
    /// under the caller-assigned global `id` and return immediately.
    /// `submit` on the returned handle's matrix queues behind the
    /// ingestion via a dependency edge and runs bit-identically to
    /// ingest-then-submit.
    fn ingest_gaussian_async(
        &self,
        id: JobId,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<Box<dyn TransportIngest>>;

    /// Run `req` on `input` under the caller-assigned global `id`.
    /// `req.placement` names a *global* shard index; transports map it
    /// to their own topology.
    fn submit(
        &self,
        id: JobId,
        input: &MatrixHandle,
        req: FactorizationRequest,
    ) -> Result<Box<dyn TransportJob>>;

    /// Read a handle's rows back from whichever shard/process holds it.
    fn get_matrix(&self, handle: &MatrixHandle) -> Result<Matrix>;

    /// Mark a DFS file's virtual byte scale everywhere it is known.
    fn set_scale(&self, name: &str, scale: f64) -> Result<()>;

    /// Sweep one finished job's DFS namespace; returns files removed.
    fn evict_job(&self, id: JobId) -> Result<usize>;

    /// Run queued jobs on the calling thread (deterministic serial
    /// baseline). Only the local transport can: a pipe has no way to
    /// lend the caller's thread to another process.
    fn drain_now(&self) -> Result<usize>;

    /// Global shard index a job was placed on, where known (local:
    /// immediately; process: once the job completed).
    fn shard_of(&self, id: JobId) -> Option<usize>;

    /// Elastic-scheduling counters, aggregated across the whole pool:
    /// per-*global*-shard steal counts plus per-label admission-hold
    /// tallies (merged by label across processes/hosts).
    fn sched_tally(&self) -> Result<SchedTally>;

    /// Fault-injection hook: kill worker process `proc` outright (no
    /// graceful shutdown), as if the OS OOM-killed it. Errors on a
    /// local transport — there is no process to kill. In-flight jobs on
    /// that worker fail; every other worker keeps serving.
    fn kill_worker(&self, proc: usize) -> Result<()>;

    /// Graceful shutdown (reject new work, drain, reap children).
    fn shutdown(&self);
}

// ----------------------------------------------------------------- local

/// [`TransportJob`] over an in-process [`JobHandle`] — pure delegation.
struct LocalJob(JobHandle);

impl TransportJob for LocalJob {
    fn id(&self) -> JobId {
        self.0.id()
    }

    fn label(&self) -> Option<&str> {
        self.0.label()
    }

    fn status(&self) -> JobStatus {
        self.0.status()
    }

    fn wait(&self) -> Result<Arc<Factorization>> {
        self.0.wait()
    }

    fn try_result(&self) -> Option<Result<Arc<Factorization>>> {
        self.0.try_result()
    }

    fn cancel(&self) -> bool {
        self.0.cancel()
    }

    fn wall_secs(&self) -> Option<f64> {
        self.0.wall_secs()
    }
}

/// [`TransportIngest`] over an in-process [`IngestHandle`] — pure
/// delegation.
struct LocalIngest(IngestHandle);

impl TransportIngest for LocalIngest {
    fn id(&self) -> JobId {
        self.0.id()
    }

    fn handle(&self) -> MatrixHandle {
        self.0.handle().clone()
    }

    fn status(&self) -> JobStatus {
        self.0.status()
    }

    fn wait(&self) -> Result<MatrixHandle> {
        self.0.wait()
    }

    fn cancel(&self) -> bool {
        self.0.cancel()
    }
}

/// The in-process transport: wraps today's sharded [`TsqrService`] with
/// zero behavior change. Global shard indices *are* the service's shard
/// indices, and every operation is a direct call.
pub struct LocalTransport {
    svc: TsqrService,
}

impl LocalTransport {
    pub fn new(svc: TsqrService) -> LocalTransport {
        LocalTransport { svc }
    }
}

impl Transport for LocalTransport {
    fn procs(&self) -> usize {
        1
    }

    fn shards(&self) -> usize {
        self.svc.shards()
    }

    fn workers(&self) -> usize {
        self.svc.workers()
    }

    fn capacity(&self) -> usize {
        self.svc.capacity()
    }

    fn backend_desc(&self) -> String {
        self.svc.backend_desc().to_string()
    }

    fn host_threads(&self) -> usize {
        self.svc.host_threads()
    }

    fn ingest_gaussian(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        self.svc.ingest_gaussian_placed(name, rows, cols, seed, placement)
    }

    fn ingest_matrix(
        &self,
        name: &str,
        a: &Matrix,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        self.svc.ingest_matrix_placed(name, a, placement)
    }

    fn ingest_gaussian_async(
        &self,
        id: JobId,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<Box<dyn TransportIngest>> {
        let recipe = IngestRecipe::Gaussian { rows, seed };
        Ok(Box::new(LocalIngest(
            self.svc.ingest_async_with_id(id, name, cols, recipe, placement)?,
        )))
    }

    fn submit(
        &self,
        id: JobId,
        input: &MatrixHandle,
        req: FactorizationRequest,
    ) -> Result<Box<dyn TransportJob>> {
        Ok(Box::new(LocalJob(self.svc.submit_with_id(id, input, req)?)))
    }

    fn get_matrix(&self, handle: &MatrixHandle) -> Result<Matrix> {
        self.svc.get_matrix(handle)
    }

    fn set_scale(&self, name: &str, scale: f64) -> Result<()> {
        self.svc.set_scale(name, scale);
        Ok(())
    }

    fn evict_job(&self, id: JobId) -> Result<usize> {
        Ok(self.svc.evict_job(id))
    }

    fn drain_now(&self) -> Result<usize> {
        Ok(self.svc.drain_now())
    }

    fn shard_of(&self, id: JobId) -> Option<usize> {
        self.svc.shard_of(id)
    }

    fn sched_tally(&self) -> Result<SchedTally> {
        Ok(self.svc.sched_tally())
    }

    fn kill_worker(&self, proc: usize) -> Result<()> {
        bail!("local transport has no worker process {proc} to kill — use worker_processes(n)")
    }

    fn shutdown(&self) {
        // TsqrService shuts itself down on drop; nothing rejects earlier
        // because the client is being dropped with us anyway
    }
}
