//! The serving side of the network layer: [`TcpServer`] accepts
//! connections on a listen address and runs the
//! [`super::worker`] protocol loop over each socket — `mrtsqr serve
//! --listen <addr>` is a thin CLI wrapper around it.
//!
//! Every connection shares one pre-built [`TsqrClient`] (one engine
//! pool, one DFS, one set of virtual clocks) and one job registry in
//! `retain_jobs` mode: a job's registry entry survives its terminal
//! push until `Evict`, so a client that reconnects mid-batch and
//! resubmits under the same ids *re-attaches* to jobs the dropped
//! connection started — a still-running job gains the new connection
//! as its push target, a finished one re-pushes its result
//! immediately, and determinism makes either path bit-identical to an
//! undisturbed run.
//!
//! One caveat the registry's shape imposes: jobs are keyed by the
//! peer-assigned id alone, so one server expects one *logical* client
//! (or clients that partition the id space). That is the topology the
//! [`super::net::TcpTransport`] builds — it is the only writer to the
//! hosts it connects.

use super::worker::{serve_connection, SharedServe};
use super::TsqrClient;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// A listening server wrapping one [`TsqrClient`]: one accept thread,
/// one session thread per connection, all sharing the client and the
/// retained job registry.
pub struct TcpServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    /// Socket clones of the live sessions (keyed by session id; each
    /// session reclaims its own entry on exit), so shutdown can sever
    /// sessions blocked reading.
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    sessions: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl TcpServer {
    /// Bind `addr` (`"127.0.0.1:0"` picks a free port — read it back
    /// with [`TcpServer::local_addr`]) and start accepting. The server
    /// owns `client`; it keeps serving until [`TcpServer::shutdown`]
    /// or drop.
    pub fn bind(client: TsqrClient, addr: &str) -> Result<TcpServer> {
        let shared = SharedServe::new(Arc::new(client));
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr:?}"))?;
        let local_addr = listener.local_addr().context("reading the bound address")?;
        // non-blocking accept so shutdown doesn't wait for one more
        // connection that never comes
        listener.set_nonblocking(true).context("non-blocking listener")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let sessions: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            let sessions = sessions.clone();
            std::thread::Builder::new()
                .name("mrtsqr-accept".into())
                .spawn(move || accept_loop(&listener, &shared, &stop, &conns, &sessions))
                .expect("spawn accept thread")
        };
        Ok(TcpServer { local_addr, stop, accept: Some(accept), conns, sessions })
    }

    /// The address the listener actually bound (resolves `:0` ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, sever every live session socket, and join the
    /// session threads (each joins its job notifiers, so in-flight
    /// jobs run to completion before this returns). Idempotent.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        for (_, stream) in self.conns.lock().expect("server connections").drain() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        let sessions: Vec<JoinHandle<()>> =
            self.sessions.lock().expect("server sessions").drain(..).collect();
        for session in sessions {
            let _ = session.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    shared: &SharedServe,
    stop: &AtomicBool,
    conns: &Arc<Mutex<HashMap<u64, TcpStream>>>,
    sessions: &Mutex<Vec<JoinHandle<()>>>,
) {
    let mut next_session = 0u64;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                let _ = stream.set_nodelay(true);
                let session_id = next_session;
                next_session += 1;
                if let Ok(clone) = stream.try_clone() {
                    conns.lock().expect("server connections").insert(session_id, clone);
                }
                let shared = shared.clone();
                let conns = conns.clone();
                let session = std::thread::Builder::new()
                    .name(format!("mrtsqr-session-{peer}"))
                    .spawn(move || {
                        // per-connection errors (including version
                        // mismatches, answered with a clean Err frame
                        // inside the loop) end this session only — the
                        // server keeps serving
                        if let Ok(read_half) = stream.try_clone() {
                            let _ = serve_connection(
                                BufReader::new(read_half),
                                stream,
                                Some(shared),
                                true,
                            );
                        }
                        conns.lock().expect("server connections").remove(&session_id);
                    });
                if let Ok(session) = session {
                    let mut guard = sessions.lock().expect("server sessions");
                    guard.retain(|h| !h.is_finished());
                    guard.push(session);
                }
            }
            Err(err) if err.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(15));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(15)),
        }
    }
}
