//! The cross-process transport: `mrtsqr worker` child processes
//! speaking the [`super::wire`] protocol over stdin/stdout pipes.
//!
//! One [`ProcessTransport`] owns `worker_processes(n)` children. Each
//! child runs its own engine pool (its own DFS shards, its own virtual
//! clocks, one [`crate::service::TsqrService`]) configured identically
//! to the parent's recipe via the `Hello` handshake — which is why
//! results are bit-identical to an in-process run: a job's namespace
//! and fault stream depend only on its caller-assigned global id, and
//! the wire format ships every `f64` as exact bits.
//!
//! # Demultiplexing
//!
//! All traffic with one worker flows over a single pipe pair, so many
//! in-flight [`crate::client::ClientJobHandle`]s must share it. Writes
//! are serialized by a mutex; reads are owned by one **reader thread**
//! per worker that routes each incoming frame by its correlation id:
//! ordinary replies go to the `ReplySlot` registered by the blocked
//! request, and pushed job-completion frames ([`wire::Op::JobDone`] /
//! [`wire::Op::JobFail`], `req_id 0`) go to the `RemoteJob` slot
//! registered at submission. When the pipe dies — worker killed,
//! crashed, or OOMed — the reader fails every pending request and every
//! in-flight job *of that worker only*; other workers keep serving
//! (the process-level mirror of the poisoned-shard isolation test).
//!
//! # Routing
//!
//! `ProcRouter` lifts the PR-4 shard router one level: a global shard
//! index `k` names `(process k / shards_per_proc, local shard k %
//! shards_per_proc)`, `Placement::Pinned(k)` maps accordingly, and
//! `Placement::Auto` picks the least-loaded *live* process
//! (deterministic job-id tie-break) and lets that worker's own router
//! pick among its local shards. Ingested inputs are staged onto a
//! worker the first time a job routed there needs them — replayed from
//! the client-side recipe (gaussian seeds replay as seeds, not bytes) —
//! and job outputs are fetched from the worker that holds them.
//!
//! # Autoscaling
//!
//! With [`SchedulerConfig::autoscale`] enabled
//! (`autoscale_max > 0`), an autoscaler thread breathes the live
//! worker population between the configured bounds: when every live
//! worker is busy and the ceiling allows, it spawns another child into
//! a parked slot (reviving a killed worker's seat counts); a worker
//! idle for two consecutive ticks is flagged out of routing for one
//! tick and then retired, never below `max(autoscale_min, 1)`, never
//! worker 0 (the ingestion home), and never a worker holding the only
//! copy of a staged file. The slot table — and with it the global
//! shard index space — is fixed at `max(worker_processes,
//! autoscale_max)`, so scaling is pure placement like everything else:
//! no result bit depends on the live population.

use super::transport::{Transport, TransportIngest, TransportJob};
use super::wire::{self, Frame, Op, WireReader, WireWriter, WorkerConfig};
use crate::coordinator::MatrixHandle;
use crate::linalg::Matrix;
use crate::service::{JobId, JobStatus, SchedTally, SchedulerConfig};
use crate::session::{Factorization, FactorizationRequest, Placement};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io::{BufReader, BufWriter, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Rows per [`wire::Op::IngestChunk`] frame when shipping an in-memory
/// matrix (bounds per-frame memory, mirrors the ingestion batch size).
pub(crate) const CHUNK_ROWS: usize = 4096;

/// Locate the `mrtsqr` binary to spawn as a worker when the builder did
/// not name one: an explicit `MRTSQR_WORKER_BIN`, the current
/// executable when it *is* `mrtsqr` (the `batch`/`serve` CLI path), or
/// an `mrtsqr` sibling of the current executable (`target/<profile>/`
/// for test and bench binaries living in `deps/`).
pub(crate) fn default_worker_binary() -> Result<PathBuf> {
    if let Some(path) = std::env::var_os("MRTSQR_WORKER_BIN") {
        return Ok(PathBuf::from(path));
    }
    let exe = std::env::current_exe().context("resolving current executable")?;
    if exe.file_stem() == Some(std::ffi::OsStr::new("mrtsqr")) {
        return Ok(exe);
    }
    let mut dir = exe.parent();
    while let Some(d) = dir {
        let candidate = d.join("mrtsqr");
        if candidate.is_file() {
            return Ok(candidate);
        }
        if d.file_name() == Some(std::ffi::OsStr::new("target")) {
            break;
        }
        dir = d.parent();
    }
    bail!(
        "cannot locate the `mrtsqr` worker binary from {exe:?} — pass \
         SessionBuilder::worker_binary(path) or set MRTSQR_WORKER_BIN"
    )
}

// ------------------------------------------------------------- reply slot

/// One blocked request's reply cell, filled by the reader thread.
/// Shared by the pipe and TCP transports.
pub(crate) struct ReplySlot {
    cell: Mutex<Option<Result<Frame>>>,
    ready: Condvar,
}

impl ReplySlot {
    pub(crate) fn new() -> ReplySlot {
        ReplySlot { cell: Mutex::new(None), ready: Condvar::new() }
    }

    pub(crate) fn fill(&self, value: Result<Frame>) {
        *self.cell.lock().expect("reply slot") = Some(value);
        self.ready.notify_all();
    }

    /// Block for the reply, up to `timeout` (`None` = forever).
    /// Returns `None` on deadline expiry — the caller decides what a
    /// silent peer means (for both transports: fail the request and
    /// mark the peer suspect, instead of wedging the client thread
    /// behind a stuck-but-not-dead worker).
    pub(crate) fn take(&self, timeout: Option<Duration>) -> Option<Result<Frame>> {
        let mut cell = self.cell.lock().expect("reply slot");
        let deadline = timeout.map(|t| Instant::now() + t);
        loop {
            if let Some(value) = cell.take() {
                return Some(value);
            }
            match deadline {
                None => cell = self.ready.wait(cell).expect("reply slot"),
                Some(deadline) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (guard, _) = self
                        .ready
                        .wait_timeout(cell, deadline - now)
                        .expect("reply slot");
                    cell = guard;
                }
            }
        }
    }
}

// ------------------------------------------------------------- remote job

/// Client-side terminal state of one remote job.
pub(crate) enum RemoteState {
    Pending,
    Done { fact: Arc<Factorization>, wall_secs: f64 },
    Failed { msg: String, wall_secs: Option<f64> },
    Cancelled,
}

/// Shared slot of one in-flight remote job, filled by the worker's
/// pushed terminal frame (or by connection death). Resolution is
/// first-writer-wins, which is what makes the TCP transport's
/// resubmit-after-reconnect safe: a duplicate terminal push for an
/// already-resolved job is a no-op.
pub(crate) struct RemoteJob {
    id: JobId,
    label: Option<String>,
    state: Mutex<RemoteState>,
    done: Condvar,
}

impl RemoteJob {
    pub(crate) fn new(id: JobId, label: Option<String>) -> RemoteJob {
        RemoteJob { id, label, state: Mutex::new(RemoteState::Pending), done: Condvar::new() }
    }

    pub(crate) fn resolve(&self, state: RemoteState) {
        let mut slot = self.state.lock().expect("remote job state");
        if matches!(*slot, RemoteState::Pending) {
            *slot = state;
        }
        self.done.notify_all();
    }

    pub(crate) fn terminal_status(&self) -> Option<JobStatus> {
        match *self.state.lock().expect("remote job state") {
            RemoteState::Pending => None,
            RemoteState::Done { .. } => Some(JobStatus::Done),
            RemoteState::Failed { .. } => Some(JobStatus::Failed),
            RemoteState::Cancelled => Some(JobStatus::Cancelled),
        }
    }
}

/// What a [`RemoteJobHandle`] needs from its connection: a blocking
/// request round-trip, and the status to report for a still-pending job
/// when the connection cannot be asked. The pipe transport answers
/// `Failed` (a dead worker's jobs are failed by its reader thread); the
/// TCP transport answers `Queued` (a dropped connection parks its jobs
/// for resubmission after reconnect).
pub(crate) trait Peer: Send + Sync {
    fn request(&self, op: Op, payload: &[u8]) -> Result<Frame>;
    fn offline_status(&self) -> JobStatus;
}

/// [`TransportJob`] over a [`RemoteJob`] plus the connection that can
/// answer status/cancel queries while the job is still live.
pub(crate) struct RemoteJobHandle<P: Peer> {
    pub(crate) job: Arc<RemoteJob>,
    pub(crate) conn: Arc<P>,
}

impl<P: Peer + 'static> TransportJob for RemoteJobHandle<P> {
    fn id(&self) -> JobId {
        self.job.id
    }

    fn label(&self) -> Option<&str> {
        self.job.label.as_deref()
    }

    fn status(&self) -> JobStatus {
        if let Some(status) = self.job.terminal_status() {
            return status;
        }
        let mut w = WireWriter::new();
        w.u64(self.job.id.0);
        match self.conn.request(Op::Status, &w.into_bytes()) {
            Ok(frame) => {
                let mut r = WireReader::new(&frame.payload);
                r.status().unwrap_or(JobStatus::Failed)
            }
            // the connection can't be asked: re-read the local state,
            // else report what an unreachable peer means (Failed for a
            // dead pipe worker, Queued for a parked TCP job)
            Err(_) => self.job.terminal_status().unwrap_or_else(|| self.conn.offline_status()),
        }
    }

    fn wait(&self) -> Result<Arc<Factorization>> {
        let mut state = self.job.state.lock().expect("remote job state");
        loop {
            match &*state {
                RemoteState::Pending => {
                    state = self.job.done.wait(state).expect("remote job state");
                }
                RemoteState::Done { fact, .. } => return Ok(fact.clone()),
                RemoteState::Failed { msg, .. } => bail!("{} failed: {msg}", self.job.id),
                RemoteState::Cancelled => {
                    bail!("{} was cancelled before it ran", self.job.id)
                }
            }
        }
    }

    fn try_result(&self) -> Option<Result<Arc<Factorization>>> {
        match &*self.job.state.lock().expect("remote job state") {
            RemoteState::Pending => None,
            RemoteState::Done { fact, .. } => Some(Ok(fact.clone())),
            RemoteState::Failed { msg, .. } => {
                Some(Err(anyhow!("{} failed: {msg}", self.job.id)))
            }
            RemoteState::Cancelled => {
                Some(Err(anyhow!("{} was cancelled before it ran", self.job.id)))
            }
        }
    }

    fn cancel(&self) -> bool {
        if self.job.terminal_status().is_some() {
            return false;
        }
        let mut w = WireWriter::new();
        w.u64(self.job.id.0);
        match self.conn.request(Op::Cancel, &w.into_bytes()) {
            Ok(frame) => {
                let mut r = WireReader::new(&frame.payload);
                r.bool().unwrap_or(false)
            }
            Err(_) => false,
        }
    }

    fn wall_secs(&self) -> Option<f64> {
        match &*self.job.state.lock().expect("remote job state") {
            RemoteState::Done { wall_secs, .. } => Some(*wall_secs),
            RemoteState::Failed { wall_secs, .. } => *wall_secs,
            _ => None,
        }
    }
}

/// [`TransportIngest`] over the wire: the serving side queued the
/// ingestion as a first-class job ([`Op::IngestAsync`]) and this handle
/// polls its status ([`Op::IngestStatus`]). Unlike factorizations,
/// ingestions have no pushed terminal frame — their result *is* the
/// matrix handle, already known — so a poll loop is all `wait` needs.
pub(crate) struct RemoteIngestHandle<P: Peer> {
    pub(crate) id: JobId,
    pub(crate) handle: MatrixHandle,
    pub(crate) conn: Arc<P>,
}

impl<P: Peer> RemoteIngestHandle<P> {
    fn remote_status(&self) -> Result<JobStatus> {
        let mut w = WireWriter::new();
        w.u64(self.id.0);
        let reply = self.conn.request(Op::IngestStatus, &w.into_bytes())?;
        ensure!(reply.op == Op::StatusReply, "expected StatusReply, got {:?}", reply.op);
        let mut r = WireReader::new(&reply.payload);
        let status = r.status()?;
        r.finish()?;
        Ok(status)
    }
}

impl<P: Peer + 'static> TransportIngest for RemoteIngestHandle<P> {
    fn id(&self) -> JobId {
        self.id
    }

    fn handle(&self) -> MatrixHandle {
        self.handle.clone()
    }

    fn status(&self) -> JobStatus {
        self.remote_status().unwrap_or_else(|_| self.conn.offline_status())
    }

    fn wait(&self) -> Result<MatrixHandle> {
        loop {
            match self.remote_status()? {
                JobStatus::Queued | JobStatus::Running => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                JobStatus::Done => return Ok(self.handle.clone()),
                JobStatus::Failed => {
                    bail!("{} (ingestion of {:?}) failed on the serving side", self.id, self.handle.file)
                }
                JobStatus::Cancelled => {
                    bail!("{} (ingestion of {:?}) was cancelled before it ran", self.id, self.handle.file)
                }
            }
        }
    }

    fn cancel(&self) -> bool {
        let mut w = WireWriter::new();
        w.u64(self.id.0);
        match self.conn.request(Op::Cancel, &w.into_bytes()) {
            Ok(frame) => {
                let mut r = WireReader::new(&frame.payload);
                r.bool().unwrap_or(false)
            }
            Err(_) => false,
        }
    }
}

// ------------------------------------------------------------ connection

/// One spawned worker process: the write half of its pipe, the registry
/// the reader thread routes into, and its liveness/load accounting.
struct WorkerConn {
    index: usize,
    child: Mutex<Child>,
    /// `None` once shut down (closing the pipe is the EOF the worker
    /// exits on).
    stdin: Mutex<Option<BufWriter<ChildStdin>>>,
    /// Correlation ids start at 1: 0 tags pushed frames.
    next_req: AtomicU64,
    pending: Mutex<HashMap<u64, Arc<ReplySlot>>>,
    jobs: Mutex<HashMap<u64, Arc<RemoteJob>>>,
    alive: AtomicBool,
    /// Set when a request timed out waiting for this worker's reply:
    /// the child is *running but not answering* (wedged, or grinding
    /// through something enormous). A suspect worker is skipped by the
    /// Auto router until its next frame arrives; unlike `alive`, the
    /// flag clears itself the moment the worker speaks again.
    suspect: AtomicBool,
    /// Set by the autoscaler's scale-down phase 1: the worker leaves
    /// `Auto` routing immediately and is killed on the next tick if it
    /// is still idle (cleared instead if a straggler job landed).
    retiring: AtomicBool,
    /// Per-request reply deadline (`None` = wait forever, the
    /// pre-timeout behavior).
    timeout: Option<Duration>,
    /// In-flight jobs — the router's load metric.
    load: AtomicUsize,
    reader: Mutex<Option<JoinHandle<()>>>,
}

impl WorkerConn {
    /// Send one request frame and block for its reply. Fails fast when
    /// the worker is dead, and cannot deadlock with the reader: the
    /// slot is registered before the write, and a dying reader fails
    /// every registered slot after flagging `alive = false`. With a
    /// configured timeout the wait is bounded too: a wedged-but-alive
    /// child fails the request and is marked suspect instead of
    /// hanging the client thread forever.
    fn request(&self, op: Op, payload: &[u8]) -> Result<Frame> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let slot = Arc::new(ReplySlot::new());
        self.pending.lock().expect("pending map").insert(req_id, slot.clone());
        if !self.alive.load(Ordering::SeqCst) {
            self.pending.lock().expect("pending map").remove(&req_id);
            bail!("worker process {} is not running", self.index);
        }
        let write_result = {
            let mut stdin = self.stdin.lock().expect("worker stdin");
            match stdin.as_mut() {
                None => Err(anyhow!("worker process {} is shut down", self.index)),
                Some(w) => wire::write_frame(w, op, req_id, payload)
                    .and_then(|()| w.flush().map_err(Into::into)),
            }
        };
        if let Err(err) = write_result {
            self.pending.lock().expect("pending map").remove(&req_id);
            bail!("worker process {}: {err:#}", self.index);
        }
        let frame = match slot.take(self.timeout) {
            Some(reply) => reply?,
            None => {
                self.pending.lock().expect("pending map").remove(&req_id);
                self.suspect.store(true, Ordering::SeqCst);
                bail!(
                    "worker process {} did not answer {:?} within {:?} — \
                     marked suspect (stuck child?); it rejoins routing when it speaks again",
                    self.index,
                    op,
                    self.timeout.expect("deadline implies a timeout")
                );
            }
        };
        if frame.op == Op::Err {
            let msg = WireReader::new(&frame.payload)
                .str()
                .unwrap_or_else(|_| "malformed error reply".into());
            bail!("worker process {}: {msg}", self.index);
        }
        Ok(frame)
    }

    /// Resolve everything still waiting on this connection — called by
    /// the reader thread exactly once, when the pipe dies.
    fn fail_all(&self, why: &str) {
        self.alive.store(false, Ordering::SeqCst);
        let pending: Vec<Arc<ReplySlot>> =
            self.pending.lock().expect("pending map").drain().map(|(_, s)| s).collect();
        for slot in pending {
            slot.fill(Err(anyhow!("worker process {}: {why}", self.index)));
        }
        let jobs: Vec<Arc<RemoteJob>> =
            self.jobs.lock().expect("jobs map").drain().map(|(_, j)| j).collect();
        for job in jobs {
            self.load.fetch_sub(1, Ordering::Relaxed);
            job.resolve(RemoteState::Failed {
                msg: format!("worker process {} {why}", self.index),
                wall_secs: None,
            });
        }
    }
}

impl Peer for WorkerConn {
    fn request(&self, op: Op, payload: &[u8]) -> Result<Frame> {
        WorkerConn::request(self, op, payload)
    }

    fn offline_status(&self) -> JobStatus {
        // a dead pipe worker's jobs are gone: the reader thread failed
        // them already, this is only the fallback for the brief race
        JobStatus::Failed
    }
}

/// Shared routing records: where each job went (and, once done, which
/// global shard served it), and which workers hold which DFS files.
/// Shared by the pipe and TCP transports (for TCP, "process" reads
/// "host").
#[derive(Default)]
pub(crate) struct RouteBook {
    /// job id → (process, global shard once known).
    pub(crate) placements: Mutex<BTreeMap<u64, (usize, Option<usize>)>>,
    /// file name → processes holding a copy.
    pub(crate) staged: Mutex<HashMap<String, BTreeSet<usize>>>,
}

/// The reader-thread demux loop for one worker (see the module docs).
fn reader_loop(
    conn: &WorkerConn,
    book: &RouteBook,
    shards_per_proc: usize,
    stdout: ChildStdout,
) {
    let mut stdout = BufReader::new(stdout);
    let why = loop {
        let frame = match wire::read_frame(&mut stdout) {
            Ok(Some(frame)) => frame,
            Ok(None) => break "exited".to_string(),
            Err(err) => break format!("desynchronized: {err:#}"),
        };
        // any frame is proof of life: a worker marked suspect by a
        // timed-out request rejoins routing as soon as it speaks
        conn.suspect.store(false, Ordering::SeqCst);
        match frame.op {
            Op::JobDone => match decode_job_done(&frame.payload) {
                Ok((id, wall_secs, mut fact)) => {
                    // remap the worker-local shard index into the
                    // global (proc, shard) flattening
                    let global = conn.index * shards_per_proc + fact.stats.shard;
                    fact.stats.shard = global;
                    if let Some(entry) =
                        book.placements.lock().expect("placements").get_mut(&id)
                    {
                        entry.1 = Some(global);
                    }
                    if let Some(q) = &fact.q {
                        book.staged
                            .lock()
                            .expect("staged map")
                            .entry(q.file.clone())
                            .or_default()
                            .insert(conn.index);
                    }
                    if let Some(job) = conn.jobs.lock().expect("jobs map").remove(&id) {
                        conn.load.fetch_sub(1, Ordering::Relaxed);
                        job.resolve(RemoteState::Done { fact: Arc::new(fact), wall_secs });
                    }
                }
                Err(err) => break format!("sent a malformed JobDone: {err:#}"),
            },
            Op::JobFail => match decode_job_fail(&frame.payload) {
                Ok((id, status, wall_secs, msg)) => {
                    if let Some(job) = conn.jobs.lock().expect("jobs map").remove(&id) {
                        conn.load.fetch_sub(1, Ordering::Relaxed);
                        let state = if status == JobStatus::Cancelled {
                            RemoteState::Cancelled
                        } else {
                            RemoteState::Failed { msg, wall_secs }
                        };
                        job.resolve(state);
                    }
                }
                Err(err) => break format!("sent a malformed JobFail: {err:#}"),
            },
            _ => {
                let slot = conn.pending.lock().expect("pending map").remove(&frame.req_id);
                // a reply nobody waits for means the requester already
                // bailed on a write error — drop it
                if let Some(slot) = slot {
                    slot.fill(Ok(frame));
                }
            }
        }
    };
    conn.fail_all(&why);
}

pub(crate) fn decode_job_done(payload: &[u8]) -> Result<(u64, f64, Factorization)> {
    let mut r = WireReader::new(payload);
    let id = r.u64()?;
    let wall = r.f64()?;
    let fact = r.factorization()?;
    r.finish()?;
    Ok((id, wall, fact))
}

pub(crate) fn decode_job_fail(payload: &[u8]) -> Result<(u64, JobStatus, Option<f64>, String)> {
    let mut r = WireReader::new(payload);
    let id = r.u64()?;
    let status = r.status()?;
    let wall = match r.u8()? {
        0 => None,
        1 => Some(r.f64()?),
        other => bail!("bad option tag {other}"),
    };
    let msg = r.str()?;
    r.finish()?;
    Ok((id, status, wall, msg))
}

// ---------------------------------------------------------------- router

/// PR 4's least-loaded/pinned placement logic, lifted across processes:
/// global shard `k` ≡ (process `k / shards_per_proc`, local shard
/// `k % shards_per_proc`).
pub(crate) struct ProcRouter {
    nprocs: usize,
    shards_per_proc: usize,
}

impl ProcRouter {
    pub(crate) fn new(nprocs: usize, shards_per_proc: usize) -> ProcRouter {
        ProcRouter { nprocs, shards_per_proc }
    }

    pub(crate) fn total_shards(&self) -> usize {
        self.nprocs * self.shards_per_proc
    }

    /// Pick the worker process for a job (and the placement to forward
    /// to it). `loads[p]` is `None` for dead processes.
    pub(crate) fn route(
        &self,
        id: JobId,
        placement: Placement,
        loads: &[Option<usize>],
    ) -> Result<(usize, Placement)> {
        debug_assert_eq!(loads.len(), self.nprocs);
        match placement {
            Placement::Pinned(k) => {
                if k >= self.total_shards() {
                    bail!(
                        "request pinned to global shard {k}, but the client has {} \
                         ({} process(es) x {} shard(s))",
                        self.total_shards(),
                        self.nprocs,
                        self.shards_per_proc
                    );
                }
                let proc = k / self.shards_per_proc;
                if loads[proc].is_none() {
                    bail!("request pinned to shard {k}, but worker process {proc} is dead");
                }
                Ok((proc, Placement::Pinned(k % self.shards_per_proc)))
            }
            Placement::Auto => {
                let min = loads
                    .iter()
                    .flatten()
                    .min()
                    .copied()
                    .ok_or_else(|| anyhow!("every worker process is dead"))?;
                let tied: Vec<usize> = loads
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| **l == Some(min))
                    .map(|(i, _)| i)
                    .collect();
                Ok((tied[(id.0 as usize) % tied.len()], Placement::Auto))
            }
        }
    }
}

// ------------------------------------------------------------- transport

/// How to re-create a seeded gaussian input on another worker on
/// demand: ship the recipe, not the rows — the worker regenerates
/// identical records from the seed. Matrices ingested by rows carry no
/// client-side copy at all; staging them elsewhere fetches the rows
/// back from a worker that holds them (exact bits, identical key
/// layout), so client memory never retains an input.
#[derive(Clone, Copy)]
pub(crate) struct GaussianRecipe {
    pub(crate) rows: usize,
    pub(crate) cols: usize,
    pub(crate) seed: u64,
}

/// The spawn recipe and live slot table shared between the transport
/// and its autoscaler thread. Slot `i` is worker process `i`'s seat:
/// `Some(conn)` while a child occupies it, `None` while it is parked
/// (never spawned, or retired). The slot count is fixed at launch —
/// `max(worker_processes, autoscale_max)` — so global shard indices
/// stay stable while the live population breathes.
struct ProcPool {
    slots: Vec<Mutex<Option<Arc<WorkerConn>>>>,
    book: Arc<RouteBook>,
    /// Spawn ingredients, retained so the autoscaler can grow the pool
    /// with children configured identically to the originals.
    program: PathBuf,
    cfg: WorkerConfig,
    shards_per_proc: usize,
    request_timeout: Option<Duration>,
}

impl ProcPool {
    /// The connection seated in slot `proc`, if any.
    fn conn(&self, proc: usize) -> Option<Arc<WorkerConn>> {
        self.slots.get(proc).and_then(|s| s.lock().expect("worker slot").clone())
    }

    /// Live (seated, pipe not dead) connections with their slot index.
    fn live(&self) -> Vec<(usize, Arc<WorkerConn>)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.lock().expect("worker slot").clone().map(|c| (i, c)))
            .filter(|(_, c)| c.alive.load(Ordering::SeqCst))
            .collect()
    }

    /// Whether retiring `proc` would lose the only copy of a staged
    /// file (an input, or a chained job's Q output). Such a worker is
    /// never scaled down.
    fn sole_holder(&self, proc: usize) -> bool {
        self.book
            .staged
            .lock()
            .expect("staged map")
            .values()
            .any(|procs| procs.len() == 1 && procs.contains(&proc))
    }

    /// Forget every staging record pointing at slot `index` — its
    /// occupant (dead or replaced) no longer serves those files.
    fn forget_staged(&self, index: usize) {
        let mut staged = self.book.staged.lock().expect("staged map");
        for procs in staged.values_mut() {
            procs.remove(&index);
        }
        staged.retain(|_, procs| !procs.is_empty());
    }

    /// (Re)spawn a worker into slot `index`: a scale-up, or the revival
    /// of a killed worker's seat. A spawn failure leaves the slot
    /// parked; the next tick retries.
    fn respawn(&self, index: usize) {
        if let Some(old) = self.slots[index].lock().expect("worker slot").take() {
            ProcessTransport::reap(std::slice::from_ref(&old));
        }
        // the replacement starts with an empty DFS: any staging records
        // of the old occupant are stale
        self.forget_staged(index);
        if let Ok((conn, _topo)) = ProcessTransport::spawn_one(
            &self.program,
            index,
            &self.cfg,
            &self.book,
            self.shards_per_proc,
            self.request_timeout,
        ) {
            *self.slots[index].lock().expect("worker slot") = Some(conn);
        }
    }

    /// Phase 2 of a scale-down: kill a worker that spent a whole tick
    /// flagged `retiring` (out of Auto routing) and is still idle.
    /// Re-checks under the slot lock that the seat still holds the same
    /// connection and that no straggler job snuck in.
    fn retire(&self, index: usize, conn: &Arc<WorkerConn>) {
        {
            let mut slot = self.slots[index].lock().expect("worker slot");
            match &*slot {
                Some(c) if Arc::ptr_eq(c, conn) && c.load.load(Ordering::Relaxed) == 0 => {
                    slot.take();
                }
                _ => return,
            }
        }
        ProcessTransport::reap(std::slice::from_ref(conn));
        self.forget_staged(index);
    }

    /// One autoscaler heartbeat. `idle[i]` counts consecutive ticks
    /// slot `i` was live and empty of work; it is the hysteresis that
    /// keeps a momentarily quiet pool from thrashing.
    fn autoscale_tick(&self, sched: &SchedulerConfig, idle: &mut [u32]) {
        // finish (or abort) retirements flagged on the previous tick
        for (i, conn) in self.live() {
            if conn.retiring.load(Ordering::SeqCst) {
                if conn.load.load(Ordering::Relaxed) == 0 {
                    self.retire(i, &conn);
                } else {
                    // a straggler landed (stale handle, pin): serve on
                    conn.retiring.store(false, Ordering::SeqCst);
                }
                idle[i] = 0;
            }
        }
        let live = self.live();
        for (i, ticks) in idle.iter_mut().enumerate() {
            let quiet = live.iter().any(|(j, c)| {
                *j == i
                    && !c.retiring.load(Ordering::SeqCst)
                    && c.load.load(Ordering::Relaxed) == 0
            });
            *ticks = if quiet { ticks.saturating_add(1) } else { 0 };
        }
        // scale up: every live worker is busy and the ceiling allows
        // one more — seat a child in the first parked (or dead) slot
        let busy =
            !live.is_empty() && live.iter().all(|(_, c)| c.load.load(Ordering::Relaxed) >= 1);
        if busy && live.len() < sched.autoscale_max {
            let parked = (0..self.slots.len()).find(|&i| {
                match &*self.slots[i].lock().expect("worker slot") {
                    None => true,
                    Some(c) => !c.alive.load(Ordering::SeqCst),
                }
            });
            if let Some(i) = parked {
                self.respawn(i);
                idle[i] = 0;
                return;
            }
        }
        // scale down, phase 1: flag the highest-index worker that has
        // been idle two ticks. Never below the floor, never worker 0
        // (the ingestion home), never a sole holder of staged data. A
        // flagged worker leaves Auto routing now and dies next tick.
        let floor = sched.autoscale_min.max(1);
        let retiring_now = live.iter().filter(|(_, c)| c.retiring.load(Ordering::SeqCst)).count();
        if live.len() - retiring_now > floor {
            if let Some((_, conn)) = live.iter().rev().find(|(i, c)| {
                *i > 0
                    && idle[*i] >= 2
                    && !c.retiring.load(Ordering::SeqCst)
                    && !self.sole_holder(*i)
            }) {
                conn.retiring.store(true, Ordering::SeqCst);
            }
        }
    }
}

/// The `Process` transport: see the [module docs](self).
pub struct ProcessTransport {
    pool: Arc<ProcPool>,
    router: ProcRouter,
    recipes: Mutex<HashMap<String, GaussianRecipe>>,
    /// Virtual byte scales to re-apply when a recipe replays.
    scales: Mutex<HashMap<String, f64>>,
    /// Topology reported by the workers' `HelloAck`s.
    workers_per_proc: usize,
    capacity: usize,
    host_threads: usize,
    backend_desc: String,
    down: AtomicBool,
    /// Autoscaler heartbeat thread (`None` when autoscaling is off).
    scaler: Mutex<Option<std::thread::JoinHandle<()>>>,
    scaler_stop: Arc<AtomicBool>,
}

impl ProcessTransport {
    /// Spawn `nprocs` workers from `program`, handshake each with
    /// `cfg`, and wire up their reader threads. `request_timeout`
    /// bounds every request's reply wait (`None` = wait forever). With
    /// `cfg.scheduler.autoscale_max > 0` the slot table is sized to the
    /// ceiling and an autoscaler thread starts breathing the pool.
    pub(crate) fn launch(
        cfg: WorkerConfig,
        nprocs: usize,
        program: PathBuf,
        request_timeout: Option<Duration>,
    ) -> Result<ProcessTransport> {
        ensure!(nprocs >= 1, "worker_processes wants at least one process");
        let sched = cfg.scheduler;
        let autoscaling = sched.autoscale_max > 0;
        let nslots = if autoscaling { nprocs.max(sched.autoscale_max) } else { nprocs };
        let book = Arc::new(RouteBook::default());
        let shards_per_proc = cfg.engine_shards.max(1);
        let mut conns = Vec::with_capacity(nprocs);
        let mut topo = None;
        for index in 0..nprocs {
            // a failure to spawn or handshake worker k must reap
            // workers 0..k — otherwise they (and their blocked reader
            // threads) outlive the failed launch forever
            match Self::spawn_one(&program, index, &cfg, &book, shards_per_proc, request_timeout) {
                Ok((conn, worker_topo)) => {
                    topo = Some(worker_topo);
                    conns.push(conn);
                }
                Err(err) => {
                    Self::reap(&conns);
                    return Err(err);
                }
            }
        }
        let (workers_per_proc, capacity, host_threads, backend_desc) =
            topo.expect("at least one worker");
        let slots: Vec<Mutex<Option<Arc<WorkerConn>>>> =
            (0..nslots).map(|i| Mutex::new(conns.get(i).cloned())).collect();
        let pool = Arc::new(ProcPool {
            slots,
            book,
            program,
            cfg,
            shards_per_proc,
            request_timeout,
        });
        let scaler_stop = Arc::new(AtomicBool::new(false));
        let scaler = if autoscaling {
            let pool = pool.clone();
            let stop = scaler_stop.clone();
            let interval = sched.autoscale_interval.max(Duration::from_millis(1));
            Some(
                std::thread::Builder::new()
                    .name("mrtsqr-autoscale".into())
                    .spawn(move || {
                        let mut idle = vec![0u32; pool.slots.len()];
                        loop {
                            // sleep in short steps so shutdown is prompt
                            // even under a long heartbeat interval
                            let mut slept = Duration::ZERO;
                            while slept < interval && !stop.load(Ordering::SeqCst) {
                                let step = (interval - slept).min(Duration::from_millis(25));
                                std::thread::sleep(step);
                                slept += step;
                            }
                            if stop.load(Ordering::SeqCst) {
                                break;
                            }
                            pool.autoscale_tick(&sched, &mut idle);
                        }
                    })
                    .expect("spawn autoscaler"),
            )
        } else {
            None
        };
        Ok(ProcessTransport {
            pool,
            router: ProcRouter::new(nslots, shards_per_proc),
            recipes: Mutex::new(HashMap::new()),
            scales: Mutex::new(HashMap::new()),
            workers_per_proc,
            capacity,
            host_threads,
            backend_desc,
            down: AtomicBool::new(false),
            scaler: Mutex::new(scaler),
            scaler_stop,
        })
    }

    /// Spawn one worker, start its demux reader, and run the `Hello`
    /// handshake. Returns the connection plus the topology its ack
    /// reported.
    fn spawn_one(
        program: &std::path::Path,
        index: usize,
        cfg: &WorkerConfig,
        book: &Arc<RouteBook>,
        shards_per_proc: usize,
        request_timeout: Option<Duration>,
    ) -> Result<(Arc<WorkerConn>, (usize, usize, usize, String))> {
        let mut child = Command::new(program)
            .arg("worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning worker process from {program:?}"))?;
        let stdin = child.stdin.take().expect("piped worker stdin");
        let stdout = child.stdout.take().expect("piped worker stdout");
        let conn = Arc::new(WorkerConn {
            index,
            child: Mutex::new(child),
            stdin: Mutex::new(Some(BufWriter::new(stdin))),
            next_req: AtomicU64::new(1),
            pending: Mutex::new(HashMap::new()),
            jobs: Mutex::new(HashMap::new()),
            alive: AtomicBool::new(true),
            suspect: AtomicBool::new(false),
            retiring: AtomicBool::new(false),
            timeout: request_timeout,
            load: AtomicUsize::new(0),
            reader: Mutex::new(None),
        });
        let reader = {
            let conn = conn.clone();
            let book = book.clone();
            std::thread::Builder::new()
                .name(format!("mrtsqr-demux-{index}"))
                .spawn(move || reader_loop(&conn, &book, shards_per_proc, stdout))
                .expect("spawn demux reader")
        };
        *conn.reader.lock().expect("reader slot") = Some(reader);

        // handshake: ship the cluster recipe, check the topology;
        // reap this one connection ourselves on any failure from here
        let handshake = (|| -> Result<(usize, usize, usize, String)> {
            let mut w = WireWriter::new();
            w.config(cfg);
            let ack = conn
                .request(Op::Hello, &w.into_bytes())
                .with_context(|| format!("handshaking worker process {index}"))?;
            ensure!(ack.op == Op::HelloAck, "worker {index}: expected HelloAck, got {:?}", ack.op);
            let mut r = WireReader::new(&ack.payload);
            let shards = r.usize()?;
            let workers = r.usize()?;
            let capacity = r.usize()?;
            let host_threads = r.usize()?;
            let backend = r.str()?;
            r.finish()?;
            ensure!(
                shards == shards_per_proc,
                "worker {index} built {shards} shard(s), expected {shards_per_proc}"
            );
            Ok((workers, capacity, host_threads, backend))
        })();
        match handshake {
            Ok(worker_topo) => Ok((conn, worker_topo)),
            Err(err) => {
                Self::reap(std::slice::from_ref(&conn));
                Err(err)
            }
        }
    }

    /// Tear down spawned workers after a failed launch: close the pipe
    /// (the EOF a worker exits on), kill as a belt-and-braces, reap the
    /// zombie, and join the reader thread.
    fn reap(conns: &[Arc<WorkerConn>]) {
        for conn in conns {
            *conn.stdin.lock().expect("worker stdin") = None;
            {
                let mut child = conn.child.lock().expect("worker child");
                let _ = child.kill();
                let _ = child.wait();
            }
            if let Some(reader) = conn.reader.lock().expect("reader slot").take() {
                let _ = reader.join();
            }
        }
    }

    fn loads(&self) -> Vec<Option<usize>> {
        self.pool
            .slots
            .iter()
            .map(|s| {
                s.lock().expect("worker slot").as_ref().and_then(|c| {
                    (c.alive.load(Ordering::SeqCst)
                        && !c.suspect.load(Ordering::SeqCst)
                        && !c.retiring.load(Ordering::SeqCst))
                    .then(|| c.load.load(Ordering::Relaxed))
                })
            })
            .collect()
    }

    fn is_staged(&self, name: &str, proc: usize) -> bool {
        self.pool
            .book
            .staged
            .lock()
            .expect("staged map")
            .get(name)
            .is_some_and(|procs| procs.contains(&proc))
    }

    fn mark_staged(&self, name: &str, proc: usize, exclusive: bool) {
        let mut staged = self.pool.book.staged.lock().expect("staged map");
        let entry = staged.entry(name.to_string()).or_default();
        if exclusive {
            entry.clear();
        }
        entry.insert(proc);
    }

    /// Ship an in-memory matrix to one worker in bounded chunks.
    fn send_matrix(
        &self,
        conn: &WorkerConn,
        name: &str,
        a: &Matrix,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        let mut w = WireWriter::new();
        w.str(name);
        w.u64(a.cols as u64);
        w.placement(placement);
        conn.request(Op::IngestBegin, &w.into_bytes())?;
        let mut row = 0;
        while row < a.rows {
            let take = CHUNK_ROWS.min(a.rows - row);
            let mut w = WireWriter::new();
            w.chunk(name, row as u64, a.cols, &a.data[row * a.cols..(row + take) * a.cols]);
            conn.request(Op::IngestChunk, &w.into_bytes())?;
            row += take;
        }
        // rows == 0 still produces a well-formed (empty) file
        let mut w = WireWriter::new();
        w.str(name);
        let reply = conn.request(Op::IngestEnd, &w.into_bytes())?;
        ensure!(reply.op == Op::Handle, "expected Handle, got {:?}", reply.op);
        let mut r = WireReader::new(&reply.payload);
        let handle = r.handle()?;
        r.finish()?;
        Ok(handle)
    }

    /// Make `handle`'s file readable on worker `proc`: a no-op when a
    /// copy is already there; otherwise replay the ingestion recipe, or
    /// — for job outputs — fetch the rows from the worker holding them.
    fn ensure_staged(&self, proc: usize, handle: &MatrixHandle) -> Result<()> {
        if self.is_staged(&handle.file, proc) {
            return Ok(());
        }
        let conn = self
            .pool
            .conn(proc)
            .ok_or_else(|| anyhow!("worker process {proc} is not running"))?;
        // copy the recipe out so no lock is held across the blocking
        // pipe round-trips below
        let recipe = self.recipes.lock().expect("recipes").get(&handle.file).copied();
        if let Some(GaussianRecipe { rows, cols, seed }) = recipe {
            let mut w = WireWriter::new();
            w.str(&handle.file);
            w.u64(rows as u64);
            w.u64(cols as u64);
            w.u64(seed);
            w.placement(Placement::Auto);
            conn.request(Op::IngestGaussian, &w.into_bytes())?;
        } else {
            // a row-ingested matrix or a job output: fetch from
            // whichever live worker holds it. Rows keep their exact
            // bits and order; keys are re-derived (same 32-byte
            // layout), so byte accounting — and with it the virtual
            // clock — is unchanged.
            let rows = self.fetch_matrix(handle)?;
            self.send_matrix(&conn, &handle.file, &rows, Placement::Auto)?;
        }
        let scale = self.scales.lock().expect("scales").get(&handle.file).copied();
        if let Some(scale) = scale {
            let mut w = WireWriter::new();
            w.str(&handle.file);
            w.f64(scale);
            conn.request(Op::SetScale, &w.into_bytes())?;
        }
        self.mark_staged(&handle.file, proc, false);
        Ok(())
    }

    fn fetch_matrix(&self, handle: &MatrixHandle) -> Result<Matrix> {
        // prefer workers known to hold the file, then try the rest
        let known: Vec<usize> = self
            .pool
            .book
            .staged
            .lock()
            .expect("staged map")
            .get(&handle.file)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default();
        let mut order: Vec<usize> = known;
        for i in 0..self.pool.slots.len() {
            if !order.contains(&i) {
                order.push(i);
            }
        }
        let mut last_err = anyhow!("no live worker holds {:?}", handle.file);
        for proc in order {
            let Some(conn) = self.pool.conn(proc) else {
                continue;
            };
            if !conn.alive.load(Ordering::SeqCst) {
                continue;
            }
            let mut w = WireWriter::new();
            w.handle(handle);
            match conn.request(Op::FetchMatrix, &w.into_bytes()) {
                Ok(reply) => {
                    ensure!(
                        reply.op == Op::MatrixData,
                        "expected MatrixData, got {:?}",
                        reply.op
                    );
                    let mut r = WireReader::new(&reply.payload);
                    let m = r.matrix()?;
                    r.finish()?;
                    self.mark_staged(&handle.file, proc, false);
                    return Ok(m);
                }
                Err(err) => last_err = err,
            }
        }
        Err(last_err)
    }

    fn ingest_target(&self, placement: Placement) -> Result<(usize, Placement)> {
        match placement {
            Placement::Auto => Ok((0, Placement::Auto)),
            Placement::Pinned(k) => {
                ensure!(
                    k < self.router.total_shards(),
                    "ingest pinned to global shard {k}, but the client has {}",
                    self.router.total_shards()
                );
                let proc = k / self.router.shards_per_proc;
                let live = self
                    .pool
                    .conn(proc)
                    .is_some_and(|c| c.alive.load(Ordering::SeqCst));
                ensure!(
                    live,
                    "ingest pinned to shard {k}, but worker process {proc} is not running"
                );
                Ok((proc, Placement::Pinned(k % self.router.shards_per_proc)))
            }
        }
    }
}

impl Transport for ProcessTransport {
    fn procs(&self) -> usize {
        self.pool.live().len()
    }

    fn shards(&self) -> usize {
        self.router.total_shards()
    }

    fn workers(&self) -> usize {
        self.workers_per_proc * self.pool.live().len()
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn backend_desc(&self) -> String {
        self.backend_desc.clone()
    }

    fn host_threads(&self) -> usize {
        self.host_threads
    }

    fn ingest_gaussian(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        let (proc, local) = self.ingest_target(placement)?;
        let mut w = WireWriter::new();
        w.str(name);
        w.u64(rows as u64);
        w.u64(cols as u64);
        w.u64(seed);
        w.placement(local);
        let conn = self
            .pool
            .conn(proc)
            .ok_or_else(|| anyhow!("worker process {proc} is not running"))?;
        let reply = conn.request(Op::IngestGaussian, &w.into_bytes())?;
        ensure!(reply.op == Op::Handle, "expected Handle, got {:?}", reply.op);
        let mut r = WireReader::new(&reply.payload);
        let handle = r.handle()?;
        r.finish()?;
        // re-ingesting a name invalidates copies staged on other
        // workers: exclusive ownership until re-staged from the fresh
        // recipe
        self.recipes
            .lock()
            .expect("recipes")
            .insert(name.to_string(), GaussianRecipe { rows, cols, seed });
        self.mark_staged(name, proc, true);
        Ok(handle)
    }

    fn ingest_gaussian_async(
        &self,
        id: JobId,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<Box<dyn TransportIngest>> {
        let (proc, local) = self.ingest_target(placement)?;
        let mut w = WireWriter::new();
        w.u64(id.0);
        w.str(name);
        w.u64(rows as u64);
        w.u64(cols as u64);
        w.u64(seed);
        w.placement(local);
        let conn = self
            .pool
            .conn(proc)
            .ok_or_else(|| anyhow!("worker process {proc} is not running"))?;
        let reply = conn.request(Op::IngestAsync, &w.into_bytes())?;
        ensure!(reply.op == Op::Handle, "expected Handle, got {:?}", reply.op);
        let mut r = WireReader::new(&reply.payload);
        let handle = r.handle()?;
        r.finish()?;
        // same bookkeeping as the synchronous path: the recipe replays
        // on other workers if a job routed there needs the matrix, and
        // the queued ingestion owns the name exclusively until then
        self.recipes
            .lock()
            .expect("recipes")
            .insert(name.to_string(), GaussianRecipe { rows, cols, seed });
        self.mark_staged(name, proc, true);
        Ok(Box::new(RemoteIngestHandle { id, handle, conn }))
    }

    fn ingest_matrix(
        &self,
        name: &str,
        a: &Matrix,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        let (proc, local) = self.ingest_target(placement)?;
        let conn = self
            .pool
            .conn(proc)
            .ok_or_else(|| anyhow!("worker process {proc} is not running"))?;
        let handle = self.send_matrix(&conn, name, a, local)?;
        // no client-side copy is retained: a stale gaussian recipe for
        // this name must go, so later staging fetches the fresh rows
        // from the worker that now holds them
        self.recipes.lock().expect("recipes").remove(name);
        self.mark_staged(name, proc, true);
        Ok(handle)
    }

    fn submit(
        &self,
        id: JobId,
        input: &MatrixHandle,
        mut req: FactorizationRequest,
    ) -> Result<Box<dyn TransportJob>> {
        let (proc, local) = self.router.route(id, req.options.placement, &self.loads())?;
        // atomic duplicate guard (mirrors the service's live-id check):
        // a second submission under a live id must not overwrite the
        // first job's registry entry — that would orphan its handle
        {
            let mut placements = self.pool.book.placements.lock().expect("placements");
            if placements.contains_key(&id.0) {
                bail!("job id {id} is already in use by a live (unevicted) job");
            }
            placements.insert(id.0, (proc, None));
        }
        if let Err(err) = self.ensure_staged(proc, input) {
            self.pool.book.placements.lock().expect("placements").remove(&id.0);
            return Err(err);
        }
        req.options.placement = local;
        let conn = match self.pool.conn(proc) {
            Some(conn) => conn,
            None => {
                self.pool.book.placements.lock().expect("placements").remove(&id.0);
                bail!("worker process {proc} is not running");
            }
        };
        let job = Arc::new(RemoteJob::new(id, req.options.label.clone()));
        conn.jobs.lock().expect("jobs map").insert(id.0, job.clone());
        conn.load.fetch_add(1, Ordering::Relaxed);
        let mut w = WireWriter::new();
        w.u64(id.0);
        w.handle(input);
        w.request(&req);
        match conn.request(Op::Submit, &w.into_bytes()) {
            Ok(_) => Ok(Box::new(RemoteJobHandle { job, conn })),
            Err(err) => {
                // roll back the optimistic registration (unless the
                // reader already failed the job on connection death)
                if conn.jobs.lock().expect("jobs map").remove(&id.0).is_some() {
                    conn.load.fetch_sub(1, Ordering::Relaxed);
                }
                self.pool.book.placements.lock().expect("placements").remove(&id.0);
                Err(err)
            }
        }
    }

    fn get_matrix(&self, handle: &MatrixHandle) -> Result<Matrix> {
        self.fetch_matrix(handle)
    }

    fn set_scale(&self, name: &str, scale: f64) -> Result<()> {
        self.scales.lock().expect("scales").insert(name.to_string(), scale);
        for (_, conn) in self.pool.live() {
            let mut w = WireWriter::new();
            w.str(name);
            w.f64(scale);
            conn.request(Op::SetScale, &w.into_bytes())?;
        }
        Ok(())
    }

    fn evict_job(&self, id: JobId) -> Result<usize> {
        if !self.pool.book.placements.lock().expect("placements").contains_key(&id.0) {
            return Ok(0);
        }
        // sweep every live worker, not just the owner: chained jobs may
        // have re-staged the namespace's outputs elsewhere (the
        // process-level analog of the service's every-shard sweep). A
        // worker whose request fails is dying — its in-memory DFS dies
        // with it, so there is nothing durable left to sweep there and
        // the error is not propagated.
        let mut swept = 0;
        for (_, conn) in self.pool.live() {
            let mut w = WireWriter::new();
            w.u64(id.0);
            if let Ok(reply) = conn.request(Op::Evict, &w.into_bytes()) {
                let mut r = WireReader::new(&reply.payload);
                swept += r.usize().unwrap_or(0);
            }
        }
        // only after the sweep: retire the id and forget client-side
        // records of the namespace's files
        self.pool.book.placements.lock().expect("placements").remove(&id.0);
        let ns = format!("job-{}/", id.0);
        self.pool
            .book
            .staged
            .lock()
            .expect("staged map")
            .retain(|name, _| !name.contains(&ns));
        Ok(swept)
    }

    fn drain_now(&self) -> Result<usize> {
        bail!(
            "manual drain needs the caller's thread inside the engine pool — \
             impossible across processes; use service workers (the default)"
        )
    }

    fn shard_of(&self, id: JobId) -> Option<usize> {
        self.pool
            .book
            .placements
            .lock()
            .expect("placements")
            .get(&id.0)
            .and_then(|(_, shard)| *shard)
    }

    fn sched_tally(&self) -> Result<SchedTally> {
        let mut per_shard = vec![0u64; self.router.total_shards()];
        let mut held: std::collections::BTreeMap<String, u64> = std::collections::BTreeMap::new();
        for (proc, conn) in self.pool.live() {
            let reply = conn.request(Op::SchedTally, &[])?;
            ensure!(reply.op == Op::TallyReply, "expected TallyReply, got {:?}", reply.op);
            let mut r = WireReader::new(&reply.payload);
            let tally = r.tally()?;
            r.finish()?;
            for (local, n) in tally.per_shard_steals.iter().enumerate() {
                if let Some(slot) = per_shard.get_mut(proc * self.router.shards_per_proc + local) {
                    *slot = *n;
                }
            }
            for (label, n) in tally.admission_held {
                *held.entry(label).or_default() += n;
            }
        }
        Ok(SchedTally {
            per_shard_steals: per_shard,
            admission_held: held.into_iter().collect(),
        })
    }

    fn kill_worker(&self, proc: usize) -> Result<()> {
        let conn = self.pool.conn(proc).ok_or_else(|| {
            anyhow!("no live worker process {proc} (client has {} slot(s))", self.pool.slots.len())
        })?;
        let mut child = conn.child.lock().expect("worker child");
        child.kill().with_context(|| format!("killing worker process {proc}"))?;
        child.wait().ok();
        // the reader thread sees EOF and fails this worker's jobs
        Ok(())
    }

    fn shutdown(&self) {
        if self.down.swap(true, Ordering::SeqCst) {
            return;
        }
        // stop the autoscaler first so it cannot spawn into slots we
        // are tearing down
        self.scaler_stop.store(true, Ordering::SeqCst);
        if let Some(scaler) = self.scaler.lock().expect("scaler slot").take() {
            let _ = scaler.join();
        }
        for slot in self.pool.slots.iter() {
            let conn = slot.lock().expect("worker slot").take();
            let Some(conn) = conn else { continue };
            // best-effort goodbye, then close the pipe (the EOF the
            // worker also understands) and reap
            {
                let mut stdin = conn.stdin.lock().expect("worker stdin");
                if let Some(w) = stdin.as_mut() {
                    let _ = wire::write_frame(w, Op::Shutdown, 0, &[]);
                    let _ = w.flush();
                }
                *stdin = None;
            }
            let _ = conn.child.lock().expect("worker child").wait();
            if let Some(reader) = conn.reader.lock().expect("reader slot").take() {
                let _ = reader.join();
            }
        }
    }
}

impl Drop for ProcessTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_maps_global_pins_to_proc_shard_pairs() {
        let router = ProcRouter::new(2, 2);
        assert_eq!(router.total_shards(), 4);
        let alive = vec![Some(0), Some(0)];
        for (global, want) in [
            (0, (0, Placement::Pinned(0))),
            (1, (0, Placement::Pinned(1))),
            (2, (1, Placement::Pinned(0))),
            (3, (1, Placement::Pinned(1))),
        ] {
            assert_eq!(
                router.route(JobId(9), Placement::Pinned(global), &alive).unwrap(),
                want
            );
        }
        let err = router.route(JobId(9), Placement::Pinned(4), &alive).unwrap_err();
        assert!(err.to_string().contains("4"), "{err}");
    }

    #[test]
    fn router_balances_and_avoids_dead_procs() {
        let router = ProcRouter::new(3, 1);
        // proc 1 busier: auto goes to 0 or 2, tie broken by job id
        let loads = vec![Some(0), Some(5), Some(0)];
        let (p0, _) = router.route(JobId(0), Placement::Auto, &loads).unwrap();
        let (p1, _) = router.route(JobId(1), Placement::Auto, &loads).unwrap();
        assert_eq!((p0, p1), (0, 2), "deterministic job-id tie-break among ties");
        // dead proc 0: auto never picks it, pin errors
        let loads = vec![None, Some(5), Some(9)];
        let (p, _) = router.route(JobId(7), Placement::Auto, &loads).unwrap();
        assert_eq!(p, 1, "least-loaded among the living");
        assert!(router.route(JobId(7), Placement::Pinned(0), &loads).is_err());
        // all dead
        assert!(router.route(JobId(7), Placement::Auto, &[None, None, None]).is_err());
    }

    #[test]
    fn reply_slot_hands_over_exactly_once() {
        let slot = Arc::new(ReplySlot::new());
        let waiter = {
            let slot = slot.clone();
            std::thread::spawn(move || slot.take(None))
        };
        slot.fill(Ok(Frame { op: Op::Ok, req_id: 3, payload: vec![] }));
        let frame = waiter.join().unwrap().expect("reply, not deadline").unwrap();
        assert_eq!((frame.op, frame.req_id), (Op::Ok, 3));
    }

    #[test]
    fn reply_slot_deadline_expires_instead_of_wedging() {
        let slot = ReplySlot::new();
        let start = Instant::now();
        assert!(slot.take(Some(Duration::from_millis(30))).is_none(), "empty slot times out");
        assert!(start.elapsed() >= Duration::from_millis(30));
        // a filled slot is handed over even with a zero deadline
        slot.fill(Ok(Frame { op: Op::Ok, req_id: 1, payload: vec![] }));
        assert!(slot.take(Some(Duration::ZERO)).is_some());
    }
}
