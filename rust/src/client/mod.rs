//! L6 — the transport-agnostic client: one API whether the engine pool
//! lives in this process or across a fleet of worker processes.
//!
//! The paper's architecture is a *distributed* MapReduce cluster —
//! independent machines exchanging only small `R` factors up the
//! reduction tree (Demmel et al., arXiv:0809.2407; Agullo et al.,
//! arXiv:0912.2572) — yet everything below L6 assumes shared memory.
//! [`TsqrClient`] removes that assumption from the public surface: it
//! speaks to a [`Transport`], and the transport decides where the
//! engine shards actually run.
//!
//! ```no_run
//! use mrtsqr::session::{FactorizationRequest, TsqrSession};
//!
//! # fn main() -> anyhow::Result<()> {
//! let client = TsqrSession::builder()
//!     .engine_shards(2)
//!     .worker_processes(2) // 0 (default) = in-process, same API
//!     .build_client()?;
//! let a = client.ingest_gaussian("A", 100_000, 25, 42)?;
//! let job = client.submit(&a, FactorizationRequest::qr())?; // returns immediately
//! println!("{}", job.wait()?.algorithm.name());
//! # Ok(())
//! # }
//! ```
//!
//! # The three transports
//!
//! * **`Local`** ([`LocalTransport`], `worker_processes(0)`, the
//!   default): wraps an in-process sharded
//!   [`crate::service::TsqrService`]. Every call is a direct
//!   delegation — no serialization, zero behavior change; results are
//!   bit-identical to using the service directly.
//! * **`Process`** ([`ProcessTransport`], `worker_processes(n)`):
//!   spawns `n` `mrtsqr worker` children, each running its own engine
//!   pool of [`crate::session::SessionBuilder::engine_shards`] shards,
//!   and speaks the versioned binary [`wire`] protocol over their
//!   stdin/stdout pipes. A reader thread per worker demultiplexes
//!   replies and pushed job completions, so any number of in-flight
//!   [`ClientJobHandle`]s share one pipe.
//! * **`Tcp`** ([`TcpTransport`],
//!   [`crate::session::SessionBuilder::connect`]): the same frames on
//!   sockets, against one or more `mrtsqr serve --listen` hosts
//!   ([`TcpServer`]). The wire version is negotiated at `Hello`
//!   (mismatches get a clean error frame), every request carries a
//!   reply deadline, and a dropped connection *parks* its jobs for
//!   reconnect-and-resubmit instead of failing them — see the
//!   [`net`] module docs for the full lifecycle.
//!
//! # The determinism contract
//!
//! In-process vs cross-process vs cross-network is *pure placement*.
//! The client assigns every job a global [`JobId`] in submission
//! order; a job's DFS namespace (`job-<id>/`) and fault-RNG stream
//! depend only on that id; and the wire format ships every `f64` as
//! exact bits. Hence the same manifest through
//! `worker_processes(2) × engine_shards(2)`, through an in-process
//! `engine_shards(4)` pool, or through `connect(addrs)` against
//! serving hosts totalling four shards produces bit-identical
//! `R`/`Q`/Σ/`virtual_secs`/fault draws and
//! [`crate::session::Factorization::result_digest`]s per job —
//! enforced by `rust/tests/client.rs`, `rust/tests/tcp.rs`, and the
//! CI cross-process and loopback-TCP batch-digest diffs.
//!
//! Global shard indices flatten the topology as
//! `proc * engine_shards + local_shard` (for TCP, read "host" for
//! "proc"); [`crate::session::Placement::Pinned`] addresses that
//! flattened space on every transport.
//!
//! # Failure isolation
//!
//! A killed or crashed worker process fails exactly the jobs in flight
//! on it — the process-level mirror of the service's poisoned-shard
//! isolation. Other workers keep serving, `Placement::Auto` routes
//! around the corpse, and pinning to a dead worker's shards errors at
//! submission. [`TsqrClient::kill_worker`] exists precisely to test
//! this. On the TCP transport the same hook severs a host's
//! *connection* instead (the server keeps running): jobs in flight
//! there park, the keeper reconnects and resubmits them under their
//! original ids, and determinism guarantees the recovered batch is
//! bit-identical. Jobs are failed only with a precise reason —
//! resubmission refused, host condemned after exhausting reconnect
//! attempts, or client shutdown — never silently lost.

pub mod net;
pub mod process;
pub mod tcp;
pub mod transport;
pub mod wire;
pub mod worker;

pub use net::TcpTransport;
pub use process::ProcessTransport;
pub use tcp::TcpServer;
pub use transport::{LocalTransport, Transport, TransportIngest, TransportJob};
pub use wire::{WorkerConfig, WIRE_VERSION};

use crate::coordinator::MatrixHandle;
use crate::linalg::Matrix;
use crate::service::{JobId, JobStatus, SchedTally};
use crate::session::{Factorization, FactorizationRequest, Placement};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Handle to one submitted job, returned by [`TsqrClient::submit`]:
/// poll or block for its [`Factorization`] exactly like a
/// [`crate::service::JobHandle`] — the transport behind it is
/// invisible.
pub struct ClientJobHandle {
    inner: Box<dyn TransportJob>,
}

impl ClientJobHandle {
    /// The client-assigned global job id (also the job's DFS namespace
    /// and fault-stream key, on whatever shard of whatever process the
    /// router picked).
    pub fn id(&self) -> JobId {
        self.inner.id()
    }

    /// The request's label, if it carried one.
    pub fn label(&self) -> Option<&str> {
        self.inner.label()
    }

    pub fn status(&self) -> JobStatus {
        self.inner.status()
    }

    /// Block until terminal. `Ok` carries the shared factorization;
    /// its `stats.shard` is the *global* shard index.
    pub fn wait(&self) -> Result<Arc<Factorization>> {
        self.inner.wait()
    }

    /// Non-blocking probe: `None` while queued or running.
    pub fn try_result(&self) -> Option<Result<Arc<Factorization>>> {
        self.inner.try_result()
    }

    /// Cancel if not yet running; `true` on success.
    pub fn cancel(&self) -> bool {
        self.inner.cancel()
    }

    /// Measured running→terminal wall seconds (worker-side on a
    /// process transport); `None` until terminal.
    pub fn wall_secs(&self) -> Option<f64> {
        self.inner.wall_secs()
    }
}

/// Handle to one queued async ingestion, returned by
/// [`TsqrClient::ingest_gaussian_async`]: the matrix handle is usable
/// for dependent submissions immediately; `wait()` blocks until the
/// rows are durable on their home shard.
pub struct ClientIngestHandle {
    inner: Box<dyn TransportIngest>,
}

impl ClientIngestHandle {
    /// The ingestion's job id (it occupies the same id space as
    /// factorization jobs).
    pub fn id(&self) -> JobId {
        self.inner.id()
    }

    /// The matrix the ingestion will produce — valid for `submit`
    /// right away; the dependent job queues behind the upload.
    pub fn handle(&self) -> MatrixHandle {
        self.inner.handle()
    }

    pub fn status(&self) -> JobStatus {
        self.inner.status()
    }

    /// Block until the rows are durably on their home shard.
    pub fn wait(&self) -> Result<MatrixHandle> {
        self.inner.wait()
    }

    /// Cancel if not yet running; `true` on success. Jobs already
    /// submitted against the handle then fail with a dependency error.
    pub fn cancel(&self) -> bool {
        self.inner.cancel()
    }
}

/// The transport-agnostic serving facade. Build with
/// [`crate::session::SessionBuilder::build_client`]; see the
/// [module docs](self) for the architecture.
pub struct TsqrClient {
    transport: Box<dyn Transport>,
    next_id: AtomicU64,
}

impl TsqrClient {
    pub(crate) fn new(transport: Box<dyn Transport>) -> TsqrClient {
        TsqrClient { transport, next_id: AtomicU64::new(0) }
    }

    // ------------------------------------------------------- topology

    /// Worker processes behind this client (1 = in-process).
    pub fn procs(&self) -> usize {
        self.transport.procs()
    }

    /// Total engine shards across all processes (the global shard
    /// index space [`Placement::Pinned`] addresses).
    pub fn shards(&self) -> usize {
        self.transport.shards()
    }

    /// Total service worker threads across all processes.
    pub fn workers(&self) -> usize {
        self.transport.workers()
    }

    /// Bounded per-shard queue capacity.
    pub fn capacity(&self) -> usize {
        self.transport.capacity()
    }

    /// Resolved compute backend name ("native", "pjrt", "custom").
    pub fn backend_desc(&self) -> String {
        self.transport.backend_desc()
    }

    /// Host threads each job's map/reduce waves fan out on (per
    /// process).
    pub fn host_threads(&self) -> usize {
        self.transport.host_threads()
    }

    // ------------------------------------------------------ ingestion

    /// Ingest a seeded gaussian matrix onto the home shard (global
    /// shard 0). Same records as
    /// [`crate::session::TsqrSession::ingest_gaussian`] for the same
    /// seed — on a process transport the *seed* travels, not the rows,
    /// and the worker generates identical records.
    pub fn ingest_gaussian(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
    ) -> Result<MatrixHandle> {
        self.transport.ingest_gaussian(name, rows, cols, seed, Placement::Auto)
    }

    /// [`TsqrClient::ingest_gaussian`] with an explicit global-shard
    /// placement, so a large input lands on its target shard up front
    /// (no staging copy when the consuming job is pinned there too).
    pub fn ingest_gaussian_placed(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        self.transport.ingest_gaussian(name, rows, cols, seed, placement)
    }

    /// Queue a gaussian ingestion as a first-class async job and
    /// return immediately (PR 8). The upload runs on the target
    /// shard's worker queue in short chunked engine-lock acquisitions,
    /// so factorizations on the same shard interleave with it, and a
    /// [`TsqrClient::submit`] naming the still-ingesting matrix queues
    /// behind it via a dependency edge — bit-identical to
    /// ingest-then-submit.
    pub fn ingest_gaussian_async(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<ClientIngestHandle> {
        let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
        self.ingest_async_id(id, name, rows, cols, seed, placement)
    }

    /// [`TsqrClient::ingest_gaussian_async`] under a *caller-chosen*
    /// job id — the relay hook `mrtsqr serve` uses so ingestion job
    /// ids agree end to end (same contract as
    /// [`TsqrClient::submit_with_id`]).
    pub fn ingest_gaussian_async_with_id(
        &self,
        id: JobId,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<ClientIngestHandle> {
        self.next_id.fetch_max(id.0 + 1, Ordering::Relaxed);
        self.ingest_async_id(id, name, rows, cols, seed, placement)
    }

    fn ingest_async_id(
        &self,
        id: JobId,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<ClientIngestHandle> {
        Ok(ClientIngestHandle {
            inner: self.transport.ingest_gaussian_async(id, name, rows, cols, seed, placement)?,
        })
    }

    /// Ingest an in-memory matrix onto the home shard (exact bits; on a
    /// process transport the rows ship as length-prefixed chunks).
    pub fn ingest_matrix(&self, name: &str, a: &Matrix) -> Result<MatrixHandle> {
        self.transport.ingest_matrix(name, a, Placement::Auto)
    }

    /// [`TsqrClient::ingest_matrix`] with an explicit global-shard
    /// placement.
    pub fn ingest_matrix_placed(
        &self,
        name: &str,
        a: &Matrix,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        self.transport.ingest_matrix(name, a, placement)
    }

    /// Read a handle's rows back from whichever shard/process holds
    /// them.
    pub fn get_matrix(&self, handle: &MatrixHandle) -> Result<Matrix> {
        self.transport.get_matrix(handle)
    }

    /// Mark a DFS file's virtual byte scale everywhere it is (or will
    /// be) staged.
    pub fn set_scale(&self, name: &str, scale: f64) -> Result<()> {
        self.transport.set_scale(name, scale)
    }

    // ----------------------------------------------------- submission

    /// Submit a job and return immediately with its handle. The client
    /// assigns the next global job id; `req.placement` (if pinned)
    /// names a *global* shard.
    pub fn submit(
        &self,
        input: &MatrixHandle,
        req: FactorizationRequest,
    ) -> Result<ClientJobHandle> {
        let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
        self.submit_id(id, input, req)
    }

    /// Submit under a *caller-chosen* job id (it must be fresh). This
    /// is the relay hook the wire protocol uses — a `mrtsqr serve`
    /// process runs jobs under the ids its remote peer assigned, so
    /// namespaces and fault streams agree end to end. Most callers
    /// want [`TsqrClient::submit`].
    pub fn submit_with_id(
        &self,
        id: JobId,
        input: &MatrixHandle,
        req: FactorizationRequest,
    ) -> Result<ClientJobHandle> {
        // keep auto-assigned ids ahead of any explicit ones
        self.next_id.fetch_max(id.0 + 1, Ordering::Relaxed);
        self.submit_id(id, input, req)
    }

    fn submit_id(
        &self,
        id: JobId,
        input: &MatrixHandle,
        req: FactorizationRequest,
    ) -> Result<ClientJobHandle> {
        Ok(ClientJobHandle { inner: self.transport.submit(id, input, req)? })
    }

    /// Run queued jobs on the calling thread in deterministic
    /// (priority, job-id) order — the serial baseline. Errors on a
    /// process transport (a pipe cannot lend threads).
    pub fn drain_now(&self) -> Result<usize> {
        self.transport.drain_now()
    }

    // ------------------------------------------------------ lifecycle

    /// Global shard index a job was placed on, where known (local
    /// transport: immediately; process transport: once the job
    /// completed — or read it off `Factorization::stats.shard`).
    pub fn shard_of(&self, id: JobId) -> Option<usize> {
        self.transport.shard_of(id)
    }

    /// Elastic-scheduling counters aggregated across the whole pool:
    /// steals per *global* shard plus per-label admission-hold tallies
    /// (merged by label across processes/hosts). All zeros/empty when
    /// the scheduler runs with everything off.
    pub fn sched_tally(&self) -> Result<SchedTally> {
        self.transport.sched_tally()
    }

    /// Sweep one finished job's DFS namespace; returns files removed.
    pub fn evict_job(&self, id: JobId) -> Result<usize> {
        self.transport.evict_job(id)
    }

    /// Fault-injection hook: kill worker process `proc` outright, as a
    /// crash/OOM would. Its in-flight jobs fail; every other worker
    /// keeps serving. Errors on the local transport.
    pub fn kill_worker(&self, proc: usize) -> Result<()> {
        self.transport.kill_worker(proc)
    }

    /// Graceful shutdown (also runs on drop): reject new work, let
    /// workers finish, reap child processes.
    pub fn shutdown(&self) {
        self.transport.shutdown();
    }
}

impl Drop for TsqrClient {
    fn drop(&mut self) {
        self.transport.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Backend, SubmitOptions, TsqrSession};

    fn local_client() -> TsqrClient {
        TsqrSession::builder()
            .backend(Backend::Native)
            .rows_per_task(50)
            .service_workers(0)
            .build_client()
            .unwrap()
    }

    #[test]
    fn local_client_round_trips_a_job() {
        let client = local_client();
        assert_eq!(client.procs(), 1);
        assert_eq!(client.shards(), 1);
        let h = client.ingest_gaussian("A", 300, 5, 1).unwrap();
        let job = client
            .submit(&h, FactorizationRequest::qr().options(SubmitOptions::new().label("smoke")))
            .unwrap();
        assert_eq!(job.status(), JobStatus::Queued);
        assert_eq!(job.label(), Some("smoke"));
        assert!(job.try_result().is_none());
        assert_eq!(client.drain_now().unwrap(), 1);
        let fact = job.wait().unwrap();
        assert_eq!(job.status(), JobStatus::Done);
        assert!(job.wall_secs().unwrap() >= 0.0);
        let q = client.get_matrix(fact.q.as_ref().unwrap()).unwrap();
        assert!(q.orthogonality_error() < 1e-10);
        assert!(client.evict_job(job.id()).unwrap() > 0);
        assert!(client.kill_worker(0).is_err(), "local transport has no process to kill");
        let tally = client.sched_tally().unwrap();
        assert_eq!(tally.per_shard_steals, vec![0], "nothing steals with the scheduler off");
        assert!(tally.admission_held.is_empty());
    }

    #[test]
    fn client_ids_are_sequential_and_fetch_max_respects_explicit_ids() {
        let client = local_client();
        let h = client.ingest_gaussian("A", 60, 3, 2).unwrap();
        let j0 = client.submit(&h, FactorizationRequest::r_only()).unwrap();
        let j1 = client.submit(&h, FactorizationRequest::r_only()).unwrap();
        assert_eq!((j0.id().0, j1.id().0), (0, 1));
        let j9 = client
            .submit_with_id(JobId(9), &h, FactorizationRequest::r_only())
            .unwrap();
        assert_eq!(j9.id().0, 9);
        let j10 = client.submit(&h, FactorizationRequest::r_only()).unwrap();
        assert_eq!(j10.id().0, 10, "auto ids must jump past explicit ones");
        client.drain_now().unwrap();
        for j in [&j0, &j1, &j9, &j10] {
            j.wait().unwrap();
        }
    }

    #[test]
    fn async_ingest_then_dependent_submit_over_the_local_transport() {
        let client = local_client();
        let ing = client.ingest_gaussian_async("A", 200, 4, 7, Placement::Auto).unwrap();
        assert_eq!(ing.id().0, 0);
        let job = client.submit(&ing.handle(), FactorizationRequest::r_only()).unwrap();
        assert_eq!(job.id().0, 1, "submit ids share the ingestion id space");
        assert_eq!(client.drain_now().unwrap(), 2, "ingest + dependent job");
        let h = ing.wait().unwrap();
        assert_eq!((h.rows, h.cols), (200, 4));
        assert_eq!(ing.status(), JobStatus::Done);
        assert_eq!(job.wait().unwrap().r.rows, 4);
    }

    #[test]
    fn duplicate_explicit_ids_are_rejected() {
        let client = local_client();
        let h = client.ingest_gaussian("A", 60, 3, 3).unwrap();
        let _j = client
            .submit_with_id(JobId(5), &h, FactorizationRequest::r_only())
            .unwrap();
        let err = client
            .submit_with_id(JobId(5), &h, FactorizationRequest::r_only())
            .unwrap_err();
        assert!(err.to_string().contains("already"), "{err}");
    }
}
