//! The network transport: a [`TcpTransport`] driving one or more
//! `mrtsqr serve --listen` hosts over length-prefixed `MRTQ` frames on
//! TCP sockets. This is [`super::process`] with the pipes swapped for
//! sockets — same wire format, same demux reader per connection, same
//! caller-assigned-id contract — plus the lifecycle a socket needs and
//! a pipe does not: a connection can *come back*.
//!
//! # Topology
//!
//! Each address in `SessionBuilder::connect(&[addrs])` names one
//! serving host: a `mrtsqr serve --listen` process running its own
//! engine pool (its own DFS shards, virtual clocks, and
//! [`crate::service::TsqrService`]). The server's topology wins — the
//! `Hello` ack reports its `engine_shards`, and every host must report
//! the same count so global shard `k` means
//! `(host k / shards_per_host, local shard k % shards_per_host)`,
//! exactly the pipe transport's flattening one layer up. Determinism
//! is preserved by construction: a job's DFS namespace and fault
//! stream depend only on its caller-assigned global id and every `f64`
//! crosses the wire as exact bits, so `result_digest`s are
//! bit-identical to an in-process or pipe-transport run.
//!
//! # Reconnect-and-resubmit
//!
//! A dead pipe means a dead child, so [`super::ProcessTransport`]
//! fails a worker's in-flight jobs outright. A dropped socket usually
//! means a network blip, so this transport *parks* the dropped
//! connection's jobs instead (their handles stay pending, status
//! `Queued`) and a background **keeper** thread reconnects, re-stages
//! inputs (gaussian recipes replay as seeds; the staged-copy records
//! for the host are dropped in case the server restarted), and
//! resubmits every parked job under its original id. Resolution is
//! first-writer-wins and the server retains finished jobs until
//! `Evict`, so a resubmission that races a delivered result — or
//! re-attaches to a job the server already finished — is harmless and
//! bit-identical. A job is failed only with a precise reason: its
//! resubmission was refused, the host was condemned after
//! `max_reconnect_attempts` consecutive failed dials, or the client
//! shut down first. Never silently lost.
//!
//! # Health checks and routing
//!
//! The keeper also pings every connected host each `health_interval`,
//! recording round-trip latency. [`NetRouter`] lifts the PR-4/5
//! placement rules across hosts: `Pinned(k)` maps to host
//! `k / shards_per_host` (an error if that host is down), `Auto` picks
//! the least-loaded live host (deterministic job-id tie-break) —
//! skipping hosts marked *suspect* by a timed-out request and, when at
//! least one brisk host is available, hosts whose last ping exceeded
//! `lag_threshold`.
//!
//! # Shutdown
//!
//! Unlike a pipe worker, a server is not owned by its client: shutdown
//! closes this client's sockets without sending `Shutdown`, and the
//! server keeps serving everyone else.

use super::process::{
    decode_job_done, decode_job_fail, GaussianRecipe, Peer, ProcRouter, RemoteIngestHandle,
    RemoteJob, RemoteJobHandle, RemoteState, ReplySlot, RouteBook, CHUNK_ROWS,
};
use super::transport::{Transport, TransportIngest, TransportJob};
use super::wire::{self, Frame, Op, WireReader, WireWriter, WorkerConfig};
use crate::coordinator::MatrixHandle;
use crate::linalg::Matrix;
use crate::service::{JobId, JobStatus, SchedTally};
use crate::session::{FactorizationRequest, Placement};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::io::{BufReader, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Knobs for the network transport, set through `SessionBuilder`.
#[derive(Debug, Clone, Copy)]
pub(crate) struct NetOptions {
    /// Reply deadline per request round-trip (`None` = wait forever).
    pub(crate) request_timeout: Option<Duration>,
    /// Dial deadline per connection attempt.
    pub(crate) connect_timeout: Duration,
    /// Keeper cadence: health pings and reconnect attempts.
    pub(crate) health_interval: Duration,
    /// Ping round-trips above this mark a host *lagging*: Auto jobs
    /// route around it while any brisk host is available.
    pub(crate) lag_threshold: Duration,
    /// Consecutive failed dials before a host is condemned and its
    /// parked jobs are failed.
    pub(crate) max_reconnect_attempts: usize,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions {
            request_timeout: Some(Duration::from_secs(30)),
            connect_timeout: Duration::from_secs(5),
            health_interval: Duration::from_millis(500),
            lag_threshold: Duration::from_millis(250),
            max_reconnect_attempts: 5,
        }
    }
}

// ----------------------------------------------------------------- router

/// One host's routing inputs: `load` is `None` when the host cannot
/// take work (disconnected, condemned, or suspect), `ping` its last
/// health round-trip.
pub(crate) struct HostHealth {
    pub(crate) load: Option<usize>,
    pub(crate) ping: Duration,
}

/// [`ProcRouter`] lifted across hosts, with latency awareness: Auto
/// placement skips lagging hosts whenever a brisk one is available;
/// pins ignore lag (a pin is a promise about *where*, not *when*).
pub(crate) struct NetRouter {
    inner: ProcRouter,
    lag_threshold: Duration,
}

impl NetRouter {
    pub(crate) fn new(nhosts: usize, shards_per_host: usize, lag_threshold: Duration) -> NetRouter {
        NetRouter { inner: ProcRouter::new(nhosts, shards_per_host), lag_threshold }
    }

    pub(crate) fn total_shards(&self) -> usize {
        self.inner.total_shards()
    }

    pub(crate) fn route(
        &self,
        id: JobId,
        placement: Placement,
        health: &[HostHealth],
    ) -> Result<(usize, Placement)> {
        if let Placement::Auto = placement {
            let brisk: Vec<Option<usize>> = health
                .iter()
                .map(|h| h.load.filter(|_| h.ping <= self.lag_threshold))
                .collect();
            if brisk.iter().any(Option::is_some) {
                return self.inner.route(id, placement, &brisk);
            }
            // every reachable host is lagging: a slow answer beats none
        }
        let reachable: Vec<Option<usize>> = health.iter().map(|h| h.load).collect();
        self.inner.route(id, placement, &reachable)
    }
}

// ------------------------------------------------------------- connection

/// One job parked on (or in flight to) a host: everything needed to
/// resubmit it verbatim after a reconnect.
#[derive(Clone)]
struct TrackedJob {
    job: Arc<RemoteJob>,
    input: MatrixHandle,
    /// As sent: placement already mapped to the host-local index.
    req: FactorizationRequest,
}

/// One serving host's connection state. The socket write half lives
/// behind `stream` (`None` while disconnected); a reader thread owns
/// the read half and demuxes frames exactly like the pipe transport's.
/// `epoch` counts connections so a stale reader of a replaced socket
/// cannot tear down its successor.
struct HostConn {
    index: usize,
    addr: String,
    book: Arc<RouteBook>,
    /// Set from the first `HelloAck` (the server's topology wins);
    /// readers remap worker-local shard indices through it.
    shards_per_host: Arc<AtomicUsize>,
    stream: Mutex<Option<TcpStream>>,
    epoch: AtomicU64,
    /// Correlation ids start at 1: 0 tags pushed frames.
    next_req: AtomicU64,
    pending: Mutex<HashMap<u64, Arc<ReplySlot>>>,
    /// In-flight *and parked* jobs, keyed by id (ordered so
    /// resubmission walks ids deterministically).
    jobs: Mutex<BTreeMap<u64, TrackedJob>>,
    connected: AtomicBool,
    /// Condemned: reconnect attempts exhausted, parked jobs failed.
    dead: AtomicBool,
    /// A request timed out against this host — skipped by Auto routing
    /// until its next frame arrives (mirrors the pipe transport).
    suspect: AtomicBool,
    load: AtomicUsize,
    /// Last health-ping round-trip, in nanoseconds.
    ping_nanos: AtomicU64,
    reconnect_failures: AtomicUsize,
    reader: Mutex<Option<JoinHandle<()>>>,
    request_timeout: Option<Duration>,
    connect_timeout: Duration,
}

impl HostConn {
    fn new(
        index: usize,
        addr: String,
        book: Arc<RouteBook>,
        shards_per_host: Arc<AtomicUsize>,
        opts: &NetOptions,
    ) -> Arc<HostConn> {
        Arc::new(HostConn {
            index,
            addr,
            book,
            shards_per_host,
            stream: Mutex::new(None),
            epoch: AtomicU64::new(0),
            next_req: AtomicU64::new(1),
            pending: Mutex::new(HashMap::new()),
            jobs: Mutex::new(BTreeMap::new()),
            connected: AtomicBool::new(false),
            dead: AtomicBool::new(false),
            suspect: AtomicBool::new(false),
            load: AtomicUsize::new(0),
            ping_nanos: AtomicU64::new(0),
            reconnect_failures: AtomicUsize::new(0),
            reader: Mutex::new(None),
            request_timeout: opts.request_timeout,
            connect_timeout: opts.connect_timeout,
        })
    }

    /// Dial, install the socket under a fresh epoch, spawn the demux
    /// reader, and run the `Hello` handshake. Returns the topology the
    /// ack reported: `(shards, workers, capacity, host_threads,
    /// backend)`.
    fn establish(
        self: &Arc<Self>,
        cfg: &WorkerConfig,
    ) -> Result<(usize, usize, usize, usize, String)> {
        // the previous connection's reader (if any) is exiting — its
        // socket is shut down; reclaim the handle before spawning anew
        self.join_reader();
        let target = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {:?}", self.addr))?
            .next()
            .ok_or_else(|| anyhow!("address {:?} resolved to nothing", self.addr))?;
        let stream = TcpStream::connect_timeout(&target, self.connect_timeout)
            .with_context(|| format!("connecting to {} (host {})", self.addr, self.index))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_write_timeout(self.request_timeout);
        let read_half = stream.try_clone().context("cloning the socket's read half")?;
        let epoch = {
            let mut guard = self.stream.lock().expect("host stream");
            let epoch = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
            *guard = Some(stream);
            self.connected.store(true, Ordering::SeqCst);
            self.suspect.store(false, Ordering::SeqCst);
            epoch
        };
        let reader = {
            let host = self.clone();
            std::thread::Builder::new()
                .name(format!("mrtsqr-net-demux-{}", self.index))
                .spawn(move || reader_loop(&host, read_half, epoch))
                .expect("spawn net demux reader")
        };
        *self.reader.lock().expect("reader slot") = Some(reader);

        let handshake = (|| -> Result<(usize, usize, usize, usize, String)> {
            let mut w = WireWriter::new();
            w.config(cfg);
            let ack = self
                .request(Op::Hello, &w.into_bytes())
                .with_context(|| format!("handshaking host {} ({})", self.index, self.addr))?;
            ensure!(
                ack.op == Op::HelloAck,
                "host {}: expected HelloAck, got {:?}",
                self.index,
                ack.op
            );
            let mut r = WireReader::new(&ack.payload);
            let shards = r.usize()?;
            let workers = r.usize()?;
            let capacity = r.usize()?;
            let host_threads = r.usize()?;
            let backend = r.str()?;
            r.finish()?;
            Ok((shards, workers, capacity, host_threads, backend))
        })();
        if handshake.is_err() {
            self.on_disconnect(None, "handshake failed");
        }
        handshake
    }

    /// Send one request frame and block for its reply, with the same
    /// no-deadlock shape as the pipe transport's (slot registered
    /// before the write; a dying reader fails every registered slot).
    fn request(&self, op: Op, payload: &[u8]) -> Result<Frame> {
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let slot = Arc::new(ReplySlot::new());
        self.pending.lock().expect("pending map").insert(req_id, slot.clone());
        let write_result = {
            let mut stream = self.stream.lock().expect("host stream");
            match stream.as_mut() {
                None => Err(anyhow!("not connected")),
                Some(s) => wire::write_frame(s, op, req_id, payload)
                    .and_then(|()| s.flush().map_err(Into::into)),
            }
        };
        if let Err(err) = write_result {
            self.pending.lock().expect("pending map").remove(&req_id);
            bail!("host {} ({}): {err:#}", self.index, self.addr);
        }
        let frame = match slot.take(self.request_timeout) {
            Some(reply) => reply?,
            None => {
                self.pending.lock().expect("pending map").remove(&req_id);
                self.suspect.store(true, Ordering::SeqCst);
                bail!(
                    "host {} ({}) did not answer {:?} within {:?} — marked suspect; \
                     it rejoins Auto routing when it speaks again",
                    self.index,
                    self.addr,
                    op,
                    self.request_timeout.expect("deadline implies a timeout")
                );
            }
        };
        if frame.op == Op::Err {
            let msg = WireReader::new(&frame.payload)
                .str()
                .unwrap_or_else(|_| "malformed error reply".into());
            bail!("host {} ({}): {msg}", self.index, self.addr);
        }
        Ok(frame)
    }

    /// Tear down the current connection (idempotent): close the
    /// socket, fail pending request waiters — and *park* this host's
    /// jobs untouched for the keeper to resubmit. `epoch` guards a
    /// stale reader of an already-replaced connection.
    fn on_disconnect(&self, epoch: Option<u64>, why: &str) {
        {
            let mut guard = self.stream.lock().expect("host stream");
            if let Some(e) = epoch {
                if self.epoch.load(Ordering::SeqCst) != e {
                    return;
                }
            }
            if let Some(s) = guard.take() {
                let _ = s.shutdown(Shutdown::Both);
            }
            self.connected.store(false, Ordering::SeqCst);
        }
        let pending: Vec<Arc<ReplySlot>> =
            self.pending.lock().expect("pending map").drain().map(|(_, s)| s).collect();
        for slot in pending {
            slot.fill(Err(anyhow!("host {} ({}): {why}", self.index, self.addr)));
        }
    }

    /// Condemn the host for good: no more reconnects, and every parked
    /// job fails with a precise reason.
    fn condemn(&self, why: &str) {
        self.dead.store(true, Ordering::SeqCst);
        self.on_disconnect(None, why);
        let parked = std::mem::take(&mut *self.jobs.lock().expect("jobs map"));
        for (_, t) in parked {
            self.load.fetch_sub(1, Ordering::Relaxed);
            t.job.resolve(RemoteState::Failed {
                msg: format!("host {} ({}) {why}", self.index, self.addr),
                wall_secs: None,
            });
        }
    }

    fn join_reader(&self) {
        let handle = self.reader.lock().expect("reader slot").take();
        if let Some(handle) = handle {
            let _ = handle.join();
        }
    }
}

impl Peer for HostConn {
    fn request(&self, op: Op, payload: &[u8]) -> Result<Frame> {
        HostConn::request(self, op, payload)
    }

    fn offline_status(&self) -> JobStatus {
        // a dropped connection parks its jobs for resubmission: they
        // are queued, not failed (a condemned host resolves them
        // terminally, so this fallback never reports a lie for long)
        JobStatus::Queued
    }
}

/// The demux loop for one host connection — the socket twin of the
/// pipe transport's, ending in *park* (via [`HostConn::on_disconnect`])
/// instead of fail-all.
fn reader_loop(host: &Arc<HostConn>, stream: TcpStream, epoch: u64) {
    let mut input = BufReader::new(stream);
    let why = loop {
        let frame = match wire::read_frame(&mut input) {
            Ok(Some(frame)) => frame,
            Ok(None) => break "connection closed".to_string(),
            Err(err) => break format!("connection desynchronized: {err:#}"),
        };
        host.suspect.store(false, Ordering::SeqCst);
        match frame.op {
            Op::JobDone => match decode_job_done(&frame.payload) {
                Ok((id, wall_secs, mut fact)) => {
                    let spp = host.shards_per_host.load(Ordering::SeqCst).max(1);
                    let global = host.index * spp + fact.stats.shard;
                    fact.stats.shard = global;
                    if let Some(entry) =
                        host.book.placements.lock().expect("placements").get_mut(&id)
                    {
                        entry.1 = Some(global);
                    }
                    if let Some(q) = &fact.q {
                        host.book
                            .staged
                            .lock()
                            .expect("staged map")
                            .entry(q.file.clone())
                            .or_default()
                            .insert(host.index);
                    }
                    if let Some(t) = host.jobs.lock().expect("jobs map").remove(&id) {
                        host.load.fetch_sub(1, Ordering::Relaxed);
                        t.job.resolve(RemoteState::Done { fact: Arc::new(fact), wall_secs });
                    }
                }
                Err(err) => break format!("sent a malformed JobDone: {err:#}"),
            },
            Op::JobFail => match decode_job_fail(&frame.payload) {
                Ok((id, status, wall_secs, msg)) => {
                    if let Some(t) = host.jobs.lock().expect("jobs map").remove(&id) {
                        host.load.fetch_sub(1, Ordering::Relaxed);
                        let state = if status == JobStatus::Cancelled {
                            RemoteState::Cancelled
                        } else {
                            RemoteState::Failed { msg, wall_secs }
                        };
                        t.job.resolve(state);
                    }
                }
                Err(err) => break format!("sent a malformed JobFail: {err:#}"),
            },
            _ => {
                let slot = host.pending.lock().expect("pending map").remove(&frame.req_id);
                if let Some(slot) = slot {
                    slot.fill(Ok(frame));
                }
            }
        }
    };
    host.on_disconnect(Some(epoch), &why);
}

// --------------------------------------------------------------- the core

/// Everything the transport and its keeper thread share.
struct NetCore {
    hosts: Vec<Arc<HostConn>>,
    router: NetRouter,
    shards_per_host: usize,
    book: Arc<RouteBook>,
    recipes: Mutex<HashMap<String, GaussianRecipe>>,
    scales: Mutex<HashMap<String, f64>>,
    /// The cluster recipe re-sent as `Hello` on every reconnect (a
    /// prebuilt server ignores its contents, but the handshake still
    /// negotiates the wire version and reports topology).
    cfg: WorkerConfig,
    opts: NetOptions,
    workers_per_host: usize,
    capacity: usize,
    host_threads: usize,
    backend_desc: String,
    /// Keeper stop flag + condvar: shutdown interrupts the sleep
    /// instead of waiting out a full health interval.
    stop: Mutex<bool>,
    stop_cv: Condvar,
}

impl NetCore {
    fn health(&self) -> Vec<HostHealth> {
        self.hosts
            .iter()
            .map(|h| HostHealth {
                load: (h.connected.load(Ordering::SeqCst)
                    && !h.dead.load(Ordering::SeqCst)
                    && !h.suspect.load(Ordering::SeqCst))
                .then(|| h.load.load(Ordering::Relaxed)),
                ping: Duration::from_nanos(h.ping_nanos.load(Ordering::Relaxed)),
            })
            .collect()
    }

    fn is_staged(&self, name: &str, hidx: usize) -> bool {
        self.book
            .staged
            .lock()
            .expect("staged map")
            .get(name)
            .is_some_and(|hosts| hosts.contains(&hidx))
    }

    fn mark_staged(&self, name: &str, hidx: usize, exclusive: bool) {
        let mut staged = self.book.staged.lock().expect("staged map");
        let entry = staged.entry(name.to_string()).or_default();
        if exclusive {
            entry.clear();
        }
        entry.insert(hidx);
    }

    /// Ship an in-memory matrix to one host in bounded chunks.
    fn send_matrix(
        &self,
        host: &HostConn,
        name: &str,
        a: &Matrix,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        let mut w = WireWriter::new();
        w.str(name);
        w.u64(a.cols as u64);
        w.placement(placement);
        host.request(Op::IngestBegin, &w.into_bytes())?;
        let mut row = 0;
        while row < a.rows {
            let take = CHUNK_ROWS.min(a.rows - row);
            let mut w = WireWriter::new();
            w.chunk(name, row as u64, a.cols, &a.data[row * a.cols..(row + take) * a.cols]);
            host.request(Op::IngestChunk, &w.into_bytes())?;
            row += take;
        }
        let mut w = WireWriter::new();
        w.str(name);
        let reply = host.request(Op::IngestEnd, &w.into_bytes())?;
        ensure!(reply.op == Op::Handle, "expected Handle, got {:?}", reply.op);
        let mut r = WireReader::new(&reply.payload);
        let handle = r.handle()?;
        r.finish()?;
        Ok(handle)
    }

    /// Make `handle`'s file readable on host `hidx` — the pipe
    /// transport's staging logic verbatim (recipes replay as seeds,
    /// outputs are fetched back from a host that holds them).
    fn ensure_staged(&self, hidx: usize, handle: &MatrixHandle) -> Result<()> {
        if self.is_staged(&handle.file, hidx) {
            return Ok(());
        }
        let host = &self.hosts[hidx];
        let recipe = self.recipes.lock().expect("recipes").get(&handle.file).copied();
        if let Some(GaussianRecipe { rows, cols, seed }) = recipe {
            let mut w = WireWriter::new();
            w.str(&handle.file);
            w.u64(rows as u64);
            w.u64(cols as u64);
            w.u64(seed);
            w.placement(Placement::Auto);
            host.request(Op::IngestGaussian, &w.into_bytes())?;
        } else {
            let rows = self.fetch_matrix(handle)?;
            self.send_matrix(host, &handle.file, &rows, Placement::Auto)?;
        }
        let scale = self.scales.lock().expect("scales").get(&handle.file).copied();
        if let Some(scale) = scale {
            let mut w = WireWriter::new();
            w.str(&handle.file);
            w.f64(scale);
            host.request(Op::SetScale, &w.into_bytes())?;
        }
        self.mark_staged(&handle.file, hidx, false);
        Ok(())
    }

    fn fetch_matrix(&self, handle: &MatrixHandle) -> Result<Matrix> {
        let known: Vec<usize> = self
            .book
            .staged
            .lock()
            .expect("staged map")
            .get(&handle.file)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default();
        let mut order: Vec<usize> = known;
        for i in 0..self.hosts.len() {
            if !order.contains(&i) {
                order.push(i);
            }
        }
        let mut last_err = anyhow!("no reachable host holds {:?}", handle.file);
        for hidx in order {
            let host = &self.hosts[hidx];
            if !host.connected.load(Ordering::SeqCst) {
                continue;
            }
            let mut w = WireWriter::new();
            w.handle(handle);
            match host.request(Op::FetchMatrix, &w.into_bytes()) {
                Ok(reply) => {
                    ensure!(
                        reply.op == Op::MatrixData,
                        "expected MatrixData, got {:?}",
                        reply.op
                    );
                    let mut r = WireReader::new(&reply.payload);
                    let m = r.matrix()?;
                    r.finish()?;
                    self.mark_staged(&handle.file, hidx, false);
                    return Ok(m);
                }
                Err(err) => last_err = err,
            }
        }
        Err(last_err)
    }

    fn ingest_target(&self, placement: Placement) -> Result<(usize, Placement)> {
        match placement {
            Placement::Auto => Ok((0, Placement::Auto)),
            Placement::Pinned(k) => {
                ensure!(
                    k < self.router.total_shards(),
                    "ingest pinned to global shard {k}, but the client has {}",
                    self.router.total_shards()
                );
                let hidx = k / self.shards_per_host;
                ensure!(
                    self.hosts[hidx].connected.load(Ordering::SeqCst),
                    "ingest pinned to shard {k}, but host {hidx} is not connected"
                );
                Ok((hidx, Placement::Pinned(k % self.shards_per_host)))
            }
        }
    }

    /// One reconnect attempt for a disconnected host (keeper-only).
    fn revive(&self, host: &Arc<HostConn>) {
        match host.establish(&self.cfg) {
            Ok((shards, ..)) => {
                if shards != self.shards_per_host {
                    host.condemn(&format!(
                        "came back serving {shards} shard(s), expected {} — \
                         topology drift breaks global shard indexing",
                        self.shards_per_host
                    ));
                    return;
                }
                host.reconnect_failures.store(0, Ordering::SeqCst);
                // the server may have restarted and lost its DFS:
                // forget this host's staged copies so resubmission
                // re-stages every input it needs (gaussian recipes
                // replay as seeds — identical records by construction)
                for hosts in self.book.staged.lock().expect("staged map").values_mut() {
                    hosts.remove(&host.index);
                }
                self.resubmit_parked(host);
            }
            Err(err) => {
                let failures = host.reconnect_failures.fetch_add(1, Ordering::SeqCst) + 1;
                if failures >= self.opts.max_reconnect_attempts {
                    host.condemn(&format!(
                        "is unreachable after {failures} reconnect attempt(s): {err:#}"
                    ));
                }
            }
        }
    }

    /// Resubmit every job parked on a freshly reconnected host under
    /// its original id. The server retains finished jobs until
    /// `Evict`, so a job it already completed re-attaches and pushes
    /// the identical result; a job it never saw (or lost to a restart)
    /// re-runs bit-identically — its namespace and fault stream depend
    /// only on the id. A job whose resubmission fails is failed with a
    /// precise reason, never dropped on the floor.
    fn resubmit_parked(&self, host: &Arc<HostConn>) {
        let parked: Vec<(u64, TrackedJob)> = host
            .jobs
            .lock()
            .expect("jobs map")
            .iter()
            .map(|(id, t)| (*id, t.clone()))
            .collect();
        for (id, t) in parked {
            if t.job.terminal_status().is_some() {
                continue;
            }
            let outcome = self.ensure_staged(host.index, &t.input).and_then(|()| {
                let mut w = WireWriter::new();
                w.u64(id);
                w.handle(&t.input);
                w.request(&t.req);
                host.request(Op::Submit, &w.into_bytes()).map(|_| ())
            });
            if let Err(err) = outcome {
                if host.jobs.lock().expect("jobs map").remove(&id).is_some() {
                    host.load.fetch_sub(1, Ordering::Relaxed);
                    t.job.resolve(RemoteState::Failed {
                        msg: format!(
                            "was parked on host {} ({}) when its connection dropped, \
                             and resubmission after reconnect failed: {err:#}",
                            host.index, host.addr
                        ),
                        wall_secs: None,
                    });
                }
            }
        }
    }
}

/// The keeper: pings connected hosts (liveness + latency for the
/// router's lag mask) and revives disconnected ones, every
/// `health_interval`, until shutdown flips the stop flag.
fn keeper_loop(core: &Arc<NetCore>) {
    loop {
        {
            let stopped = core.stop.lock().expect("keeper stop flag");
            let (stopped, _) = core
                .stop_cv
                .wait_timeout(stopped, core.opts.health_interval)
                .expect("keeper stop flag");
            if *stopped {
                return;
            }
        }
        for host in &core.hosts {
            if host.dead.load(Ordering::SeqCst) {
                continue;
            }
            if host.connected.load(Ordering::SeqCst) {
                let started = Instant::now();
                match host.request(Op::Ping, &[]) {
                    Ok(frame) if frame.op == Op::Pong => {
                        let nanos = started.elapsed().as_nanos().min(u128::from(u64::MAX));
                        host.ping_nanos.store(nanos as u64, Ordering::Relaxed);
                    }
                    Ok(frame) => {
                        host.on_disconnect(
                            None,
                            &format!("health ping answered with {:?}", frame.op),
                        );
                    }
                    Err(err) => {
                        host.on_disconnect(None, &format!("health ping failed: {err:#}"));
                    }
                }
            } else {
                core.revive(host);
            }
        }
    }
}

// -------------------------------------------------------------- transport

/// The network [`Transport`]: see the [module docs](self).
pub struct TcpTransport {
    core: Arc<NetCore>,
    keeper: Mutex<Option<JoinHandle<()>>>,
    down: AtomicBool,
}

impl TcpTransport {
    /// Dial every address, handshake each host, validate the shared
    /// shard count, and start the keeper. Any host failing the initial
    /// dial fails the whole connect (reconnects only cover drops
    /// *after* a topology was established).
    pub(crate) fn connect(
        addrs: &[String],
        cfg: WorkerConfig,
        opts: NetOptions,
    ) -> Result<TcpTransport> {
        ensure!(!addrs.is_empty(), "connect wants at least one server address");
        let book = Arc::new(RouteBook::default());
        let shards_per_host = Arc::new(AtomicUsize::new(0));
        let hosts: Vec<Arc<HostConn>> = addrs
            .iter()
            .enumerate()
            .map(|(index, addr)| {
                HostConn::new(index, addr.clone(), book.clone(), shards_per_host.clone(), &opts)
            })
            .collect();
        let teardown = |hosts: &[Arc<HostConn>]| {
            for host in hosts {
                host.on_disconnect(None, "client startup failed");
                host.join_reader();
            }
        };
        let mut topo = None;
        for host in &hosts {
            let (shards, workers, capacity, host_threads, backend) =
                match host.establish(&cfg) {
                    Ok(t) => t,
                    Err(err) => {
                        teardown(&hosts);
                        return Err(err);
                    }
                };
            let known = shards_per_host.load(Ordering::SeqCst);
            if known == 0 {
                shards_per_host.store(shards.max(1), Ordering::SeqCst);
            } else if shards != known {
                let (index, addr) = (host.index, host.addr.clone());
                teardown(&hosts);
                bail!(
                    "host {index} ({addr}) serves {shards} shard(s) but host 0 serves \
                     {known} — every host must run the same engine-shard count so \
                     global shard indices mean the same thing everywhere"
                );
            }
            topo = Some((workers, capacity, host_threads, backend));
        }
        let (workers_per_host, capacity, host_threads, backend_desc) =
            topo.expect("at least one host");
        let spp = shards_per_host.load(Ordering::SeqCst).max(1);
        let core = Arc::new(NetCore {
            router: NetRouter::new(hosts.len(), spp, opts.lag_threshold),
            shards_per_host: spp,
            hosts,
            book,
            recipes: Mutex::new(HashMap::new()),
            scales: Mutex::new(HashMap::new()),
            cfg,
            opts,
            workers_per_host,
            capacity,
            host_threads,
            backend_desc,
            stop: Mutex::new(false),
            stop_cv: Condvar::new(),
        });
        let keeper = {
            let core = core.clone();
            std::thread::Builder::new()
                .name("mrtsqr-net-keeper".into())
                .spawn(move || keeper_loop(&core))
                .expect("spawn net keeper")
        };
        Ok(TcpTransport { core, keeper: Mutex::new(Some(keeper)), down: AtomicBool::new(false) })
    }
}

impl Transport for TcpTransport {
    fn procs(&self) -> usize {
        self.core.hosts.len()
    }

    fn shards(&self) -> usize {
        self.core.router.total_shards()
    }

    fn workers(&self) -> usize {
        self.core.workers_per_host * self.core.hosts.len()
    }

    fn capacity(&self) -> usize {
        self.core.capacity
    }

    fn backend_desc(&self) -> String {
        self.core.backend_desc.clone()
    }

    fn host_threads(&self) -> usize {
        self.core.host_threads
    }

    fn ingest_gaussian(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        let core = &self.core;
        let (hidx, local) = core.ingest_target(placement)?;
        let mut w = WireWriter::new();
        w.str(name);
        w.u64(rows as u64);
        w.u64(cols as u64);
        w.u64(seed);
        w.placement(local);
        let reply = core.hosts[hidx].request(Op::IngestGaussian, &w.into_bytes())?;
        ensure!(reply.op == Op::Handle, "expected Handle, got {:?}", reply.op);
        let mut r = WireReader::new(&reply.payload);
        let handle = r.handle()?;
        r.finish()?;
        core.recipes
            .lock()
            .expect("recipes")
            .insert(name.to_string(), GaussianRecipe { rows, cols, seed });
        core.mark_staged(name, hidx, true);
        Ok(handle)
    }

    fn ingest_gaussian_async(
        &self,
        id: JobId,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
        placement: Placement,
    ) -> Result<Box<dyn TransportIngest>> {
        let core = &self.core;
        let (hidx, local) = core.ingest_target(placement)?;
        let mut w = WireWriter::new();
        w.u64(id.0);
        w.str(name);
        w.u64(rows as u64);
        w.u64(cols as u64);
        w.u64(seed);
        w.placement(local);
        let reply = core.hosts[hidx].request(Op::IngestAsync, &w.into_bytes())?;
        ensure!(reply.op == Op::Handle, "expected Handle, got {:?}", reply.op);
        let mut r = WireReader::new(&reply.payload);
        let handle = r.handle()?;
        r.finish()?;
        core.recipes
            .lock()
            .expect("recipes")
            .insert(name.to_string(), GaussianRecipe { rows, cols, seed });
        core.mark_staged(name, hidx, true);
        Ok(Box::new(RemoteIngestHandle { id, handle, conn: core.hosts[hidx].clone() }))
    }

    fn ingest_matrix(
        &self,
        name: &str,
        a: &Matrix,
        placement: Placement,
    ) -> Result<MatrixHandle> {
        let core = &self.core;
        let (hidx, local) = core.ingest_target(placement)?;
        let handle = core.send_matrix(&core.hosts[hidx], name, a, local)?;
        core.recipes.lock().expect("recipes").remove(name);
        core.mark_staged(name, hidx, true);
        Ok(handle)
    }

    fn submit(
        &self,
        id: JobId,
        input: &MatrixHandle,
        mut req: FactorizationRequest,
    ) -> Result<Box<dyn TransportJob>> {
        let core = &self.core;
        let (hidx, local) = core.router.route(id, req.options.placement, &core.health())?;
        {
            let mut placements = core.book.placements.lock().expect("placements");
            if placements.contains_key(&id.0) {
                bail!("job id {id} is already in use by a live (unevicted) job");
            }
            placements.insert(id.0, (hidx, None));
        }
        if let Err(err) = core.ensure_staged(hidx, input) {
            core.book.placements.lock().expect("placements").remove(&id.0);
            return Err(err);
        }
        req.options.placement = local;
        let host = core.hosts[hidx].clone();
        let job = Arc::new(RemoteJob::new(id, req.options.label.clone()));
        host.jobs.lock().expect("jobs map").insert(
            id.0,
            TrackedJob { job: job.clone(), input: input.clone(), req: req.clone() },
        );
        host.load.fetch_add(1, Ordering::Relaxed);
        let mut w = WireWriter::new();
        w.u64(id.0);
        w.handle(input);
        w.request(&req);
        match host.request(Op::Submit, &w.into_bytes()) {
            Ok(_) => Ok(Box::new(RemoteJobHandle { job, conn: host })),
            Err(err) => {
                // a submit the host never acknowledged: roll back
                // rather than park — the caller holds the error
                if host.jobs.lock().expect("jobs map").remove(&id.0).is_some() {
                    host.load.fetch_sub(1, Ordering::Relaxed);
                }
                core.book.placements.lock().expect("placements").remove(&id.0);
                Err(err)
            }
        }
    }

    fn get_matrix(&self, handle: &MatrixHandle) -> Result<Matrix> {
        self.core.fetch_matrix(handle)
    }

    fn set_scale(&self, name: &str, scale: f64) -> Result<()> {
        self.core.scales.lock().expect("scales").insert(name.to_string(), scale);
        for host in &self.core.hosts {
            // a disconnected host re-stages (and re-scales) everything
            // it needs after reconnect — skipping it here is safe
            if !host.connected.load(Ordering::SeqCst) {
                continue;
            }
            let mut w = WireWriter::new();
            w.str(name);
            w.f64(scale);
            host.request(Op::SetScale, &w.into_bytes())?;
        }
        Ok(())
    }

    fn evict_job(&self, id: JobId) -> Result<usize> {
        let core = &self.core;
        if !core.book.placements.lock().expect("placements").contains_key(&id.0) {
            return Ok(0);
        }
        // sweep every connected host (chained jobs may have re-staged
        // outputs anywhere); this also releases the server-side job
        // registry entry that backed reconnect re-attachment
        let mut swept = 0;
        for host in &core.hosts {
            if !host.connected.load(Ordering::SeqCst) {
                continue;
            }
            let mut w = WireWriter::new();
            w.u64(id.0);
            if let Ok(reply) = host.request(Op::Evict, &w.into_bytes()) {
                let mut r = WireReader::new(&reply.payload);
                swept += r.usize().unwrap_or(0);
            }
        }
        core.book.placements.lock().expect("placements").remove(&id.0);
        let ns = format!("job-{}/", id.0);
        core.book
            .staged
            .lock()
            .expect("staged map")
            .retain(|name, _| !name.contains(&ns));
        Ok(swept)
    }

    fn drain_now(&self) -> Result<usize> {
        bail!(
            "manual drain needs the caller's thread inside the engine pool — \
             impossible across the network; use service workers (the default)"
        )
    }

    fn shard_of(&self, id: JobId) -> Option<usize> {
        self.core
            .book
            .placements
            .lock()
            .expect("placements")
            .get(&id.0)
            .and_then(|(_, shard)| *shard)
    }

    fn sched_tally(&self) -> Result<SchedTally> {
        // same aggregation as the pipe transport, one level up: each
        // host's tally covers its local shards, remapped into the
        // global index space; admission holds merge by label
        let core = &self.core;
        let mut per_shard = vec![0u64; core.router.total_shards()];
        let mut held: BTreeMap<String, u64> = BTreeMap::new();
        for host in &core.hosts {
            if !host.connected.load(Ordering::SeqCst) {
                continue;
            }
            let reply = host.request(Op::SchedTally, &[])?;
            ensure!(reply.op == Op::TallyReply, "expected TallyReply, got {:?}", reply.op);
            let mut r = WireReader::new(&reply.payload);
            let tally = r.tally()?;
            r.finish()?;
            for (local, n) in tally.per_shard_steals.iter().enumerate() {
                if let Some(slot) = per_shard.get_mut(host.index * core.shards_per_host + local) {
                    *slot = *n;
                }
            }
            for (label, n) in tally.admission_held {
                *held.entry(label).or_default() += n;
            }
        }
        Ok(SchedTally {
            per_shard_steals: per_shard,
            admission_held: held.into_iter().collect(),
        })
    }

    /// Fault-injection hook, reinterpreted for the network: sever the
    /// connection to host `proc` as if the network blipped. The server
    /// process keeps running; the keeper reconnects and resubmits the
    /// parked jobs (this is what the mid-batch-kill determinism test
    /// exercises).
    fn kill_worker(&self, proc: usize) -> Result<()> {
        let host = self
            .core
            .hosts
            .get(proc)
            .ok_or_else(|| anyhow!("no host {proc} (client has {})", self.core.hosts.len()))?;
        ensure!(
            host.connected.load(Ordering::SeqCst),
            "host {proc} is already disconnected"
        );
        host.on_disconnect(None, "connection severed by the client (fault injection)");
        Ok(())
    }

    fn shutdown(&self) {
        if self.down.swap(true, Ordering::SeqCst) {
            return;
        }
        // stop the keeper first so nothing reconnects behind us
        {
            let mut stopped = self.core.stop.lock().expect("keeper stop flag");
            *stopped = true;
            self.core.stop_cv.notify_all();
        }
        if let Some(keeper) = self.keeper.lock().expect("keeper handle").take() {
            let _ = keeper.join();
        }
        for host in &self.core.hosts {
            // deliberately not Op::Shutdown: the server outlives its
            // clients (it may be serving others right now)
            host.on_disconnect(None, "client shut down");
            host.join_reader();
            let parked = std::mem::take(&mut *host.jobs.lock().expect("jobs map"));
            for (_, t) in parked {
                host.load.fetch_sub(1, Ordering::Relaxed);
                t.job.resolve(RemoteState::Failed {
                    msg: format!(
                        "the client shut down while the job was parked for \
                         resubmission to host {} ({})",
                        host.index, host.addr
                    ),
                    wall_secs: None,
                });
            }
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn health(entries: &[(Option<usize>, u64)]) -> Vec<HostHealth> {
        entries
            .iter()
            .map(|&(load, ping_ms)| HostHealth { load, ping: Duration::from_millis(ping_ms) })
            .collect()
    }

    #[test]
    fn auto_routes_around_lagging_hosts_when_a_brisk_one_exists() {
        let router = NetRouter::new(3, 2, Duration::from_millis(100));
        // host 0 idle but lagging; host 2 busier but brisk: auto skips 0
        let h = health(&[(Some(0), 500), (None, 0), (Some(3), 5)]);
        let (host, _) = router.route(JobId(1), Placement::Auto, &h).unwrap();
        assert_eq!(host, 2, "lagging host skipped while a brisk one lives");
    }

    #[test]
    fn auto_falls_back_to_lagging_hosts_when_all_lag() {
        let router = NetRouter::new(2, 1, Duration::from_millis(100));
        let h = health(&[(Some(7), 500), (Some(2), 900)]);
        let (host, _) = router.route(JobId(4), Placement::Auto, &h).unwrap();
        assert_eq!(host, 1, "a slow answer beats none: least-loaded among laggards");
    }

    #[test]
    fn pins_ignore_lag_but_not_death() {
        let router = NetRouter::new(2, 2, Duration::from_millis(100));
        let h = health(&[(Some(0), 5), (Some(0), 900)]);
        // global shard 3 → host 1, local shard 1 — lag is no obstacle
        assert_eq!(
            router.route(JobId(9), Placement::Pinned(3), &h).unwrap(),
            (1, Placement::Pinned(1))
        );
        let h = health(&[(Some(0), 5), (None, 0)]);
        assert!(router.route(JobId(9), Placement::Pinned(3), &h).is_err());
    }
}
