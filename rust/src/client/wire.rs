//! The versioned binary wire format of the cross-process protocol.
//!
//! Everything a [`crate::client::TsqrClient`] ships between processes —
//! [`FactorizationRequest`]s, [`Factorization`]s, [`JobStats`],
//! [`JobStatus`], ingestion chunks — is encoded here by hand (serde is
//! not vendored offline, in the same spirit as
//! [`crate::util::json::Json`] on the emission side). Three properties
//! the protocol depends on:
//!
//! * **Length-prefixed framing.** Every message is one [`Frame`]:
//!   a fixed header (`magic "MRTQ"`, `version`, `opcode`, `req_id`,
//!   payload length) followed by exactly `len` payload bytes, so a
//!   reader thread can demultiplex many in-flight requests off one pipe
//!   without any payload knowledge.
//! * **Exact-bit `f64`.** Floats travel as `to_bits()` little-endian
//!   words ([`WireWriter::f64`]/[`WireReader::f64`]), never through a
//!   decimal detour, so `R`/Σ/`virtual_secs` — and with them
//!   [`crate::session::Factorization::result_digest`] — survive the
//!   trip bit-for-bit. In-process and cross-process runs of the same
//!   job agree on every digest (`rust/tests/client.rs`).
//! * **Versioned and self-describing.** The header carries
//!   [`WIRE_VERSION`]; a peer speaking a different version is rejected
//!   at the handshake, never mis-parsed. Decoders are *total*: any
//!   truncated, oversized, or corrupt frame (bad magic, unknown opcode,
//!   short payload, trailing bytes) returns an error instead of
//!   panicking or misreading — the unit tests exercise each rejection.
//!
//! Integers are little-endian throughout. Strings are UTF-8 with a
//! `u32` byte-length prefix; `Option`s are a one-byte tag; sequences a
//! `u32` count.

use crate::coordinator::{Algorithm, CoordOpts, MatrixHandle, SvdParts};
use crate::dfs::{DiskModel, IoMeter};
use crate::linalg::Matrix;
use crate::mapreduce::{ClusterConfig, FaultPolicy, JobStats, StepStats};
use crate::service::{JobStatus, SchedTally, SchedulerConfig};
use crate::session::{
    AlgoChoice, AutoDecision, Backend, Factorization, FactorizationRequest, Placement, Priority,
    SketchChoice, SubmitOptions, Want,
};
use crate::sketch::{SketchKind, SketchOptions};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::time::Duration;

/// Frame preamble: identifies a byte stream as this protocol.
pub const WIRE_MAGIC: [u8; 4] = *b"MRTQ";

/// Protocol version. Bumped on any incompatible change; the `Hello`
/// handshake rejects a peer whose header says otherwise (as a typed
/// [`VersionMismatch`] error, so serving loops can reply with a clean
/// [`Op::Err`] frame instead of hanging up silently). v2 added the
/// [`Op::Ping`]/[`Op::Pong`] liveness probes used by the network
/// transport's health checks. v3 extended [`WorkerConfig`] with the
/// kernel-tuning knobs (`panel_block`, `mixed_precision`) and
/// [`AutoDecision`] with its `mixed_precision` marker. v4 added the
/// streaming layer: the [`Op::IngestAsync`]/[`Op::IngestStatus`]
/// queued-ingestion opcodes, the [`Op::StreamFold`] single-pass
/// streamed-QR opcode, and [`WorkerConfig`]'s `stream_chunk_rows`
/// knob. v5 added elastic scheduling: the request codec's
/// `no_steal`/`quota_exempt` opt-outs, the stats codec's `stolen`
/// placement flag, [`WorkerConfig`]'s [`SchedulerConfig`] group, and
/// the [`Op::SchedTally`]/[`Op::TallyReply`] scheduler-counter probe.
/// v6 added the randomized sketching family: the `LowRank`/`Solve`
/// want tags, the request codec's sketch operator + seed fields (the
/// seed is part of the digest contract, so it ships exactly like an
/// ingestion seed), the factorization codec's least-squares `solution`
/// block, and [`AutoDecision`]'s recorded [`SketchChoice`].
pub const WIRE_VERSION: u16 = 6;

/// Upper bound on one frame's payload (1 GiB) — a corrupt length
/// prefix must not look like an allocation request.
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

/// Message kinds. `1..` flow client → worker; `100..` are replies and
/// pushes worker → client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u16)]
pub enum Op {
    /// Handshake; payload: [`WorkerConfig`]. Must be the first frame.
    Hello = 1,
    /// Generate + ingest a seeded gaussian matrix worker-side.
    IngestGaussian = 2,
    /// Open a streamed matrix ingestion (name, cols, placement).
    IngestBegin = 3,
    /// One chunk of rows for an open ingestion (exact f64 bits).
    IngestChunk = 4,
    /// Close a streamed ingestion; reply is the `Handle`.
    IngestEnd = 5,
    /// Submit a job under a *caller-assigned* global job id.
    Submit = 6,
    /// Poll one job's [`JobStatus`].
    Status = 7,
    /// Cancel a queued job.
    Cancel = 8,
    /// Evict a finished job's DFS namespace.
    Evict = 9,
    /// Read a matrix handle's rows back.
    FetchMatrix = 10,
    /// Set a DFS file's virtual byte scale.
    SetScale = 11,
    /// Graceful worker shutdown (acked, then the worker exits).
    Shutdown = 12,
    /// Liveness/latency probe (empty payload); replied with [`Op::Pong`].
    /// The network transport's health checks time these round trips.
    Ping = 13,
    /// Queue a recipe-described ingestion as a first-class job under a
    /// caller-assigned job id; the reply is the matrix `Handle`
    /// (usable for dependent `Submit`s immediately — the serving side
    /// queues them behind the ingestion). Payload: id, name, rows,
    /// cols, seed, placement.
    IngestAsync = 14,
    /// Poll an asynchronous ingestion's [`JobStatus`] by job id.
    IngestStatus = 15,
    /// Drive a server-side single-pass streamed QR
    /// ([`crate::stream::RFold`]). Payload: a one-byte subop — `0`
    /// begin (name, cols, chunk_rows), `1` push (a `chunk` of rows),
    /// `2` finish (name; replies `MatrixData` with the final `R`).
    StreamFold = 16,
    /// Poll the serving side's elastic-scheduling counters (empty
    /// payload); replied with [`Op::TallyReply`].
    SchedTally = 17,
    /// Handshake reply: topology of the serving side.
    HelloAck = 100,
    /// Empty success ack.
    Ok = 101,
    /// A [`MatrixHandle`].
    Handle = 102,
    /// A [`JobStatus`] byte.
    StatusReply = 103,
    /// A boolean.
    Flag = 104,
    /// A count.
    Count = 105,
    /// A dense matrix (rows, cols, exact f64 bits).
    MatrixData = 106,
    /// Request failed; payload is the error message.
    Err = 107,
    /// Reply to [`Op::Ping`] (empty payload).
    Pong = 112,
    /// Reply to [`Op::SchedTally`]: a [`SchedTally`] payload.
    TallyReply = 113,
    /// Push (req_id 0): job reached Done. Payload: id, wall_secs,
    /// [`Factorization`].
    JobDone = 110,
    /// Push (req_id 0): job reached Failed/Cancelled. Payload: id,
    /// wall_secs, message.
    JobFail = 111,
}

impl Op {
    pub fn from_u16(v: u16) -> Result<Op> {
        Ok(match v {
            1 => Op::Hello,
            2 => Op::IngestGaussian,
            3 => Op::IngestBegin,
            4 => Op::IngestChunk,
            5 => Op::IngestEnd,
            6 => Op::Submit,
            7 => Op::Status,
            8 => Op::Cancel,
            9 => Op::Evict,
            10 => Op::FetchMatrix,
            11 => Op::SetScale,
            12 => Op::Shutdown,
            13 => Op::Ping,
            14 => Op::IngestAsync,
            15 => Op::IngestStatus,
            16 => Op::StreamFold,
            17 => Op::SchedTally,
            100 => Op::HelloAck,
            101 => Op::Ok,
            102 => Op::Handle,
            103 => Op::StatusReply,
            104 => Op::Flag,
            105 => Op::Count,
            106 => Op::MatrixData,
            107 => Op::Err,
            110 => Op::JobDone,
            111 => Op::JobFail,
            112 => Op::Pong,
            113 => Op::TallyReply,
            other => bail!("wire: unknown opcode {other}"),
        })
    }
}

/// Typed error for a frame whose header carries a different protocol
/// version. [`read_frame`] returns this (wrapped in `anyhow`) instead
/// of a plain message so serving loops can `downcast_ref` it and send
/// a clean [`Op::Err`] reply — addressed by the header's `req_id`,
/// which is version-independent — before closing the connection,
/// rather than leaving the stale peer to hang on a silent hangup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VersionMismatch {
    /// The version the peer's frame header claimed.
    pub peer: u16,
    /// The offending frame's request id (header layout is shared
    /// across versions, so this is safe to echo in an error reply).
    pub req_id: u64,
}

impl std::fmt::Display for VersionMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "wire: protocol version {} != supported {WIRE_VERSION} \
             (upgrade both ends to the same mrtsqr build)",
            self.peer
        )
    }
}

impl std::error::Error for VersionMismatch {}

/// One protocol message: opcode + request-correlation id + payload.
/// `req_id` pairs replies with requests on a multiplexed pipe; pushed
/// frames ([`Op::JobDone`]/[`Op::JobFail`]) use `req_id = 0` and carry
/// the job id in the payload instead.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub op: Op,
    pub req_id: u64,
    pub payload: Vec<u8>,
}

/// Serialize one frame to a byte stream (header + payload).
pub fn write_frame(w: &mut impl Write, op: Op, req_id: u64, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME_BYTES as usize,
        "wire: frame payload {} bytes exceeds the {} limit",
        payload.len(),
        MAX_FRAME_BYTES
    );
    let mut header = [0u8; 4 + 2 + 2 + 8 + 4];
    header[0..4].copy_from_slice(&WIRE_MAGIC);
    header[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    header[6..8].copy_from_slice(&(op as u16).to_le_bytes());
    header[8..16].copy_from_slice(&req_id.to_le_bytes());
    header[16..20].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame. `Ok(None)` on a clean EOF *at a frame boundary*
/// (the peer closed the pipe between messages); any mid-frame EOF,
/// bad magic, version mismatch, unknown opcode or oversized length is
/// an error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let mut header = [0u8; 20];
    // hand-rolled read_exact that distinguishes boundary EOF
    let mut filled = 0;
    while filled < header.len() {
        let n = match r.read(&mut header[filled..]) {
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        if n == 0 {
            if filled == 0 {
                return Ok(None);
            }
            bail!("wire: truncated frame header ({filled} of {} bytes)", header.len());
        }
        filled += n;
    }
    ensure!(
        header[0..4] == WIRE_MAGIC,
        "wire: bad magic {:02x?} (not a mrtsqr protocol stream)",
        &header[0..4]
    );
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    let req_id = u64::from_le_bytes(header[8..16].try_into().unwrap());
    if version != WIRE_VERSION {
        return Err(VersionMismatch { peer: version, req_id }.into());
    }
    let op = Op::from_u16(u16::from_le_bytes(header[6..8].try_into().unwrap()))?;
    let len = u32::from_le_bytes(header[16..20].try_into().unwrap());
    ensure!(len <= MAX_FRAME_BYTES, "wire: frame length {len} exceeds the {MAX_FRAME_BYTES} limit");
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .with_context(|| format!("wire: truncated payload (wanted {len} bytes)"))?;
    Ok(Some(Frame { op, req_id, payload }))
}

// ---------------------------------------------------------------- writer

/// Append-only payload encoder.
#[derive(Debug, Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> WireWriter {
        WireWriter::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Exact-bit float: the IEEE-754 word, never a decimal rendering.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn opt_str(&mut self, s: Option<&str>) {
        match s {
            None => self.u8(0),
            Some(s) => {
                self.u8(1);
                self.str(s);
            }
        }
    }

    fn f64s(&mut self, vs: &[f64]) {
        self.u32(vs.len() as u32);
        for v in vs {
            self.f64(*v);
        }
    }

    // ---------------------------------------------------- domain types

    pub fn handle(&mut self, h: &MatrixHandle) {
        self.str(&h.file);
        self.u64(h.rows as u64);
        self.u64(h.cols as u64);
    }

    /// Algorithms travel as their canonical CLI spelling
    /// ([`Algorithm::cli_name`]) — self-describing and stable across
    /// enum-layout changes.
    pub fn algorithm(&mut self, a: Algorithm) {
        self.str(a.cli_name());
    }

    pub fn placement(&mut self, p: Placement) {
        match p {
            Placement::Auto => self.u8(0),
            Placement::Pinned(k) => {
                self.u8(1);
                self.u64(k as u64);
            }
        }
    }

    pub fn request(&mut self, req: &FactorizationRequest) {
        match req.want {
            Want::Qr => self.u8(0),
            Want::ROnly => self.u8(1),
            Want::Svd => self.u8(2),
            Want::SingularValues => self.u8(3),
            Want::LowRank { rank, oversample, power_iters } => {
                self.u8(4);
                self.u64(rank as u64);
                self.u64(oversample as u64);
                self.u64(power_iters as u64);
            }
            Want::Solve { rhs } => {
                self.u8(5);
                self.u64(rhs as u64);
            }
        }
        match req.algo {
            AlgoChoice::Auto => self.u8(0),
            AlgoChoice::Fixed(a) => {
                self.u8(1);
                self.algorithm(a);
            }
        }
        self.bool(req.refine);
        self.f64(req.condition_threshold);
        self.u8(match req.options.priority {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        });
        self.opt_str(req.options.label.as_deref());
        self.placement(req.options.placement);
        self.bool(req.options.no_steal);
        self.bool(req.options.quota_exempt);
        // v6: the sketch operator + seed travel on every request (the
        // non-sketch wants ignore them, like `refine` on Fixed algos)
        self.sketch_kind(req.sketch.kind);
        self.u64(req.sketch.seed);
    }

    fn sketch_kind(&mut self, k: SketchKind) {
        self.u8(match k {
            SketchKind::Gaussian => 0,
            SketchKind::CountSketch => 1,
        });
    }

    pub fn matrix(&mut self, m: &Matrix) {
        self.u64(m.rows as u64);
        self.u64(m.cols as u64);
        for v in &m.data {
            self.f64(*v);
        }
    }

    /// One ingestion chunk: a run of rows of a named in-progress file.
    pub fn chunk(&mut self, name: &str, first_row: u64, cols: usize, data: &[f64]) {
        self.str(name);
        self.u64(first_row);
        self.u64(cols as u64);
        self.f64s(data);
    }

    fn io_meter(&mut self, io: &IoMeter) {
        self.u64(io.bytes_read);
        self.u64(io.bytes_written);
        self.u64(io.records_read);
        self.u64(io.records_written);
    }

    fn step(&mut self, s: &StepStats) {
        self.str(&s.name);
        self.u64(s.map_tasks as u64);
        self.u64(s.reduce_tasks as u64);
        self.u64(s.distinct_keys as u64);
        self.io_meter(&s.map_io);
        self.io_meter(&s.reduce_io);
        self.f64(s.map_compute_secs);
        self.f64(s.reduce_compute_secs);
        self.f64(s.virtual_secs);
        self.f64(s.wall_secs);
        self.u64(s.map_attempts as u64);
        self.u64(s.reduce_attempts as u64);
        self.u64(s.faults as u64);
        self.u64(s.host_threads as u64);
    }

    pub fn stats(&mut self, stats: &JobStats) {
        self.u64(stats.shard as u64);
        self.bool(stats.stolen);
        self.u32(stats.steps.len() as u32);
        for s in &stats.steps {
            self.step(s);
        }
    }

    pub fn status(&mut self, s: JobStatus) {
        self.u8(match s {
            JobStatus::Queued => 0,
            JobStatus::Running => 1,
            JobStatus::Done => 2,
            JobStatus::Failed => 3,
            JobStatus::Cancelled => 4,
        });
    }

    fn auto_decision(&mut self, d: &AutoDecision) {
        self.f64(d.kappa_estimate);
        self.f64(d.threshold);
        self.algorithm(d.chosen);
        self.bool(d.probe_reused);
        self.bool(d.mixed_precision);
        match &d.sketch {
            None => self.u8(0),
            Some(c) => {
                self.u8(1);
                self.sketch_kind(c.kind);
                self.u64(c.seed);
                self.u64(c.oversample as u64);
            }
        }
    }

    pub fn factorization(&mut self, f: &Factorization) {
        match &f.q {
            None => self.u8(0),
            Some(h) => {
                self.u8(1);
                self.handle(h);
            }
        }
        self.matrix(&f.r);
        match &f.svd {
            None => self.u8(0),
            Some(parts) => {
                self.u8(1);
                self.f64s(&parts.sigma);
                self.matrix(&parts.v);
            }
        }
        // v6: the least-squares solution block (digest-relevant)
        match &f.solution {
            None => self.u8(0),
            Some(x) => {
                self.u8(1);
                self.matrix(x);
            }
        }
        self.algorithm(f.algorithm);
        match &f.auto {
            None => self.u8(0),
            Some(d) => {
                self.u8(1);
                self.auto_decision(d);
            }
        }
        self.stats(&f.stats);
    }

    pub fn config(&mut self, cfg: &WorkerConfig) {
        self.f64(cfg.model.beta_r);
        self.f64(cfg.model.beta_w);
        self.f64(cfg.model.byte_scale);
        self.f64(cfg.model.iteration_startup_secs);
        self.f64(cfg.model.task_startup_secs);
        self.u64(cfg.cluster.map_slots as u64);
        self.u64(cfg.cluster.reduce_slots as u64);
        self.u64(cfg.cluster.host_threads as u64);
        match cfg.faults {
            None => self.u8(0),
            Some((policy, seed)) => {
                self.u8(1);
                self.f64(policy.probability);
                self.u64(policy.max_attempts as u64);
                self.f64(policy.waste_fraction);
                self.u64(seed);
            }
        }
        self.u64(cfg.opts.rows_per_task as u64);
        self.u64(cfg.opts.reduce_tasks as u64);
        match cfg.opts.gather_limit {
            None => self.u8(0),
            Some(rows) => {
                self.u8(1);
                self.u64(rows as u64);
            }
        }
        match cfg.opts.panel_block {
            None => self.u8(0),
            Some(b) => {
                self.u8(1);
                self.u64(b as u64);
            }
        }
        self.bool(cfg.opts.mixed_precision);
        self.u64(cfg.opts.stream_chunk_rows as u64);
        self.u8(match cfg.backend {
            Backend::Auto => 0,
            Backend::Native => 1,
            Backend::Pjrt => 2,
        });
        self.u64(cfg.engine_shards as u64);
        self.u64(cfg.service_workers as u64);
        self.u64(cfg.queue_capacity as u64);
        self.bool(cfg.scheduler.steal);
        self.bool(cfg.scheduler.locality);
        match cfg.scheduler.quota_per_label {
            None => self.u8(0),
            Some(q) => {
                self.u8(1);
                self.u64(q as u64);
            }
        }
        self.u64(cfg.scheduler.autoscale_min as u64);
        self.u64(cfg.scheduler.autoscale_max as u64);
        self.u64(cfg.scheduler.autoscale_interval.as_millis() as u64);
    }

    /// Elastic-scheduling counters ([`Op::TallyReply`]).
    pub fn tally(&mut self, t: &SchedTally) {
        self.u32(t.per_shard_steals.len() as u32);
        for &n in &t.per_shard_steals {
            self.u64(n);
        }
        self.u32(t.admission_held.len() as u32);
        for (label, n) in &t.admission_held {
            self.str(label);
            self.u64(*n);
        }
    }
}

// ---------------------------------------------------------------- reader

/// Bounds-checked payload decoder; every read can fail on truncation,
/// and [`WireReader::finish`] rejects trailing garbage.
#[derive(Debug)]
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.buf.len() - self.pos >= n,
            "wire: truncated payload (wanted {n} bytes at offset {}, have {})",
            self.pos,
            self.buf.len() - self.pos
        );
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Every byte must have been consumed — trailing bytes mean the
    /// peer and we disagree about the message layout.
    pub fn finish(self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "wire: {} trailing bytes after a complete message",
            self.buf.len() - self.pos
        );
        Ok(())
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("wire: bad bool byte {other}"),
        }
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        Ok(std::str::from_utf8(bytes).context("wire: non-UTF-8 string")?.to_string())
    }

    pub fn opt_str(&mut self) -> Result<Option<String>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.str()?)),
            other => bail!("wire: bad option tag {other}"),
        }
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        ensure!(
            n.checked_mul(8).is_some_and(|bytes| self.buf.len() - self.pos >= bytes),
            "wire: float run of {n} exceeds the remaining payload"
        );
        (0..n).map(|_| self.f64()).collect()
    }

    // ---------------------------------------------------- domain types

    pub fn handle(&mut self) -> Result<MatrixHandle> {
        let file = self.str()?;
        let rows = self.usize()?;
        let cols = self.usize()?;
        Ok(MatrixHandle { file, rows, cols })
    }

    pub fn algorithm(&mut self) -> Result<Algorithm> {
        Algorithm::parse(&self.str()?)
    }

    pub fn placement(&mut self) -> Result<Placement> {
        match self.u8()? {
            0 => Ok(Placement::Auto),
            1 => Ok(Placement::Pinned(self.usize()?)),
            other => bail!("wire: bad placement tag {other}"),
        }
    }

    pub fn request(&mut self) -> Result<FactorizationRequest> {
        let want = match self.u8()? {
            0 => Want::Qr,
            1 => Want::ROnly,
            2 => Want::Svd,
            3 => Want::SingularValues,
            4 => Want::LowRank {
                rank: self.usize()?,
                oversample: self.usize()?,
                power_iters: self.usize()?,
            },
            5 => Want::Solve { rhs: self.usize()? },
            other => bail!("wire: bad want tag {other}"),
        };
        let algo = match self.u8()? {
            0 => AlgoChoice::Auto,
            1 => AlgoChoice::Fixed(self.algorithm()?),
            other => bail!("wire: bad algo tag {other}"),
        };
        let refine = self.bool()?;
        let condition_threshold = self.f64()?;
        let priority = match self.u8()? {
            0 => Priority::Low,
            1 => Priority::Normal,
            2 => Priority::High,
            other => bail!("wire: bad priority tag {other}"),
        };
        let label = self.opt_str()?;
        let placement = self.placement()?;
        let no_steal = self.bool()?;
        let quota_exempt = self.bool()?;
        let sketch = SketchOptions { kind: self.sketch_kind()?, seed: self.u64()? };
        Ok(FactorizationRequest {
            want,
            algo,
            refine,
            condition_threshold,
            options: SubmitOptions { priority, label, placement, no_steal, quota_exempt },
            sketch,
        })
    }

    fn sketch_kind(&mut self) -> Result<SketchKind> {
        Ok(match self.u8()? {
            0 => SketchKind::Gaussian,
            1 => SketchKind::CountSketch,
            other => bail!("wire: bad sketch-kind tag {other}"),
        })
    }

    pub fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.usize()?;
        let cols = self.usize()?;
        // both multiplications are overflow-checked: a corrupt header
        // must fail cleanly, not wrap into a bogus bounds pass (and a
        // capacity-overflow panic that would kill a demux thread)
        let n = rows
            .checked_mul(cols)
            .filter(|n| {
                n.checked_mul(8).is_some_and(|bytes| self.buf.len() - self.pos >= bytes)
            })
            .ok_or_else(|| anyhow::anyhow!("wire: matrix {rows}x{cols} exceeds the payload"))?;
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(self.f64()?);
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Inverse of [`WireWriter::chunk`].
    pub fn chunk(&mut self) -> Result<(String, u64, usize, Vec<f64>)> {
        let name = self.str()?;
        let first_row = self.u64()?;
        let cols = self.usize()?;
        let data = self.f64s()?;
        ensure!(
            cols > 0 && data.len() % cols == 0,
            "wire: chunk of {} values is not a whole number of {cols}-wide rows",
            data.len()
        );
        Ok((name, first_row, cols, data))
    }

    fn io_meter(&mut self) -> Result<IoMeter> {
        Ok(IoMeter {
            bytes_read: self.u64()?,
            bytes_written: self.u64()?,
            records_read: self.u64()?,
            records_written: self.u64()?,
        })
    }

    fn step(&mut self) -> Result<StepStats> {
        Ok(StepStats {
            name: self.str()?,
            map_tasks: self.usize()?,
            reduce_tasks: self.usize()?,
            distinct_keys: self.usize()?,
            map_io: self.io_meter()?,
            reduce_io: self.io_meter()?,
            map_compute_secs: self.f64()?,
            reduce_compute_secs: self.f64()?,
            virtual_secs: self.f64()?,
            wall_secs: self.f64()?,
            map_attempts: self.usize()?,
            reduce_attempts: self.usize()?,
            faults: self.usize()?,
            host_threads: self.usize()?,
        })
    }

    pub fn stats(&mut self) -> Result<JobStats> {
        let shard = self.usize()?;
        let stolen = self.bool()?;
        let nsteps = self.u32()? as usize;
        let mut steps = Vec::with_capacity(nsteps.min(1024));
        for _ in 0..nsteps {
            steps.push(self.step()?);
        }
        Ok(JobStats { steps, shard, stolen })
    }

    pub fn status(&mut self) -> Result<JobStatus> {
        Ok(match self.u8()? {
            0 => JobStatus::Queued,
            1 => JobStatus::Running,
            2 => JobStatus::Done,
            3 => JobStatus::Failed,
            4 => JobStatus::Cancelled,
            other => bail!("wire: bad status byte {other}"),
        })
    }

    fn auto_decision(&mut self) -> Result<AutoDecision> {
        Ok(AutoDecision {
            kappa_estimate: self.f64()?,
            threshold: self.f64()?,
            chosen: self.algorithm()?,
            probe_reused: self.bool()?,
            mixed_precision: self.bool()?,
            sketch: match self.u8()? {
                0 => None,
                1 => Some(SketchChoice {
                    kind: self.sketch_kind()?,
                    seed: self.u64()?,
                    oversample: self.usize()?,
                }),
                other => bail!("wire: bad option tag {other}"),
            },
        })
    }

    pub fn factorization(&mut self) -> Result<Factorization> {
        let q = match self.u8()? {
            0 => None,
            1 => Some(self.handle()?),
            other => bail!("wire: bad option tag {other}"),
        };
        let r = self.matrix()?;
        let svd = match self.u8()? {
            0 => None,
            1 => {
                let sigma = self.f64s()?;
                let v = self.matrix()?;
                Some(SvdParts { sigma, v })
            }
            other => bail!("wire: bad option tag {other}"),
        };
        let solution = match self.u8()? {
            0 => None,
            1 => Some(self.matrix()?),
            other => bail!("wire: bad option tag {other}"),
        };
        let algorithm = self.algorithm()?;
        let auto = match self.u8()? {
            0 => None,
            1 => Some(self.auto_decision()?),
            other => bail!("wire: bad option tag {other}"),
        };
        let stats = self.stats()?;
        Ok(Factorization { q, r, svd, solution, algorithm, auto, stats })
    }

    pub fn config(&mut self) -> Result<WorkerConfig> {
        let model = DiskModel {
            beta_r: self.f64()?,
            beta_w: self.f64()?,
            byte_scale: self.f64()?,
            iteration_startup_secs: self.f64()?,
            task_startup_secs: self.f64()?,
        };
        let cluster = ClusterConfig {
            map_slots: self.usize()?,
            reduce_slots: self.usize()?,
            host_threads: self.usize()?,
        };
        let faults = match self.u8()? {
            0 => None,
            1 => {
                let policy = FaultPolicy {
                    probability: self.f64()?,
                    max_attempts: self.usize()?,
                    waste_fraction: self.f64()?,
                };
                Some((policy, self.u64()?))
            }
            other => bail!("wire: bad option tag {other}"),
        };
        let opts = CoordOpts {
            rows_per_task: self.usize()?,
            reduce_tasks: self.usize()?,
            gather_limit: match self.u8()? {
                0 => None,
                1 => Some(self.usize()?),
                other => bail!("wire: bad option tag {other}"),
            },
            panel_block: match self.u8()? {
                0 => None,
                1 => Some(self.usize()?),
                other => bail!("wire: bad option tag {other}"),
            },
            mixed_precision: self.bool()?,
            stream_chunk_rows: self.usize()?,
        };
        let backend = match self.u8()? {
            0 => Backend::Auto,
            1 => Backend::Native,
            2 => Backend::Pjrt,
            other => bail!("wire: bad backend tag {other}"),
        };
        let engine_shards = self.usize()?;
        let service_workers = self.usize()?;
        let queue_capacity = self.usize()?;
        let scheduler = SchedulerConfig {
            steal: self.bool()?,
            locality: self.bool()?,
            quota_per_label: match self.u8()? {
                0 => None,
                1 => Some(self.usize()?),
                other => bail!("wire: bad option tag {other}"),
            },
            autoscale_min: self.usize()?,
            autoscale_max: self.usize()?,
            autoscale_interval: Duration::from_millis(self.u64()?),
        };
        Ok(WorkerConfig {
            model,
            cluster,
            faults,
            opts,
            backend,
            engine_shards,
            service_workers,
            queue_capacity,
            scheduler,
        })
    }

    /// Inverse of [`WireWriter::tally`].
    pub fn tally(&mut self) -> Result<SchedTally> {
        let nshards = self.u32()? as usize;
        ensure!(
            nshards.checked_mul(8).is_some_and(|bytes| self.buf.len() - self.pos >= bytes),
            "wire: steal-counter run of {nshards} exceeds the remaining payload"
        );
        let per_shard_steals = (0..nshards).map(|_| self.u64()).collect::<Result<Vec<_>>>()?;
        let nlabels = self.u32()? as usize;
        let mut admission_held = Vec::with_capacity(nlabels.min(1024));
        for _ in 0..nlabels {
            let label = self.str()?;
            admission_held.push((label, self.u64()?));
        }
        Ok(SchedTally { per_shard_steals, admission_held })
    }
}

/// The full cluster recipe a worker process needs to reconstruct the
/// parent's [`crate::session::SessionBuilder`] — shipped in the
/// [`Op::Hello`] handshake so every worker's engine pool is configured
/// identically to an in-process run (same disk model, fault seed,
/// tuning knobs), which is what makes cross-process results
/// bit-identical.
#[derive(Debug, Clone, Copy)]
pub struct WorkerConfig {
    pub model: DiskModel,
    pub cluster: ClusterConfig,
    pub faults: Option<(FaultPolicy, u64)>,
    pub opts: CoordOpts,
    pub backend: Backend,
    /// Engine shards *per worker process*.
    pub engine_shards: usize,
    /// Service worker threads per shard (clamped to ≥ 1 worker-side:
    /// manual drain does not exist across a pipe).
    pub service_workers: usize,
    pub queue_capacity: usize,
    /// Elastic-scheduling policy of the serving side's job queues
    /// (stealing, locality, quotas, autoscale bounds) — pure
    /// scheduling, so shipping it changes no result bits.
    pub scheduler: SchedulerConfig,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip_request(req: &FactorizationRequest) -> FactorizationRequest {
        let mut w = WireWriter::new();
        w.request(req);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        let out = r.request().unwrap();
        r.finish().unwrap();
        out
    }

    #[test]
    fn request_roundtrips_every_variant() {
        // the satellite's property sweep: every want × algo choice ×
        // priority × placement, plus the label edge cases (absent,
        // empty, unicode) and the v5 opt-out flags
        let wants = [
            FactorizationRequest::qr(),
            FactorizationRequest::r_only(),
            FactorizationRequest::svd(),
            FactorizationRequest::singular_values(),
            FactorizationRequest::low_rank(7).oversample(3).power_iters(2),
            FactorizationRequest::solve().rhs_cols(4),
        ];
        let algos: Vec<AlgoChoice> = std::iter::once(AlgoChoice::Auto)
            .chain(Algorithm::ALL.into_iter().map(AlgoChoice::Fixed))
            .collect();
        for base in wants {
            for &algo in &algos {
                for priority in [Priority::Low, Priority::Normal, Priority::High] {
                    for placement in [Placement::Auto, Placement::Pinned(0), Placement::Pinned(usize::MAX >> 1)] {
                        for label in [None, Some(""), Some("hot-λ-job")] {
                            let mut req = base.clone().refined(true);
                            req.algo = algo;
                            req.condition_threshold = 1.5e7;
                            req.options = SubmitOptions::new()
                                .priority(priority)
                                .placement(placement);
                            req.options.label = label.map(str::to_string);
                            // both flag polarities cross the sweep
                            req.options.no_steal = label.is_some();
                            req.options.quota_exempt = priority == Priority::High;
                            assert_eq!(roundtrip_request(&req), req);
                        }
                    }
                }
            }
        }
        // and the everything-on corner
        let req = FactorizationRequest::qr().options(
            SubmitOptions::new()
                .priority(Priority::High)
                .label("t1")
                .pinned(2)
                .no_steal()
                .quota_exempt(),
        );
        assert_eq!(roundtrip_request(&req), req);
    }

    #[test]
    fn sketch_fields_roundtrip_exactly() {
        // the v6 fields: operator + seed on every request, with the
        // LowRank/Solve wants carrying their shape parameters
        let req = FactorizationRequest::low_rank(9)
            .oversample(0)
            .power_iters(3)
            .with_sketch(SketchOptions { kind: SketchKind::CountSketch, seed: u64::MAX })
            .randomized();
        assert_eq!(roundtrip_request(&req), req);
        let req = FactorizationRequest::solve()
            .rhs_cols(1)
            .with_sketch(SketchOptions { kind: SketchKind::Gaussian, seed: 0 });
        assert_eq!(roundtrip_request(&req), req);
        // a plain QR still carries (and preserves) the default sketch
        let back = roundtrip_request(&FactorizationRequest::qr());
        assert_eq!(back.sketch, SketchOptions::default());
    }

    #[test]
    fn request_f64_fields_are_bit_exact() {
        // a threshold that has no short decimal rendering must survive
        // exactly — the wire ships bits, not digits
        let mut req = FactorizationRequest::qr();
        req.condition_threshold = f64::from_bits(0x3FF0_0000_0000_0001); // 1.0 + ulp
        let back = roundtrip_request(&req);
        assert_eq!(back.condition_threshold.to_bits(), req.condition_threshold.to_bits());
    }

    fn sample_stats() -> JobStats {
        let mut io = IoMeter::default();
        io.add_read(123_456_789, 1000);
        io.add_write(987, 7);
        let step = |name: &str, virt: f64| StepStats {
            name: name.into(),
            map_tasks: 40,
            reduce_tasks: 3,
            distinct_keys: 17,
            map_io: io,
            reduce_io: IoMeter::default(),
            map_compute_secs: 0.25,
            reduce_compute_secs: 0.5,
            virtual_secs: virt,
            wall_secs: 0.001,
            map_attempts: 41,
            reduce_attempts: 3,
            faults: 1,
            host_threads: 8,
        };
        JobStats {
            steps: vec![step("s1", 100.125), step("auto-select(...)", 0.0)],
            shard: 3,
            stolen: true,
        }
    }

    #[test]
    fn stats_roundtrip_bit_exact() {
        let stats = sample_stats();
        let mut w = WireWriter::new();
        w.stats(&stats);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        let back = r.stats().unwrap();
        r.finish().unwrap();
        assert_eq!(back.shard, stats.shard);
        assert_eq!(back.stolen, stats.stolen);
        assert_eq!(back.steps.len(), stats.steps.len());
        for (a, b) in back.steps.iter().zip(&stats.steps) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.map_io, b.map_io);
            assert_eq!(a.reduce_io, b.reduce_io);
            assert_eq!(a.virtual_secs.to_bits(), b.virtual_secs.to_bits());
            assert_eq!(a.wall_secs.to_bits(), b.wall_secs.to_bits());
            assert_eq!(a.faults, b.faults);
            assert_eq!(a.host_threads, b.host_threads);
            assert_eq!(a.map_attempts, b.map_attempts);
        }
        assert_eq!(back.virtual_secs().to_bits(), stats.virtual_secs().to_bits());
    }

    #[test]
    fn factorization_roundtrip_preserves_the_result_digest() {
        // the headline contract: the digest (exact R/Σ bits) survives
        // the wire — including awkward values like -0.0, denormals and
        // 1+ulp that any decimal detour would mangle
        let mut rng = Rng::new(7);
        let mut r = Matrix::gaussian(5, 5, &mut rng);
        r.data[0] = -0.0;
        r.data[1] = f64::MIN_POSITIVE / 2.0; // subnormal
        r.data[2] = f64::from_bits(0x3FF0_0000_0000_0001);
        let fact = Factorization {
            q: Some(MatrixHandle::new("shard-1/job-9/tmp/q-0", 400, 5)),
            r,
            svd: Some(SvdParts {
                sigma: vec![3.5, 1.0, 0.5, 1e-300, 4e-320],
                v: Matrix::gaussian(5, 5, &mut rng),
            }),
            solution: None,
            algorithm: Algorithm::IndirectTsqr { refine: true },
            auto: Some(AutoDecision {
                kappa_estimate: 37.25,
                threshold: 1e3,
                chosen: Algorithm::IndirectTsqr { refine: true },
                probe_reused: true,
                mixed_precision: true,
                sketch: None,
            }),
            stats: sample_stats(),
        };
        let mut w = WireWriter::new();
        w.factorization(&fact);
        let bytes = w.into_bytes();
        let mut rd = WireReader::new(&bytes);
        let back = rd.factorization().unwrap();
        rd.finish().unwrap();
        assert_eq!(back.result_digest(), fact.result_digest());
        assert_eq!(back.q, fact.q);
        assert_eq!(back.algorithm, fact.algorithm);
        let (a, b) = (back.auto.unwrap(), fact.auto.unwrap());
        assert_eq!(a.kappa_estimate.to_bits(), b.kappa_estimate.to_bits());
        assert_eq!(a.chosen, b.chosen);
        assert_eq!(a.probe_reused, b.probe_reused);
        for (x, y) in back.svd.as_ref().unwrap().sigma.iter().zip(&fact.svd.as_ref().unwrap().sigma)
        {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(
            back.stats.virtual_secs().to_bits(),
            fact.stats.virtual_secs().to_bits()
        );
    }

    #[test]
    fn solve_factorization_roundtrips_solution_and_nan_kappa() {
        // the v6 blocks: a Solve result's x enters the digest, and a
        // LowRank auto decision's NaN kappa must survive (NaN has no
        // decimal rendering; the wire ships bits)
        let mut rng = Rng::new(9);
        let fact = Factorization {
            q: None,
            r: Matrix::gaussian(4, 4, &mut rng),
            svd: None,
            solution: Some(Matrix::gaussian(4, 2, &mut rng)),
            algorithm: Algorithm::Randomized,
            auto: Some(AutoDecision {
                kappa_estimate: f64::NAN,
                threshold: 1e3,
                chosen: Algorithm::Randomized,
                probe_reused: false,
                mixed_precision: false,
                sketch: Some(SketchChoice {
                    kind: SketchKind::CountSketch,
                    seed: 0x5EED,
                    oversample: 8,
                }),
            }),
            stats: sample_stats(),
        };
        let mut w = WireWriter::new();
        w.factorization(&fact);
        let bytes = w.into_bytes();
        let mut rd = WireReader::new(&bytes);
        let back = rd.factorization().unwrap();
        rd.finish().unwrap();
        assert_eq!(back.result_digest(), fact.result_digest());
        let (xa, xb) = (back.solution.as_ref().unwrap(), fact.solution.as_ref().unwrap());
        assert_eq!((xa.rows, xa.cols), (xb.rows, xb.cols));
        for (a, b) in xa.data.iter().zip(&xb.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let d = back.auto.unwrap();
        assert!(d.kappa_estimate.is_nan());
        assert_eq!(d.sketch, fact.auto.unwrap().sketch);
        // and: a digest with a solution differs from one without
        let mut without = back.clone();
        without.solution = None;
        assert_ne!(without.result_digest(), fact.result_digest());
    }

    #[test]
    fn frames_roundtrip_through_a_byte_stream() {
        let mut w = WireWriter::new();
        w.str("hello");
        let payload = w.into_bytes();
        let mut stream = Vec::new();
        write_frame(&mut stream, Op::Submit, 42, &payload).unwrap();
        write_frame(&mut stream, Op::Ok, 43, &[]).unwrap();
        let mut cursor = &stream[..];
        let f1 = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!((f1.op, f1.req_id), (Op::Submit, 42));
        assert_eq!(f1.payload, payload);
        let f2 = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!((f2.op, f2.req_id, f2.payload.len()), (Op::Ok, 43, 0));
        // clean EOF at the boundary
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn corrupt_frames_are_rejected_not_misread() {
        let mut good = Vec::new();
        write_frame(&mut good, Op::Submit, 1, &[1, 2, 3, 4]).unwrap();

        // truncated header
        let mut cut = &good[..10];
        assert!(read_frame(&mut cut).is_err());
        // truncated payload
        let mut cut = &good[..good.len() - 2];
        assert!(read_frame(&mut cut).is_err());
        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("magic"));
        // future protocol version: a typed error carrying the peer's
        // version and the frame's req_id, so serving loops can reply
        // with a clean Err frame before hanging up
        let mut bad = good.clone();
        bad[4..6].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
        let err = read_frame(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("version"), "{err:#}");
        let vm = err.downcast_ref::<VersionMismatch>().expect("typed version error");
        assert_eq!((vm.peer, vm.req_id), (WIRE_VERSION + 1, 1));
        // unknown opcode
        let mut bad = good.clone();
        bad[6..8].copy_from_slice(&999u16.to_le_bytes());
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("opcode"));
        // absurd length prefix must not become an allocation
        let mut bad = good.clone();
        bad[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("limit"));
    }

    #[test]
    fn corrupt_payloads_are_rejected_not_misread() {
        // truncated mid-struct
        let mut w = WireWriter::new();
        w.request(&FactorizationRequest::qr().options(SubmitOptions::new().label("x")));
        let bytes = w.into_bytes();
        for cut in [0, 1, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                WireReader::new(&bytes[..cut]).request().is_err(),
                "cut at {cut} must not decode"
            );
        }
        // trailing garbage
        let mut padded = bytes.clone();
        padded.push(0);
        let mut r = WireReader::new(&padded);
        r.request().unwrap();
        assert!(r.finish().unwrap_err().to_string().contains("trailing"));
        // bad enum tags
        assert!(WireReader::new(&[9]).status().is_err());
        assert!(WireReader::new(&[7]).placement().is_err());
        assert!(WireReader::new(&[2]).bool().is_err());
        // a matrix whose header promises more data than the payload has
        let mut w = WireWriter::new();
        w.u64(1 << 40);
        w.u64(1 << 40);
        let bytes = w.into_bytes();
        assert!(WireReader::new(&bytes).matrix().is_err());
        // a header whose rows*cols fits usize but whose byte count
        // wraps: must be a clean error, not a capacity-overflow panic
        // (the demux reader thread dies on panics without cleanup)
        let mut w = WireWriter::new();
        w.u64(1 << 61);
        w.u64(4);
        let bytes = w.into_bytes();
        assert!(WireReader::new(&bytes).matrix().is_err());
        // non-UTF-8 string
        let mut w = WireWriter::new();
        w.u32(2);
        let mut bytes = w.into_bytes();
        bytes.extend_from_slice(&[0xFF, 0xFE]);
        assert!(WireReader::new(&bytes).str().is_err());
    }

    #[test]
    fn chunks_roundtrip_and_validate_row_alignment() {
        let data = [1.5, -0.0, 3.25, f64::MIN_POSITIVE, 5.0, 6.0];
        let mut w = WireWriter::new();
        w.chunk("A", 1000, 3, &data);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        let (name, first, cols, back) = r.chunk().unwrap();
        r.finish().unwrap();
        assert_eq!((name.as_str(), first, cols), ("A", 1000, 3));
        for (a, b) in back.iter().zip(&data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // 5 values do not make whole 3-wide rows
        let mut w = WireWriter::new();
        w.chunk("A", 0, 3, &data);
        let mut bytes = w.into_bytes();
        // shrink the count prefix to 5 (name(4+1) + first(8) + cols(8) = offset 21)
        bytes[21..25].copy_from_slice(&5u32.to_le_bytes());
        bytes.truncate(bytes.len() - 8);
        assert!(WireReader::new(&bytes).chunk().is_err());
    }

    #[test]
    fn worker_config_roundtrips() {
        let cfg = WorkerConfig {
            model: DiskModel { beta_r: 1.25e-9, ..DiskModel::icme_like() },
            cluster: ClusterConfig { map_slots: 40, reduce_slots: 13, host_threads: 3 },
            faults: Some((
                FaultPolicy { probability: 0.125, max_attempts: 7, waste_fraction: 0.5 },
                777,
            )),
            opts: CoordOpts {
                rows_per_task: 50,
                reduce_tasks: 4,
                gather_limit: Some(99),
                panel_block: Some(8),
                mixed_precision: true,
                stream_chunk_rows: 777,
            },
            backend: Backend::Native,
            engine_shards: 2,
            service_workers: 3,
            queue_capacity: 64,
            scheduler: SchedulerConfig::new()
                .steal(true)
                .locality(true)
                .quota_per_label(4)
                .autoscale(1, 6)
                .autoscale_interval(Duration::from_millis(125)),
        };
        let mut w = WireWriter::new();
        w.config(&cfg);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        let back = r.config().unwrap();
        r.finish().unwrap();
        assert_eq!(back.scheduler, cfg.scheduler);
        assert_eq!(back.model, cfg.model);
        assert_eq!(back.cluster.reduce_slots, 13);
        assert_eq!(back.cluster.host_threads, 3);
        let (policy, seed) = back.faults.unwrap();
        assert_eq!(policy.probability, 0.125);
        assert_eq!(policy.max_attempts, 7);
        assert_eq!(seed, 777);
        assert_eq!(back.opts.gather_limit, Some(99));
        assert_eq!(back.opts.stream_chunk_rows, 777);
        assert_eq!(back.backend, Backend::Native);
        assert_eq!(
            (back.engine_shards, back.service_workers, back.queue_capacity),
            (2, 3, 64)
        );
    }

    #[test]
    fn tally_roundtrips() {
        let t = SchedTally {
            per_shard_steals: vec![0, 7, 0, 19],
            admission_held: vec![("batch".into(), 12), ("t1".into(), 0)],
        };
        let mut w = WireWriter::new();
        w.tally(&t);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.tally().unwrap(), t);
        r.finish().unwrap();
        // the empty tally (a serving side with scheduling off)
        let mut w = WireWriter::new();
        w.tally(&SchedTally::default());
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.tally().unwrap(), SchedTally::default());
        r.finish().unwrap();
        // a corrupt steal-counter count must not become an allocation
        let mut w = WireWriter::new();
        w.u32(u32::MAX);
        let bytes = w.into_bytes();
        assert!(WireReader::new(&bytes).tally().is_err());
    }

    #[test]
    fn status_roundtrips_every_state() {
        for s in [
            JobStatus::Queued,
            JobStatus::Running,
            JobStatus::Done,
            JobStatus::Failed,
            JobStatus::Cancelled,
        ] {
            let mut w = WireWriter::new();
            w.status(s);
            let bytes = w.into_bytes();
            let mut r = WireReader::new(&bytes);
            assert_eq!(r.status().unwrap(), s);
            r.finish().unwrap();
        }
    }
}
