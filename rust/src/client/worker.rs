//! The serving side of the wire protocol: `mrtsqr worker` and
//! `mrtsqr serve`.
//!
//! Both subcommands run the same loop (`serve_loop`) over a
//! [`TsqrClient`] — the protocol is served *by the transport-agnostic
//! facade itself*, which is what makes it composable:
//!
//! * `mrtsqr worker` ([`run_worker`]) waits for the `Hello` handshake,
//!   reconstructs the peer's cluster recipe ([`WorkerConfig`]) into an
//!   in-process client (`Local` transport over an engine pool), and
//!   serves. This is the child process a
//!   [`crate::client::ProcessTransport`] spawns.
//! * `mrtsqr serve` ([`run_serve`]) serves a client the CLI already
//!   built — which may itself use `--worker-procs N`, making `serve` a
//!   relay: any program able to frame bytes on a pipe gets a full
//!   cross-process engine pool without linking this crate.
//! * `mrtsqr serve --listen <addr>` ([`super::tcp::TcpServer`]) runs
//!   the same per-connection loop over sockets, one session thread per
//!   accepted connection, all sharing one client and one job registry
//!   (`retain_jobs` mode) so a reconnecting client can re-attach to
//!   its in-flight jobs.
//!
//! One reader (the loop) owns stdin; stdout is mutex-shared between
//! the loop's replies and the per-job waiter threads that push
//! [`Op::JobDone`]/[`Op::JobFail`] frames when factorizations finish —
//! the sending half of the demux scheme described in
//! [`crate::client::process`].
//!
//! Jobs are executed under the ids the *peer* assigns
//! ([`TsqrClient::submit_with_id`]), so DFS namespaces and fault
//! streams agree across the pipe — the determinism contract's other
//! half.

use super::wire::{self, Frame, Op, WireReader, WireWriter, WorkerConfig, MAX_FRAME_BYTES};
use super::{ClientIngestHandle, ClientJobHandle, TsqrClient};
use crate::linalg::Matrix;
use crate::service::{JobId, JobStatus};
use crate::session::{Placement, SessionBuilder};
use crate::stream::RFold;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::{Arc, Mutex};

/// Serve the protocol on this process's stdin/stdout, building the
/// engine pool from the peer's `Hello` handshake. The loop ends on
/// `Shutdown` or EOF (the parent closed the pipe); a protocol error is
/// fatal — the parent treats our exit as worker death.
pub fn run_worker() -> Result<()> {
    let stdin = std::io::stdin();
    serve_loop(stdin.lock(), std::io::stdout(), None)
}

/// Serve the protocol on stdin/stdout over a client the caller already
/// built (the `mrtsqr serve` subcommand). The `Hello` frame is then a
/// version handshake only — its embedded config is ignored in favor of
/// the CLI's.
pub fn run_serve(client: TsqrClient) -> Result<()> {
    let stdin = std::io::stdin();
    serve_loop(stdin.lock(), std::io::stdout(), Some(client))
}

/// One in-progress streamed ingestion (chunks buffered until `End`).
struct PendingIngest {
    cols: usize,
    placement: Placement,
    rows: usize,
    data: Vec<f64>,
}

/// State shared by every connection of one network server: the
/// pre-built client and the job registry. A TCP client that loses its
/// connection mid-batch reconnects and resubmits under the same ids —
/// the shared registry is what lets the new connection attach to jobs
/// the old one started (see the `Op::Submit` arm of the serve loop).
#[derive(Clone)]
pub(crate) struct SharedServe {
    client: Arc<TsqrClient>,
    jobs: Arc<Mutex<HashMap<u64, Arc<ClientJobHandle>>>>,
    /// Async-ingestion jobs, keyed by their (peer-assigned) job id —
    /// shared like `jobs` so a reconnecting TCP client can keep
    /// polling an ingestion the old connection queued.
    ingest_jobs: Arc<Mutex<HashMap<u64, Arc<ClientIngestHandle>>>>,
}

impl SharedServe {
    pub(crate) fn new(client: Arc<TsqrClient>) -> SharedServe {
        SharedServe {
            client,
            jobs: Arc::new(Mutex::new(HashMap::new())),
            ingest_jobs: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    pub(crate) fn client(&self) -> &Arc<TsqrClient> {
        &self.client
    }
}

/// Everything one serving session holds between frames.
struct Server<W: Write + Send + 'static> {
    out: Arc<Mutex<W>>,
    client: Option<Arc<TsqrClient>>,
    /// Whether `Hello` must supply the cluster config (worker mode) or
    /// only version-handshake a pre-built client (serve mode).
    prebuilt: bool,
    jobs: Arc<Mutex<HashMap<u64, Arc<ClientJobHandle>>>>,
    /// Pipe mode reclaims a registry entry once its terminal frame is
    /// pushed; network mode retains it until `Evict` so a reconnecting
    /// client can re-attach (and a done-but-undelivered result is
    /// re-pushed immediately on resubmission).
    retain_jobs: bool,
    ingests: HashMap<String, PendingIngest>,
    /// Queued asynchronous ingestions ([`Op::IngestAsync`]), polled by
    /// [`Op::IngestStatus`] and cancellable via [`Op::Cancel`].
    ingest_jobs: Arc<Mutex<HashMap<u64, Arc<ClientIngestHandle>>>>,
    /// Open server-side streamed folds ([`Op::StreamFold`]), one
    /// [`RFold`] per stream name, connection-local like `ingests`.
    folds: HashMap<String, RFold>,
    /// Live notify threads, joined before the loop returns so every
    /// submitted job's terminal frame is flushed before worker exit.
    notifiers: Vec<std::thread::JoinHandle<()>>,
}

fn send<W: Write>(out: &Mutex<W>, op: Op, req_id: u64, payload: &[u8]) -> Result<()> {
    let mut w = out.lock().expect("protocol writer");
    wire::write_frame(&mut *w, op, req_id, payload)?;
    w.flush()?;
    Ok(())
}

/// The protocol loop shared by both entry points; exposed to the crate
/// so tests can serve over in-memory pipes.
pub(crate) fn serve_loop<R: Read, W: Write + Send + 'static>(
    input: R,
    output: W,
    prebuilt: Option<TsqrClient>,
) -> Result<()> {
    let shared = prebuilt.map(|client| SharedServe::new(Arc::new(client)));
    serve_connection(input, output, shared, false)
}

/// Serve one connection's frames. With `Some(shared)` the session runs
/// over a pre-built client (and, for the TCP server, a job registry
/// shared across connections); with `None` the `Hello` handshake must
/// carry the cluster config (worker mode). `retain_jobs` selects the
/// network-mode registry lifetime: entries survive their terminal push
/// until `Evict`, so reconnecting clients can re-attach.
pub(crate) fn serve_connection<R: Read, W: Write + Send + 'static>(
    mut input: R,
    output: W,
    shared: Option<SharedServe>,
    retain_jobs: bool,
) -> Result<()> {
    let mut server = Server {
        out: Arc::new(Mutex::new(output)),
        prebuilt: shared.is_some(),
        client: shared.as_ref().map(|s| s.client.clone()),
        ingest_jobs: shared.as_ref().map(|s| s.ingest_jobs.clone()).unwrap_or_default(),
        jobs: shared.map(|s| s.jobs).unwrap_or_default(),
        retain_jobs,
        ingests: HashMap::new(),
        folds: HashMap::new(),
        notifiers: Vec::new(),
    };
    loop {
        let frame = match wire::read_frame(&mut input) {
            Ok(Some(frame)) => frame,
            Ok(None) => break,
            Err(err) => {
                // a peer speaking another protocol version gets a
                // clean error frame (at *our* version, echoing the
                // offending req_id) before the hangup, instead of a
                // silent connection drop it cannot diagnose
                if let Some(vm) = err.downcast_ref::<wire::VersionMismatch>() {
                    let mut w = WireWriter::new();
                    w.str(&vm.to_string());
                    let _ = send(&server.out, Op::Err, vm.req_id, &w.into_bytes());
                }
                return Err(err);
            }
        };
        let shutdown = frame.op == Op::Shutdown;
        let req_id = frame.req_id;
        match server.handle(frame) {
            Ok((op, payload)) => send(&server.out, op, req_id, &payload)?,
            Err(err) => {
                let mut w = WireWriter::new();
                w.str(&format!("{err:#}"));
                send(&server.out, Op::Err, req_id, &w.into_bytes())?;
            }
        }
        if shutdown {
            break;
        }
    }
    // let every in-flight job finish and push its terminal frame (the
    // client — and with it the engine pool — is still alive here);
    // only then drop the client, which drains and joins the pool
    for notifier in server.notifiers.drain(..) {
        let _ = notifier.join();
    }
    Ok(())
}

impl<W: Write + Send + 'static> Server<W> {
    fn client(&self) -> Result<&Arc<TsqrClient>> {
        self.client
            .as_ref()
            .ok_or_else(|| anyhow!("protocol: Hello handshake required before any other op"))
    }

    fn handle(&mut self, frame: Frame) -> Result<(Op, Vec<u8>)> {
        let mut r = WireReader::new(&frame.payload);
        match frame.op {
            Op::Hello => {
                let cfg = r.config()?;
                r.finish()?;
                if self.client.is_none() {
                    self.client = Some(Arc::new(build_from_config(&cfg)?));
                } else if !self.prebuilt {
                    bail!("protocol: duplicate Hello");
                }
                let client = self.client()?;
                let mut w = WireWriter::new();
                w.u64(client.shards() as u64);
                w.u64(client.workers() as u64);
                w.u64(client.capacity() as u64);
                w.u64(client.host_threads() as u64);
                w.str(&client.backend_desc());
                Ok((Op::HelloAck, w.into_bytes()))
            }
            Op::IngestGaussian => {
                let name = r.str()?;
                let rows = r.usize()?;
                let cols = r.usize()?;
                let seed = r.u64()?;
                let placement = r.placement()?;
                r.finish()?;
                let handle =
                    self.client()?.ingest_gaussian_placed(&name, rows, cols, seed, placement)?;
                let mut w = WireWriter::new();
                w.handle(&handle);
                Ok((Op::Handle, w.into_bytes()))
            }
            Op::IngestBegin => {
                let name = r.str()?;
                let cols = r.usize()?;
                let placement = r.placement()?;
                r.finish()?;
                self.client()?;
                self.ingests
                    .insert(name, PendingIngest { cols, placement, rows: 0, data: Vec::new() });
                Ok((Op::Ok, Vec::new()))
            }
            Op::IngestChunk => {
                let (name, first_row, cols, data) = r.chunk()?;
                r.finish()?;
                let pending = self
                    .ingests
                    .get_mut(&name)
                    .ok_or_else(|| anyhow!("protocol: chunk for unopened ingestion {name:?}"))?;
                if cols != pending.cols || first_row != pending.rows as u64 {
                    bail!(
                        "protocol: chunk ({first_row}, {cols} cols) does not continue \
                         ingestion {name:?} at row {} with {} cols",
                        pending.rows,
                        pending.cols
                    );
                }
                pending.rows += data.len() / cols;
                pending.data.extend_from_slice(&data);
                Ok((Op::Ok, Vec::new()))
            }
            Op::IngestEnd => {
                let name = r.str()?;
                r.finish()?;
                let pending = self
                    .ingests
                    .remove(&name)
                    .ok_or_else(|| anyhow!("protocol: end of unopened ingestion {name:?}"))?;
                let matrix =
                    Matrix { rows: pending.rows, cols: pending.cols, data: pending.data };
                let handle =
                    self.client()?.ingest_matrix_placed(&name, &matrix, pending.placement)?;
                let mut w = WireWriter::new();
                w.handle(&handle);
                Ok((Op::Handle, w.into_bytes()))
            }
            Op::Submit => {
                let id = r.u64()?;
                let input = r.handle()?;
                let req = r.request()?;
                r.finish()?;
                let client = self.client()?.clone();
                // network mode: a Submit under a registered id is a
                // *resubmission* after a dropped connection — attach
                // this connection as the push target instead of
                // re-running (determinism makes the result identical
                // either way; a job that already finished re-pushes
                // its terminal frame immediately)
                let attached = if self.retain_jobs {
                    self.jobs.lock().expect("jobs registry").get(&id).cloned()
                } else {
                    None
                };
                let job = match attached {
                    Some(job) => job,
                    None => {
                        let job = Arc::new(client.submit_with_id(JobId(id), &input, req)?);
                        self.jobs.lock().expect("jobs registry").insert(id, job.clone());
                        job
                    }
                };
                // a long-running serve session must not accumulate one
                // JoinHandle per job ever submitted
                self.notifiers.retain(|h| !h.is_finished());
                // waiter thread: push the terminal frame when the job
                // finishes, however many jobs are in flight
                let out = self.out.clone();
                let registry = self.jobs.clone();
                let retain = self.retain_jobs;
                let notifier = std::thread::Builder::new()
                    .name(format!("mrtsqr-notify-{id}"))
                    .spawn(move || {
                        let result = job.wait();
                        let mut w = WireWriter::new();
                        w.u64(id);
                        let (op, payload) = match result {
                            Ok(fact) => {
                                w.f64(job.wall_secs().unwrap_or(0.0));
                                w.factorization(&fact);
                                (Op::JobDone, w.into_bytes())
                            }
                            Err(err) => {
                                let status = if job.status() == JobStatus::Cancelled {
                                    JobStatus::Cancelled
                                } else {
                                    JobStatus::Failed
                                };
                                w.status(status);
                                match job.wall_secs() {
                                    None => w.u8(0),
                                    Some(secs) => {
                                        w.u8(1);
                                        w.f64(secs);
                                    }
                                }
                                w.str(&format!("{err:#}"));
                                (Op::JobFail, w.into_bytes())
                            }
                        };
                        // a send failure means the peer is gone; the
                        // loop will exit on its own EOF (and, over
                        // TCP, a reconnecting client resubmits to get
                        // the frame re-pushed)
                        let _ = send(&out, op, 0, &payload);
                        // pipe mode: the peer's handle has the
                        // terminal state now (the pushed frame
                        // precedes any later unknown-job error reply
                        // on the FIFO pipe), so the registry entry can
                        // be reclaimed. Network mode retains it until
                        // Evict for reconnect-and-resubmit.
                        if !retain {
                            registry.lock().expect("jobs registry").remove(&id);
                        }
                    })
                    .expect("spawn notify thread");
                self.notifiers.push(notifier);
                Ok((Op::Ok, Vec::new()))
            }
            Op::Status => {
                let id = r.u64()?;
                r.finish()?;
                let job = self.job(id)?;
                let mut w = WireWriter::new();
                w.status(job.status());
                Ok((Op::StatusReply, w.into_bytes()))
            }
            Op::Cancel => {
                let id = r.u64()?;
                r.finish()?;
                // the id spaces are shared: try factorizations first,
                // then queued ingestions
                let cancelled = match self.job(id) {
                    Ok(job) => job.cancel(),
                    Err(err) => {
                        let ing = self
                            .ingest_jobs
                            .lock()
                            .expect("ingest registry")
                            .get(&id)
                            .cloned();
                        match ing {
                            Some(ing) => ing.cancel(),
                            None => return Err(err),
                        }
                    }
                };
                let mut w = WireWriter::new();
                w.bool(cancelled);
                Ok((Op::Flag, w.into_bytes()))
            }
            Op::IngestAsync => {
                let id = r.u64()?;
                let name = r.str()?;
                let rows = r.usize()?;
                let cols = r.usize()?;
                let seed = r.u64()?;
                let placement = r.placement()?;
                r.finish()?;
                let ing = self.client()?.ingest_gaussian_async_with_id(
                    JobId(id),
                    &name,
                    rows,
                    cols,
                    seed,
                    placement,
                )?;
                let mut w = WireWriter::new();
                w.handle(&ing.handle());
                self.ingest_jobs.lock().expect("ingest registry").insert(id, Arc::new(ing));
                Ok((Op::Handle, w.into_bytes()))
            }
            Op::IngestStatus => {
                let id = r.u64()?;
                r.finish()?;
                let ing = self
                    .ingest_jobs
                    .lock()
                    .expect("ingest registry")
                    .get(&id)
                    .cloned()
                    .ok_or_else(|| anyhow!("protocol: unknown ingestion job id {id}"))?;
                let mut w = WireWriter::new();
                w.status(ing.status());
                Ok((Op::StatusReply, w.into_bytes()))
            }
            Op::StreamFold => {
                match r.u8()? {
                    0 => {
                        // begin: name, cols, chunk_rows
                        let name = r.str()?;
                        let cols = r.usize()?;
                        let chunk_rows = r.usize()?;
                        r.finish()?;
                        self.client()?;
                        self.folds.insert(name, RFold::new(cols, chunk_rows));
                        Ok((Op::Ok, Vec::new()))
                    }
                    1 => {
                        // push: one chunk of rows folded into the
                        // running R — O(cols²) retained state, the raw
                        // rows are never kept
                        let (name, _first_row, cols, data) = r.chunk()?;
                        r.finish()?;
                        let fold = self.folds.get_mut(&name).ok_or_else(|| {
                            anyhow!("protocol: chunk for unopened stream fold {name:?}")
                        })?;
                        let rows = data.len() / cols;
                        fold.push_chunk(&Matrix { rows, cols, data })?;
                        Ok((Op::Ok, Vec::new()))
                    }
                    2 => {
                        // finish: reply with the final R
                        let name = r.str()?;
                        r.finish()?;
                        let fold = self.folds.remove(&name).ok_or_else(|| {
                            anyhow!("protocol: finish of unopened stream fold {name:?}")
                        })?;
                        let (r_final, _stats) = fold.finish_r()?;
                        let mut w = WireWriter::new();
                        w.matrix(&r_final);
                        Ok((Op::MatrixData, w.into_bytes()))
                    }
                    other => bail!("protocol: unknown StreamFold subop {other}"),
                }
            }
            Op::Evict => {
                let id = r.u64()?;
                r.finish()?;
                let swept = self.client()?.evict_job(JobId(id))?;
                self.jobs.lock().expect("jobs registry").remove(&id);
                let mut w = WireWriter::new();
                w.u64(swept as u64);
                Ok((Op::Count, w.into_bytes()))
            }
            Op::FetchMatrix => {
                let handle = r.handle()?;
                r.finish()?;
                let matrix = self.client()?.get_matrix(&handle)?;
                let mut w = WireWriter::new();
                w.matrix(&matrix);
                let payload = w.into_bytes();
                // an oversized reply must come back as a clean error —
                // letting write_frame's size ensure fail would kill
                // this whole serving session (and with it every
                // in-flight job), not just this request
                if payload.len() > MAX_FRAME_BYTES as usize {
                    bail!(
                        "matrix {:?} is {} bytes — beyond the single-frame fetch limit; \
                         read it on the worker that holds it (pin chained jobs there)",
                        handle.file,
                        payload.len()
                    );
                }
                Ok((Op::MatrixData, payload))
            }
            Op::SetScale => {
                let name = r.str()?;
                let scale = r.f64()?;
                r.finish()?;
                self.client()?.set_scale(&name, scale)?;
                Ok((Op::Ok, Vec::new()))
            }
            Op::SchedTally => {
                r.finish()?;
                let tally = self.client()?.sched_tally()?;
                let mut w = WireWriter::new();
                w.tally(&tally);
                Ok((Op::TallyReply, w.into_bytes()))
            }
            Op::Ping => {
                // liveness probe: answered even before Hello — the
                // network transport's health checker must be able to
                // time a round trip without owning the handshake
                r.finish()?;
                Ok((Op::Pong, Vec::new()))
            }
            Op::Shutdown => {
                r.finish()?;
                Ok((Op::Ok, Vec::new()))
            }
            other => bail!("protocol: unexpected client-bound opcode {other:?}"),
        }
    }

    fn job(&self, id: u64) -> Result<Arc<ClientJobHandle>> {
        self.jobs
            .lock()
            .expect("jobs registry")
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow!("protocol: unknown job id {id}"))
    }
}

/// Reconstruct the peer's cluster recipe into an in-process client.
/// `service_workers` is clamped to ≥ 1: manual drain cannot reach
/// across a pipe, so a worker always has background execution.
fn build_from_config(cfg: &WorkerConfig) -> Result<TsqrClient> {
    let mut cfg = *cfg;
    cfg.service_workers = cfg.service_workers.max(1);
    SessionBuilder::from_worker_config(&cfg).build_client()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Backend, FactorizationRequest, TsqrSession};
    use std::io::Cursor;

    /// Drive one request frame through a serve loop over in-memory
    /// pipes and return every frame the server wrote back.
    fn roundtrip(frames: &[(Op, u64, Vec<u8>)]) -> Vec<Frame> {
        let mut input = Vec::new();
        for (op, req_id, payload) in frames {
            wire::write_frame(&mut input, *op, *req_id, payload).unwrap();
        }
        let client = TsqrSession::builder()
            .backend(Backend::Native)
            .rows_per_task(50)
            .service_workers(1)
            .build_client()
            .unwrap();
        let out: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        serve_loop(Cursor::new(input), SharedBuf(out.clone()), Some(client)).unwrap();
        let bytes = out.lock().unwrap().clone();
        let mut cursor = &bytes[..];
        let mut frames = Vec::new();
        while let Some(frame) = wire::read_frame(&mut cursor).unwrap() {
            frames.push(frame);
        }
        frames
    }

    /// `Write` into an `Arc<Mutex<Vec<u8>>>` so the test can read what
    /// the server (and its waiter threads) wrote.
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn hello_payload() -> Vec<u8> {
        let mut w = WireWriter::new();
        w.config(&WorkerConfig {
            model: crate::dfs::DiskModel::icme_like(),
            cluster: crate::mapreduce::ClusterConfig {
                map_slots: 40,
                reduce_slots: 40,
                host_threads: 1,
            },
            faults: None,
            opts: crate::coordinator::CoordOpts::default(),
            backend: Backend::Native,
            engine_shards: 1,
            service_workers: 1,
            queue_capacity: 8,
            scheduler: crate::service::SchedulerConfig::default(),
        });
        w.into_bytes()
    }

    #[test]
    fn serve_loop_runs_a_whole_job_over_in_memory_pipes() {
        // Hello → ingest → submit → shutdown; the reply stream must
        // carry the acks, the handle, and the pushed JobDone whose
        // factorization decodes with a valid digest
        let mut ingest = WireWriter::new();
        ingest.str("A");
        ingest.u64(200);
        ingest.u64(4);
        ingest.u64(7);
        ingest.placement(Placement::Auto);
        let mut submit = WireWriter::new();
        submit.u64(3); // peer-assigned job id
        submit.handle(&crate::coordinator::MatrixHandle::new("A", 200, 4));
        submit.request(&FactorizationRequest::r_only());
        let frames = roundtrip(&[
            (Op::Hello, 1, hello_payload()),
            (Op::IngestGaussian, 2, ingest.into_bytes()),
            (Op::Submit, 3, submit.into_bytes()),
            // note: no explicit Shutdown — EOF must also end the loop
        ]);
        // replies in request order (the loop is serial)…
        assert_eq!(frames[0].op, Op::HelloAck);
        assert_eq!(frames[1].op, Op::Handle);
        let mut r = WireReader::new(&frames[1].payload);
        let h = r.handle().unwrap();
        assert_eq!((h.file.as_str(), h.rows, h.cols), ("A", 200, 4));
        assert_eq!(frames[2].op, Op::Ok, "submit ack");
        // …plus the pushed JobDone (serve_loop drops the client, which
        // joins workers, before we read the stream — the push is there)
        let done = frames.iter().find(|f| f.op == Op::JobDone).expect("JobDone push");
        assert_eq!(done.req_id, 0, "pushes carry req_id 0");
        let mut r = WireReader::new(&done.payload);
        assert_eq!(r.u64().unwrap(), 3, "peer-assigned id echoes back");
        let _wall = r.f64().unwrap();
        let fact = r.factorization().unwrap();
        r.finish().unwrap();
        assert_eq!(fact.r.cols, 4);
        assert_eq!(fact.result_digest().len(), 16);
    }

    #[test]
    fn ping_is_answered_with_pong() {
        let frames = roundtrip(&[(Op::Ping, 1, Vec::new())]);
        assert_eq!((frames[0].op, frames[0].req_id), (Op::Pong, 1));
        assert!(frames[0].payload.is_empty());
    }

    #[test]
    fn sched_tally_over_the_wire() {
        let frames =
            roundtrip(&[(Op::Hello, 1, hello_payload()), (Op::SchedTally, 2, Vec::new())]);
        assert_eq!((frames[1].op, frames[1].req_id), (Op::TallyReply, 2));
        let mut r = WireReader::new(&frames[1].payload);
        let t = r.tally().unwrap();
        r.finish().unwrap();
        assert_eq!(t.per_shard_steals, vec![0], "one idle counter per shard");
        assert!(t.admission_held.is_empty());
    }

    #[test]
    fn version_mismatch_gets_a_clean_error_frame_not_a_hang() {
        // a doctored Hello claiming WIRE_VERSION+1: the session must
        // write an Err frame naming the version (at our version, with
        // the offending req_id) and then end with an error
        let mut input = Vec::new();
        wire::write_frame(&mut input, Op::Hello, 7, &hello_payload()).unwrap();
        input[4..6].copy_from_slice(&(wire::WIRE_VERSION + 1).to_le_bytes());
        let client = TsqrSession::builder()
            .backend(Backend::Native)
            .rows_per_task(50)
            .service_workers(1)
            .build_client()
            .unwrap();
        let out: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        let err = serve_loop(Cursor::new(input), SharedBuf(out.clone()), Some(client))
            .expect_err("mismatched version is a session error");
        assert!(err.to_string().contains("version"), "{err:#}");
        let bytes = out.lock().unwrap().clone();
        let frame = wire::read_frame(&mut &bytes[..]).unwrap().expect("error frame");
        assert_eq!((frame.op, frame.req_id), (Op::Err, 7));
        let msg = WireReader::new(&frame.payload).str().unwrap();
        assert!(msg.contains("version"), "{msg}");
    }

    #[test]
    fn ops_before_hello_are_rejected_in_worker_mode() {
        let mut input = Vec::new();
        let mut w = WireWriter::new();
        w.str("A");
        w.f64(2.0);
        wire::write_frame(&mut input, Op::SetScale, 1, &w.into_bytes()).unwrap();
        let out: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
        serve_loop(Cursor::new(input), SharedBuf(out.clone()), None).unwrap();
        let bytes = out.lock().unwrap().clone();
        let frame = wire::read_frame(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(frame.op, Op::Err);
        let msg = WireReader::new(&frame.payload).str().unwrap();
        assert!(msg.contains("Hello"), "{msg}");
    }

    #[test]
    fn async_ingest_over_the_wire_runs_a_dependent_job() {
        // IngestAsync replies with the handle immediately; a Submit
        // naming the still-ingesting matrix queues behind it on the
        // serving side and must still complete (JobDone push)
        let mut ingest = WireWriter::new();
        ingest.u64(1); // peer-assigned ingestion job id
        ingest.str("A");
        ingest.u64(200);
        ingest.u64(4);
        ingest.u64(7);
        ingest.placement(Placement::Auto);
        let mut status = WireWriter::new();
        status.u64(1);
        let mut submit = WireWriter::new();
        submit.u64(5);
        submit.handle(&crate::coordinator::MatrixHandle::new("A", 200, 4));
        submit.request(&FactorizationRequest::r_only());
        let frames = roundtrip(&[
            (Op::Hello, 1, hello_payload()),
            (Op::IngestAsync, 2, ingest.into_bytes()),
            (Op::IngestStatus, 3, status.into_bytes()),
            (Op::Submit, 4, submit.into_bytes()),
        ]);
        assert_eq!(frames[1].op, Op::Handle, "IngestAsync acks with the handle");
        let mut r = WireReader::new(&frames[1].payload);
        let h = r.handle().unwrap();
        assert_eq!((h.file.as_str(), h.rows, h.cols), ("A", 200, 4));
        assert_eq!(frames[2].op, Op::StatusReply);
        let mut r = WireReader::new(&frames[2].payload);
        let s = r.status().unwrap(); // any live state — the upload races the poll
        assert_ne!(s, JobStatus::Failed, "queued ingestion must not have failed");
        assert_eq!(frames[3].op, Op::Ok, "submit ack");
        let done = frames.iter().find(|f| f.op == Op::JobDone).expect("JobDone push");
        let mut r = WireReader::new(&done.payload);
        assert_eq!(r.u64().unwrap(), 5);
        let _wall = r.f64().unwrap();
        let fact = r.factorization().unwrap();
        assert_eq!(fact.r.cols, 4, "dependent job ran against the ingested matrix");
    }

    #[test]
    fn stream_fold_over_the_wire_is_chunking_invariant() {
        // the same 5 rows through a 2-chunk split and a one-shot push
        // must produce bitwise-identical R frames
        let rows: Vec<f64> = (0..15).map(|i| (i as f64).mul_add(0.5, 1.0)).collect();
        let begin = |name: &str| {
            let mut w = WireWriter::new();
            w.u8(0);
            w.str(name);
            w.u64(3);
            w.u64(2); // fold leaf size: 2 rows
            w.into_bytes()
        };
        let push = |name: &str, first: u64, data: &[f64]| {
            let mut w = WireWriter::new();
            w.u8(1);
            w.chunk(name, first, 3, data);
            w.into_bytes()
        };
        let finish = |name: &str| {
            let mut w = WireWriter::new();
            w.u8(2);
            w.str(name);
            w.into_bytes()
        };
        let frames = roundtrip(&[
            (Op::StreamFold, 1, begin("S")),
            (Op::StreamFold, 2, push("S", 0, &rows[..9])),
            (Op::StreamFold, 3, push("S", 3, &rows[9..])),
            (Op::StreamFold, 4, finish("S")),
            (Op::StreamFold, 5, begin("T")),
            (Op::StreamFold, 6, push("T", 0, &rows)),
            (Op::StreamFold, 7, finish("T")),
            (Op::StreamFold, 8, finish("T")), // already closed: an error
        ]);
        assert_eq!(frames[3].op, Op::MatrixData);
        assert_eq!(frames[6].op, Op::MatrixData);
        let mut r = WireReader::new(&frames[3].payload);
        let r_split = r.matrix().unwrap();
        let mut r = WireReader::new(&frames[6].payload);
        let r_oneshot = r.matrix().unwrap();
        assert_eq!((r_split.rows, r_split.cols), (3, 3));
        let bits = |m: &Matrix| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&r_split), bits(&r_oneshot), "arrival chunking must not change R");
        assert_eq!(frames[7].op, Op::Err, "finishing a closed fold is a clean error");
    }

    #[test]
    fn chunked_ingest_reassembles_in_order_and_rejects_gaps() {
        let mut begin = WireWriter::new();
        begin.str("M");
        begin.u64(2);
        begin.placement(Placement::Auto);
        let mut c0 = WireWriter::new();
        c0.chunk("M", 0, 2, &[1.0, 2.0, 3.0, 4.0]);
        let mut gap = WireWriter::new();
        gap.chunk("M", 5, 2, &[9.0, 9.0]); // wrong offset: must be rejected
        let mut c1 = WireWriter::new();
        c1.chunk("M", 2, 2, &[5.0, 6.0]);
        let mut end = WireWriter::new();
        end.str("M");
        let mut fetch = WireWriter::new();
        fetch.handle(&crate::coordinator::MatrixHandle::new("M", 3, 2));
        let frames = roundtrip(&[
            (Op::IngestBegin, 1, begin.into_bytes()),
            (Op::IngestChunk, 2, c0.into_bytes()),
            (Op::IngestChunk, 3, gap.into_bytes()),
            (Op::IngestChunk, 4, c1.into_bytes()),
            (Op::IngestEnd, 5, end.into_bytes()),
            (Op::FetchMatrix, 6, fetch.into_bytes()),
        ]);
        assert_eq!(frames[0].op, Op::Ok);
        assert_eq!(frames[1].op, Op::Ok);
        assert_eq!(frames[2].op, Op::Err, "out-of-order chunk must be rejected");
        assert_eq!(frames[3].op, Op::Ok, "in-order chunk still lands after the bad one");
        assert_eq!(frames[4].op, Op::Handle);
        let mut r = WireReader::new(&frames[4].payload);
        assert_eq!(r.handle().unwrap().rows, 3);
        assert_eq!(frames[5].op, Op::MatrixData);
        let mut r = WireReader::new(&frames[5].payload);
        let m = r.matrix().unwrap();
        assert_eq!((m.rows, m.cols), (3, 2));
        assert_eq!(m.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }
}
