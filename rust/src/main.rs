//! `mrtsqr` — CLI for the Direct TSQR MapReduce reproduction.
//!
//! ```text
//! mrtsqr qr        --rows 100000 --cols 25 --algo direct [--pjrt] [--condition 1e8]
//! mrtsqr svd       --rows 50000  --cols 10 [--pjrt]
//! mrtsqr stability --rows 5000   --cols 50            # Fig. 6 sweep
//! mrtsqr faults    --rows 80000  --cols 10 --prob 0.125  # Fig. 7 point
//! mrtsqr model     --beta-r 64 --beta-w 126            # Tables III-V
//! mrtsqr info                                          # artifact manifest
//! ```

use anyhow::{bail, Result};
use mrtsqr::coordinator::{Algorithm, Coordinator, MatrixHandle};
use mrtsqr::dfs::DiskModel;
use mrtsqr::linalg::matrix_with_condition;
use mrtsqr::mapreduce::{ClusterConfig, Engine, FaultPolicy};
use mrtsqr::perfmodel::{lower_bound_secs, AlgoKind, StageParallelism, WorkloadShape};
use mrtsqr::runtime::{BlockCompute, Manifest, NativeRuntime, PjrtRuntime};
use mrtsqr::util::cli::Args;
use mrtsqr::util::rng::Rng;
use mrtsqr::util::table::{commas, sci, Table};
use mrtsqr::workload::{gaussian_matrix, get_matrix, put_matrix};

fn parse_algo(s: &str) -> Result<Algorithm> {
    Ok(match s {
        "cholesky" => Algorithm::Cholesky { refine: false },
        "cholesky-ir" => Algorithm::Cholesky { refine: true },
        "indirect" => Algorithm::IndirectTsqr { refine: false },
        "indirect-ir" => Algorithm::IndirectTsqr { refine: true },
        "direct" => Algorithm::DirectTsqr,
        "direct-fused" => Algorithm::DirectTsqrFused,
        "householder" => Algorithm::Householder,
        other => bail!(
            "unknown --algo {other:?} (cholesky|cholesky-ir|indirect|indirect-ir|direct|direct-fused|householder)"
        ),
    })
}

fn build_compute(args: &Args) -> Result<Box<dyn BlockCompute>> {
    if args.flag("pjrt") {
        Ok(Box::new(PjrtRuntime::from_default_artifacts()?))
    } else {
        Ok(Box::new(NativeRuntime))
    }
}

fn make_engine(args: &Args) -> Engine {
    let model = DiskModel {
        beta_r: args.get_f64("beta-r", 64.0) * 1e-9,
        beta_w: args.get_f64("beta-w", 126.0) * 1e-9,
        byte_scale: args.get_f64("byte-scale", 1.0),
        iteration_startup_secs: args.get_f64("startup", 15.0),
        task_startup_secs: args.get_f64("task-startup", 2.0),
    };
    let cluster = ClusterConfig {
        map_slots: args.get_usize("map-slots", 40),
        reduce_slots: args.get_usize("reduce-slots", 40),
    };
    Engine::new(model, cluster)
}

fn load_input(args: &Args, engine: &mut Engine) -> MatrixHandle {
    let rows = args.get_usize("rows", 100_000);
    let cols = args.get_usize("cols", 10);
    let seed = args.get_u64("seed", 42);
    if let Some(kappa) = args.get("condition") {
        let kappa: f64 = kappa.parse().expect("--condition wants a number");
        let mut rng = Rng::new(seed);
        let a = matrix_with_condition(rows, cols, kappa, &mut rng);
        put_matrix(&mut engine.dfs, "A", &a);
    } else {
        gaussian_matrix(&mut engine.dfs, "A", rows, cols, seed);
    }
    MatrixHandle::new("A", rows, cols)
}

fn cmd_qr(args: &Args) -> Result<()> {
    let algo = parse_algo(&args.get_or("algo", "direct"))?;
    let compute = build_compute(args)?;
    let mut engine = make_engine(args);
    let input = load_input(args, &mut engine);
    let mut coord = Coordinator::new(engine, compute.as_ref());
    coord.opts.rows_per_task = args.get_usize("rows-per-task", 1000);

    let res = coord.qr(&input, algo)?;
    println!("algorithm      : {}", algo.name());
    println!("matrix         : {} x {}", commas(input.rows as u64), input.cols);
    println!("virtual time   : {:.1} s", res.stats.virtual_secs());
    println!("wall time      : {:.3} s", res.stats.wall_secs());
    println!("steps          : {}", res.stats.steps.len());
    let io = res.stats.total_io();
    println!("bytes read     : {}", commas(io.bytes_read));
    println!("bytes written  : {}", commas(io.bytes_written));
    let a = get_matrix(&coord.engine.dfs, &input.file, input.cols)?;
    if let Some(qh) = &res.q {
        let q = get_matrix(&coord.engine.dfs, &qh.file, qh.cols)?;
        let recon = a.sub(&q.matmul(&res.r)).frob_norm() / a.frob_norm();
        println!("|A-QR|/|A|     : {}", sci(recon));
        println!("|QtQ-I|_2      : {}", sci(q.orthogonality_error()));
    } else {
        println!("(R-only algorithm — no Q factor)");
    }
    Ok(())
}

fn cmd_svd(args: &Args) -> Result<()> {
    let compute = build_compute(args)?;
    let mut engine = make_engine(args);
    let input = load_input(args, &mut engine);
    let mut coord = Coordinator::new(engine, compute.as_ref());
    let out = coord.svd(&input)?;
    let svd = out.svd.expect("svd parts");
    println!("TSVD via Direct TSQR — {} x {}", commas(input.rows as u64), input.cols);
    println!("virtual time : {:.1} s", out.stats.virtual_secs());
    println!("sigma        : {:?}", &svd.sigma[..svd.sigma.len().min(8)]);
    Ok(())
}

fn cmd_stability(args: &Args) -> Result<()> {
    let compute = build_compute(args)?;
    let rows = args.get_usize("rows", 5000);
    let cols = args.get_usize("cols", 50);
    let mut table = Table::new(
        "Fig. 6 — |QtQ-I|_2 vs condition number",
        &["kappa", "Chol", "Chol+IR", "Indirect", "Indirect+IR", "Direct"],
    );
    for exp in (1..=16).step_by(3) {
        let kappa = 10f64.powi(exp);
        let mut row = vec![format!("1e{exp:02}")];
        for algo in [
            Algorithm::Cholesky { refine: false },
            Algorithm::Cholesky { refine: true },
            Algorithm::IndirectTsqr { refine: false },
            Algorithm::IndirectTsqr { refine: true },
            Algorithm::DirectTsqr,
        ] {
            let mut engine = make_engine(args);
            let mut rng = Rng::new(7);
            let a = matrix_with_condition(rows, cols, kappa, &mut rng);
            put_matrix(&mut engine.dfs, "A", &a);
            let input = MatrixHandle::new("A", rows, cols);
            let mut coord = Coordinator::new(engine, compute.as_ref());
            let cell = match coord.qr(&input, algo) {
                Ok(res) => {
                    let q = get_matrix(&coord.engine.dfs, &res.q.unwrap().file, cols)?;
                    sci(q.orthogonality_error())
                }
                Err(_) => "breakdown".to_string(),
            };
            row.push(cell);
        }
        table.row(&row);
    }
    table.print();
    Ok(())
}

fn cmd_faults(args: &Args) -> Result<()> {
    let compute = build_compute(args)?;
    let prob = args.get_f64("prob", 0.125);
    let mut engine =
        make_engine(args).with_faults(FaultPolicy::new(prob), args.get_u64("seed", 99));
    let input = load_input(args, &mut engine);
    let mut coord = Coordinator::new(engine, compute.as_ref());
    let res = coord.qr(&input, Algorithm::DirectTsqr)?;
    println!("fault probability : {prob}");
    println!("faults injected   : {}", res.stats.total_faults());
    println!("virtual time      : {:.1} s", res.stats.virtual_secs());
    Ok(())
}

fn cmd_model(args: &Args) -> Result<()> {
    let beta_r = args.get_f64("beta-r", 64.0) * 1e-9;
    let beta_w = args.get_f64("beta-w", 126.0) * 1e-9;
    let par = StageParallelism::default();
    let mut table = Table::new(
        "Table V — computed lower bounds T_lb (secs)",
        &["Rows", "Cols", "Cholesky", "Indirect", "Chol+IR", "Ind+IR", "Direct", "House."],
    );
    for &(m, n) in &[
        (4_000_000_000u64, 4u64),
        (2_500_000_000, 10),
        (600_000_000, 25),
        (500_000_000, 50),
        (150_000_000, 100),
    ] {
        let (m1, m1d) = StageParallelism::paper_m1(m, n).unwrap();
        let mut row = vec![commas(m), n.to_string()];
        for kind in AlgoKind::ALL {
            let m1_used = if kind == AlgoKind::DirectTsqr { m1d } else { m1 };
            let shape = WorkloadShape::new(m, n, m1_used);
            let t = lower_bound_secs(kind, &shape, &par, beta_r, beta_w);
            row.push(format!("{:.0}", t));
        }
        table.row(&row);
    }
    table.print();
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)?;
    println!("artifacts dir : {}", dir.display());
    println!("modules       : {}", manifest.entries.len());
    let mut table = Table::new("AOT artifact manifest", &["op", "block rows", "cols", "file"]);
    for e in &manifest.entries {
        table.row(&[e.op.name().into(), e.b.to_string(), e.n.to_string(), e.file.clone()]);
    }
    table.print();
    Ok(())
}

const USAGE: &str = "usage: mrtsqr <qr|svd|stability|faults|model|info> [options]
  common options: --rows N --cols N --seed N --pjrt --algo <name>
                  --beta-r s/GB --beta-w s/GB --byte-scale X
  see README.md for the full list";

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("qr") => cmd_qr(&args),
        Some("svd") => cmd_svd(&args),
        Some("stability") => cmd_stability(&args),
        Some("faults") => cmd_faults(&args),
        Some("model") => cmd_model(&args),
        Some("info") => cmd_info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}
