//! `mrtsqr` — CLI for the Direct TSQR MapReduce reproduction.
//!
//! ```text
//! mrtsqr qr        --rows 100000 --cols 25 --algo auto [--pjrt] [--condition 1e8]
//! mrtsqr svd       --rows 50000  --cols 10 [--pjrt]
//! mrtsqr sigma     --rows 50000  --cols 10            # singular values only
//! mrtsqr lowrank   --rows 50000  --cols 64 --rank 4 --sketch countsketch  # randomized SVD
//! mrtsqr solve     --rows 50000  --cols 10 --rhs 1    # least squares min |Ax-b|
//! mrtsqr batch     --manifest jobs.txt --jobs 4       # concurrent job service
//! mrtsqr batch     --manifest jobs.txt --worker-procs 2  # …across worker processes
//! mrtsqr batch     --manifest jobs.txt --connect host:7420  # …against a remote server
//! mrtsqr stream    --rows 1000000 --cols 10 --chunk-rows 4096  # single-pass streaming R/Σ
//! mrtsqr serve     --shards 2                         # wire protocol on stdin/stdout
//! mrtsqr serve     --listen 0.0.0.0:7420 --shards 4   # …served over TCP
//! mrtsqr loadgen   --connect host:7420 --jobs-total 2000 --concurrency 16
//! mrtsqr worker                                       # child of the Process transport
//! mrtsqr stability --rows 5000   --cols 50            # Fig. 6 sweep
//! mrtsqr faults    --rows 80000  --cols 10 --prob 0.125  # Fig. 7 point
//! mrtsqr model     --beta-r 64 --beta-w 126            # Tables III-V
//! mrtsqr info                                          # artifact manifest
//! ```
//!
//! Everything runs through the [`mrtsqr::session`] layer (`batch`,
//! `serve` and `worker` through the transport-agnostic
//! [`mrtsqr::client::TsqrClient`]); `--algo` accepts the seven fixed
//! algorithm names plus `auto` (condition-aware selection, the
//! default).

use anyhow::{Context, Result};
use mrtsqr::coordinator::{Algorithm, MatrixHandle};
use mrtsqr::dfs::DiskModel;
use mrtsqr::linalg::{matrix_with_condition, Matrix};
use mrtsqr::mapreduce::{ClusterConfig, FaultPolicy};
use mrtsqr::perfmodel::{lower_bound_secs, AlgoKind, StageParallelism, WorkloadShape};
use mrtsqr::runtime::Manifest;
use mrtsqr::service::{parse_manifest_full, SchedulerConfig};
use mrtsqr::session::{AlgoChoice, Backend, FactorizationRequest, SessionBuilder, TsqrSession};
use mrtsqr::sketch::{SketchKind, SketchOptions, DEFAULT_OVERSAMPLE, DEFAULT_SKETCH_SEED};
use mrtsqr::util::cli::Args;
use mrtsqr::util::json::Json;
use mrtsqr::util::rng::Rng;
use mrtsqr::util::table::{commas, sci, Table};

fn parse_algo_choice(s: &str) -> Result<AlgoChoice> {
    if s == "auto" {
        return Ok(AlgoChoice::Auto);
    }
    Ok(AlgoChoice::Fixed(Algorithm::parse(s)?))
}

fn session_builder(args: &Args) -> SessionBuilder {
    let model = DiskModel {
        beta_r: args.get_f64("beta-r", 64.0) * 1e-9,
        beta_w: args.get_f64("beta-w", 126.0) * 1e-9,
        byte_scale: args.get_f64("byte-scale", 1.0),
        iteration_startup_secs: args.get_f64("startup", 15.0),
        task_startup_secs: args.get_f64("task-startup", 2.0),
    };
    let cluster = ClusterConfig {
        map_slots: args.get_usize("map-slots", 40),
        reduce_slots: args.get_usize("reduce-slots", 40),
        host_threads: args.get_usize("host-threads", mrtsqr::mapreduce::default_host_threads()),
    };
    let builder = TsqrSession::builder()
        .disk_model(model)
        .cluster(cluster)
        .backend(if args.flag("pjrt") { Backend::Pjrt } else { Backend::Auto })
        .rows_per_task(args.get_usize("rows-per-task", 1000));
    // kernel-layer knobs: --panel-block is a pure speed knob (digests
    // unchanged at any width); --mixed-precision opts Auto runs into
    // the κ-gated f32 step-1 path (changes bits where it fires)
    let builder = match args.get("panel-block") {
        Some(b) => builder.panel_block(b.parse().expect("--panel-block wants a width")),
        None => builder,
    };
    let builder = if args.flag("mixed-precision") { builder.mixed_precision(true) } else { builder };
    // optional fault injection (--fault-prob > 0 turns it on): lets
    // `serve`d clusters and loadgen runs exercise the retry path with
    // the same per-job determinism as the test suites
    let prob = args.get_f64("fault-prob", 0.0);
    let builder = if prob > 0.0 {
        builder.fault_policy(
            FaultPolicy {
                probability: prob,
                max_attempts: args.get_usize("fault-attempts", 4),
                waste_fraction: args.get_f64("fault-waste", 0.5),
            },
            args.get_u64("fault-seed", 99),
        )
    } else {
        builder
    };
    // reply deadline for the pipe/TCP transports (seconds)
    match args.get("request-timeout") {
        Some(secs) => {
            let secs: f64 = secs.parse().expect("--request-timeout wants seconds");
            builder.request_timeout(std::time::Duration::from_secs_f64(secs))
        }
        None => builder,
    }
}

/// Elastic-scheduling knobs: CLI flags layered over a manifest's
/// `%scheduler` directive, CLI winning key by key. `--steal` /
/// `--locality` switch those policies on, `--quota-per-label N` caps
/// concurrent jobs per label (0 = off), `--autoscale MIN:MAX` bounds
/// the worker-process autoscaler (0:0 = off; needs `--worker-procs`),
/// `--autoscale-interval-ms N` its heartbeat. Every knob is pure
/// scheduling: `result_digest`s are identical at any setting.
fn scheduler_config(args: &Args, base: Option<SchedulerConfig>) -> Result<SchedulerConfig> {
    let mut cfg = base.unwrap_or_default();
    if args.flag("steal") {
        cfg.steal = true;
    }
    if args.flag("locality") {
        cfg.locality = true;
    }
    if let Some(n) = args.get("quota-per-label") {
        let n: usize = n.parse().ok().context("--quota-per-label wants a count")?;
        cfg.quota_per_label = if n == 0 { None } else { Some(n) };
    }
    if let Some(spec) = args.get("autoscale") {
        let (min, max) = spec.split_once(':').context("--autoscale wants MIN:MAX")?;
        cfg.autoscale_min = min.parse().ok().context("--autoscale min wants a count")?;
        cfg.autoscale_max = max.parse().ok().context("--autoscale max wants a count")?;
        if cfg.autoscale_max > 0 && cfg.autoscale_min > cfg.autoscale_max {
            anyhow::bail!(
                "--autoscale min {} exceeds max {}",
                cfg.autoscale_min,
                cfg.autoscale_max
            );
        }
    }
    if let Some(ms) = args.get("autoscale-interval-ms") {
        let ms: u64 = ms.parse().ok().context("--autoscale-interval-ms wants millis")?;
        cfg.autoscale_interval = std::time::Duration::from_millis(ms);
    }
    Ok(cfg)
}

/// `--connect host:port[,host:port…]` — the remote servers a `batch`
/// or `loadgen` client drives instead of a local engine pool.
fn connect_addrs(args: &Args) -> Vec<String> {
    args.get("connect")
        .map(|s| s.split(',').map(|a| a.trim().to_string()).filter(|a| !a.is_empty()).collect())
        .unwrap_or_default()
}

fn load_input(args: &Args, session: &mut TsqrSession) -> Result<MatrixHandle> {
    let rows = args.get_usize("rows", 100_000);
    let cols = args.get_usize("cols", 10);
    let seed = args.get_u64("seed", 42);
    if let Some(kappa) = args.get("condition") {
        let kappa: f64 = kappa.parse().expect("--condition wants a number");
        let mut rng = Rng::new(seed);
        let a = matrix_with_condition(rows, cols, kappa, &mut rng);
        session.ingest_matrix("A", &a)
    } else {
        session.ingest_gaussian("A", rows, cols, seed)
    }
}

fn cmd_qr(args: &Args) -> Result<()> {
    let algo = parse_algo_choice(&args.get_or("algo", "auto"))?;
    let mut session = session_builder(args).build()?;
    let input = load_input(args, &mut session)?;
    let req = FactorizationRequest { algo, ..FactorizationRequest::qr() };

    let res = session.factorize(&input, &req)?;
    println!("backend        : {}", session.backend_desc());
    println!(
        "host threads   : {} configured, {} realized",
        session.host_threads(),
        res.stats.host_threads()
    );
    match &res.auto {
        Some(d) => println!(
            "algorithm      : {} (auto: kappa~{:.1e} vs threshold {:.0e})",
            res.algorithm.name(),
            d.kappa_estimate,
            d.threshold
        ),
        None => println!("algorithm      : {}", res.algorithm.name()),
    }
    println!("matrix         : {} x {}", commas(input.rows as u64), input.cols);
    println!("virtual time   : {:.1} s", res.stats.virtual_secs());
    println!("wall time      : {:.3} s", res.stats.wall_secs());
    println!("steps          : {}", res.stats.steps.len());
    let io = res.stats.total_io();
    println!("bytes read     : {}", commas(io.bytes_read));
    println!("bytes written  : {}", commas(io.bytes_written));
    let a = session.get_matrix(&input)?;
    if let Some(qh) = &res.q {
        let q = session.get_matrix(qh)?;
        let recon = a.sub(&q.matmul(&res.r)).frob_norm() / a.frob_norm();
        println!("|A-QR|/|A|     : {}", sci(recon));
        println!("|QtQ-I|_2      : {}", sci(q.orthogonality_error()));
    } else {
        println!("(R-only algorithm — no Q factor)");
    }
    Ok(())
}

fn cmd_svd(args: &Args) -> Result<()> {
    let mut session = session_builder(args).build()?;
    let input = load_input(args, &mut session)?;
    let out = session.svd(&input)?;
    let sigma = out.sigma().expect("svd parts");
    println!("TSVD via Direct TSQR — {} x {}", commas(input.rows as u64), input.cols);
    println!("virtual time : {:.1} s", out.stats.virtual_secs());
    println!("sigma        : {:?}", &sigma[..sigma.len().min(8)]);
    Ok(())
}

fn cmd_sigma(args: &Args) -> Result<()> {
    let mut session = session_builder(args).build()?;
    let input = load_input(args, &mut session)?;
    let out = session.singular_values(&input)?;
    let sigma = out.sigma().expect("sigma");
    println!("singular values via {} — {} x {}", out.algorithm.name(),
        commas(input.rows as u64), input.cols);
    println!("virtual time : {:.1} s", out.stats.virtual_secs());
    println!("sigma        : {:?}", &sigma[..sigma.len().min(8)]);
    Ok(())
}

/// `--sketch gauss|countsketch` + `--sketch-seed N` — the sketching
/// operator the randomized family draws. The seed is digest-relevant
/// (like the ingestion seed); every scheduling knob still is not.
fn sketch_options(args: &Args) -> Result<SketchOptions> {
    let kind = match args.get("sketch") {
        Some(name) => SketchKind::parse(name)?,
        None => SketchKind::Gaussian,
    };
    Ok(SketchOptions { kind, seed: args.get_u64("sketch-seed", DEFAULT_SKETCH_SEED) })
}

/// Randomized low-rank SVD (`A ≈ Û Σ̂ V̂ᵀ`, rank `k`): the PR 10
/// sketching family as a CLI surface. `--algo auto` gates sketch vs
/// exact truncation on rank-vs-cols; `--algo randomized` / `--algo
/// direct` force a side. Prints the same `result_digest` line the
/// batch/stream reports carry so CI can diff runs across scheduling
/// knobs.
fn cmd_lowrank(args: &Args) -> Result<()> {
    let rank = args.get_usize("rank", 4);
    let algo = parse_algo_choice(&args.get_or("algo", "auto"))?;
    let mut session = session_builder(args).build()?;
    let input = load_input(args, &mut session)?;
    let req = FactorizationRequest::low_rank(rank)
        .oversample(args.get_usize("oversample", DEFAULT_OVERSAMPLE))
        .power_iters(args.get_usize("power-iters", 0))
        .with_sketch(sketch_options(args)?);
    let req = match algo {
        AlgoChoice::Auto => req.auto(),
        AlgoChoice::Fixed(a) => req.with_algorithm(a),
    };
    let res = session.factorize(&input, &req)?;

    println!("low-rank       : {} x {} -> rank {}", commas(input.rows as u64), input.cols, rank);
    match &res.auto {
        Some(d) => println!("algorithm      : {} ({})", res.algorithm.name(), d.step_stats().name),
        None => println!("algorithm      : {}", res.algorithm.name()),
    }
    let sigma = res.sigma().expect("low-rank sigma");
    println!("sigma_hat      : {:?}", &sigma[..sigma.len().min(8)]);
    println!("virtual time   : {:.1} s", res.stats.virtual_secs());
    println!("steps          : {}", res.stats.steps.len());
    if args.flag("check") {
        // |A - U Σ Vᵀ| / |A| — materializes A and Û, so keep it to
        // demo-sized runs
        let a = session.get_matrix(&input)?;
        let u = session.get_matrix(res.q.as_ref().expect("low-rank U"))?;
        let svd = res.svd.as_ref().expect("low-rank parts");
        let scaled = Matrix::from_fn(u.rows, sigma.len(), |i, j| u[(i, j)] * sigma[j]);
        let recon = scaled.matmul(&svd.v.transpose());
        println!("|A-USV'|/|A|   : {}", sci(a.sub(&recon).frob_norm() / a.frob_norm()));
    }
    println!("result_digest  : {}", res.result_digest());
    Ok(())
}

/// Least squares `min |Ax - b|` over the augmented input `[A b]`
/// (`--cols` counts A's columns; `--rhs` b's). `--algo auto` probes κ
/// and solves from the probe when benign, else sketch-and-precondition;
/// `--algo randomized` forces the sketched path.
fn cmd_solve(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 100_000);
    let cols = args.get_usize("cols", 10);
    let rhs = args.get_usize("rhs", 1);
    let seed = args.get_u64("seed", 42);
    let algo = parse_algo_choice(&args.get_or("algo", "auto"))?;
    let mut session = session_builder(args).build()?;
    let input = session.ingest_gaussian("Ab", rows, cols + rhs, seed)?;
    let req = FactorizationRequest::solve().rhs_cols(rhs).with_sketch(sketch_options(args)?);
    let req = match algo {
        AlgoChoice::Auto => req.auto(),
        AlgoChoice::Fixed(a) => req.with_algorithm(a),
    };
    let res = session.factorize(&input, &req)?;

    let x = res.solution.as_ref().expect("solve solution");
    println!("least squares  : {} x {} A, {} rhs column(s)", commas(rows as u64), cols, rhs);
    match &res.auto {
        Some(d) => println!("algorithm      : {} ({})", res.algorithm.name(), d.step_stats().name),
        None => println!("algorithm      : {}", res.algorithm.name()),
    }
    println!("virtual time   : {:.1} s", res.stats.virtual_secs());
    // the relative residual |Ax-b|/|b| is cheap next to the solve
    // itself: one m*n*rhs matmul on the materialized input
    let ab = session.get_matrix(&input)?;
    let a = Matrix::from_fn(rows, cols, |i, j| ab[(i, j)]);
    let b = Matrix::from_fn(rows, rhs, |i, j| ab[(i, cols + j)]);
    let resid = a.matmul(x).sub(&b);
    println!("|Ax-b|/|b|     : {}", sci(resid.frob_norm() / b.frob_norm()));
    println!("result_digest  : {}", res.result_digest());
    Ok(())
}

/// Run a manifest of factorization requests concurrently through one
/// [`mrtsqr::client::TsqrClient`], printing per-job stats plus
/// aggregate throughput. `--jobs N` sets the per-shard worker count
/// (default 4), `--shards N` the engine-shard pool size (default 1),
/// `--worker-procs N` moves the whole pool into `N` spawned
/// `mrtsqr worker` processes (each running `--shards` shards; 0 =
/// in-process, the default), `--serial` drains the queue on one thread
/// instead (the baseline the aggregate numbers are compared against;
/// in-process only), `--json PATH` additionally writes the report as
/// JSON — including a per-job `result_digest` of the exact R/Σ bits,
/// so two reports taken at different `--shards`/`--worker-procs`
/// values can be diffed for the placement-determinism invariant with a
/// one-line `grep | diff`.
fn cmd_batch(args: &Args) -> Result<()> {
    let manifest_path = args
        .get("manifest")
        .map(str::to_string)
        .or_else(|| args.positional.first().cloned())
        .context("batch wants a manifest: mrtsqr batch --manifest jobs.txt")?;
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading manifest {manifest_path:?}"))?;
    let manifest = parse_manifest_full(&text)?;
    let entries = manifest.entries;
    let sched = scheduler_config(args, manifest.scheduler)?;
    let serial = args.flag("serial");
    let procs = args.get_usize("worker-procs", 0);
    let connect = connect_addrs(args);
    if serial && procs > 0 {
        anyhow::bail!("--serial drains on the calling thread, which cannot reach into worker \
                       processes — drop --serial or --worker-procs");
    }
    if !connect.is_empty() && (serial || procs > 0) {
        anyhow::bail!("--connect drives remote servers — drop --serial / --worker-procs \
                       (the servers' own topology applies)");
    }
    let workers = if serial { 0 } else { args.get_usize("jobs", 4).max(1) };
    let shards = args.get_usize("shards", 1).max(1);

    // serial mode has no workers draining during submission, so the
    // queue must hold the whole manifest or submit() would block forever
    let queue = args.get_usize("queue", 64).max(if serial { entries.len() } else { 1 });
    let client = session_builder(args)
        .service_workers(workers)
        .queue_capacity(queue)
        .engine_shards(shards)
        .worker_processes(procs)
        .connect(&connect)
        .scheduler(sched)
        .build_client()?;
    println!(
        "service        : backend={} procs={} shards={} (total) workers={} (total) queue-capacity={}/shard",
        client.backend_desc(),
        client.procs(),
        client.shards(),
        client.workers(),
        client.capacity()
    );

    // stage every input first, then submit the whole manifest: the
    // queue drains while later jobs are still being submitted
    let inputs: Vec<MatrixHandle> = entries
        .iter()
        .map(|e| client.ingest_gaussian(&e.name, e.rows, e.cols, e.seed))
        .collect::<Result<_>>()?;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = entries
        .iter()
        .zip(&inputs)
        .map(|(e, h)| client.submit(h, e.request()))
        .collect::<Result<_>>()?;
    if serial {
        client.drain_now()?;
    }

    let mut table = Table::new(
        "Batch report (wall = running->done, queue wait excluded; shard * = stolen)",
        &["job", "label", "request", "priority", "shard", "status", "virtual (s)", "wall (s)"],
    );
    let mut job_rows = Vec::new();
    let (mut sum_wall, mut sum_virtual, mut failed) = (0.0f64, 0.0f64, 0usize);
    // per-(global-)shard aggregates: jobs served and summed job wall
    let mut shard_jobs = vec![0usize; client.shards()];
    let mut shard_wall = vec![0.0f64; client.shards()];
    for (entry, handle) in entries.iter().zip(&handles) {
        let (status, virt, digest, shard, stolen) = match handle.wait() {
            Ok(fact) => (
                format!("done ({})", fact.algorithm.cli_name()),
                fact.stats.virtual_secs(),
                Some(fact.result_digest()),
                Some(fact.stats.shard),
                fact.stats.stolen,
            ),
            Err(err) => {
                failed += 1;
                // a cross-process job that died with its worker has no
                // known shard — report it honestly instead of booking
                // it under shard 0
                (format!("FAILED: {err:#}"), 0.0, None, client.shard_of(handle.id()), false)
            }
        };
        // failed-while-running jobs report their measured wall too;
        // only cancelled/never-ran jobs fall back to 0
        let wall = handle.wall_secs().unwrap_or(0.0);
        sum_wall += wall;
        sum_virtual += virt;
        if let Some(shard) = shard {
            shard_jobs[shard] += 1;
            shard_wall[shard] += wall;
        }
        table.row(&[
            handle.id().to_string(),
            entry.name.clone(),
            entry.describe(),
            entry.priority.name().into(),
            shard.map_or_else(
                || "?".into(),
                |s| if stolen { format!("{s}*") } else { s.to_string() },
            ),
            status.clone(),
            format!("{virt:.1}"),
            format!("{wall:.3}"),
        ]);
        job_rows.push(Json::obj([
            ("id", Json::num(handle.id().0 as f64)),
            ("label", Json::str(&entry.name)),
            ("request", Json::str(entry.describe())),
            ("priority", Json::str(entry.priority.name())),
            (
                "shard",
                match shard {
                    Some(s) => Json::num(s as f64),
                    None => Json::Null,
                },
            ),
            ("status", Json::str(status)),
            ("stolen", Json::Bool(stolen)),
            ("virtual_secs", Json::num(virt)),
            ("wall_secs", Json::num(wall)),
            (
                "result_digest",
                match digest {
                    Some(d) => Json::str(d),
                    None => Json::Null,
                },
            ),
        ]));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    table.print();

    let jobs = handles.len();
    println!("jobs           : {jobs} submitted, {failed} failed");
    println!("sum job wall   : {sum_wall:.3} s");
    println!("aggregate wall : {elapsed:.3} s (submit -> all done)");
    if sum_wall > 0.0 {
        println!(
            "overlap        : {:.2}x (sum of per-job walls / aggregate wall{})",
            sum_wall / elapsed,
            if workers > 1 { "; >1 means jobs genuinely ran concurrently" } else { "" }
        );
    }
    println!("throughput     : {:.2} jobs/s", jobs as f64 / elapsed.max(1e-9));
    println!("virtual total  : {sum_virtual:.1} s");
    // elastic-scheduling tallies (all zero with the default config)
    let tally = client.sched_tally().unwrap_or_default();
    if client.shards() > 1 {
        for (k, (n, w)) in shard_jobs.iter().zip(&shard_wall).enumerate() {
            let steals = tally.per_shard_steals.get(k).copied().unwrap_or(0);
            println!("shard {k:<8} : {n} jobs, {w:.3} s summed wall, {steals} stolen");
        }
    }
    for (label, held) in &tally.admission_held {
        println!("admission      : label {label:?} held {held} submission(s) at quota");
    }

    if let Some(path) = args.get("json") {
        let shard_rows: Vec<Json> = shard_jobs
            .iter()
            .zip(&shard_wall)
            .enumerate()
            .map(|(k, (n, w))| {
                Json::obj([
                    ("shard", Json::num(k as f64)),
                    ("jobs", Json::num(*n as f64)),
                    ("sum_job_wall_secs", Json::num(*w)),
                    ("steals", Json::num(tally.per_shard_steals.get(k).copied().unwrap_or(0) as f64)),
                ])
            })
            .collect();
        let admission_rows: Vec<Json> = tally
            .admission_held
            .iter()
            .map(|(label, held)| {
                Json::obj([("label", Json::str(label)), ("held", Json::num(*held as f64))])
            })
            .collect();
        let report = Json::obj([
            ("manifest", Json::str(&manifest_path)),
            ("workers", Json::num(workers as f64)),
            ("procs", Json::num(client.procs() as f64)),
            ("shards", Json::num(client.shards() as f64)),
            ("host_threads", Json::num(client.host_threads() as f64)),
            ("jobs", Json::num(jobs as f64)),
            ("failed", Json::num(failed as f64)),
            ("sum_job_wall_secs", Json::num(sum_wall)),
            ("aggregate_wall_secs", Json::num(elapsed)),
            ("throughput_jobs_per_sec", Json::num(jobs as f64 / elapsed.max(1e-9))),
            ("virtual_secs_total", Json::num(sum_virtual)),
            ("steal", Json::Bool(sched.steal)),
            ("per_shard", Json::Arr(shard_rows)),
            ("admission_held", Json::Arr(admission_rows)),
            ("per_job", Json::Arr(job_rows)),
        ]);
        std::fs::write(path, report.render() + "\n")
            .with_context(|| format!("writing {path:?}"))?;
        println!("json report    : {path}");
    }
    // a failed job is a failed batch: CI smoke must go red, not just
    // print FAILED rows
    if failed > 0 {
        anyhow::bail!("{failed} of {jobs} batch jobs failed");
    }
    Ok(())
}

/// Single-pass streaming factorization over a synthetic row stream:
/// `--rows N` seeded gaussian rows arrive in `--chunk-rows C` arrival
/// chunks (0 = one single push) and fold into a running `R`
/// ([`mrtsqr::stream::RFold`]) without the input ever being
/// materialized — the peak-resident line next to the row count shows
/// the `O(n²)` bound. `--stream-chunk-rows L` sets the canonical fold
/// leaf height (this shapes the fold tree, so it is part of the
/// streamed digest contract — unlike the arrival chunking, which never
/// changes bits); `--sigma`
/// adds singular values; `--q` re-forms the full `Q` from the spilled
/// leaf recipes (a second pass over the spill, never over the input).
/// The `result_digest` line is the same FNV-1a digest `batch --json`
/// emits, so CI diffs streamed runs at different arrival chunkings /
/// `--host-threads` values against each other with one
/// `grep result_digest | diff`.
fn cmd_stream(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 100_000);
    let cols = args.get_usize("cols", 10);
    let seed = args.get_u64("seed", 42);
    let arrival = args.get_usize("chunk-rows", 1000);
    let want_sigma = args.flag("sigma");
    let want_q = args.flag("q");
    let mut session = session_builder(args)
        .stream_chunk_rows(args.get_usize("stream-chunk-rows", 1000))
        .build()?;

    let t0 = std::time::Instant::now();
    let mut w = session.stream("S", cols);
    if want_q {
        w = w.retain_q()?;
    }
    // one shared rng: the row sequence depends only on the seed, so any
    // --chunk-rows slicing of it feeds the fold the exact same rows
    let mut rng = Rng::new(seed);
    let mut remaining = rows;
    while remaining > 0 {
        let take = if arrival == 0 { remaining } else { arrival.min(remaining) };
        let chunk = Matrix::gaussian(take, cols, &mut rng);
        w.push_chunk(&chunk)?;
        remaining -= take;
    }
    let (r, sigma, stats, q_err) = if want_q {
        let (qh, r, stats) = w.finalize_qr()?;
        let q = session.get_matrix(&qh)?;
        let sigma = want_sigma.then(|| mrtsqr::stream::sigma_from_r(&r));
        (r, sigma, stats, Some(q.orthogonality_error()))
    } else if want_sigma {
        let (r, sigma, stats) = w.finalize_sigma()?;
        (r, Some(sigma), stats, None)
    } else {
        let (r, stats) = w.finalize_r()?;
        (r, None, stats, None)
    };
    let wall = t0.elapsed().as_secs_f64();

    println!("stream         : {} x {} gaussian rows (seed {})", commas(rows as u64), cols, seed);
    println!(
        "arrival chunks : {}",
        if arrival == 0 { "one-shot".to_string() } else { format!("{arrival} rows") }
    );
    println!(
        "fold           : {} rows/leaf, {} leaves, {} reductions, depth {}",
        stats.chunk_rows, stats.leaves, stats.folds, stats.max_depth
    );
    println!("input passes   : {}", stats.input_passes());
    println!(
        "peak resident  : {} rows (vs {} streamed)",
        commas(stats.peak_resident_rows as u64),
        commas(stats.rows)
    );
    println!("wall time      : {wall:.3} s");
    if let Some(err) = q_err {
        println!("|QtQ-I|_2      : {}", sci(err));
    }
    if let Some(s) = &sigma {
        println!("sigma          : {:?}", &s[..s.len().min(8)]);
    }
    println!("result_digest  : {}", mrtsqr::stream::result_digest(&r, sigma.as_deref()));
    Ok(())
}

fn cmd_stability(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 5000);
    let cols = args.get_usize("cols", 50);
    let backend = if args.flag("pjrt") { Backend::Pjrt } else { Backend::Auto };
    let (compute, _) = backend.resolve()?;
    let mut table = Table::new(
        "Fig. 6 — |QtQ-I|_2 vs condition number",
        &["kappa", "Chol", "Chol+IR", "Indirect", "Indirect+IR", "Direct"],
    );
    for exp in (1..=16).step_by(3) {
        let kappa = 10f64.powi(exp);
        let mut row = vec![format!("1e{exp:02}")];
        for algo in [
            Algorithm::Cholesky { refine: false },
            Algorithm::Cholesky { refine: true },
            Algorithm::IndirectTsqr { refine: false },
            Algorithm::IndirectTsqr { refine: true },
            Algorithm::DirectTsqr,
        ] {
            let mut session = session_builder(args).compute(compute.clone()).build()?;
            let mut rng = Rng::new(7);
            let a = matrix_with_condition(rows, cols, kappa, &mut rng);
            let input = session.ingest_matrix("A", &a)?;
            let cell = match session.qr_with(&input, algo) {
                Ok(res) => {
                    let q = session.get_matrix(&res.q.unwrap())?;
                    sci(q.orthogonality_error())
                }
                Err(_) => "breakdown".to_string(),
            };
            row.push(cell);
        }
        table.row(&row);
    }
    table.print();
    Ok(())
}

fn cmd_faults(args: &Args) -> Result<()> {
    let prob = args.get_f64("prob", 0.125);
    let mut session = session_builder(args)
        .fault_policy(FaultPolicy::new(prob), args.get_u64("seed", 99))
        .build()?;
    let input = load_input(args, &mut session)?;
    let res = session.qr_with(&input, Algorithm::DirectTsqr)?;
    println!("fault probability : {prob}");
    println!("faults injected   : {}", res.stats.total_faults());
    println!("virtual time      : {:.1} s", res.stats.virtual_secs());
    Ok(())
}

fn cmd_model(args: &Args) -> Result<()> {
    let beta_r = args.get_f64("beta-r", 64.0) * 1e-9;
    let beta_w = args.get_f64("beta-w", 126.0) * 1e-9;
    let par = StageParallelism::default();
    let mut table = Table::new(
        "Table V — computed lower bounds T_lb (secs)",
        &["Rows", "Cols", "Cholesky", "Indirect", "Chol+IR", "Ind+IR", "Direct", "House."],
    );
    for &(m, n) in &[
        (4_000_000_000u64, 4u64),
        (2_500_000_000, 10),
        (600_000_000, 25),
        (500_000_000, 50),
        (150_000_000, 100),
    ] {
        let (m1, m1d) = StageParallelism::paper_m1(m, n).unwrap();
        let mut row = vec![commas(m), n.to_string()];
        for kind in AlgoKind::ALL {
            let m1_used = if kind == AlgoKind::DirectTsqr { m1d } else { m1 };
            let shape = WorkloadShape::new(m, n, m1_used);
            let t = lower_bound_secs(kind, &shape, &par, beta_r, beta_w);
            row.push(format!("{:.0}", t));
        }
        table.row(&row);
    }
    table.print();
    Ok(())
}

/// Serve the binary wire protocol over a client built from the CLI
/// flags: `--shards N` engine shards, `--jobs N` workers per shard,
/// `--queue N` capacity, and `--worker-procs N` to relay the whole
/// pool into spawned `mrtsqr worker` processes. Default transport is
/// stdin/stdout (any program able to frame bytes on a pipe gets a full
/// factorization service without linking the crate);
/// `--listen <addr>` serves TCP connections instead — remote
/// `TsqrClient`s reach it through `SessionBuilder::connect(addrs)`.
fn cmd_serve(args: &Args) -> Result<()> {
    let client = session_builder(args)
        .service_workers(args.get_usize("jobs", 2).max(1))
        .queue_capacity(args.get_usize("queue", 64))
        .engine_shards(args.get_usize("shards", 1))
        .worker_processes(args.get_usize("worker-procs", 0))
        .scheduler(scheduler_config(args, None)?)
        .build_client()?;
    if let Some(addr) = args.get("listen") {
        let topology = format!(
            "procs={} shards={} workers={}",
            client.procs(),
            client.shards(),
            client.workers()
        );
        let server = mrtsqr::client::TcpServer::bind(client, addr)?;
        eprintln!(
            "mrtsqr serve: protocol v{} listening on {}, {topology}",
            mrtsqr::client::WIRE_VERSION,
            server.local_addr()
        );
        // serve until killed: connections come and go, the engine pool
        // and the retained job registry stay
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    eprintln!(
        "mrtsqr serve: protocol v{} on stdio, procs={} shards={} workers={}",
        mrtsqr::client::WIRE_VERSION,
        client.procs(),
        client.shards(),
        client.workers()
    );
    mrtsqr::client::worker::run_serve(client)
}

/// Hammer a factorization service with a synthetic stream of
/// concurrent mixed jobs and report throughput plus latency
/// percentiles. `--connect host:port[,…]` drives remote
/// `mrtsqr serve --listen` hosts (the usual mode); without it the
/// load runs against an in-process pool built from the same flags as
/// `batch`. `--jobs-total N` jobs (default 1000) are drawn from the
/// deterministic 8-way request mix over `--inputs K` gaussian matrices
/// (ingested once, reused round-robin), submitted by `--concurrency C`
/// closed-loop threads (each submits, waits, evicts, repeats — so at
/// most `C` jobs are in flight). `--bench-json PATH` writes the
/// summary for the BENCH_6 trajectory.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use mrtsqr::service::synthetic_manifest;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    let connect = connect_addrs(args);
    let total = args.get_usize("jobs-total", 1000).max(1);
    let concurrency = args.get_usize("concurrency", 8).max(1);
    let inputs = args.get_usize("inputs", 6).max(1);
    let rows = args.get_usize("rows", 2000);
    let cols = args.get_usize("cols", 6);
    let seed = args.get_u64("seed", 42);

    let sched = scheduler_config(args, None)?;
    let client = Arc::new(
        session_builder(args)
            .service_workers(args.get_usize("jobs", 4).max(1))
            .queue_capacity(args.get_usize("queue", 64))
            .engine_shards(args.get_usize("shards", 1))
            .connect(&connect)
            .scheduler(sched)
            .build_client()?,
    );
    println!(
        "loadgen        : {} jobs, {} closed-loop submitters, {} inputs, target = {} \
         (backend={} hosts={} shards={})",
        total,
        concurrency,
        inputs,
        if connect.is_empty() { "in-process".to_string() } else { connect.join(",") },
        client.backend_desc(),
        client.procs(),
        client.shards(),
    );

    let entries = synthetic_manifest(total, inputs, rows, cols, seed);
    // ingest each distinct input once, up front (entries sharing a
    // name share rows/cols/seed by construction)
    let mut handles = std::collections::HashMap::new();
    for e in &entries {
        if !handles.contains_key(&e.name) {
            handles.insert(e.name.clone(), client.ingest_gaussian(&e.name, e.rows, e.cols, e.seed)?);
        }
    }
    let handles = Arc::new(handles);
    let entries = Arc::new(entries);

    let next = Arc::new(AtomicUsize::new(0));
    let failures: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = std::time::Instant::now();
    let submitters: Vec<_> = (0..concurrency)
        .map(|_| {
            let (client, entries, handles) = (client.clone(), entries.clone(), handles.clone());
            let (next, failures) = (next.clone(), failures.clone());
            std::thread::spawn(move || {
                // per-thread latency samples, merged after the join
                let mut latencies = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= entries.len() {
                        return latencies;
                    }
                    let entry = &entries[i];
                    let input = &handles[&entry.name];
                    let started = std::time::Instant::now();
                    let outcome = client
                        .submit(input, entry.request())
                        .and_then(|job| job.wait().map(|_| job.id()));
                    match outcome {
                        Ok(id) => {
                            latencies.push(started.elapsed().as_secs_f64());
                            // keep the DFS bounded across thousands of jobs
                            let _ = client.evict_job(id);
                        }
                        Err(err) => {
                            failures.lock().expect("failure log").push(format!("{err:#}"));
                        }
                    }
                }
            })
        })
        .collect();
    let mut latencies: Vec<f64> = Vec::with_capacity(total);
    for submitter in submitters {
        latencies.extend(submitter.join().expect("submitter thread"));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let failed = failures.lock().expect("failure log").len();

    latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let idx = ((latencies.len() as f64 * p).ceil() as usize).clamp(1, latencies.len());
        latencies[idx - 1]
    };
    let mean = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().sum::<f64>() / latencies.len() as f64
    };
    let (p50, p95, p99) = (pct(0.50), pct(0.95), pct(0.99));
    let max = latencies.last().copied().unwrap_or(0.0);
    let throughput = latencies.len() as f64 / elapsed.max(1e-9);

    println!("completed      : {} ok, {failed} failed in {elapsed:.3} s", latencies.len());
    println!("throughput     : {throughput:.2} jobs/s");
    println!(
        "latency        : p50 {:.1} ms, p95 {:.1} ms, p99 {:.1} ms, mean {:.1} ms, max {:.1} ms",
        p50 * 1e3,
        p95 * 1e3,
        p99 * 1e3,
        mean * 1e3,
        max * 1e3
    );
    if failed > 0 {
        let log = failures.lock().expect("failure log");
        for msg in log.iter().take(3) {
            eprintln!("loadgen failure: {msg}");
        }
    }
    // elastic-scheduling tallies (all zero with the default config)
    let tally = client.sched_tally().unwrap_or_default();
    let total_steals: u64 = tally.per_shard_steals.iter().sum();
    if sched.steal || total_steals > 0 {
        println!("steals         : {total_steals} across {} shard(s)", client.shards());
    }
    for (label, held) in &tally.admission_held {
        println!("admission      : label {label:?} held {held} submission(s) at quota");
    }

    if let Some(path) = args.get("bench-json") {
        let steal_rows: Vec<Json> = tally
            .per_shard_steals
            .iter()
            .map(|n| Json::num(*n as f64))
            .collect();
        let admission_rows: Vec<Json> = tally
            .admission_held
            .iter()
            .map(|(label, held)| {
                Json::obj([("label", Json::str(label)), ("held", Json::num(*held as f64))])
            })
            .collect();
        let report = Json::obj([
            ("jobs", Json::num(total as f64)),
            ("concurrency", Json::num(concurrency as f64)),
            ("hosts", Json::num(client.procs() as f64)),
            ("shards", Json::num(client.shards() as f64)),
            ("elapsed_secs", Json::num(elapsed)),
            ("throughput_jobs_per_sec", Json::num(throughput)),
            ("per_shard_steals", Json::Arr(steal_rows)),
            ("admission_held", Json::Arr(admission_rows)),
            (
                "latency",
                Json::obj([
                    ("p50_ms", Json::num(p50 * 1e3)),
                    ("p95_ms", Json::num(p95 * 1e3)),
                    ("p99_ms", Json::num(p99 * 1e3)),
                    ("mean_ms", Json::num(mean * 1e3)),
                    ("max_ms", Json::num(max * 1e3)),
                ]),
            ),
            ("failed", Json::num(failed as f64)),
        ]);
        std::fs::write(path, report.render() + "\n")
            .with_context(|| format!("writing {path:?}"))?;
        println!("bench json     : {path}");
    }
    if failed > 0 {
        anyhow::bail!("{failed} of {total} loadgen jobs failed");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)?;
    println!("artifacts dir : {}", dir.display());
    println!("modules       : {}", manifest.entries.len());
    let mut table = Table::new("AOT artifact manifest", &["op", "block rows", "cols", "file"]);
    for e in &manifest.entries {
        table.row(&[e.op.name().into(), e.b.to_string(), e.n.to_string(), e.file.clone()]);
    }
    table.print();
    Ok(())
}

const USAGE: &str = "usage: mrtsqr <qr|svd|sigma|lowrank|solve|batch|stream|serve|loadgen|worker|stability|faults|model|info> [options]
  common options: --rows N --cols N --seed N --pjrt
                  --algo <auto|cholesky|cholesky-ir|indirect|indirect-ir|direct|direct-fused|householder>
                  --beta-r s/GB --beta-w s/GB --byte-scale X
                  --host-threads N   (worker threads for task bodies; results identical for any N)
                  --panel-block N    (blocked-QR panel width; pure speed knob, results identical)
                  --mixed-precision  (let Auto take the kappa-gated f32 step-1 path; changes bits)
                  --fault-prob P --fault-attempts N --fault-waste F --fault-seed N  (fault injection)
                  --request-timeout SECS   (per-request deadline on the Process/Tcp transports)
  lowrank options: --rank K --oversample P --power-iters Q [--check]
                  --sketch <gauss|countsketch> --sketch-seed N   (digest-relevant, like --seed)
                  --algo <auto|randomized|direct>   (auto gates sketch-vs-exact on rank vs cols)
  solve options:  --rhs K --sketch <gauss|countsketch> --sketch-seed N --algo <auto|randomized|...>
                  (--cols counts A's columns, --rhs b's; input is the augmented [A b])
  batch options:  --manifest FILE --jobs N --shards N --worker-procs N --queue N [--serial] [--json PATH]
                  --connect host:port[,host:port...]   (drive remote `serve --listen` hosts instead)
                  (manifest lines: name rows cols seed <qr|r|svd|sigma|lowrank:<rank>|solve[:<rhs>]> <algo>
                   [low|normal|high] [@shard] [+nosteal] [+exempt]; sketching wants take :p<n>/:q<n>/:s<seed>/
                   :gauss/:countsketch knobs; `%scheduler key=value...` lines configure the pool)
  scheduling:     --steal --locality --quota-per-label N --autoscale MIN:MAX --autoscale-interval-ms N
                  (batch/serve/loadgen; pure placement — result digests identical at any setting)
  stream options: --rows N --cols N --seed N [--sigma] [--q]
                  --chunk-rows N          (arrival granularity; 0 = one-shot; never changes bits)
                  --stream-chunk-rows N   (fold leaf height; shapes the fold tree, part of the digest)
  serve options:  --jobs N --shards N --worker-procs N --queue N
                  default: wire protocol on stdin/stdout; --listen host:port serves TCP instead
  loadgen options: --connect host:port[,...] --jobs-total N --concurrency N --inputs K
                  --rows N --cols N --seed N [--bench-json PATH]
                  (without --connect: in-process pool from --jobs/--shards, like batch)
  worker:         no options — spawned by the Process transport; config arrives in the Hello handshake
  see README.md for the full list";

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("qr") => cmd_qr(&args),
        Some("svd") => cmd_svd(&args),
        Some("sigma") => cmd_sigma(&args),
        Some("lowrank") => cmd_lowrank(&args),
        Some("solve") => cmd_solve(&args),
        Some("batch") => cmd_batch(&args),
        Some("stream") => cmd_stream(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("worker") => mrtsqr::client::worker::run_worker(),
        Some("stability") => cmd_stability(&args),
        Some("faults") => cmd_faults(&args),
        Some("model") => cmd_model(&args),
        Some("info") => cmd_info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}
