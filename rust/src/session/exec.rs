//! The one request-execution path.
//!
//! Both front doors run every [`FactorizationRequest`] through
//! [`execute`]: a [`crate::session::TsqrSession`] calls it inline on its
//! privately-owned engine (factorize ≡ submit + wait with nothing
//! queued), and a [`crate::service::TsqrService`] worker calls it with a
//! cluster-shared, per-job-namespaced [`Coordinator`]. Keeping the
//! want/algo dispatch here means the service cannot drift from the
//! session: same probe, same auto decision, same pipelines, same stats.

use super::request::{AlgoChoice, FactorizationRequest, Want};
use super::select::{estimate_condition, AutoDecision, SketchChoice};
use super::Factorization;
use crate::coordinator::direct_tsqr::SvdParts;
use crate::coordinator::{ar_inv, cholesky_qr, householder, indirect_tsqr, RFactorMethod};
use crate::coordinator::{Algorithm, Coordinator, MatrixHandle};
use crate::linalg::{jacobi_svd, Matrix};
use crate::mapreduce::JobStats;
use crate::sketch::{rand_svd, solve as sketch_solve};
use anyhow::{bail, Result};

/// Run one factorization request against a coordinator (owned or
/// cluster-shared engine — the coordinator hides the difference).
pub(crate) fn execute(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    req: &FactorizationRequest,
) -> Result<Factorization> {
    match req.algo {
        AlgoChoice::Fixed(algo) => run_fixed(coord, input, req, algo, None),
        AlgoChoice::Auto => run_auto(coord, input, req),
    }
}

fn run_auto(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    req: &FactorizationRequest,
) -> Result<Factorization> {
    // wants with a single serving algorithm resolve without a probe
    match req.want {
        Want::Svd => return run_fixed(coord, input, req, Algorithm::DirectTsqr, None),
        Want::SingularValues => {
            // "it would be favorable to use the TSQR implementation
            // from Sec. II-B to compute R" (paper §III-B)
            return run_fixed(
                coord,
                input,
                req,
                Algorithm::IndirectTsqr { refine: false },
                None,
            );
        }
        Want::LowRank { rank, oversample, .. } => {
            return auto_low_rank(coord, input, req, rank, oversample);
        }
        Want::Solve { rhs } => return auto_solve(coord, input, req, rhs),
        Want::Qr | Want::ROnly => {}
    }

    // one-pass probe: Indirect-TSQR R + serial Jacobi κ estimate
    let (probe_r, mut stats) = indirect_tsqr::indirect_r(coord, input)?;

    if req.want == Want::ROnly {
        // the probe's R is already backward stable — no second pass
        // needed whichever way the estimate leans, so the recorded
        // decision is the algorithm that actually served the request
        let decision = AutoDecision {
            kappa_estimate: estimate_condition(&probe_r),
            threshold: req.condition_threshold,
            chosen: Algorithm::IndirectTsqr { refine: false },
            probe_reused: true,
            mixed_precision: false,
            sketch: None,
        };
        stats.push(decision.step_stats());
        return Ok(Factorization {
            q: None,
            r: probe_r,
            svd: None,
            solution: None,
            algorithm: decision.chosen,
            auto: Some(decision),
            stats,
        });
    }

    let mut decision = AutoDecision::from_probe(&probe_r, req.condition_threshold, req.refine);
    // Mixed-precision step 1 is an explicit session opt-in and only
    // engages when the probe shows the f32 mantissa plus one f64
    // refinement sweep can still deliver full accuracy (κ within
    // MIXED_KAPPA_MAX). The well-conditioned branch reuses the probe's
    // f64 R as-is, so only the Direct-TSQR rerun is eligible.
    if !decision.probe_reused
        && coord.opts.mixed_precision
        && decision.kappa_estimate.is_finite()
        && decision.kappa_estimate <= crate::linalg::MIXED_KAPPA_MAX
    {
        decision.mixed_precision = true;
    }
    stats.push(decision.step_stats());

    if decision.probe_reused {
        // Well-conditioned branch: finish the probe's Indirect-TSQR R
        // into Q = A·R⁻¹ instead of re-running a factorization from
        // scratch — 2 passes over A instead of 3, and the indirect Q
        // loses κ·ε instead of Cholesky QR's κ²·ε. An optional
        // refinement sweep still applies on top (req.refine).
        let (q, r, st) =
            ar_inv::q_via_rinv(coord, input, &probe_r, req.refine, RFactorMethod::IndirectTsqr)?;
        stats.extend(st);
        return Ok(Factorization {
            q: Some(q),
            r,
            svd: None,
            solution: None,
            algorithm: decision.chosen,
            auto: Some(decision),
            stats,
        });
    }

    // ill-conditioned: the unconditionally stable path
    coord.mixed_step1 = decision.mixed_precision;
    let out = run_fixed(coord, input, req, decision.chosen, Some((decision, stats)));
    coord.mixed_step1 = false;
    out
}

/// `Auto` for `Want::LowRank`: no probe — the sketch-vs-exact call is a
/// pure shape question ([`rand_svd::sketch_pays_off`]): below half the
/// columns the randomized path reads strictly fewer bytes; above it the
/// exact truncated Direct-TSQR SVD is both cheaper and exact.
fn auto_low_rank(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    req: &FactorizationRequest,
    rank: usize,
    oversample: usize,
) -> Result<Factorization> {
    let randomized = rand_svd::sketch_pays_off(input.cols, rank, oversample);
    let decision = AutoDecision {
        kappa_estimate: f64::NAN, // rank gate, not a κ probe
        threshold: req.condition_threshold,
        chosen: if randomized { Algorithm::Randomized } else { Algorithm::DirectTsqr },
        probe_reused: false,
        mixed_precision: false,
        sketch: randomized.then(|| SketchChoice::new(req.sketch, oversample)),
    };
    let mut stats = JobStats::default();
    stats.push(decision.step_stats());
    run_fixed(coord, input, req, decision.chosen, Some((decision, stats)))
}

/// `Auto` for `Want::Solve`: run the usual one-pass Indirect-TSQR probe
/// on the augmented `[A b]` and estimate κ₂(A) from the leading `n×n`
/// block of its `R`. Well-conditioned systems are *solved from the
/// probe itself* — back-substitution on `R_aug`, one pass over the
/// input, probe reused. Ill-conditioned systems go to
/// sketch-and-precondition, which is immune to κ(A) by construction.
fn auto_solve(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    req: &FactorizationRequest,
    rhs: usize,
) -> Result<Factorization> {
    let n = sketch_solve::split_cols(input.cols, rhs)?;
    let (probe_r, mut stats) = indirect_tsqr::indirect_r(coord, input)?;
    let r_a = Matrix::from_fn(n, n, |i, j| probe_r[(i, j)]);
    let kappa = estimate_condition(&r_a);

    if kappa.is_finite() && kappa <= req.condition_threshold {
        let decision = AutoDecision {
            kappa_estimate: kappa,
            threshold: req.condition_threshold,
            chosen: Algorithm::IndirectTsqr { refine: false },
            probe_reused: true,
            mixed_precision: false,
            sketch: None,
        };
        stats.push(decision.step_stats());
        let (x, r_a) = sketch_solve::solve_from_augmented_r(&probe_r, n, rhs)?;
        return Ok(Factorization {
            q: None,
            r: r_a,
            svd: None,
            solution: Some(x),
            algorithm: decision.chosen,
            auto: Some(decision),
            stats,
        });
    }

    let decision = AutoDecision {
        kappa_estimate: kappa,
        threshold: req.condition_threshold,
        chosen: Algorithm::Randomized,
        probe_reused: false,
        mixed_precision: false,
        sketch: Some(SketchChoice::new(req.sketch, 0)),
    };
    stats.push(decision.step_stats());
    run_fixed(coord, input, req, decision.chosen, Some((decision, stats)))
}

fn run_fixed(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    req: &FactorizationRequest,
    algo: Algorithm,
    auto: Option<(AutoDecision, JobStats)>,
) -> Result<Factorization> {
    let (auto, mut stats) = match auto {
        Some((d, s)) => (Some(d), s),
        None => (None, JobStats::default()),
    };
    match req.want {
        Want::Qr => {
            let res = coord.qr(input, algo)?;
            stats.extend(res.stats);
            Ok(Factorization {
                q: res.q,
                r: res.r,
                svd: None,
                solution: None,
                algorithm: algo,
                auto,
                stats,
            })
        }
        Want::ROnly => {
            let (r, st) = r_only(coord, input, algo)?;
            stats.extend(st);
            Ok(Factorization {
                q: None,
                r,
                svd: None,
                solution: None,
                algorithm: algo,
                auto,
                stats,
            })
        }
        Want::Svd => {
            if algo != Algorithm::DirectTsqr {
                bail!(
                    "want=Svd is served by Direct TSQR only (paper §III-B), not {}",
                    algo.name()
                );
            }
            let out = coord.svd(input)?;
            stats.extend(out.stats);
            Ok(Factorization {
                q: Some(out.q),
                r: out.r,
                svd: out.svd,
                solution: None,
                algorithm: algo,
                auto,
                stats,
            })
        }
        Want::SingularValues => {
            let (r, st) = r_only(coord, input, algo)?;
            stats.extend(st);
            let svd = jacobi_svd(&r);
            Ok(Factorization {
                q: None,
                r,
                svd: Some(SvdParts { sigma: svd.sigma, v: svd.v }),
                solution: None,
                algorithm: algo,
                auto,
                stats,
            })
        }
        Want::LowRank { rank, oversample, power_iters } => {
            let out = match algo {
                Algorithm::Randomized => rand_svd::randomized_svd(
                    coord,
                    input,
                    rank,
                    oversample,
                    power_iters,
                    req.sketch,
                )?,
                // exact truncation rides the Direct-TSQR SVD; no other
                // pipeline produces the Û the want promises
                Algorithm::DirectTsqr => rand_svd::exact_low_rank(coord, input, rank)?,
                other => bail!(
                    "want=LowRank is served by randomized or direct (exact truncation), not {}",
                    other.name()
                ),
            };
            stats.extend(out.stats);
            Ok(Factorization {
                q: Some(out.u),
                r: out.r,
                svd: Some(SvdParts { sigma: out.sigma, v: out.v }),
                solution: None,
                algorithm: algo,
                auto,
                stats,
            })
        }
        Want::Solve { rhs } => {
            let (x, r, st) = match algo {
                Algorithm::Randomized => {
                    let out = sketch_solve::sketched_solve(coord, input, rhs, req.sketch)?;
                    (out.x, out.r, out.stats)
                }
                // any R-producing pipeline on the augmented [A b]
                // yields the solution by back-substitution, no Q pass
                other => {
                    let n = sketch_solve::split_cols(input.cols, rhs)?;
                    let (r_aug, st) = r_only(coord, input, other)?;
                    let (x, r_a) = sketch_solve::solve_from_augmented_r(&r_aug, n, rhs)?;
                    (x, r_a, st)
                }
            };
            stats.extend(st);
            Ok(Factorization {
                q: None,
                r,
                svd: None,
                solution: Some(x),
                algorithm: algo,
                auto,
                stats,
            })
        }
    }
}

/// The cheapest R-only pipeline each algorithm offers.
fn r_only(
    coord: &mut Coordinator,
    input: &MatrixHandle,
    algo: Algorithm,
) -> Result<(Matrix, JobStats)> {
    match algo {
        Algorithm::Cholesky { .. } => cholesky_qr::cholesky_r(coord, input),
        Algorithm::IndirectTsqr { .. } => indirect_tsqr::indirect_r(coord, input),
        Algorithm::Householder => householder::householder_r(coord, input, None),
        // the direct variants have no cheaper R-only path: run the
        // full factorization and drop Q
        Algorithm::DirectTsqr | Algorithm::DirectTsqrFused => {
            let res = coord.qr(input, algo)?;
            Ok((res.r, res.stats))
        }
        Algorithm::Randomized => {
            bail!("the randomized family serves LowRank/Solve requests, not R-only pipelines")
        }
    }
}
