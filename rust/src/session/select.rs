//! Condition-aware algorithm selection (the [`super::AlgoChoice::Auto`]
//! policy).
//!
//! The paper's Fig. 6 shows the trade-off the policy encodes: the
//! indirect methods are the cheapest pipelines but their `Q = A·R⁻¹`
//! loses orthogonality like κ·ε (κ²·ε for Cholesky QR's Gram-based `R`,
//! which also breaks down for κ ≳ 1e8), while Direct TSQR is
//! unconditionally stable at a ~30–50% job-time premium (Table VI). A
//! one-pass Indirect-TSQR probe produces a backward-stable `R` whose
//! singular values match A's in exact arithmetic, so a serial n×n
//! Jacobi SVD of that `R` gives a reliable κ₂ estimate even deep into
//! ill-conditioned territory.
//!
//! On the well-conditioned branch the probe's `R` is *reused*: the
//! session finishes it into `Q = A·R⁻¹` ([`crate::coordinator::ar_inv`])
//! rather than re-running a factorization from scratch — two passes
//! over `A` instead of three, with κ·ε orthogonality where the old
//! Cholesky-QR rerun gave κ²·ε (see [`AutoDecision::probe_reused`]).

use crate::coordinator::Algorithm;
use crate::linalg::{jacobi_svd, Matrix};
use crate::mapreduce::StepStats;
use crate::sketch::{SketchKind, SketchOptions};

/// κ₂ estimate of the input from a probe's `n×n` triangular factor.
pub fn estimate_condition(r: &Matrix) -> f64 {
    jacobi_svd(r).condition_number()
}

/// Sketch parameters an `Auto` decision committed to when it picked the
/// randomized family — recorded (marker step + wire) because the seed
/// and operator are part of the digest contract.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SketchChoice {
    pub kind: SketchKind,
    pub seed: u64,
    /// Oversampling width (`LowRank` decisions; 0 for `Solve`).
    pub oversample: usize,
}

impl SketchChoice {
    pub(crate) fn new(sketch: SketchOptions, oversample: usize) -> SketchChoice {
        SketchChoice { kind: sketch.kind, seed: sketch.seed, oversample }
    }
}

/// The recorded outcome of one `Auto` selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoDecision {
    /// κ₂(A) estimated from the Indirect-TSQR probe's `R`.
    pub kappa_estimate: f64,
    /// Threshold the estimate was compared against.
    pub threshold: f64,
    /// The algorithm the policy settled on.
    pub chosen: Algorithm,
    /// Whether the probe's `R` directly served the request (the
    /// well-conditioned and R-only branches: one fewer pass over `A`).
    pub probe_reused: bool,
    /// Whether the chosen Direct TSQR run takes the mixed-precision
    /// step-1 path (session opt-in and κ within
    /// [`crate::linalg::MIXED_KAPPA_MAX`]). Recorded here — and in the
    /// marker step — because it changes result bits for that run.
    pub mixed_precision: bool,
    /// Sketch parameters, when the policy picked the randomized family
    /// (`LowRank` rank-gate or `Solve` ill-conditioned branch).
    /// `kappa_estimate` is NaN for `LowRank` decisions — the rank gate
    /// never runs a probe.
    pub sketch: Option<SketchChoice>,
}

impl AutoDecision {
    /// Decide from a probe `R`: finish the probe indirectly (reusing
    /// its `R`) for well-conditioned inputs, Direct TSQR otherwise.
    pub(crate) fn from_probe(r: &Matrix, threshold: f64, refine: bool) -> AutoDecision {
        let kappa = estimate_condition(r);
        if kappa.is_finite() && kappa <= threshold {
            AutoDecision {
                kappa_estimate: kappa,
                threshold,
                chosen: Algorithm::IndirectTsqr { refine },
                probe_reused: true,
                mixed_precision: false,
                sketch: None,
            }
        } else {
            AutoDecision {
                kappa_estimate: kappa,
                threshold,
                chosen: Algorithm::DirectTsqr,
                probe_reused: false,
                mixed_precision: false,
                sketch: None,
            }
        }
    }

    /// Zero-cost marker step recording the decision in the job stats
    /// (also how the CLI prints the decision line).
    pub fn step_stats(&self) -> StepStats {
        // LowRank decisions come from the rank gate, not a κ probe
        let basis = if self.kappa_estimate.is_nan() {
            "rank-gate".to_string()
        } else {
            format!("kappa~{:.1e}", self.kappa_estimate)
        };
        let sketch = match &self.sketch {
            Some(c) => format!(
                ", sketch={} seed={} p={}",
                c.kind.cli_name(),
                c.seed,
                c.oversample
            ),
            None => String::new(),
        };
        StepStats {
            name: format!(
                "auto-select({basis} -> {}{}{}{sketch})",
                self.chosen.cli_name(),
                if self.probe_reused { ", probe-reused" } else { "" },
                if self.mixed_precision { ", mixed-precision" } else { "" }
            ),
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::householder_qr;
    use crate::linalg::matrix_with_condition;
    use crate::util::rng::Rng;

    #[test]
    fn estimate_tracks_prescribed_condition() {
        let mut rng = Rng::new(1);
        for &kappa in &[1e0, 1e4, 1e9, 1e13] {
            let a = matrix_with_condition(300, 6, kappa, &mut rng);
            let (_, r) = householder_qr(&a);
            let est = estimate_condition(&r);
            assert!(
                (est.log10() - kappa.log10()).abs() < 0.5,
                "kappa {kappa:.0e} estimated {est:.2e}"
            );
        }
    }

    #[test]
    fn decision_splits_on_threshold() {
        let mut rng = Rng::new(2);
        let a = matrix_with_condition(300, 5, 10.0, &mut rng);
        let (_, r) = householder_qr(&a);
        let d = AutoDecision::from_probe(&r, 1e6, false);
        assert_eq!(d.chosen, Algorithm::IndirectTsqr { refine: false });
        assert!(d.probe_reused, "well-conditioned pick reuses the probe's R");

        let a = matrix_with_condition(300, 5, 1e12, &mut rng);
        let (_, r) = householder_qr(&a);
        let d = AutoDecision::from_probe(&r, 1e6, true);
        assert_eq!(d.chosen, Algorithm::DirectTsqr);
        assert!(!d.probe_reused, "the stable path re-reads A from scratch");
    }

    #[test]
    fn refine_is_honored_on_the_cheap_pick() {
        let mut rng = Rng::new(3);
        let a = matrix_with_condition(200, 4, 5.0, &mut rng);
        let (_, r) = householder_qr(&a);
        let d = AutoDecision::from_probe(&r, 1e6, true);
        assert_eq!(d.chosen, Algorithm::IndirectTsqr { refine: true });
        assert!(d.probe_reused);
    }

    #[test]
    fn marker_step_is_zero_cost_and_named() {
        let d = AutoDecision {
            kappa_estimate: 3.0,
            threshold: 1e6,
            chosen: Algorithm::IndirectTsqr { refine: false },
            probe_reused: true,
            mixed_precision: false,
            sketch: None,
        };
        let s = d.step_stats();
        assert!(s.name.starts_with("auto-select"));
        assert!(s.name.contains("indirect"));
        assert!(s.name.contains("probe-reused"));
        assert!(!s.name.contains("mixed-precision"));
        assert_eq!(s.virtual_secs, 0.0);
        assert_eq!(s.map_tasks, 0);

        let d2 = AutoDecision {
            kappa_estimate: 1e12,
            threshold: 1e6,
            chosen: Algorithm::DirectTsqr,
            probe_reused: false,
            mixed_precision: false,
            sketch: None,
        };
        assert!(!d2.step_stats().name.contains("probe-reused"));
        assert!(d2.step_stats().name.contains("direct"));

        let d3 = AutoDecision { mixed_precision: true, ..d2 };
        assert!(d3.step_stats().name.contains("mixed-precision"));
    }

    #[test]
    fn sketch_decisions_mark_seed_and_gate() {
        let d = AutoDecision {
            kappa_estimate: f64::NAN,
            threshold: 1e3,
            chosen: Algorithm::Randomized,
            probe_reused: false,
            mixed_precision: false,
            sketch: Some(SketchChoice::new(SketchOptions { kind: SketchKind::Gaussian, seed: 42 }, 8)),
        };
        let s = d.step_stats();
        assert!(s.name.contains("rank-gate"), "{}", s.name);
        assert!(s.name.contains("randomized"));
        assert!(s.name.contains("sketch=gauss seed=42 p=8"));
        assert!(!s.name.contains("kappa"));
        assert_eq!(s.virtual_secs, 0.0);
    }
}
