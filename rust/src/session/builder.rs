//! Fluent construction of a [`TsqrSession`]: cluster, disk model, fault
//! policy, compute backend, host parallelism, and tuning knobs in one
//! place.

use super::TsqrSession;
use crate::coordinator::CoordOpts;
use crate::dfs::DiskModel;
use crate::mapreduce::{ClusterConfig, Engine, FaultPolicy};
use crate::runtime::{NativeRuntime, SharedCompute};
use anyhow::Result;
use std::sync::Arc;

/// Compute-backend selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// PJRT when the crate was built with the `pjrt` feature *and* the
    /// AOT artifacts exist on disk; the pure-rust oracle otherwise.
    Auto,
    /// The pure-rust [`NativeRuntime`] (always available).
    Native,
    /// The PJRT/XLA artifact path; errors when the build lacks the
    /// `pjrt` feature or the artifacts are missing.
    Pjrt,
}

impl Backend {
    /// Resolve to a concrete (shareable, thread-safe) compute backend
    /// plus a short human-readable name. Sessions sharing one resolved
    /// backend reuse its compiled-executable cache — build it once,
    /// clone the [`SharedCompute`] `Arc` into as many sessions (and
    /// host worker threads) as needed.
    pub fn resolve(self) -> Result<(SharedCompute, &'static str)> {
        match self {
            Backend::Native => Ok((Arc::new(NativeRuntime), "native")),
            Backend::Auto => {
                #[cfg(feature = "pjrt")]
                {
                    let dir = crate::runtime::Manifest::default_dir();
                    if dir.join("manifest.tsv").exists() {
                        let rt = crate::runtime::PjrtRuntime::from_default_artifacts()?;
                        return Ok((Arc::new(rt), "pjrt"));
                    }
                }
                Ok((Arc::new(NativeRuntime), "native"))
            }
            Backend::Pjrt => {
                #[cfg(feature = "pjrt")]
                {
                    let rt = crate::runtime::PjrtRuntime::from_default_artifacts()?;
                    return Ok((Arc::new(rt), "pjrt"));
                }
                #[cfg(not(feature = "pjrt"))]
                anyhow::bail!(
                    "this build has no PJRT support — rebuild with `--features pjrt` \
                     (and run `make artifacts`)"
                );
            }
        }
    }
}

/// Builder for [`TsqrSession`] — see the [`crate::session`] module docs
/// for the full tour.
pub struct SessionBuilder {
    model: DiskModel,
    cluster: ClusterConfig,
    faults: Option<(FaultPolicy, u64)>,
    backend: Backend,
    compute: Option<SharedCompute>,
    opts: CoordOpts,
}

impl SessionBuilder {
    pub(crate) fn new() -> Self {
        SessionBuilder {
            model: DiskModel::icme_like(),
            cluster: ClusterConfig::default(),
            faults: None,
            backend: Backend::Auto,
            compute: None,
            opts: CoordOpts::default(),
        }
    }

    /// Disk-bandwidth model for the virtual clock (default:
    /// [`DiskModel::icme_like`], the paper's fitted cluster).
    pub fn disk_model(mut self, model: DiskModel) -> Self {
        self.model = model;
        self
    }

    /// Map/reduce slot counts (default: the paper's 40/40). Overwrites
    /// any earlier [`host_threads`](Self::host_threads) call with the
    /// config's own pool size.
    pub fn cluster(mut self, cluster: ClusterConfig) -> Self {
        self.cluster = cluster;
        self
    }

    /// Host worker threads executing map/reduce task bodies (default:
    /// the machine's available parallelism; `1` runs tasks inline).
    /// Purely a wall-clock knob — results and all non-wall metrics are
    /// bit-identical for every value (see `rust/tests/parallel.rs`).
    pub fn host_threads(mut self, n: usize) -> Self {
        self.cluster.host_threads = n.max(1);
        self
    }

    /// Inject task faults with Hadoop retry semantics (paper Fig. 7).
    pub fn fault_policy(mut self, policy: FaultPolicy, seed: u64) -> Self {
        self.faults = Some((policy, seed));
        self
    }

    /// Compute-backend selector (default: [`Backend::Auto`]).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Share an already-resolved backend (see [`Backend::resolve`]) or
    /// plug in a custom [`crate::runtime::BlockCompute`] implementation.
    pub fn compute(mut self, compute: SharedCompute) -> Self {
        self.compute = Some(compute);
        self
    }

    /// Rows per step-1 map task (default 1000).
    pub fn rows_per_task(mut self, rows: usize) -> Self {
        self.opts.rows_per_task = rows;
        self
    }

    /// Reduce tasks for shuffling stages (default 40, the paper's r_max).
    pub fn reduce_tasks(mut self, tasks: usize) -> Self {
        self.opts.reduce_tasks = tasks;
        self
    }

    /// Step-2 gather limit in rows — small values force the recursive
    /// Direct TSQR (paper Alg. 2).
    pub fn gather_limit(mut self, rows: usize) -> Self {
        self.opts.gather_limit = Some(rows);
        self
    }

    /// Assemble the session.
    pub fn build(self) -> Result<TsqrSession> {
        let (compute, backend_desc) = match self.compute {
            Some(c) => (c, "custom"),
            None => self.backend.resolve()?,
        };
        let mut engine = Engine::new(self.model, self.cluster);
        if let Some((policy, seed)) = self.faults {
            engine = engine.with_faults(policy, seed);
        }
        Ok(TsqrSession {
            engine: Some(engine),
            compute,
            backend_desc,
            opts: self.opts,
            seq: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_always_resolves() {
        let (_, desc) = Backend::Native.resolve().unwrap();
        assert_eq!(desc, "native");
    }

    #[test]
    fn builder_knobs_reach_the_session() {
        let s = TsqrSession::builder()
            .backend(Backend::Native)
            .rows_per_task(123)
            .reduce_tasks(7)
            .gather_limit(99)
            .host_threads(3)
            .build()
            .unwrap();
        assert_eq!(s.opts.rows_per_task, 123);
        assert_eq!(s.opts.reduce_tasks, 7);
        assert_eq!(s.opts.gather_limit, Some(99));
        assert_eq!(s.backend_desc(), "native");
        assert_eq!(s.host_threads(), 3);
    }

    #[test]
    fn host_threads_floor_is_one() {
        let s = TsqrSession::builder()
            .backend(Backend::Native)
            .host_threads(0)
            .build()
            .unwrap();
        assert_eq!(s.host_threads(), 1);
    }

    #[test]
    fn resolved_backend_is_shareable_across_threads() {
        use crate::runtime::BlockCompute as _;
        // the whole point of SharedCompute: Arc<dyn BlockCompute> moves
        // freely across host worker threads
        let (compute, _) = Backend::Native.resolve().unwrap();
        let handle = std::thread::spawn(move || {
            let m = crate::linalg::Matrix::identity(3);
            compute.gram(&m).unwrap().data
        });
        assert_eq!(handle.join().unwrap(), crate::linalg::Matrix::identity(3).data);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_errors_without_the_feature() {
        assert!(Backend::Pjrt.resolve().is_err());
    }
}
