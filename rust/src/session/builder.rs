//! Fluent construction of a [`TsqrSession`]: cluster, disk model, fault
//! policy, compute backend, host parallelism, and tuning knobs in one
//! place.

use super::TsqrSession;
use crate::client::net::{NetOptions, TcpTransport};
use crate::client::process::{default_worker_binary, ProcessTransport};
use crate::client::{LocalTransport, TsqrClient, WorkerConfig};
use crate::coordinator::CoordOpts;
use crate::dfs::DiskModel;
use crate::mapreduce::{ClusterConfig, Engine, FaultPolicy};
use crate::runtime::{NativeRuntime, SharedCompute};
use crate::service::{SchedulerConfig, ServiceConfig, TsqrService};
use anyhow::{ensure, Result};
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// Compute-backend selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// PJRT when the crate was built with the `pjrt` feature *and* the
    /// AOT artifacts exist on disk; the pure-rust oracle otherwise.
    Auto,
    /// The pure-rust [`NativeRuntime`] (always available).
    Native,
    /// The PJRT/XLA artifact path; errors when the build lacks the
    /// `pjrt` feature or the artifacts are missing.
    Pjrt,
}

/// Process-wide pool of resolved backends (one per backend kind). All
/// sessions and job services resolving through [`Backend::resolve`]
/// share these instances, so a PJRT backend's per-shape executable
/// cache is compiled once and reused by every in-flight job in the
/// process.
static NATIVE_POOL: OnceLock<SharedCompute> = OnceLock::new();
#[cfg(feature = "pjrt")]
static PJRT_POOL: std::sync::Mutex<Option<SharedCompute>> = std::sync::Mutex::new(None);

impl Backend {
    /// Resolve to a concrete (shareable, thread-safe) compute backend
    /// plus a short human-readable name.
    ///
    /// Resolution is *pooled*: every `resolve()` of the same backend
    /// kind in this process returns a clone of one shared instance, so
    /// all sessions and all in-flight service jobs share a single
    /// per-shape compiled-executable cache (the PJRT path compiles each
    /// `(op, block_rows, cols)` shape exactly once process-wide). Use
    /// [`Backend::resolve_fresh`] when an isolated instance is needed
    /// (e.g. per-backend runtime-stats accounting).
    pub fn resolve(self) -> Result<(SharedCompute, &'static str)> {
        match self {
            Backend::Native => Ok((
                NATIVE_POOL.get_or_init(|| Arc::new(NativeRuntime::new())).clone(),
                "native",
            )),
            Backend::Auto => {
                #[cfg(feature = "pjrt")]
                {
                    let dir = crate::runtime::Manifest::default_dir();
                    if dir.join("manifest.tsv").exists() {
                        return Backend::Pjrt.resolve();
                    }
                }
                Backend::Native.resolve()
            }
            Backend::Pjrt => {
                #[cfg(feature = "pjrt")]
                {
                    let mut pool = PJRT_POOL.lock().expect("pjrt backend pool");
                    if let Some(rt) = pool.as_ref() {
                        return Ok((rt.clone(), "pjrt"));
                    }
                    // failures (missing artifacts) are not cached: a
                    // later resolve after `make artifacts` succeeds
                    let rt: SharedCompute =
                        Arc::new(crate::runtime::PjrtRuntime::from_default_artifacts()?);
                    *pool = Some(rt.clone());
                    return Ok((rt, "pjrt"));
                }
                #[cfg(not(feature = "pjrt"))]
                anyhow::bail!(
                    "this build has no PJRT support — rebuild with `--features pjrt` \
                     (and run `make artifacts`)"
                );
            }
        }
    }

    /// Resolve a *fresh* (unpooled) backend instance with its own
    /// executable cache and stats. The pre-pool behavior of
    /// [`Backend::resolve`].
    pub fn resolve_fresh(self) -> Result<(SharedCompute, &'static str)> {
        match self {
            Backend::Native => Ok((Arc::new(NativeRuntime::new()), "native")),
            Backend::Auto => {
                #[cfg(feature = "pjrt")]
                {
                    let dir = crate::runtime::Manifest::default_dir();
                    if dir.join("manifest.tsv").exists() {
                        let rt = crate::runtime::PjrtRuntime::from_default_artifacts()?;
                        return Ok((Arc::new(rt), "pjrt"));
                    }
                }
                Ok((Arc::new(NativeRuntime::new()), "native"))
            }
            Backend::Pjrt => {
                #[cfg(feature = "pjrt")]
                {
                    let rt = crate::runtime::PjrtRuntime::from_default_artifacts()?;
                    return Ok((Arc::new(rt), "pjrt"));
                }
                #[cfg(not(feature = "pjrt"))]
                anyhow::bail!(
                    "this build has no PJRT support — rebuild with `--features pjrt` \
                     (and run `make artifacts`)"
                );
            }
        }
    }
}

/// Builder for [`TsqrSession`] — see the [`crate::session`] module docs
/// for the full tour.
pub struct SessionBuilder {
    model: DiskModel,
    cluster: ClusterConfig,
    faults: Option<(FaultPolicy, u64)>,
    backend: Backend,
    compute: Option<SharedCompute>,
    opts: CoordOpts,
    ns: String,
    service: ServiceConfig,
    /// Worker processes a [`TsqrClient`] built from this builder spawns
    /// (0 = in-process `Local` transport).
    worker_procs: usize,
    /// Override for the `mrtsqr` binary the `Process` transport spawns.
    worker_binary: Option<PathBuf>,
    /// Remote `mrtsqr serve --listen` addresses — non-empty selects the
    /// `Tcp` transport (mutually exclusive with `worker_procs ≥ 1`).
    connect_addrs: Vec<String>,
    /// Explicit per-request reply deadline. `None` = transport default:
    /// wait forever on pipes, the `NetOptions` default on sockets.
    request_timeout: Option<Duration>,
    /// The remaining `Tcp`-transport knobs.
    net: NetOptions,
}

impl SessionBuilder {
    pub(crate) fn new() -> Self {
        SessionBuilder {
            model: DiskModel::icme_like(),
            cluster: ClusterConfig::default(),
            faults: None,
            backend: Backend::Auto,
            compute: None,
            opts: CoordOpts::default(),
            ns: String::new(),
            service: ServiceConfig::default(),
            worker_procs: 0,
            worker_binary: None,
            connect_addrs: Vec::new(),
            request_timeout: None,
            net: NetOptions::default(),
        }
    }

    /// Reconstruct a builder from the cluster recipe a
    /// [`crate::client::wire::Op::Hello`] handshake shipped — how an
    /// `mrtsqr worker` process becomes configured identically to the
    /// parent that spawned it.
    pub(crate) fn from_worker_config(cfg: &WorkerConfig) -> SessionBuilder {
        SessionBuilder {
            model: cfg.model,
            cluster: cfg.cluster,
            faults: cfg.faults,
            backend: cfg.backend,
            compute: None,
            opts: cfg.opts,
            ns: String::new(),
            service: ServiceConfig {
                workers: cfg.service_workers,
                queue_capacity: cfg.queue_capacity.max(1),
                engine_shards: cfg.engine_shards.max(1),
                scheduler: cfg.scheduler,
            },
            worker_procs: 0,
            worker_binary: None,
            connect_addrs: Vec::new(),
            request_timeout: None,
            net: NetOptions::default(),
        }
    }

    /// Disk-bandwidth model for the virtual clock (default:
    /// [`DiskModel::icme_like`], the paper's fitted cluster).
    pub fn disk_model(mut self, model: DiskModel) -> Self {
        self.model = model;
        self
    }

    /// Map/reduce slot counts (default: the paper's 40/40). Overwrites
    /// any earlier [`host_threads`](Self::host_threads) call with the
    /// config's own pool size.
    pub fn cluster(mut self, cluster: ClusterConfig) -> Self {
        self.cluster = cluster;
        self
    }

    /// Host worker threads executing map/reduce task bodies (default:
    /// the machine's available parallelism; `1` runs tasks inline).
    /// Purely a wall-clock knob — results and all non-wall metrics are
    /// bit-identical for every value (see `rust/tests/parallel.rs`).
    pub fn host_threads(mut self, n: usize) -> Self {
        self.cluster.host_threads = n.max(1);
        self
    }

    /// Inject task faults with Hadoop retry semantics (paper Fig. 7).
    pub fn fault_policy(mut self, policy: FaultPolicy, seed: u64) -> Self {
        self.faults = Some((policy, seed));
        self
    }

    /// Compute-backend selector (default: [`Backend::Auto`]).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Share an already-resolved backend (see [`Backend::resolve`]) or
    /// plug in a custom [`crate::runtime::BlockCompute`] implementation.
    pub fn compute(mut self, compute: SharedCompute) -> Self {
        self.compute = Some(compute);
        self
    }

    /// Rows per step-1 map task (default 1000).
    pub fn rows_per_task(mut self, rows: usize) -> Self {
        self.opts.rows_per_task = rows;
        self
    }

    /// Reduce tasks for shuffling stages (default 40, the paper's r_max).
    pub fn reduce_tasks(mut self, tasks: usize) -> Self {
        self.opts.reduce_tasks = tasks;
        self
    }

    /// Step-2 gather limit in rows — small values force the recursive
    /// Direct TSQR (paper Alg. 2).
    pub fn gather_limit(mut self, rows: usize) -> Self {
        self.opts.gather_limit = Some(rows);
        self
    }

    /// Panel width of the native backend's blocked Householder QR
    /// (default [`crate::linalg::DEFAULT_PANEL`]). Purely a speed knob:
    /// `R` is bit-identical to the textbook column-by-column
    /// factorization at every width, and `Q` bits are panel-invariant
    /// (the compact-WY accumulation runs at its own fixed internal
    /// block size) — so result digests never depend on this setting.
    /// Ignored when a custom or PJRT compute backend serves the
    /// session. The floor is 1.
    pub fn panel_block(mut self, b: usize) -> Self {
        self.opts.panel_block = Some(b.max(1));
        self
    }

    /// Opt in to mixed-precision step-1 panel factorization for `Auto`
    /// requests (default **off**). When enabled, an `Auto` decision
    /// that already lands on Direct TSQR additionally checks the κ
    /// probe: if κ ≤ [`crate::linalg::MIXED_KAPPA_MAX`], step-1 blocks
    /// are factored in f32 storage with f64 accumulation and finished
    /// with one f64 refinement sweep. This *changes result bits* for
    /// those runs (never for fixed-algorithm requests, which skip the
    /// probe), and is recorded in the `auto-select` marker step.
    pub fn mixed_precision(mut self, on: bool) -> Self {
        self.opts.mixed_precision = on;
        self
    }

    /// Canonical leaf block height for streaming folds
    /// ([`crate::session::TsqrSession::stream`], default 1000 to
    /// mirror `rows_per_task`). This shapes the fold tree, so it is
    /// part of the *streamed* digest contract — but the arrival
    /// chunking (how many rows each `push_chunk` carries) never
    /// changes bits. The floor is 1.
    pub fn stream_chunk_rows(mut self, rows: usize) -> Self {
        self.opts.stream_chunk_rows = rows.max(1);
        self
    }

    /// DFS namespace prefix for this session's temp files (e.g.
    /// `"s0/"`). Sessions whose requests land in one shared store must
    /// use distinct namespaces, or their `seq`-derived intermediate
    /// names collide — the job service does this automatically
    /// (`job-<id>/` per job). Default: `""` (the historical `tmp/…`
    /// names).
    pub fn namespace(mut self, ns: impl Into<String>) -> Self {
        self.ns = ns.into();
        self
    }

    /// Worker threads a [`TsqrService`] built from this builder will
    /// run jobs on (`0` = no background workers: jobs execute only via
    /// [`TsqrService::drain_now`] / [`TsqrService::drain_one`], the
    /// deterministic serial mode). Default: 2. Ignored by
    /// [`SessionBuilder::build`].
    pub fn service_workers(mut self, n: usize) -> Self {
        self.service.workers = n;
        self
    }

    /// Bounded FIFO queue capacity of a [`TsqrService`] built from this
    /// builder: `submit` blocks (and `try_submit` errors) while this
    /// many jobs are queued (per engine shard). Default: 64. Ignored by
    /// [`SessionBuilder::build`].
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.service.queue_capacity = n.max(1);
        self
    }

    /// Engine shards of a [`TsqrService`] built from this builder
    /// (default 1 = one shared engine, exactly the pre-shard service).
    /// Each shard is an independent `Mutex<Engine>` — its own DFS
    /// subtree and virtual clock — so jobs placed on different shards
    /// run with **zero cross-job locking**; all shards share one pooled
    /// compute backend. The floor is 1.
    ///
    /// **Ingestion placement:** every `ingest_*` call pins the matrix
    /// to shard 0 (its *home* shard). A job routed or
    /// [`pinned`](crate::session::FactorizationRequest::pinned) to
    /// another shard gets the input by a cheap O(1) reference-counted
    /// copy at submission ([`crate::dfs::Dfs::export_file`]) — no
    /// replication up front, no deep copy ever, and the explicit
    /// `Placement::Pinned(k)` escape hatch remains for callers that
    /// want to co-locate chained jobs with a shard's DFS.
    ///
    /// Placement is invisible in results: for any workload, `shards=1`
    /// and `shards=N` produce bit-identical `R`/`Q`/Σ/`virtual_secs`
    /// and fault draws per job (`rust/tests/shards.rs`). Ignored by
    /// [`SessionBuilder::build`].
    pub fn engine_shards(mut self, n: usize) -> Self {
        self.service.engine_shards = n.max(1);
        self
    }

    /// Elastic-scheduling policy of a [`TsqrService`] / [`TsqrClient`]
    /// built from this builder — the one knob group for work stealing,
    /// chained-job locality, per-label admission quotas, and worker
    /// autoscaling (see [`SchedulerConfig`]). Default:
    /// [`SchedulerConfig::default`], everything off — exactly the
    /// pre-elastic service. Every policy here is *pure scheduling*:
    /// results, `virtual_secs`, fault draws, and result digests are
    /// bit-identical at every setting (`rust/tests/steal.rs`). Shipped
    /// to worker processes and remote hosts in the config handshake.
    /// Ignored by [`SessionBuilder::build`].
    pub fn scheduler(mut self, scheduler: SchedulerConfig) -> Self {
        self.service.scheduler = scheduler;
        self
    }

    /// Worker *processes* of a [`TsqrClient`] built from this builder
    /// ([`SessionBuilder::build_client`]). `0` (the default) keeps the
    /// whole engine pool in this process behind the `Local` transport —
    /// the exact [`TsqrService`] behavior. `n ≥ 1` spawns `n`
    /// `mrtsqr worker` children, each running its *own* engine pool of
    /// [`SessionBuilder::engine_shards`] shards with
    /// [`SessionBuilder::service_workers`] threads per shard, reached
    /// over the framed stdin/stdout wire protocol
    /// ([`crate::client::wire`]).
    ///
    /// Like engine shards, worker processes are *pure placement*:
    /// global shard `k` means (process `k / engine_shards`, local shard
    /// `k % engine_shards`), and every job's results are bit-identical
    /// wherever it runs (`rust/tests/client.rs`). Ignored by
    /// [`SessionBuilder::build`] and [`SessionBuilder::build_service`].
    pub fn worker_processes(mut self, n: usize) -> Self {
        self.worker_procs = n;
        self
    }

    /// Path of the `mrtsqr` binary spawned as a worker process
    /// (default: auto-detected — the current executable when it is
    /// `mrtsqr`, an `mrtsqr` sibling in the build tree, or
    /// `MRTSQR_WORKER_BIN`). Tests pass
    /// `env!("CARGO_BIN_EXE_mrtsqr")`.
    pub fn worker_binary(mut self, path: impl Into<PathBuf>) -> Self {
        self.worker_binary = Some(path.into());
        self
    }

    /// Drive remote `mrtsqr serve --listen` hosts instead of local
    /// worker processes: a [`TsqrClient`] built from this builder uses
    /// the `Tcp` transport ([`crate::client::TcpTransport`]), one
    /// connection per address, with the servers' own engine topology
    /// (their `--shards` wins; every host must serve the same count).
    /// Mutually exclusive with [`SessionBuilder::worker_processes`].
    /// Global shard `k` means (host `k / shards_per_host`, local shard
    /// `k % shards_per_host`) — the process-transport flattening one
    /// level up, with the same bit-identity guarantee
    /// (`rust/tests/tcp.rs`).
    pub fn connect<S: AsRef<str>>(mut self, addrs: &[S]) -> Self {
        self.connect_addrs = addrs.iter().map(|a| a.as_ref().to_string()).collect();
        self
    }

    /// Reply deadline for every wire request (pipe and TCP transports):
    /// a request unanswered within `timeout` fails and marks the peer
    /// *suspect* — skipped by Auto routing until it speaks again —
    /// instead of wedging the client thread behind a stuck peer.
    /// Default: wait forever on pipes, 30 s on TCP.
    pub fn request_timeout(mut self, timeout: Duration) -> Self {
        self.request_timeout = Some(timeout);
        self
    }

    /// Dial deadline per TCP connection attempt (default 5 s).
    pub fn connect_timeout(mut self, timeout: Duration) -> Self {
        self.net.connect_timeout = timeout;
        self
    }

    /// Cadence of the TCP keeper's health pings and reconnect attempts
    /// (default 500 ms).
    pub fn net_health_interval(mut self, interval: Duration) -> Self {
        self.net.health_interval = interval.max(Duration::from_millis(1));
        self
    }

    /// Health-ping round-trip above which a host counts as *lagging*:
    /// Auto jobs route around it while any brisk host is available
    /// (default 250 ms). Pins ignore lag.
    pub fn net_lag_threshold(mut self, threshold: Duration) -> Self {
        self.net.lag_threshold = threshold;
        self
    }

    /// Consecutive failed reconnect dials before a host is condemned
    /// and its parked jobs fail with a precise error (default 5).
    pub fn net_reconnect_attempts(mut self, attempts: usize) -> Self {
        self.net.max_reconnect_attempts = attempts.max(1);
        self
    }

    fn into_cluster_parts(self) -> Result<ClusterParts> {
        let (mut compute, backend_desc) = match self.compute {
            Some(c) => (c, "custom"),
            None => self.backend.resolve()?,
        };
        // A non-default panel width needs its own NativeRuntime value
        // (the pooled instance stays at DEFAULT_PANEL). The runtime is
        // a stateless two-word value, so skipping the pool costs
        // nothing; custom/PJRT backends ignore the knob.
        if let Some(b) = self.opts.panel_block {
            if backend_desc == "native" {
                compute = Arc::new(NativeRuntime::with_panel(b));
            }
        }
        Ok(ClusterParts {
            model: self.model,
            cluster: self.cluster,
            faults: self.faults,
            compute,
            backend_desc,
            opts: self.opts,
            ns: self.ns,
            service: self.service,
        })
    }

    /// Assemble the session.
    pub fn build(self) -> Result<TsqrSession> {
        let p = self.into_cluster_parts()?;
        Ok(TsqrSession {
            engine: Some(p.make_engine()),
            compute: p.compute,
            backend_desc: p.backend_desc,
            opts: p.opts,
            seq: 0,
            ns: p.ns,
        })
    }

    /// Assemble a concurrent job service instead of a session: the same
    /// cluster recipe (disk model + slots + faults + backend + tuning),
    /// served through bounded job queues by
    /// [`SessionBuilder::service_workers`] worker threads per
    /// [`SessionBuilder::engine_shards`] shard. See [`crate::service`].
    pub fn build_service(self) -> Result<TsqrService> {
        let p = self.into_cluster_parts()?;
        let engines: Vec<Engine> = (0..p.service.engine_shards.max(1))
            .map(|_| p.make_engine())
            .collect();
        Ok(TsqrService::start(engines, p.compute, p.backend_desc, p.opts, p.service))
    }

    /// Assemble a transport-agnostic [`TsqrClient`] — the L6 facade.
    /// With [`SessionBuilder::worker_processes`] at 0 (default) the
    /// client wraps an in-process [`TsqrService`] (the `Local`
    /// transport, zero behavior change); with `n ≥ 1` it spawns `n`
    /// `mrtsqr worker` processes and speaks the framed wire protocol
    /// (the `Process` transport); with [`SessionBuilder::connect`]
    /// addresses it dials remote `mrtsqr serve` hosts (the `Tcp`
    /// transport). See [`crate::client`].
    pub fn build_client(self) -> Result<TsqrClient> {
        if !self.connect_addrs.is_empty() {
            ensure!(
                self.worker_procs == 0,
                "connect(addrs) and worker_processes(n ≥ 1) are mutually exclusive — \
                 a client drives either remote hosts or local child processes"
            );
            ensure!(
                self.compute.is_none(),
                "a custom compute backend cannot cross the network — \
                 connect() talks to servers that resolved their own backend"
            );
            let cfg = WorkerConfig {
                model: self.model,
                cluster: self.cluster,
                faults: self.faults,
                opts: self.opts,
                backend: self.backend,
                engine_shards: self.service.engine_shards.max(1),
                service_workers: self.service.workers,
                queue_capacity: self.service.queue_capacity.max(1),
                scheduler: self.service.scheduler,
            };
            let mut net = self.net;
            if let Some(timeout) = self.request_timeout {
                net.request_timeout = Some(timeout);
            }
            let transport = TcpTransport::connect(&self.connect_addrs, cfg, net)?;
            return Ok(TsqrClient::new(Box::new(transport)));
        }
        if self.worker_procs == 0 {
            let svc = self.build_service()?;
            return Ok(TsqrClient::new(Box::new(LocalTransport::new(svc))));
        }
        ensure!(
            self.compute.is_none(),
            "a custom compute backend cannot cross a process boundary — \
             use worker_processes(0) or a named Backend"
        );
        let cfg = WorkerConfig {
            model: self.model,
            cluster: self.cluster,
            faults: self.faults,
            opts: self.opts,
            backend: self.backend,
            engine_shards: self.service.engine_shards.max(1),
            service_workers: self.service.workers,
            queue_capacity: self.service.queue_capacity.max(1),
            scheduler: self.service.scheduler,
        };
        let program = match self.worker_binary {
            Some(path) => path,
            None => default_worker_binary()?,
        };
        let transport =
            ProcessTransport::launch(cfg, self.worker_procs, program, self.request_timeout)?;
        Ok(TsqrClient::new(Box::new(transport)))
    }
}

/// Everything a builder resolves before handing it to a session or a
/// service. Holds the engine *recipe* rather than an engine, so a
/// sharded service can stamp out N identically-configured engines.
struct ClusterParts {
    model: DiskModel,
    cluster: ClusterConfig,
    faults: Option<(FaultPolicy, u64)>,
    compute: SharedCompute,
    backend_desc: &'static str,
    opts: CoordOpts,
    ns: String,
    service: ServiceConfig,
}

impl ClusterParts {
    fn make_engine(&self) -> Engine {
        let mut engine = Engine::new(self.model, self.cluster);
        if let Some((policy, seed)) = self.faults {
            engine = engine.with_faults(policy, seed);
        }
        engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_always_resolves() {
        let (_, desc) = Backend::Native.resolve().unwrap();
        assert_eq!(desc, "native");
    }

    #[test]
    fn builder_knobs_reach_the_session() {
        let s = TsqrSession::builder()
            .backend(Backend::Native)
            .rows_per_task(123)
            .reduce_tasks(7)
            .gather_limit(99)
            .host_threads(3)
            .build()
            .unwrap();
        assert_eq!(s.opts.rows_per_task, 123);
        assert_eq!(s.opts.reduce_tasks, 7);
        assert_eq!(s.opts.gather_limit, Some(99));
        assert_eq!(s.backend_desc(), "native");
        assert_eq!(s.host_threads(), 3);
    }

    #[test]
    fn host_threads_floor_is_one() {
        let s = TsqrSession::builder()
            .backend(Backend::Native)
            .host_threads(0)
            .build()
            .unwrap();
        assert_eq!(s.host_threads(), 1);
    }

    #[test]
    fn resolve_pools_one_instance_per_backend_kind() {
        // the per-shape executable pool: every resolve() of a kind is
        // the same instance, shared by all sessions and in-flight jobs
        // (thin-pointer comparison: wide-pointer eq on dyn Arcs is
        // lint-ambiguous)
        let data_ptr = |c: &SharedCompute| Arc::as_ptr(c) as *const u8;
        let (a, _) = Backend::Native.resolve().unwrap();
        let (b, _) = Backend::Native.resolve().unwrap();
        assert!(std::ptr::eq(data_ptr(&a), data_ptr(&b)), "resolve() must pool");
        let (c, _) = Backend::Native.resolve_fresh().unwrap();
        assert!(!std::ptr::eq(data_ptr(&a), data_ptr(&c)), "resolve_fresh() must not pool");
    }

    #[test]
    fn namespace_flows_into_session_temp_names() {
        let mut s = TsqrSession::builder()
            .backend(Backend::Native)
            .namespace("s0/")
            .build()
            .unwrap();
        let h = s.ingest_gaussian("A", 120, 4, 1).unwrap();
        let f = s.qr_with(&h, crate::coordinator::Algorithm::DirectTsqr).unwrap();
        assert!(f.q.as_ref().unwrap().file.starts_with("s0/tmp/"));
    }

    #[test]
    fn service_knobs_reach_the_service() {
        let svc = TsqrSession::builder()
            .backend(Backend::Native)
            .service_workers(0)
            .queue_capacity(3)
            .build_service()
            .unwrap();
        assert_eq!(svc.workers(), 0);
        assert_eq!(svc.capacity(), 3);
        assert_eq!(svc.backend_desc(), "native");
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.shards(), 1, "default is the single-engine service");
    }

    #[test]
    fn scheduler_knob_reaches_the_service() {
        let sched = SchedulerConfig::new().steal(true).locality(true).quota_per_label(2);
        let svc = TsqrSession::builder()
            .backend(Backend::Native)
            .service_workers(0)
            .scheduler(sched)
            .build_service()
            .unwrap();
        assert_eq!(svc.scheduler(), sched);
        // the default is everything-off — the pre-elastic service
        let svc = TsqrSession::builder()
            .backend(Backend::Native)
            .service_workers(0)
            .build_service()
            .unwrap();
        assert_eq!(svc.scheduler(), SchedulerConfig::default());
    }

    #[test]
    fn engine_shards_knob_builds_a_pool() {
        let svc = TsqrSession::builder()
            .backend(Backend::Native)
            .engine_shards(4)
            .service_workers(0)
            .build_service()
            .unwrap();
        assert_eq!(svc.shards(), 4);
        // floor is one shard
        let svc = TsqrSession::builder()
            .backend(Backend::Native)
            .engine_shards(0)
            .service_workers(0)
            .build_service()
            .unwrap();
        assert_eq!(svc.shards(), 1);
    }

    #[test]
    fn resolved_backend_is_shareable_across_threads() {
        use crate::runtime::BlockCompute as _;
        // the whole point of SharedCompute: Arc<dyn BlockCompute> moves
        // freely across host worker threads
        let (compute, _) = Backend::Native.resolve().unwrap();
        let handle = std::thread::spawn(move || {
            let m = crate::linalg::Matrix::identity(3);
            compute.gram(&m).unwrap().data
        });
        assert_eq!(handle.join().unwrap(), crate::linalg::Matrix::identity(3).data);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_errors_without_the_feature() {
        assert!(Backend::Pjrt.resolve().is_err());
    }
}
