//! Fluent construction of a [`TsqrSession`]: cluster, disk model, fault
//! policy, compute backend, and tuning knobs in one place.

use super::TsqrSession;
use crate::coordinator::CoordOpts;
use crate::dfs::DiskModel;
use crate::mapreduce::{ClusterConfig, Engine, FaultPolicy};
use crate::runtime::{BlockCompute, NativeRuntime};
use anyhow::Result;
use std::rc::Rc;

/// Compute-backend selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// PJRT when the crate was built with the `pjrt` feature *and* the
    /// AOT artifacts exist on disk; the pure-rust oracle otherwise.
    Auto,
    /// The pure-rust [`NativeRuntime`] (always available).
    Native,
    /// The PJRT/XLA artifact path; errors when the build lacks the
    /// `pjrt` feature or the artifacts are missing.
    Pjrt,
}

impl Backend {
    /// Resolve to a concrete (shareable) compute backend plus a short
    /// human-readable name. Sessions sharing one resolved backend reuse
    /// its compiled-executable cache — build it once, clone the `Rc`
    /// into as many sessions as needed.
    pub fn resolve(self) -> Result<(Rc<dyn BlockCompute>, &'static str)> {
        match self {
            Backend::Native => Ok((Rc::new(NativeRuntime), "native")),
            Backend::Auto => {
                #[cfg(feature = "pjrt")]
                {
                    let dir = crate::runtime::Manifest::default_dir();
                    if dir.join("manifest.tsv").exists() {
                        let rt = crate::runtime::PjrtRuntime::from_default_artifacts()?;
                        return Ok((Rc::new(rt), "pjrt"));
                    }
                }
                Ok((Rc::new(NativeRuntime), "native"))
            }
            Backend::Pjrt => {
                #[cfg(feature = "pjrt")]
                {
                    let rt = crate::runtime::PjrtRuntime::from_default_artifacts()?;
                    return Ok((Rc::new(rt), "pjrt"));
                }
                #[cfg(not(feature = "pjrt"))]
                anyhow::bail!(
                    "this build has no PJRT support — rebuild with `--features pjrt` \
                     (and run `make artifacts`)"
                );
            }
        }
    }
}

/// Builder for [`TsqrSession`] — see the [`crate::session`] module docs
/// for the full tour.
pub struct SessionBuilder {
    model: DiskModel,
    cluster: ClusterConfig,
    faults: Option<(FaultPolicy, u64)>,
    backend: Backend,
    compute: Option<Rc<dyn BlockCompute>>,
    opts: CoordOpts,
}

impl SessionBuilder {
    pub(crate) fn new() -> Self {
        SessionBuilder {
            model: DiskModel::icme_like(),
            cluster: ClusterConfig::default(),
            faults: None,
            backend: Backend::Auto,
            compute: None,
            opts: CoordOpts::default(),
        }
    }

    /// Disk-bandwidth model for the virtual clock (default:
    /// [`DiskModel::icme_like`], the paper's fitted cluster).
    pub fn disk_model(mut self, model: DiskModel) -> Self {
        self.model = model;
        self
    }

    /// Map/reduce slot counts (default: the paper's 40/40).
    pub fn cluster(mut self, cluster: ClusterConfig) -> Self {
        self.cluster = cluster;
        self
    }

    /// Inject task faults with Hadoop retry semantics (paper Fig. 7).
    pub fn fault_policy(mut self, policy: FaultPolicy, seed: u64) -> Self {
        self.faults = Some((policy, seed));
        self
    }

    /// Compute-backend selector (default: [`Backend::Auto`]).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Share an already-resolved backend (see [`Backend::resolve`]) or
    /// plug in a custom [`BlockCompute`] implementation.
    pub fn compute(mut self, compute: Rc<dyn BlockCompute>) -> Self {
        self.compute = Some(compute);
        self
    }

    /// Rows per step-1 map task (default 1000).
    pub fn rows_per_task(mut self, rows: usize) -> Self {
        self.opts.rows_per_task = rows;
        self
    }

    /// Reduce tasks for shuffling stages (default 40, the paper's r_max).
    pub fn reduce_tasks(mut self, tasks: usize) -> Self {
        self.opts.reduce_tasks = tasks;
        self
    }

    /// Step-2 gather limit in rows — small values force the recursive
    /// Direct TSQR (paper Alg. 2).
    pub fn gather_limit(mut self, rows: usize) -> Self {
        self.opts.gather_limit = Some(rows);
        self
    }

    /// Assemble the session.
    pub fn build(self) -> Result<TsqrSession> {
        let (compute, backend_desc) = match self.compute {
            Some(c) => (c, "custom"),
            None => self.backend.resolve()?,
        };
        let mut engine = Engine::new(self.model, self.cluster);
        if let Some((policy, seed)) = self.faults {
            engine = engine.with_faults(policy, seed);
        }
        Ok(TsqrSession {
            engine: Some(engine),
            compute,
            backend_desc,
            opts: self.opts,
            seq: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_always_resolves() {
        let (_, desc) = Backend::Native.resolve().unwrap();
        assert_eq!(desc, "native");
    }

    #[test]
    fn builder_knobs_reach_the_session() {
        let s = TsqrSession::builder()
            .backend(Backend::Native)
            .rows_per_task(123)
            .reduce_tasks(7)
            .gather_limit(99)
            .build()
            .unwrap();
        assert_eq!(s.opts.rows_per_task, 123);
        assert_eq!(s.opts.reduce_tasks, 7);
        assert_eq!(s.opts.gather_limit, Some(99));
        assert_eq!(s.backend_desc(), "native");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_errors_without_the_feature() {
        assert!(Backend::Pjrt.resolve().is_err());
    }
}
