//! Streaming ingestion: row chunks into the DFS without materializing
//! the full matrix.
//!
//! [`MatrixWriter`] buffers rows and appends them to the session's DFS
//! file in bounded batches, so a terabyte-class tall-and-skinny matrix
//! can be staged with O(batch) memory — the same layout
//! [`crate::workload::put_matrix`] produces (one row record per matrix
//! row, keyed by 32-byte global row id).
//!
//! [`StreamingWriter`] goes one step further: it never stages the rows
//! at all. Each pushed chunk folds into a running `R`
//! ([`crate::stream::RFold`]), so R/Σ of an unbounded stream costs one
//! pass and `O(n²)` resident state — and with
//! [`retain_q`](StreamingWriter::retain_q) the leaf `Q` factors spill
//! to the DFS as chunk recipes that
//! [`finalize_qr`](StreamingWriter::finalize_qr) replays
//! Direct-TSQR-style into a full `Q`.

use crate::coordinator::MatrixHandle;
use crate::dfs::records::{encode_row, row_key, Record};
use crate::dfs::Dfs;
use crate::linalg::Matrix;
use crate::stream::{FoldStats, RFold};
use anyhow::{ensure, Result};

/// Rows buffered before each DFS append.
const FLUSH_EVERY: usize = 4096;

/// An in-progress streaming ingestion. Obtain via
/// [`crate::session::TsqrSession::ingest`]; call [`finish`](Self::finish)
/// to get the [`MatrixHandle`] the factorization APIs consume.
///
/// Creating a writer truncates any existing DFS file of the same name.
/// Every pushed row is durable: the buffered tail is flushed on
/// [`finish`](Self::finish) *and* on drop, so a writer abandoned by an
/// early `?` return leaves a well-formed (if partial) row file rather
/// than silently losing up to a batch of rows.
pub struct MatrixWriter<'s> {
    dfs: &'s mut Dfs,
    file: String,
    cols: usize,
    next_row: u64,
    buf: Vec<Record>,
}

impl<'s> MatrixWriter<'s> {
    pub(crate) fn new(dfs: &'s mut Dfs, name: &str, cols: usize) -> MatrixWriter<'s> {
        // fresh file: streaming appends follow
        dfs.put(name, Vec::new());
        MatrixWriter {
            dfs,
            file: name.to_string(),
            cols,
            next_row: 0,
            buf: Vec::with_capacity(FLUSH_EVERY),
        }
    }

    /// Append one row (must match the declared width).
    pub fn push_row(&mut self, row: &[f64]) -> Result<()> {
        ensure!(
            row.len() == self.cols,
            "row width {} != declared cols {}",
            row.len(),
            self.cols
        );
        self.buf.push(Record::new(row_key(self.next_row), encode_row(row)));
        self.next_row += 1;
        if self.buf.len() >= FLUSH_EVERY {
            self.flush();
        }
        Ok(())
    }

    /// Append a block of rows.
    pub fn push_chunk(&mut self, chunk: &Matrix) -> Result<()> {
        ensure!(
            chunk.cols == self.cols,
            "chunk width {} != declared cols {}",
            chunk.cols,
            self.cols
        );
        for i in 0..chunk.rows {
            self.push_row(chunk.row(i))?;
        }
        Ok(())
    }

    /// Rows written so far.
    pub fn rows_written(&self) -> usize {
        self.next_row as usize
    }

    fn flush(&mut self) {
        if !self.buf.is_empty() {
            self.dfs.append(&self.file, std::mem::take(&mut self.buf));
        }
    }

    /// Flush the tail and return the handle for factorization requests.
    pub fn finish(mut self) -> MatrixHandle {
        self.flush();
        MatrixHandle::new(&self.file, self.next_row as usize, self.cols)
    }
}

impl Drop for MatrixWriter<'_> {
    fn drop(&mut self) {
        self.flush();
    }
}

/// A single-pass streaming factorization in progress. Obtain via
/// [`crate::session::TsqrSession::stream`].
///
/// Unlike [`MatrixWriter`], nothing is staged under the stream's name:
/// rows fold into a running `R` as they arrive
/// ([`crate::stream::RFold`]), so the raw input never exists in the
/// DFS and an abandoned writer leaves **no partial matrix visible** —
/// dropping mid-stream deletes any spilled chunk recipes and the
/// stream's name never resolves to a file.
///
/// `R`/Σ come straight out of [`finalize_r`](Self::finalize_r) /
/// [`finalize_sigma`](Self::finalize_sigma) after the last row, one
/// pass total. Full `Q` needs [`retain_q`](Self::retain_q) before the
/// first row: factored leaf `Q`s then spill to
/// `<ns>stream/<name>/q1-*` as they form, and
/// [`finalize_qr`](Self::finalize_qr) replays the Direct-TSQR
/// Q-formation over the fold tree, writing `<ns>stream/<name>/Q`.
pub struct StreamingWriter<'s> {
    dfs: &'s mut Dfs,
    /// Spill namespace: `<session-ns>stream/<name>/`.
    ns: String,
    cols: usize,
    fold: RFold,
    spilled: bool,
    finished: bool,
}

impl<'s> StreamingWriter<'s> {
    pub(crate) fn new(
        dfs: &'s mut Dfs,
        session_ns: &str,
        name: &str,
        cols: usize,
        chunk_rows: usize,
    ) -> StreamingWriter<'s> {
        StreamingWriter {
            dfs,
            ns: format!("{session_ns}stream/{name}/"),
            cols,
            fold: RFold::new(cols, chunk_rows),
            spilled: false,
            finished: false,
        }
    }

    /// Keep the chunk recipes needed for a full `Q`. Must be called
    /// before the first row; errors afterwards.
    pub fn retain_q(mut self) -> Result<Self> {
        self.fold.record_q()?;
        Ok(self)
    }

    /// Rows streamed so far.
    pub fn rows(&self) -> u64 {
        self.fold.rows()
    }

    /// Running pass/size accounting.
    pub fn stats(&self) -> &FoldStats {
        self.fold.stats()
    }

    /// Fold one row into the stream.
    pub fn push_row(&mut self, row: &[f64]) -> Result<()> {
        self.fold.push_row(row)?;
        self.drain_spill();
        Ok(())
    }

    /// Fold a chunk of rows (any height — bits never depend on the
    /// arrival chunking).
    pub fn push_chunk(&mut self, chunk: &Matrix) -> Result<()> {
        self.fold.push_chunk(chunk)?;
        self.drain_spill();
        Ok(())
    }

    fn spill_file(&self, index: usize) -> String {
        format!("{}q1-{index:08}", self.ns)
    }

    fn drain_spill(&mut self) {
        for (index, q) in self.fold.drain_leaf_q() {
            let file = self.spill_file(index);
            crate::workload::put_matrix(self.dfs, &file, &q);
            self.spilled = true;
        }
    }

    fn take_fold(&mut self) -> RFold {
        std::mem::replace(&mut self.fold, RFold::new(self.cols, 1))
    }

    /// Finish the stream and return `R` (possibly ragged `m×n` if
    /// fewer than `n` rows arrived) plus the pass accounting. Any
    /// spilled chunk recipes are discarded.
    pub fn finalize_r(mut self) -> Result<(Matrix, FoldStats)> {
        let (r, stats) = self.take_fold().finish_r()?;
        if self.spilled {
            self.dfs.delete_prefix(&self.ns);
        }
        self.finished = true;
        Ok((r, stats))
    }

    /// Finish the stream and return `(R, Σ)` — Σ descending, computed
    /// from the streamed `R` (same singular values as the stream).
    pub fn finalize_sigma(mut self) -> Result<(Matrix, Vec<f64>, FoldStats)> {
        let (r, stats) = self.take_fold().finish_r()?;
        ensure!(
            r.rows == r.cols,
            "singular values need at least {} rows streamed (got {})",
            self.cols,
            stats.rows
        );
        if self.spilled {
            self.dfs.delete_prefix(&self.ns);
        }
        self.finished = true;
        let sigma = crate::stream::sigma_from_r(&r);
        Ok((r, sigma, stats))
    }

    /// Finish the stream and form the full thin `Q` by replaying the
    /// Direct-TSQR Q-formation over the fold tree: each spilled leaf
    /// `Q` is multiplied by its tree transform and appended to
    /// `<ns>stream/<name>/Q` in row order; spills are deleted as they
    /// are consumed. Requires [`retain_q`](Self::retain_q) and at
    /// least `cols` rows.
    pub fn finalize_qr(mut self) -> Result<(MatrixHandle, Matrix, FoldStats)> {
        ensure!(
            self.fold.records_q(),
            "finalize_qr needs retain_q() before the first row (R-only streams keep no chunk recipes)"
        );
        let (r, tree, stats) = self.take_fold().finish_tree()?;
        ensure!(
            r.rows == r.cols,
            "full Q needs at least {} rows streamed (got {})",
            self.cols,
            stats.rows
        );
        let qfile = format!("{}Q", self.ns);
        self.dfs.put(&qfile, Vec::new());
        let mut next_row = 0u64;
        for t in tree.leaf_transforms() {
            let part = if t.factored {
                let q1 = crate::workload::get_matrix(self.dfs, &self.spill_file(t.index), self.cols)?;
                q1.matmul(&t.transform)
            } else {
                t.transform
            };
            debug_assert_eq!(part.rows, t.rows);
            let recs: Vec<Record> = (0..part.rows)
                .map(|i| Record::new(row_key(next_row + i as u64), encode_row(part.row(i))))
                .collect();
            next_row += part.rows as u64;
            self.dfs.append(&qfile, recs);
            self.dfs.delete(&self.spill_file(t.index));
        }
        self.finished = true;
        let q = MatrixHandle::new(&qfile, next_row as usize, r.rows);
        Ok((q, r, stats))
    }
}

impl Drop for StreamingWriter<'_> {
    fn drop(&mut self) {
        // Abandoned mid-stream: leave nothing visible. (After a
        // finalize_* the outputs must survive — only spill cleanup has
        // already happened there.)
        if !self.finished && self.spilled {
            self.dfs.delete_prefix(&self.ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::{get_matrix, put_matrix};

    #[test]
    fn streamed_rows_match_put_matrix_layout() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(10, 3, &mut rng);
        let mut dfs = Dfs::new();
        put_matrix(&mut dfs, "ref", &a);

        let mut w = MatrixWriter::new(&mut dfs, "streamed", 3);
        for i in 0..a.rows {
            w.push_row(a.row(i)).unwrap();
        }
        assert_eq!(w.rows_written(), 10);
        let h = w.finish();
        assert_eq!((h.rows, h.cols), (10, 3));

        assert_eq!(dfs.get("streamed").unwrap(), dfs.get("ref").unwrap());
    }

    #[test]
    fn flushes_in_bounded_batches() {
        let rows = 2 * FLUSH_EVERY + 17;
        let mut dfs = Dfs::new();
        let mut w = MatrixWriter::new(&mut dfs, "big", 2);
        for i in 0..rows {
            w.push_row(&[i as f64, -(i as f64)]).unwrap();
            // O(batch) memory: the buffer never holds a full batch
            assert!(w.buf.len() < FLUSH_EVERY, "buffer grew to {}", w.buf.len());
        }
        let h = w.finish();
        assert_eq!(h.rows, rows);
        assert_eq!(dfs.file_records("big").unwrap(), rows);
        let back = get_matrix(&dfs, "big", 2).unwrap();
        assert_eq!(back[(FLUSH_EVERY, 0)], FLUSH_EVERY as f64);
    }

    #[test]
    fn re_ingesting_overwrites_stale_rows() {
        let mut dfs = Dfs::new();
        let mut w = MatrixWriter::new(&mut dfs, "A", 1);
        for _ in 0..5 {
            w.push_row(&[1.0]).unwrap();
        }
        w.finish();
        let mut w = MatrixWriter::new(&mut dfs, "A", 1);
        w.push_row(&[2.0]).unwrap();
        let h = w.finish();
        assert_eq!(h.rows, 1);
        assert_eq!(dfs.file_records("A").unwrap(), 1);
    }

    #[test]
    fn dropped_writer_flushes_its_tail() {
        let mut dfs = Dfs::new();
        {
            let mut w = MatrixWriter::new(&mut dfs, "partial", 2);
            for i in 0..10 {
                w.push_row(&[i as f64, 0.0]).unwrap();
            }
            // no finish(): simulates an early `?` return unwinding past
            // the writer
        }
        assert_eq!(dfs.file_records("partial").unwrap(), 10);
        let back = get_matrix(&dfs, "partial", 2).unwrap();
        assert_eq!(back[(9, 0)], 9.0);
    }

    #[test]
    fn width_mismatches_are_rejected() {
        let mut dfs = Dfs::new();
        let mut w = MatrixWriter::new(&mut dfs, "A", 3);
        assert!(w.push_row(&[1.0, 2.0]).is_err());
        let mut rng = Rng::new(2);
        let chunk = Matrix::gaussian(4, 2, &mut rng);
        assert!(w.push_chunk(&chunk).is_err());
    }
}
