//! Streaming ingestion: row chunks into the DFS without materializing
//! the full matrix.
//!
//! [`MatrixWriter`] buffers rows and appends them to the session's DFS
//! file in bounded batches, so a terabyte-class tall-and-skinny matrix
//! can be staged with O(batch) memory — the same layout
//! [`crate::workload::put_matrix`] produces (one row record per matrix
//! row, keyed by 32-byte global row id).

use crate::coordinator::MatrixHandle;
use crate::dfs::records::{encode_row, row_key, Record};
use crate::dfs::Dfs;
use crate::linalg::Matrix;
use anyhow::{ensure, Result};

/// Rows buffered before each DFS append.
const FLUSH_EVERY: usize = 4096;

/// An in-progress streaming ingestion. Obtain via
/// [`crate::session::TsqrSession::ingest`]; call [`finish`](Self::finish)
/// to get the [`MatrixHandle`] the factorization APIs consume.
///
/// Creating a writer truncates any existing DFS file of the same name.
/// Every pushed row is durable: the buffered tail is flushed on
/// [`finish`](Self::finish) *and* on drop, so a writer abandoned by an
/// early `?` return leaves a well-formed (if partial) row file rather
/// than silently losing up to a batch of rows.
pub struct MatrixWriter<'s> {
    dfs: &'s mut Dfs,
    file: String,
    cols: usize,
    next_row: u64,
    buf: Vec<Record>,
}

impl<'s> MatrixWriter<'s> {
    pub(crate) fn new(dfs: &'s mut Dfs, name: &str, cols: usize) -> MatrixWriter<'s> {
        // fresh file: streaming appends follow
        dfs.put(name, Vec::new());
        MatrixWriter {
            dfs,
            file: name.to_string(),
            cols,
            next_row: 0,
            buf: Vec::with_capacity(FLUSH_EVERY),
        }
    }

    /// Append one row (must match the declared width).
    pub fn push_row(&mut self, row: &[f64]) -> Result<()> {
        ensure!(
            row.len() == self.cols,
            "row width {} != declared cols {}",
            row.len(),
            self.cols
        );
        self.buf.push(Record::new(row_key(self.next_row), encode_row(row)));
        self.next_row += 1;
        if self.buf.len() >= FLUSH_EVERY {
            self.flush();
        }
        Ok(())
    }

    /// Append a block of rows.
    pub fn push_chunk(&mut self, chunk: &Matrix) -> Result<()> {
        ensure!(
            chunk.cols == self.cols,
            "chunk width {} != declared cols {}",
            chunk.cols,
            self.cols
        );
        for i in 0..chunk.rows {
            self.push_row(chunk.row(i))?;
        }
        Ok(())
    }

    /// Rows written so far.
    pub fn rows_written(&self) -> usize {
        self.next_row as usize
    }

    fn flush(&mut self) {
        if !self.buf.is_empty() {
            self.dfs.append(&self.file, std::mem::take(&mut self.buf));
        }
    }

    /// Flush the tail and return the handle for factorization requests.
    pub fn finish(mut self) -> MatrixHandle {
        self.flush();
        MatrixHandle::new(&self.file, self.next_row as usize, self.cols)
    }
}

impl Drop for MatrixWriter<'_> {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::{get_matrix, put_matrix};

    #[test]
    fn streamed_rows_match_put_matrix_layout() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(10, 3, &mut rng);
        let mut dfs = Dfs::new();
        put_matrix(&mut dfs, "ref", &a);

        let mut w = MatrixWriter::new(&mut dfs, "streamed", 3);
        for i in 0..a.rows {
            w.push_row(a.row(i)).unwrap();
        }
        assert_eq!(w.rows_written(), 10);
        let h = w.finish();
        assert_eq!((h.rows, h.cols), (10, 3));

        assert_eq!(dfs.get("streamed").unwrap(), dfs.get("ref").unwrap());
    }

    #[test]
    fn flushes_in_bounded_batches() {
        let rows = 2 * FLUSH_EVERY + 17;
        let mut dfs = Dfs::new();
        let mut w = MatrixWriter::new(&mut dfs, "big", 2);
        for i in 0..rows {
            w.push_row(&[i as f64, -(i as f64)]).unwrap();
            // O(batch) memory: the buffer never holds a full batch
            assert!(w.buf.len() < FLUSH_EVERY, "buffer grew to {}", w.buf.len());
        }
        let h = w.finish();
        assert_eq!(h.rows, rows);
        assert_eq!(dfs.file_records("big").unwrap(), rows);
        let back = get_matrix(&dfs, "big", 2).unwrap();
        assert_eq!(back[(FLUSH_EVERY, 0)], FLUSH_EVERY as f64);
    }

    #[test]
    fn re_ingesting_overwrites_stale_rows() {
        let mut dfs = Dfs::new();
        let mut w = MatrixWriter::new(&mut dfs, "A", 1);
        for _ in 0..5 {
            w.push_row(&[1.0]).unwrap();
        }
        w.finish();
        let mut w = MatrixWriter::new(&mut dfs, "A", 1);
        w.push_row(&[2.0]).unwrap();
        let h = w.finish();
        assert_eq!(h.rows, 1);
        assert_eq!(dfs.file_records("A").unwrap(), 1);
    }

    #[test]
    fn dropped_writer_flushes_its_tail() {
        let mut dfs = Dfs::new();
        {
            let mut w = MatrixWriter::new(&mut dfs, "partial", 2);
            for i in 0..10 {
                w.push_row(&[i as f64, 0.0]).unwrap();
            }
            // no finish(): simulates an early `?` return unwinding past
            // the writer
        }
        assert_eq!(dfs.file_records("partial").unwrap(), 10);
        let back = get_matrix(&dfs, "partial", 2).unwrap();
        assert_eq!(back[(9, 0)], 9.0);
    }

    #[test]
    fn width_mismatches_are_rejected() {
        let mut dfs = Dfs::new();
        let mut w = MatrixWriter::new(&mut dfs, "A", 3);
        assert!(w.push_row(&[1.0, 2.0]).is_err());
        let mut rng = Rng::new(2);
        let chunk = Matrix::gaussian(4, 2, &mut rng);
        assert!(w.push_chunk(&chunk).is_err());
    }
}
