//! L4 — the session layer, the crate's primary API.
//!
//! The paper's pitch is that one stable algorithm (Direct TSQR) serves
//! QR and SVD alike "with only a small change"; this layer gives that a
//! single ergonomic front door. A [`TsqrSession`] bundles what used to
//! be five hand-assembled structs (`DiskModel`, `ClusterConfig`,
//! `Engine`, `CoordOpts`, `DirectOpts`) behind one builder, ingest
//! methods stream matrices into the simulated DFS, and one
//! request/response pair — [`FactorizationRequest`] →
//! [`Factorization`] — replaces the three differently-shaped
//! `Coordinator` entry points:
//!
//! ```no_run
//! use mrtsqr::session::{FactorizationRequest, TsqrSession};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = TsqrSession::builder().build()?;
//! let a = session.ingest_gaussian("A", 100_000, 25, 42)?;
//! let fact = session.factorize(&a, &FactorizationRequest::qr())?;
//! println!("ran {} in {:.1} virtual s", fact.algorithm.name(), fact.stats.virtual_secs());
//! # Ok(())
//! # }
//! ```
//!
//! With the default [`AlgoChoice::Auto`] policy the session estimates
//! κ₂(A) from a one-pass Indirect-TSQR probe; for well-conditioned
//! inputs it *reuses* the probe's `R` and finishes `Q = A·R⁻¹` in one
//! more pass (two passes over A total, κ·ε orthogonality), and for
//! everything else it runs the unconditionally stable Direct TSQR. The
//! decision — including the [`AutoDecision::probe_reused`] flag — is
//! recorded in [`Factorization::auto`] and as a marker step in the
//! stats. The old [`Coordinator`] remains the internal execution layer.
//!
//! Sessions also own the *host parallelism* knob
//! ([`SessionBuilder::host_threads`]): map/reduce waves execute on a
//! real thread pool with bit-identical results at any pool size.
//!
//! A session serves one caller at a time; for concurrent multi-request
//! serving over one shared cluster, build a
//! [`crate::service::TsqrService`] from the same
//! [`SessionBuilder`] ([`SessionBuilder::build_service`]) — `factorize`
//! here and `submit`/`wait` there run the *same* execution path
//! (the crate-internal `exec` module), so a session is exactly a job
//! service degenerated to
//! inline execution.

mod builder;
pub(crate) mod exec;
mod ingest;
mod request;
mod select;

pub use builder::{Backend, SessionBuilder};
pub use ingest::{MatrixWriter, StreamingWriter};
pub use request::{
    AlgoChoice, FactorizationRequest, Placement, Priority, SubmitOptions, Want,
    DEFAULT_CONDITION_THRESHOLD,
};
pub use select::{estimate_condition, AutoDecision, SketchChoice};

pub use crate::coordinator::MatrixHandle;

use crate::coordinator::direct_tsqr::SvdParts;
use crate::coordinator::{Algorithm, Coordinator, CoordOpts};
use crate::dfs::Dfs;
use crate::linalg::Matrix;
use crate::mapreduce::{Engine, JobStats};
use crate::runtime::SharedCompute;
use crate::util::rng::Rng;
use crate::workload;
use anyhow::Result;

/// The unified result of any [`TsqrSession::factorize`] call (and of
/// every [`crate::service::JobHandle::wait`]).
#[derive(Debug, Clone)]
pub struct Factorization {
    /// Orthogonal factor (or `QU` for SVD requests) left in the DFS as
    /// row records; `None` for R-only algorithms/requests. The handle
    /// points into the namespace the request ran under — a session's
    /// configured namespace (default `""`, i.e. `tmp/…`) or, through a
    /// job service, the submitting job's private `job-<id>/tmp/…`
    /// prefix — and stays readable for the lifetime of the owning
    /// session/service cluster: nothing else writes into that
    /// namespace, and the service only deletes it on an explicit
    /// [`crate::service::TsqrService::evict_job`].
    pub q: Option<MatrixHandle>,
    /// The `n×n` triangular factor.
    pub r: Matrix,
    /// Σ and V for SVD/singular-value requests (truncated to `rank`
    /// for `Want::LowRank`).
    pub svd: Option<SvdParts>,
    /// The `n×rhs` least-squares solution(s) for `Want::Solve`
    /// requests; `None` otherwise.
    pub solution: Option<Matrix>,
    /// The algorithm that actually ran.
    pub algorithm: Algorithm,
    /// The recorded `Auto` decision (`None` for `Fixed` requests).
    pub auto: Option<AutoDecision>,
    /// Per-step metrics, probe passes included.
    pub stats: JobStats,
}

impl Factorization {
    /// Singular values, when the request computed them.
    pub fn sigma(&self) -> Option<&[f64]> {
        self.svd.as_ref().map(|s| s.sigma.as_slice())
    }

    /// FNV-1a digest of the result's numerical content: `R`'s shape and
    /// exact bit patterns plus Σ (when present) plus the least-squares
    /// solution (when present). Two runs of the same request agree on
    /// this hex string iff their factors are bit-identical —
    /// `mrtsqr batch --json` emits it per job so CI can
    /// diff a `--shards 1` report against a `--shards 4` report with
    /// one `grep | diff` (wall-clock fields differ; digests must not).
    pub fn result_digest(&self) -> String {
        crate::util::digest::full_digest(&self.r, self.sigma(), self.solution.as_ref())
    }
}

/// A factorization session: owns the simulated cluster (engine + DFS)
/// and a shareable compute backend. Build with [`TsqrSession::builder`].
pub struct TsqrSession {
    /// `None` only transiently while a coordinator borrows the engine.
    engine: Option<Engine>,
    compute: SharedCompute,
    backend_desc: &'static str,
    opts: CoordOpts,
    seq: usize,
    /// DFS namespace prefix for this session's temp files (see
    /// [`SessionBuilder::namespace`]).
    ns: String,
}

impl TsqrSession {
    /// Start configuring a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// A default session on the pure-rust backend (tests, quick runs).
    pub fn native() -> TsqrSession {
        Self::builder()
            .backend(Backend::Native)
            .build()
            .expect("native session construction cannot fail")
    }

    /// Short name of the resolved compute backend ("native", "pjrt",
    /// "custom").
    pub fn backend_desc(&self) -> &'static str {
        self.backend_desc
    }

    /// Clone the resolved backend to share with other sessions or
    /// threads (reuses compiled-executable caches across all of them).
    pub fn compute_handle(&self) -> SharedCompute {
        self.compute.clone()
    }

    /// Configured host worker-thread count for task execution (see
    /// [`SessionBuilder::host_threads`]). The *realized* per-request
    /// parallelism lands in [`JobStats::host_threads`].
    pub fn host_threads(&self) -> usize {
        self.engine
            .as_ref()
            .expect("session engine poisoned")
            .cluster
            .host_threads
    }

    /// The session's simulated DFS (read results, inspect byte totals).
    pub fn dfs(&self) -> &Dfs {
        &self.engine.as_ref().expect("session engine poisoned").dfs
    }

    /// Mutable DFS access (advanced: pre-staged files, cleanup).
    pub fn dfs_mut(&mut self) -> &mut Dfs {
        &mut self.engine.as_mut().expect("session engine poisoned").dfs
    }

    /// Mark a DFS file's virtual byte scale (scaled-down reproductions
    /// of paper-sized workloads; see `DESIGN.md` §2).
    pub fn set_scale(&mut self, name: &str, scale: f64) {
        self.dfs_mut().set_scale(name, scale);
    }

    // ------------------------------------------------------ ingestion

    /// Stream a matrix into the DFS chunk by chunk without materializing
    /// it; call [`MatrixWriter::finish`] for the handle.
    pub fn ingest(&mut self, name: &str, cols: usize) -> MatrixWriter<'_> {
        MatrixWriter::new(self.dfs_mut(), name, cols)
    }

    /// Open a **single-pass streaming factorization**: rows fold into a
    /// running `R` as they arrive ([`crate::stream::RFold`]) instead of
    /// being staged, so R/Σ of an unbounded stream costs one pass and
    /// `O(n²)` resident state — the raw input never exists in the DFS.
    /// Leaf block height comes from
    /// [`SessionBuilder::stream_chunk_rows`]; the arrival chunking
    /// never changes bits. Call
    /// [`StreamingWriter::retain_q`] before the first row if the full
    /// `Q` will be needed.
    pub fn stream(&mut self, name: &str, cols: usize) -> StreamingWriter<'_> {
        let ns = self.ns.clone();
        let chunk_rows = self.opts.stream_chunk_rows;
        StreamingWriter::new(self.dfs_mut(), &ns, name, cols, chunk_rows)
    }

    /// Ingest an in-memory matrix (subsumes `workload::put_matrix`).
    pub fn ingest_matrix(&mut self, name: &str, a: &Matrix) -> Result<MatrixHandle> {
        let mut w = self.ingest(name, a.cols);
        w.push_chunk(a)?;
        Ok(w.finish())
    }

    /// Ingest a seeded gaussian `rows × cols` matrix one row at a time
    /// (subsumes `workload::gaussian_matrix`; identical records for the
    /// same seed).
    pub fn ingest_gaussian(
        &mut self,
        name: &str,
        rows: usize,
        cols: usize,
        seed: u64,
    ) -> Result<MatrixHandle> {
        let mut rng = Rng::new(seed);
        let mut w = self.ingest(name, cols);
        let mut row = vec![0.0f64; cols];
        for _ in 0..rows {
            for v in row.iter_mut() {
                *v = rng.gaussian();
            }
            w.push_row(&row)?;
        }
        Ok(w.finish())
    }

    /// Read a handle's rows back into memory (verification, small
    /// factors).
    pub fn get_matrix(&self, handle: &MatrixHandle) -> Result<Matrix> {
        workload::get_matrix(self.dfs(), &handle.file, handle.cols)
    }

    // --------------------------------------------------- factorization

    /// Run one factorization request. See [`FactorizationRequest`] for
    /// the knobs and [`Factorization`] for what comes back.
    ///
    /// This is a submit + wait with nothing queued: the request runs
    /// inline on the session's private engine through the *same*
    /// execution path a [`crate::service::TsqrService`] worker uses
    /// ([`exec::execute`]), so session and service results are
    /// identical by construction.
    pub fn factorize(
        &mut self,
        input: &MatrixHandle,
        req: &FactorizationRequest,
    ) -> Result<Factorization> {
        self.with_coordinator(|c| exec::execute(c, input, req))
    }

    /// Convenience: full QR with auto-selection.
    pub fn qr(&mut self, input: &MatrixHandle) -> Result<Factorization> {
        self.factorize(input, &FactorizationRequest::qr())
    }

    /// Convenience: full QR with a pinned algorithm.
    pub fn qr_with(&mut self, input: &MatrixHandle, algo: Algorithm) -> Result<Factorization> {
        self.factorize(input, &FactorizationRequest::qr().with_algorithm(algo))
    }

    /// Convenience: tall-and-skinny SVD (`A = (QU) Σ Vᵀ`).
    pub fn svd(&mut self, input: &MatrixHandle) -> Result<Factorization> {
        self.factorize(input, &FactorizationRequest::svd())
    }

    /// Convenience: singular values only.
    pub fn singular_values(&mut self, input: &MatrixHandle) -> Result<Factorization> {
        self.factorize(input, &FactorizationRequest::singular_values())
    }

    /// Convenience: rank-`rank` truncated SVD with the default sketch
    /// (auto-gated randomized vs exact; see [`crate::sketch`]).
    pub fn low_rank(&mut self, input: &MatrixHandle, rank: usize) -> Result<Factorization> {
        self.factorize(input, &FactorizationRequest::low_rank(rank))
    }

    /// Convenience: least squares against the input's trailing column
    /// (the input must be the augmented `[A b]`; see
    /// [`FactorizationRequest::solve`]).
    pub fn solve(&mut self, input: &MatrixHandle) -> Result<Factorization> {
        self.factorize(input, &FactorizationRequest::solve())
    }

    /// Run `f` against the internal execution layer (a [`Coordinator`]
    /// borrowing this session's engine and backend). Crate-internal
    /// escape hatch for benches/experiments that drive raw pipelines.
    pub(crate) fn with_coordinator<T>(
        &mut self,
        f: impl FnOnce(&mut Coordinator) -> Result<T>,
    ) -> Result<T> {
        let engine = self.engine.take().expect("session engine poisoned");
        let mut coord = Coordinator::new(engine, &*self.compute)
            .with_opts(self.opts)
            .with_namespace(self.ns.clone());
        coord.seq = self.seq;
        let out = f(&mut coord);
        self.seq = coord.seq;
        self.engine = Some(coord.into_engine());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix_with_condition;
    use crate::workload::gaussian_matrix;

    fn recon_err(a: &Matrix, q: &Matrix, r: &Matrix) -> f64 {
        a.sub(&q.matmul(r)).frob_norm() / a.frob_norm()
    }

    #[test]
    fn ingest_gaussian_matches_workload_generator() {
        let mut s = TsqrSession::native();
        let h = s.ingest_gaussian("A", 100, 5, 42).unwrap();
        assert_eq!((h.rows, h.cols), (100, 5));
        let mut dfs = Dfs::new();
        gaussian_matrix(&mut dfs, "A", 100, 5, 42);
        assert_eq!(s.dfs().get("A").unwrap(), dfs.get("A").unwrap());
    }

    #[test]
    fn fixed_direct_qr_round_trips() {
        let mut s = TsqrSession::native();
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(400, 6, &mut rng);
        let h = s.ingest_matrix("A", &a).unwrap();
        let f = s.qr_with(&h, Algorithm::DirectTsqr).unwrap();
        assert_eq!(f.algorithm, Algorithm::DirectTsqr);
        assert!(f.auto.is_none());
        let q = s.get_matrix(f.q.as_ref().unwrap()).unwrap();
        assert!(q.orthogonality_error() < 1e-12);
        assert!(recon_err(&a, &q, &f.r) < 1e-12);
    }

    #[test]
    fn handles_from_successive_requests_stay_distinct() {
        // the session threads the temp-file counter across requests so
        // a second factorization must not clobber the first one's Q
        let mut s = TsqrSession::native();
        let mut rng = Rng::new(2);
        let a = Matrix::gaussian(120, 4, &mut rng);
        let h = s.ingest_matrix("A", &a).unwrap();
        let f1 = s.qr_with(&h, Algorithm::DirectTsqr).unwrap();
        let q1 = s.get_matrix(f1.q.as_ref().unwrap()).unwrap();
        let f2 = s.qr_with(&h, Algorithm::DirectTsqrFused).unwrap();
        assert_ne!(f1.q.as_ref().unwrap().file, f2.q.as_ref().unwrap().file);
        // the first Q is still intact in the DFS
        let q1_again = s.get_matrix(f1.q.as_ref().unwrap()).unwrap();
        assert_eq!(q1.data, q1_again.data);
    }

    #[test]
    fn auto_r_only_is_single_pass() {
        let mut s = TsqrSession::native();
        let h = s.ingest_gaussian("A", 300, 5, 7).unwrap();
        let f = s.factorize(&h, &FactorizationRequest::r_only()).unwrap();
        assert!(f.q.is_none());
        // two tree levels + the zero-cost decision marker
        assert_eq!(f.stats.steps.len(), 3);
        assert!(f.stats.steps[2].name.starts_with("auto-select"));
        // the recorded decision names the algorithm that actually ran
        assert_eq!(f.auto.unwrap().chosen, f.algorithm);
        assert!(f.stats.steps[2].name.contains(f.algorithm.cli_name()));
        let g = f.r.transpose().matmul(&f.r);
        let a = s.get_matrix(&h).unwrap();
        assert!(g.sub(&a.gram()).max_abs() < 1e-10 * a.gram().max_abs());
    }

    #[test]
    fn singular_values_match_direct_svd() {
        let mut s = TsqrSession::native();
        let mut rng = Rng::new(3);
        let sigma_true = vec![8.0, 2.0, 0.5, 0.125];
        let (a, _, _) =
            crate::linalg::matgen::matrix_with_spectrum(256, 4, &sigma_true, &mut rng);
        let h = s.ingest_matrix("A", &a).unwrap();
        let sv = s.singular_values(&h).unwrap();
        for (got, want) in sv.sigma().unwrap().iter().zip(&sigma_true) {
            assert!((got / want - 1.0).abs() < 1e-10, "{got} vs {want}");
        }
        let full = s.svd(&h).unwrap();
        assert_eq!(full.algorithm, Algorithm::DirectTsqr);
        for (got, want) in full.sigma().unwrap().iter().zip(&sigma_true) {
            assert!((got / want - 1.0).abs() < 1e-10, "{got} vs {want}");
        }
        // V agrees up to column signs: |V₁ᵀV₂| = I
        let v1 = &sv.svd.as_ref().unwrap().v;
        let v2 = &full.svd.as_ref().unwrap().v;
        let prod = v1.transpose().matmul(v2);
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)].abs() - want).abs() < 1e-9, "V mismatch at {i},{j}");
            }
        }
    }

    #[test]
    fn svd_rejects_non_direct_algorithms() {
        let mut s = TsqrSession::native();
        let h = s.ingest_gaussian("A", 64, 4, 1).unwrap();
        let req = FactorizationRequest::svd().with_algorithm(Algorithm::Householder);
        let err = s.factorize(&h, &req).unwrap_err();
        assert!(err.to_string().contains("Direct TSQR"), "{err}");
    }

    #[test]
    fn auto_reuses_probe_on_well_conditioned_input() {
        let mut s = TsqrSession::native();
        let h = s.ingest_gaussian("A", 400, 6, 11).unwrap();
        let f = s.qr(&h).unwrap();
        // the probe's R is finished via A·R⁻¹ — i.e. Indirect TSQR
        assert_eq!(f.algorithm, Algorithm::IndirectTsqr { refine: false });
        let d = f.auto.unwrap();
        assert!(d.probe_reused, "well-conditioned branch must reuse the probe");
        assert!(d.kappa_estimate < 1e3, "gaussian kappa ~ O(10), got {}", d.kappa_estimate);
        assert!(f
            .stats
            .steps
            .iter()
            .any(|st| st.name.starts_with("auto-select") && st.name.contains("probe-reused")));
        let a = s.get_matrix(&h).unwrap();
        let q = s.get_matrix(f.q.as_ref().unwrap()).unwrap();
        assert!(recon_err(&a, &q, &f.r) < 1e-12);
        assert!(q.orthogonality_error() < 1e-10);
    }

    /// The probe-reuse satellite's contract: two passes over A instead
    /// of the old three (probe + Cholesky rerun + A·R⁻¹), with
    /// orthogonality still at the κ·ε level the threshold admits.
    #[test]
    fn auto_probe_reuse_cuts_passes_over_a() {
        let mut s = TsqrSession::native();
        let h = s.ingest_gaussian("A", 600, 5, 21).unwrap();
        let a_bytes = s.dfs().file_bytes("A").unwrap();
        let f = s.qr(&h).unwrap();
        assert!(f.auto.unwrap().probe_reused);
        // steps: indirect-level1, indirect-level2, auto-select marker,
        // ar-inv — nothing else
        assert_eq!(f.stats.steps.len(), 4, "{:?}", step_names(&f));
        let passes_over_a = f
            .stats
            .steps
            .iter()
            .filter(|st| st.map_io.bytes_read >= a_bytes)
            .count();
        assert_eq!(passes_over_a, 2, "probe pass + A·R⁻¹ pass only: {:?}", step_names(&f));
        // orthogonality at κ·ε level (κ ≤ threshold=1e3 ⇒ ~1e-13)
        let q = s.get_matrix(f.q.as_ref().unwrap()).unwrap();
        let d = f.auto.unwrap();
        let tol = (d.kappa_estimate * 1e-13).max(1e-11);
        assert!(q.orthogonality_error() < tol, "orth {}", q.orthogonality_error());
    }

    fn step_names(f: &Factorization) -> Vec<&str> {
        f.stats.steps.iter().map(|s| s.name.as_str()).collect()
    }

    #[test]
    fn auto_picks_direct_on_ill_conditioned_input() {
        let mut s = TsqrSession::native();
        let mut rng = Rng::new(4);
        let a = matrix_with_condition(500, 8, 1e12, &mut rng);
        let h = s.ingest_matrix("A", &a).unwrap();
        let f = s.qr(&h).unwrap();
        assert_eq!(f.algorithm, Algorithm::DirectTsqr);
        let d = f.auto.unwrap();
        assert!(d.kappa_estimate > 1e10, "estimate {}", d.kappa_estimate);
        let q = s.get_matrix(f.q.as_ref().unwrap()).unwrap();
        assert!(q.orthogonality_error() < 1e-12);
        assert!(recon_err(&a, &q, &f.r) < 1e-11);
    }

    #[test]
    fn auto_refine_reaches_the_cheap_pick() {
        let mut s = TsqrSession::native();
        let h = s.ingest_gaussian("A", 200, 4, 5).unwrap();
        let f = s.factorize(&h, &FactorizationRequest::qr().refined(true)).unwrap();
        assert_eq!(f.algorithm, Algorithm::IndirectTsqr { refine: true });
        // refinement re-factors the computed Q: more than the bare
        // 2-pass pipeline
        assert!(f.stats.steps.len() > 4);
    }

    #[test]
    fn fault_policy_flows_through_the_builder() {
        use crate::mapreduce::FaultPolicy;
        let mut s = TsqrSession::builder()
            .backend(Backend::Native)
            .fault_policy(
                FaultPolicy { probability: 0.2, max_attempts: 16, waste_fraction: 0.5 },
                99,
            )
            .rows_per_task(20)
            .build()
            .unwrap();
        let h = s.ingest_gaussian("A", 400, 4, 6).unwrap();
        let f = s.qr_with(&h, Algorithm::DirectTsqr).unwrap();
        assert!(f.stats.total_faults() > 0, "faults should fire at p=0.2");
        let q = s.get_matrix(f.q.as_ref().unwrap()).unwrap();
        assert!(q.orthogonality_error() < 1e-12);
    }
}
