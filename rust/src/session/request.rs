//! The unified request type: *what* to compute and *how* to choose the
//! algorithm, in one struct — replacing the three differently-shaped
//! `Coordinator` entry points.

use crate::coordinator::Algorithm;
use crate::sketch::{SketchOptions, DEFAULT_OVERSAMPLE};

/// Which factors the caller wants back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Want {
    /// `A = QR`: `Factorization.q` + `Factorization.r`.
    Qr,
    /// The triangular factor only (no Q pass where the algorithm allows).
    ROnly,
    /// `A = (QU) Σ Vᵀ` (paper §III-B): `q` holds `QU`, `svd` holds Σ, V.
    Svd,
    /// Σ (and V) only — one pass over A plus a serial n×n SVD.
    SingularValues,
    /// Rank-`rank` truncated SVD `A ≈ Û Σ_r V_rᵀ` ([`crate::sketch`]):
    /// `q` holds `Û`, `svd` holds the leading Σ, V. Served by the
    /// randomized range finder (`Fixed(Randomized)`, or `Auto` when the
    /// oversampled width is at most half the columns) or exactly by
    /// truncating the Direct-TSQR SVD.
    LowRank {
        /// Target rank, `1 ..= min(rows, cols)`.
        rank: usize,
        /// Extra sketch columns beyond `rank` (Halko's `p`; default
        /// [`crate::sketch::DEFAULT_OVERSAMPLE`]).
        oversample: usize,
        /// Power-iteration count `q` — each costs one more pass over A
        /// and sharpens slowly-decaying spectra.
        power_iters: usize,
    },
    /// Least squares `min ‖A x − b‖₂` on an *augmented* ingested matrix
    /// `[A b]` whose trailing `rhs` columns are right-hand sides:
    /// `Factorization.solution` holds the `n×rhs` solution(s). Served
    /// exactly from any R-producing pipeline's augmented triangle, or
    /// by sketch-and-precondition (`Fixed(Randomized)`, or `Auto` when
    /// the κ probe flags the system ill-conditioned).
    Solve {
        /// Trailing right-hand-side column count, `1 ..= cols-1`.
        rhs: usize,
    },
}

/// How to pick the algorithm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AlgoChoice {
    /// Condition-aware selection: a one-pass Indirect-TSQR probe
    /// estimates κ₂(A) from its `R`; well-conditioned inputs finish
    /// that same `R` into `Q = A·R⁻¹` (the probe is *reused* — one
    /// more pass), everything else runs the stable Direct TSQR.
    Auto,
    /// Run exactly this algorithm.
    Fixed(Algorithm),
}

/// Default κ₂ threshold below which `Auto` considers an input
/// well-conditioned. The probe-reusing indirect finish loses
/// orthogonality like κ·ε (paper Fig. 6), so κ ≤ 1e3 keeps the cheap
/// path's `‖QᵀQ−I‖` at ~1e-13 — comfortably better than the κ²·ε a
/// Cholesky-QR rerun would give at the same threshold, and far from
/// any breakdown regime.
pub const DEFAULT_CONDITION_THRESHOLD: f64 = 1e3;

/// Scheduling priority of a request on a [`crate::service::TsqrService`]
/// queue: higher priorities are dequeued first, FIFO within a priority.
/// Sessions (inline execution) ignore it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Low,
    Normal,
    High,
}

impl Priority {
    pub fn name(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Parse a CLI/manifest priority name.
    pub fn parse(s: &str) -> anyhow::Result<Priority> {
        Ok(match s {
            "low" => Priority::Low,
            "normal" => Priority::Normal,
            "high" => Priority::High,
            other => anyhow::bail!("unknown priority {other:?} (low|normal|high)"),
        })
    }
}

/// Which engine shard of a [`crate::service::TsqrService`] a job runs
/// on. `Auto` lets the router pick the least-loaded shard (deterministic
/// job-id tie-break); `Pinned(k)` is the escape hatch for callers that
/// want locality with a specific shard's DFS (e.g. chained jobs reading
/// an earlier job's Q without a cross-shard copy). Sessions and
/// single-shard services have exactly one shard, so both variants are
/// equivalent there. Placement never changes results: every modelled
/// quantity is bit-identical whichever shard serves the job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Route to the least-loaded shard.
    Auto,
    /// Run on shard `k`; submission errors when `k` is out of range.
    Pinned(usize),
}

/// Submit-time scheduling options, consolidated in one struct: how a
/// job service queues, places, and (since the elastic scheduler) steals
/// or admission-controls the request. Sessions (inline execution)
/// ignore all of it. Build fluently and attach with
/// [`FactorizationRequest::options`]:
///
/// ```
/// use mrtsqr::session::{FactorizationRequest, Priority, SubmitOptions};
///
/// let req = FactorizationRequest::qr()
///     .options(SubmitOptions::new().priority(Priority::High).label("t1").pinned(2).no_steal());
/// assert_eq!(req.options.priority, Priority::High);
/// ```
///
/// None of these knobs ever changes numerical results: priority,
/// placement, stealing and admission are pure scheduling, and every
/// modelled metric (R/Q/Σ bits, `virtual_secs`, fault draws) is
/// identical at any setting.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitOptions {
    /// Queue priority on a job service.
    pub priority: Priority,
    /// Human-readable tenant tag carried through the job service into
    /// per-job reporting (`mrtsqr batch` prints it) and used as the
    /// admission-quota key when the scheduler enforces per-label
    /// fair-share.
    pub label: Option<String>,
    /// Engine-shard placement on a job service.
    pub placement: Placement,
    /// Opt this job out of queue-level work stealing: it only ever runs
    /// on the shard the router (or a pin) placed it on.
    pub no_steal: bool,
    /// Opt this job out of per-label admission quotas (it still counts
    /// toward its label's in-flight total for *other* jobs).
    pub quota_exempt: bool,
}

impl Default for SubmitOptions {
    fn default() -> Self {
        SubmitOptions {
            priority: Priority::Normal,
            label: None,
            placement: Placement::Auto,
            no_steal: false,
            quota_exempt: false,
        }
    }
}

impl SubmitOptions {
    /// Default options: `Normal` priority, no label, `Auto` placement,
    /// stealing and quotas both applicable.
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue priority when submitted to a job service.
    pub fn priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Tag the request for per-job reporting and admission quotas.
    pub fn label(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }

    /// Set the engine-shard placement explicitly.
    pub fn placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }

    /// Pin the job to engine shard `k` of a sharded service (see
    /// [`Placement`]).
    pub fn pinned(mut self, shard: usize) -> Self {
        self.placement = Placement::Pinned(shard);
        self
    }

    /// Opt the job out of queue-level work stealing.
    pub fn no_steal(mut self) -> Self {
        self.no_steal = true;
        self
    }

    /// Opt the job out of per-label admission quotas.
    pub fn quota_exempt(mut self) -> Self {
        self.quota_exempt = true;
        self
    }
}

/// A factorization request; every knob in one place.
///
/// `refine` applies one sweep of iterative refinement (paper §II-C)
/// when `Auto` picks an indirect method; `Fixed` algorithms carry their
/// own `refine` flag and ignore this field. The [`SubmitOptions`] in
/// `options` only matter when the request is submitted to a job
/// service.
#[derive(Debug, Clone, PartialEq)]
pub struct FactorizationRequest {
    pub want: Want,
    pub algo: AlgoChoice,
    pub refine: bool,
    /// κ₂ threshold for the `Auto` policy.
    pub condition_threshold: f64,
    /// Submit-time scheduling options (priority, label, placement,
    /// steal/quota opt-outs). Sessions ignore them.
    pub options: SubmitOptions,
    /// Sketch operator + seed for the randomized family. Ignored by
    /// the Qr/ROnly/Svd/SingularValues wants; for `LowRank`/`Solve` the
    /// seed is part of the digest contract (same seed → same bits at
    /// every scaling setting) and ships in the wire payload like an
    /// ingestion seed.
    pub sketch: SketchOptions,
}

impl Default for FactorizationRequest {
    fn default() -> Self {
        FactorizationRequest {
            want: Want::Qr,
            algo: AlgoChoice::Auto,
            refine: false,
            condition_threshold: DEFAULT_CONDITION_THRESHOLD,
            options: SubmitOptions::default(),
            sketch: SketchOptions::default(),
        }
    }
}

impl FactorizationRequest {
    /// Full QR (the default want), auto-selected algorithm.
    pub fn qr() -> Self {
        Self::default()
    }

    /// Triangular factor only.
    pub fn r_only() -> Self {
        FactorizationRequest { want: Want::ROnly, ..Self::default() }
    }

    /// Tall-and-skinny SVD via the Direct TSQR extension.
    pub fn svd() -> Self {
        FactorizationRequest { want: Want::Svd, ..Self::default() }
    }

    /// Singular values only (paper §III-B, last sentence).
    pub fn singular_values() -> Self {
        FactorizationRequest { want: Want::SingularValues, ..Self::default() }
    }

    /// Rank-`rank` truncated SVD with default oversampling and no
    /// power iterations; tune with [`Self::oversample`] /
    /// [`Self::power_iters`] / [`Self::with_sketch`].
    pub fn low_rank(rank: usize) -> Self {
        FactorizationRequest {
            want: Want::LowRank { rank, oversample: DEFAULT_OVERSAMPLE, power_iters: 0 },
            ..Self::default()
        }
    }

    /// Least squares against the input's trailing column (`rhs = 1`);
    /// widen with [`Self::rhs_cols`].
    pub fn solve() -> Self {
        FactorizationRequest { want: Want::Solve { rhs: 1 }, ..Self::default() }
    }

    /// Override the oversampling width of a `LowRank` request (no-op
    /// for other wants).
    pub fn oversample(mut self, p: usize) -> Self {
        if let Want::LowRank { oversample, .. } = &mut self.want {
            *oversample = p;
        }
        self
    }

    /// Override the power-iteration count of a `LowRank` request
    /// (no-op for other wants).
    pub fn power_iters(mut self, q: usize) -> Self {
        if let Want::LowRank { power_iters, .. } = &mut self.want {
            *power_iters = q;
        }
        self
    }

    /// Override the right-hand-side column count of a `Solve` request
    /// (no-op for other wants).
    pub fn rhs_cols(mut self, k: usize) -> Self {
        if let Want::Solve { rhs } = &mut self.want {
            *rhs = k;
        }
        self
    }

    /// Replace the sketch operator + seed wholesale.
    pub fn with_sketch(mut self, sketch: SketchOptions) -> Self {
        self.sketch = sketch;
        self
    }

    /// Pin the randomized family explicitly (shorthand for
    /// `.with_algorithm(Algorithm::Randomized)`).
    pub fn randomized(mut self) -> Self {
        self.algo = AlgoChoice::Fixed(Algorithm::Randomized);
        self
    }

    /// Pin the algorithm instead of auto-selecting.
    pub fn with_algorithm(mut self, algo: Algorithm) -> Self {
        self.algo = AlgoChoice::Fixed(algo);
        self
    }

    /// Explicitly request condition-aware auto-selection.
    pub fn auto(mut self) -> Self {
        self.algo = AlgoChoice::Auto;
        self
    }

    /// Ask `Auto` for one iterative-refinement sweep on indirect picks.
    pub fn refined(mut self, refine: bool) -> Self {
        self.refine = refine;
        self
    }

    /// Override the `Auto` condition threshold.
    pub fn with_condition_threshold(mut self, kappa: f64) -> Self {
        self.condition_threshold = kappa;
        self
    }

    /// Replace the submit-time scheduling options wholesale (the
    /// consolidated successor to the loose `with_priority` / `labeled`
    /// / `pinned` setters).
    pub fn options(mut self, options: SubmitOptions) -> Self {
        self.options = options;
        self
    }

    /// Queue priority when submitted to a job service.
    #[deprecated(since = "0.9.0", note = "use .options(SubmitOptions::new().priority(..))")]
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.options.priority = priority;
        self
    }

    /// Tag the request for per-job reporting.
    #[deprecated(since = "0.9.0", note = "use .options(SubmitOptions::new().label(..))")]
    pub fn labeled(mut self, label: impl Into<String>) -> Self {
        self.options.label = Some(label.into());
        self
    }

    /// Pin the job to engine shard `k` of a sharded service (see
    /// [`Placement`]).
    #[deprecated(since = "0.9.0", note = "use .options(SubmitOptions::new().pinned(..))")]
    pub fn pinned(mut self, shard: usize) -> Self {
        self.options.placement = Placement::Pinned(shard);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_auto_qr() {
        let r = FactorizationRequest::default();
        assert_eq!(r.want, Want::Qr);
        assert_eq!(r.algo, AlgoChoice::Auto);
        assert!(!r.refine);
        assert_eq!(r.condition_threshold, DEFAULT_CONDITION_THRESHOLD);
        assert_eq!(r.options, SubmitOptions::default());
        assert_eq!(r.options.priority, Priority::Normal);
        assert!(r.options.label.is_none());
        assert_eq!(r.options.placement, Placement::Auto);
        assert!(!r.options.no_steal && !r.options.quota_exempt);
    }

    #[test]
    fn placement_pins_a_shard() {
        let r = FactorizationRequest::qr().options(SubmitOptions::new().pinned(3));
        assert_eq!(r.options.placement, Placement::Pinned(3));
    }

    #[test]
    fn priority_orders_and_parses() {
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert!(Priority::parse("urgent").is_err());
        let r = FactorizationRequest::qr()
            .options(SubmitOptions::new().priority(Priority::High).label("hot"));
        assert_eq!(r.options.priority, Priority::High);
        assert_eq!(r.options.label.as_deref(), Some("hot"));
    }

    #[test]
    fn submit_options_compose_all_knobs() {
        let o = SubmitOptions::new()
            .priority(Priority::Low)
            .label("tenant-a")
            .pinned(2)
            .no_steal()
            .quota_exempt();
        assert_eq!(o.priority, Priority::Low);
        assert_eq!(o.label.as_deref(), Some("tenant-a"));
        assert_eq!(o.placement, Placement::Pinned(2));
        assert!(o.no_steal && o.quota_exempt);
        let o = SubmitOptions::new().placement(Placement::Auto);
        assert_eq!(o.placement, Placement::Auto);
    }

    /// The pre-redesign loose setters must keep delegating into
    /// `options` bit-for-bit (they are deprecated shims, not parallel
    /// state).
    #[test]
    #[allow(deprecated)]
    fn deprecated_setters_delegate_into_options() {
        let r = FactorizationRequest::qr()
            .with_priority(Priority::High)
            .labeled("legacy")
            .pinned(1);
        let want = FactorizationRequest::qr()
            .options(SubmitOptions::new().priority(Priority::High).label("legacy").pinned(1));
        assert_eq!(r, want);
    }

    #[test]
    fn builder_methods_compose() {
        let r = FactorizationRequest::r_only()
            .with_algorithm(Algorithm::DirectTsqr)
            .refined(true)
            .with_condition_threshold(1e4);
        assert_eq!(r.want, Want::ROnly);
        assert_eq!(r.algo, AlgoChoice::Fixed(Algorithm::DirectTsqr));
        assert!(r.refine);
        assert_eq!(r.condition_threshold, 1e4);
        let r = r.auto();
        assert_eq!(r.algo, AlgoChoice::Auto);
    }

    #[test]
    fn sketch_requests_compose() {
        use crate::sketch::SketchKind;
        let r = FactorizationRequest::low_rank(5);
        assert_eq!(
            r.want,
            Want::LowRank { rank: 5, oversample: DEFAULT_OVERSAMPLE, power_iters: 0 }
        );
        assert_eq!(r.sketch, SketchOptions::default());
        let r = r
            .oversample(3)
            .power_iters(2)
            .with_sketch(SketchOptions { kind: SketchKind::CountSketch, seed: 99 })
            .randomized();
        assert_eq!(r.want, Want::LowRank { rank: 5, oversample: 3, power_iters: 2 });
        assert_eq!(r.sketch.kind, SketchKind::CountSketch);
        assert_eq!(r.sketch.seed, 99);
        assert_eq!(r.algo, AlgoChoice::Fixed(Algorithm::Randomized));

        let r = FactorizationRequest::solve().rhs_cols(4);
        assert_eq!(r.want, Want::Solve { rhs: 4 });
        // cross-want setters are no-ops, not panics
        let r = r.oversample(9).power_iters(9);
        assert_eq!(r.want, Want::Solve { rhs: 4 });
        assert_eq!(FactorizationRequest::qr().rhs_cols(9).want, Want::Qr);
    }
}
