//! Key-value record codec.
//!
//! A tall-and-skinny matrix in HDFS is a collection of key-value pairs:
//! the key identifies a row (the paper uses 32-byte strings, `K = 32`
//! in its Table III byte counts), the value is the row's `n` doubles.
//! We keep the exact same layout so the engine's measured byte counts
//! line up with the paper's formulas.

/// Key size in bytes — matches the paper's `K = 32`.
pub const KEY_BYTES: usize = 32;

/// One key-value pair in a DFS file.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub key: Vec<u8>,
    pub value: Vec<u8>,
}

impl Record {
    pub fn new(key: Vec<u8>, value: Vec<u8>) -> Self {
        Record { key, value }
    }

    /// Bytes this record occupies on (simulated) disk.
    pub fn size_bytes(&self) -> u64 {
        (self.key.len() + self.value.len()) as u64
    }
}

/// 32-byte row key: zero-padded decimal of the global row id (a stand-in
/// for the paper's uuid-derived strings, same byte count).
pub fn row_key(row_id: u64) -> Vec<u8> {
    let s = format!("{:0width$}", row_id, width = KEY_BYTES);
    debug_assert_eq!(s.len(), KEY_BYTES);
    s.into_bytes()
}

/// Encode a row of f64 as little-endian bytes.
pub fn encode_row(row: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(row.len() * 8);
    for v in row {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a little-endian f64 row.
pub fn decode_row(bytes: &[u8]) -> Vec<f64> {
    assert!(bytes.len() % 8 == 0, "row byte length not a multiple of 8");
    bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Encode an `r × c` matrix header + data as a single record value
/// (used for Q/R factor shipping between steps: the paper emits whole
/// factors keyed by task id).
pub fn encode_matrix(rows: usize, cols: usize, data: &[f64]) -> Vec<u8> {
    assert_eq!(data.len(), rows * cols);
    let mut out = Vec::with_capacity(16 + data.len() * 8);
    out.extend_from_slice(&(rows as u64).to_le_bytes());
    out.extend_from_slice(&(cols as u64).to_le_bytes());
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a matrix record value -> (rows, cols, data).
pub fn decode_matrix(bytes: &[u8]) -> (usize, usize, Vec<f64>) {
    assert!(bytes.len() >= 16, "matrix record too short");
    let rows = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let data = decode_row(&bytes[16..]);
    assert_eq!(data.len(), rows * cols, "matrix record size mismatch");
    (rows, cols, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_key_is_32_bytes_and_ordered() {
        assert_eq!(row_key(0).len(), KEY_BYTES);
        assert_eq!(row_key(u64::MAX / 2).len(), KEY_BYTES);
        assert!(row_key(5) < row_key(50));
        assert!(row_key(99) < row_key(100));
    }

    #[test]
    fn row_roundtrip() {
        let row = vec![1.5, -2.25, 0.0, f64::MIN_POSITIVE, 1e300];
        assert_eq!(decode_row(&encode_row(&row)), row);
    }

    #[test]
    fn row_roundtrip_preserves_bits() {
        let row = vec![-0.0, f64::NAN];
        let back = decode_row(&encode_row(&row));
        assert_eq!(back[0].to_bits(), (-0.0f64).to_bits());
        assert!(back[1].is_nan());
    }

    #[test]
    fn matrix_roundtrip() {
        let data: Vec<f64> = (0..12).map(|i| i as f64 * 0.5).collect();
        let enc = encode_matrix(3, 4, &data);
        let (r, c, d) = decode_matrix(&enc);
        assert_eq!((r, c), (3, 4));
        assert_eq!(d, data);
    }

    #[test]
    fn record_size() {
        let rec = Record::new(row_key(7), encode_row(&[1.0, 2.0]));
        assert_eq!(rec.size_bytes(), 32 + 16);
    }

    #[test]
    #[should_panic]
    fn decode_bad_length_panics() {
        decode_row(&[0u8; 7]);
    }
}
