//! Simulated HDFS substrate.
//!
//! The paper runs on Hadoop, whose performance is dominated by HDFS
//! disk I/O — its model fits measured job times within 2× from just the
//! inverse read/write bandwidths `β_r`, `β_w` (paper §V-A, Table II).
//! This module provides the equivalent substrate: a named key-value
//! file store ([`store::Dfs`]) whose every read and write is accounted
//! ([`bandwidth::IoMeter`]) and charged to a virtual disk clock via a
//! [`bandwidth::DiskModel`]. The MapReduce engine schedules those
//! charges over worker slots to produce job makespans comparable to the
//! paper's wall-clock measurements (see DESIGN.md §2 for why this
//! substitution preserves the evaluation's shape).

pub mod bandwidth;
pub mod records;
pub mod store;

pub use bandwidth::{DiskModel, IoMeter};
pub use records::{decode_row, encode_row, row_key, Record, KEY_BYTES};
pub use store::Dfs;
