//! Disk-bandwidth model + I/O accounting.
//!
//! The paper's performance model (§V-A) has exactly two fitted
//! parameters: the inverse read bandwidth `β_r` and inverse write
//! bandwidth `β_w`. Table II reports them normalized per map slot
//! (`β_r/m_max ≈ 1.4–2.3 s/GB`, `β_w/m_max ≈ 3.0–3.2 s/GB`, writes ~2×
//! slower from HDFS replication), i.e. one Hadoop-streaming slot reads
//! at only ~10–17 MB/s. A task reading `B` bytes therefore takes
//! `B·β_r` seconds, and the paper's lower bound divides the *total*
//! step bytes by the step parallelism `p_j` — exactly what the engine's
//! slot-scheduled virtual clock computes.
//!
//! `byte_scale` maps our scaled-down workloads back to paper-scale
//! bytes (DESIGN.md §2): the DFS stores ~2000× fewer rows, the virtual
//! clock charges as if each byte were `byte_scale` bytes, so Table V/VI
//! reproductions land in the paper's own units.

/// Inverse-bandwidth disk model (per-slot seconds/byte).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiskModel {
    /// Seconds per (virtual) byte read by one slot. Paper-units default:
    /// 1.6 s/GB · m_max(=40) = 64 s/GB = 6.4e-8 s/B.
    pub beta_r: f64,
    /// Seconds per (virtual) byte written by one slot (~2× beta_r).
    pub beta_w: f64,
    /// Virtual bytes charged per actual stored byte (workload scale-up).
    pub byte_scale: f64,
    /// Fixed startup cost per MapReduce iteration (Hadoop job launch).
    pub iteration_startup_secs: f64,
    /// Fixed per-task-attempt scheduling overhead.
    pub task_startup_secs: f64,
}

impl DiskModel {
    /// Defaults fitted to the paper's ICME cluster (Table II).
    pub fn icme_like() -> Self {
        DiskModel {
            beta_r: 64.0e-9,  // 64 s/GB per slot
            beta_w: 126.0e-9, // 126 s/GB per slot
            byte_scale: 1.0,
            iteration_startup_secs: 15.0,
            task_startup_secs: 2.0,
        }
    }

    /// Pure-bandwidth model (no startup costs) — the paper's `T_lb`
    /// counts only reads and writes.
    pub fn pure_bandwidth(beta_r: f64, beta_w: f64) -> Self {
        DiskModel {
            beta_r,
            beta_w,
            byte_scale: 1.0,
            iteration_startup_secs: 0.0,
            task_startup_secs: 0.0,
        }
    }

    /// Same model with a workload scale factor.
    pub fn with_scale(mut self, byte_scale: f64) -> Self {
        self.byte_scale = byte_scale;
        self
    }

    pub fn read_secs(&self, bytes: u64) -> f64 {
        self.read_secs_f(bytes as f64)
    }

    pub fn write_secs(&self, bytes: u64) -> f64 {
        self.write_secs_f(bytes as f64)
    }

    /// Virtual-byte variants (bytes already carry a per-file scale; the
    /// model's global `byte_scale` multiplies on top).
    pub fn read_secs_f(&self, bytes: f64) -> f64 {
        bytes * self.byte_scale * self.beta_r
    }

    pub fn write_secs_f(&self, bytes: f64) -> f64 {
        bytes * self.byte_scale * self.beta_w
    }
}

impl Default for DiskModel {
    fn default() -> Self {
        Self::icme_like()
    }
}

/// Byte counters for one task / step / job.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IoMeter {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub records_read: u64,
    pub records_written: u64,
}

impl IoMeter {
    pub fn add_read(&mut self, bytes: u64, records: u64) {
        self.bytes_read += bytes;
        self.records_read += records;
    }

    pub fn add_write(&mut self, bytes: u64, records: u64) {
        self.bytes_written += bytes;
        self.records_written += records;
    }

    pub fn merge(&mut self, other: &IoMeter) {
        self.bytes_read += other.bytes_read;
        self.bytes_written += other.bytes_written;
        self.records_read += other.records_read;
        self.records_written += other.records_written;
    }

    /// Virtual disk seconds for this meter under `model`.
    pub fn disk_secs(&self, model: &DiskModel) -> f64 {
        model.read_secs(self.bytes_read) + model.write_secs(self.bytes_written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_slower_than_read_by_default() {
        let m = DiskModel::default();
        assert!(m.beta_w > m.beta_r);
        let ratio = m.beta_w / m.beta_r;
        assert!(ratio > 1.5 && ratio < 3.0, "paper-like ratio, got {ratio}");
    }

    #[test]
    fn charges_linear() {
        let m = DiskModel::pure_bandwidth(2e-9, 4e-9);
        assert!((m.read_secs(1_000_000_000) - 2.0).abs() < 1e-12);
        assert!((m.write_secs(500_000_000) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn byte_scale_multiplies() {
        let m = DiskModel::pure_bandwidth(1.0, 1.0).with_scale(2000.0);
        assert!((m.read_secs(10) - 20000.0).abs() < 1e-9);
    }

    #[test]
    fn meter_merge_and_secs() {
        let mut a = IoMeter::default();
        a.add_read(100, 2);
        a.add_write(50, 1);
        let mut b = IoMeter::default();
        b.add_read(10, 1);
        b.merge(&a);
        assert_eq!(b.bytes_read, 110);
        assert_eq!(b.bytes_written, 50);
        assert_eq!(b.records_read, 3);
        let m = DiskModel::pure_bandwidth(1.0, 2.0);
        assert!((b.disk_secs(&m) - (110.0 + 100.0)).abs() < 1e-12);
    }
}
