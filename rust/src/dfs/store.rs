//! The simulated distributed file store.
//!
//! Files are named sequences of [`Record`]s held in memory (the *real*
//! disk is irrelevant — what matters for reproducing the paper is the
//! byte accounting, which [`Dfs`] performs on every access). Reads and
//! writes return/consume whole files or splits, mirroring how Hadoop
//! streams splits into map tasks.

use super::records::Record;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Named key-value file store with byte accounting.
///
/// Each file carries a *virtual byte scale* (default 1.0): the virtual
/// disk clock charges `actual bytes × scale`. Scaled-down reproductions
/// mark matrix-sized files (`O(m·n)` data) with the workload scale while
/// factor/metadata files (`O(m₁·n²)`) stay at 1.0 — because when the
/// simulation runs the paper's real task counts, those files already
/// have paper-scale size (see DESIGN.md §2).
///
/// Files are reference-counted (`Arc`) so independent stores — the
/// engine-shard pool behind a [`crate::service::TsqrService`] keeps one
/// `Dfs` per shard — can share one physical copy of a large ingested
/// matrix: [`Dfs::export_file`] / [`Dfs::import_file`] move a handle in
/// O(1), and copy-on-write ([`Arc::make_mut`]) keeps later appends to
/// either side private.
#[derive(Debug, Default)]
pub struct Dfs {
    files: BTreeMap<String, Arc<Vec<Record>>>,
    scales: BTreeMap<String, f64>,
}

impl Dfs {
    pub fn new() -> Self {
        Self::default()
    }

    /// Virtual-byte multiplier of a file (1.0 if unset).
    pub fn scale(&self, name: &str) -> f64 {
        self.scales.get(name).copied().unwrap_or(1.0)
    }

    /// Mark a file's virtual byte scale.
    pub fn set_scale(&mut self, name: &str, scale: f64) {
        if scale == 1.0 {
            self.scales.remove(name);
        } else {
            self.scales.insert(name.to_string(), scale);
        }
    }

    /// Virtual bytes of a file (`actual × scale`).
    pub fn virtual_bytes(&self, name: &str) -> Result<f64> {
        Ok(self.file_bytes(name)? as f64 * self.scale(name))
    }

    /// Create/overwrite a file from records.
    pub fn put(&mut self, name: &str, records: Vec<Record>) {
        self.files.insert(name.to_string(), Arc::new(records));
    }

    /// Append records to a file (creating it if needed). Appending to a
    /// file whose records are shared with another store detaches this
    /// store's copy first (copy-on-write).
    pub fn append(&mut self, name: &str, mut records: Vec<Record>) {
        Arc::make_mut(self.files.entry(name.to_string()).or_default()).append(&mut records);
    }

    /// Hand out a file's shared record handle plus its virtual scale —
    /// the cheap (O(1)) half of a cross-shard copy. The records behind
    /// the `Arc` are immutable from the receiver's perspective; a later
    /// `append` on either store detaches via copy-on-write.
    pub fn export_file(&self, name: &str) -> Result<(Arc<Vec<Record>>, f64)> {
        match self.files.get(name) {
            Some(recs) => Ok((recs.clone(), self.scale(name))),
            None => bail!("dfs: no such file {name:?}"),
        }
    }

    /// Install an exported file handle under `name` (overwriting any
    /// existing file), carrying its virtual scale along.
    pub fn import_file(&mut self, name: &str, records: Arc<Vec<Record>>, scale: f64) {
        self.files.insert(name.to_string(), records);
        self.set_scale(name, scale);
    }

    pub fn exists(&self, name: &str) -> bool {
        self.files.contains_key(name)
    }

    pub fn delete(&mut self, name: &str) -> bool {
        self.files.remove(name).is_some()
    }

    /// Delete every file whose name starts with `prefix` (a namespace
    /// sweep — e.g. `job-3/` evicts one service job's intermediates).
    /// Returns the number of files removed.
    pub fn delete_prefix(&mut self, prefix: &str) -> usize {
        let names: Vec<String> = self
            .files
            .range(prefix.to_string()..)
            .take_while(|(name, _)| name.starts_with(prefix))
            .map(|(name, _)| name.clone())
            .collect();
        for name in &names {
            self.files.remove(name);
            self.scales.remove(name);
        }
        names.len()
    }

    pub fn get(&self, name: &str) -> Result<&[Record]> {
        match self.files.get(name) {
            Some(recs) => Ok(recs.as_slice()),
            None => bail!("dfs: no such file {name:?}"),
        }
    }

    /// Total bytes of a file (what a full scan reads).
    pub fn file_bytes(&self, name: &str) -> Result<u64> {
        Ok(self.get(name)?.iter().map(|r| r.size_bytes()).sum())
    }

    pub fn file_records(&self, name: &str) -> Result<usize> {
        Ok(self.get(name)?.len())
    }

    pub fn list(&self) -> Vec<&str> {
        self.files.keys().map(|s| s.as_str()).collect()
    }

    /// Split a file into `nsplits` contiguous row-range splits
    /// (record index ranges), like HDFS input splits. Splits are as
    /// even as possible; trailing splits may be one record shorter.
    pub fn splits(&self, name: &str, nsplits: usize) -> Result<Vec<(usize, usize)>> {
        let n = self.file_records(name)?;
        if nsplits == 0 {
            bail!("dfs: zero splits requested");
        }
        let nsplits = nsplits.min(n.max(1));
        let base = n / nsplits;
        let extra = n % nsplits;
        let mut out = Vec::with_capacity(nsplits);
        let mut start = 0;
        for i in 0..nsplits {
            let len = base + usize::from(i < extra);
            out.push((start, start + len));
            start += len;
        }
        Ok(out)
    }

    /// Records of one split.
    pub fn read_split(&self, name: &str, split: (usize, usize)) -> Result<&[Record]> {
        let recs = self.get(name)?;
        if split.1 > recs.len() || split.0 > split.1 {
            bail!("dfs: bad split {split:?} for {name:?} ({} records)", recs.len());
        }
        Ok(&recs[split.0..split.1])
    }

    /// Total bytes stored (the paper reports "HDFS Size (GB)").
    pub fn total_bytes(&self) -> u64 {
        self.files
            .values()
            .map(|f| f.iter().map(|r| r.size_bytes()).sum::<u64>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::records::{encode_row, row_key};

    fn mk_records(n: usize, cols: usize) -> Vec<Record> {
        (0..n)
            .map(|i| Record::new(row_key(i as u64), encode_row(&vec![i as f64; cols])))
            .collect()
    }

    #[test]
    fn put_get_roundtrip() {
        let mut dfs = Dfs::new();
        let recs = mk_records(5, 3);
        dfs.put("a", recs.clone());
        assert_eq!(dfs.get("a").unwrap(), &recs[..]);
        assert!(dfs.exists("a"));
        assert!(!dfs.exists("b"));
    }

    #[test]
    fn missing_file_errors() {
        let dfs = Dfs::new();
        assert!(dfs.get("nope").is_err());
    }

    #[test]
    fn append_grows() {
        let mut dfs = Dfs::new();
        dfs.append("a", mk_records(2, 1));
        dfs.append("a", mk_records(3, 1));
        assert_eq!(dfs.file_records("a").unwrap(), 5);
    }

    #[test]
    fn bytes_accounting() {
        let mut dfs = Dfs::new();
        dfs.put("a", mk_records(10, 4));
        // 10 rows × (32 key + 32 value)
        assert_eq!(dfs.file_bytes("a").unwrap(), 10 * (32 + 32));
        assert_eq!(dfs.total_bytes(), 640);
    }

    #[test]
    fn splits_cover_exactly() {
        let mut dfs = Dfs::new();
        dfs.put("a", mk_records(10, 1));
        for nsplits in 1..=12 {
            let splits = dfs.splits("a", nsplits).unwrap();
            let total: usize = splits.iter().map(|(s, e)| e - s).sum();
            assert_eq!(total, 10, "nsplits={nsplits}");
            // contiguous & ordered
            let mut prev = 0;
            for &(s, e) in &splits {
                assert_eq!(s, prev);
                assert!(e >= s);
                prev = e;
            }
        }
    }

    #[test]
    fn splits_balanced() {
        let mut dfs = Dfs::new();
        dfs.put("a", mk_records(10, 1));
        let splits = dfs.splits("a", 4).unwrap();
        let sizes: Vec<usize> = splits.iter().map(|(s, e)| e - s).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn read_split_bounds_checked() {
        let mut dfs = Dfs::new();
        dfs.put("a", mk_records(4, 1));
        assert!(dfs.read_split("a", (2, 4)).is_ok());
        assert!(dfs.read_split("a", (2, 5)).is_err());
    }

    #[test]
    fn delete_removes() {
        let mut dfs = Dfs::new();
        dfs.put("a", mk_records(1, 1));
        assert!(dfs.delete("a"));
        assert!(!dfs.delete("a"));
        assert!(!dfs.exists("a"));
    }

    #[test]
    fn export_import_shares_one_physical_copy() {
        let mut src = Dfs::new();
        src.put("A", mk_records(100, 3));
        src.set_scale("A", 7.5);
        let (recs, scale) = src.export_file("A").unwrap();
        let mut dst = Dfs::new();
        dst.import_file("A", recs, scale);
        // same bytes, same scale, and physically the same allocation
        assert_eq!(src.get("A").unwrap(), dst.get("A").unwrap());
        assert_eq!(dst.scale("A"), 7.5);
        let (a, _) = src.export_file("A").unwrap();
        let (b, _) = dst.export_file("A").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "import must not deep-copy");
        assert!(src.export_file("missing").is_err());
    }

    #[test]
    fn append_after_import_copies_on_write() {
        let mut src = Dfs::new();
        src.put("A", mk_records(4, 1));
        let (recs, scale) = src.export_file("A").unwrap();
        let mut dst = Dfs::new();
        dst.import_file("A", recs, scale);
        dst.append("A", mk_records(2, 1));
        assert_eq!(dst.file_records("A").unwrap(), 6);
        // the source's copy is untouched by the receiver's append
        assert_eq!(src.file_records("A").unwrap(), 4);
    }

    #[test]
    fn delete_prefix_sweeps_a_namespace() {
        let mut dfs = Dfs::new();
        dfs.put("job-1/tmp/a", mk_records(1, 1));
        dfs.put("job-1/tmp/b", mk_records(1, 1));
        dfs.set_scale("job-1/tmp/b", 5.0);
        dfs.put("job-10/tmp/a", mk_records(1, 1));
        dfs.put("job-2/tmp/a", mk_records(1, 1));
        dfs.put("A", mk_records(1, 1));
        assert_eq!(dfs.delete_prefix("job-1/"), 2);
        assert!(!dfs.exists("job-1/tmp/a"));
        assert_eq!(dfs.scale("job-1/tmp/b"), 1.0, "scale entry swept too");
        // `job-1/` must not catch `job-10/`
        assert!(dfs.exists("job-10/tmp/a"));
        assert!(dfs.exists("job-2/tmp/a"));
        assert!(dfs.exists("A"));
        assert_eq!(dfs.delete_prefix("job-9/"), 0);
    }
}
