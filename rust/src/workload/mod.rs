//! Workload generators: matrices written into the DFS as row records.
//!
//! The benches use the paper's matrix *aspect ratios* scaled down
//! ~2000× (DESIGN.md §2); the stability study uses prescribed-condition
//! matrices from [`crate::linalg::matgen`].
//!
//! Application code should prefer the session-layer ingestion API
//! ([`crate::session::TsqrSession::ingest`] and friends), which streams
//! row chunks through a [`crate::session::MatrixWriter`]; the helpers
//! here remain the low-level substrate those conveniences build on.

use crate::dfs::records::{encode_row, row_key, Record};
use crate::dfs::Dfs;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Write an in-memory matrix to a DFS file, one record per row, keyed by
/// global row id (the paper's canonical HDFS layout).
pub fn put_matrix(dfs: &mut Dfs, name: &str, a: &Matrix) {
    let recs: Vec<Record> = (0..a.rows)
        .map(|i| Record::new(row_key(i as u64), encode_row(a.row(i))))
        .collect();
    dfs.put(name, recs);
}

/// Read a whole DFS matrix file back (rows in key order as stored).
pub fn get_matrix(dfs: &Dfs, name: &str, cols: usize) -> anyhow::Result<Matrix> {
    let recs = dfs.get(name)?;
    let mut data = Vec::with_capacity(recs.len() * cols);
    for rec in recs {
        let row = crate::dfs::records::decode_row(&rec.value);
        anyhow::ensure!(row.len() == cols, "row width {} != {}", row.len(), cols);
        data.extend_from_slice(&row);
    }
    Ok(Matrix::from_rows(recs.len(), cols, data))
}

/// Stream a gaussian `m × n` matrix into the DFS without materializing
/// a `Matrix` (row at a time) — the perf-bench workload.
pub fn gaussian_matrix(dfs: &mut Dfs, name: &str, m: usize, n: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let mut recs = Vec::with_capacity(m);
    let mut row = vec![0.0f64; n];
    for i in 0..m {
        for v in row.iter_mut() {
            *v = rng.gaussian();
        }
        recs.push(Record::new(row_key(i as u64), encode_row(&row)));
    }
    dfs.put(name, recs);
}

/// The five paper workloads (rows, cols) scaled by `1/scale`, with the
/// byte scale to hand to [`crate::dfs::DiskModel::with_scale`] so the
/// virtual clock still charges paper-scale bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaledWorkload {
    pub paper_rows: u64,
    pub cols: usize,
    pub rows: usize,
    pub byte_scale: f64,
    /// paper's step-1 map tasks (indirect / direct variants)
    pub m1_indirect: u64,
    pub m1_direct: u64,
}

/// Paper Table VI workload list, scaled down by `scale` (rows are
/// rounded to a multiple of 1000 to keep splits tidy).
pub fn paper_workloads(scale: u64) -> Vec<ScaledWorkload> {
    let raw: [(u64, usize, u64, u64); 5] = [
        (4_000_000_000, 4, 1200, 2000),
        (2_500_000_000, 10, 1680, 2640),
        (600_000_000, 25, 1200, 1600),
        (500_000_000, 50, 1920, 2560),
        (150_000_000, 100, 1200, 1600),
    ];
    raw.iter()
        .map(|&(m, n, m1i, m1d)| {
            let rows = (((m / scale) / 1000).max(1) * 1000) as usize;
            ScaledWorkload {
                paper_rows: m,
                cols: n,
                rows,
                byte_scale: m as f64 / rows as f64,
                m1_indirect: m1i,
                m1_direct: m1d,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut dfs = Dfs::new();
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(10, 3, &mut rng);
        put_matrix(&mut dfs, "a", &a);
        let back = get_matrix(&dfs, "a", 3).unwrap();
        assert_eq!(back.data, a.data);
    }

    #[test]
    fn gaussian_streaming_matches_records() {
        let mut dfs = Dfs::new();
        gaussian_matrix(&mut dfs, "g", 100, 5, 42);
        assert_eq!(dfs.file_records("g").unwrap(), 100);
        assert_eq!(dfs.file_bytes("g").unwrap(), 100 * (32 + 40));
        let m = get_matrix(&dfs, "g", 5).unwrap();
        // deterministic per seed
        let mut dfs2 = Dfs::new();
        gaussian_matrix(&mut dfs2, "g", 100, 5, 42);
        assert_eq!(get_matrix(&dfs2, "g", 5).unwrap().data, m.data);
    }

    #[test]
    fn paper_workloads_scaled() {
        let w = paper_workloads(2000);
        assert_eq!(w.len(), 5);
        assert_eq!(w[0].rows, 2_000_000);
        assert_eq!(w[0].cols, 4);
        // byte scale maps back to paper rows
        assert!((w[0].byte_scale * w[0].rows as f64 - 4e9).abs() < 1e-3);
        assert_eq!(w[4].cols, 100);
        assert_eq!(w[4].rows, 75_000);
    }

    #[test]
    fn wrong_width_errors() {
        let mut dfs = Dfs::new();
        gaussian_matrix(&mut dfs, "g", 4, 3, 1);
        assert!(get_matrix(&dfs, "g", 5).is_err());
    }
}
