//! Test-matrix generation with prescribed condition number.
//!
//! The stability study (paper Fig. 6) sweeps matrices of condition
//! number 10¹ … 10¹⁶ and measures `‖QᵀQ − I‖₂` per algorithm. Matrices
//! are built as `U · Σ · Vᵀ` with Haar-random orthogonal factors (QR of
//! gaussian matrices) and a log-spaced spectrum — exactly recoverable
//! singular values for the TSVD checks.

use super::matrix::Matrix;
use super::qr::householder_qr;
use crate::util::rng::Rng;

/// Haar-ish random `m×k` matrix with orthonormal columns (QR of gaussian).
pub fn random_orthogonal(m: usize, rng: &mut Rng) -> Matrix {
    random_orthonormal_cols(m, m, rng)
}

/// Random `m×k` with orthonormal columns, `m ≥ k`.
pub fn random_orthonormal_cols(m: usize, k: usize, rng: &mut Rng) -> Matrix {
    assert!(m >= k);
    let g = Matrix::gaussian(m, k, rng);
    let (q, _) = householder_qr(&g);
    q
}

/// Log-spaced spectrum from 1 down to 1/kappa.
pub fn log_spectrum(n: usize, kappa: f64) -> Vec<f64> {
    assert!(kappa >= 1.0 && n > 0);
    if n == 1 {
        return vec![1.0];
    }
    (0..n)
        .map(|j| kappa.powf(-(j as f64) / (n as f64 - 1.0)))
        .collect()
}

/// `m×n` matrix with prescribed 2-norm condition number `kappa`.
pub fn matrix_with_condition(m: usize, n: usize, kappa: f64, rng: &mut Rng) -> Matrix {
    let (mat, _, _) = matrix_with_spectrum(m, n, &log_spectrum(n, kappa), rng);
    mat
}

/// `m×n = U diag(sigma) Vᵀ`; returns (A, U, V) so tests can verify the
/// recovered singular vectors.
pub fn matrix_with_spectrum(
    m: usize,
    n: usize,
    sigma: &[f64],
    rng: &mut Rng,
) -> (Matrix, Matrix, Matrix) {
    assert_eq!(sigma.len(), n);
    let u = random_orthonormal_cols(m, n, rng);
    let v = random_orthogonal(n, rng);
    // A = (U * sigma) Vᵀ
    let mut us = u.clone();
    for j in 0..n {
        for i in 0..m {
            us[(i, j)] *= sigma[j];
        }
    }
    (us.matmul(&v.transpose()), u, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::jacobi_svd;

    #[test]
    fn orthogonal_is_orthogonal() {
        let mut rng = Rng::new(1);
        let q = random_orthogonal(10, &mut rng);
        assert!(q.orthogonality_error() < 1e-13);
    }

    #[test]
    fn orthonormal_cols_tall() {
        let mut rng = Rng::new(2);
        let q = random_orthonormal_cols(40, 7, &mut rng);
        assert!(q.orthogonality_error() < 1e-13);
    }

    #[test]
    fn spectrum_endpoints() {
        let s = log_spectrum(5, 1e8);
        assert!((s[0] - 1.0).abs() < 1e-15);
        assert!((s[4] - 1e-8).abs() < 1e-22);
        for w in s.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn condition_number_realized() {
        let mut rng = Rng::new(3);
        for &kappa in &[1e2, 1e6, 1e10] {
            let a = matrix_with_condition(60, 6, kappa, &mut rng);
            // measure via SVD of R from QR (cheap, accurate)
            let (_, r) = householder_qr(&a);
            let svd = jacobi_svd(&r);
            let measured = svd.condition_number();
            assert!(
                (measured / kappa - 1.0).abs() < 1e-6,
                "kappa {kappa} measured {measured}"
            );
        }
    }

    #[test]
    fn spectrum_recovered_by_svd() {
        let mut rng = Rng::new(4);
        let sigma = vec![5.0, 2.0, 1.0, 0.5];
        let (a, _, _) = matrix_with_spectrum(30, 4, &sigma, &mut rng);
        let (_, r) = householder_qr(&a);
        let svd = jacobi_svd(&r);
        for (got, want) in svd.sigma.iter().zip(&sigma) {
            assert!((got / want - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn n_equals_one() {
        let mut rng = Rng::new(5);
        let a = matrix_with_condition(10, 1, 1.0, &mut rng);
        assert_eq!(a.cols, 1);
    }
}
