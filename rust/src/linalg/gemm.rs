//! Tiled f64 gemm microkernel — the BLAS-3 engine behind the blocked
//! kernels in [`crate::linalg::block`] and the public
//! [`Matrix::matmul`](crate::linalg::Matrix::matmul) /
//! [`Matrix::gram`](crate::linalg::Matrix::gram) entry points.
//!
//! # Bit-determinism contract
//!
//! Every output element is produced by **one** accumulator that sums the
//! full k-range in ascending order. The register tiling (MR×NR output
//! blocks) changes only *which elements are in flight together*, never
//! the per-element operation sequence, so the result is bitwise
//! identical for any tile traversal, any caller-side blocking, and any
//! thread count above this layer. This is the same argument that keeps
//! `host_threads`/`engine_shards`/`worker_processes` pure scheduling:
//! the FP op sequence per output element is fixed by (shape, inputs)
//! alone.
//!
//! Strides (`lda`/`ldb`/`ldc`) are row strides in elements, so callers
//! can aim the kernel at sub-panels of a larger row-major buffer without
//! copying.

/// How the computed product is written into `C`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Acc {
    /// `C = A·B`
    Store,
    /// `C += A·B`
    Add,
    /// `C -= A·B`
    Sub,
}

/// Register-tile height (rows of C per microtile).
const MR: usize = 4;
/// Register-tile width (cols of C per microtile).
const NR: usize = 4;

#[inline]
fn write_tile(
    t: &[[f64; NR]; MR],
    mr: usize,
    nr: usize,
    c: &mut [f64],
    ldc: usize,
    i0: usize,
    j0: usize,
    acc: Acc,
) {
    for di in 0..mr {
        let base = (i0 + di) * ldc + j0;
        let crow = &mut c[base..base + nr];
        match acc {
            Acc::Store => {
                for dj in 0..nr {
                    crow[dj] = t[di][dj];
                }
            }
            Acc::Add => {
                for dj in 0..nr {
                    crow[dj] += t[di][dj];
                }
            }
            Acc::Sub => {
                for dj in 0..nr {
                    crow[dj] -= t[di][dj];
                }
            }
        }
    }
}

/// `C (m×n) ⟵ A (m×k) · B (k×n)`, all row-major with explicit row
/// strides. `acc` selects store / accumulate / subtract.
///
/// Each `C[i][j]` is the k-ascending sum of `A[i][kk] * B[kk][j]` in a
/// single accumulator — bitwise independent of the tiling.
pub fn gemm_nn(
    m: usize,
    k: usize,
    n: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
    acc: Acc,
) {
    debug_assert!(m == 0 || k == 0 || a.len() >= (m - 1) * lda + k);
    debug_assert!(k == 0 || n == 0 || b.len() >= (k - 1) * ldb + n);
    debug_assert!(m == 0 || n == 0 || c.len() >= (m - 1) * ldc + n);
    if m == 0 || n == 0 {
        return;
    }
    let mut i0 = 0;
    while i0 < m {
        let mr = MR.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            let mut t = [[0.0f64; NR]; MR];
            for kk in 0..k {
                let bbase = kk * ldb + j0;
                let brow = &b[bbase..bbase + nr];
                for di in 0..mr {
                    let av = a[(i0 + di) * lda + kk];
                    let trow = &mut t[di];
                    for dj in 0..nr {
                        trow[dj] += av * brow[dj];
                    }
                }
            }
            write_tile(&t, mr, nr, c, ldc, i0, j0, acc);
            j0 += nr;
        }
        i0 += mr;
    }
}

/// `C (m×n) ⟵ Aᵀ · B` where `A` is a **k×m** row-major buffer (so `Aᵀ`
/// is m×k) and `B` is k×n row-major. Same per-element k-ascending
/// accumulation contract as [`gemm_nn`].
///
/// Reading `A` row-by-row makes this the natural kernel for Gram
/// matrices (`AᵀA`) and for applying a column-stored reflector panel
/// `V` (each stored row of the buffer is one reflector, i.e. one
/// *column* of `V`).
pub fn gemm_at_b(
    m: usize,
    k: usize,
    n: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
    acc: Acc,
) {
    debug_assert!(m == 0 || k == 0 || a.len() >= (k - 1) * lda + m);
    debug_assert!(k == 0 || n == 0 || b.len() >= (k - 1) * ldb + n);
    debug_assert!(m == 0 || n == 0 || c.len() >= (m - 1) * ldc + n);
    if m == 0 || n == 0 {
        return;
    }
    let mut i0 = 0;
    while i0 < m {
        let mr = MR.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            let mut t = [[0.0f64; NR]; MR];
            for kk in 0..k {
                let abase = kk * lda + i0;
                let arow = &a[abase..abase + mr];
                let bbase = kk * ldb + j0;
                let brow = &b[bbase..bbase + nr];
                for di in 0..mr {
                    let av = arow[di];
                    let trow = &mut t[di];
                    for dj in 0..nr {
                        trow[dj] += av * brow[dj];
                    }
                }
            }
            write_tile(&t, mr, nr, c, ldc, i0, j0, acc);
            j0 += nr;
        }
        i0 += mr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn rand_buf(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.gaussian()).collect()
    }

    #[test]
    fn matches_naive_all_shapes() {
        let mut rng = Rng::new(7);
        // hit every mr/nr edge combination around the 4×4 tile
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 2, 5),
            (4, 4, 4),
            (5, 7, 3),
            (8, 1, 9),
            (13, 11, 6),
            (17, 32, 17),
        ] {
            let a = rand_buf(&mut rng, m * k);
            let b = rand_buf(&mut rng, k * n);
            let want = naive_nn(m, k, n, &a, &b);
            let mut c = vec![0.0; m * n];
            gemm_nn(m, k, n, &a, k, &b, n, &mut c, n, Acc::Store);
            for (got, want) in c.iter().zip(&want) {
                // identical per-element op order => exactly equal
                assert_eq!(got.to_bits(), want.to_bits(), "{m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn add_and_sub_accumulate() {
        let mut rng = Rng::new(8);
        let (m, k, n) = (6, 5, 7);
        let a = rand_buf(&mut rng, m * k);
        let b = rand_buf(&mut rng, k * n);
        let base = rand_buf(&mut rng, m * n);
        let prod = naive_nn(m, k, n, &a, &b);

        let mut c = base.clone();
        gemm_nn(m, k, n, &a, k, &b, n, &mut c, n, Acc::Add);
        for i in 0..m * n {
            assert_eq!(c[i].to_bits(), (base[i] + prod[i]).to_bits());
        }

        let mut c = base.clone();
        gemm_nn(m, k, n, &a, k, &b, n, &mut c, n, Acc::Sub);
        for i in 0..m * n {
            assert_eq!(c[i].to_bits(), (base[i] - prod[i]).to_bits());
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Rng::new(9);
        // A is k×m row-major; C = Aᵀ·B is m×n
        for &(m, k, n) in &[(3, 9, 4), (5, 5, 5), (10, 2, 7), (4, 16, 4)] {
            let a = rand_buf(&mut rng, k * m);
            let b = rand_buf(&mut rng, k * n);
            // explicit transpose then same k-ascending accumulation
            let mut at = vec![0.0; m * k];
            for kk in 0..k {
                for i in 0..m {
                    at[i * k + kk] = a[kk * m + i];
                }
            }
            let want = naive_nn(m, k, n, &at, &b);
            let mut c = vec![0.0; m * n];
            gemm_at_b(m, k, n, &a, m, &b, n, &mut c, n, Acc::Store);
            for (got, want) in c.iter().zip(&want) {
                assert_eq!(got.to_bits(), want.to_bits(), "{m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn strided_subviews_match_dense() {
        // aim the kernel at an interior sub-block of larger buffers and
        // check it sees exactly the same numbers as a packed copy
        let mut rng = Rng::new(10);
        let (m, k, n) = (5, 6, 4);
        let (lda, ldb, ldc) = (k + 3, n + 2, n + 5);
        let abuf = rand_buf(&mut rng, m * lda);
        let bbuf = rand_buf(&mut rng, k * ldb);
        let mut cbuf = vec![0.0; m * ldc];

        let mut ap = vec![0.0; m * k];
        for i in 0..m {
            ap[i * k..(i + 1) * k].copy_from_slice(&abuf[i * lda..i * lda + k]);
        }
        let mut bp = vec![0.0; k * n];
        for i in 0..k {
            bp[i * n..(i + 1) * n].copy_from_slice(&bbuf[i * ldb..i * ldb + n]);
        }
        let want = naive_nn(m, k, n, &ap, &bp);
        gemm_nn(m, k, n, &abuf, lda, &bbuf, ldb, &mut cbuf, ldc, Acc::Store);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(cbuf[i * ldc + j].to_bits(), want[i * n + j].to_bits());
            }
        }
    }

    #[test]
    fn empty_dims_are_noops() {
        let mut c = vec![42.0; 4];
        gemm_nn(0, 3, 2, &[], 3, &[0.0; 6], 2, &mut c, 2, Acc::Store);
        gemm_nn(2, 0, 2, &[], 0, &[], 2, &mut c, 2, Acc::Add);
        assert!(c.iter().all(|&x| x == 42.0));
    }
}
