//! One-sided Jacobi SVD for small square matrices.
//!
//! The TSVD extension (paper §III-B, last paragraph) factors the final
//! `R̃ = U Σ Vᵀ` on the leader — `R̃` is n×n so any robust serial SVD
//! works. One-sided Jacobi is simple, accurate (it computes small
//! singular values to high relative accuracy, which the stability
//! example exploits), and dependency-free.

use super::matrix::Matrix;

/// Result of `a = U · diag(sigma) · Vᵀ`.
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Matrix,
    pub sigma: Vec<f64>,
    pub v: Matrix,
}

/// One-sided Jacobi SVD of a square matrix.
///
/// Rotates column pairs of `W = A·V` until all pairs are orthogonal;
/// then `sigma_j = ‖w_j‖`, `u_j = w_j/sigma_j`. Singular values are
/// returned in descending order.
pub fn jacobi_svd(a: &Matrix) -> Svd {
    let n = a.rows;
    assert_eq!(a.cols, n, "jacobi_svd expects square input");
    let mut w = a.clone();
    let mut v = Matrix::identity(n);
    let eps = f64::EPSILON;

    // Cyclic sweeps until convergence (bounded for safety).
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2x2 Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..n {
                    let (wp, wq) = (w[(i, p)], w[(i, q)]);
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(f64::MIN_POSITIVE));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation annihilating the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    w[(i, p)] = c * wp - s * wq;
                    w[(i, q)] = s * wp + c * wq;
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off <= 4.0 * eps {
            break;
        }
    }

    // Extract sigma and U; handle (numerically) zero columns.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| (0..n).map(|i| w[(i, j)] * w[(i, j)]).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());

    let mut u = Matrix::zeros(n, n);
    let mut sigma = vec![0.0; n];
    let mut vv = Matrix::zeros(n, n);
    let mut zero_cols = Vec::new();
    for (newj, &oldj) in order.iter().enumerate() {
        sigma[newj] = norms[oldj];
        if norms[oldj] > 0.0 {
            for i in 0..n {
                u[(i, newj)] = w[(i, oldj)] / norms[oldj];
            }
        } else {
            zero_cols.push(newj);
        }
        for i in 0..n {
            vv[(i, newj)] = v[(i, oldj)];
        }
    }
    // Rank-deficient input: complete U to an orthonormal basis by
    // Gram-Schmidt of canonical vectors against the existing columns.
    for &j in &zero_cols {
        let mut best: Option<Vec<f64>> = None;
        for cand in 0..n {
            let mut e = vec![0.0f64; n];
            e[cand] = 1.0;
            for col in 0..n {
                if sigma[col] > 0.0 || col < j {
                    let dot: f64 = (0..n).map(|i| u[(i, col)] * e[i]).sum();
                    for (i, ei) in e.iter_mut().enumerate() {
                        *ei -= dot * u[(i, col)];
                    }
                }
            }
            let norm = e.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 0.5 {
                for x in &mut e {
                    *x /= norm;
                }
                best = Some(e);
                break;
            }
            if best.is_none() && norm > 1e-8 {
                for x in &mut e {
                    *x /= norm;
                }
                best = Some(e);
            }
        }
        if let Some(e) = best {
            for i in 0..n {
                u[(i, j)] = e[i];
            }
        } else {
            u[(j, j)] = 1.0; // unreachable for n >= 1 in practice
        }
    }
    Svd { u, sigma, v: vv }
}

impl Svd {
    /// Reconstruct `U Σ Vᵀ`.
    pub fn reconstruct(&self) -> Matrix {
        let n = self.sigma.len();
        let mut us = self.u.clone();
        for j in 0..n {
            for i in 0..us.rows {
                us[(i, j)] *= self.sigma[j];
            }
        }
        us.matmul(&self.v.transpose())
    }

    /// cond₂ = sigma_max / sigma_min (inf if singular).
    pub fn condition_number(&self) -> f64 {
        let smax = self.sigma.first().copied().unwrap_or(0.0);
        let smin = self.sigma.last().copied().unwrap_or(0.0);
        if smin == 0.0 {
            f64::INFINITY
        } else {
            smax / smin
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check(a: &Matrix, tol: f64) {
        let svd = jacobi_svd(a);
        let recon = a.sub(&svd.reconstruct()).frob_norm() / a.frob_norm().max(1e-300);
        assert!(recon < tol, "recon {recon}");
        assert!(svd.u.orthogonality_error() < tol);
        assert!(svd.v.orthogonality_error() < tol);
        for w in svd.sigma.windows(2) {
            assert!(w[0] >= w[1], "sigma not sorted: {:?}", svd.sigma);
        }
    }

    #[test]
    fn random_square() {
        let mut rng = Rng::new(1);
        for &n in &[1usize, 2, 5, 10, 25] {
            check(&Matrix::gaussian(n, n, &mut rng), 1e-12);
        }
    }

    #[test]
    fn diagonal_exact() {
        let mut d = Matrix::zeros(3, 3);
        d[(0, 0)] = 3.0;
        d[(1, 1)] = -1.0;
        d[(2, 2)] = 2.0;
        let svd = jacobi_svd(&d);
        let s = &svd.sigma;
        assert!((s[0] - 3.0).abs() < 1e-14);
        assert!((s[1] - 2.0).abs() < 1e-14);
        assert!((s[2] - 1.0).abs() < 1e-14);
    }

    #[test]
    fn singular_matrix() {
        // rank-1
        let u = Matrix::from_rows(3, 1, vec![1.0, 2.0, 2.0]);
        let a = u.matmul(&u.transpose());
        let svd = jacobi_svd(&a);
        assert!((svd.sigma[0] - 9.0).abs() < 1e-12);
        assert!(svd.sigma[1].abs() < 1e-12);
        check(&a, 1e-12);
    }

    #[test]
    fn tiny_singular_values_relative_accuracy() {
        // A = U diag(1, 1e-8) Vᵀ. Forming A at all perturbs sigma_min by
        // ~eps·‖A‖ ≈ 1e-16 absolute, i.e. ~1e-8 relative on 1e-8 — the
        // Jacobi recovery must stay within that inherent limit.
        let mut rng = Rng::new(2);
        let q1 = crate::linalg::random_orthogonal(2, &mut rng);
        let q2 = crate::linalg::random_orthogonal(2, &mut rng);
        let mut d = Matrix::zeros(2, 2);
        d[(0, 0)] = 1.0;
        d[(1, 1)] = 1e-8;
        let a = q1.matmul(&d).matmul(&q2.transpose());
        let svd = jacobi_svd(&a);
        assert!((svd.sigma[1] / 1e-8 - 1.0).abs() < 1e-6, "{:?}", svd.sigma);
    }

    #[test]
    fn condition_number() {
        let mut d = Matrix::zeros(2, 2);
        d[(0, 0)] = 8.0;
        d[(1, 1)] = 2.0;
        let svd = jacobi_svd(&d);
        assert!((svd.condition_number() - 4.0).abs() < 1e-12);
    }
}
