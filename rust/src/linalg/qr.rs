//! Householder QR — the coordinator-side factorization.
//!
//! [`householder_qr`] is the production entry point: it routes to the
//! blocked compact-WY panel kernel in [`super::block`], which factors
//! width-`b` panels and forms thin `Q` through gemm. The textbook
//! column-at-a-time loop is retained verbatim as
//! [`householder_qr_reference`] — it is the oracle the blocked kernel's
//! `R` must match *bitwise* (see `block.rs` module docs for why that
//! holds at any panel width) and the cross-check against the Python AOT
//! `qr_panel` kernel, whose shape grid and adversarial cases are ported
//! into the tests below.

use super::block::{blocked_qr, DEFAULT_PANEL};
use super::matrix::Matrix;

/// Thin QR factorization: `a (m×n, m ≥ n) -> (Q m×n, R n×n)`.
///
/// Numerically stable (backward error and orthogonality both `O(ε)`),
/// which is exactly the property the paper's Direct TSQR inherits.
/// Implemented as blocked panel QR at [`DEFAULT_PANEL`]; `R` is bitwise
/// identical to [`householder_qr_reference`].
pub fn householder_qr(a: &Matrix) -> (Matrix, Matrix) {
    blocked_qr(a, DEFAULT_PANEL)
}

/// Textbook column-at-a-time Householder QR — the bit-level oracle for
/// the blocked kernel and the seed's original implementation, kept
/// byte-for-byte. Slower than [`householder_qr`] (column-strided memory
/// access, no gemm); use only in tests and benches.
pub fn householder_qr_reference(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "householder_qr requires m >= n, got {m}x{n}");
    let mut work = a.clone();
    // Reflectors stored column-wise: v_j lives in vs[j*m..(j+1)*m].
    let mut vs = vec![0.0f64; m * n];

    for j in 0..n {
        // x = work[j.., j]; norm with scaling for overflow safety.
        let mut normx = 0.0f64;
        for i in j..m {
            normx = normx.hypot(work[(i, j)]);
        }
        let v = &mut vs[j * m..(j + 1) * m];
        for i in j..m {
            v[i] = work[(i, j)];
        }
        if normx > 0.0 {
            let alpha = if v[j] >= 0.0 { -normx } else { normx };
            v[j] -= alpha;
        }
        let vnorm2: f64 = v[j..].iter().map(|x| x * x).sum();
        let beta = if vnorm2 > 0.0 { 2.0 / vnorm2 } else { 0.0 };
        // trailing update: work -= v (beta vᵀ work)
        for col in j..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i] * work[(i, col)];
            }
            let s = beta * dot;
            if s != 0.0 {
                for i in j..m {
                    work[(i, col)] -= s * v[i];
                }
            }
        }
    }

    // R = upper triangle of the leading n rows.
    let mut r = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r[(i, j)] = work[(i, j)];
        }
    }

    // Thin Q = H_0 … H_{n-1} [I; 0].
    let mut q = Matrix::from_fn(m, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for j in (0..n).rev() {
        let v = &vs[j * m..(j + 1) * m];
        let vnorm2: f64 = v[j..].iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        let beta = 2.0 / vnorm2;
        for col in 0..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i] * q[(i, col)];
            }
            let s = beta * dot;
            if s != 0.0 {
                for i in j..m {
                    q[(i, col)] -= s * v[i];
                }
            }
        }
    }
    (q, r)
}

/// Sign-normalize a thin QR pair so `diag(R) ≥ 0` (QR is unique only up
/// to column signs; tests compare normalized factors).
pub fn sign_normalize(q: &mut Matrix, r: &mut Matrix) {
    let n = r.rows;
    for j in 0..n {
        if r[(j, j)] < 0.0 {
            for k in j..r.cols {
                r[(j, k)] = -r[(j, k)];
            }
            for i in 0..q.rows {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_qr(a: &Matrix, tol: f64) {
        let (q, r) = householder_qr(a);
        let recon_err = a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm().max(1e-300);
        assert!(recon_err < tol, "||A-QR||/||A|| = {recon_err}");
        assert!(q.orthogonality_error() < tol, "orth {}", q.orthogonality_error());
        assert!(r.is_upper_triangular(1e-14 * a.frob_norm().max(1.0)));
    }

    #[test]
    fn random_tall() {
        let mut rng = Rng::new(1);
        for &(m, n) in &[(8usize, 4usize), (50, 10), (200, 25), (64, 64)] {
            check_qr(&Matrix::gaussian(m, n, &mut rng), 1e-13);
        }
    }

    #[test]
    fn single_column() {
        let a = Matrix::from_rows(4, 1, vec![3.0, 0.0, 4.0, 0.0]);
        let (q, r) = householder_qr(&a);
        assert!((r[(0, 0)].abs() - 5.0).abs() < 1e-14);
        assert!(q.orthogonality_error() < 1e-14);
    }

    #[test]
    fn zero_column_no_nan() {
        let mut rng = Rng::new(4);
        let mut a = Matrix::gaussian(16, 4, &mut rng);
        for i in 0..16 {
            a[(i, 2)] = 0.0;
        }
        let (q, r) = householder_qr(&a);
        assert!(q.data.iter().all(|v| v.is_finite()));
        let recon = a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm();
        assert!(recon < 1e-13);
    }

    #[test]
    fn ill_conditioned_orthogonality() {
        // Columns spanning 14 orders of magnitude: Q must stay orthogonal.
        let mut rng = Rng::new(5);
        let mut a = Matrix::gaussian(100, 8, &mut rng);
        for j in 0..8 {
            let s = 10f64.powi(-(2 * j as i32));
            for i in 0..100 {
                a[(i, j)] *= s;
            }
        }
        let (q, _) = householder_qr(&a);
        assert!(q.orthogonality_error() < 1e-13);
    }

    #[test]
    fn matches_gram_cholesky_r() {
        // |R| from QR == chol(AᵀA) up to signs, for well-conditioned A.
        let mut rng = Rng::new(6);
        let a = Matrix::gaussian(60, 5, &mut rng);
        let (mut q, mut r) = householder_qr(&a);
        sign_normalize(&mut q, &mut r);
        let l = crate::linalg::cholesky(&a.gram()).unwrap();
        let lt = l.transpose();
        assert!(r.sub(&lt).max_abs() < 1e-10 * r.max_abs());
    }

    #[test]
    fn sign_normalize_makes_diag_nonneg() {
        let mut rng = Rng::new(7);
        let a = Matrix::gaussian(30, 6, &mut rng);
        let (mut q, mut r) = householder_qr(&a);
        sign_normalize(&mut q, &mut r);
        for j in 0..6 {
            assert!(r[(j, j)] >= 0.0);
        }
        let recon = a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm();
        assert!(recon < 1e-13);
    }

    // ---- cases ported from the Python AOT kernel oracle suite
    // (python/tests/test_kernel.py): same shape grid, adversarial
    // constructions, and tolerance structure, seeded through our Rng.

    /// The Python suite's `SHAPES` grid, verbatim.
    const ORACLE_SHAPES: [(usize, usize); 9] = [
        (8, 4),
        (32, 4),
        (64, 8),
        (100, 10),
        (128, 16),
        (256, 25),
        (300, 50),
        (512, 50),
        (256, 100),
    ];

    #[test]
    fn oracle_shape_grid() {
        // python: reconstruction and orthogonality < 1e-13 per shape,
        // R strictly upper-triangular; plus our stronger bit-level
        // check that blocked == reference on R.
        for (idx, &(m, n)) in ORACLE_SHAPES.iter().enumerate() {
            let mut rng = Rng::new((m * 1000 + n + idx) as u64);
            let a = Matrix::gaussian(m, n, &mut rng);
            check_qr(&a, 1e-13);
            let (_, r) = householder_qr(&a);
            let (_, r_ref) = householder_qr_reference(&a);
            let same = r.data.iter().zip(&r_ref.data).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "R bits drifted from reference at {m}x{n}");
        }
    }

    #[test]
    fn oracle_ill_conditioned_logspace() {
        // python: b=256, n=10, singular values logspace(0, -14, n);
        // orthogonality must survive at < 1e-13.
        let n = 10;
        let sigma: Vec<f64> = (0..n).map(|i| 10f64.powf(-14.0 * i as f64 / (n - 1) as f64)).collect();
        let mut rng = Rng::new(256 * 1000 + 10);
        let (a, _, _) = crate::linalg::matgen::matrix_with_spectrum(256, n, &sigma, &mut rng);
        let (q, r) = householder_qr(&a);
        assert!(q.orthogonality_error() < 1e-13);
        let recon = a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm();
        assert!(recon < 1e-13);
    }

    #[test]
    fn oracle_square_16() {
        // python: the m == n edge of the kernel contract.
        let mut rng = Rng::new(16 * 1000 + 16);
        let a = Matrix::gaussian(16, 16, &mut rng);
        check_qr(&a, 1e-13);
    }

    #[test]
    #[should_panic(expected = "m >= n")]
    fn oracle_wide_input_rejected() {
        // python: a 4×8 block must be rejected, not silently factored.
        let a = Matrix::zeros(4, 8);
        let _ = householder_qr(&a);
    }

    #[test]
    fn reference_and_blocked_agree_to_eps_on_adversarial_shapes() {
        // O(ε) agreement on Q (R is checked bitwise elsewhere): zero
        // column, 14-decade column scaling, and m == n.
        let mut rng = Rng::new(31);
        let mut zero_col = Matrix::gaussian(64, 8, &mut rng);
        for i in 0..64 {
            zero_col[(i, 3)] = 0.0;
        }
        let mut scaled = Matrix::gaussian(100, 8, &mut rng);
        for j in 0..8 {
            let s = 10f64.powi(-(2 * j as i32));
            for i in 0..100 {
                scaled[(i, j)] *= s;
            }
        }
        let square = Matrix::gaussian(32, 32, &mut rng);
        for a in [&zero_col, &scaled, &square] {
            let (mut q, mut r) = householder_qr(a);
            let (mut q_ref, mut r_ref) = householder_qr_reference(a);
            sign_normalize(&mut q, &mut r);
            sign_normalize(&mut q_ref, &mut r_ref);
            let scale = q_ref.max_abs().max(1.0);
            assert!(q.sub(&q_ref).max_abs() < 1e-12 * scale);
            let rscale = r_ref.max_abs().max(1e-300);
            assert!(r.sub(&r_ref).max_abs() < 1e-12 * rscale);
        }
    }
}
