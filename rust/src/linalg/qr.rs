//! Serial Householder QR — the coordinator-side factorization.
//!
//! Used for (a) the step-2 factorization of the stacked `R` factors when
//! routed on the leader instead of through PJRT, (b) the iterative-
//! refinement inner QR, and (c) as an independent oracle against the
//! Pallas `qr_panel` kernel in tests. Same algorithm as the kernel:
//! column-wise Householder reflections, thin `Q` formed by applying the
//! reflectors to `[I; 0]` in reverse.

use super::matrix::Matrix;

/// Thin QR factorization: `a (m×n, m ≥ n) -> (Q m×n, R n×n)`.
///
/// Numerically stable (backward error and orthogonality both `O(ε)`),
/// which is exactly the property the paper's Direct TSQR inherits.
pub fn householder_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "householder_qr requires m >= n, got {m}x{n}");
    let mut work = a.clone();
    // Reflectors stored column-wise: v_j lives in vs[j*m..(j+1)*m].
    let mut vs = vec![0.0f64; m * n];

    for j in 0..n {
        // x = work[j.., j]; norm with scaling for overflow safety.
        let mut normx = 0.0f64;
        for i in j..m {
            normx = normx.hypot(work[(i, j)]);
        }
        let v = &mut vs[j * m..(j + 1) * m];
        for i in j..m {
            v[i] = work[(i, j)];
        }
        if normx > 0.0 {
            let alpha = if v[j] >= 0.0 { -normx } else { normx };
            v[j] -= alpha;
        }
        let vnorm2: f64 = v[j..].iter().map(|x| x * x).sum();
        let beta = if vnorm2 > 0.0 { 2.0 / vnorm2 } else { 0.0 };
        // trailing update: work -= v (beta vᵀ work)
        for col in j..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i] * work[(i, col)];
            }
            let s = beta * dot;
            if s != 0.0 {
                for i in j..m {
                    work[(i, col)] -= s * v[i];
                }
            }
        }
    }

    // R = upper triangle of the leading n rows.
    let mut r = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r[(i, j)] = work[(i, j)];
        }
    }

    // Thin Q = H_0 … H_{n-1} [I; 0].
    let mut q = Matrix::from_fn(m, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for j in (0..n).rev() {
        let v = &vs[j * m..(j + 1) * m];
        let vnorm2: f64 = v[j..].iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        let beta = 2.0 / vnorm2;
        for col in 0..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i] * q[(i, col)];
            }
            let s = beta * dot;
            if s != 0.0 {
                for i in j..m {
                    q[(i, col)] -= s * v[i];
                }
            }
        }
    }
    (q, r)
}

/// Sign-normalize a thin QR pair so `diag(R) ≥ 0` (QR is unique only up
/// to column signs; tests compare normalized factors).
pub fn sign_normalize(q: &mut Matrix, r: &mut Matrix) {
    let n = r.rows;
    for j in 0..n {
        if r[(j, j)] < 0.0 {
            for k in j..r.cols {
                r[(j, k)] = -r[(j, k)];
            }
            for i in 0..q.rows {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_qr(a: &Matrix, tol: f64) {
        let (q, r) = householder_qr(a);
        let recon_err = a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm().max(1e-300);
        assert!(recon_err < tol, "||A-QR||/||A|| = {recon_err}");
        assert!(q.orthogonality_error() < tol, "orth {}", q.orthogonality_error());
        assert!(r.is_upper_triangular(1e-14 * a.frob_norm().max(1.0)));
    }

    #[test]
    fn random_tall() {
        let mut rng = Rng::new(1);
        for &(m, n) in &[(8usize, 4usize), (50, 10), (200, 25), (64, 64)] {
            check_qr(&Matrix::gaussian(m, n, &mut rng), 1e-13);
        }
    }

    #[test]
    fn single_column() {
        let a = Matrix::from_rows(4, 1, vec![3.0, 0.0, 4.0, 0.0]);
        let (q, r) = householder_qr(&a);
        assert!((r[(0, 0)].abs() - 5.0).abs() < 1e-14);
        assert!(q.orthogonality_error() < 1e-14);
    }

    #[test]
    fn zero_column_no_nan() {
        let mut rng = Rng::new(4);
        let mut a = Matrix::gaussian(16, 4, &mut rng);
        for i in 0..16 {
            a[(i, 2)] = 0.0;
        }
        let (q, r) = householder_qr(&a);
        assert!(q.data.iter().all(|v| v.is_finite()));
        let recon = a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm();
        assert!(recon < 1e-13);
    }

    #[test]
    fn ill_conditioned_orthogonality() {
        // Columns spanning 14 orders of magnitude: Q must stay orthogonal.
        let mut rng = Rng::new(5);
        let mut a = Matrix::gaussian(100, 8, &mut rng);
        for j in 0..8 {
            let s = 10f64.powi(-(2 * j as i32));
            for i in 0..100 {
                a[(i, j)] *= s;
            }
        }
        let (q, _) = householder_qr(&a);
        assert!(q.orthogonality_error() < 1e-13);
    }

    #[test]
    fn matches_gram_cholesky_r() {
        // |R| from QR == chol(AᵀA) up to signs, for well-conditioned A.
        let mut rng = Rng::new(6);
        let a = Matrix::gaussian(60, 5, &mut rng);
        let (mut q, mut r) = householder_qr(&a);
        sign_normalize(&mut q, &mut r);
        let l = crate::linalg::cholesky(&a.gram()).unwrap();
        let lt = l.transpose();
        assert!(r.sub(&lt).max_abs() < 1e-10 * r.max_abs());
    }

    #[test]
    fn sign_normalize_makes_diag_nonneg() {
        let mut rng = Rng::new(7);
        let a = Matrix::gaussian(30, 6, &mut rng);
        let (mut q, mut r) = householder_qr(&a);
        sign_normalize(&mut q, &mut r);
        for j in 0..6 {
            assert!(r[(j, j)] >= 0.0);
        }
        let recon = a.sub(&q.matmul(&r)).frob_norm() / a.frob_norm();
        assert!(recon < 1e-13);
    }
}
