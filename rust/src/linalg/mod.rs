//! Pure-rust dense linear algebra substrate.
//!
//! The paper's MapReduce algorithms interleave *distributed* block
//! computations (routed through the PJRT artifacts, see [`crate::runtime`])
//! with *serial* `n×n` steps executed on the coordinator node: the
//! Cholesky factorization of `AᵀA`, the triangular inverse for
//! `Q = A·R⁻¹`, the step-2 QR of the stacked R factors, and the small
//! SVD of `R̃` for the TSVD extension. This module implements those,
//! plus an independent oracle for every distributed kernel and the
//! prescribed-condition-number matrix generator used by the stability
//! study (paper Fig. 6).

pub mod cholesky;
pub mod matgen;
pub mod matrix;
pub mod qr;
pub mod svd;
pub mod trisolve;

pub use cholesky::{cholesky, CholeskyError};
pub use matgen::{matrix_with_condition, random_orthogonal};
pub use matrix::Matrix;
pub use qr::householder_qr;
pub use svd::jacobi_svd;
pub use trisolve::{back_substitute, tri_inverse_upper};
