//! Pure-rust dense linear algebra substrate.
//!
//! The paper's MapReduce algorithms interleave *distributed* block
//! computations (routed through the PJRT artifacts, see [`crate::runtime`])
//! with *serial* `n×n` steps executed on the coordinator node: the
//! Cholesky factorization of `AᵀA`, the triangular inverse for
//! `Q = A·R⁻¹`, the step-2 QR of the stacked R factors, and the small
//! SVD of `R̃` for the TSVD extension. This module implements those,
//! plus an independent oracle for every distributed kernel and the
//! prescribed-condition-number matrix generator used by the stability
//! study (paper Fig. 6).
//!
//! The hot-path kernels are blocked (PR 7): [`gemm`] is the tiled
//! f64 microkernel every product routes through, and [`block`] holds
//! the compact-WY panel QR behind [`householder_qr`], the batched
//! [`block::factor_blocks`] entry, and the κ-gated [`block::mixed_qr`]
//! fast path. The bit-determinism story — why `panel_block` and
//! batching are pure speed knobs — lives in the `block` module docs.

pub mod block;
pub mod cholesky;
pub mod gemm;
pub mod matgen;
pub mod matrix;
pub mod qr;
pub mod svd;
pub mod trisolve;

pub use block::{blocked_qr, factor_blocks, mixed_qr, PanelWorkspace, DEFAULT_PANEL, MIXED_KAPPA_MAX};
pub use cholesky::{cholesky, CholeskyError};
pub use matgen::{matrix_with_condition, random_orthogonal};
pub use matrix::Matrix;
pub use qr::{householder_qr, householder_qr_reference, sign_normalize};
pub use svd::jacobi_svd;
pub use trisolve::{back_substitute, tri_inverse_upper};
