//! Serial Cholesky factorization — the Cholesky-QR reduce-side step.
//!
//! The paper's Cholesky QR computes `AᵀA` in MapReduce, gathers the
//! small `n×n` Gram matrix on one node, and factors it serially. The
//! crucial *failure mode* (Fig. 6): `cond(AᵀA) = cond(A)²`, so for
//! `cond(A) ≳ 1e8` the Gram matrix is numerically indefinite and the
//! factorization **breaks down** — surfaced here as
//! [`CholeskyError::NotPositiveDefinite`] rather than NaNs.

use super::matrix::Matrix;
use thiserror::Error;

#[derive(Debug, Error, Clone, PartialEq)]
pub enum CholeskyError {
    #[error("matrix is not positive definite (pivot {pivot:.3e} at index {index}) — for Cholesky QR this means cond(A)^2 exceeded 1/eps")]
    NotPositiveDefinite { index: usize, pivot: f64 },
    #[error("matrix is not square: {rows}x{cols}")]
    NotSquare { rows: usize, cols: usize },
}

/// Lower-triangular `L` with `A = L·Lᵀ` (Cholesky–Banachiewicz).
pub fn cholesky(a: &Matrix) -> Result<Matrix, CholeskyError> {
    if a.rows != a.cols {
        return Err(CholeskyError::NotSquare { rows: a.rows, cols: a.cols });
    }
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return Err(CholeskyError::NotPositiveDefinite { index: i, pivot: s });
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn factors_spd() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(40, 6, &mut rng);
        let g = a.gram();
        let l = cholesky(&g).unwrap();
        let recon = g.sub(&l.matmul(&l.transpose()));
        assert!(recon.max_abs() < 1e-10 * g.max_abs());
        assert!(l.transpose().is_upper_triangular(0.0));
    }

    #[test]
    fn known_2x2() {
        let a = Matrix::from_rows(2, 2, vec![4.0, 2.0, 2.0, 5.0]);
        let l = cholesky(&a).unwrap();
        assert!((l[(0, 0)] - 2.0).abs() < 1e-15);
        assert!((l[(1, 0)] - 1.0).abs() < 1e-15);
        assert!((l[(1, 1)] - 2.0).abs() < 1e-15);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        match cholesky(&a) {
            Err(CholeskyError::NotPositiveDefinite { index: 1, .. }) => {}
            other => panic!("expected breakdown, got {other:?}"),
        }
    }

    #[test]
    fn rejects_nonsquare() {
        let a = Matrix::zeros(2, 3);
        assert!(matches!(cholesky(&a), Err(CholeskyError::NotSquare { .. })));
    }

    #[test]
    fn breaks_down_past_condition_1e8() {
        // The paper's Fig. 6 phenomenon: gram of cond ~ 1e9 matrix fails.
        let mut rng = Rng::new(2);
        let a = crate::linalg::matrix_with_condition(80, 8, 1e9, &mut rng);
        let g = a.gram();
        assert!(cholesky(&g).is_err(), "expected κ² breakdown");
    }

    #[test]
    fn survives_condition_1e6() {
        let mut rng = Rng::new(3);
        let a = crate::linalg::matrix_with_condition(80, 8, 1e6, &mut rng);
        assert!(cholesky(&a.gram()).is_ok());
    }
}
