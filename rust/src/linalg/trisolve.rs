//! Triangular solves and inversion — the `Q = A·R⁻¹` indirect path.
//!
//! The paper's indirect methods compute `R⁻¹` serially on the leader
//! (R is n×n upper triangular, cheap) and broadcast it to the map tasks
//! that form `A_i · R⁻¹`. This inversion is the *numerically unstable*
//! step the Direct TSQR avoids: the forward error scales with cond(R).

use super::matrix::Matrix;

/// Solve `R x = b` for upper-triangular `R` by back substitution.
pub fn back_substitute(r: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        for j in i + 1..n {
            x[i] -= r[(i, j)] * x[j];
        }
        x[i] /= r[(i, i)];
    }
    x
}

/// Inverse of an upper-triangular matrix (column-by-column back subst).
///
/// Returns `None` if a diagonal entry is zero/non-finite (singular R —
/// the paper assumes full-rank A throughout).
pub fn tri_inverse_upper(r: &Matrix) -> Option<Matrix> {
    let n = r.rows;
    assert_eq!(r.cols, n);
    for i in 0..n {
        if r[(i, i)] == 0.0 || !r[(i, i)].is_finite() {
            return None;
        }
    }
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0f64; n];
    for col in 0..n {
        e[col] = 1.0;
        let x = back_substitute(r, &e);
        e[col] = 0.0;
        for i in 0..n {
            inv[(i, col)] = x[i];
        }
    }
    Some(inv)
}

/// Solve `Lᵀ·x = b` given lower-triangular L (used by Cholesky QR:
/// `R = Lᵀ`, so `A·R⁻¹` needs `R⁻¹ = L⁻ᵀ`).
pub fn lower_transpose_inverse(l: &Matrix) -> Option<Matrix> {
    tri_inverse_upper(&l.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::householder_qr;
    use crate::util::rng::Rng;

    #[test]
    fn back_substitute_known() {
        let r = Matrix::from_rows(2, 2, vec![2.0, 1.0, 0.0, 4.0]);
        let x = back_substitute(&r, &[4.0, 8.0]);
        assert_eq!(x, vec![1.0, 2.0]);
    }

    #[test]
    fn inverse_times_r_is_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(30, 6, &mut rng);
        let (_, r) = householder_qr(&a);
        let rinv = tri_inverse_upper(&r).unwrap();
        let eye = r.matmul(&rinv);
        let mut err = eye.clone();
        for i in 0..6 {
            err[(i, i)] -= 1.0;
        }
        assert!(err.max_abs() < 1e-12);
        // R⁻¹ of upper triangular is upper triangular
        assert!(rinv.is_upper_triangular(1e-14));
    }

    #[test]
    fn singular_returns_none() {
        let mut r = Matrix::identity(3);
        r[(1, 1)] = 0.0;
        assert!(tri_inverse_upper(&r).is_none());
    }

    #[test]
    fn lower_transpose_matches() {
        let mut rng = Rng::new(2);
        let a = Matrix::gaussian(30, 5, &mut rng);
        let l = crate::linalg::cholesky(&a.gram()).unwrap();
        let inv = lower_transpose_inverse(&l).unwrap();
        let eye = l.transpose().matmul(&inv);
        let mut err = eye;
        for i in 0..5 {
            err[(i, i)] -= 1.0;
        }
        assert!(err.max_abs() < 1e-10);
    }
}
