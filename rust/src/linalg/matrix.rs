//! Row-major dense matrix with the operations the coordinator needs.

use super::gemm;
use crate::util::rng::Rng;
use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major `rows × cols` matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn gaussian(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.gaussian()).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Rows `[start, end)` as a new matrix.
    pub fn slice_rows(&self, start: usize, end: usize) -> Matrix {
        assert!(start <= end && end <= self.rows);
        Matrix {
            rows: end - start,
            cols: self.cols,
            data: self.data[start * self.cols..end * self.cols].to_vec(),
        }
    }

    /// Leading `r × c` principal block.
    pub fn block(&self, r: usize, c: usize) -> Matrix {
        assert!(r <= self.rows && c <= self.cols);
        Matrix::from_fn(r, c, |i, j| self[(i, j)])
    }

    /// Stack matrices vertically (all must share `cols`).
    pub fn vstack(parts: &[&Matrix]) -> Matrix {
        assert!(!parts.is_empty());
        let cols = parts[0].cols;
        let rows = parts.iter().map(|p| {
            assert_eq!(p.cols, cols, "vstack column mismatch");
            p.rows
        }).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        Matrix { rows, cols, data }
    }

    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// `self · other`, routed through the tiled gemm microkernel
    /// ([`crate::linalg::gemm`]). Per-element k-ascending accumulation
    /// keeps the result bitwise identical to the seed's naive loop for
    /// finite inputs (a `+0.0`-initialized accumulator can never turn
    /// into `−0.0` under round-to-nearest, so dropping the old
    /// skip-zero shortcut does not move bits).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        gemm::gemm_nn(m, k, n, &self.data, k, &other.data, n, &mut out.data, n, gemm::Acc::Store);
        out
    }

    /// `selfᵀ · self` — the Gram matrix, via the transposed gemm
    /// kernel. Both triangles come out of the same row-ascending
    /// accumulation, so the result stays exactly symmetric (bitwise)
    /// like the seed's mirror-the-upper-triangle loop.
    pub fn gram(&self) -> Matrix {
        let (m, n) = (self.rows, self.cols);
        let mut g = Matrix::zeros(n, n);
        gemm::gemm_at_b(n, m, n, &self.data, n, &self.data, n, &mut g.data, n, gemm::Acc::Store);
        g
    }

    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|v| v * s).collect(),
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Spectral norm ‖·‖₂ via power iteration on `AᵀA` (the error
    /// metrics in the paper are 2-norms of small matrices).
    pub fn norm2(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        let g = if self.rows >= self.cols {
            self.gram()
        } else {
            self.transpose().gram()
        };
        let n = g.rows;
        let mut v = vec![1.0 / (n as f64).sqrt(); n];
        let mut lambda = 0.0f64;
        for _ in 0..200 {
            let mut w = vec![0.0; n];
            for i in 0..n {
                let gr = g.row(i);
                w[i] = gr.iter().zip(&v).map(|(a, b)| a * b).sum();
            }
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm == 0.0 {
                return 0.0;
            }
            let next = norm;
            for x in &mut w {
                *x /= norm;
            }
            v = w;
            if (next - lambda).abs() <= 1e-13 * next.max(1.0) {
                lambda = next;
                break;
            }
            lambda = next;
        }
        lambda.max(0.0).sqrt()
    }

    /// `‖QᵀQ − I‖₂` — the paper's orthogonality loss metric.
    pub fn orthogonality_error(&self) -> f64 {
        let mut g = self.gram();
        for i in 0..g.rows {
            g[(i, i)] -= 1.0;
        }
        g.norm2()
    }

    /// Max |aᵢⱼ| — used for exactness assertions.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    pub fn is_upper_triangular(&self, tol: f64) -> bool {
        for i in 0..self.rows {
            for j in 0..i.min(self.cols) {
                if self[(i, j)].abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            write!(f, "  [")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}]", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(3, 2);
        m[(2, 1)] = 5.0;
        assert_eq!(m[(2, 1)], 5.0);
        assert_eq!(m.row(2), &[0.0, 5.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_rows(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(5, 3, &mut rng);
        let c = a.matmul(&Matrix::identity(3));
        assert_eq!(c.data, a.data);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = Matrix::gaussian(20, 4, &mut rng);
        let g1 = a.gram();
        let g2 = a.transpose().matmul(&a);
        assert!(g1.sub(&g2).max_abs() < 1e-12);
    }

    #[test]
    fn vstack_and_slice() {
        let a = Matrix::from_rows(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_rows(2, 2, vec![3.0, 4.0, 5.0, 6.0]);
        let s = Matrix::vstack(&[&a, &b]);
        assert_eq!(s.rows, 3);
        assert_eq!(s.slice_rows(1, 3).data, b.data);
    }

    #[test]
    fn norm2_of_diag() {
        let mut d = Matrix::zeros(3, 3);
        d[(0, 0)] = 3.0;
        d[(1, 1)] = -7.0;
        d[(2, 2)] = 2.0;
        assert!((d.norm2() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn norm2_matches_frob_for_rank1() {
        // rank-1: ||A||_2 == ||A||_F
        let u = Matrix::from_rows(3, 1, vec![1.0, 2.0, 2.0]);
        let v = Matrix::from_rows(1, 2, vec![3.0, 4.0]);
        let a = u.matmul(&v);
        assert!((a.norm2() - a.frob_norm()).abs() < 1e-9);
    }

    #[test]
    fn orthogonality_error_of_identity_cols() {
        let q = Matrix::from_fn(6, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        assert!(q.orthogonality_error() < 1e-12);
    }

    #[test]
    fn upper_triangular_check() {
        let mut r = Matrix::zeros(3, 3);
        r[(0, 1)] = 1.0;
        assert!(r.is_upper_triangular(0.0));
        r[(2, 0)] = 0.5;
        assert!(!r.is_upper_triangular(1e-12));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(4, 7, &mut rng);
        assert_eq!(a.transpose().transpose().data, a.data);
    }
}
